"""Pallas kernels vs pure-jnp oracles: forward values and custom-VJP
gradients, swept over shapes (and a dtype spot-check) with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, mlp_block, ref, survival_theta
from compile.kernels.mlp_block import BLOCK_ROWS, vmem_bytes

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- mlp_block


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 7, 32, 128, 131, 256]),
    d_in=st.sampled_from([8, 16, 64]),
    d_h=st.sampled_from([16, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_forward_matches_ref(rows, d_in, d_h, seed):
    x = _rand(seed, (rows, d_in))
    w1 = _rand(seed + 1, (d_in, d_h), 0.2)
    w2 = _rand(seed + 2, (d_h, d_in), 0.2)
    got = mlp_block(x, w1, w2)
    want = ref.mlp_block(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([4, 63, 128, 200]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_gradients_match_ref(rows, seed):
    d_in, d_h = 16, 48
    x = _rand(seed, (rows, d_in))
    w1 = _rand(seed + 1, (d_in, d_h), 0.2)
    w2 = _rand(seed + 2, (d_h, d_in), 0.2)

    def loss_kernel(x, w1, w2):
        return jnp.sum(mlp_block(x, w1, w2) ** 2)

    def loss_ref(x, w1, w2):
        return jnp.sum(ref.mlp_block(x, w1, w2) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w1, w2)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w1, w2)
    for a, b, name in zip(gk, gr, ["dx", "dw1", "dw2"]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4, err_msg=name)


def test_mlp_multi_block_accumulation():
    # rows > BLOCK_ROWS exercises the revisited-output dw accumulation.
    rows = BLOCK_ROWS * 3
    x = _rand(0, (rows, 8))
    w1 = _rand(1, (8, 24), 0.2)
    w2 = _rand(2, (24, 8), 0.2)
    gk = jax.grad(lambda w: jnp.sum(mlp_block(x, w, w2)))(w1)
    gr = jax.grad(lambda w: jnp.sum(ref.mlp_block(x, w, w2)))(w1)
    np.testing.assert_allclose(gk, gr, rtol=2e-4, atol=2e-4)


def test_mlp_under_jit_and_vmem_estimate():
    x = _rand(3, (64, 16))
    w1 = _rand(4, (16, 64), 0.2)
    w2 = _rand(5, (64, 16), 0.2)
    got = jax.jit(mlp_block)(x, w1, w2)
    np.testing.assert_allclose(got, ref.mlp_block(x, w1, w2), rtol=3e-5, atol=3e-5)
    # VMEM estimate: static formula, sanity range (< 16 MiB for our sizes).
    assert vmem_bytes(64, 16, 64, 16) < 16 * 2**20


# ---------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    bh=st.sampled_from([1, 3, 8]),
    t=st.sampled_from([1, 4, 16, 33]),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_forward_matches_ref(bh, t, d, seed):
    q = _rand(seed, (bh, t, d))
    k = _rand(seed + 1, (bh, t, d))
    v = _rand(seed + 2, (bh, t, d))
    got = attention(q, k, v)
    want = jnp.stack([ref.attention(q[i], k[i], v[i]) for i in range(bh)])
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(t=st.sampled_from([2, 8, 17]), seed=st.integers(0, 2**31 - 1))
def test_attention_gradients_match_ref(t, seed):
    bh, d = 4, 8
    q = _rand(seed, (bh, t, d))
    k = _rand(seed + 1, (bh, t, d))
    v = _rand(seed + 2, (bh, t, d))

    def loss_kernel(q, k, v):
        return jnp.sum(attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        o = jnp.stack([ref.attention(q[i], k[i], v[i]) for i in range(bh)])
        return jnp.sum(o**2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, ["dq", "dk", "dv"]):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4, err_msg=name)


def test_attention_is_causal():
    # Changing a future kv pair must not change earlier outputs.
    q = _rand(0, (1, 8, 4))
    k = _rand(1, (1, 8, 4))
    v = _rand(2, (1, 8, 4))
    base = attention(q, k, v)
    k2 = k.at[0, 7].add(100.0)
    v2 = v.at[0, 7].add(-50.0)
    pert = attention(q, k2, v2)
    np.testing.assert_allclose(base[0, :7], pert[0, :7], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[0, 7], pert[0, 7])


def test_attention_rows_are_convex_combinations():
    # Softmax weights sum to 1 ⇒ output rows lie in the convex hull of v
    # rows; with constant v the output equals v.
    q = _rand(0, (2, 6, 4))
    k = _rand(1, (2, 6, 4))
    v = jnp.ones((2, 6, 4)) * 3.0
    out = attention(q, k, v)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- survival


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 7, 128, 256]),
    k=st.sampled_from([1, 16, 64]),
    q=st.floats(1e-4, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_theta_matches_ref(n, k, q, seed):
    key = jax.random.PRNGKey(seed)
    elapsed = jnp.abs(jax.random.normal(key, (n, k))) * 100
    qv = jnp.full((n,), q, dtype=jnp.float32)
    mask = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (n, k)) > 0.3).astype(jnp.float32)
    got = survival_theta(elapsed, qv, mask)
    want = ref.survival_theta(elapsed, qv, mask)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_theta_bounds_and_base():
    # No known walks → theta = 0.5 everywhere; full mask at elapsed 0 →
    # theta = 0.5 + K.
    n, k = 8, 16
    elapsed = jnp.zeros((n, k))
    q = jnp.full((n,), 0.1)
    got0 = survival_theta(elapsed, q, jnp.zeros((n, k)))
    np.testing.assert_allclose(got0, 0.5, rtol=1e-6)
    got1 = survival_theta(elapsed, q, jnp.ones((n, k)))
    np.testing.assert_allclose(got1, 0.5 + k, rtol=1e-6)


def test_theta_monotone_in_elapsed():
    n, k = 4, 8
    q = jnp.full((n,), 0.05)
    mask = jnp.ones((n, k))
    t1 = survival_theta(jnp.full((n, k), 10.0), q, mask)
    t2 = survival_theta(jnp.full((n, k), 50.0), q, mask)
    assert (t1 > t2).all()


@pytest.mark.parametrize("pad", [0, 3])
def test_theta_mask_excludes_walks(pad):
    n, k = 4, 8
    q = jnp.full((n,), 0.05)
    elapsed = jnp.full((n, k), 5.0)
    mask = jnp.ones((n, k)).at[:, :pad].set(0.0)
    got = survival_theta(elapsed, q, mask)
    want = 0.5 + (k - pad) * (1 - 0.05) ** 5
    np.testing.assert_allclose(got, want, rtol=1e-5)
