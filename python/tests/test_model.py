"""L2 model tests: shapes, loss behaviour, training dynamics, flattening
round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, forward, init_params, loss_fn, make_flat_fns

TINY = ModelConfig(vocab=16, seq=16, d_model=32, n_heads=2, n_layers=1, batch=4, lr=0.3)


def test_forward_shapes():
    params = init_params(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, TINY.seq), 0, TINY.vocab)
    logits = forward(params, toks, TINY)
    assert logits.shape == (4, TINY.seq, TINY.vocab)
    assert jnp.isfinite(logits).all()


def test_initial_loss_near_uniform():
    params = init_params(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, TINY.seq + 1), 0, TINY.vocab)
    l = loss_fn(params, toks, TINY)
    # Near-zero init ⇒ near-uniform logits ⇒ loss ≈ ln(vocab).
    assert abs(float(l) - np.log(TINY.vocab)) < 0.1


def test_train_step_reduces_loss():
    flat0, train_step, _ = make_flat_fns(TINY)
    step = jax.jit(train_step)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, TINY.seq + 1), 0, TINY.vocab)
    p = flat0
    losses = []
    for _ in range(25):
        p, l = step(p, toks)
        losses.append(float(l))
    assert losses[-1] < 0.5 * losses[0], losses


def test_eval_loss_matches_train_loss_value():
    flat0, train_step, eval_loss = make_flat_fns(TINY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, TINY.seq + 1), 0, TINY.vocab)
    (le,) = eval_loss(flat0, toks)
    _, lt = train_step(flat0, toks)
    np.testing.assert_allclose(float(le), float(lt), rtol=1e-5)


def test_flatten_roundtrip_deterministic():
    f1, _, _ = make_flat_fns(TINY)
    f2, _, _ = make_flat_fns(TINY)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert f1.dtype == jnp.float32
    assert f1.ndim == 1


def test_param_count_formula():
    flat0, _, _ = make_flat_fns(TINY)
    d, v, t = TINY.d_model, TINY.vocab, TINY.seq
    expected = (
        v * d  # embed
        + t * d  # pos
        + d * v  # out
        + 2 * d  # ln_f
        + TINY.n_layers * (2 * d + d * 3 * d + d * d + 2 * d + d * 4 * d + 4 * d * d)
    )
    assert flat0.shape[0] == expected


def test_gradients_flow_to_all_params():
    cfg = TINY
    flat0, train_step, _ = make_flat_fns(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (4, cfg.seq + 1), 0, cfg.vocab)
    new, _ = train_step(flat0, toks)
    moved = np.asarray(new) != np.asarray(flat0)
    # Positional embeddings / LNs / all matrices should receive gradient;
    # the embedding rows of unseen tokens stay put, so demand > 80%.
    assert moved.mean() > 0.8, moved.mean()


@pytest.mark.parametrize("heads", [1, 2, 4])
def test_head_count_variants(heads):
    cfg = ModelConfig(vocab=16, seq=8, d_model=32, n_heads=heads, n_layers=1, batch=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq), 0, cfg.vocab)
    logits = forward(params, toks, cfg)
    assert logits.shape == (2, cfg.seq, cfg.vocab)


def test_causality_of_full_model():
    # Changing the last input token must not change earlier logits.
    params = init_params(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, TINY.seq), 0, TINY.vocab)
    base = forward(params, toks, TINY)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % TINY.vocab)
    pert = forward(params, toks2, TINY)
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], rtol=1e-5, atol=1e-6)
