"""AOT pipeline tests: artifact emission, manifest consistency, and the
HLO-text interchange invariants the rust loader depends on."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ, DECAFORK_MODEL="tiny")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--model", "tiny"],
        cwd=ROOT,
        env=env,
        check=True,
        capture_output=True,
    )
    return out


def _manifest(artifacts):
    text = (artifacts / "manifest.txt").read_text()
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        k, v = line.split("=", 1)
        out[k] = v
    return out


def test_all_artifacts_emitted(artifacts):
    for name in [
        "train_step.hlo.txt",
        "eval_loss.hlo.txt",
        "survival_theta.hlo.txt",
        "init_params.f32",
        "manifest.txt",
    ]:
        assert (artifacts / name).exists(), name


def test_manifest_keys_and_consistency(artifacts):
    m = _manifest(artifacts)
    for key in [
        "model",
        "vocab",
        "seq",
        "batch",
        "lr",
        "param_count",
        "train_step",
        "theta_kernel",
        "theta_nodes",
        "theta_walks",
        "init_params",
    ]:
        assert key in m, key
    # init_params length must equal 4 * param_count bytes.
    raw = (artifacts / m["init_params"]).stat().st_size
    assert raw == 4 * int(m["param_count"])


def test_hlo_is_text_with_entry(artifacts):
    text = (artifacts / "train_step.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text
    # The tuple-return convention the rust side unwraps.
    assert "tuple" in text.lower()


def test_hlo_has_no_custom_calls(artifacts):
    # interpret=True must lower the Pallas kernels to plain HLO; a Mosaic
    # custom-call would be unloadable by the CPU PJRT client.
    for name in ["train_step.hlo.txt", "survival_theta.hlo.txt"]:
        text = (artifacts / name).read_text()
        assert "mosaic" not in text.lower(), name
        assert "tpu_custom_call" not in text.lower(), name


def test_hlo_parameter_shapes_match_manifest(artifacts):
    m = _manifest(artifacts)
    text = (artifacts / "train_step.hlo.txt").read_text()
    pc = m["param_count"]
    b = m["batch"]
    t1 = int(m["seq"]) + 1
    assert f"f32[{pc}]" in text, "flat param vector shape missing"
    assert f"s32[{b},{t1}]" in text, "token batch shape missing"


def test_theta_kernel_shapes(artifacts):
    m = _manifest(artifacts)
    text = (artifacts / "survival_theta.hlo.txt").read_text()
    n, k = m["theta_nodes"], m["theta_walks"]
    assert f"f32[{n},{k}]" in text
    assert f"f32[{n}]" in text
