"""Layer-2: the char-level transformer LM trained by random-walk SGD.

The walk token carries a *flattened* f32 parameter vector (one PJRT buffer
on the rust side); `train_step` unflattens, runs fwd/bwd (through the
Pallas kernels in `kernels/`) and one SGD update, and reflattens. The
whole function is jitted and AOT-lowered by `aot.py`.

Model: untied embedding, learned positional embedding, `n_layers` blocks
of (pre-LN causal multi-head attention → pre-LN fused MLP), final LN,
output projection; cross-entropy next-token loss.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.attention import attention
from .kernels.mlp_block import mlp_block


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 32
    seq: int = 32
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    batch: int = 8
    lr: float = 0.3
    init_scale: float = 0.02

    @property
    def d_head(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def init_params(key, cfg: ModelConfig):
    """Initialize the parameter pytree."""
    ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
    s = cfg.init_scale
    params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * s,
        "pos": jax.random.normal(ks[1], (cfg.seq, cfg.d_model)) * s,
        "out_w": jax.random.normal(ks[2], (cfg.d_model, cfg.vocab)) * s,
        "ln_f": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        k0, k1, k2, k3 = ks[4 + 4 * i : 8 + 4 * i]
        params["blocks"].append(
            {
                "ln1": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
                "wqkv": jax.random.normal(k0, (cfg.d_model, 3 * cfg.d_model)) * s,
                "wo": jax.random.normal(k1, (cfg.d_model, cfg.d_model)) * s,
                "ln2": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
                "w1": jax.random.normal(k2, (cfg.d_model, 4 * cfg.d_model)) * s,
                "w2": jax.random.normal(k3, (4 * cfg.d_model, cfg.d_model)) * s,
            }
        )
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(params, tokens, cfg: ModelConfig):
    """Logits for input tokens (B, T) → (B, T, vocab)."""
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :t, :]
    for blk in params["blocks"]:
        # Attention sublayer (pre-LN).
        h = _layer_norm(x, blk["ln1"]["g"], blk["ln1"]["b"])
        qkv = h.reshape(b * t, cfg.d_model) @ blk["wqkv"]
        qkv = qkv.reshape(b, t, 3, cfg.n_heads, cfg.d_head)
        # (B, T, 3, H, dh) → 3 x (B*H, T, dh)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, t, cfg.d_head)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, t, cfg.d_head)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, t, cfg.d_head)
        o = attention(q, k, v)  # Pallas kernel (L1)
        o = o.reshape(b, cfg.n_heads, t, cfg.d_head).transpose(0, 2, 1, 3)
        o = o.reshape(b * t, cfg.d_model) @ blk["wo"]
        x = x + o.reshape(b, t, cfg.d_model)
        # MLP sublayer (pre-LN, fused Pallas kernel).
        h = _layer_norm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        m = mlp_block(h.reshape(b * t, cfg.d_model), blk["w1"], blk["w2"])
        x = x + m.reshape(b, t, cfg.d_model)
    x = _layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    return x @ params["out_w"]


def loss_fn(params, tokens, cfg: ModelConfig):
    """Mean next-token cross-entropy. tokens: (B, T+1) int32."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_flat_fns(cfg: ModelConfig, key=None):
    """Build (flat_init, train_step, eval_loss) over flattened params."""
    if key is None:
        key = jax.random.PRNGKey(0)
    params0 = init_params(key, cfg)
    flat0, unravel = ravel_pytree(params0)

    def train_step(flat_params, tokens):
        params = unravel(flat_params)
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
        new_params = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g, params, grads)
        new_flat, _ = ravel_pytree(new_params)
        return new_flat, loss

    def eval_loss(flat_params, tokens):
        return (loss_fn(unravel(flat_params), tokens, cfg),)

    return flat0, train_step, eval_loss
