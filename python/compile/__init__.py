# Build-time-only package: JAX model (L2) + Pallas kernels (L1) + AOT
# lowering to HLO text. Never imported by the runtime (rust loads the
# artifacts directly).
