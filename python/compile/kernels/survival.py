"""Batched DECAFORK estimator sweep as a Pallas kernel.

Computes theta[i] = 0.5 + sum_k mask[i,k] * (1-q[i])^elapsed[i,k] for all
nodes at once — Eq. (1) under the analytic geometric survival (paper
footnote 5). The rust coordinator evaluates theta node-by-node on its hot
path; this kernel exists to show the control plane itself batch-offloads:
one call refreshes every node's estimate (e.g. for monitoring dashboards
or the threshold-design sweeps), and on a TPU it is a pure VPU
elementwise + row-reduction kernel.

Grid: row (node) blocks; each step holds an (N_block, K) elapsed/mask tile
and the matching q slice in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True
BLOCK_NODES = 128


def _kernel(elapsed_ref, q_ref, mask_ref, theta_ref):
    elapsed = elapsed_ref[...]
    q = q_ref[...]
    mask = mask_ref[...]
    log1mq = jnp.log1p(-q)[:, None]
    surv = jnp.exp(elapsed * log1mq)
    theta_ref[...] = 0.5 + jnp.sum(surv * mask, axis=-1)


def survival_theta(elapsed, q, mask):
    """theta over all nodes. elapsed/mask: (N, K) f32, q: (N,) f32."""
    n, k = elapsed.shape
    if n <= BLOCK_NODES:
        grid = (1,)
        mat = pl.BlockSpec((n, k), lambda i: (0, 0))
        vec = pl.BlockSpec((n,), lambda i: (0,))
    else:
        assert n % BLOCK_NODES == 0, "N must be a multiple of BLOCK_NODES"
        grid = (n // BLOCK_NODES,)
        mat = pl.BlockSpec((BLOCK_NODES, k), lambda i: (i, 0))
        vec = pl.BlockSpec((BLOCK_NODES,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[mat, vec, mat],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((n,), elapsed.dtype),
        interpret=INTERPRET,
    )(elapsed, q, mask)
