"""Fused transformer MLP block as a Pallas kernel with a custom VJP.

Forward:  y = gelu(x @ w1) @ w2, row-tiled so each grid step streams one
row-block of activations through VMEM while both weight matrices stay
resident (the dominant VMEM tenant; see the footprint estimate in
``vmem_bytes``). Backward: a second Pallas kernel recomputes the hidden
pre-activation for its row block (rematerialization — cheaper than saving
`h` to HBM, the standard TPU trade) and accumulates dw1/dw2 across grid
steps with the revisited-output-block pattern.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU the same BlockSpecs drive the HBM↔VMEM
schedule (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# Row-block size: multiples of 8 (f32 sublane) — 128 rows x d<=512 keeps
# x-tile + h-tile + weights well under a 16 MiB VMEM budget.
BLOCK_ROWS = 128


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def _gelu_grad(x):
    th = jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3))
    inner = 0.7978845608028654 * (1.0 + 3 * 0.044715 * x**2)
    return 0.5 * (1.0 + th) + 0.5 * x * (1.0 - th**2) * inner


def _fwd_kernel(x_ref, w1_ref, w2_ref, y_ref):
    x = x_ref[...]
    h = x @ w1_ref[...]
    y_ref[...] = _gelu(h) @ w2_ref[...]


def _bwd_kernel(x_ref, w1_ref, w2_ref, dy_ref, dx_ref, dw1_ref, dw2_ref):
    # Recompute the hidden pre-activation for this row block.
    i = pl.program_id(0)
    x = x_ref[...]
    dy = dy_ref[...]
    h = x @ w1_ref[...]
    a = _gelu(h)
    da = dy @ w2_ref[...].T
    dh = da * _gelu_grad(h)
    dx_ref[...] = dh @ w1_ref[...].T

    # dw accumulation: the full dw1/dw2 output blocks are revisited by
    # every grid step; initialize on the first and accumulate after.
    @pl.when(i == 0)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref)
        dw2_ref[...] = jnp.zeros_like(dw2_ref)

    dw1_ref[...] += x.T @ dh
    dw2_ref[...] += a.T @ dy


def _row_block(rows):
    """Row-block size: tile at BLOCK_ROWS only when rows divide evenly —
    a ragged final block would read out-of-bounds padding into the dw
    accumulation (observed as wrong dw1 for rows=200; values in the OOB
    region are unspecified by Pallas)."""
    if rows > BLOCK_ROWS and rows % BLOCK_ROWS == 0:
        return BLOCK_ROWS
    return rows


def _grid(rows):
    return (rows // _row_block(rows),)


def _row_spec(rows, cols):
    rb = _row_block(rows)
    if rb == rows:
        return pl.BlockSpec((rows, cols), lambda i: (0, 0))
    return pl.BlockSpec((rb, cols), lambda i: (i, 0))


def _full_spec(r, c):
    return pl.BlockSpec((r, c), lambda i: (0, 0))


def _mlp_fwd_impl(x, w1, w2):
    rows, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    return pl.pallas_call(
        _fwd_kernel,
        grid=_grid(rows),
        in_specs=[
            _row_spec(rows, d_in),
            _full_spec(d_in, d_h),
            _full_spec(d_h, d_out),
        ],
        out_specs=_row_spec(rows, d_out),
        out_shape=jax.ShapeDtypeStruct((rows, d_out), x.dtype),
        interpret=INTERPRET,
    )(x, w1, w2)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def mlp_block(x, w1, w2):
    """Fused ``gelu(x @ w1) @ w2`` with Pallas forward/backward kernels."""
    return _mlp_fwd_impl(x, w1, w2)


def _fwd_rule(x, w1, w2):
    return _mlp_fwd_impl(x, w1, w2), (x, w1, w2)


def _bwd_rule(res, dy):
    x, w1, w2 = res
    rows, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    dx, dw1, dw2 = pl.pallas_call(
        _bwd_kernel,
        grid=_grid(rows),
        in_specs=[
            _row_spec(rows, d_in),
            _full_spec(d_in, d_h),
            _full_spec(d_h, d_out),
            _row_spec(rows, d_out),
        ],
        out_specs=[
            _row_spec(rows, d_in),
            _full_spec(d_in, d_h),
            _full_spec(d_h, d_out),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d_in), x.dtype),
            jax.ShapeDtypeStruct((d_in, d_h), w1.dtype),
            jax.ShapeDtypeStruct((d_h, d_out), w2.dtype),
        ],
        interpret=INTERPRET,
    )(x, w1, w2, dy)
    return dx, dw1, dw2


mlp_block.defvjp(_fwd_rule, _bwd_rule)


def vmem_bytes(rows, d_in, d_h, d_out, itemsize=4):
    """Static VMEM footprint estimate for one fwd grid step (DESIGN §Perf):
    x-tile + w1 + w2 + h-tile + y-tile."""
    rb = min(rows, BLOCK_ROWS)
    return itemsize * (rb * d_in + d_in * d_h + d_h * d_out + rb * d_h + rb * d_out)
