"""Causal attention as a Pallas kernel with a custom VJP.

The grid iterates over (batch*heads); each grid step holds one head's
(T, d_head) q/k/v tiles in VMEM and computes the causally masked softmax
attention for that head (T is small in this model, so a single KV block
suffices; the BlockSpec is the seam where a flash-style KV loop would slot
in for long sequences — the mask/scale/normalization algebra below is
already the online-softmax form).

Backward is the standard attention VJP, again per (batch*head) as a
Pallas kernel, recomputing the probability matrix (rematerialization).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    t, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=q.dtype))
    s = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask, s, jnp.array(-1e30, dtype=q.dtype))
    # Numerically stable softmax (the m/l pair is the flash-attention
    # running max / normalizer, degenerate single-block case).
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = (p / l) @ v


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    t, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=q.dtype))
    s = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask, s, jnp.array(-1e30, dtype=q.dtype))
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    p = e / l
    dv_ref[0] = p.T @ do
    dp = do @ v.T
    # softmax VJP: ds = p * (dp - rowsum(dp * p))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0] = (ds @ k) * scale
    dk_ref[0] = (ds.T @ q) * scale


def _specs(bh, t, d):
    return [pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)) for _ in range(bh)]


def _attn_fwd_impl(q, k, v):
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _fwd_kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def attention(q, k, v):
    """Causal attention over stacked heads.

    q, k, v: (batch*heads, T, d_head) → (batch*heads, T, d_head).
    """
    return _attn_fwd_impl(q, k, v)


def _fwd_rule(q, k, v):
    return _attn_fwd_impl(q, k, v), (q, k, v)


def _bwd_rule(res, do):
    q, k, v = res
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    dq, dk, dv = pl.pallas_call(
        _bwd_kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((bh, t, d), q.dtype)] * 3,
        interpret=INTERPRET,
    )(q, k, v, do)
    return dq, dk, dv


attention.defvjp(_fwd_rule, _bwd_rule)
