"""Layer-1 Pallas kernels (interpret=True on CPU; see DESIGN.md
§Hardware-Adaptation for the TPU mapping) and their pure-jnp oracles."""

from . import ref  # noqa: F401
from .mlp_block import mlp_block  # noqa: F401
from .attention import attention  # noqa: F401
from .survival import survival_theta  # noqa: F401
