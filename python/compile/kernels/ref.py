"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest +
hypothesis assert allclose between kernel and oracle across shapes/dtypes
(see python/tests/test_kernels.py). These are also the functions whose
gradients validate the custom-VJP backward kernels.
"""

import jax.numpy as jnp
from jax import nn


def gelu(x):
    """tanh-approximation GELU (matches the kernel's closed form)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def mlp_block(x, w1, w2):
    """Fused MLP block: ``gelu(x @ w1) @ w2``.

    x: (rows, d_in), w1: (d_in, d_hidden), w2: (d_hidden, d_out).
    """
    return gelu(x @ w1) @ w2


def attention(q, k, v):
    """Causal single-head attention for one (batch*head) slice.

    q, k, v: (T, d_head). Returns (T, d_head).
    """
    t = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], dtype=q.dtype))
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, jnp.array(-1e30, dtype=q.dtype))
    p = nn.softmax(scores, axis=-1)
    return p @ v


def survival_theta(elapsed, q, mask):
    """Batched DECAFORK estimator under the analytic geometric survival.

    theta[i] = 0.5 + sum_k mask[i,k] * (1-q[i])^elapsed[i,k]   (Eq. 1)

    elapsed: (N, K) steps since walk k was seen at node i,
    q:       (N,)   per-node geometric parameter (≈ stationary prob),
    mask:    (N, K) 1.0 where walk k is known to node i (and not the
             visiting walk), else 0.0.
    """
    log1mq = jnp.log1p(-q)[:, None]
    surv = jnp.exp(elapsed * log1mq)
    return 0.5 + jnp.sum(surv * mask, axis=-1)
