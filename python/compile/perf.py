"""L1/L2 performance analysis (§Perf in DESIGN.md / EXPERIMENTS.md).

interpret=True wallclock is NOT a TPU proxy, so the Pallas kernels are
assessed *structurally*: VMEM footprint per grid step, FLOPs, bytes moved
HBM<->VMEM, arithmetic intensity, and the implied compute- vs
memory-bound regime on a reference TPU core (v4-ish numbers: 137 bf16
TFLOP/s MXU, 1.2 TB/s HBM, 16 MiB VMEM). The L2 graph is profiled via
XLA's cost analysis on the lowered module (flops / bytes accessed),
which is meaningful on any backend.

Usage (from python/): python -m compile.perf --model small
"""

import argparse

import jax
import jax.numpy as jnp

from .kernels.mlp_block import BLOCK_ROWS, vmem_bytes
from .model import make_flat_fns

# Reference TPU core characteristics (order-of-magnitude roofline only).
MXU_FLOPS = 137e12
HBM_BW = 1.2e12
VMEM = 16 * 2**20


def mlp_report(rows, d_in, d_h, d_out):
    vm = vmem_bytes(rows, d_in, d_h, d_out)
    rb = min(rows, BLOCK_ROWS)
    flops = 2 * rows * d_in * d_h + 2 * rows * d_h * d_out
    # Weights stream once per grid sweep; activations once per row.
    bytes_moved = 4 * (rows * d_in + rows * d_out + d_in * d_h + d_h * d_out)
    ai = flops / bytes_moved
    t_compute = flops / MXU_FLOPS
    t_memory = bytes_moved / HBM_BW
    return {
        "kernel": f"mlp {rows}x{d_in}->{d_h}->{d_out}",
        "vmem_block": vm,
        "vmem_frac": vm / VMEM,
        "rows_per_block": rb,
        "flops": flops,
        "bytes": bytes_moved,
        "ai": ai,
        "bound": "compute" if t_compute > t_memory else "memory",
        "mxu_busy_frac": min(1.0, t_compute / max(t_compute, t_memory)),
    }


def attn_report(bh, t, d):
    # Per (batch*head) grid step: q,k,v,o tiles + t x t score tile.
    vm = 4 * (4 * t * d + t * t)
    flops = bh * (2 * t * t * d * 2 + 5 * t * t)  # qk^T, pv + softmax ops
    bytes_moved = 4 * bh * 4 * t * d
    ai = flops / bytes_moved
    return {
        "kernel": f"attention {bh}x{t}x{d}",
        "vmem_block": vm,
        "vmem_frac": vm / VMEM,
        "rows_per_block": t,
        "flops": flops,
        "bytes": bytes_moved,
        "ai": ai,
        "bound": "compute" if flops / MXU_FLOPS > bytes_moved / HBM_BW else "memory",
        "mxu_busy_frac": min(
            1.0, (flops / MXU_FLOPS) / max(flops / MXU_FLOPS, bytes_moved / HBM_BW)
        ),
    }


def theta_report(n, k):
    vm = 4 * (2 * n * k + 2 * n)
    flops = 4 * n * k  # log1p, mul, exp, fma — all VPU
    bytes_moved = 4 * (2 * n * k + 2 * n)
    return {
        "kernel": f"survival_theta {n}x{k}",
        "vmem_block": vm,
        "vmem_frac": vm / VMEM,
        "rows_per_block": min(n, 128),
        "flops": flops,
        "bytes": bytes_moved,
        "ai": flops / bytes_moved,
        "bound": "memory (VPU elementwise)",
        "mxu_busy_frac": 0.0,
    }


def l2_cost_analysis(model):
    from .aot import CONFIGS

    cfg = CONFIGS[model]
    flat0, train_step, _ = make_flat_fns(cfg)
    p_spec = jax.ShapeDtypeStruct(flat0.shape, jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    lowered = jax.jit(train_step).lower(p_spec, tok_spec)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return cfg, flat0.shape[0], ca


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="small")
    args = ap.parse_args()

    print("== L1 structural roofline (reference TPU core) ==")
    header = f"{'kernel':34} {'VMEM/blk':>10} {'%VMEM':>7} {'FLOPs':>12} {'AI':>7} bound"
    print(header)
    cfg, n_params, ca = l2_cost_analysis(args.model)
    rows = cfg.batch * cfg.seq
    for r in [
        mlp_report(rows, cfg.d_model, 4 * cfg.d_model, cfg.d_model),
        attn_report(cfg.batch * cfg.n_heads, cfg.seq, cfg.d_model // cfg.n_heads),
        theta_report(256, 64),
    ]:
        print(
            f"{r['kernel']:34} {r['vmem_block']:>10} {r['vmem_frac']:>6.1%} "
            f"{r['flops']:>12.3e} {r['ai']:>7.1f} {r['bound']}"
        )
    print("\n== L2 XLA cost analysis of the jitted train step ==")
    print(f"model={args.model} params={n_params}")
    for key in sorted(ca):
        if key in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
            print(f"  {key}: {ca[key]:.4g}")
    flops = ca.get("flops", 0.0)
    bytes_acc = ca.get("bytes accessed", 1.0)
    print(f"  arithmetic intensity: {flops / bytes_acc:.2f} flops/byte")
    print(
        f"  roofline on ref core: {'compute' if flops / MXU_FLOPS > bytes_acc / HBM_BW else 'memory'}-bound"
    )


if __name__ == "__main__":
    main()
