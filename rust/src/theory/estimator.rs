//! Distributional analysis of the DECAFORK estimator under Assumption 1.
//!
//! The central object is `θ̂_{Tf,Td}(t) = S(t − L_{i,k}(t))` — the survival
//! estimate a random node holds at time `t` for one walk forked at `Tf`
//! and terminated at `Td` (set `Td = t` while the walk is alive). Lemma 1
//! gives its CDF, Corollary 1 its mean, Lemma 3 its variance; Lemma 2
//! assembles the mean of the full estimator `θ̂_i(t)` from an event
//! history, and Theorem 1's limits fall out of those pieces.

use super::Rates;

/// The distribution of a single walk's survival estimate `S(t − L)` under
/// Assumption 1 (Lemma 1). Times are absolute; requires `Tf ≤ Td ≤ t`.
#[derive(Debug, Clone, Copy)]
pub struct ThetaHatDistribution {
    pub rates: Rates,
    /// Fork time of the walk (use a very negative number for "active since
    /// forever"; `f64::NEG_INFINITY` is handled).
    pub t_f: f64,
    /// Termination time (set `= t` for a still-active walk).
    pub t_d: f64,
    /// Evaluation time.
    pub t: f64,
}

impl ThetaHatDistribution {
    pub fn new(rates: Rates, t_f: f64, t_d: f64, t: f64) -> Self {
        assert!(t_f <= t_d && t_d <= t, "need Tf <= Td <= t");
        ThetaHatDistribution { rates, t_f, t_d, t }
    }

    /// Active walk forked at `t_f` (Lemma 1 with `Td = t`).
    pub fn active(rates: Rates, t_f: f64, t: f64) -> Self {
        Self::new(rates, t_f, t, t)
    }

    /// Lemma 1: CDF of `S(t − L)` at `x ∈ [0, 1]`.
    pub fn cdf(&self, x: f64) -> f64 {
        let Rates { lambda_r, lambda_a } = self.rates;
        let (t, t_f, t_d) = (self.t, self.t_f, self.t_d);
        if x < 0.0 {
            return 0.0;
        }
        // Upper support point: values above e^{−λ_r (t − T_d)} cannot be
        // observed (the walk was last seen no later than T_d).
        let upper = (-lambda_r * (t - t_d)).exp();
        if x >= upper {
            return 1.0;
        }
        // Atom at (near) zero: the fork never reached the observing node
        // before dying, probability e^{−λ_a (T_d − T_f)}; below the lower
        // support point e^{−λ_r (t − T_f)} only the atom contributes.
        let atom = if t_f == f64::NEG_INFINITY {
            0.0
        } else {
            (-lambda_a * (t_d - t_f)).exp()
        };
        let lower = if t_f == f64::NEG_INFINITY { 0.0 } else { (-lambda_r * (t - t_f)).exp() };
        if x < lower {
            return atom;
        }
        if t_f == f64::NEG_INFINITY {
            // Active-forever walk: S is uniform on (0, upper] (Obs. 2/3).
            return (x / upper).clamp(0.0, 1.0);
        }
        // Main branch of Lemma 1.
        let val = x * (1.0 - (-lambda_a * (t - t_f)).exp() * x.powf(-lambda_a / lambda_r)) / upper + atom;
        val.clamp(0.0, 1.0)
    }

    /// Corollary 1: closed-form mean.
    pub fn mean(&self) -> f64 {
        let Rates { lambda_r, lambda_a } = self.rates.regularized();
        let (t, t_f, t_d) = (self.t, self.t_f, self.t_d);
        if t_f == f64::NEG_INFINITY {
            // Obs. 2/3: uniform on (0, e^{−λ_r (t − T_d)}).
            return 0.5 * (-lambda_r * (t - t_d)).exp();
        }
        let ratio = 1.0 / (2.0 - lambda_a / lambda_r);
        (-lambda_a * (t_d - t_f)).exp() * (-lambda_r * (t - t_d)).exp() * (ratio - 1.0)
            + 0.5 * (-lambda_r * (t - t_d)).exp()
            + (-2.0 * lambda_r * (t - t_f)).exp() * (lambda_r * (t - t_d)).exp() * (0.5 - ratio)
    }

    /// Mean via numerical integration of the CDF: `E[X] = ∫ (1−F) dx` on
    /// `[0, 1]`. Used to cross-validate Corollary 1 (and to expose any
    /// transcription typo in the closed form — see tests).
    pub fn mean_quadrature(&self) -> f64 {
        self.moment_quadrature(1)
    }

    /// `E[X^k]` by integrating `k x^{k−1} (1 − F(x))` over the support.
    pub fn moment_quadrature(&self, k: u32) -> f64 {
        let n = 20_000;
        let upper = (-self.rates.lambda_r * (self.t - self.t_d)).exp();
        let h = upper / n as f64;
        let mut acc = 0.0;
        for i in 0..n {
            let x = (i as f64 + 0.5) * h;
            acc += k as f64 * x.powi(k as i32 - 1) * (1.0 - self.cdf(x)) * h;
        }
        acc
    }

    /// Variance via quadrature (robust reference implementation).
    pub fn variance_quadrature(&self) -> f64 {
        let m1 = self.moment_quadrature(1);
        let m2 = self.moment_quadrature(2);
        (m2 - m1 * m1).max(0.0)
    }

    /// Lemma 3's closed-form variance as printed in the paper (requires
    /// `λ_a ∉ {2λ_r, 3λ_r}`). The printed expression is long and easy to
    /// mis-transcribe; [`variance_quadrature`] is the ground truth the
    /// tests compare against — see `integration_theory.rs`.
    pub fn variance_closed_form(&self) -> f64 {
        let Rates { lambda_r: lr, lambda_a: la } = self.rates.regularized();
        assert!((la - 2.0 * lr).abs() > 1e-9 && (la - 3.0 * lr).abs() > 1e-9);
        let (t, tf, td) = (self.t, self.t_f, self.t_d);
        let pref = (td * (lr - la) - 4.0 * lr * t).exp()
            / (12.0 * (la - 3.0 * lr) * (la - 2.0 * lr).powi(2));
        let term1 = 3.0 * (-la + 3.0 * lr)
            * (2.0 * (la * (tf - td)).exp() * (lr - la)
                + la * (2.0 * lr * (tf - td)).exp()
                + la
                - 2.0 * lr)
                .powi(2)
            * ((la + lr) * td + 2.0 * lr * t).exp();
        let term2 = 4.0 * (la - 2.0 * lr).powi(2)
            * (2.0 * lr * (t - td)).exp()
            * (2.0 * la * (la * td + 3.0 * tf * lr).exp()
                + (la - 3.0 * lr) * (td * (la + 3.0 * lr)).exp()
                - (lr - la) * 3.0 * (la * tf + 3.0 * lr * td).exp());
        pref * (term1 + term2)
    }
}

/// Event history for Lemma 2 / Theorem 1: counts of walks active forever,
/// terminated at given times, and forked at given times. Fractional counts
/// are allowed so Corollary 3's expected-fork recursion can reuse this.
#[derive(Debug, Clone, Default)]
pub struct EventHistory {
    /// `|A_t|` — walks active since (effectively) forever.
    pub active_forever: f64,
    /// `(T_d, |D_{T_d}|)` — termination events.
    pub terminated: Vec<(f64, f64)>,
    /// `(T_f, |F_{T_f}|)` — fork events (walks still active).
    pub forked: Vec<(f64, f64)>,
}

impl EventHistory {
    /// Lemma 2: `E[θ̂_i(t)]` for a node visited by one of the
    /// active-forever walks at time `t`.
    pub fn mean_theta(&self, rates: Rates, t: f64) -> f64 {
        let Rates { lambda_r, lambda_a } = rates.regularized();
        let ratio = 1.0 / (2.0 - lambda_a / lambda_r);
        let mut acc = 0.5 + (self.active_forever - 1.0).max(0.0) / 2.0;
        for &(t_d, count) in &self.terminated {
            acc += count * 0.5 * (-lambda_r * (t - t_d)).exp();
        }
        for &(t_f, count) in &self.forked {
            acc += count
                * (0.5 + (-lambda_a * (t - t_f)).exp() * (ratio - 1.0)
                    + (-2.0 * lambda_r * (t - t_f)).exp() * (0.5 - ratio));
        }
        acc
    }

    /// The variance proxy `σ²(t)` from Lemmas 4/5: active walks contribute
    /// `1/12` each (uniform), forked walks their Lemma-3 variance,
    /// terminated walks `e^{−2λ_r (t−T_d)}/12` (scaled uniform).
    pub fn sigma2(&self, rates: Rates, t: f64) -> f64 {
        let mut acc = (self.active_forever - 1.0).max(0.0) / 12.0;
        for &(t_d, count) in &self.terminated {
            acc += count * (-2.0 * rates.lambda_r * (t - t_d)).exp() / 12.0;
        }
        for &(t_f, count) in &self.forked {
            let dist = ThetaHatDistribution::active(rates, t_f, t);
            acc += count * dist.variance_quadrature();
        }
        acc
    }

    /// Theorem 1 limit check: the number of walks active between the last
    /// event and `t` (what `2·E[θ̂]` should converge to).
    pub fn current_population(&self) -> f64 {
        self.active_forever + self.forked.iter().map(|&(_, c)| c).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rates() -> Rates {
        Rates::new(0.01, 0.025) // mean return 100, mean arrival 40
    }

    #[test]
    fn cdf_is_monotone_and_bounded() {
        let d = ThetaHatDistribution::new(rates(), 0.0, 400.0, 500.0);
        let mut prev = -1.0;
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            let f = d.cdf(x);
            assert!((0.0..=1.0).contains(&f), "F({x}) = {f}");
            assert!(f >= prev - 1e-12, "non-monotone at {x}");
            prev = f;
        }
        assert_eq!(d.cdf(1.0), 1.0);
        assert_eq!(d.cdf(-0.1), 0.0);
    }

    #[test]
    fn active_forever_is_uniform() {
        // Obs. 2: active-forever walk's survival estimate ~ U(0,1).
        let d = ThetaHatDistribution::new(rates(), f64::NEG_INFINITY, 500.0, 500.0);
        for x in [0.1, 0.4, 0.9] {
            assert!((d.cdf(x) - x).abs() < 1e-9);
        }
        assert!((d.mean() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn terminated_forever_walk_is_scaled_uniform() {
        // Obs. 3: terminated at T_d, support [0, e^{−λ_r (t−T_d)}].
        let r = rates();
        let d = ThetaHatDistribution::new(r, f64::NEG_INFINITY, 400.0, 500.0);
        let upper = (-r.lambda_r * 100.0).exp();
        assert!((d.mean() - upper / 2.0).abs() < 1e-9);
        assert!((d.cdf(upper / 2.0) - 0.5).abs() < 1e-9);
        assert_eq!(d.cdf(upper * 1.01), 1.0);
    }

    #[test]
    fn corollary1_matches_quadrature() {
        for (tf, td, t) in [(0.0, 400.0, 500.0), (100.0, 450.0, 500.0), (0.0, 500.0, 500.0)] {
            let d = ThetaHatDistribution::new(rates(), tf, td, t);
            let closed = d.mean();
            let quad = d.mean_quadrature();
            assert!(
                (closed - quad).abs() < 2e-3,
                "Tf={tf} Td={td} t={t}: closed {closed} vs quad {quad}"
            );
        }
    }

    #[test]
    fn mean_decays_after_termination() {
        let r = rates();
        let d1 = ThetaHatDistribution::new(r, 0.0, 300.0, 400.0);
        let d2 = ThetaHatDistribution::new(r, 0.0, 300.0, 800.0);
        assert!(d1.mean() > d2.mean());
        assert!(d2.mean() < 0.05);
    }

    #[test]
    fn freshly_forked_walk_converges_to_half() {
        // Theorem 1 ingredient: active fork contribution → ½ as t−Tf → ∞.
        let r = rates();
        let early = ThetaHatDistribution::active(r, 0.0, 50.0);
        let late = ThetaHatDistribution::active(r, 0.0, 2000.0).mean();
        assert!((late - 0.5).abs() < 0.01, "late {late}");
        // Transient value is rate-dependent (with λ_a > 2λ_r the node sees
        // the fork quickly and the estimate *overshoots* ½ at first); what
        // must hold is consistency with the Lemma-1 distribution.
        let m = early.mean();
        assert!((0.0..=1.0).contains(&m));
        assert!((m - early.mean_quadrature()).abs() < 2e-3, "closed {m}");
    }

    #[test]
    fn lemma2_stationary_population() {
        // K walks active forever: E[θ̂] = ½ + (K−1)/2 = K/2.
        let h = EventHistory { active_forever: 10.0, ..Default::default() };
        assert!((h.mean_theta(rates(), 1000.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn lemma2_tracks_population_theorem1() {
        // 10 forever + 5 terminated at 300 + 3 forked at 310, evaluated
        // long after: E[θ̂] → (10 + 3)/2.
        let h = EventHistory {
            active_forever: 10.0,
            terminated: vec![(300.0, 5.0)],
            forked: vec![(310.0, 3.0)],
        };
        let m = h.mean_theta(rates(), 5000.0);
        assert!((2.0 * m - h.current_population()).abs() < 0.05, "2E[θ̂] = {}", 2.0 * m);
    }

    #[test]
    fn sigma2_positive_and_scales() {
        let h = EventHistory {
            active_forever: 10.0,
            terminated: vec![(300.0, 5.0)],
            forked: vec![(320.0, 2.0)],
        };
        let s_early = h.sigma2(rates(), 330.0);
        assert!(s_early > 9.0 / 12.0);
        // Terminated contribution decays.
        let s_late = h.sigma2(rates(), 5000.0);
        assert!(s_late < s_early + 2.0 / 12.0 + 1e-9);
    }
}
