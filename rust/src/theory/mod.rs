//! The paper's theoretical toolbox (Secs. IV–V), implemented as executable
//! formulas and validated against Monte-Carlo simulation in
//! `rust/tests/integration_theory.rs`.
//!
//! Everything here works under **Assumption 1**: return times
//! `R_i ~ Exp(λ_r)` and first hitting times of forked walks
//! `H ~ Exp(λ_a)`, the continuous relaxation of the (empirically
//! geometric) discrete distributions on random regular graphs.
//!
//! Contents:
//! * [`estimator`] — Lemma 1 (CDF of a single walk's survival estimate),
//!   Corollary 1 (its mean), Lemma 3 (its variance, plus a quadrature
//!   cross-check), Lemma 2 (mean of the full estimator under an event
//!   history), Observations 2–3, Propositions 3–4 (Irwin–Hall forms).
//! * [`bounds`] — Lemma 4 / Lemma 5 (Bennett bounds on fork/termination
//!   probabilities), Theorem 2 (reaction time), Theorem 3 / Corollary 2
//!   (growth without failures), Corollary 3 (overshoot recursion) and a
//!   small Theorem 4 tree evaluator.

pub mod bounds;
pub mod estimator;

pub use bounds::{
    fork_probability_bound, growth_bound, overshoot_recursion, reaction_time_bound,
    termination_probability_bound, time_until_growth, GrowthBound,
};
pub use estimator::{EventHistory, ThetaHatDistribution};

/// Assumption-1 rates bundled together.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rates {
    /// Return-time rate λ_r (mean return time 1/λ_r ≈ n for regular graphs).
    pub lambda_r: f64,
    /// Fork arrival rate λ_a (mean first-hitting time 1/λ_a).
    pub lambda_a: f64,
}

impl Rates {
    pub fn new(lambda_r: f64, lambda_a: f64) -> Self {
        assert!(lambda_r > 0.0 && lambda_a > 0.0);
        Rates { lambda_r, lambda_a }
    }

    /// The closed forms of Corollary 1 / Lemmas 2–3 have removable
    /// singularities at `λ_a ∈ {2λ_r, 3λ_r}` (the paper excludes them in
    /// Lemma 3). Nudge `λ_a` off those points by a relative 1e-6 — the
    /// formulas are continuous there, so the perturbation error is far
    /// below Monte-Carlo noise.
    pub fn regularized(&self) -> Rates {
        let mut la = self.lambda_a;
        for mult in [2.0, 3.0] {
            let s = mult * self.lambda_r;
            if (la - s).abs() < 1e-6 * self.lambda_r {
                la = s * (1.0 + 1e-6);
            }
        }
        Rates { lambda_r: self.lambda_r, lambda_a: la }
    }

    /// Rates implied by a graph under the regular-graph approximation:
    /// `λ_r ≈ π_i = 1 / E[R_i]` and `λ_a ≈ 1 / E[H]` with `E[H] ≈ n`
    /// (mean hitting time from a random start on a well-connected regular
    /// graph is Θ(n)).
    pub fn from_graph(g: &crate::graph::Graph, node: usize) -> Self {
        let mean_return = g.mean_return_time(node);
        Rates { lambda_r: 1.0 / mean_return, lambda_a: 1.0 / g.n() as f64 }
    }
}
