//! Worst-case guarantees: reaction time (Theorem 2), growth without
//! failures (Theorem 3 / Corollary 2), fork/termination probability
//! bounds (Lemmas 4/5, Bennett), and the overshoot recursion
//! (Corollary 3) with a small exact Theorem-4 tree evaluator.

use super::estimator::EventHistory;
use super::Rates;
use crate::stats::IrwinHall;

/// Bennett's `h(ζ) = (1+ζ)·ln(1+ζ) − ζ`.
pub fn bennett_h(zeta: f64) -> f64 {
    assert!(zeta >= 0.0);
    (1.0 + zeta) * zeta.ln_1p() - zeta
}

/// Lemma 4: upper bound on the probability that a node forks at time `t`
/// given event history `h`, when `E[θ̂_i(t)] > ε`.
///
/// The paper's display squares the deviation inside `h`; the classical
/// Bennett inequality for variables in `[0,1]` uses the raw deviation
/// (`P(S ≤ E−d) ≤ exp(−σ² h(d/σ²))`). We expose the classical form —
/// which we verify is a genuine upper bound by Monte-Carlo in
/// `integration_theory.rs` — and note the printed variant in DESIGN.md.
pub fn fork_probability_bound(h: &EventHistory, rates: Rates, t: f64, epsilon: f64, p: f64) -> f64 {
    let mean = h.mean_theta(rates, t);
    if mean <= epsilon {
        return p; // no concentration help below the threshold
    }
    let sigma2 = h.sigma2(rates, t).max(1e-12);
    let dev = mean - epsilon;
    p * (-sigma2 * bennett_h(dev / sigma2)).exp()
}

/// Lemma 5: upper bound on the probability that a node *terminates* at
/// time `t` when `E[θ̂_i(t)] < ε₂` (mirror image of Lemma 4).
pub fn termination_probability_bound(
    h: &EventHistory,
    rates: Rates,
    t: f64,
    epsilon2: f64,
    p: f64,
) -> f64 {
    let mean = h.mean_theta(rates, t);
    if mean >= epsilon2 {
        return p;
    }
    let sigma2 = h.sigma2(rates, t).max(1e-12);
    let dev = epsilon2 - mean;
    p * (-sigma2 * bennett_h(dev / sigma2)).exp()
}

/// Theorem 2: bound on the time until at least one fork occurs after `D`
/// walks failed at `T_d` and `R` forks already happened, with `K` walks
/// surviving the burst (`K = K' − D`).
///
/// Returns the smallest `T − T_d` such that the no-fork probability
/// `δ(T) = Π_t [1 − p·F_{Σ_{K+R−1}}(ε')·F_{Σ_{D−R}}((ε−ε'−½)·e^{λ_r (t−T_d)})]`
/// drops below `delta`, scanning `eps_prime` over a grid to get the best
/// (smallest) bound, as the paper suggests. `None` if not reached within
/// `max_t` steps.
pub fn reaction_time_bound(
    d: u32,
    r: u32,
    k: u32,
    epsilon: f64,
    p: f64,
    rates: Rates,
    delta: f64,
    max_t: u64,
) -> Option<u64> {
    assert!(r < d, "need R < D");
    let best = (1..40)
        .map(|i| epsilon * i as f64 / 40.0)
        .filter(|&e1| e1 < epsilon - 0.5)
        .filter_map(|e1| reaction_time_bound_fixed(d, r, k, epsilon, e1, p, rates, delta, max_t))
        .min();
    best
}

/// Theorem 2 with a fixed ε′ split.
#[allow(clippy::too_many_arguments)]
pub fn reaction_time_bound_fixed(
    d: u32,
    r: u32,
    k: u32,
    epsilon: f64,
    eps_prime: f64,
    p: f64,
    rates: Rates,
    delta: f64,
    max_t: u64,
) -> Option<u64> {
    assert!(eps_prime > 0.0 && eps_prime < epsilon - 0.5);
    let surviving = IrwinHall::new(k + r - 1);
    let dead = IrwinHall::new(d - r);
    let f_surv = surviving.cdf(eps_prime);
    let mut log_no_fork = 0.0f64;
    let log_delta = delta.ln();
    for dt in 0..=max_t {
        // Terminated walks' contribution lives on [0, e^{−λ_r dt}]:
        // F'_{Σ_D}(x) = F_{Σ_D}(x · e^{λ_r dt}).
        let scaled = (epsilon - eps_prime - 0.5) * (rates.lambda_r * dt as f64).exp();
        let f_dead = dead.cdf(scaled);
        let q = 1.0 - p * f_surv * f_dead;
        log_no_fork += q.ln();
        if log_no_fork <= log_delta {
            return Some(dt);
        }
    }
    None
}

/// Theorem 3 building block: `p_ν⁺ = ν · p · F_{Σ_{ν−1}}(ε − ½)` — the
/// per-step forking probability bound with `ν` walks all known to all
/// nodes.
pub fn p_nu_plus(nu: u32, p: f64, epsilon: f64) -> f64 {
    (nu as f64 * p * IrwinHall::new(nu - 1).cdf(epsilon - 0.5)).min(1.0)
}

/// Result of the Theorem 3 growth bound.
#[derive(Debug, Clone, PartialEq)]
pub struct GrowthBound {
    /// Probability bound δ on ever exceeding `z` walks within time `T`.
    pub delta: f64,
    /// The per-level propagation times `T_{ν,1}`.
    pub t_nu1: Vec<f64>,
    /// The index `m` reached by the schedule.
    pub m: u32,
}

/// Theorem 3: bound the probability that, running DECAFORK for duration
/// `t_total` with **no failures** and starting from `Z0 = z0` walks, the
/// population ever exceeds `z`.
pub fn growth_bound(z0: u32, z: u32, epsilon: f64, p: f64, n: usize, rates: Rates, t_total: f64) -> GrowthBound {
    assert!(z > z0);
    let lambda_a = rates.lambda_a;
    let mut t_nu1 = Vec::new();
    let mut elapsed = 0.0;
    let mut delta = 0.0;
    let mut m = z0;
    // Walk the fork ladder ν = Z0 … z−1 while the schedule fits in T.
    for nu in z0..z {
        let p_nu = p_nu_plus(nu, p, epsilon);
        if p_nu <= 0.0 {
            // Forking impossible at this ν ⇒ growth beyond it has
            // probability 0 under the bound.
            m = nu;
            return GrowthBound { delta, t_nu1, m };
        }
        let t1 = (lambda_a * n as f64 / p_nu).ln().max(0.0) / lambda_a;
        if elapsed + t1 >= t_total || nu == z - 1 {
            // Remaining time at level ν = m: no more forks allowed.
            let t_m2 = (t_total - elapsed).max(0.0);
            delta += p_nu * t_m2;
            m = nu;
            return GrowthBound { delta: delta.min(1.0), t_nu1, m };
        }
        delta += n as f64 * (-lambda_a * t1).exp() + t1 * p_nu;
        t_nu1.push(t1);
        elapsed += t1;
        m = nu + 1;
    }
    GrowthBound { delta: delta.min(1.0), t_nu1, m }
}

/// Corollary 2: for confidence `delta`, the time horizon `T` during which
/// `Z_t < z` holds with probability ≥ 1 − δ. Inverts [`growth_bound`] by
/// bisection over `t_total`.
pub fn time_until_growth(z0: u32, z: u32, epsilon: f64, p: f64, n: usize, rates: Rates, delta: f64) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    while growth_bound(z0, z, epsilon, p, n, rates, hi).delta < delta && hi < 1e12 {
        hi *= 2.0;
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if growth_bound(z0, z, epsilon, p, n, rates, mid).delta < delta {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Corollary 3: approximate upper bound on `E[Z_{t}]` after a failure at
/// `T_d` left `z_td` walks, assuming the expected number of forks
/// materializes every step. Returns the trajectory
/// `[E[Z_{T_d}], …, E[Z_{T_d + steps}]]`.
pub fn overshoot_recursion(
    z_td: u32,
    t_d: f64,
    steps: usize,
    epsilon: f64,
    p: f64,
    rates: Rates,
    d_failed: u32,
) -> Vec<f64> {
    let mut traj = Vec::with_capacity(steps + 1);
    let mut h = EventHistory {
        active_forever: z_td as f64,
        terminated: vec![(t_d, d_failed as f64)],
        forked: Vec::new(),
    };
    let mut z = z_td as f64;
    traj.push(z);
    for s in 1..=steps {
        let t = t_d + s as f64;
        let zc = z.ceil();
        // Every one of the ⌈z⌉ visited nodes may fork with bounded prob.
        let pf = fork_probability_bound(&h, rates, t, epsilon, p);
        let forks = zc * pf;
        if forks > 1e-9 {
            h.forked.push((t, forks));
        }
        z = zc + forks;
        traj.push(z);
    }
    traj
}

/// Theorem 4 (small-depth exact tree): upper bound on `E[Z_{t0+x}]` after
/// failures, evaluating the full binary threshold tree. Exponential in
/// `x`; intended for `x ≤ ~14`. Thresholds are chosen per-branch as
/// `κ = ceil(E[Z] + slack·√Var)` with binomial fork counts bounded by
/// Lemma 4 — a concrete instantiation of the paper's "appropriate choice".
pub fn theorem4_tree_bound(
    z_t0: u32,
    t0: f64,
    x: u32,
    epsilon: f64,
    p: f64,
    rates: Rates,
    d_failed: u32,
    t_d: f64,
) -> f64 {
    assert!(x >= 1 && x <= 20, "tree depth must be small");
    struct Ctx {
        epsilon: f64,
        p: f64,
        rates: Rates,
    }
    // Recursive expectation over {fork-burst, no-burst} branches.
    fn rec(ctx: &Ctx, h: &EventHistory, z: f64, t: f64, depth: u32) -> f64 {
        if depth == 0 {
            let pf = fork_probability_bound(h, ctx.rates, t, ctx.epsilon, ctx.p);
            return z + z * pf;
        }
        let pf = fork_probability_bound(h, ctx.rates, t, ctx.epsilon, ctx.p);
        // Threshold: expected forks plus 3σ of Binomial(z, pf).
        let mean_forks = z * pf;
        let sd = (z * pf * (1.0 - pf)).sqrt();
        let kappa_extra = (mean_forks + 3.0 * sd).ceil();
        // P(more than κ_extra forks) via Chernoff-style tail of Binomial.
        let tail = binom_tail(z.round() as u64, pf, kappa_extra as u64);
        // Branch "many forks": worst case doubles the population.
        let mut h_hi = h.clone();
        h_hi.forked.push((t, z));
        let hi = rec(ctx, &h_hi, 2.0 * z, t + 1.0, depth - 1);
        // Branch "few forks": at most κ_extra forks.
        let mut h_lo = h.clone();
        if kappa_extra > 0.0 {
            h_lo.forked.push((t, kappa_extra));
        }
        let lo = rec(ctx, &h_lo, z + kappa_extra, t + 1.0, depth - 1);
        tail * hi + (1.0 - tail).min(1.0) * lo
    }
    let h = EventHistory {
        active_forever: z_t0 as f64,
        terminated: vec![(t_d, d_failed as f64)],
        forked: Vec::new(),
    };
    let ctx = Ctx { epsilon, p, rates };
    rec(&ctx, &h, z_t0 as f64, t0, x - 1)
}

/// Upper tail `P(Bin(n, p) > k)` via the exact sum (n is small here).
fn binom_tail(n: u64, p: f64, k: u64) -> f64 {
    if k >= n {
        return 0.0;
    }
    let mut acc = 0.0;
    for i in (k + 1)..=n {
        let logp = crate::stats::ln_binom(n, i) + i as f64 * p.max(1e-300).ln() + (n - i) as f64 * (1.0 - p).max(1e-300).ln();
        acc += logp.exp();
    }
    acc.min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rates() -> Rates {
        Rates::new(0.01, 0.025)
    }

    #[test]
    fn bennett_h_properties() {
        assert!((bennett_h(0.0)).abs() < 1e-12);
        assert!(bennett_h(1.0) > 0.0);
        // Convex increasing.
        assert!(bennett_h(2.0) > 2.0 * bennett_h(1.0));
    }

    #[test]
    fn fork_bound_decreases_with_health() {
        // Healthy population far above ε ⇒ tiny fork probability.
        let healthy = EventHistory { active_forever: 10.0, ..Default::default() };
        let b = fork_probability_bound(&healthy, rates(), 1000.0, 2.0, 0.1);
        assert!(b < 0.01, "bound {b}");
        // Depleted population ⇒ bound degrades to p.
        let dead = EventHistory { active_forever: 2.0, ..Default::default() };
        let b2 = fork_probability_bound(&dead, rates(), 1000.0, 2.0, 0.1);
        assert!((b2 - 0.1).abs() < 1e-12);
    }

    #[test]
    fn termination_bound_mirrors_fork_bound() {
        let low = EventHistory { active_forever: 4.0, ..Default::default() };
        let b = termination_probability_bound(&low, rates(), 1000.0, 5.75, 0.1);
        assert!(b < 0.01, "bound {b}");
        let high = EventHistory { active_forever: 14.0, ..Default::default() };
        let b2 = termination_probability_bound(&high, rates(), 1000.0, 5.75, 0.1);
        assert!((b2 - 0.1).abs() < 1e-12);
    }

    #[test]
    fn reaction_time_bound_finite_and_monotone() {
        // 5 of 10 walks fail; bound the time to the first fork.
        let t1 = reaction_time_bound(5, 0, 5, 2.0, 0.1, rates(), 0.1, 100_000).unwrap();
        assert!(t1 > 0, "t1 {t1}");
        // Tighter confidence takes longer.
        let t2 = reaction_time_bound(5, 0, 5, 2.0, 0.1, rates(), 0.01, 100_000).unwrap();
        assert!(t2 >= t1, "{t2} < {t1}");
        // Larger ε reacts faster.
        let t3 = reaction_time_bound(5, 0, 5, 3.25, 0.1, rates(), 0.1, 100_000).unwrap();
        assert!(t3 <= t1, "{t3} > {t1}");
    }

    #[test]
    fn later_forks_take_longer_theorem2_implication() {
        // After R forks the remaining deficit is smaller ⇒ slower forks.
        let t_r0 = reaction_time_bound(5, 0, 5, 2.0, 0.1, rates(), 0.1, 200_000).unwrap();
        let t_r3 = reaction_time_bound(5, 3, 5, 2.0, 0.1, rates(), 0.1, 200_000).unwrap();
        assert!(t_r3 >= t_r0, "{t_r3} < {t_r0}");
    }

    #[test]
    fn p_nu_plus_decays_in_nu() {
        let p = 0.1;
        let eps = 2.0;
        let a = p_nu_plus(10, p, eps);
        let b = p_nu_plus(14, p, eps);
        assert!(b < a, "{b} >= {a}");
        assert!(a < 0.01);
    }

    #[test]
    fn growth_bound_monotone_in_time_and_eps() {
        let r = rates();
        let g1 = growth_bound(10, 15, 2.0, 0.1, 100, r, 1_000.0);
        let g2 = growth_bound(10, 15, 2.0, 0.1, 100, r, 100_000.0);
        assert!(g2.delta >= g1.delta);
        let g3 = growth_bound(10, 15, 3.25, 0.1, 100, r, 1_000.0);
        assert!(g3.delta >= g1.delta, "larger eps forks more");
    }

    #[test]
    fn time_until_growth_inverts() {
        let r = rates();
        let t = time_until_growth(10, 15, 2.0, 0.1, 100, r, 0.1);
        assert!(t > 0.0);
        let d = growth_bound(10, 15, 2.0, 0.1, 100, r, t).delta;
        assert!(d <= 0.11, "delta at T: {d}");
    }

    #[test]
    fn overshoot_recursion_grows_then_saturates_slowly() {
        let traj = overshoot_recursion(5, 2000.0, 400, 2.0, 0.1, rates(), 5);
        assert_eq!(traj.len(), 401);
        assert!(traj[0] == 5.0);
        assert!(traj[400] >= traj[0]);
        // Non-decreasing (Z_t is a submartingale here).
        for w in traj.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }

    #[test]
    fn theorem4_small_tree_bounds_corollary3_start() {
        let r = rates();
        let t4 = theorem4_tree_bound(5, 2010.0, 6, 2.0, 0.1, r, 5, 2000.0);
        assert!(t4 >= 5.0);
        assert!(t4 < 40.0, "tree bound exploded: {t4}");
    }

    #[test]
    fn binom_tail_sane() {
        assert_eq!(binom_tail(10, 0.5, 10), 0.0);
        let t = binom_tail(10, 0.5, 4); // P(X > 4) = P(X >= 5) ≈ 0.623
        assert!((t - 0.623).abs() < 0.01, "{t}");
    }
}
