//! Decentralized thread-per-node runtime.
//!
//! The synchronous engine in [`crate::sim`] reproduces the paper's
//! simulations; this module demonstrates that the algorithms really are
//! decentralized: every node is an OS thread owning only its local
//! [`NodeState`] and a clone of the control algorithm, edges are mpsc
//! channels, and tokens are messages carrying Lamport-style logical
//! clocks. There is no global scheduler on the token path — the only
//! shared state is telemetry (atomic counters) and the stop flag.
//!
//! Rules 1–3 hold by construction: a node can only talk to its channel
//! neighbors, walks never talk to each other, and fork/terminate happen
//! at the currently visited node.

pub mod actor;

pub use actor::{ActorRun, ActorRuntime, ActorStats};
