//! Thread-per-node actor runtime for the control algorithms.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::control::{ControlAlgorithm, VisitCtx};
use crate::graph::Graph;
use crate::rng::Rng;
use crate::walks::{NodeState, SurvivalModel, WalkId};

/// A token message: the walk, its MISSINGPERSON slot, and a Lamport clock.
#[derive(Debug, Clone)]
struct Token {
    id: WalkId,
    slot: u16,
    /// Logical time: max over causal history of hops.
    lamport: u64,
}

/// Shared telemetry.
#[derive(Debug, Default)]
pub struct ActorStats {
    pub hops: AtomicU64,
    pub forks: AtomicU64,
    pub control_terminations: AtomicU64,
    pub failures: AtomicU64,
    pub alive: AtomicI64,
}

/// Outcome of an actor-runtime run.
#[derive(Debug, Clone)]
pub struct ActorRun {
    pub hops: u64,
    pub forks: u64,
    pub control_terminations: u64,
    pub failures: u64,
    pub final_alive: i64,
    /// Sampled population trace (wall-clock sampling by the monitor).
    pub z_samples: Vec<i64>,
}

/// Configuration + handles for a decentralized run.
pub struct ActorRuntime {
    pub graph: Arc<Graph>,
    pub z0: u32,
    /// Per-hop probabilistic failure (applied by the sender, modelling
    /// loss in transit).
    pub p_f: f64,
    /// Survival model for every node.
    pub survival: SurvivalModel,
    /// Stop after this many total hops.
    pub hop_budget: u64,
    /// Wall-clock safety net.
    pub max_wall: Duration,
    pub seed: u64,
}

impl ActorRuntime {
    /// Run the decentralized system: spawns one thread per node, injects
    /// `z0` tokens at node 0, lets the control algorithm govern the
    /// population until the hop budget is exhausted.
    pub fn run(&self, control: &dyn ControlAlgorithm) -> anyhow::Result<ActorRun> {
        let n = self.graph.n();
        anyhow::ensure!(n >= 2, "need at least two nodes");
        let stats = Arc::new(ActorStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let next_id = Arc::new(AtomicU64::new(0));

        // Edges: one channel per node.
        let mut senders: Vec<Sender<Token>> = Vec::with_capacity(n);
        let mut receivers: Vec<Option<Receiver<Token>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = std::sync::mpsc::channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        let senders = Arc::new(senders);
        let z_samples = Arc::new(Mutex::new(Vec::new()));

        std::thread::scope(|scope| -> anyhow::Result<()> {
            // Node actors.
            for node in 0..n {
                let rx = receivers[node].take().unwrap();
                let senders = Arc::clone(&senders);
                let stats = Arc::clone(&stats);
                let stop = Arc::clone(&stop);
                let next_id = Arc::clone(&next_id);
                let graph = Arc::clone(&self.graph);
                let mut alg = control.clone_box();
                let mut state = NodeState::new(self.z0 as usize, self.survival);
                let mut rng = Rng::new(self.seed).split(node as u64 + 1);
                let z0 = self.z0;
                let p_f = self.p_f;
                let hop_budget = self.hop_budget;
                scope.spawn(move || {
                    let mut clock: u64 = 0;
                    loop {
                        let token = match rx.recv_timeout(Duration::from_millis(20)) {
                            Ok(t) => t,
                            Err(_) => {
                                if stop.load(Ordering::Relaxed) {
                                    return;
                                }
                                continue;
                            }
                        };
                        clock = clock.max(token.lamport) + 1;
                        state.observe(clock, token.id, token.slot);

                        // Control decision (one per local clock tick by
                        // construction — each receipt advances the clock).
                        let decision = {
                            let mut ctx = VisitCtx {
                                t: clock,
                                node: node as u32,
                                walk: token.id,
                                slot: token.slot,
                                z0,
                                state: &mut state,
                                rng: &mut rng,
                            };
                            alg.on_visit(&mut ctx)
                        };

                        let mut outgoing: Vec<Token> = Vec::with_capacity(1 + decision.forks.len());
                        if decision.terminate {
                            stats.control_terminations.fetch_add(1, Ordering::Relaxed);
                            stats.alive.fetch_add(-1, Ordering::Relaxed);
                        } else {
                            outgoing.push(Token { id: token.id, slot: token.slot, lamport: clock });
                        }
                        for slot in decision.forks {
                            let id = WalkId(next_id.fetch_add(1, Ordering::Relaxed));
                            state.observe(clock, id, slot);
                            stats.forks.fetch_add(1, Ordering::Relaxed);
                            stats.alive.fetch_add(1, Ordering::Relaxed);
                            outgoing.push(Token { id, slot, lamport: clock });
                        }

                        for tok in outgoing {
                            let hops = stats.hops.fetch_add(1, Ordering::Relaxed);
                            if hops >= hop_budget {
                                stop.store(true, Ordering::Relaxed);
                                return;
                            }
                            // Loss in transit.
                            if rng.bernoulli(p_f) {
                                stats.failures.fetch_add(1, Ordering::Relaxed);
                                stats.alive.fetch_add(-1, Ordering::Relaxed);
                                continue;
                            }
                            let to = graph.step(node, &mut rng);
                            // A send fails only if the peer already exited
                            // (shutdown race) — the token is then lost,
                            // which is just another failure mode.
                            if senders[to].send(tok).is_err() {
                                stats.failures.fetch_add(1, Ordering::Relaxed);
                                stats.alive.fetch_add(-1, Ordering::Relaxed);
                            }
                        }
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                });
            }

            // Monitor thread: samples the population until stop.
            {
                let stats = Arc::clone(&stats);
                let stop = Arc::clone(&stop);
                let z_samples = Arc::clone(&z_samples);
                let max_wall = self.max_wall;
                scope.spawn(move || {
                    let start = std::time::Instant::now();
                    loop {
                        let alive = stats.alive.load(Ordering::Relaxed);
                        z_samples.lock().unwrap().push(alive);
                        // Extinction ends the run (nothing can restart a
                        // dead system — the paper's catastrophic failure);
                        // the wall clock is a safety net for tests.
                        if alive <= 0 && stats.hops.load(Ordering::Relaxed) > 0 {
                            stop.store(true, Ordering::Relaxed);
                        }
                        if start.elapsed() > max_wall {
                            stop.store(true, Ordering::Relaxed);
                        }
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                });
            }

            // Inject Z0 tokens at node 0.
            stats.alive.store(self.z0 as i64, Ordering::Relaxed);
            for slot in 0..self.z0 {
                let id = WalkId(next_id.fetch_add(1, Ordering::Relaxed));
                senders[0]
                    .send(Token { id, slot: slot as u16, lamport: 0 })
                    .map_err(|_| anyhow::anyhow!("injection failed"))?;
            }
            Ok(())
        })?;

        let z_samples = Arc::try_unwrap(z_samples).unwrap().into_inner().unwrap();
        Ok(ActorRun {
            hops: stats.hops.load(Ordering::Relaxed),
            forks: stats.forks.load(Ordering::Relaxed),
            control_terminations: stats.control_terminations.load(Ordering::Relaxed),
            failures: stats.failures.load(Ordering::Relaxed),
            final_alive: stats.alive.load(Ordering::Relaxed),
            z_samples,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{Decafork, NoControl};
    use crate::graph::generators;

    fn runtime(p_f: f64, budget: u64) -> ActorRuntime {
        let g = generators::random_regular(16, 4, &mut Rng::new(3)).unwrap();
        ActorRuntime {
            graph: Arc::new(g),
            z0: 4,
            p_f,
            survival: SurvivalModel::Empirical,
            hop_budget: budget,
            max_wall: Duration::from_secs(30),
            seed: 7,
        }
    }

    #[test]
    fn tokens_circulate_without_failures() {
        let run = runtime(0.0, 20_000).run(&NoControl).unwrap();
        assert!(run.hops >= 20_000);
        assert_eq!(run.failures, 0);
        assert_eq!(run.forks, 0);
        assert_eq!(run.final_alive, 4);
    }

    #[test]
    fn decafork_sustains_population_under_losses() {
        // With per-hop losses and no control the population dies after
        // ~Z0/p_f hops; DECAFORK must both fork and extend the system's
        // life by at least an order of magnitude (with a 4-walk
        // population, eventual extinction over an unbounded horizon is
        // always possible, so the assertion is on survival *scale*).
        let dead = runtime(0.01, 1_000_000).run(&NoControl).unwrap();
        assert_eq!(dead.final_alive, 0, "expected extinction without control");
        let run = runtime(0.01, 100_000).run(&Decafork::new(2.0)).unwrap();
        assert!(run.forks > 0, "no forks happened");
        // Relative criterion (robust to CPU contention in the suite):
        // DECAFORK either survives the whole budget or outlives the
        // uncontrolled system several times over.
        assert!(
            run.final_alive > 0 || run.hops >= 4 * dead.hops,
            "DECAFORK died early: {} hops vs {} uncontrolled, {} forks",
            run.hops,
            dead.hops,
            run.forks
        );
    }
}
