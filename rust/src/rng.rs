//! Deterministic pseudo-random number generation.
//!
//! The environment has no network access, so the usual `rand` crate is not
//! available; this module provides the small, well-known generators the
//! simulator needs: a SplitMix64 seeder and a xoshiro256++ stream, plus the
//! distributions used by the paper's failure and control models
//! (uniform, Bernoulli, exponential, geometric) and sequence helpers.
//!
//! Determinism is a hard requirement: every experiment in EXPERIMENTS.md is
//! reproducible from a `u64` seed, and the multi-run aggregator derives
//! per-run streams via [`Rng::split`] so runs are independent but stable
//! under re-ordering/parallelism.

/// Stream-derivation tags for the stream-mode (sharded) engine's
/// randomness ownership model: instead of one engine-wide stream whose
/// consumption order encodes the schedule, every random draw belongs to
/// exactly one owner — a walk, a node, or the failure model — and each
/// owner gets an independent child stream derived from the scenario's
/// simulation stream via [`super::Rng::derive`]`(tag, index)`. Fork children
/// split the *parent walk's* stream (tagged by the within-decision fork
/// index), so a walk's entire draw sequence is a pure function of the
/// scenario, never of hop-iteration order — the property the sharded
/// engine's schedule invariance rests on (DESIGN.md §Per-walk streams).
pub mod streams {
    /// Per-walk streams: `derive(WALK, slot)` for the `Z0` originals.
    pub const WALK: u64 = 0x77616c6b; // "walk"
    /// Per-node streams: `derive(NODE, node)` for control decisions.
    pub const NODE: u64 = 0x6e6f6465; // "node"
    /// Model-level failure stream (bursts, Byzantine Markov flips).
    pub const FAIL: u64 = 0x6661696c; // "fail"
    /// Engine-construction draws (random start placement).
    pub const INIT: u64 = 0x696e6974; // "init"
    /// Per-node learning streams: `derive(LEARN, node)` for batch
    /// sampling in the sharded trainer. A node's batches are a pure
    /// function of its own stream, so visit processing can be sharded
    /// without the sample sequence depending on call interleaving.
    pub const LEARN: u64 = 0x6c6561726e; // "learn"
}

/// SplitMix64 step — used for seeding and stream splitting.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Small, fast, passes BigCrush; more than adequate for
/// Monte-Carlo simulation of random walks.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start at the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Derive an independent child stream; `tag` distinguishes siblings.
    pub fn split(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2].rotate_left(17) ^ tag.wrapping_mul(0xA076_1D64_78BD_642F);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Two-level split `self.split(tag).split(index)`: one named family
    /// ([`streams`]), one member. The extra level keeps families with
    /// colliding indices (walk 3, node 3) on unrelated streams.
    pub fn derive(&self, tag: u64, index: u64) -> Rng {
        self.split(tag).split(index)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0) is undefined");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only entered for lo < n; recompute threshold.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// [`below`](Self::below) with the rejection threshold
    /// `n.wrapping_neg() % n` precomputed by the caller. Draw-for-draw
    /// and bit-for-bit compatible with `below(n)`: `below` accepts
    /// exactly when `lo >= threshold` (its `lo >= n` fast path is
    /// subsumed, since `threshold < n`), it just computes the modulo
    /// lazily. Hot-loop callers that sample the same bound many times
    /// (the graph backends' neighbor draw) hoist the division here.
    #[inline]
    pub fn below_threshold(&mut self, n: u64, threshold: u64) -> usize {
        debug_assert!(n > 0, "below_threshold(0, _) is undefined");
        debug_assert_eq!(threshold, n.wrapping_neg() % n, "stale precomputed threshold");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        self.f64() < p
    }

    /// Exponential variate with rate `lambda` (mean `1/lambda`).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // Inverse-CDF; 1 - f64() is in (0, 1] so ln() is finite.
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Geometric variate on support {1, 2, ...} with success prob `q`
    /// (number of trials up to and including the first success).
    #[inline]
    pub fn geometric(&mut self, q: f64) -> u64 {
        debug_assert!(q > 0.0 && q <= 1.0);
        if q >= 1.0 {
            return 1;
        }
        let u = 1.0 - self.f64(); // in (0, 1]
        (u.ln() / (1.0 - q).ln()).ceil().max(1.0) as u64
    }

    /// Choose a uniformly random element of a non-empty slice.
    #[inline]
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_streams_are_independent_and_stable() {
        let root = Rng::new(7);
        let mut c1 = root.split(0);
        let mut c2 = root.split(1);
        let mut c1b = root.split(0);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn derive_is_stable_and_family_separated() {
        let root = Rng::new(99);
        // Stable: same (tag, index) → same stream.
        let mut a = root.derive(streams::WALK, 3);
        let mut b = root.derive(streams::WALK, 3);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Equivalent to the explicit two-level split.
        let mut c = root.derive(streams::NODE, 7);
        let mut d = root.split(streams::NODE).split(7);
        for _ in 0..16 {
            assert_eq!(c.next_u64(), d.next_u64());
        }
        // Family-separated: walk 3 and node 3 share an index but not a
        // stream.
        let mut w = root.derive(streams::WALK, 3);
        let mut n = root.derive(streams::NODE, 3);
        let same = (0..64).filter(|_| w.next_u64() == n.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let n = 10;
        let mut counts = vec![0usize; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        for &c in &counts {
            let expect = trials as f64 / n as f64;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt() + 50.0);
        }
    }

    #[test]
    fn below_threshold_matches_below_draw_for_draw() {
        // The precomputed-threshold kernel must consume the same number
        // of raw draws and return the same value as `below` from the
        // same state — including awkward bounds where the rejection
        // zone is non-empty (non-powers of two near 2^63).
        for n in [1usize, 2, 3, 7, 10, 64, 1000, (1u64 << 63) as usize + 12345] {
            let threshold = (n as u64).wrapping_neg() % n as u64;
            let mut a = Rng::new(0xABCD ^ n as u64);
            let mut b = a.clone();
            for _ in 0..256 {
                assert_eq!(a.below(n), b.below_threshold(n as u64, threshold));
                assert_eq!(a.next_u64(), b.next_u64(), "stream desynced at n={n}");
            }
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let lambda = 0.25;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.05 / lambda);
    }

    #[test]
    fn geometric_mean_and_support() {
        let mut r = Rng::new(13);
        let q = 0.2;
        let n = 200_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let g = r.geometric(q);
            assert!(g >= 1);
            sum += g as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0 / q).abs() < 0.1);
    }

    #[test]
    fn bernoulli_extremes() {
        let mut r = Rng::new(17);
        assert!(!r.bernoulli(0.0));
        assert!(r.bernoulli(1.0));
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }
}
