//! Paper-figure regeneration harness (Figs. 1–6).
//!
//! Each `figN` function runs the corresponding experiment and returns a
//! [`FigureResult`] with one aggregated `Z_t` series per curve plus the
//! derived summary rows (reaction times, overshoot, fork counts). Used by
//! both the `decafork figure` CLI subcommand and the `cargo bench`
//! targets, which print the same series the paper plots.
//!
//! Scaling: the paper uses 50 runs over a 10 000-step horizon. `runs` is a
//! parameter so benches can run a faster replication count while the CLI
//! default reproduces the paper (`--runs 50`).

use crate::report::{self, Table};
use crate::scenario::{presets, ControlSpec, FailureSpec, GraphSpec, Scenario};
use crate::sim::metrics::Trace;
use crate::sim::{run_many_with_budget, AggregateTrace, CoreBudget};

/// One curve: label + aggregate across runs (+ raw traces for derived
/// statistics).
pub struct Curve {
    pub label: String,
    pub agg: AggregateTrace,
    pub traces: Vec<Trace>,
}

/// A reproduced figure.
pub struct FigureResult {
    pub name: &'static str,
    pub title: String,
    pub curves: Vec<Curve>,
    /// Burst times (for reaction-time summaries).
    pub bursts: Vec<u64>,
    pub z0: u32,
}

impl FigureResult {
    /// Render the mean `Z_t` series as an ASCII plot.
    pub fn plot(&self, width: usize, height: usize) -> String {
        let series: Vec<(&str, &[f64])> = self
            .curves
            .iter()
            .map(|c| (c.label.as_str(), c.agg.mean.as_slice()))
            .collect();
        report::ascii_plot(&self.title, &series, width, height)
    }

    /// Summary table: per curve, the paper's qualitative metrics.
    pub fn summary(&self) -> String {
        let mut t = Table::new(&[
            "curve",
            "mean Z (t>500)",
            "min Z",
            "max Z",
            "reaction(b1)",
            "reaction(b2)",
            "forks/run",
            "terms/run",
            "extinct",
        ]);
        for c in &self.curves {
            let horizon = c.traces[0].horizon();
            let reaction = |b: Option<&u64>| -> String {
                match b {
                    None => "-".into(),
                    Some(&bt) => {
                        let (m, unrec) = AggregateTrace::mean_recovery(&c.traces, bt, self.z0);
                        match m {
                            Some(v) if unrec == 0 => format!("{v:.0}"),
                            Some(v) => format!("{v:.0} ({unrec} fail)"),
                            None => "never".into(),
                        }
                    }
                }
            };
            let mean_z: f64 = c
                .traces
                .iter()
                .map(|tr| tr.mean_z(500, horizon))
                .sum::<f64>()
                / c.traces.len() as f64;
            let forks = c.agg.forks_per_run.iter().sum::<usize>() as f64 / c.agg.runs as f64;
            let terms = c.agg.terms_per_run.iter().sum::<usize>() as f64 / c.agg.runs as f64;
            t.row(vec![
                c.label.clone(),
                format!("{mean_z:.2}"),
                format!("{}", c.agg.min.iter().min().unwrap()),
                format!("{}", c.agg.max.iter().max().unwrap()),
                reaction(self.bursts.first()),
                reaction(self.bursts.get(1)),
                format!("{forks:.1}"),
                format!("{terms:.1}"),
                format!("{}/{}", c.agg.extinctions, c.agg.runs),
            ]);
        }
        t.render()
    }

    /// Write `results/<name>.csv`: `t, <label>_mean, <label>_std, ...`.
    pub fn write_csv(&self, dir: &str) -> anyhow::Result<std::path::PathBuf> {
        let mut headers: Vec<String> = vec!["t".into()];
        for c in &self.curves {
            headers.push(format!("{}_mean", c.label));
            headers.push(format!("{}_std", c.label));
        }
        let len = self.curves.iter().map(|c| c.agg.mean.len()).min().unwrap();
        let mut rows = Vec::with_capacity(len);
        for t in 0..len {
            let mut row = vec![t as f64];
            for c in &self.curves {
                row.push(c.agg.mean[t]);
                row.push(c.agg.std[t]);
            }
            rows.push(row);
        }
        let path = std::path::Path::new(dir).join(format!("{}.csv", self.name));
        let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report::write_csv(&path, &hdr, &rows)?;
        Ok(path)
    }
}

fn run_curve(
    label: &str,
    cfg: &Scenario,
    threads: usize,
    cores: CoreBudget,
) -> anyhow::Result<Curve> {
    let (traces, agg) = run_many_with_budget(cfg, threads, cores)?;
    Ok(Curve { label: label.to_string(), agg, traces })
}

/// `shards` selects the engine per replication (1 = shared-stream, the
/// historical figure semantics; >= 2 = stream mode — statistically the
/// same figures, different sample paths). It rides in `params.shards`,
/// so every curve derived from the base config inherits it.
fn base_cfg(runs: usize, shards: usize) -> Scenario {
    let mut cfg = presets::fig1_base(runs);
    cfg.params.shards = shards.max(1);
    cfg
}

/// MISSINGPERSON ε_mp: the paper says "properly tuned"; the natural scale
/// is the mean return time `2|E|/deg = n` (= 100 here). Staleness of a
/// healthy slot is ~Exp(1/100), so false-alarm rate per step ≈
/// `Z0·(Z0−1)·p·e^{−ε_mp/100}`; ε_mp = 800 keeps pre-failure forking
/// near zero over a 10k-step horizon while still (slowly) detecting true
/// losses — the paper's Fig. 1 trade-off.
const MP_EPS: u64 = 800;

/// Fig. 1: MISSINGPERSON vs DECAFORK (ε=2) vs DECAFORK+ (3.25/5.75),
/// bursts −5 @ 2000 and −6 @ 6000.
pub fn fig1(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let mut curves = Vec::new();
    for (label, control) in [
        ("missingperson", ControlSpec::MissingPerson { eps_mp: MP_EPS }),
        ("decafork(e=2)", ControlSpec::Decafork { epsilon: 2.0 }),
        ("decafork+(3.25/5.75)", ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 }),
    ] {
        let cfg = Scenario { control, ..base.clone() };
        curves.push(run_curve(label, &cfg, threads, cores)?);
    }
    Ok(FigureResult {
        name: "fig1",
        title: "Fig.1 — burst failures (8-regular n=100, Z0=10)".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Fig. 2: bursts + per-step probabilistic failure p_f.
pub fn fig2(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let mut curves = Vec::new();
    for p_f in [0.0002, 0.001] {
        let failures = FailureSpec::Composite(vec![
            FailureSpec::paper_bursts(),
            FailureSpec::Probabilistic { p_f },
        ]);
        for (label, control) in [
            (
                format!("decafork(e=2) pf={p_f}"),
                ControlSpec::Decafork { epsilon: 2.0 },
            ),
            (
                format!("decafork+ pf={p_f}"),
                ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 },
            ),
        ] {
            let cfg = Scenario { control, failures: failures.clone(), ..base.clone() };
            curves.push(run_curve(&label, &cfg, threads, cores)?);
        }
    }
    Ok(FigureResult {
        name: "fig2",
        title: "Fig.2 — bursts + probabilistic failures".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Fig. 3: bursts + a Byzantine node. The Byzantine node terminates every
/// arriving walk during its `Byz` phase `[1000, 5000)` (after the paper's
/// required failure-free initialization), then abruptly turns honest
/// (`No Byz`) — the hard switch DECAFORK overshoots on.
pub fn fig3(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let failures = FailureSpec::Composite(vec![
        FailureSpec::paper_bursts(),
        FailureSpec::ByzantineScheduled { node: 1, schedule: vec![(1000, true), (5000, false)] },
    ]);
    let mut curves = Vec::new();
    for (label, control) in [
        ("decafork(e=2)", ControlSpec::Decafork { epsilon: 2.0 }),
        ("decafork(e=3.25)", ControlSpec::Decafork { epsilon: 3.25 }),
        ("decafork+(3.25/5.75)", ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 }),
    ] {
        let cfg = Scenario { control, failures: failures.clone(), ..base.clone() };
        curves.push(run_curve(label, &cfg, threads, cores)?);
    }
    Ok(FigureResult {
        name: "fig3",
        title: "Fig.3 — bursts + Byzantine node (Byz until t=5000, honest after)".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Fig. 4: scaling in n ∈ {50, 100, 200} with per-n tuned ε. The paper
/// lists ε ∈ {1.85, 2, 2.1} "well-tuned for the respective n" without the
/// assignment; empirically the *inverse* pairing (larger ε for smaller n)
/// reproduces its claim that smaller graphs react faster — smaller graphs
/// have tighter return-time support, so they tolerate a more aggressive
/// threshold without overshoot.
pub fn fig4(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let mut curves = Vec::new();
    for (n, eps) in [(50usize, 2.1), (100, 2.0), (200, 1.85)] {
        let cfg = Scenario {
            graph: GraphSpec::RandomRegular { n, d: 8 },
            control: ControlSpec::Decafork { epsilon: eps },
            ..base.clone()
        };
        curves.push(run_curve(&format!("n={n} (e={eps})"), &cfg, threads, cores)?);
    }
    Ok(FigureResult {
        name: "fig4",
        title: "Fig.4 — DECAFORK across graph sizes".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Fig. 5: the ε trade-off (reaction time vs overshoot), n = 100.
pub fn fig5(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let mut curves = Vec::new();
    for eps in [1.5, 2.0, 2.5, 3.0, 3.5] {
        let cfg = Scenario {
            control: ControlSpec::Decafork { epsilon: eps },
            ..base.clone()
        };
        curves.push(run_curve(&format!("e={eps}"), &cfg, threads, cores)?);
    }
    Ok(FigureResult {
        name: "fig5",
        title: "Fig.5 — reaction-time vs overshoot trade-off in ε".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Fig. 6: four graph families at n = 100.
pub fn fig6(
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    let base = base_cfg(runs, shards);
    let mut curves = Vec::new();
    for (label, graph, eps) in [
        ("8-regular", GraphSpec::RandomRegular { n: 100, d: 8 }, 2.0),
        ("complete", GraphSpec::Complete { n: 100 }, 2.0),
        ("erdos-renyi", GraphSpec::ErdosRenyi { n: 100, p: 0.08 }, 1.9),
        ("power-law", GraphSpec::PowerLaw { n: 100, m: 4 }, 2.1),
    ] {
        let cfg = Scenario {
            graph,
            control: ControlSpec::Decafork { epsilon: eps },
            ..base.clone()
        };
        curves.push(run_curve(label, &cfg, threads, cores)?);
    }
    Ok(FigureResult {
        name: "fig6",
        title: "Fig.6 — DECAFORK across graph families (n=100)".into(),
        curves,
        bursts: vec![2000, 6000],
        z0: 10,
    })
}

/// Run a figure by id. `cores` is the replication × shard core budget
/// (CLI `--cores` / `DECAFORK_CORES` / detected parallelism).
pub fn by_id(
    id: u32,
    runs: usize,
    threads: usize,
    shards: usize,
    cores: CoreBudget,
) -> anyhow::Result<FigureResult> {
    match id {
        1 => fig1(runs, threads, shards, cores),
        2 => fig2(runs, threads, shards, cores),
        3 => fig3(runs, threads, shards, cores),
        4 => fig4(runs, threads, shards, cores),
        5 => fig5(runs, threads, shards, cores),
        6 => fig6(runs, threads, shards, cores),
        other => anyhow::bail!("unknown figure id {other} (have 1..=6)"),
    }
}

#[cfg(test)]
mod tests {
    // Figure harnesses are exercised end-to-end in the bench targets and
    // integration tests; here only the cheap plumbing.
    use super::*;

    #[test]
    fn by_id_rejects_unknown() {
        assert!(by_id(7, 1, 1, 1, CoreBudget::detect()).is_err());
    }

    #[test]
    fn fig1_smoke_tiny() {
        // 2 runs, tiny horizon via direct config manipulation is not
        // exposed; run the real fig1 at 1 run only in release-mode CI
        // (cargo test still completes in seconds at n=100, horizon 10k).
        let f = fig1(1, 1, 1, CoreBudget::detect()).unwrap();
        assert_eq!(f.curves.len(), 3);
        assert!(f.write_csv(&std::env::temp_dir().join("decafork_figtest").to_string_lossy()).is_ok());
        assert!(!f.summary().is_empty());
        assert!(f.plot(60, 12).contains("Fig.1"));
    }
}
