//! Random-walk tokens and per-node bookkeeping.
//!
//! A *walk* is the paper's token: the currently visited node holds it,
//! performs local work (in the learning application, one SGD step) and
//! forwards it to a uniformly random neighbor. Each walk carries a unique
//! identifier plus a fork lineage (paper footnote 8: a forked walk appends
//! the forking node and fork time to its identifier).
//!
//! Every node maintains a [`NodeState`]: the last-seen table `L_{i,k}`,
//! the pooled empirical return-time distribution `R̂_i`, and the estimator
//! `θ̂_i(t) = ½ + Σ_{ℓ≠k} S(t − L_{i,ℓ})` from Eq. (1).

pub mod lineage;
pub mod node_state;

pub use node_state::{NodeState, SurvivalModel};

/// Globally unique walk identifier (never reused within a simulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WalkId(pub u64);

impl std::fmt::Display for WalkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "w{}", self.0)
    }
}

/// Fork lineage: how this walk came to exist (paper footnote 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lineage {
    /// One of the `Z0` walks created at start-up; `slot` is its index.
    Original { slot: u16 },
    /// Forked from `parent` by node `by` at time `at`. For MISSINGPERSON
    /// replacements, `slot` records the identity being replaced; DECAFORK
    /// forks carry the parent's slot for reporting only.
    Forked { parent: WalkId, by: u32, at: u64, slot: u16 },
}

impl Lineage {
    /// The slot label (original index or replaced identity).
    pub fn slot(&self) -> u16 {
        match *self {
            Lineage::Original { slot } => slot,
            Lineage::Forked { slot, .. } => slot,
        }
    }
}

/// A live (or dead) walk token.
#[derive(Debug, Clone)]
pub struct Walk {
    pub id: WalkId,
    pub lineage: Lineage,
    /// Node currently holding the token.
    pub at: u32,
    pub alive: bool,
    /// Time of creation (0 for originals).
    pub born: u64,
    /// Time of death, if any.
    pub died: Option<u64>,
    /// Index of an application payload (e.g. model parameters) in the
    /// engine's payload store; forks clone the payload.
    pub payload: Option<usize>,
}

/// Allocator for unique walk ids.
#[derive(Debug, Default, Clone)]
pub struct WalkIdGen {
    next: u64,
}

impl WalkIdGen {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn fresh(&mut self) -> WalkId {
        let id = WalkId(self.next);
        self.next += 1;
        id
    }

    /// Number of ids handed out so far.
    pub fn issued(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_unique_and_ordered() {
        let mut g = WalkIdGen::new();
        let a = g.fresh();
        let b = g.fresh();
        assert_ne!(a, b);
        assert!(a < b);
        assert_eq!(g.issued(), 2);
    }

    #[test]
    fn lineage_slots() {
        let orig = Lineage::Original { slot: 3 };
        assert_eq!(orig.slot(), 3);
        let fork = Lineage::Forked { parent: WalkId(0), by: 7, at: 100, slot: 3 };
        assert_eq!(fork.slot(), 3);
    }

    #[test]
    fn display() {
        assert_eq!(WalkId(5).to_string(), "w5");
    }
}
