//! Random-walk tokens and per-node bookkeeping.
//!
//! A *walk* is the paper's token: the currently visited node holds it,
//! performs local work (in the learning application, one SGD step) and
//! forwards it to a uniformly random neighbor. Each walk carries a unique
//! identifier plus a fork lineage (paper footnote 8: a forked walk appends
//! the forking node and fork time to its identifier).
//!
//! ## Storage layout (see `DESIGN.md` §Walk arena)
//!
//! Live walks are stored in a [`WalkArena`]: a struct-of-arrays store
//! whose dense columns (`at`, `born`, `lineage`, `payload`, and — for
//! stream-mode engines — each walk's own `Rng` stream) hold **only
//! live walks, in creation order**, so the engine's hot loop touches
//! cache-contiguous data and never skips dead entries. Retired walks move
//! to a cold `graveyard` that preserves the full [`Walk`] record for
//! lineage inspection. Walk identity is a generational [`WalkId`]
//! (arena slot index + generation), so a slot freed by a kill can be
//! reused by a fork in the same step without the two walks ever aliasing.
//!
//! Every node maintains a [`NodeState`]: the last-seen table `L_{i,k}`
//! (struct-of-arrays `ids ∥ last` columns with a compact O(1)
//! open-addressing [`SlotIndex`]), the pooled empirical return-time
//! distribution `R̂_i`, a
//! memoised survival table `dt → S(dt)` (DESIGN.md §Survival cache),
//! and the estimator `θ̂_i(t) = ½ + Σ_{ℓ≠k} S(t − L_{i,ℓ})` from
//! Eq. (1).
//!
//! Engines keep node states behind a [`NodeStore`] (DESIGN.md §Lazy
//! node store): by default a node's state is materialized on **first
//! visit** and kept in a sparse first-visit-order column, so engine
//! memory and prune sweeps are O(visited) rather than O(n) — the
//! property that makes 10⁸-node scenarios runnable. The eager dense
//! layout survives as the selectable [`NodeStateMode::Dense`] oracle.

pub mod arena;
pub mod lineage;
pub mod node_state;
pub mod node_store;
pub mod slot_index;

pub use arena::WalkArena;
pub use node_state::{NodeState, SurvivalModel};
pub use node_store::{NodeStateMode, NodeStore, StatesView};
pub use slot_index::SlotIndex;

/// Unique walk identifier: a packed generational index. The low 32 bits
/// are the walk's [`WalkArena`] slot index, the high 32 bits the slot's
/// generation at spawn time. Two walks that ever coexist — or that reuse
/// the same slot at different times — always compare unequal, which is
/// all the estimator's last-seen tables rely on.
///
/// The raw `u64` constructor is kept public: `WalkId(n)` with `n < 2³²`
/// is simply "slot n, generation 0", which is how sequential allocators
/// (the actor runtime, the frozen reference engine, tests) mint ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WalkId(pub u64);

impl WalkId {
    /// Pack a slot index and generation into an id.
    pub const fn compose(index: u32, generation: u32) -> WalkId {
        WalkId(((generation as u64) << 32) | index as u64)
    }

    /// Arena slot index (low 32 bits).
    pub const fn index(self) -> u32 {
        self.0 as u32
    }

    /// Slot generation at spawn time (high 32 bits).
    pub const fn generation(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

impl std::fmt::Display for WalkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.generation() == 0 {
            write!(f, "w{}", self.index())
        } else {
            write!(f, "w{}.g{}", self.index(), self.generation())
        }
    }
}

/// Fork lineage: how this walk came to exist (paper footnote 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lineage {
    /// One of the `Z0` walks created at start-up; `slot` is its index.
    Original { slot: u16 },
    /// Forked from `parent` by node `by` at time `at`. For MISSINGPERSON
    /// replacements, `slot` records the identity being replaced; DECAFORK
    /// forks carry the parent's slot for reporting only.
    Forked { parent: WalkId, by: u32, at: u64, slot: u16 },
}

impl Lineage {
    /// The slot label (original index or replaced identity).
    pub fn slot(&self) -> u16 {
        match *self {
            Lineage::Original { slot } => slot,
            Lineage::Forked { slot, .. } => slot,
        }
    }
}

/// A materialized walk record: what the arena's graveyard stores and what
/// [`WalkArena::snapshot`] hands to lineage analytics. The live hot path
/// never builds these — it works on the arena's columns directly through
/// [`WalkRef`]/[`WalkMut`] views.
#[derive(Debug, Clone)]
pub struct Walk {
    pub id: WalkId,
    pub lineage: Lineage,
    /// Node currently (or last) holding the token.
    pub at: u32,
    pub alive: bool,
    /// Time of creation (0 for originals).
    pub born: u64,
    /// Time of death, if any.
    pub died: Option<u64>,
    /// Index of an application payload (e.g. model parameters) in the
    /// learning layer's payload store; forks clone the payload.
    pub payload: Option<usize>,
}

/// Cheap by-value view of a live walk (all fields `Copy`), handed to
/// hooks that only read walk state.
#[derive(Debug, Clone, Copy)]
pub struct WalkRef {
    pub id: WalkId,
    pub at: u32,
    pub born: u64,
    pub lineage: Lineage,
    pub payload: Option<usize>,
}

impl From<&Walk> for WalkRef {
    fn from(w: &Walk) -> Self {
        WalkRef { id: w.id, at: w.at, born: w.born, lineage: w.lineage, payload: w.payload }
    }
}

/// Mutable view of a live walk: read-only identity plus a mutable borrow
/// of the application payload slot — the only field hooks may change.
#[derive(Debug)]
pub struct WalkMut<'a> {
    pub id: WalkId,
    pub at: u32,
    pub born: u64,
    pub lineage: Lineage,
    pub payload: &'a mut Option<usize>,
}

impl<'a> From<&'a mut Walk> for WalkMut<'a> {
    fn from(w: &'a mut Walk) -> Self {
        WalkMut { id: w.id, at: w.at, born: w.born, lineage: w.lineage, payload: &mut w.payload }
    }
}

/// Sequential allocator for unique walk ids (generation always 0). Used
/// by the actor runtime and the frozen reference engine; the arena mints
/// its own generational ids.
#[derive(Debug, Default, Clone)]
pub struct WalkIdGen {
    next: u64,
}

impl WalkIdGen {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn fresh(&mut self) -> WalkId {
        let id = WalkId(self.next);
        self.next += 1;
        id
    }

    /// Number of ids handed out so far.
    pub fn issued(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_unique_and_ordered() {
        let mut g = WalkIdGen::new();
        let a = g.fresh();
        let b = g.fresh();
        assert_ne!(a, b);
        assert!(a < b);
        assert_eq!(g.issued(), 2);
    }

    #[test]
    fn generational_packing_roundtrips() {
        let id = WalkId::compose(7, 3);
        assert_eq!(id.index(), 7);
        assert_eq!(id.generation(), 3);
        assert_ne!(id, WalkId::compose(7, 4));
        assert_ne!(id, WalkId::compose(8, 3));
        // Sequential ids are generation-0 slots.
        assert_eq!(WalkId(5), WalkId::compose(5, 0));
    }

    #[test]
    fn lineage_slots() {
        let orig = Lineage::Original { slot: 3 };
        assert_eq!(orig.slot(), 3);
        let fork = Lineage::Forked { parent: WalkId(0), by: 7, at: 100, slot: 3 };
        assert_eq!(fork.slot(), 3);
    }

    #[test]
    fn display() {
        assert_eq!(WalkId(5).to_string(), "w5");
        assert_eq!(WalkId::compose(5, 2).to_string(), "w5.g2");
    }
}
