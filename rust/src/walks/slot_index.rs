//! A compact open-addressing index `walk-slot → column position` for
//! [`NodeState`](super::NodeState)'s last-seen table.
//!
//! ## Why not the direct array
//!
//! The previous `slot_pos: Vec<u32>` was indexed by `WalkId::index()`
//! directly, so every visited node paid ~4 B × the **largest walk-slot
//! index it ever observed** — the peak concurrent walk population, not
//! the handful of walks that node actually knows. At `scale_1m`
//! (10⁶ nodes) that footprint is what forced Z0 down to 1024: a dense
//! population would have cost tens of gigabytes of mostly-`u32::MAX`
//! entries. This table is sized by the node's **own** entry count
//! (power-of-two buckets at ≤ 7/8 load), so per-node memory tracks
//! `|L_i(t)|` and a million-node graph can carry a dense walk
//! population.
//!
//! ## Why it cannot move a θ̂ bit
//!
//! The index is **lookup-only**: it is consulted for point queries
//! (`observe`'s revisit check, `knows`, `last_seen_of`) and never
//! iterated. The θ̂ float sum runs over the `ids ∥ last` columns in
//! first-seen order exactly as before, `observe`'s append/update logic
//! is unchanged, and the index stores the same `position` values the
//! direct array stored — so every golden trace, stream golden, and
//! cached-θ̂ equivalence lock passes unchanged (plus the dedicated
//! `prop_compact_index_matches_direct_array` schedule test).
//!
//! Implementation: Fibonacci-hashed linear probing with backward-shift
//! deletion (no tombstones — probe chains stay short under the
//! `prune`-heavy churn this table lives in), quartering on
//! [`maybe_shrink`](SlotIndex::maybe_shrink) so a node that once knew
//! many walks gives the memory back after pruning.

/// Bucket marker for "no key".
const EMPTY: u32 = u32::MAX;
/// Smallest non-empty bucket array.
const MIN_CAP: usize = 8;

/// Open-addressing map from a walk's arena slot index to its position in
/// the node's `ids ∥ last` columns. Keys are `WalkId::index()` values
/// (`< 2³² − 1`; the arena asserts the same bound on slot space).
#[derive(Debug, Clone, Default)]
pub struct SlotIndex {
    /// Parallel bucket arrays (`keys[b] == EMPTY` ⇒ vacant).
    keys: Vec<u32>,
    vals: Vec<u32>,
    len: usize,
}

impl SlotIndex {
    /// An empty index. Allocates nothing until the first insert — most
    /// nodes of a sparse-visit graph never see a walk.
    pub fn new() -> Self {
        SlotIndex::default()
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the index holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated bucket count — the index's memory footprint in units of
    /// 8 B. Grows with this node's peak entry count, **not** with the
    /// global walk-slot space (the whole point; asserted by the memory
    /// tests).
    pub fn capacity(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn home(&self, key: u32) -> usize {
        // Fibonacci hashing: multiply by ⌊2⁶⁴/φ⌋ and keep the top bits.
        // Sequential slot indices (the common allocation pattern) spread
        // instead of clustering one probe chain.
        let h = (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> 32) as usize & (self.keys.len() - 1)
    }

    /// Bucket holding `key`, if present.
    #[inline]
    fn find(&self, key: u32) -> Option<usize> {
        if self.keys.is_empty() {
            return None;
        }
        let mask = self.keys.len() - 1;
        let mut b = self.home(key);
        loop {
            match self.keys[b] {
                EMPTY => return None,
                k if k == key => return Some(b),
                _ => b = (b + 1) & mask,
            }
        }
    }

    /// The column position stored for `key`.
    #[inline]
    pub fn get(&self, key: u32) -> Option<u32> {
        self.find(key).map(|b| self.vals[b])
    }

    /// Number of buckets a lookup of `key` scans (1 = home-bucket hit;
    /// counts through the terminating `EMPTY` or match, whichever comes
    /// first; 0 on an unallocated table). **Read-only telemetry** — the
    /// same walk [`find`](Self::find) performs, re-traced for the
    /// observability layer's probe-length histograms; it touches no
    /// bucket mutably and so cannot perturb any lookup or trace.
    #[inline]
    pub fn probe_len(&self, key: u32) -> u32 {
        if self.keys.is_empty() {
            return 0;
        }
        let mask = self.keys.len() - 1;
        let mut b = self.home(key);
        let mut probes = 1u32;
        loop {
            match self.keys[b] {
                EMPTY => return probes,
                k if k == key => return probes,
                _ => {
                    b = (b + 1) & mask;
                    probes += 1;
                }
            }
        }
    }

    /// Hint the cache that `key`'s home bucket is about to be probed.
    /// The blocked control pipeline issues this one block ahead of the
    /// [`get`](Self::get) that `pos_or_create` runs, hiding the random
    /// (Fibonacci-hashed) line miss behind the previous block's work.
    /// Only the home bucket is hinted — probe chains are short by the
    /// 7/8 load bound, and a second-line continuation is in-page and
    /// usually covered by the hardware next-line prefetcher. Advisory
    /// only; no-op on an unallocated index.
    #[inline(always)]
    pub fn prefetch(&self, key: u32) {
        if self.keys.is_empty() {
            return;
        }
        let b = self.home(key);
        crate::runtime::prefetch::prefetch_read(&self.keys[b]);
        crate::runtime::prefetch::prefetch_read(&self.vals[b]);
    }

    /// Insert `key → val`, overwriting any existing mapping (that is how
    /// a reused arena slot supersedes its dead predecessor's pointer).
    pub fn set(&mut self, key: u32, val: u32) {
        debug_assert_ne!(key, EMPTY, "u32::MAX is the vacancy marker, not a valid slot");
        if let Some(b) = self.find(key) {
            self.vals[b] = val;
            return;
        }
        // Grow before inserting when the next entry would pass 7/8 load.
        if self.keys.len() * 7 < (self.len + 1) * 8 {
            self.rehash((self.keys.len() * 2).max(MIN_CAP));
        }
        let mask = self.keys.len() - 1;
        let mut b = self.home(key);
        while self.keys[b] != EMPTY {
            b = (b + 1) & mask;
        }
        self.keys[b] = key;
        self.vals[b] = val;
        self.len += 1;
    }

    /// Remove `key` (no-op when absent), repairing the probe chain by
    /// backward shifting so lookups never need tombstones.
    pub fn remove(&mut self, key: u32) {
        let Some(mut hole) = self.find(key) else { return };
        let mask = self.keys.len() - 1;
        let mut b = hole;
        loop {
            b = (b + 1) & mask;
            if self.keys[b] == EMPTY {
                break;
            }
            // An entry may move into the hole iff the hole lies within
            // its probe chain, i.e. cyclically between its home bucket
            // and its current bucket.
            let home = self.home(self.keys[b]);
            if (b.wrapping_sub(home) & mask) >= (b.wrapping_sub(hole) & mask) {
                self.keys[hole] = self.keys[b];
                self.vals[hole] = self.vals[b];
                hole = b;
            }
        }
        self.keys[hole] = EMPTY;
        self.len -= 1;
    }

    /// Release bucket memory no longer justified by the entry count
    /// (called after `prune`'s bulk removals): quarter-occupancy or
    /// emptiness shrinks the table, so a node's index tracks its
    /// *current* neighborhood of walks, not its historical peak.
    pub fn maybe_shrink(&mut self) {
        if self.len == 0 {
            self.keys = Vec::new();
            self.vals = Vec::new();
            return;
        }
        let mut target = self.keys.len();
        while target > MIN_CAP && self.len * 4 < target {
            target /= 2;
        }
        if target < self.keys.len() {
            self.rehash(target);
        }
    }

    fn rehash(&mut self, new_cap: usize) {
        debug_assert!(new_cap.is_power_of_two() && self.len * 8 <= new_cap * 7);
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; new_cap]);
        let old_vals = std::mem::take(&mut self.vals);
        self.vals = vec![0; new_cap];
        let mask = new_cap - 1;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k == EMPTY {
                continue;
            }
            let mut b = self.home(k);
            while self.keys[b] != EMPTY {
                b = (b + 1) & mask;
            }
            self.keys[b] = k;
            self.vals[b] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::collections::HashMap;

    #[test]
    fn empty_allocates_nothing_and_answers_none() {
        let idx = SlotIndex::new();
        assert_eq!(idx.capacity(), 0);
        assert!(idx.is_empty());
        assert_eq!(idx.get(0), None);
        assert_eq!(idx.get(u32::MAX - 1), None);
    }

    #[test]
    fn set_get_overwrite_remove() {
        let mut idx = SlotIndex::new();
        idx.set(3, 10);
        idx.set(900_000_000, 11); // far-apart keys share nothing
        assert_eq!(idx.get(3), Some(10));
        assert_eq!(idx.get(900_000_000), Some(11));
        idx.set(3, 99); // supersede
        assert_eq!(idx.get(3), Some(99));
        assert_eq!(idx.len(), 2);
        idx.remove(3);
        assert_eq!(idx.get(3), None);
        assert_eq!(idx.get(900_000_000), Some(11));
        idx.remove(3); // absent: no-op
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn capacity_tracks_entries_not_key_magnitude() {
        // The direct array this replaces would have been ~16 MB here;
        // the index must stay at the MIN_CAP floor for a handful of
        // huge-valued keys.
        let mut idx = SlotIndex::new();
        for k in 0..5u32 {
            idx.set(4_000_000 * (k + 1), k);
        }
        assert_eq!(idx.len(), 5);
        assert!(idx.capacity() <= 16, "capacity {} scales with key magnitude", idx.capacity());
        for k in 0..5u32 {
            assert_eq!(idx.get(4_000_000 * (k + 1)), Some(k));
        }
    }

    #[test]
    fn shrink_returns_memory_after_bulk_removal() {
        let mut idx = SlotIndex::new();
        for k in 0..4096u32 {
            idx.set(k, k);
        }
        let peak = idx.capacity();
        for k in 0..4090u32 {
            idx.remove(k);
        }
        idx.maybe_shrink();
        assert!(idx.capacity() < peak / 64, "{} vs peak {peak}", idx.capacity());
        for k in 4090..4096u32 {
            assert_eq!(idx.get(k), Some(k), "survivor lost in shrink");
        }
        for k in 0..4090u32 {
            assert_eq!(idx.get(k), None);
        }
        // Emptying gives everything back.
        for k in 4090..4096u32 {
            idx.remove(k);
        }
        idx.maybe_shrink();
        assert_eq!(idx.capacity(), 0);
    }

    #[test]
    fn probe_len_counts_the_lookup_walk_read_only() {
        let mut idx = SlotIndex::new();
        assert_eq!(idx.probe_len(7), 0, "unallocated table: nothing to probe");
        idx.set(1, 10);
        // Present and absent keys both terminate; a hit at the home
        // bucket reports exactly one probe.
        for k in 0..64u32 {
            let p = idx.probe_len(k);
            assert!(p >= 1 && p as usize <= idx.capacity(), "key {k}: {p}");
        }
        // Force a chain: fill near capacity so some keys collide, then
        // verify probe_len agrees with what get() must traverse (a
        // present key's probe walk ends on its own bucket).
        for k in 0..64u32 {
            idx.set(k, k);
        }
        let before: Vec<_> = (0..128u32).map(|k| idx.get(k)).collect();
        let lens: Vec<_> = (0..128u32).map(|k| idx.probe_len(k)).collect();
        let after: Vec<_> = (0..128u32).map(|k| idx.get(k)).collect();
        assert_eq!(before, after, "probe_len mutated the table");
        assert!(lens.iter().all(|&p| p >= 1));
    }

    #[test]
    fn randomized_ops_match_std_hashmap() {
        // 20k mixed operations against HashMap<u32, u32> as the oracle,
        // with a key universe small enough to force collisions, chain
        // wraparound and backward-shift repairs constantly.
        let mut rng = Rng::new(0xD1CE);
        let mut idx = SlotIndex::new();
        let mut model: HashMap<u32, u32> = HashMap::new();
        for op in 0..20_000u32 {
            let key = rng.below(512) as u32;
            match rng.below(10) {
                0..=5 => {
                    idx.set(key, op);
                    model.insert(key, op);
                }
                6..=7 => {
                    idx.remove(key);
                    model.remove(&key);
                }
                8 => {
                    assert_eq!(idx.get(key), model.get(&key).copied(), "op {op} key {key}");
                }
                _ => {
                    idx.maybe_shrink();
                    assert_eq!(idx.len(), model.len());
                }
            }
        }
        assert_eq!(idx.len(), model.len());
        for (k, v) in &model {
            assert_eq!(idx.get(*k), Some(*v), "final sweep key {k}");
        }
        for k in 0..512u32 {
            if !model.contains_key(&k) {
                assert_eq!(idx.get(k), None, "ghost key {k}");
            }
        }
    }
}
