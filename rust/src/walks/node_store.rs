//! Lazy sparse storage for per-node estimator state — O(visited) engine
//! memory at any graph size.
//!
//! ## Why the dense column had to go
//!
//! PR 6 made the *topology* O(1) at 10⁸ nodes (implicit circulant
//! backend), but both engines still eagerly built `Vec<NodeState>` over
//! all n nodes (~10 GB at 10⁸) and the periodic prune sweep walked every
//! one of them. Return-time theory says that is almost all waste: on a
//! regular graph `E[R_i] ≈ n`, so with `Z0` walks over a `T`-step horizon
//! at most `Z0·T ≪ n` nodes are ever visited — every other node's state
//! is a default value it never reads.
//!
//! A [`NodeStore`] owns one contiguous node range `[base, base+len)`
//! (one store per shard in the stream-mode engine; one covering store in
//! the shared-stream engine) and materializes a node's [`NodeState`] —
//! and, in stream mode, its decision [`Rng`] stream — on **first visit**.
//!
//! ## Why laziness cannot move a bit (DESIGN.md §Lazy node store)
//!
//! Construction of a node's state is a pure function of
//! `(graph, node, params)`: `NodeState::new(mp_slots,
//! survival.resolve(&graph, node))` draws no randomness and reads
//! nothing mutable, and the per-node decision stream
//! `node_root.split(node)` is a pure derivation from the scenario's node
//! stream root ([`Rng::split`] never advances the parent). A state
//! materialized at first visit is therefore **value-identical** to one
//! built eagerly at t = 0 — and before its first visit a node's state is
//! observably inert: `observe`, control decisions and fork visibility
//! all happen at visit time, and `prune` of a fresh state is a no-op.
//!
//! Iteration order is the other half of the contract. Lazily-created
//! states live in a dense column in **first-visit order**, with a
//! [`SlotIndex`]-style Fibonacci-hashed map (`local node id → column
//! position`) used for point lookups only — never iterated. Sweeps
//! (prune, telemetry) walk the visited column, so their order is a pure
//! function of the trace, not of hash geometry; and since every
//! `NodeState` is self-contained (θ̂ float sums run over a single node's
//! own `ids ∥ last` columns), cross-node iteration order could not move
//! a θ̂ bit even if it were nondeterministic. The lazy-vs-dense oracle
//! (`prop_lazy_store_bit_identical_to_dense`) and both pinned golden
//! families lock this end to end.
//!
//! Purity has a placement payoff too (ISSUE 8): because `NodeStore::new`
//! is a pure function of `(mode, graph, range, params, stream root)`,
//! the stream-mode engine builds shard k's store *on* pool worker k
//! rather than on the coordinator thread. On NUMA hosts with first-touch
//! page policy that lands each shard's state columns in the memory the
//! worker that will grow and sweep them runs closest to — a pure
//! placement choice (DESIGN.md §Locality & routing) that cannot change
//! which store is built.

use std::sync::Arc;

use super::node_state::NodeState;
use super::slot_index::SlotIndex;
use crate::graph::Graph;
use crate::rng::Rng;
use crate::runtime::prefetch::prefetch_slice;
use crate::sim::engine::SurvivalSpec;

/// How engine node state is stored — the `--node-state` /
/// `DECAFORK_NODE_STATE` knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStateMode {
    /// Eagerly allocate every node's state at construction (the pre-lazy
    /// behavior). O(n) memory and prune sweeps; kept as the selectable
    /// A/B oracle the lazy path is bit-compared against.
    Dense,
    /// Allocate a node's state on first visit (the default): memory and
    /// housekeeping are O(visited), which is what lets `scale_100m` run
    /// on hardware that could never hold 10⁸ dense states.
    Lazy,
}

impl Default for NodeStateMode {
    fn default() -> Self {
        NodeStateMode::Lazy
    }
}

/// Sparse-capable store for the per-node state of one contiguous node
/// range `[base, base + len)`.
///
/// Both engines route every state access through here. In `Dense` mode
/// the store is exactly the old `Vec<NodeState>` slice (position =
/// `node − base`); in `Lazy` mode states sit in a first-visit-order
/// column behind a compact open-addressing map. The parallel `rngs`
/// column (stream-mode engines only) shares the same positions, so
/// [`state_rng_mut`](Self::state_rng_mut) hands out disjoint `&mut`
/// borrows of a node's state and its decision stream in one call.
#[derive(Debug)]
pub struct NodeStore {
    mode: NodeStateMode,
    /// First node id of the owned range.
    base: u32,
    /// Node count of the owned range.
    range_len: u32,
    /// MISSINGPERSON slot-table size handed to every constructed state
    /// (0 for control families that never read it).
    mp_slots: usize,
    survival: SurvivalSpec,
    graph: Arc<Graph>,
    /// Root of the per-node decision streams (`node_root.split(node)`),
    /// stream-mode engines only. `None` in the shared-stream engine,
    /// whose decisions draw from the single engine stream.
    node_root: Option<Rng>,
    /// The state column. Dense: position = local node id, all `len`
    /// entries present. Lazy: first-visit order, one entry per visited
    /// node.
    states: Vec<NodeState>,
    /// Per-node decision streams, parallel to `states` (empty when
    /// `node_root` is `None`).
    rngs: Vec<Rng>,
    /// Lazy mode: local node id of `states[pos]`, i.e. the visited list
    /// in first-visit order. Empty in dense mode (position *is* the
    /// local id there).
    visited: Vec<u32>,
    /// Lazy mode: local node id → column position. Point lookups only —
    /// iteration always goes through `states`/`visited`, so hash order
    /// can never leak into results.
    index: SlotIndex,
}

impl NodeStore {
    /// Build the store for `[base, base + len)`. In `Dense` mode every
    /// state (and stream) is constructed here, in ascending node order —
    /// byte-identical to the `Vec` columns this type replaced; in `Lazy`
    /// mode construction is deferred to first visit, which produces the
    /// same values (see the module docs' purity argument).
    pub fn new(
        mode: NodeStateMode,
        graph: Arc<Graph>,
        base: u32,
        len: u32,
        mp_slots: usize,
        survival: SurvivalSpec,
        node_root: Option<Rng>,
    ) -> Self {
        let mut store = NodeStore {
            mode,
            base,
            range_len: len,
            mp_slots,
            survival,
            graph,
            node_root,
            states: Vec::new(),
            rngs: Vec::new(),
            visited: Vec::new(),
            index: SlotIndex::new(),
        };
        if mode == NodeStateMode::Dense {
            store.states = (base..base + len)
                .map(|i| NodeState::new(mp_slots, store.survival.resolve(&store.graph, i as usize)))
                .collect();
            if let Some(root) = &store.node_root {
                store.rngs = (base..base + len).map(|i| root.split(i as u64)).collect();
            }
        }
        store
    }

    /// Storage mode.
    pub fn mode(&self) -> NodeStateMode {
        self.mode
    }

    /// First node id of the owned range.
    pub fn base(&self) -> u32 {
        self.base
    }

    /// Node count of the owned range.
    pub fn range_len(&self) -> u32 {
        self.range_len
    }

    /// Number of materialized states: the visited count in lazy mode,
    /// the full range length in dense mode.
    pub fn visited_count(&self) -> usize {
        self.states.len()
    }

    /// Column position for `node`, materializing state (and stream) on a
    /// lazy first visit.
    #[inline]
    fn pos_or_create(&mut self, node: u32) -> usize {
        debug_assert!(
            node >= self.base && node - self.base < self.range_len,
            "node {node} outside store range [{}, {})",
            self.base,
            self.base as u64 + self.range_len as u64
        );
        let local = node - self.base;
        match self.mode {
            NodeStateMode::Dense => local as usize,
            NodeStateMode::Lazy => {
                if let Some(pos) = self.index.get(local) {
                    return pos as usize;
                }
                // First visit: pure construction from (graph, node,
                // params) — no RNG consumed, so the value is identical
                // to the one eager construction would have produced.
                let pos = self.states.len();
                self.index.set(local, pos as u32);
                self.states
                    .push(NodeState::new(self.mp_slots, self.survival.resolve(&self.graph, node as usize)));
                if let Some(root) = &self.node_root {
                    self.rngs.push(root.split(node as u64));
                }
                self.visited.push(local);
                pos
            }
        }
    }

    /// Tier-A visit prefetch: hint the lines the *lookup* for `node`
    /// will probe — the `SlotIndex` home bucket in lazy mode, the state
    /// row directly in dense mode (where position = local id needs no
    /// lookup). The blocked control pipeline issues this one block
    /// ahead of [`prefetch_state`](Self::prefetch_state). Advisory
    /// only: never materializes, never changes results; silently skips
    /// out-of-range nodes (they belong to another shard's store).
    #[inline(always)]
    pub fn prefetch_lookup(&self, node: u32) {
        if node < self.base || node - self.base >= self.range_len {
            return;
        }
        let local = node - self.base;
        match self.mode {
            NodeStateMode::Dense => prefetch_slice(&self.states, local as usize),
            NodeStateMode::Lazy => self.index.prefetch(local),
        }
    }

    /// Tier-B visit prefetch: hint `node`'s state row (and decision
    /// stream, when the store owns streams) ahead of
    /// [`state_rng_mut`](Self::state_rng_mut). Needs the index probe
    /// that [`prefetch_lookup`](Self::prefetch_lookup) warmed; a lazy
    /// node not yet visited has no row to hint, which is fine — its
    /// first visit pays the materialization anyway. Advisory only.
    #[inline(always)]
    pub fn prefetch_state(&self, node: u32) {
        if node < self.base || node - self.base >= self.range_len {
            return;
        }
        let local = node - self.base;
        let pos = match self.mode {
            NodeStateMode::Dense => local as usize,
            NodeStateMode::Lazy => match self.index.get(local) {
                Some(p) => p as usize,
                None => return,
            },
        };
        prefetch_slice(&self.states, pos);
        prefetch_slice(&self.rngs, pos);
    }

    /// Mutable state of `node`, materializing it on a lazy first visit.
    #[inline]
    pub fn state_mut(&mut self, node: u32) -> &mut NodeState {
        let pos = self.pos_or_create(node);
        &mut self.states[pos]
    }

    /// Mutable state **and** decision stream of `node` as disjoint
    /// borrows (the control phase needs both at once). Panics if the
    /// store was built without a `node_root` — only stream-mode engines
    /// own per-node streams.
    #[inline]
    pub fn state_rng_mut(&mut self, node: u32) -> (&mut NodeState, &mut Rng) {
        let pos = self.pos_or_create(node);
        (&mut self.states[pos], &mut self.rngs[pos])
    }

    /// Read-only state of `node`, **without** materializing: `None` for
    /// a lazily-stored node that was never visited (dense mode always
    /// answers within range).
    pub fn get(&self, node: u32) -> Option<&NodeState> {
        if node < self.base || node - self.base >= self.range_len {
            return None;
        }
        let local = node - self.base;
        match self.mode {
            NodeStateMode::Dense => self.states.get(local as usize),
            NodeStateMode::Lazy => self.index.get(local).map(|pos| &self.states[pos as usize]),
        }
    }

    /// Whether `node` falls in this store's range.
    pub fn contains(&self, node: u32) -> bool {
        node >= self.base && (node - self.base) < self.range_len
    }

    /// Buckets a lookup of `node` scans in the lazy index (1 =
    /// home-bucket hit; dense mode and out-of-range answer 0 — there is
    /// no probe chain to measure). **Read-only telemetry** for the
    /// observability layer's probe-length counters: re-traces the walk
    /// [`SlotIndex::get`] performs without materializing or mutating
    /// anything, so it cannot move a bit of any trace.
    #[inline]
    pub fn probe_len(&self, node: u32) -> u32 {
        if !self.contains(node) || self.mode == NodeStateMode::Dense {
            return 0;
        }
        self.index.probe_len(node - self.base)
    }

    /// Materialized states as `(node, &state)` pairs: ascending node
    /// order in dense mode, first-visit order in lazy mode. Both orders
    /// are pure functions of the scenario — never of hash geometry.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &NodeState)> + '_ {
        self.states.iter().enumerate().map(move |(pos, s)| {
            let local = match self.mode {
                NodeStateMode::Dense => pos as u32,
                NodeStateMode::Lazy => self.visited[pos],
            };
            (self.base + local, s)
        })
    }

    /// Drop dead-weight last-seen entries from every **materialized**
    /// state: O(visited) in lazy mode instead of the dense sweep's
    /// O(range). Never-visited nodes hold no entries, so skipping them
    /// is exact, not approximate.
    pub fn prune(&mut self, t: u64) {
        for s in &mut self.states {
            s.prune(t);
        }
    }

    /// Total resident bytes of this store: struct + state column (stack
    /// parts and heap tails), decision streams, visited list and lookup
    /// map. The measurement `benches/perf_state.rs` builds its O(visited)
    /// acceptance bar on.
    pub fn memory_bytes(&self) -> usize {
        let per_state: usize = self
            .states
            .iter()
            .map(|s| std::mem::size_of::<NodeState>() + s.heap_bytes())
            .sum();
        std::mem::size_of::<Self>()
            + per_state
            + self.rngs.len() * std::mem::size_of::<Rng>()
            + self.visited.len() * std::mem::size_of::<u32>()
            + self.index.capacity() * 8
    }
}

/// Visited-aware telemetry view over one or more [`NodeStore`]s — what
/// both engines' `states()` accessor now returns instead of a bare
/// `&[NodeState]` slice (a dense slice cannot exist in lazy mode; most
/// nodes have no state).
#[derive(Debug, Clone, Copy)]
pub struct StatesView<'a> {
    stores: &'a [NodeStore],
}

impl<'a> StatesView<'a> {
    /// View over a sharded engine's per-shard stores (range order).
    pub fn new(stores: &'a [NodeStore]) -> Self {
        StatesView { stores }
    }

    /// View over a single covering store (the shared-stream engine).
    pub fn single(store: &'a NodeStore) -> Self {
        StatesView { stores: std::slice::from_ref(store) }
    }

    /// Number of materialized states across all stores (the full node
    /// count in dense mode).
    pub fn visited_count(&self) -> usize {
        self.stores.iter().map(NodeStore::visited_count).sum()
    }

    /// All materialized states as `(node, &state)` pairs: stores in
    /// node-range order, within a store dense/first-visit order (see
    /// [`NodeStore::iter`]).
    pub fn iter(&self) -> impl Iterator<Item = (u32, &'a NodeState)> + '_ {
        self.stores.iter().flat_map(NodeStore::iter)
    }

    /// Point lookup without materializing (`None` = never visited, or
    /// out of range).
    pub fn get(&self, node: u32) -> Option<&'a NodeState> {
        self.stores.iter().find(|s| s.contains(node)).and_then(|s| s.get(node))
    }

    /// Total engine-state resident bytes across stores.
    pub fn memory_bytes(&self) -> usize {
        self.stores.iter().map(NodeStore::memory_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::walks::WalkId;

    fn small_graph() -> Arc<Graph> {
        Arc::new(generators::random_regular(40, 4, &mut Rng::new(3)).unwrap())
    }

    fn store(mode: NodeStateMode, graph: Arc<Graph>, with_rngs: bool) -> NodeStore {
        let n = graph.n() as u32;
        let root = with_rngs.then(|| Rng::new(0xA0B1).split(77));
        NodeStore::new(mode, graph, 0, n, 4, SurvivalSpec::Empirical, root)
    }

    #[test]
    fn dense_matches_the_eager_columns_it_replaced() {
        let g = small_graph();
        let s = store(NodeStateMode::Dense, g.clone(), true);
        assert_eq!(s.visited_count(), g.n());
        // Ascending node order, every node present, untouched defaults.
        for (expect, (node, st)) in s.iter().enumerate() {
            assert_eq!(node, expect as u32);
            assert_eq!(st.known_walks(), 0);
            assert_eq!(st.slot_last_seen.len(), 4);
        }
    }

    #[test]
    fn lazy_materializes_on_first_visit_in_visit_order() {
        let g = small_graph();
        let mut s = store(NodeStateMode::Lazy, g, false);
        assert_eq!(s.visited_count(), 0);
        assert!(s.get(7).is_none(), "get must not materialize");
        for (t, node) in [(1u64, 9u32), (2, 3), (3, 9), (4, 31)] {
            s.state_mut(node).observe(t, WalkId(0), 0);
        }
        assert_eq!(s.visited_count(), 3);
        let order: Vec<u32> = s.iter().map(|(n, _)| n).collect();
        assert_eq!(order, vec![9, 3, 31], "iteration must be first-visit order");
        assert_eq!(s.get(9).unwrap().last_seen_of(WalkId(0)), Some(3));
        assert!(s.get(8).is_none());
    }

    #[test]
    fn lazy_and_dense_stores_agree_under_a_random_schedule() {
        // The store-level oracle: drive both modes through an identical
        // observe/theta/prune schedule and demand bit-equal θ̂ sums and
        // identical bookkeeping — including the per-node RNG streams,
        // which lazy mode derives at first visit instead of eagerly.
        let g = small_graph();
        let mut rng = Rng::new(0xFEED);
        let mut dense = store(NodeStateMode::Dense, g.clone(), true);
        let mut lazy = store(NodeStateMode::Lazy, g.clone(), true);
        let mut t = 0u64;
        for step in 0..600u64 {
            t += 1 + rng.below(3) as u64;
            let node = rng.below(g.n()) as u32;
            let walk = WalkId(rng.below(12) as u64);
            match rng.below(10) {
                0 => {
                    dense.prune(t);
                    lazy.prune(t);
                }
                1..=2 => {
                    let (sd, rd) = dense.state_rng_mut(node);
                    let (sl, rl) = lazy.state_rng_mut(node);
                    assert_eq!(
                        sd.theta(t, walk).to_bits(),
                        sl.theta(t, walk).to_bits(),
                        "step {step}: θ̂ diverged at node {node}"
                    );
                    assert_eq!(rd.next_u64(), rl.next_u64(), "step {step}: stream diverged");
                }
                _ => {
                    assert_eq!(
                        dense.state_mut(node).observe(t, walk, (walk.0 % 4) as u16),
                        lazy.state_mut(node).observe(t, walk, (walk.0 % 4) as u16),
                        "step {step}: return sample diverged at node {node}"
                    );
                }
            }
        }
        // Every visited node's state agrees field-for-field on the
        // observable surface.
        for (node, sl) in lazy.iter() {
            let sd = dense.get(node).unwrap();
            assert_eq!(sd.known_walks(), sl.known_walks(), "node {node}");
            assert_eq!(sd.slot_last_seen, sl.slot_last_seen, "node {node}");
            assert_eq!(sd.last_control_step, sl.last_control_step, "node {node}");
        }
    }

    #[test]
    fn probe_len_is_zero_for_dense_and_unvisited_tables() {
        let g = small_graph();
        let dense = store(NodeStateMode::Dense, g.clone(), false);
        assert_eq!(dense.probe_len(5), 0, "dense mode has no probe chain");
        let mut lazy = store(NodeStateMode::Lazy, g, false);
        assert_eq!(lazy.probe_len(5), 0, "empty index: nothing to probe");
        lazy.state_mut(5).observe(1, WalkId(0), 0);
        assert!(lazy.probe_len(5) >= 1);
        assert_eq!(lazy.visited_count(), 1, "probe_len must not materialize");
        lazy.probe_len(7);
        assert_eq!(lazy.visited_count(), 1);
        assert_eq!(lazy.probe_len(10_000), 0, "out of range");
    }

    #[test]
    fn lazy_memory_tracks_visits_not_nodes() {
        // A million-node implicit graph: the dense store would pay ~n ×
        // size_of::<NodeState>() before the first step; the lazy store
        // must stay within a few KB after a handful of visits.
        let g = Arc::new(generators::implicit_ring(1_000_000, 8).unwrap());
        let mut s = NodeStore::new(
            NodeStateMode::Lazy,
            g,
            0,
            1_000_000,
            0,
            SurvivalSpec::AnalyticGeometric,
            Some(Rng::new(5)),
        );
        let empty = s.memory_bytes();
        for k in 0..10u32 {
            s.state_mut(k * 99_991).observe(k as u64 + 1, WalkId(k as u64), 0);
        }
        assert_eq!(s.visited_count(), 10);
        let ten = s.memory_bytes();
        let dense_floor = 1_000_000 * std::mem::size_of::<NodeState>();
        assert!(
            ten < empty + 10 * 1024,
            "10 visits cost {} B over the empty store — not O(visited)",
            ten - empty
        );
        assert!(ten * 100 < dense_floor, "lazy store ({ten} B) is not ≪ dense ({dense_floor} B)");
    }

    #[test]
    fn sharded_ranges_partition_like_the_dense_columns() {
        // Per-shard stores over contiguous ranges must jointly equal the
        // single covering store: same states, same streams, routed by
        // base offset.
        let g = small_graph();
        let root = Rng::new(9).split(13);
        let whole = NodeStore::new(
            NodeStateMode::Dense,
            g.clone(),
            0,
            40,
            2,
            SurvivalSpec::Empirical,
            Some(root.clone()),
        );
        let nps = 14u32; // ceil(40/3)
        for k in 0..3u32 {
            let base = k * nps;
            let len = nps.min(40 - base);
            let mut part = NodeStore::new(
                NodeStateMode::Lazy,
                g.clone(),
                base,
                len,
                2,
                SurvivalSpec::Empirical,
                Some(root.clone()),
            );
            for node in base..base + len {
                let (st, rng) = part.state_rng_mut(node);
                assert_eq!(st.slot_last_seen, whole.get(node).unwrap().slot_last_seen);
                // Streams are derived from the *global* node id, so the
                // partition cannot change any decision draw.
                let mut expect = root.split(node as u64);
                assert_eq!(rng.next_u64(), expect.next_u64(), "node {node}");
            }
            assert_eq!(part.visited_count() as u32, len);
        }
    }

    #[test]
    fn view_spans_stores_and_counts_visits() {
        let g = small_graph();
        let mut a = NodeStore::new(
            NodeStateMode::Lazy,
            g.clone(),
            0,
            20,
            0,
            SurvivalSpec::Empirical,
            None,
        );
        let mut b =
            NodeStore::new(NodeStateMode::Lazy, g, 20, 20, 0, SurvivalSpec::Empirical, None);
        a.state_mut(5).observe(1, WalkId(0), 0);
        b.state_mut(33).observe(2, WalkId(1), 0);
        b.state_mut(21).observe(3, WalkId(0), 0);
        let stores = [a, b];
        let v = StatesView::new(&stores);
        assert_eq!(v.visited_count(), 3);
        let nodes: Vec<u32> = v.iter().map(|(n, _)| n).collect();
        assert_eq!(nodes, vec![5, 33, 21], "store order, then first-visit order");
        assert!(v.get(5).is_some() && v.get(33).is_some());
        assert!(v.get(6).is_none() && v.get(99).is_none());
        assert!(v.memory_bytes() > 0);
    }
}
