//! Per-node bookkeeping for the DECAFORK estimator.
//!
//! Node `i` tracks, for every walk `k` it has ever seen, the last visit
//! time `L_{i,k}(t)`; revisits yield samples `t − L_{i,k}(t)` of the
//! return-time variable `R_i` (pooled across walks — they are i.i.d.).
//! The survival function `S(·)` used in the estimator can come from the
//! empirical distribution (the algorithm's default) or from an analytic
//! fit (footnote 5: speeds up initialization when the family is known).

use super::WalkId;
use crate::stats::fit::{exp_survival, geom_survival};
use crate::stats::EmpiricalCdf;

/// Which survival function backs `S(t − L)` in the estimator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SurvivalModel {
    /// Empirical CDF of observed return times (paper default).
    Empirical,
    /// Analytic geometric tail `S(x) = (1−q)^x` (random regular graphs,
    /// Tishby et al. 2021; q ≈ π_i = deg(i)/2|E|).
    Geometric { q: f64 },
    /// Analytic exponential tail `S(x) = exp(−λ x)` (the continuous
    /// relaxation used for the paper's theory, Assumption 1).
    Exponential { lambda: f64 },
}

/// State a single node keeps to run MISSINGPERSON / DECAFORK / DECAFORK+.
#[derive(Debug, Clone)]
pub struct NodeState {
    /// `L_{i,k}`: last time each known walk was seen here. Stored as a
    /// flat vector in **first-seen order** — iteration order is
    /// deterministic, so the floating-point sum in
    /// [`theta`](Self::theta) is reproducible across runs (HashMap order
    /// randomization flipped near-threshold decisions; see DESIGN.md
    /// §Perf). Lookups go through `slot_pos`, not a linear scan: under
    /// sustained churn this vector accumulates one entry per walk that
    /// ever visited (dead walks linger until [`prune`](Self::prune)), so
    /// a scan would make every *visit* O(history) — the node-table twin
    /// of the seed engine's O(history) step loop.
    last_seen: Vec<(WalkId, u64)>,
    /// `WalkId::index()` → position of that slot's **latest** walk in
    /// `last_seen` (`u32::MAX` = none). Entries for earlier generations
    /// of a reused slot stay in `last_seen` (they still decay inside θ̂,
    /// exactly like the seed's unique-id entries) but become unreachable
    /// here — dead walks never visit again, so nothing ever looks them
    /// up. Bounded by the peak *concurrent* population for the arena
    /// engine's generational ids; sequential allocators (reference
    /// engine, actor runtime) grow it with ids-ever-minted instead —
    /// the seed's own O(history) footprint, acceptable for those
    /// paths, and ids are assumed < 2³² (`WalkArena::spawn` asserts
    /// the same bound on slot space).
    slot_pos: Vec<u32>,
    /// Pooled empirical return-time distribution `R̂_i`.
    pub return_cdf: EmpiricalCdf,
    /// Survival model used by `theta`.
    pub model: SurvivalModel,
    /// Per-slot last-seen table for MISSINGPERSON (indexed by original
    /// walk identity `ℓ ∈ [Z0]`); initialized to 0 per the algorithm.
    pub slot_last_seen: Vec<u64>,
    /// Step at which this node last executed a control decision; the paper
    /// (footnote 6) has a node process one visiting walk per time step.
    pub last_control_step: Option<u64>,
}

impl NodeState {
    /// Fresh state with `z0` MISSINGPERSON slots.
    pub fn new(z0: usize, model: SurvivalModel) -> Self {
        NodeState {
            last_seen: Vec::new(),
            slot_pos: Vec::new(),
            return_cdf: EmpiricalCdf::new(),
            model,
            slot_last_seen: vec![0; z0],
            last_control_step: None,
        }
    }

    /// Record a visit of walk `id` (with MISSINGPERSON slot `slot`) at
    /// time `t`. Returns the return-time sample `t − L_{i,k}` if this is a
    /// revisit. Updates both tables. O(1): the `slot_pos` index replaces
    /// the seed's linear scan; behaviour (entries, order, samples) is
    /// identical — a reused slot index with a different generation misses
    /// the stored id and is treated as a brand-new walk, exactly as a
    /// fresh unique id was.
    pub fn observe(&mut self, t: u64, id: WalkId, slot: u16) -> Option<u32> {
        let idx = id.index() as usize;
        if idx >= self.slot_pos.len() {
            self.slot_pos.resize(idx + 1, u32::MAX);
        }
        let pos = self.slot_pos[idx];
        let sample = if pos != u32::MAX && self.last_seen[pos as usize].0 == id {
            let last = &mut self.last_seen[pos as usize].1;
            let dt = (t - *last) as u32;
            *last = t;
            if dt > 0 {
                self.return_cdf.add(dt);
                Some(dt)
            } else {
                None
            }
        } else {
            self.slot_pos[idx] = self.last_seen.len() as u32;
            self.last_seen.push((id, t));
            None
        };
        if let Some(s) = self.slot_last_seen.get_mut(slot as usize) {
            *s = t;
        }
        sample
    }

    /// Number of distinct walks this node has ever seen (`|L_i(t)|`).
    pub fn known_walks(&self) -> usize {
        self.last_seen.len()
    }

    /// Whether walk `id` has visited this node before.
    pub fn knows(&self, id: WalkId) -> bool {
        self.last_seen.iter().any(|(k, _)| *k == id)
    }

    /// Last-seen time for a walk, if known.
    pub fn last_seen_of(&self, id: WalkId) -> Option<u64> {
        self.last_seen.iter().find(|(k, _)| *k == id).map(|(_, t)| *t)
    }

    /// Survival `S(dt)` under the configured model.
    #[inline]
    pub fn survival(&mut self, dt: u32) -> f64 {
        match self.model {
            SurvivalModel::Empirical => self.return_cdf.survival(dt),
            SurvivalModel::Geometric { q } => geom_survival(q, dt),
            SurvivalModel::Exponential { lambda } => exp_survival(lambda, dt as f64),
        }
    }

    /// The DECAFORK estimator, Eq. (1):
    /// `θ̂_i(t) = ½ + Σ_{ℓ ∈ L_i(t) \ {k}} S(t − L_{i,ℓ}(t))`,
    /// where `k` is the currently visiting walk (known to be alive, hence
    /// the deterministic ½ from Prop. 1).
    pub fn theta(&mut self, t: u64, visiting: WalkId) -> f64 {
        let mut acc = 0.5;
        // Iteration is in first-seen order (deterministic), so the
        // floating-point sum — and therefore every threshold comparison —
        // is reproducible across runs and thread counts.
        let model = self.model;
        match model {
            SurvivalModel::Empirical => {
                // Disjoint-field split borrow: mutate the CDF cache while
                // iterating the last-seen table.
                let cdf = &mut self.return_cdf;
                for &(id, last) in self.last_seen.iter() {
                    if id == visiting {
                        continue;
                    }
                    acc += cdf.survival((t - last) as u32);
                }
            }
            SurvivalModel::Geometric { q } => {
                // exp(dt·ln(1−q)) — one ln hoisted out of the loop beats
                // per-walk powi (§Perf iteration 4).
                let log1mq = (-q).ln_1p();
                for &(id, last) in self.last_seen.iter() {
                    if id != visiting {
                        acc += ((t - last) as f64 * log1mq).exp();
                    }
                }
            }
            SurvivalModel::Exponential { lambda } => {
                for &(id, last) in self.last_seen.iter() {
                    if id != visiting {
                        acc += exp_survival(lambda, (t - last) as f64);
                    }
                }
            }
        }
        acc
    }

    /// Drop walks whose survival contribution is *exactly* zero and whose
    /// absence can no longer change future estimates (dt already beyond
    /// twice the largest observed return time). This is a pure
    /// memory/speed optimization — contributions removed are identically 0
    /// under the empirical model and < 1e-12 under analytic models.
    pub fn prune(&mut self, t: u64) {
        let max_obs = self.return_cdf.max_observed() as u64;
        let horizon = match self.model {
            SurvivalModel::Empirical => 2 * max_obs.max(1),
            SurvivalModel::Geometric { q } => {
                if q <= 0.0 {
                    return;
                }
                (28.0 / -(1.0 - q).ln()).ceil() as u64 // S < 1e-12
            }
            SurvivalModel::Exponential { lambda } => (28.0 / lambda).ceil() as u64,
        };
        // Stable in-place sweep (the seed's `retain`, plus index fix-up
        // in the same O(|last_seen|) pass). `slot_pos` entries are only
        // touched when they point at the entry being moved or dropped —
        // an entry superseded by a later generation of its slot leaves
        // the newer walk's index pointer alone.
        let mut w = 0usize;
        for r in 0..self.last_seen.len() {
            let (id, last) = self.last_seen[r];
            let sp = &mut self.slot_pos[id.index() as usize];
            if t.saturating_sub(last) <= horizon {
                if *sp == r as u32 {
                    *sp = w as u32;
                }
                self.last_seen[w] = (id, last);
                w += 1;
            } else if *sp == r as u32 {
                *sp = u32::MAX;
            }
        }
        self.last_seen.truncate(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u64) -> WalkId {
        WalkId(n)
    }

    #[test]
    fn observe_records_return_samples() {
        let mut s = NodeState::new(4, SurvivalModel::Empirical);
        assert_eq!(s.observe(10, id(1), 0), None); // first sighting
        assert_eq!(s.observe(25, id(1), 0), Some(15)); // revisit: sample 15
        assert_eq!(s.return_cdf.len(), 1);
        assert_eq!(s.last_seen_of(id(1)), Some(25));
        assert_eq!(s.slot_last_seen[0], 25);
    }

    #[test]
    fn same_step_revisit_yields_no_sample() {
        let mut s = NodeState::new(1, SurvivalModel::Empirical);
        s.observe(5, id(1), 0);
        assert_eq!(s.observe(5, id(1), 0), None);
        assert_eq!(s.return_cdf.len(), 0);
    }

    #[test]
    fn reused_slot_index_is_a_new_walk() {
        // Arena slot reuse: a later generation of the same slot index
        // must be treated as a brand-new walk (no return-time sample
        // against the dead predecessor), while the predecessor's entry
        // keeps decaying inside theta until pruned — the same behaviour
        // the seed had with globally unique ids.
        let mut s = NodeState::new(2, SurvivalModel::Geometric { q: 0.1 });
        let old = WalkId::compose(3, 0);
        let new = WalkId::compose(3, 1);
        s.observe(10, old, 0);
        assert_eq!(s.observe(50, new, 1), None, "new generation must not look like a revisit");
        assert_eq!(s.known_walks(), 2);
        assert_eq!(s.last_seen_of(old), Some(10));
        assert_eq!(s.last_seen_of(new), Some(50));
        // Revisit of the live generation hits its own entry.
        assert_eq!(s.observe(60, new, 1), Some(10));
        assert_eq!(s.last_seen_of(old), Some(10), "dead predecessor untouched");
        // After pruning the stale predecessor (geometric horizon
        // 28/−ln(0.9) ≈ 266 < its staleness 290), the live walk's
        // index entry survives the rebuild and still resolves.
        s.prune(300);
        assert_eq!(s.known_walks(), 1);
        assert_eq!(s.observe(310, new, 1), Some(250));
    }

    #[test]
    fn theta_base_is_half_for_lone_walk() {
        let mut s = NodeState::new(1, SurvivalModel::Empirical);
        s.observe(3, id(1), 0);
        assert!((s.theta(10, id(1)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn theta_counts_other_walks_with_empty_cdf_as_alive() {
        let mut s = NodeState::new(3, SurvivalModel::Empirical);
        s.observe(1, id(1), 0);
        s.observe(2, id(2), 1);
        s.observe(3, id(3), 2);
        // Empty return distribution → survival = 1 for all others.
        assert!((s.theta(4, id(1)) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn theta_decays_for_stale_walks_geometric() {
        let mut s = NodeState::new(2, SurvivalModel::Geometric { q: 0.1 });
        s.observe(0, id(1), 0);
        s.observe(0, id(2), 1);
        let early = s.theta(1, id(1));
        let late = s.theta(100, id(1));
        assert!(early > late);
        assert!((late - 0.5) < 1e-4, "stale contribution should vanish: {late}");
    }

    #[test]
    fn theta_bounds() {
        let mut s = NodeState::new(4, SurvivalModel::Empirical);
        for k in 0..8u64 {
            s.observe(k, id(k), (k % 4) as u16);
        }
        for v in [5u32, 20, 100] {
            s.return_cdf.add(v);
        }
        let th = s.theta(50, id(0));
        assert!(th >= 0.5 - 1e-12);
        assert!(th <= 0.5 + (s.known_walks() - 1) as f64 + 1e-12);
    }

    #[test]
    fn exponential_model_survival() {
        let mut s = NodeState::new(1, SurvivalModel::Exponential { lambda: 0.05 });
        assert!((s.survival(0) - 1.0).abs() < 1e-12);
        assert!((s.survival(20) - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn prune_drops_only_dead_weight() {
        let mut s = NodeState::new(2, SurvivalModel::Empirical);
        s.observe(0, id(1), 0);
        s.observe(90, id(2), 1);
        // Observed return times max out at 10.
        for v in [5u32, 10] {
            s.return_cdf.add(v);
        }
        let before = s.theta(100, id(2));
        s.prune(100);
        let after = s.theta(100, id(2));
        assert_eq!(s.known_walks(), 1); // id(1) dropped (dt=100 > 2*10)
        assert!((before - after).abs() < 1e-12, "prune changed theta");
    }

    #[test]
    fn theta_matches_irwin_hall_mean_under_stationarity() {
        // Prop. 1 sanity: K walks whose elapsed times are drawn from R_i
        // itself give E[θ̂] ≈ K/2 (within Monte-Carlo noise).
        let mut rng = crate::rng::Rng::new(42);
        let q = 0.05;
        let k = 10u64;
        let trials = 3000;
        let mut total = 0.0;
        for trial in 0..trials {
            let mut s = NodeState::new(k as usize, SurvivalModel::Geometric { q });
            let t = 1_000_000u64;
            for w in 0..k {
                // Elapsed time since last visit ~ R_i (probability integral
                // transform argument from Prop. 1).
                let dt = rng.geometric(q);
                s.observe(t - dt, id(w + trial * k), (w % k) as u16);
            }
            total += s.theta(t, id(trial * k)); // first walk is "visiting"
        }
        let mean = total / trials as f64;
        // E[θ̂] = ½ + (K−1)·(1−q)/(2−q) ≈ ½ + 9·0.487 = 4.886
        let expect = 0.5 + (k - 1) as f64 * crate::stats::fit::geom_self_survival_mean(q);
        assert!((mean - expect).abs() < 0.15, "mean {mean} expect {expect}");
    }
}
