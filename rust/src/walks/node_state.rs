//! Per-node bookkeeping for the DECAFORK estimator.
//!
//! Node `i` tracks, for every walk `k` it has ever seen, the last visit
//! time `L_{i,k}(t)`; revisits yield samples `t − L_{i,k}(t)` of the
//! return-time variable `R_i` (pooled across walks — they are i.i.d.).
//! The survival function `S(·)` used in the estimator can come from the
//! empirical distribution (the algorithm's default) or from an analytic
//! fit (footnote 5: speeds up initialization when the family is known).
//!
//! ## θ̂ evaluation (DESIGN.md §Survival cache)
//!
//! Eq. (1) is a sum of one survival value per known walk, evaluated on
//! every control decision — the hot path of the whole simulator once
//! walk counts grow. Two layers keep it fast without moving a single
//! bit of the result:
//!
//! * the last-seen table is stored **struct-of-arrays** (`ids ∥ last`),
//!   so the θ̂ loop is a dense gather-and-sum over two contiguous
//!   columns rather than a strided walk over `(WalkId, u64)` pairs;
//! * survival values are memoised in a per-node [`SurvivalTable`]
//!   (`dt → S(dt)`), turning the per-term `exp` / CDF division into an
//!   indexed load. The memo stores exactly the `f64` the direct code
//!   path produces and is invalidated precisely when the empirical CDF's
//!   observable values change, so the float sum — in first-seen order,
//!   always — is bit-identical to the uncached evaluation.
//!
//! The frozen reference engine opts out via [`NodeState::new_uncached`]
//! (seed semantics had no memo); the golden-trace lock then proves the
//! cached and direct paths equivalent end-to-end, and
//! `benches/perf_control.rs` measures what the cache buys.

use super::slot_index::SlotIndex;
use super::WalkId;
use crate::stats::fit::{exp_survival, geom_survival};
use crate::stats::{EmpiricalCdf, SurvivalTable};

/// Which survival function backs `S(t − L)` in the estimator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SurvivalModel {
    /// Empirical CDF of observed return times (paper default).
    Empirical,
    /// Analytic geometric tail `S(x) = (1−q)^x` (random regular graphs,
    /// Tishby et al. 2021; q ≈ π_i = deg(i)/2|E|).
    Geometric { q: f64 },
    /// Analytic exponential tail `S(x) = exp(−λ x)` (the continuous
    /// relaxation used for the paper's theory, Assumption 1).
    Exponential { lambda: f64 },
}

/// State a single node keeps to run MISSINGPERSON / DECAFORK / DECAFORK+.
#[derive(Debug, Clone)]
pub struct NodeState {
    /// `L_{i,k}` ids column: every known walk, in **first-seen order** —
    /// iteration order is deterministic, so the floating-point sum in
    /// [`theta`](Self::theta) is reproducible across runs (HashMap order
    /// randomization flipped near-threshold decisions; see DESIGN.md
    /// §Perf). Split from `last` (struct-of-arrays) so the θ̂ loop scans
    /// two dense homogeneous columns. Lookups go through `slot_pos`, not
    /// a linear scan: under sustained churn these columns accumulate one
    /// entry per walk that ever visited (dead walks linger until
    /// [`prune`](Self::prune)), so a scan would make every *visit*
    /// O(history) — the node-table twin of the seed engine's O(history)
    /// step loop.
    ids: Vec<WalkId>,
    /// `L_{i,k}` last-visit-time column, parallel to `ids`.
    last: Vec<u64>,
    /// `WalkId::index()` → position of that slot's **latest** walk in
    /// `ids`/`last`. Entries for earlier generations of a reused slot
    /// stay in the columns (they still decay inside θ̂, exactly like the
    /// seed's unique-id entries) but become unreachable here — dead
    /// walks never visit again. All point lookups
    /// ([`observe`](Self::observe), [`knows`](Self::knows),
    /// [`last_seen_of`](Self::last_seen_of)) resolve through this index,
    /// so a superseded generation reads as *unknown* even while its
    /// entry keeps decaying.
    ///
    /// Storage is a compact open-addressing [`SlotIndex`] (lookup-only;
    /// never iterated, so θ̂ order and bits cannot depend on it). The
    /// direct `Vec<u32>` it replaced was sized by the largest slot index
    /// the node ever observed — the global peak walk population, which
    /// at `scale_1m` priced a dense population at tens of GB of index
    /// and capped Z0 at 1024. This table is sized by the node's own
    /// entry count `|L_i(t)|` instead, and
    /// [`prune`](Self::prune) gives bucket memory back. Semantics are
    /// locked against the old direct array by
    /// `prop_compact_index_matches_direct_array`.
    index: SlotIndex,
    /// Memoised `dt → S(dt)` backing cached θ̂ evaluation.
    table: SurvivalTable,
    /// Whether [`theta`](Self::theta) uses the memo (hot default) or the
    /// direct per-term computation (frozen reference engine).
    cached: bool,
    /// Pooled empirical return-time distribution `R̂_i`.
    pub return_cdf: EmpiricalCdf,
    /// Survival model used by `theta`.
    pub model: SurvivalModel,
    /// Per-slot last-seen table for MISSINGPERSON (indexed by original
    /// walk identity `ℓ ∈ [Z0]`); initialized to 0 per the algorithm.
    /// Sized by the constructor's `z0` argument — engines running a
    /// control family that never reads it pass 0 and the table stays
    /// empty ([`observe`](Self::observe) tolerates that); at the
    /// million-node scale presets an unconditional `Z0`-sized column per
    /// node would be gigabytes of zeros.
    pub slot_last_seen: Vec<u64>,
    /// Step at which this node last executed a control decision; the paper
    /// (footnote 6) has a node process one visiting walk per time step.
    pub last_control_step: Option<u64>,
}

impl NodeState {
    /// Fresh state with `z0` MISSINGPERSON slots and survival-cached θ̂.
    pub fn new(z0: usize, model: SurvivalModel) -> Self {
        Self::with_cache(z0, model, true)
    }

    /// Fresh state evaluating θ̂ **directly** (no [`SurvivalTable`]) —
    /// the seed engine's exact arithmetic path. Used by the frozen
    /// [`ReferenceEngine`](crate::sim::reference::ReferenceEngine) so
    /// golden traces lock cached-vs-direct equivalence, and by
    /// `perf_control` as the before side of the measurement.
    pub fn new_uncached(z0: usize, model: SurvivalModel) -> Self {
        Self::with_cache(z0, model, false)
    }

    fn with_cache(z0: usize, model: SurvivalModel, cached: bool) -> Self {
        NodeState {
            ids: Vec::new(),
            last: Vec::new(),
            index: SlotIndex::new(),
            table: SurvivalTable::new(),
            cached,
            return_cdf: EmpiricalCdf::new(),
            model,
            slot_last_seen: vec![0; z0],
            last_control_step: None,
        }
    }

    /// Whether θ̂ evaluation goes through the survival memo.
    pub fn is_cached(&self) -> bool {
        self.cached
    }

    /// The survival memo (telemetry/tests).
    pub fn survival_table(&self) -> &SurvivalTable {
        &self.table
    }

    /// Record a visit of walk `id` (with MISSINGPERSON slot `slot`) at
    /// time `t`. Returns the return-time sample `t − L_{i,k}` if this is a
    /// revisit. Updates both tables. O(1) expected: the compact index
    /// replaces the seed's linear scan; behaviour (entries, order,
    /// samples) is identical — a reused slot index with a different
    /// generation misses the stored id and is treated as a brand-new
    /// walk, exactly as a fresh unique id was.
    pub fn observe(&mut self, t: u64, id: WalkId, slot: u16) -> Option<u32> {
        let idx = id.index();
        let hit = match self.index.get(idx) {
            Some(pos) if self.ids[pos as usize] == id => Some(pos as usize),
            _ => None,
        };
        let sample = if let Some(pos) = hit {
            let last = &mut self.last[pos];
            let dt = (t - *last) as u32;
            *last = t;
            if dt > 0 {
                self.return_cdf.add(dt);
                Some(dt)
            } else {
                None
            }
        } else {
            // New walk, or a new generation superseding a dead one's
            // pointer (its column entry stays and keeps decaying in θ̂).
            self.index.set(idx, self.ids.len() as u32);
            self.ids.push(id);
            self.last.push(t);
            None
        };
        if let Some(s) = self.slot_last_seen.get_mut(slot as usize) {
            *s = t;
        }
        sample
    }

    /// Number of distinct walks this node has ever seen (`|L_i(t)|`).
    pub fn known_walks(&self) -> usize {
        self.ids.len()
    }

    /// Position of `id` in the columns, resolved through the compact
    /// index: O(1) expected, and superseded generations of a reused slot
    /// resolve to `None` (they are unreachable to every walk that still
    /// exists — the same semantics [`observe`](Self::observe) applies).
    #[inline]
    fn pos_of(&self, id: WalkId) -> Option<usize> {
        let pos = self.index.get(id.index())?;
        if self.ids[pos as usize] == id {
            Some(pos as usize)
        } else {
            None
        }
    }

    /// Whether walk `id` has visited this node before. O(1) expected via
    /// the compact index (previously a linear scan over the history).
    pub fn knows(&self, id: WalkId) -> bool {
        self.pos_of(id).is_some()
    }

    /// Last-seen time for a walk, if known. O(1) expected.
    pub fn last_seen_of(&self, id: WalkId) -> Option<u64> {
        self.pos_of(id).map(|p| self.last[p])
    }

    /// Bucket count of the compact lookup index — per-node index memory
    /// in 8 B units. Tracks `|L_i(t)|`, not the global walk-slot space
    /// (the `scale_1m` memory guarantee; see the memory unit tests).
    pub fn index_footprint(&self) -> usize {
        self.index.capacity()
    }

    /// Resident heap bytes behind this state: the `ids ∥ last` columns,
    /// the compact lookup index, the survival memo, the pooled
    /// return-time histogram and the MISSINGPERSON slot table. Combined
    /// with `size_of::<NodeState>()` this is the per-node term of the
    /// engine-state accounting `NodeStore::memory_bytes` reports and
    /// `benches/perf_state.rs` gates on.
    pub fn heap_bytes(&self) -> usize {
        self.ids.len() * std::mem::size_of::<WalkId>()
            + self.last.len() * std::mem::size_of::<u64>()
            + self.index.capacity() * 8
            + self.table.capacity() * std::mem::size_of::<f64>()
            + self.return_cdf.heap_bytes()
            + self.slot_last_seen.len() * std::mem::size_of::<u64>()
    }

    /// Survival `S(dt)` under the configured model. Cold-path helper —
    /// deliberately **not** routed through the memo: its geometric form
    /// (`powi`) is a different float expression than the θ̂ loop's
    /// `exp(dt·ln(1−q))`, and mixing the two in one table would poison
    /// the determinism contract.
    #[inline]
    pub fn survival(&mut self, dt: u32) -> f64 {
        match self.model {
            SurvivalModel::Empirical => self.return_cdf.survival(dt),
            SurvivalModel::Geometric { q } => geom_survival(q, dt),
            SurvivalModel::Exponential { lambda } => exp_survival(lambda, dt as f64),
        }
    }

    /// The DECAFORK estimator, Eq. (1):
    /// `θ̂_i(t) = ½ + Σ_{ℓ ∈ L_i(t) \ {k}} S(t − L_{i,ℓ}(t))`,
    /// where `k` is the currently visiting walk (known to be alive, hence
    /// the deterministic ½ from Prop. 1).
    ///
    /// Iteration is in first-seen order (deterministic), so the
    /// floating-point sum — and therefore every threshold comparison —
    /// is reproducible across runs and thread counts. The cached and
    /// direct paths produce bit-identical sums (locked by
    /// `prop_cached_theta_bit_identical_to_direct` and the golden
    /// traces).
    pub fn theta(&mut self, t: u64, visiting: WalkId) -> f64 {
        if self.cached {
            self.theta_cached(t, visiting)
        } else {
            self.theta_direct(t, visiting)
        }
    }

    /// Table-driven evaluation: every survival term is an indexed load,
    /// computed at most once per distinct `dt` per memo epoch.
    fn theta_cached(&mut self, t: u64, visiting: WalkId) -> f64 {
        let mut acc = 0.5;
        match self.model {
            SurvivalModel::Empirical => {
                let NodeState { ids, last, return_cdf, table, .. } = self;
                // Constant during this call: `observe` (the only sample
                // source on the sim path) never runs mid-θ̂.
                let total = return_cdf.len();
                let max_obs = return_cdf.max_observed();
                // The cdf's lazy rebuild fires on the first below-maximum
                // query; mirror that trigger exactly (not per-call, not
                // per-add) so the memo epoch tracks the direct path's
                // rebuild schedule bit-for-bit.
                let mut synced = false;
                for (&wid, &seen) in ids.iter().zip(last.iter()) {
                    if wid == visiting {
                        continue;
                    }
                    if total == 0 {
                        // Warm-up fast path of `EmpiricalCdf::survival`.
                        acc += 1.0;
                        continue;
                    }
                    let dt = (t - seen) as u32;
                    if dt >= max_obs {
                        // Beyond-support fast path: identically 0.0 in
                        // every epoch, never triggers a rebuild.
                        continue;
                    }
                    if !synced {
                        table.sync(return_cdf.survival_epoch());
                        synced = true;
                    }
                    acc += table.lookup(dt, |d| return_cdf.survival(d));
                }
            }
            SurvivalModel::Geometric { q } => {
                // exp(dt·ln(1−q)) — one ln hoisted out of the loop beats
                // per-walk powi (§Perf iteration 4); the memo replays the
                // exact same expression (§Perf iteration 6).
                let log1mq = (-q).ln_1p();
                let NodeState { ids, last, table, .. } = self;
                for (&wid, &seen) in ids.iter().zip(last.iter()) {
                    if wid == visiting {
                        continue;
                    }
                    let dt = t - seen;
                    if dt < SurvivalTable::MAX_DT as u64 {
                        acc += table.lookup(dt as u32, |d| (d as f64 * log1mq).exp());
                    } else {
                        // u32 would truncate; keep the direct u64 → f64
                        // widening for absurd staleness (prune disabled).
                        acc += (dt as f64 * log1mq).exp();
                    }
                }
            }
            SurvivalModel::Exponential { lambda } => {
                let NodeState { ids, last, table, .. } = self;
                for (&wid, &seen) in ids.iter().zip(last.iter()) {
                    if wid == visiting {
                        continue;
                    }
                    let dt = t - seen;
                    if dt < SurvivalTable::MAX_DT as u64 {
                        acc += table.lookup(dt as u32, |d| exp_survival(lambda, d as f64));
                    } else {
                        acc += exp_survival(lambda, dt as f64);
                    }
                }
            }
        }
        acc
    }

    /// Direct (seed-exact) evaluation: one survival computation per term.
    /// Frozen arithmetic — the reference side of the determinism lock and
    /// of `perf_control`'s before/after measurement.
    fn theta_direct(&mut self, t: u64, visiting: WalkId) -> f64 {
        let mut acc = 0.5;
        match self.model {
            SurvivalModel::Empirical => {
                // Disjoint-field split borrow: mutate the CDF cache while
                // iterating the last-seen columns.
                let NodeState { ids, last, return_cdf, .. } = self;
                for (&wid, &seen) in ids.iter().zip(last.iter()) {
                    if wid != visiting {
                        acc += return_cdf.survival((t - seen) as u32);
                    }
                }
            }
            SurvivalModel::Geometric { q } => {
                let log1mq = (-q).ln_1p();
                for (&wid, &seen) in self.ids.iter().zip(self.last.iter()) {
                    if wid != visiting {
                        acc += ((t - seen) as f64 * log1mq).exp();
                    }
                }
            }
            SurvivalModel::Exponential { lambda } => {
                for (&wid, &seen) in self.ids.iter().zip(self.last.iter()) {
                    if wid != visiting {
                        acc += exp_survival(lambda, (t - seen) as f64);
                    }
                }
            }
        }
        acc
    }

    /// Drop walks whose survival contribution is *exactly* zero and whose
    /// absence can no longer change future estimates (dt already beyond
    /// twice the largest observed return time). This is a pure
    /// memory/speed optimization — contributions removed are identically 0
    /// under the empirical model and < 1e-12 under analytic models. It is
    /// also what keeps the [`SurvivalTable`] small: live `dt` values stay
    /// within the horizon plus one prune interval.
    pub fn prune(&mut self, t: u64) {
        let max_obs = self.return_cdf.max_observed() as u64;
        let horizon = match self.model {
            SurvivalModel::Empirical => 2 * max_obs.max(1),
            SurvivalModel::Geometric { q } => {
                if q <= 0.0 {
                    return;
                }
                (28.0 / -(1.0 - q).ln()).ceil() as u64 // S < 1e-12
            }
            SurvivalModel::Exponential { lambda } => (28.0 / lambda).ceil() as u64,
        };
        // Stable in-place sweep (the seed's `retain`, plus index fix-up
        // in the same O(|L_i|) pass over both columns). Index entries
        // are only touched when they point at the entry being moved or
        // dropped — an entry superseded by a later generation of its
        // slot leaves the newer walk's index pointer alone (and owns no
        // pointer of its own to remove).
        let mut w = 0usize;
        for r in 0..self.ids.len() {
            let (id, last) = (self.ids[r], self.last[r]);
            let owns_pointer = self.index.get(id.index()) == Some(r as u32);
            if t.saturating_sub(last) <= horizon {
                if owns_pointer {
                    self.index.set(id.index(), w as u32);
                }
                self.ids[w] = id;
                self.last[w] = last;
                w += 1;
            } else if owns_pointer {
                self.index.remove(id.index());
            }
        }
        self.ids.truncate(w);
        self.last.truncate(w);
        // Bulk removals may leave the bucket array mostly vacant; give
        // the memory back so a node's footprint tracks its current
        // neighborhood of walks, not its historical peak.
        self.index.maybe_shrink();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u64) -> WalkId {
        WalkId(n)
    }

    #[test]
    fn observe_records_return_samples() {
        let mut s = NodeState::new(4, SurvivalModel::Empirical);
        assert_eq!(s.observe(10, id(1), 0), None); // first sighting
        assert_eq!(s.observe(25, id(1), 0), Some(15)); // revisit: sample 15
        assert_eq!(s.return_cdf.len(), 1);
        assert_eq!(s.last_seen_of(id(1)), Some(25));
        assert_eq!(s.slot_last_seen[0], 25);
    }

    #[test]
    fn same_step_revisit_yields_no_sample() {
        let mut s = NodeState::new(1, SurvivalModel::Empirical);
        s.observe(5, id(1), 0);
        assert_eq!(s.observe(5, id(1), 0), None);
        assert_eq!(s.return_cdf.len(), 0);
    }

    #[test]
    fn knows_and_last_seen_resolve_through_index() {
        let mut s = NodeState::new(4, SurvivalModel::Empirical);
        for w in 0..3u64 {
            s.observe(10 + w, id(w), w as u16);
        }
        assert!(s.knows(id(0)) && s.knows(id(1)) && s.knows(id(2)));
        assert_eq!(s.last_seen_of(id(1)), Some(11));
        // Never-seen ids: both inside and beyond the index's range.
        assert!(!s.knows(id(3)));
        assert!(!s.knows(WalkId(1_000_000)));
        assert_eq!(s.last_seen_of(WalkId(1_000_000)), None);
        // Pruned ids become unknown again.
        s.return_cdf.add(5);
        s.prune(1000); // horizon 10 ≪ staleness ~990
        assert!(!s.knows(id(0)));
        assert_eq!(s.last_seen_of(id(0)), None);
    }

    #[test]
    fn reused_slot_index_is_a_new_walk() {
        // Arena slot reuse: a later generation of the same slot index
        // must be treated as a brand-new walk (no return-time sample
        // against the dead predecessor), while the predecessor's entry
        // keeps decaying inside theta until pruned — the same behaviour
        // the seed had with globally unique ids. Point lookups resolve
        // through `slot_pos`, so the superseded generation reads as
        // unknown even while its entry still contributes to θ̂.
        let mut s = NodeState::new(2, SurvivalModel::Geometric { q: 0.1 });
        let old = WalkId::compose(3, 0);
        let new = WalkId::compose(3, 1);
        s.observe(10, old, 0);
        assert!(s.knows(old));
        assert_eq!(s.observe(50, new, 1), None, "new generation must not look like a revisit");
        assert_eq!(s.known_walks(), 2);
        // The index now resolves slot 3 to the live generation only.
        assert!(s.knows(new) && !s.knows(old));
        assert_eq!(s.last_seen_of(new), Some(50));
        assert_eq!(s.last_seen_of(old), None, "superseded generation is unreachable");
        // ... but the predecessor's entry still decays inside θ̂ (visible
        // as a positive contribution beyond the live walk's ½).
        let th = s.theta(60, new);
        let expect = 0.5 + (50f64 * (-0.1f64).ln_1p()).exp();
        assert!((th - expect).abs() < 1e-12, "theta {th} expect {expect}");
        // Revisit of the live generation hits its own entry.
        assert_eq!(s.observe(60, new, 1), Some(10));
        // After pruning the stale predecessor (geometric horizon
        // 28/−ln(0.9) ≈ 266 < its staleness 290), the live walk's
        // index entry survives the rebuild and still resolves.
        s.prune(300);
        assert_eq!(s.known_walks(), 1);
        assert_eq!(s.last_seen_of(new), Some(60));
        assert_eq!(s.observe(310, new, 1), Some(250));
    }

    #[test]
    fn observe_without_slot_table_records_returns_normally() {
        // z0 = 0: no MISSINGPERSON slot table (the sharded engine's
        // memory gate for non-MP controls). Return-time bookkeeping and
        // θ̂ must be unaffected.
        let mut s = NodeState::new(0, SurvivalModel::Empirical);
        assert!(s.slot_last_seen.is_empty());
        assert_eq!(s.observe(10, id(1), 3), None);
        assert_eq!(s.observe(25, id(1), 3), Some(15));
        assert!(s.slot_last_seen.is_empty(), "slot writes must be dropped, not panic");
        assert_eq!(s.return_cdf.len(), 1);
        assert!(s.knows(id(1)));
    }

    #[test]
    fn theta_base_is_half_for_lone_walk() {
        let mut s = NodeState::new(1, SurvivalModel::Empirical);
        s.observe(3, id(1), 0);
        assert!((s.theta(10, id(1)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn theta_counts_other_walks_with_empty_cdf_as_alive() {
        let mut s = NodeState::new(3, SurvivalModel::Empirical);
        s.observe(1, id(1), 0);
        s.observe(2, id(2), 1);
        s.observe(3, id(3), 2);
        // Empty return distribution → survival = 1 for all others.
        assert!((s.theta(4, id(1)) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn theta_decays_for_stale_walks_geometric() {
        let mut s = NodeState::new(2, SurvivalModel::Geometric { q: 0.1 });
        s.observe(0, id(1), 0);
        s.observe(0, id(2), 1);
        let early = s.theta(1, id(1));
        let late = s.theta(100, id(1));
        assert!(early > late);
        assert!((late - 0.5) < 1e-4, "stale contribution should vanish: {late}");
    }

    #[test]
    fn theta_bounds() {
        let mut s = NodeState::new(4, SurvivalModel::Empirical);
        for k in 0..8u64 {
            s.observe(k, id(k), (k % 4) as u16);
        }
        for v in [5u32, 20, 100] {
            s.return_cdf.add(v);
        }
        let th = s.theta(50, id(0));
        assert!(th >= 0.5 - 1e-12);
        assert!(th <= 0.5 + (s.known_walks() - 1) as f64 + 1e-12);
    }

    #[test]
    fn exponential_model_survival() {
        let mut s = NodeState::new(1, SurvivalModel::Exponential { lambda: 0.05 });
        assert!((s.survival(0) - 1.0).abs() < 1e-12);
        assert!((s.survival(20) - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn prune_drops_only_dead_weight() {
        let mut s = NodeState::new(2, SurvivalModel::Empirical);
        s.observe(0, id(1), 0);
        s.observe(90, id(2), 1);
        // Observed return times max out at 10.
        for v in [5u32, 10] {
            s.return_cdf.add(v);
        }
        let before = s.theta(100, id(2));
        s.prune(100);
        let after = s.theta(100, id(2));
        assert_eq!(s.known_walks(), 1); // id(1) dropped (dt=100 > 2*10)
        assert!((before - after).abs() < 1e-12, "prune changed theta");
    }

    #[test]
    fn cached_theta_memoises_analytic_terms() {
        // Two instances, same schedule: cached and direct θ̂ agree to the
        // bit, and the memo demonstrably holds the values.
        let mut c = NodeState::new(4, SurvivalModel::Geometric { q: 0.05 });
        let mut d = NodeState::new_uncached(4, SurvivalModel::Geometric { q: 0.05 });
        assert!(c.is_cached() && !d.is_cached());
        for w in 0..6u64 {
            c.observe(w * 7, id(w), (w % 4) as u16);
            d.observe(w * 7, id(w), (w % 4) as u16);
        }
        for t in [50u64, 51, 90, 200] {
            assert_eq!(c.theta(t, id(0)).to_bits(), d.theta(t, id(0)).to_bits(), "t={t}");
        }
        assert!(c.survival_table().filled() > 0, "memo never populated");
        assert_eq!(d.survival_table().filled(), 0, "direct path must not touch the memo");
    }

    #[test]
    fn cached_theta_tracks_empirical_updates() {
        // Interleave samples (which can invalidate the memo) with θ̂ and
        // check the cached value keeps matching a direct-path twin.
        let mut rng = crate::rng::Rng::new(9);
        let mut c = NodeState::new(8, SurvivalModel::Empirical);
        let mut d = NodeState::new_uncached(8, SurvivalModel::Empirical);
        let mut t = 0u64;
        for step in 0..400u64 {
            t += rng.below(4) as u64;
            let w = id(rng.below(12) as u64);
            c.observe(t, w, (w.0 % 8) as u16);
            d.observe(t, w, (w.0 % 8) as u16);
            if step % 3 == 0 {
                let visiting = id(rng.below(12) as u64);
                let a = c.theta(t, visiting);
                let b = d.theta(t, visiting);
                assert_eq!(a.to_bits(), b.to_bits(), "step {step} t {t}");
            }
        }
    }

    /// The retired direct-array index, reimplemented verbatim as a test
    /// oracle: `slot_pos[WalkId::index()]` → column position, `u32::MAX`
    /// = none, sized by the largest slot index ever observed. Drives the
    /// same public semantics `NodeState` must preserve.
    struct DirectArrayModel {
        ids: Vec<WalkId>,
        last: Vec<u64>,
        slot_pos: Vec<u32>,
    }

    impl DirectArrayModel {
        fn new() -> Self {
            DirectArrayModel { ids: Vec::new(), last: Vec::new(), slot_pos: Vec::new() }
        }

        fn observe(&mut self, t: u64, id: WalkId) -> Option<u32> {
            let idx = id.index() as usize;
            if idx >= self.slot_pos.len() {
                self.slot_pos.resize(idx + 1, u32::MAX);
            }
            let pos = self.slot_pos[idx];
            if pos != u32::MAX && self.ids[pos as usize] == id {
                let dt = (t - self.last[pos as usize]) as u32;
                self.last[pos as usize] = t;
                (dt > 0).then_some(dt)
            } else {
                self.slot_pos[idx] = self.ids.len() as u32;
                self.ids.push(id);
                self.last.push(t);
                None
            }
        }

        fn pos_of(&self, id: WalkId) -> Option<usize> {
            let pos = *self.slot_pos.get(id.index() as usize)?;
            (pos != u32::MAX && self.ids[pos as usize] == id).then_some(pos as usize)
        }

        fn knows(&self, id: WalkId) -> bool {
            self.pos_of(id).is_some()
        }

        fn last_seen_of(&self, id: WalkId) -> Option<u64> {
            self.pos_of(id).map(|p| self.last[p])
        }

        /// The seed prune sweep with the fixed staleness horizon the
        /// geometric model yields (so the oracle needs no CDF).
        fn prune(&mut self, t: u64, horizon: u64) {
            let mut w = 0usize;
            for r in 0..self.ids.len() {
                let (id, last) = (self.ids[r], self.last[r]);
                let sp = &mut self.slot_pos[id.index() as usize];
                if t.saturating_sub(last) <= horizon {
                    if *sp == r as u32 {
                        *sp = w as u32;
                    }
                    self.ids[w] = id;
                    self.last[w] = last;
                    w += 1;
                } else if *sp == r as u32 {
                    *sp = u32::MAX;
                }
            }
            self.ids.truncate(w);
            self.last.truncate(w);
        }
    }

    #[test]
    fn prop_compact_index_matches_direct_array() {
        // Randomized observe / prune / supersede schedules (ISSUE 4):
        // the compact open-addressing index must answer `observe` (the
        // revisit/sample decision), `knows`, `last_seen_of` and
        // first-seen positions identically to the old direct `slot_pos`
        // array — including a superseded generation resolving to `None`
        // while its column entry survives until pruned.
        let q = 0.1f64;
        let horizon = (28.0 / -(1.0 - q).ln()).ceil() as u64; // NodeState's own prune horizon
        for case in 0..20u64 {
            let mut rng = crate::rng::Rng::new(0xA11CE ^ case);
            let mut state = NodeState::new(0, SurvivalModel::Geometric { q });
            let mut model = DirectArrayModel::new();
            let mut generation = vec![0u32; 24];
            let mut t = 0u64;
            for step in 0..600u64 {
                t += rng.below(30) as u64;
                let slot = rng.below(generation.len()) as u32;
                match rng.below(12) {
                    // Supersede: the slot's next generation takes over
                    // its index pointer on first observation.
                    0 => generation[slot as usize] += 1,
                    1 => {
                        state.prune(t);
                        model.prune(t, horizon);
                    }
                    _ => {
                        let id = WalkId::compose(slot, generation[slot as usize]);
                        assert_eq!(
                            state.observe(t, id, 0),
                            model.observe(t, id),
                            "case {case} step {step}: observe sample diverged"
                        );
                    }
                }
                // Query the full id space: live generations, superseded
                // ones, and never-seen slots far beyond the index range.
                for probe_slot in [slot, (slot + 7) % 24, 1_000_000 + slot] {
                    let generation_now = generation.get(probe_slot as usize).copied().unwrap_or(9);
                    for g in generation_now.saturating_sub(1)..=generation_now {
                        let id = WalkId::compose(probe_slot, g);
                        assert_eq!(state.knows(id), model.knows(id), "case {case} step {step}");
                        assert_eq!(
                            state.last_seen_of(id),
                            model.last_seen_of(id),
                            "case {case} step {step} id {id}"
                        );
                        assert_eq!(
                            state.pos_of(id),
                            model.pos_of(id),
                            "case {case} step {step}: first-seen position diverged"
                        );
                    }
                }
                assert_eq!(state.known_walks(), model.ids.len(), "case {case} step {step}");
            }
        }
    }

    #[test]
    fn index_memory_tracks_entries_not_walk_slot_space() {
        // The scale_1m unlock: a node that knows a handful of walks must
        // not pay for the peak walk-slot index it happened to observe.
        let mut s = NodeState::new(0, SurvivalModel::Geometric { q: 0.1 });
        for k in 0..6u32 {
            // Slot indices up to ~16M — the old direct array would have
            // resized to 64 MB per node here.
            s.observe(10 + k as u64, WalkId::compose((k + 1) * 2_800_000, 0), 0);
        }
        assert_eq!(s.known_walks(), 6);
        assert!(
            s.index_footprint() <= 16,
            "index footprint {} buckets scales with slot space",
            s.index_footprint()
        );
        // ... and prune hands bucket memory back.
        s.return_cdf.add(5);
        s.prune(1_000_000);
        assert_eq!(s.known_walks(), 0);
        assert_eq!(s.index_footprint(), 0, "pruned-empty index must release its buckets");
    }

    #[test]
    fn theta_matches_irwin_hall_mean_under_stationarity() {
        // Prop. 1 sanity: K walks whose elapsed times are drawn from R_i
        // itself give E[θ̂] ≈ K/2 (within Monte-Carlo noise).
        let mut rng = crate::rng::Rng::new(42);
        let q = 0.05;
        let k = 10u64;
        let trials = 3000;
        let mut total = 0.0;
        for trial in 0..trials {
            let mut s = NodeState::new(k as usize, SurvivalModel::Geometric { q });
            let t = 1_000_000u64;
            for w in 0..k {
                // Elapsed time since last visit ~ R_i (probability integral
                // transform argument from Prop. 1).
                let dt = rng.geometric(q);
                s.observe(t - dt, id(w + trial * k), (w % k) as u16);
            }
            total += s.theta(t, id(trial * k)); // first walk is "visiting"
        }
        let mean = total / trials as f64;
        // E[θ̂] = ½ + (K−1)·(1−q)/(2−q) ≈ ½ + 9·0.487 = 4.886
        let expect = 0.5 + (k - 1) as f64 * crate::stats::fit::geom_self_survival_mean(q);
        assert!((mean - expect).abs() < 0.15, "mean {mean} expect {expect}");
    }
}
