//! The walk arena: a struct-of-arrays store for live walks with
//! generational ids, a cold graveyard for retired walks, and stable
//! (order-preserving) compaction.
//!
//! ## Why this shape (DESIGN.md §Walk arena has the full discussion)
//!
//! The seed engine kept every walk ever created in one `Vec<Walk>` and
//! relied on ids being sequential so `id.0` indexed the vector. That made
//! `step` O(walks ever created) and blocked any compaction. The arena
//! instead keeps:
//!
//! * **dense columns** (`ids`, `at`, `born`, `lineage`, `payload`) that
//!   hold only live walks, **in creation order** — the engine's hop loop
//!   is a straight scan of `at` with no liveness checks;
//! * a **sparse slot table** mapping `WalkId::index()` to the walk's
//!   dense position, with a per-slot generation bumped on every retire so
//!   freed indices can be reused without id aliasing;
//! * a **graveyard** of materialized [`Walk`] records for retired walks,
//!   so lineage inspection and trace post-mortems keep working off the
//!   hot path.
//!
//! Compaction is **stable**, not swap-remove: the engine's determinism
//! lock (`tests/golden_traces.rs`) requires the hop loop to draw RNG
//! values in exactly the seed engine's order, i.e. creation order of the
//! surviving walks. A swap-remove would permute that order and change
//! every trace. Stable compaction costs one O(live) sweep per step *with
//! deaths* (steps without deaths skip it entirely) and keeps the columns
//! byte-for-byte in seed iteration order.
//!
//! Mid-step kills only tombstone the dense entry (`dead[i] = true`); the
//! engine compacts at well-defined barriers (after pre-step failures and
//! at end of step), so dense indices are stable for the whole hop loop.

use super::{Lineage, Walk, WalkId, WalkMut, WalkRef};
use crate::rng::Rng;

/// Sentinel for "this slot's walk is retired".
const RETIRED: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct SlotMeta {
    /// Generation minted into ids spawned from this slot.
    gen: u32,
    /// Dense position of the slot's live walk, or [`RETIRED`].
    dense: u32,
}

/// Struct-of-arrays store for the live walk population.
#[derive(Debug, Clone, Default)]
pub struct WalkArena {
    // Dense, creation-ordered columns; one entry per live (or
    // tombstoned-this-step) walk.
    ids: Vec<WalkId>,
    at: Vec<u32>,
    born: Vec<u64>,
    lineage: Vec<Lineage>,
    payload: Vec<Option<usize>>,
    /// Tombstones for walks retired since the last compaction.
    dead: Vec<bool>,
    /// Per-walk RNG streams (stream-mode engines only; `None` for the
    /// shared-stream engine). Parallel to the dense columns, compacted in
    /// the same stable sweep; retired walks' streams are simply dropped —
    /// the graveyard stores no randomness.
    streams: Option<Vec<Rng>>,
    /// Sparse table indexed by `WalkId::index()`.
    slots: Vec<SlotMeta>,
    /// Reusable slot indices (retired walks' slots).
    free: Vec<u32>,
    /// Cold store of retired walks, in retirement order.
    graveyard: Vec<Walk>,
    /// Live walks (dense entries minus tombstones).
    live: u32,
}

impl WalkArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        WalkArena {
            ids: Vec::with_capacity(n),
            at: Vec::with_capacity(n),
            born: Vec::with_capacity(n),
            lineage: Vec::with_capacity(n),
            payload: Vec::with_capacity(n),
            dead: Vec::with_capacity(n),
            slots: Vec::with_capacity(n),
            ..Self::default()
        }
    }

    /// An arena with the per-walk stream column enabled (stream-mode
    /// engines). Spawns must then go through
    /// [`spawn_with_stream`](Self::spawn_with_stream) so the column stays
    /// parallel to the dense columns.
    pub fn with_streams(n: usize) -> Self {
        WalkArena { streams: Some(Vec::with_capacity(n)), ..Self::with_capacity(n) }
    }

    /// Whether the per-walk stream column is enabled.
    #[inline]
    pub fn has_streams(&self) -> bool {
        self.streams.is_some()
    }

    /// Number of live walks.
    #[inline]
    pub fn live(&self) -> u32 {
        self.live
    }

    /// Length of the dense columns (live walks plus tombstones not yet
    /// compacted away). Equals `live()` right after [`compact`](Self::compact).
    #[inline]
    pub fn dense_len(&self) -> usize {
        self.ids.len()
    }

    /// Dense id column. Creation-ordered and tombstone-free when called
    /// at a compaction barrier (which is the only place the engine reads
    /// it — as the `alive` roster handed to failure models).
    #[inline]
    pub fn ids(&self) -> &[WalkId] {
        debug_assert_eq!(self.ids.len(), self.live as usize, "ids() read between barriers");
        &self.ids
    }

    /// Current node of the walk at dense position `i`.
    #[inline]
    pub fn position(&self, i: usize) -> u32 {
        self.at[i]
    }

    #[inline]
    pub fn set_position(&mut self, i: usize, node: u32) {
        self.at[i] = node;
    }

    #[inline]
    pub fn id_at(&self, i: usize) -> WalkId {
        self.ids[i]
    }

    #[inline]
    pub fn lineage_at(&self, i: usize) -> Lineage {
        self.lineage[i]
    }

    #[inline]
    pub fn born_at(&self, i: usize) -> u64 {
        self.born[i]
    }

    /// Application payload index of the walk at dense position `i`.
    #[inline]
    pub fn payload_at(&self, i: usize) -> Option<usize> {
        self.payload[i]
    }

    /// By-value view of the live walk at dense position `i`.
    #[inline]
    pub fn walk_ref(&self, i: usize) -> WalkRef {
        WalkRef {
            id: self.ids[i],
            at: self.at[i],
            born: self.born[i],
            lineage: self.lineage[i],
            payload: self.payload[i],
        }
    }

    /// Mutable view (payload only) of the live walk at dense position `i`.
    #[inline]
    pub fn walk_mut(&mut self, i: usize) -> WalkMut<'_> {
        WalkMut {
            id: self.ids[i],
            at: self.at[i],
            born: self.born[i],
            lineage: self.lineage[i],
            payload: &mut self.payload[i],
        }
    }

    /// Mutable iterator over the payload column (creation order). Only
    /// meaningful at a compaction barrier; used to seed initial payloads.
    pub fn payloads_mut(&mut self) -> impl Iterator<Item = &mut Option<usize>> {
        debug_assert_eq!(self.ids.len(), self.live as usize);
        self.payload.iter_mut()
    }

    /// Spawn a walk, reusing a retired slot when one is free (its
    /// generation was bumped at retirement, so the new id never aliases
    /// the old one). Returns the id and the dense position.
    pub fn spawn(&mut self, at: u32, born: u64, lineage: Lineage) -> (WalkId, usize) {
        debug_assert!(self.streams.is_none(), "stream-enabled arena: use spawn_with_stream");
        self.spawn_inner(at, born, lineage)
    }

    /// Spawn a walk carrying its own RNG stream (stream-mode engines;
    /// requires [`with_streams`](Self::with_streams)).
    pub fn spawn_with_stream(
        &mut self,
        at: u32,
        born: u64,
        lineage: Lineage,
        stream: Rng,
    ) -> (WalkId, usize) {
        self.streams
            .as_mut()
            .expect("spawn_with_stream on a stream-less arena")
            .push(stream);
        self.spawn_inner(at, born, lineage)
    }

    fn spawn_inner(&mut self, at: u32, born: u64, lineage: Lineage) -> (WalkId, usize) {
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                assert!(self.slots.len() < RETIRED as usize, "walk slot space exhausted");
                self.slots.push(SlotMeta { gen: 0, dense: RETIRED });
                (self.slots.len() - 1) as u32
            }
        };
        let dense = self.ids.len();
        let meta = &mut self.slots[index as usize];
        meta.dense = dense as u32;
        let id = WalkId::compose(index, meta.gen);
        self.ids.push(id);
        self.at.push(at);
        self.born.push(born);
        self.lineage.push(lineage);
        self.payload.push(None);
        self.dead.push(false);
        self.live += 1;
        (id, dense)
    }

    /// The RNG stream of the walk at dense position `i` (read-only; fork
    /// children split from this state — `Rng::split` never advances the
    /// parent).
    #[inline]
    pub fn stream_at(&self, i: usize) -> &Rng {
        &self.streams.as_ref().expect("stream-less arena")[i]
    }

    /// Whether the dense entry `i` was retired since the last compaction
    /// (mid-step tombstone).
    #[inline]
    pub fn is_tombstoned(&self, i: usize) -> bool {
        self.dead[i]
    }

    /// Disjoint borrows of the columns the stream-mode hop phase needs:
    /// the read-only id roster plus mutable position and per-walk stream
    /// columns, all in creation order. Callers chunk the two mutable
    /// slices into contiguous shard ranges. Only meaningful at a
    /// compaction barrier (dense prefix all alive).
    pub fn hop_columns_mut(&mut self) -> (&[WalkId], &mut [u32], &mut [Rng]) {
        debug_assert_eq!(self.ids.len(), self.live as usize, "hop columns read between barriers");
        let streams = self.streams.as_mut().expect("stream-less arena");
        (&self.ids, &mut self.at, streams)
    }

    /// [`hop_columns_mut`](Self::hop_columns_mut) plus read-only views
    /// of the lineage and payload columns, for the mailbox-routing hop
    /// phase: a worker that just hopped a surviving walk assembles its
    /// full arrival record (id, slot, payload) right there, while it
    /// still owns the walk, instead of leaving a coordinator scan to
    /// re-read the columns serially between the phases. The hop phase
    /// never writes lineage or payload, so the shared views are sound
    /// alongside the mutable position/stream chunks.
    #[allow(clippy::type_complexity)]
    pub fn hop_columns_routed_mut(
        &mut self,
    ) -> (&[WalkId], &[Lineage], &[Option<usize>], &mut [u32], &mut [Rng]) {
        debug_assert_eq!(self.ids.len(), self.live as usize, "hop columns read between barriers");
        let streams = self.streams.as_mut().expect("stream-less arena");
        (&self.ids, &self.lineage, &self.payload, &mut self.at, streams)
    }

    /// Dense position of a live walk, or `None` if the id is stale
    /// (retired, or from a previous occupant of the slot).
    #[inline]
    pub fn resolve(&self, id: WalkId) -> Option<usize> {
        let meta = self.slots.get(id.index() as usize)?;
        if meta.gen != id.generation() || meta.dense == RETIRED {
            return None;
        }
        Some(meta.dense as usize)
    }

    /// Whether `id` names a currently live walk.
    #[inline]
    pub fn is_live(&self, id: WalkId) -> bool {
        self.resolve(id).is_some()
    }

    /// Retire the walk at dense position `i`: tombstone the dense entry,
    /// move the record to the graveyard, bump the slot generation and
    /// free the slot for reuse. Returns the graveyard record.
    pub fn retire(&mut self, i: usize, died: u64) -> &Walk {
        debug_assert!(!self.dead[i], "double retire at dense {i}");
        self.dead[i] = true;
        self.live -= 1;
        let id = self.ids[i];
        let index = id.index() as usize;
        let meta = &mut self.slots[index];
        debug_assert_eq!(meta.dense, i as u32);
        meta.dense = RETIRED;
        meta.gen = meta.gen.wrapping_add(1);
        self.free.push(index as u32);
        self.graveyard.push(Walk {
            id,
            lineage: self.lineage[i],
            at: self.at[i],
            alive: false,
            born: self.born[i],
            died: Some(died),
            payload: self.payload[i],
        });
        self.graveyard.last().unwrap()
    }

    /// Remove tombstones with a stable in-place sweep, preserving the
    /// creation order of survivors (the determinism lock — see module
    /// docs). No-op when nothing died since the last call.
    pub fn compact(&mut self) {
        if self.ids.len() == self.live as usize {
            return;
        }
        let mut w = 0;
        for r in 0..self.ids.len() {
            if self.dead[r] {
                continue;
            }
            if w != r {
                self.ids[w] = self.ids[r];
                self.at[w] = self.at[r];
                self.born[w] = self.born[r];
                self.lineage[w] = self.lineage[r];
                self.payload[w] = self.payload[r];
                self.dead[w] = false;
                self.slots[self.ids[w].index() as usize].dense = w as u32;
                if let Some(streams) = &mut self.streams {
                    streams.swap(w, r);
                }
            }
            w += 1;
        }
        self.ids.truncate(w);
        self.at.truncate(w);
        self.born.truncate(w);
        self.lineage.truncate(w);
        self.payload.truncate(w);
        self.dead.truncate(w);
        if let Some(streams) = &mut self.streams {
            streams.truncate(w);
        }
        debug_assert_eq!(w, self.live as usize);
    }

    /// Retired walks, in retirement order (cold storage).
    pub fn graveyard(&self) -> &[Walk] {
        &self.graveyard
    }

    /// Materialize every walk this arena has ever held — live walks first
    /// (creation order), then the graveyard (retirement order). Cold
    /// path: used by lineage analytics and reports, never per step.
    pub fn snapshot(&self) -> Vec<Walk> {
        let mut out = Vec::with_capacity(self.ids.len() + self.graveyard.len());
        for i in 0..self.ids.len() {
            if self.dead[i] {
                continue;
            }
            out.push(Walk {
                id: self.ids[i],
                lineage: self.lineage[i],
                at: self.at[i],
                alive: true,
                born: self.born[i],
                died: None,
                payload: self.payload[i],
            });
        }
        out.extend(self.graveyard.iter().cloned());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn orig(slot: u16) -> Lineage {
        Lineage::Original { slot }
    }

    #[test]
    fn spawn_assigns_sequential_generation_zero_ids() {
        let mut a = WalkArena::new();
        for k in 0..5u16 {
            let (id, dense) = a.spawn(k as u32, 0, orig(k));
            assert_eq!(id, WalkId(k as u64), "fresh slots mint seed-compatible ids");
            assert_eq!(dense, k as usize);
        }
        assert_eq!(a.live(), 5);
        assert_eq!(a.ids().len(), 5);
    }

    #[test]
    fn retire_then_spawn_reuses_slot_without_aliasing() {
        let mut a = WalkArena::new();
        let (id0, _) = a.spawn(1, 0, orig(0));
        let (id1, _) = a.spawn(2, 0, orig(1));
        a.retire(a.resolve(id0).unwrap(), 10);
        a.compact();
        // The fork reuses slot 0 but with a bumped generation.
        let (id2, _) = a.spawn(3, 10, orig(2));
        assert_eq!(id2.index(), id0.index());
        assert_eq!(id2.generation(), id0.generation() + 1);
        assert_ne!(id2, id0, "reused slot must never alias the retired walk");
        // Stale id no longer resolves; live ones do.
        assert!(a.resolve(id0).is_none());
        assert!(a.is_live(id1));
        assert!(a.is_live(id2));
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn same_step_kill_and_fork_never_alias() {
        // The satellite invariant: retire tombstones immediately free the
        // slot, and a spawn in the same step (before compaction) gets the
        // bumped generation.
        let mut a = WalkArena::new();
        let (id0, d0) = a.spawn(0, 0, orig(0));
        a.retire(d0, 5);
        let (id1, _) = a.spawn(9, 5, orig(1)); // same step, reuses slot 0
        assert_eq!(id1.index(), id0.index());
        assert_ne!(id1, id0);
        assert!(a.resolve(id0).is_none());
        assert_eq!(a.resolve(id1), Some(1)); // dense 1: tombstone not yet compacted
        a.compact();
        assert_eq!(a.resolve(id1), Some(0));
        assert_eq!(a.live(), 1);
        assert_eq!(a.graveyard().len(), 1);
        assert_eq!(a.graveyard()[0].id, id0);
        assert_eq!(a.graveyard()[0].died, Some(5));
    }

    #[test]
    fn compact_is_stable_in_creation_order() {
        let mut a = WalkArena::new();
        let ids: Vec<WalkId> = (0..6).map(|k| a.spawn(k, 0, orig(k as u16)).0).collect();
        // Kill 1 and 4.
        a.retire(a.resolve(ids[1]).unwrap(), 3);
        a.retire(a.resolve(ids[4]).unwrap(), 3);
        a.compact();
        let survivors: Vec<WalkId> = a.ids().to_vec();
        assert_eq!(survivors, vec![ids[0], ids[2], ids[3], ids[5]]);
        // Slot table repointed correctly.
        for (want_dense, id) in survivors.iter().enumerate() {
            assert_eq!(a.resolve(*id), Some(want_dense));
        }
    }

    #[test]
    fn snapshot_has_live_and_dead_with_lineage() {
        let mut a = WalkArena::new();
        let (p, _) = a.spawn(0, 0, orig(0));
        let (c, _) = a.spawn(1, 2, Lineage::Forked { parent: p, by: 1, at: 2, slot: 0 });
        a.retire(a.resolve(p).unwrap(), 4);
        a.compact();
        let snap = a.snapshot();
        assert_eq!(snap.len(), 2);
        let alive: Vec<_> = snap.iter().filter(|w| w.alive).collect();
        assert_eq!(alive.len(), 1);
        assert_eq!(alive[0].id, c);
        let dead = snap.iter().find(|w| !w.alive).unwrap();
        assert_eq!(dead.id, p);
        assert_eq!(dead.died, Some(4));
        // Ancestry still resolvable through the graveyard.
        assert_eq!(crate::walks::lineage::root_slot(&snap, c), Some(0));
    }

    #[test]
    fn stream_column_follows_walk_through_compaction() {
        // Each walk's stream must stay glued to its walk across stable
        // compaction — a misaligned stream column would silently hand one
        // walk another's randomness and break schedule invariance.
        let mut a = WalkArena::with_streams(4);
        assert!(a.has_streams());
        let ids: Vec<WalkId> = (0..4u16)
            .map(|k| a.spawn_with_stream(k as u32, 0, orig(k), Rng::new(1000 + k as u64)).0)
            .collect();
        // Fingerprint each walk's stream by what a clone would draw next.
        let finger = |a: &WalkArena, d: usize| a.stream_at(d).clone().next_u64();
        let fp: Vec<u64> = (0..4).map(|d| finger(&a, d)).collect();
        a.retire(a.resolve(ids[1]).unwrap(), 3);
        a.compact();
        let survivors = [ids[0], ids[2], ids[3]];
        let expect = [fp[0], fp[2], fp[3]];
        for (id, want) in survivors.iter().zip(expect) {
            let d = a.resolve(*id).unwrap();
            assert_eq!(finger(&a, d), want, "stream column misaligned after compaction");
        }
        let (roster, at, streams) = a.hop_columns_mut();
        assert_eq!(roster.len(), 3);
        assert_eq!(at.len(), 3);
        assert_eq!(streams.len(), 3);
    }

    #[test]
    fn payload_follows_walk_through_compaction_and_retirement() {
        let mut a = WalkArena::new();
        let (id0, d0) = a.spawn(0, 0, orig(0));
        let (id1, d1) = a.spawn(1, 0, orig(1));
        *a.walk_mut(d0).payload = Some(10);
        *a.walk_mut(d1).payload = Some(11);
        a.retire(a.resolve(id0).unwrap(), 1);
        a.compact();
        assert_eq!(a.walk_ref(a.resolve(id1).unwrap()).payload, Some(11));
        let dead = &a.graveyard()[0];
        assert_eq!((dead.id, dead.payload), (id0, Some(10)));
    }
}
