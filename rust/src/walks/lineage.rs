//! Lineage analytics over the walk forest (paper footnote 8: identifiers
//! accumulate fork ancestry). Used by the learning reports to show which
//! initial models' progress survives failures, and by tests to verify
//! fork bookkeeping.

use super::{Lineage, Walk, WalkId};
use std::collections::HashMap;

/// Index walks by id for ancestry traversal.
fn by_id(walks: &[Walk]) -> HashMap<WalkId, &Walk> {
    walks.iter().map(|w| (w.id, w)).collect()
}

/// The original slot (root identity in `[Z0]`) a walk descends from.
pub fn root_slot(walks: &[Walk], id: WalkId) -> Option<u16> {
    let idx = by_id(walks);
    let mut cur = idx.get(&id)?;
    loop {
        match cur.lineage {
            Lineage::Original { slot } => return Some(slot),
            Lineage::Forked { parent, .. } => cur = idx.get(&parent)?,
        }
    }
}

/// Fork depth (0 for originals).
pub fn depth(walks: &[Walk], id: WalkId) -> Option<usize> {
    let idx = by_id(walks);
    let mut cur = idx.get(&id)?;
    let mut d = 0;
    loop {
        match cur.lineage {
            Lineage::Original { .. } => return Some(d),
            Lineage::Forked { parent, .. } => {
                d += 1;
                cur = idx.get(&parent)?;
            }
        }
    }
}

/// The full ancestry chain id → … → original (inclusive).
pub fn ancestry(walks: &[Walk], id: WalkId) -> Vec<WalkId> {
    let idx = by_id(walks);
    let mut chain = Vec::new();
    let mut cur = match idx.get(&id) {
        Some(w) => *w,
        None => return chain,
    };
    loop {
        chain.push(cur.id);
        match cur.lineage {
            Lineage::Original { .. } => return chain,
            Lineage::Forked { parent, .. } => match idx.get(&parent) {
                Some(p) => cur = p,
                None => return chain,
            },
        }
    }
}

/// Count of *living* walks per original slot — the redundancy each
/// initial task identity still enjoys.
pub fn survivors_per_root(walks: &[Walk]) -> HashMap<u16, usize> {
    let mut out = HashMap::new();
    for w in walks.iter().filter(|w| w.alive) {
        if let Some(slot) = root_slot(walks, w.id) {
            *out.entry(slot).or_insert(0) += 1;
        }
    }
    out
}

/// Summary line for reports: living walks, distinct surviving roots,
/// max fork depth among the living.
pub fn lineage_summary(walks: &[Walk]) -> String {
    let alive: Vec<&Walk> = walks.iter().filter(|w| w.alive).collect();
    let roots = survivors_per_root(walks);
    let max_depth = alive
        .iter()
        .filter_map(|w| depth(walks, w.id))
        .max()
        .unwrap_or(0);
    format!(
        "{} living walks from {} surviving root identities (max fork depth {})",
        alive.len(),
        roots.len(),
        max_depth
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn walk(id: u64, lineage: Lineage, alive: bool) -> Walk {
        Walk { id: WalkId(id), lineage, at: 0, alive, born: 0, died: None, payload: None }
    }

    fn forest() -> Vec<Walk> {
        vec![
            walk(0, Lineage::Original { slot: 0 }, false),
            walk(1, Lineage::Original { slot: 1 }, true),
            walk(2, Lineage::Forked { parent: WalkId(0), by: 3, at: 10, slot: 0 }, true),
            walk(3, Lineage::Forked { parent: WalkId(2), by: 5, at: 20, slot: 0 }, true),
            walk(4, Lineage::Forked { parent: WalkId(1), by: 7, at: 30, slot: 1 }, false),
        ]
    }

    #[test]
    fn roots_and_depths() {
        let f = forest();
        assert_eq!(root_slot(&f, WalkId(3)), Some(0));
        assert_eq!(root_slot(&f, WalkId(4)), Some(1));
        assert_eq!(depth(&f, WalkId(0)), Some(0));
        assert_eq!(depth(&f, WalkId(3)), Some(2));
        assert_eq!(root_slot(&f, WalkId(99)), None);
    }

    #[test]
    fn ancestry_chain() {
        let f = forest();
        assert_eq!(ancestry(&f, WalkId(3)), vec![WalkId(3), WalkId(2), WalkId(0)]);
        assert_eq!(ancestry(&f, WalkId(1)), vec![WalkId(1)]);
    }

    #[test]
    fn survivor_counts() {
        let f = forest();
        let s = survivors_per_root(&f);
        assert_eq!(s.get(&0), Some(&2)); // walks 2 and 3
        assert_eq!(s.get(&1), Some(&1)); // walk 1 (walk 4 dead)
        let summary = lineage_summary(&f);
        assert!(summary.contains("3 living walks"));
        assert!(summary.contains("2 surviving root"));
        assert!(summary.contains("depth 2"));
    }
}
