//! `decafork` — CLI for the self-regulating random-walk system.
//!
//! Subcommands:
//! * `simulate` — one experiment (graph × control × failures), CSV/plot out
//! * `figure`   — regenerate a paper figure (1–6)
//! * `train`    — decentralized RW-SGD with failures + DECAFORK+ (needs
//!   `make artifacts`)
//! * `actors`   — the thread-per-node decentralized runtime
//! * `theory`   — evaluate the paper's bounds for a given setting
//! * `design`   — threshold design from Irwin–Hall quantiles
//! * `info`     — graph family properties

use std::sync::Arc;
use std::time::Duration;

use decafork::cli::Args;
use decafork::control::{Decafork, DecaforkPlus, MissingPerson, NoControl};
use decafork::coordinator::ActorRuntime;
use decafork::graph::generators;
use decafork::learning::{
    presets as learn_presets, LearnSpec, PjrtOp, TrainOp, TrainOptions, TrainingRun,
};
use decafork::report::{ascii_plot, Table};
use decafork::rng::Rng;
use decafork::runtime::{default_artifacts_dir, Runtime, TrainStep};
use decafork::scenario::{parse, ControlSpec, FailureSpec, GraphSpec, Scenario};
use decafork::sim::engine::SimParams;
use decafork::sim::run_many_with_budget;
use decafork::stats::irwin_hall::{design_epsilon, design_epsilon2};
use decafork::theory::{growth_bound, overshoot_recursion, reaction_time_bound, Rates};
use decafork::walks::SurvivalModel;
use decafork::{figures, theory};

const USAGE: &str = "decafork <simulate|figure|train|actors|theory|design|info> [flags]

  simulate --graph regular|er|complete|ba --n 100 --d 8 --z0 10
           --topology implicit-ring|implicit-smallworld|<any --graph value>
                        (implicit-*: zero-edge-storage backend, works at
                         --n 10000000 and beyond)
           --control decafork|decafork+|missingperson|periodic|none
           --eps 2.0 --eps2 5.75 --eps-mp 600 --period 100
           --pf 0.0 --bursts 2000:5,6000:6 --byz-node -1
           --horizon 10000 --runs 10 --seed 57005 --csv results/sim.csv
           --shards 1   (>=2: stream-mode sharded engine per replication)
           --cores N    (total core budget split across runs x shards;
                         default DECAFORK_CORES or detected parallelism)
           --node-state dense|lazy   (per-node state storage; default
                         lazy = allocate on first visit, O(visited)
                         memory — bit-identical to dense at any scale)
           --routing serial|mailbox  (stream-mode arrival routing;
                         default mailbox = hop workers bin arrivals,
                         O(shards) coordinator work — bit-identical to
                         the serial O(live-walks) oracle scan)
           --pin-cores on|off        (default off; pin pool worker k to
                         core k+1 — Linux, best-effort, placement only,
                         never changes results)
           --hop-path scalar|blocked (stream-mode hot-phase execution;
                         default blocked = prefetch + batched draws
                         over 64-walk blocks — bit-identical to the
                         scalar per-walk oracle loop)
           --metrics off|jsonl|csv   (default off; stream one step
                         record per --metrics-every steps — phase
                         spans, worker counters, Z_t, theta, recovery
                         series. Observation only: traces stay
                         bit-identical)
           --metrics-out PATH        (default metrics.jsonl / .csv)
           --metrics-every K         (flush period in steps; default 1.
                         Records are period totals — nothing is lost
                         at coarse periods)
  figure   --id 1..6 --runs 10 --out results [--runs 50 = paper scale]
           --shards 1 --cores N
  train    --preset learn_tiny|learn_10k|learn_100k  (or --n 64 --d 8
           --z0 4 --horizon 400 --burst 200:2 --eps 2.0 --vocab 32
           --batch 8 --seq 16 --lr 0.1 --tokens 4096)
           --local      (pure-Rust bigram operator; no artifacts needed)
           --artifacts artifacts   (default: PJRT executable via
                                    `make artifacts`)
           --shards N   (flag present: sharded trainer on the stream-mode
                         engine; results invariant in N)
           --cores M    --merge-every K   --merge (gossip-on-meet,
                         shared-stream path only)
  actors   --n 32 --d 4 --z0 6 --pf 0.002 --hops 200000 --eps 2.0
  theory   --z0 10 --d 5 --eps 2.0 --n 100
  design   --z0 10 --delta 1e-4
  info     --graph regular --n 100 --d 8   (--topology works here too)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("figure") => cmd_figure(&args),
        Some("train") => cmd_train(&args),
        Some("actors") => cmd_actors(&args),
        Some("theory") => cmd_theory(&args),
        Some("design") => cmd_design(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = parse::scenario(args)?;
    let cores = parse::cores(args)?;
    let t0 = std::time::Instant::now();
    let (_traces, agg) = run_many_with_budget(&cfg, args.get("threads", 0usize)?, cores)?;
    let dt = t0.elapsed();
    println!(
        "{} on {} | {} runs x {} steps in {:.2?}",
        cfg.control.label(),
        cfg.graph.label(),
        cfg.runs,
        cfg.horizon,
        dt
    );
    println!(
        "extinctions: {}/{}  capped: {}  mean forks/run: {:.1}",
        agg.extinctions,
        agg.runs,
        agg.capped_runs,
        agg.forks_per_run.iter().sum::<usize>() as f64 / agg.runs as f64
    );
    println!(
        "state footprint (max over runs): {} visited nodes, {}",
        agg.max_visited_nodes,
        decafork::report::human_bytes(agg.max_state_bytes)
    );
    if cfg.params.metrics.enabled() {
        println!(
            "metrics: {} -> {} (every {} steps)",
            cfg.params.metrics.mode.as_str(),
            cfg.params.metrics.out_path(),
            cfg.params.metrics.period()
        );
    }
    println!("{}", ascii_plot("Z_t (mean over runs)", &[("Z", &agg.mean)], 90, 16));
    if let Some(csv) = args.flags.get("csv") {
        let rows: Vec<Vec<f64>> = (0..agg.mean.len())
            .map(|t| vec![t as f64, agg.mean[t], agg.std[t]])
            .collect();
        decafork::report::write_csv(csv, &["t", "z_mean", "z_std"], &rows)?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id: u32 = args.get("id", 1)?;
    let runs = args.get("runs", 10usize)?;
    let out = args.get_str("out", "results");
    let t0 = std::time::Instant::now();
    let fig = figures::by_id(
        id,
        runs,
        args.get("threads", 0usize)?,
        parse::shards(args)?,
        parse::cores(args)?,
    )?;
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv(&out)?;
    println!("({} runs in {:.2?}; csv: {})", runs, t0.elapsed(), path.display());
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    // Workload: a named preset (`learning::presets`) or the historical
    // flag-built scenario.
    let mut spec = match args.flags.get("preset") {
        Some(name) => learn_presets::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown preset '{name}' (try learn_tiny, learn_10k, learn_100k)")
        })?,
        None => {
            let bursts = parse::bursts(&args.get_str("burst", "200:2"))?;
            LearnSpec {
                name: "custom",
                scenario: Scenario {
                    graph: GraphSpec::RandomRegular {
                        n: args.get("n", 64usize)?,
                        d: args.get("d", 8usize)?,
                    },
                    params: SimParams { z0: args.get("z0", 4u32)?, ..Default::default() },
                    control: ControlSpec::Decafork { epsilon: args.get("eps", 2.0f64)? },
                    failures: if bursts.is_empty() {
                        FailureSpec::None
                    } else {
                        FailureSpec::Burst { events: bursts }
                    },
                    horizon: 400,
                    runs: 1,
                    seed: 7,
                },
                tokens_per_node: args.get("tokens", 4096usize)?,
                vocab: args.get("vocab", 32usize)?,
                batch: args.get("batch", 8usize)?,
                seq: args.get("seq", 16usize)?,
                lr: args.get("lr", 0.1f32)?,
                merge_period: 0,
            }
        }
    };
    spec.scenario.horizon = args.get("horizon", spec.scenario.horizon)?;
    spec.scenario.seed = args.get("seed", spec.scenario.seed)?;
    let stream = args.flags.get("shards").is_some();
    // Knobs that belong to the *other* path are a misconfiguration, not
    // something to ignore silently: a user asking for consensus merging
    // must not get a merge-free run that looks successful.
    anyhow::ensure!(
        stream || args.flags.get("merge-every").is_none(),
        "--merge-every is a sharded-trainer knob; add --shards N (the preset's \
         merge period only applies to sharded runs)"
    );
    anyhow::ensure!(
        !(stream && args.has("merge")),
        "--merge (gossip-on-meet) is a shared-stream extension; drop --shards or --merge"
    );
    // All train entry points route through the CoreBudget (ISSUE 5
    // satellite): `--shards` is a request, the budget decides what is
    // actually spawned — and stream-mode invariance makes the plan free.
    let opts = TrainOptions {
        stream,
        shards: parse::shards(args)?,
        budget: parse::cores(args)?,
        merge_period: {
            // Always run the validator (it rejects a valueless
            // `--merge-every`); the preset default applies only to
            // sharded runs, and only when the flag is genuinely absent.
            let explicit = parse::merge_every(args)?;
            if args.flags.contains_key("merge-every") {
                explicit
            } else if stream {
                spec.merge_period
            } else {
                0
            }
        },
        merge_on_meet: args.has("merge"),
    };

    if args.has("local") {
        // Pure-Rust bigram operator: no artifacts, no PJRT — the path CI
        // and toolchain-only machines can always run.
        let op = spec.op();
        println!(
            "operator: local bigram | {} params (vocab {}), batch {}x{}, lr {}",
            op.param_count(),
            spec.vocab,
            op.batch(),
            op.seq() + 1,
            spec.lr
        );
        run_train(&spec, &op, &opts)
    } else {
        let artifacts = std::path::PathBuf::from(
            args.get_str("artifacts", &default_artifacts_dir().to_string_lossy()),
        );
        anyhow::ensure!(
            decafork::runtime::artifacts_present(&artifacts),
            "no artifacts at {} — run `make artifacts` first (or pass --local \
             for the pure-Rust operator)",
            artifacts.display()
        );
        let rt = Runtime::cpu()?;
        let train = TrainStep::load(&rt, &artifacts)?;
        // The corpus must speak the compiled model's vocabulary.
        spec.vocab = train.manifest.get_usize("vocab")?;
        let op = PjrtOp::new(&train)?;
        println!(
            "operator: PJRT | {} params, batch {}x{} tokens, lr {}",
            op.param_count(),
            op.batch(),
            op.seq() + 1,
            train.manifest.get_f64("lr")?
        );
        run_train(&spec, &op, &opts)
    }
}

/// Shared tail of `cmd_train`, generic over the operator.
fn run_train<O: TrainOp>(spec: &LearnSpec, op: &O, opts: &TrainOptions) -> anyhow::Result<()> {
    let corpus = Arc::new(spec.corpus());
    if opts.stream {
        println!(
            "workload {}: {} | sharded trainer, {} workers (requested {}, budget {}), \
             merge every {}",
            spec.name,
            spec.scenario.label(),
            opts.planned_workers(),
            opts.shards,
            opts.budget.total(),
            if opts.merge_period == 0 {
                "never".into()
            } else {
                format!("{} steps", opts.merge_period)
            },
        );
    } else {
        println!("workload {}: {} | shared-stream trainer", spec.name, spec.scenario.label());
    }
    let t0 = std::time::Instant::now();
    let summary = TrainingRun::execute_budgeted(&spec.scenario, 0, op, corpus, opts)?;
    println!(
        "ran {} SGD steps across walks in {:.2?}; survivors: {}; merges: {}",
        summary.steps,
        t0.elapsed(),
        summary.survivors,
        summary.merges
    );
    println!("lineage: {}", summary.lineage);
    println!("loss: first {:.4} -> last-20-mean {:.4}", summary.first_loss, summary.last_loss_mean);
    // The canonical loss-stream fingerprint CI's learn smoke diffs
    // across shard counts (sharded runs are bit-identical at any worker
    // count; shared-stream runs are their own family).
    println!("loss_digest=0x{:016x}", summary.loss_digest());
    let curve: Vec<f64> = summary
        .losses
        .chunks(8.max(summary.losses.len() / 64))
        .map(|c| c.iter().map(|&(_, _, l)| l as f64).sum::<f64>() / c.len() as f64)
        .collect();
    println!("{}", ascii_plot("training loss (visit order)", &[("loss", &curve)], 80, 12));
    let z: Vec<f64> = summary.trace.z.iter().map(|&v| v as f64).collect();
    println!("{}", ascii_plot("Z_t during training", &[("Z", &z)], 80, 8));
    Ok(())
}

fn cmd_actors(args: &Args) -> anyhow::Result<()> {
    let n = args.get("n", 32usize)?;
    let d = args.get("d", 4usize)?;
    let seed = args.get("seed", 7u64)?;
    let graph = Arc::new(generators::random_regular(n, d, &mut Rng::new(seed))?);
    let rtm = ActorRuntime {
        graph,
        z0: args.get("z0", 6u32)?,
        p_f: args.get("pf", 0.002f64)?,
        survival: SurvivalModel::Empirical,
        hop_budget: args.get("hops", 200_000u64)?,
        max_wall: Duration::from_secs(args.get("wall", 60u64)?),
        seed,
    };
    let control = args.get_str("control", "decafork");
    let t0 = std::time::Instant::now();
    let run = match control.as_str() {
        "decafork" => rtm.run(&Decafork::new(args.get("eps", 2.0)?))?,
        "decafork+" => rtm.run(&DecaforkPlus::new(args.get("eps", 3.25)?, args.get("eps2", 5.75)?))?,
        "missingperson" => rtm.run(&MissingPerson::new(args.get("eps-mp", 600u64)?))?,
        "none" => rtm.run(&NoControl)?,
        other => anyhow::bail!("unknown control '{other}'"),
    };
    let dt = t0.elapsed();
    println!(
        "decentralized run: {} hops in {:.2?} ({:.0} hops/s across {} node threads)",
        run.hops,
        dt,
        run.hops as f64 / dt.as_secs_f64(),
        n
    );
    println!(
        "forks: {}  control-terminations: {}  failures: {}  final population: {}",
        run.forks, run.control_terminations, run.failures, run.final_alive
    );
    let z: Vec<f64> = run.z_samples.iter().map(|&v| v as f64).collect();
    println!("{}", ascii_plot("population (wall-clock samples)", &[("Z", &z)], 80, 10));
    Ok(())
}

fn cmd_theory(args: &Args) -> anyhow::Result<()> {
    let z0: u32 = args.get("z0", 10)?;
    let d: u32 = args.get("d", 5)?;
    let eps: f64 = args.get("eps", 2.0)?;
    let n: usize = args.get("n", 100)?;
    let rates = Rates::new(1.0 / n as f64, 1.0 / n as f64);
    let p = 1.0 / z0 as f64;

    println!("Assumption-1 rates: lambda_r = lambda_a = 1/n = {:.4}\n", rates.lambda_r);

    let header = format!("Thm2: steps to 1st fork (D={d} failed)");
    let mut t = Table::new(&["delta", &header]);
    for delta in [0.5, 0.1, 0.01] {
        let bound = reaction_time_bound(d, 0, z0 - d, eps, p, rates, delta, 2_000_000)
            .map(|v| v.to_string())
            .unwrap_or_else(|| ">2e6".into());
        t.row(vec![format!("{delta}"), bound]);
    }
    println!("{}", t.render());

    let mut t = Table::new(&["z", "Thm3 delta(T=10000)", "Cor2 T(delta=0.1)"]);
    for z in [z0 + 2, z0 + 5, 2 * z0] {
        let g = growth_bound(z0, z, eps, p, n, rates, 10_000.0);
        let tt = theory::time_until_growth(z0, z, eps, p, n, rates, 0.1);
        t.row(vec![z.to_string(), format!("{:.4}", g.delta), format!("{tt:.0}")]);
    }
    println!("{}", t.render());

    let traj = overshoot_recursion(z0 - d, 2000.0, 600, eps, p, rates, d);
    println!(
        "Cor3 overshoot recursion from Z={} after D={} failures: E[Z] after 200/400/600 steps = {:.1}/{:.1}/{:.1}",
        z0 - d,
        d,
        traj[200],
        traj[400],
        traj[600]
    );
    Ok(())
}

fn cmd_design(args: &Args) -> anyhow::Result<()> {
    let z0: u32 = args.get("z0", 10)?;
    let delta: f64 = args.get("delta", 1e-4)?;
    let eps = design_epsilon(z0, delta);
    let eps2 = design_epsilon2(z0, delta);
    println!("Z0 = {z0}, spurious-action probability delta = {delta}");
    println!("  DECAFORK  : eps  = {eps:.3}   (fork prob with Z0 healthy walks ~ p*delta)");
    println!("  DECAFORK+ : eps2 = {eps2:.3}  (termination prob likewise)");
    println!("(paper Fig. 1 uses eps=2, eps2=5.75 for Z0=10)");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let spec = parse::graph(args)?;
    let mut rng = Rng::new(args.get("seed", 1u64)?);
    let g = spec.build(&mut rng)?;
    let stats = decafork::graph::properties::degree_stats(&g);
    println!("{}: n={} m={} connected={}", spec.label(), g.n(), g.m(), g.is_connected());
    println!(
        "degrees: min {} max {} mean {:.2} std {:.2}",
        stats.min, stats.max, stats.mean, stats.std
    );
    println!("diameter: {}", decafork::graph::properties::diameter(&g));
    println!("mean return time at node 0 (Kac): {:.1}", g.mean_return_time(0));
    println!(
        "empirical cover time from node 0: {}",
        decafork::graph::properties::empirical_cover_time(&g, 0, &mut rng)
    );
    Ok(())
}
