//! Train-step operators: the one-visit SGD computation the walk engines
//! drive, abstracted behind [`TrainOp`] so the same trainer code runs on
//!
//! * the AOT-compiled JAX/Pallas executable ([`PjrtOp`], production —
//!   needs `make artifacts` and a real PJRT plugin), and
//! * a pure-Rust bigram language model ([`BigramOp`]) that needs nothing
//!   but the crate — the operator the determinism tests, the CI learn
//!   smoke and `benches/perf_learn.rs` run on, since a toolchain-only
//!   environment has no PJRT.
//!
//! The contract every operator must honor for the sharded trainer's
//! schedule invariance: [`TrainOp::step`] is a **pure function** of
//! `(params, tokens)` — same inputs, bit-identical outputs, no interior
//! state, no randomness. `Sync` is a supertrait because shard replicas
//! evaluate the operator concurrently (read-only) during the parallel
//! control phase.

use crate::rng::Rng;
use crate::runtime::TrainStep;

/// One SGD step: `(params, token batch) → (new params, mean loss)`.
///
/// `tokens` is a flattened row-major `(batch, seq+1)` matrix of token
/// ids — `seq` inputs plus the next-token targets, exactly the layout
/// [`ShardedCorpus::sample_batch`](crate::learning::ShardedCorpus::sample_batch)
/// produces.
pub trait TrainOp: Sync {
    /// Parameter vector length.
    fn param_count(&self) -> usize;
    /// Rows per batch.
    fn batch(&self) -> usize;
    /// Input sequence length (the token matrix has `seq + 1` columns).
    fn seq(&self) -> usize;
    /// Scale of the uniform init ([`init_params`] draws from
    /// `±init_scale`).
    fn init_scale(&self) -> f32 {
        0.02
    }
    /// Run one SGD step. Must be a pure function of its inputs.
    fn step(&self, params: &[f32], tokens: &[i32]) -> anyhow::Result<(Vec<f32>, f32)>;
}

/// The deterministic initial parameter vector every walk's model starts
/// from (paper footnote 4: all `Z0` walks are created by one node, as if
/// from one init). Identical to the scheme the shared-stream
/// `TrainingRun` has always used, so seeds stay comparable.
pub fn init_params<O: TrainOp + ?Sized>(op: &O, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x494E4954);
    let scale = op.init_scale();
    (0..op.param_count()).map(|_| (rng.f64() as f32 - 0.5) * 2.0 * scale).collect()
}

/// Check that `corpus` can feed `op`-shaped batches for a graph of
/// `n_nodes` nodes — shared by both trainer entry points so a
/// misconfiguration fails on the coordinator with a clear message
/// instead of tripping `sample_batch`'s assert inside a worker thread.
pub fn validate_corpus<O: TrainOp + ?Sized>(
    op: &O,
    corpus: &crate::learning::corpus::ShardedCorpus,
    n_nodes: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        corpus.n_nodes() >= n_nodes,
        "corpus has {} shards but the graph has {n_nodes} nodes",
        corpus.n_nodes()
    );
    anyhow::ensure!(
        corpus.shard(0).len() > op.seq() + 1,
        "corpus shards ({} tokens) are too small for seq {} batch windows",
        corpus.shard(0).len(),
        op.seq()
    );
    Ok(())
}

/// The production operator: the `(params f32[P], tokens i32[B,T]) →
/// (new_params, loss)` executable lowered from `python/compile/model.py`,
/// executed through PJRT. Shapes and hyperparameters are read from the
/// artifact manifest once, at construction, so the hot path is
/// `Result`-free.
pub struct PjrtOp<'a> {
    train: &'a TrainStep,
    params: usize,
    batch: usize,
    seq: usize,
    init_scale: f32,
}

impl<'a> PjrtOp<'a> {
    pub fn new(train: &'a TrainStep) -> anyhow::Result<Self> {
        Ok(PjrtOp {
            params: train.param_count()?,
            batch: train.manifest.get_usize("batch")?,
            seq: train.manifest.get_usize("seq")?,
            init_scale: train.manifest.get_f64("init_scale").unwrap_or(0.02) as f32,
            train,
        })
    }
}

impl TrainOp for PjrtOp<'_> {
    fn param_count(&self) -> usize {
        self.params
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn init_scale(&self) -> f32 {
        self.init_scale
    }
    fn step(&self, params: &[f32], tokens: &[i32]) -> anyhow::Result<(Vec<f32>, f32)> {
        self.train.step(params, tokens)
    }
}

/// A pure-Rust bigram language model: parameters are a `vocab × vocab`
/// logit matrix (row = current token, column = next token), trained by
/// online softmax cross-entropy SGD over the batch's consecutive pairs.
///
/// Deliberately simple — the walk/fork/merge machinery is what the
/// sharded trainer exercises, not model capacity — but genuinely
/// learnable on the Markov [`ShardedCorpus`]: the bigram table *is* the
/// corpus's generative model, so the loss drops from `≈ ln(vocab)`
/// toward the corpus's bigram entropy. Every float operation runs in a
/// fixed order, so `step` is bit-deterministic.
///
/// [`ShardedCorpus`]: crate::learning::ShardedCorpus
#[derive(Debug, Clone)]
pub struct BigramOp {
    pub vocab: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f32,
}

impl BigramOp {
    pub fn new(vocab: usize, batch: usize, seq: usize, lr: f32) -> Self {
        assert!(vocab >= 2 && batch >= 1 && seq >= 1);
        assert!(lr > 0.0);
        BigramOp { vocab, batch, seq, lr }
    }
}

impl TrainOp for BigramOp {
    fn param_count(&self) -> usize {
        self.vocab * self.vocab
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn init_scale(&self) -> f32 {
        0.02
    }

    fn step(&self, params: &[f32], tokens: &[i32]) -> anyhow::Result<(Vec<f32>, f32)> {
        let v = self.vocab;
        anyhow::ensure!(
            params.len() == v * v,
            "param vector must be vocab^2 = {}, got {}",
            v * v,
            params.len()
        );
        let t1 = self.seq + 1;
        anyhow::ensure!(
            tokens.len() == self.batch * t1,
            "token batch must be {}x{t1}, got {}",
            self.batch,
            tokens.len()
        );
        let mut p = params.to_vec();
        let mut exps = vec![0f32; v];
        let mut loss_sum = 0f64;
        let mut pairs = 0usize;
        for row in tokens.chunks_exact(t1) {
            for w in row.windows(2) {
                let (a, b) = (w[0], w[1]);
                anyhow::ensure!(
                    (0..v as i32).contains(&a) && (0..v as i32).contains(&b),
                    "token ({a}, {b}) outside vocab {v}"
                );
                let (a, b) = (a as usize, b as usize);
                let base = a * v;
                let logits = &p[base..base + v];
                // Max-shifted softmax for numerical stability.
                let mut m = f32::NEG_INFINITY;
                for &x in logits {
                    if x > m {
                        m = x;
                    }
                }
                let mut z = 0f32;
                for (e, &x) in exps.iter_mut().zip(logits) {
                    *e = (x - m).exp();
                    z += *e;
                }
                loss_sum += (z.ln() + m - logits[b]) as f64;
                pairs += 1;
                // Online SGD on the current-token row: grad = p̂ − onehot.
                let inv = 1.0 / z;
                for (c, &e) in exps.iter().enumerate() {
                    let grad = e * inv - if c == b { 1.0 } else { 0.0 };
                    p[base + c] -= self.lr * grad;
                }
            }
        }
        anyhow::ensure!(pairs > 0, "empty token batch");
        Ok((p, (loss_sum / pairs as f64) as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::ShardedCorpus;

    fn op() -> BigramOp {
        BigramOp::new(16, 4, 8, 0.3)
    }

    #[test]
    fn bigram_learns_the_markov_corpus() {
        let op = op();
        let corpus = ShardedCorpus::markov(1, 20_000, 16, 5);
        let mut rng = Rng::new(3);
        let mut p = init_params(&op, 7);
        let (_, first) = op
            .step(&p, &corpus.sample_batch(0, op.batch(), op.seq(), &mut rng.clone()))
            .unwrap();
        assert!(
            (first - (16f32).ln()).abs() < 0.3,
            "near-uniform init should cost ≈ ln(vocab): {first}"
        );
        let mut last = first;
        for _ in 0..400 {
            let tokens = corpus.sample_batch(0, op.batch(), op.seq(), &mut rng);
            let (np, l) = op.step(&p, &tokens).unwrap();
            p = np;
            last = l;
        }
        assert!(last < 0.75 * first, "no learning progress: {first} -> {last}");
        // Not degenerate either: bounded below by the corpus entropy.
        assert!(last > 0.2, "suspiciously low loss {last}");
    }

    #[test]
    fn bigram_step_is_bit_deterministic() {
        let op = op();
        let corpus = ShardedCorpus::markov(1, 2000, 16, 9);
        let mut rng = Rng::new(4);
        let tokens = corpus.sample_batch(0, op.batch(), op.seq(), &mut rng);
        let p = init_params(&op, 11);
        let (p1, l1) = op.step(&p, &tokens).unwrap();
        let (p2, l2) = op.step(&p, &tokens).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert!(p1.iter().zip(&p2).all(|(a, b)| a.to_bits() == b.to_bits()));
        // And the params actually moved.
        assert!(p1.iter().zip(&p).any(|(a, b)| a != b));
    }

    #[test]
    fn bigram_rejects_bad_shapes_and_tokens() {
        let op = op();
        let p = vec![0.0; op.param_count()];
        assert!(op.step(&p, &[0; 3]).is_err(), "wrong batch shape must error");
        assert!(op.step(&p[..5], &vec![0; 4 * 9]).is_err(), "wrong param len must error");
        let mut bad = vec![0i32; 4 * 9];
        bad[7] = 16; // == vocab, out of range
        assert!(op.step(&p, &bad).is_err(), "out-of-vocab token must error");
        bad[7] = -1;
        assert!(op.step(&p, &bad).is_err(), "negative token must error");
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        let op = op();
        let a = init_params(&op, 42);
        let b = init_params(&op, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 256);
        assert!(a.iter().all(|x| x.abs() <= 0.02));
        assert_ne!(a, init_params(&op, 43));
    }
}
