//! Random-walk SGD over the simulation engine: a [`VisitHook`] that runs
//! one AOT train step per visit and duplicates model payloads on forks.
//!
//! Token-carries-model semantics (paper Secs. I–II): the model lives in
//! the token; the visited node contributes *data* and *compute*. A fork
//! copies the model, so after failures the surviving/forked lineages carry
//! the accumulated progress — the learning-level payoff of DECAFORK.

use std::sync::Arc;

use crate::learning::corpus::ShardedCorpus;
use crate::learning::ops::{init_params, TrainOp};
use crate::rng::Rng;
use crate::sim::engine::{Engine, VisitHook};
use crate::sim::metrics::Trace;
use crate::sim::CoreBudget;
use crate::walks::{Walk, WalkMut, WalkRef};

/// Per-visit training hook, generic over the train operator (the PJRT
/// executable in production, the pure-Rust [`BigramOp`] in tests and
/// benches).
///
/// [`BigramOp`]: crate::learning::ops::BigramOp
pub struct TrainerHook<'a, O: TrainOp> {
    op: &'a O,
    corpus: Arc<ShardedCorpus>,
    rng: Rng,
    /// Model store: payload index → parameter vector.
    params: Vec<Option<Vec<f32>>>,
    /// (t, walk id, loss) per executed step.
    pub losses: Vec<(u64, u64, f32)>,
    /// Total SGD steps executed.
    pub steps: usize,
    /// Extension (beyond the paper): when two model-carrying walks meet
    /// at a node, average their parameters (gossip-on-meet). The walks
    /// stay independent RWs — only the payloads mix — so Rules 1–3 still
    /// hold (the *node* does the averaging with tokens it currently
    /// holds).
    pub merge_on_meet: bool,
    /// Last known position of each live model-carrying walk.
    walk_pos: std::collections::HashMap<u64, (u32, usize)>,
    /// Number of pairwise merges performed.
    pub merges: usize,
}

impl<'a, O: TrainOp> TrainerHook<'a, O> {
    pub fn new(op: &'a O, corpus: Arc<ShardedCorpus>, seed: u64) -> Self {
        TrainerHook {
            op,
            corpus,
            rng: Rng::new(seed),
            params: Vec::new(),
            losses: Vec::new(),
            steps: 0,
            merge_on_meet: false,
            walk_pos: std::collections::HashMap::new(),
            merges: 0,
        }
    }

    /// Enable gossip-on-meet parameter averaging.
    pub fn with_merge(mut self) -> Self {
        self.merge_on_meet = true;
        self
    }

    /// Allocate a payload slot holding `init` parameters.
    pub fn alloc(&mut self, init: Vec<f32>) -> usize {
        self.params.push(Some(init));
        self.params.len() - 1
    }

    /// Read a payload's parameters.
    pub fn get(&self, idx: usize) -> Option<&Vec<f32>> {
        self.params.get(idx).and_then(|p| p.as_ref())
    }

    /// Smoothed (windowed-mean) loss curve for reporting.
    pub fn loss_curve(&self, window: usize) -> Vec<f64> {
        let xs: Vec<f64> = self.losses.iter().map(|&(_, _, l)| l as f64).collect();
        xs.chunks(window.max(1))
            .map(|c| c.iter().sum::<f64>() / c.len() as f64)
            .collect()
    }
}

impl<O: TrainOp> VisitHook for TrainerHook<'_, O> {
    fn on_visit(&mut self, t: u64, node: u32, walk: WalkMut<'_>) {
        let Some(idx) = *walk.payload else { return };
        // Gossip-on-meet: average with any co-located model first (the
        // position map is updated per visit, so "co-located" means the
        // other walk's latest processed position — an approximation of a
        // true simultaneous meeting; see module docs).
        if self.merge_on_meet {
            let peers: Vec<usize> = self
                .walk_pos
                .iter()
                .filter(|&(&wid, &(pos, _))| wid != walk.id.0 && pos == node)
                .map(|(_, &(_, pidx))| pidx)
                .collect();
            for peer_idx in peers {
                if peer_idx == idx {
                    continue;
                }
                // Split-borrow the two parameter vectors and average.
                if let (Some(mine), Some(theirs)) = {
                    let (lo, hi) = if idx < peer_idx { (idx, peer_idx) } else { (peer_idx, idx) };
                    let (a, b) = self.params.split_at_mut(hi);
                    (a[lo].as_mut(), b[0].as_mut())
                } {
                    for (x, y) in mine.iter_mut().zip(theirs.iter_mut()) {
                        let avg = 0.5 * (*x + *y);
                        *x = avg;
                        *y = avg;
                    }
                    self.merges += 1;
                }
            }
            self.walk_pos.insert(walk.id.0, (node, idx));
        }
        let Some(p) = self.params[idx].take() else { return };
        let tokens =
            self.corpus
                .sample_batch(node as usize, self.op.batch(), self.op.seq(), &mut self.rng);
        match self.op.step(&p, &tokens) {
            Ok((new_p, loss)) => {
                self.params[idx] = Some(new_p);
                self.losses.push((t, walk.id.0, loss));
                self.steps += 1;
            }
            Err(e) => {
                // Put the old params back; surface the error loudly — a
                // failing train step is a bug, not a tolerable condition.
                self.params[idx] = Some(p);
                panic!("train step failed at t={t} node={node}: {e:#}");
            }
        }
    }

    fn on_fork(&mut self, _t: u64, parent: WalkRef, child: WalkMut<'_>) {
        if let Some(pidx) = parent.payload {
            if let Some(p) = self.params[pidx].clone() {
                self.params.push(Some(p));
                *child.payload = Some(self.params.len() - 1);
                if self.merge_on_meet {
                    self.walk_pos.insert(child.id.0, (child.at, self.params.len() - 1));
                }
            }
        }
    }

    fn on_death(&mut self, _t: u64, walk: &Walk) {
        if let Some(idx) = walk.payload {
            // Free the model — the paper's "complete loss of information
            // held by the RW".
            self.params[idx] = None;
        }
        self.walk_pos.remove(&walk.id.0);
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainingSummary {
    pub trace: Trace,
    pub losses: Vec<(u64, u64, f32)>,
    pub steps: usize,
    pub first_loss: f32,
    pub last_loss_mean: f32,
    pub survivors: usize,
    /// Model-mixing rounds: gossip-on-meet merges on the shared-stream
    /// path, barrier parameter-merge rounds on the sharded path (0 when
    /// the respective extension is off).
    pub merges: usize,
    /// Lineage summary of the final walk forest.
    pub lineage: String,
}

impl TrainingSummary {
    /// Assemble a summary from a finished run's raw outputs, deriving
    /// the loss statistics (first loss, mean of the last 20) in one
    /// place for both trainer paths.
    pub fn from_parts(
        trace: Trace,
        losses: Vec<(u64, u64, f32)>,
        steps: usize,
        merges: usize,
        survivors: usize,
        lineage: String,
    ) -> Self {
        let first_loss = losses.first().map(|&(_, _, l)| l).unwrap_or(f32::NAN);
        let tail = losses.len().saturating_sub(20);
        let last_loss_mean = if losses.is_empty() {
            f32::NAN
        } else {
            losses[tail..].iter().map(|&(_, _, l)| l).sum::<f32>() / (losses.len() - tail) as f32
        };
        TrainingSummary {
            trace,
            losses,
            steps,
            first_loss,
            last_loss_mean,
            survivors,
            merges,
            lineage,
        }
    }

    /// FNV digest of the canonical loss stream
    /// ([`loss_digest`](crate::learning::sharded::loss_digest)) — what
    /// the shard-invariance gates compare.
    pub fn loss_digest(&self) -> u64 {
        crate::learning::sharded::loss_digest(&self.losses)
    }
}

/// How a [`TrainingRun`] is executed: which engine family, how many
/// stream workers, and under which core budget. Horizon and seed are
/// *not* options — [`TrainingRun::execute_budgeted`] always takes them
/// from the scenario, so the two can never drift apart.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// `false` = the shared-stream [`Engine`] (the historical path);
    /// `true` = the stream-mode sharded trainer
    /// ([`learning::sharded`](crate::learning::sharded)) — a different
    /// trace family whose results are invariant in the worker count.
    pub stream: bool,
    /// Requested stream workers (a request, like `run_many`'s knobs —
    /// the budget decides what is actually spawned).
    pub shards: usize,
    /// The core budget that plans the actual worker count
    /// ([`planned_workers`](Self::planned_workers)), exactly as
    /// `run_many` budgets replications × shards. Training runs stopped
    /// bypassing the ISSUE 4 budgeting here.
    pub budget: CoreBudget,
    /// Sharded path: barrier parameter-merge period (0 = never).
    pub merge_period: u64,
    /// Shared-stream path: gossip-on-meet parameter averaging.
    pub merge_on_meet: bool,
}

impl TrainOptions {
    /// The stream-worker count the budget actually grants
    /// (`plan(1 run, 1 thread, shards)`) — the single source both the
    /// CLI's announcement and the execution path read, so what is
    /// printed is what is spawned.
    pub fn planned_workers(&self) -> usize {
        self.budget.plan(1, 1, self.shards.max(1)).workers_per_run
    }
}

/// End-to-end training run: wires an [`Engine`] to a [`TrainerHook`],
/// seeds `Z0` identical models, runs to `horizon`.
pub struct TrainingRun;

impl TrainingRun {
    pub fn execute<O: TrainOp>(
        engine: &mut Engine,
        op: &O,
        corpus: Arc<ShardedCorpus>,
        horizon: u64,
        seed: u64,
    ) -> anyhow::Result<TrainingSummary> {
        Self::execute_opts(engine, op, corpus, horizon, seed, false)
    }

    /// `execute` with the gossip-on-meet extension toggled.
    pub fn execute_opts<O: TrainOp>(
        engine: &mut Engine,
        op: &O,
        corpus: Arc<ShardedCorpus>,
        horizon: u64,
        seed: u64,
        merge_on_meet: bool,
    ) -> anyhow::Result<TrainingSummary> {
        crate::learning::ops::validate_corpus(op, &corpus, engine.graph.n())?;
        let mut hook = TrainerHook::new(op, corpus, seed);
        if merge_on_meet {
            hook = hook.with_merge();
        }
        // All Z0 walks start from the same (deterministic) init, as if one
        // node created them (paper footnote 4).
        let init = init_params(op, seed);
        for payload in engine.payloads_mut() {
            // Allocate one payload per initial walk.
            *payload = Some(hook.alloc(init.clone()));
        }
        engine.run_to_with(horizon, &mut hook);
        Ok(TrainingSummary::from_parts(
            engine.trace().clone(),
            std::mem::take(&mut hook.losses),
            hook.steps,
            hook.merges,
            engine.alive() as usize,
            crate::walks::lineage::lineage_summary(&engine.snapshot()),
        ))
    }

    /// The budgeted entry point every `train` surface routes through
    /// (ISSUE 5 satellite): builds the engine itself from the scenario
    /// (which also supplies the horizon and the seed) and plans the
    /// stream-worker count through the [`CoreBudget`] —
    /// [`TrainOptions::planned_workers`] caps workers at the budget, so
    /// a `--shards 64` request on an 8-core box spawns 8 workers, not
    /// 64, and (stream-mode invariance) produces the identical result
    /// either way.
    pub fn execute_budgeted<O: TrainOp>(
        scenario: &crate::scenario::Scenario,
        run: usize,
        op: &O,
        corpus: Arc<ShardedCorpus>,
        opts: &TrainOptions,
    ) -> anyhow::Result<TrainingSummary> {
        // Options that belong to the other path are a misconfiguration
        // for any caller, not just the CLI: reject instead of silently
        // ignoring them.
        anyhow::ensure!(
            opts.stream || opts.merge_period == 0,
            "merge_period is a sharded-trainer option (set stream: true)"
        );
        anyhow::ensure!(
            !(opts.stream && opts.merge_on_meet),
            "merge_on_meet (gossip-on-meet) is a shared-stream option (set stream: false)"
        );
        if opts.stream {
            crate::learning::sharded::train_sharded(
                scenario,
                run,
                op,
                corpus,
                &crate::learning::sharded::ShardedTrainOptions {
                    workers: opts.planned_workers(),
                    horizon: scenario.horizon,
                    seed: scenario.seed,
                    merge_period: opts.merge_period,
                },
            )
        } else {
            let mut engine = scenario.engine(run)?;
            Self::execute_opts(
                &mut engine,
                op,
                corpus,
                scenario.horizon,
                scenario.seed,
                opts.merge_on_meet,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/integration_runtime.rs
    // (they need real artifacts). Here we test the payload bookkeeping
    // with a stub hook exercising the same lifecycle.
    use crate::sim::engine::VisitHook;
    use crate::walks::{Lineage, Walk, WalkId, WalkMut, WalkRef};

    struct StubStore {
        params: Vec<Option<Vec<f32>>>,
    }
    impl VisitHook for StubStore {
        fn on_fork(&mut self, _t: u64, parent: WalkRef, child: WalkMut<'_>) {
            if let Some(p) = parent.payload.and_then(|i| self.params[i].clone()) {
                self.params.push(Some(p));
                *child.payload = Some(self.params.len() - 1);
            }
        }
        fn on_death(&mut self, _t: u64, w: &Walk) {
            if let Some(i) = w.payload {
                self.params[i] = None;
            }
        }
    }

    fn walk(id: u64, payload: Option<usize>) -> Walk {
        Walk {
            id: WalkId(id),
            lineage: Lineage::Original { slot: 0 },
            at: 0,
            alive: true,
            born: 0,
            died: None,
            payload,
        }
    }

    #[test]
    fn fork_clones_payload_death_frees_it() {
        let mut store = StubStore { params: vec![Some(vec![1.0, 2.0])] };
        let parent = walk(0, Some(0));
        let mut child = walk(1, None);
        store.on_fork(5, WalkRef::from(&parent), WalkMut::from(&mut child));
        assert_eq!(child.payload, Some(1));
        assert_eq!(store.params[1].as_deref(), Some(&[1.0, 2.0][..]));
        store.on_death(6, &parent);
        assert!(store.params[0].is_none());
        assert!(store.params[1].is_some(), "child payload must survive parent death");
    }
}
