//! Named training workloads: a simulation [`Scenario`] (graph, control,
//! failures — from `scenario::presets`) bundled with the learning-side
//! knobs a run needs (corpus size, vocab, batch shape, learning rate,
//! merge period). One name, one workload — the CLI (`train --preset`),
//! `benches/perf_learn.rs`, the shard-invariance tests and CI's learn
//! smoke all resolve the same spec.

use crate::learning::corpus::ShardedCorpus;
use crate::learning::ops::BigramOp;
use crate::scenario::{presets, Scenario};

/// A complete training workload description.
#[derive(Debug, Clone)]
pub struct LearnSpec {
    pub name: &'static str,
    pub scenario: Scenario,
    /// Tokens generated per node shard (must exceed `seq + 1`).
    pub tokens_per_node: usize,
    pub vocab: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f32,
    /// Default barrier parameter-merge period (0 = never; the CLI's
    /// `--merge-every` overrides it).
    pub merge_period: u64,
}

impl LearnSpec {
    /// Node count of the scenario's graph spec.
    pub fn n_nodes(&self) -> usize {
        self.scenario.graph.nodes()
    }

    /// Generate the workload's corpus (deterministic in the scenario
    /// seed; one shard per graph node).
    pub fn corpus(&self) -> ShardedCorpus {
        ShardedCorpus::markov(
            self.n_nodes(),
            self.tokens_per_node,
            self.vocab,
            self.scenario.seed ^ 0xC0FFEE,
        )
    }

    /// The pure-Rust train operator for this workload.
    pub fn op(&self) -> BigramOp {
        BigramOp::new(self.vocab, self.batch, self.seq, self.lr)
    }
}

/// Resolve a preset by name (`learn_tiny`, `learn_10k`, `learn_100k`).
pub fn by_name(name: &str) -> Option<LearnSpec> {
    match name {
        "learn_tiny" => Some(learn_tiny()),
        "learn_10k" => Some(learn_10k()),
        "learn_100k" => Some(learn_100k()),
        _ => None,
    }
}

/// Smoke-sized workload: 64 nodes, 8 walks, one burst. Small enough for
/// a unit test, big enough that forks, deaths and payload handoff all
/// fire. CI's learn-smoke step runs it at shards 1 and 4 and diffs the
/// loss digest.
pub fn learn_tiny() -> LearnSpec {
    LearnSpec {
        name: "learn_tiny",
        scenario: presets::learn_tiny_scenario(),
        tokens_per_node: 512,
        vocab: 16,
        batch: 4,
        seq: 8,
        lr: 0.3,
        merge_period: 50,
    }
}

/// The `perf_learn` workload: 10k nodes / 512 walks (see
/// `scenario::presets::learn_10k` for the simulation-side tuning). The
/// bigram batch (16 × 32 pairs over a 64-symbol vocab) makes the SGD
/// work dominate the simulation step — the regime where sharding the
/// control phase pays.
pub fn learn_10k() -> LearnSpec {
    LearnSpec {
        name: "learn_10k",
        scenario: presets::learn_10k(),
        tokens_per_node: 2048,
        vocab: 64,
        batch: 16,
        seq: 32,
        lr: 0.1,
        merge_period: 100,
    }
}

/// Training at `scale_100k` size: 100k nodes / 4096 model-carrying
/// walks. Tokens per node are kept small (256 ≈ 100 MB of corpus total)
/// — per-node data scarcity is the realistic regime at this scale, and
/// each node still holds far more than one batch window.
pub fn learn_100k() -> LearnSpec {
    LearnSpec {
        name: "learn_100k",
        scenario: presets::learn_100k(),
        tokens_per_node: 256,
        vocab: 64,
        batch: 16,
        seq: 32,
        lr: 0.1,
        merge_period: 100,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::ops::TrainOp;

    #[test]
    fn presets_resolve_by_name_and_are_consistent() {
        for name in ["learn_tiny", "learn_10k", "learn_100k"] {
            let spec = by_name(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(
                spec.tokens_per_node > spec.seq + 1,
                "{name}: corpus shards too small for the batch window"
            );
            assert!(spec.vocab >= 4);
            assert_eq!(spec.op().param_count(), spec.vocab * spec.vocab);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn tiny_corpus_builds_and_matches_graph() {
        let spec = learn_tiny();
        let corpus = spec.corpus();
        assert_eq!(corpus.n_nodes(), spec.n_nodes());
        assert_eq!(corpus.vocab, spec.vocab);
        // Deterministic in the scenario seed.
        assert_eq!(corpus.shard(3), spec.corpus().shard(3));
    }

    #[test]
    fn scale_specs_stay_affordable() {
        // learn_100k's corpus must not regress into the GB regime: the
        // whole point of tokens_per_node = 256 is ~100 MB total.
        let spec = learn_100k();
        let bytes = spec.n_nodes() * spec.tokens_per_node * std::mem::size_of::<i32>();
        assert!(bytes <= 128 << 20, "learn_100k corpus ballooned to {bytes} bytes");
        assert!(spec.n_nodes() == 100_000);
        assert_eq!(learn_10k().n_nodes(), 10_000);
    }
}
