//! RW-SGD on the stream-mode [`ShardedEngine`]: the learning layer's
//! [`ShardHook`] implementation, which is what finally lets the paper's
//! motivating application — token-carries-model decentralized training —
//! run at the `scale_100k`-class sizes the sharded engine simulates.
//!
//! ## How the trainer maps onto the hook protocol
//!
//! * **Models ride walks** exactly as in the shared-stream
//!   [`TrainerHook`](crate::learning::TrainerHook): `params[idx]` is the
//!   model of the walk whose payload slot holds `idx`; forks clone it,
//!   deaths free it.
//! * **Visits are shard-parallel.** During the control phase, shard `k`'s
//!   [`TrainerShard`] replica handles the arrivals at its node range:
//!   it samples a batch from the visited node's corpus shard on the
//!   node's own learning stream (`rng::streams::LEARN` — per-node
//!   ownership is what makes the sample sequence independent of call
//!   interleaving), runs the [`TrainOp`] on the walk's current model
//!   (read-only through the shared hook), and queues the result as a
//!   **delta** `(dense, walk, new params, loss)`. Every walk arrives at
//!   exactly one node per step, so no model is read by two shards.
//! * **Deltas merge at the barrier.** [`ShardHook::merge`] combines the
//!   replicas' deltas sorted by the visiting walk's dense index — the
//!   canonical order — before the engine applies fork decisions, so a
//!   forking parent hands its child the *post-visit* model and the loss
//!   stream `losses` is bit-identical at every shard count
//!   (`tests/learning_sharded.rs` locks shards 1/2/8).
//! * **Periodic parameter merge** (`merge_period`): every that many
//!   steps, at the end-of-step barrier, all live models are averaged in
//!   dense order — the decentralized consensus step the multi-stream
//!   RW-learning literature (Gholami & Seferoglu 2024; Ayache et al.)
//!   alternates with local SGD. Fixed-order f32 summation keeps the
//!   average bit-identical across shard counts. `0` disables it.
//!
//! The trainer never touches simulation state (the hook protocol gives
//! it no handle to do so), so attaching it cannot move a single trace
//! bit: θ̂ telemetry, both golden families and the frozen reference are
//! untouched by construction.

use std::sync::Arc;

use crate::learning::corpus::ShardedCorpus;
use crate::learning::ops::{init_params, TrainOp};
use crate::learning::rwsgd::TrainingSummary;
use crate::rng::{streams, Rng};
use crate::scenario::Scenario;
use crate::sim::shard_hook::{ShardHook, ShardVisit};
use crate::walks::{Walk, WalkArena, WalkId, WalkMut, WalkRef};

/// FNV-1a digest of a canonical loss stream — the compact fingerprint
/// the shard-invariance tests, `benches/perf_learn.rs` and CI's learn
/// smoke compare. Folds every `(t, walk id, loss bits)` triple, so two
/// digests agree iff the streams are bit-identical and equally ordered.
pub fn loss_digest(losses: &[(u64, u64, f32)]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |x: u64| {
        for byte in x.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for &(t, walk, loss) in losses {
        mix(t);
        mix(walk);
        mix(loss.to_bits() as u64);
    }
    h
}

/// A queued visit result: computed in the parallel phase, applied at the
/// barrier in dense order.
struct VisitDelta {
    /// Dense position of the visiting walk — the canonical merge key.
    dense: u32,
    walk: u64,
    node: u32,
    /// Payload (model) index the result belongs to.
    idx: usize,
    result: anyhow::Result<(Vec<f32>, f32)>,
}

/// Per-shard replica: the node-range's learning streams plus this step's
/// delta queue. Everything here is shard-local; the shared model store
/// lives in the [`ShardedTrainer`] and is read-only during phases.
pub struct TrainerShard {
    /// One learning stream per owned node (indexed by the engine's
    /// shard-local node index), derived per *node id* — the same stream
    /// regardless of how many shards the run uses.
    node_rngs: Vec<Rng>,
    deltas: Vec<VisitDelta>,
}

/// The sharded RW-SGD trainer. See the module docs for the data flow;
/// drive it with [`ShardedEngine::run_to_with`] or the
/// [`train_sharded`] entry point.
///
/// [`ShardedEngine::run_to_with`]: crate::sim::sharded::ShardedEngine::run_to_with
pub struct ShardedTrainer<'a, O: TrainOp> {
    op: &'a O,
    corpus: Arc<ShardedCorpus>,
    /// Root of the per-node learning streams (`derive(LEARN, node)`).
    learn_root: Rng,
    /// Average all live models every this many steps (0 = never).
    merge_period: u64,
    /// Model store: payload index → parameter vector.
    params: Vec<Option<Vec<f32>>>,
    /// (t, walk id, loss) per executed step, in canonical order.
    pub losses: Vec<(u64, u64, f32)>,
    /// Total SGD steps executed.
    pub steps: usize,
    /// Parameter-merge rounds performed at the barrier.
    pub merge_rounds: usize,
}

impl<'a, O: TrainOp> ShardedTrainer<'a, O> {
    pub fn new(op: &'a O, corpus: Arc<ShardedCorpus>, seed: u64) -> Self {
        ShardedTrainer {
            op,
            corpus,
            learn_root: Rng::new(seed),
            merge_period: 0,
            params: Vec::new(),
            losses: Vec::new(),
            steps: 0,
            merge_rounds: 0,
        }
    }

    /// Enable the periodic barrier parameter merge (`every >= 1` steps).
    pub fn with_merge_period(mut self, every: u64) -> Self {
        self.merge_period = every;
        self
    }

    /// Allocate a payload slot holding `init` parameters.
    pub fn alloc(&mut self, init: Vec<f32>) -> usize {
        self.params.push(Some(init));
        self.params.len() - 1
    }

    /// Read a payload's parameters.
    pub fn get(&self, idx: usize) -> Option<&Vec<f32>> {
        self.params.get(idx).and_then(|p| p.as_ref())
    }

    /// Digest of the canonical loss stream ([`loss_digest`]).
    pub fn digest(&self) -> u64 {
        loss_digest(&self.losses)
    }
}

impl<O: TrainOp> ShardHook for ShardedTrainer<'_, O> {
    type Replica = TrainerShard;

    fn replicas(
        &mut self,
        shards: usize,
        nodes_per_shard: usize,
        n_nodes: usize,
    ) -> Vec<TrainerShard> {
        (0..shards)
            .map(|k| {
                let lo = (k * nodes_per_shard).min(n_nodes);
                let hi = ((k + 1) * nodes_per_shard).min(n_nodes);
                TrainerShard {
                    node_rngs: (lo..hi)
                        .map(|i| self.learn_root.derive(streams::LEARN, i as u64))
                        .collect(),
                    deltas: Vec::new(),
                }
            })
            .collect()
    }

    fn on_shard_visit(&self, rep: &mut TrainerShard, _t: u64, visit: &ShardVisit) {
        let Some(idx) = visit.payload else { return };
        let Some(p) = self.params.get(idx).and_then(|p| p.as_ref()) else { return };
        let tokens = self.corpus.sample_batch(
            visit.node as usize,
            self.op.batch(),
            self.op.seq(),
            &mut rep.node_rngs[visit.local as usize],
        );
        rep.deltas.push(VisitDelta {
            dense: visit.dense,
            walk: visit.walk.0,
            node: visit.node,
            idx,
            result: self.op.step(p, &tokens),
        });
    }

    fn merge(&mut self, t: u64, replicas: &mut [TrainerShard]) -> anyhow::Result<()> {
        let total: usize = replicas.iter().map(|r| r.deltas.len()).sum();
        if total == 0 {
            return Ok(());
        }
        let mut merged = Vec::with_capacity(total);
        for r in replicas.iter_mut() {
            merged.append(&mut r.deltas);
        }
        // Dense indices are unique within a step (each walk visits one
        // node once), so this total order is exactly the shards = 1
        // processing order.
        merged.sort_unstable_by_key(|d| d.dense);
        for d in merged {
            let (new_p, loss) = d.result.map_err(|e| {
                e.context(format!(
                    "train step failed at t={t} node={} walk={}",
                    d.node,
                    WalkId(d.walk)
                ))
            })?;
            self.params[d.idx] = Some(new_p);
            self.losses.push((t, d.walk, loss));
            self.steps += 1;
        }
        Ok(())
    }

    fn on_fork(&mut self, _t: u64, parent: WalkRef, child: WalkMut<'_>) {
        // The child inherits a copy of the parent's *post-visit* model
        // (merge ran first) — the walk-payload handoff the paper's
        // resilience story depends on.
        if let Some(pidx) = parent.payload {
            if let Some(p) = self.params[pidx].clone() {
                self.params.push(Some(p));
                *child.payload = Some(self.params.len() - 1);
            }
        }
    }

    fn on_death(&mut self, _t: u64, walk: &Walk) {
        if let Some(idx) = walk.payload {
            // The paper's "complete loss of information held by the RW".
            self.params[idx] = None;
        }
    }

    fn end_step(&mut self, t: u64, arena: &WalkArena) -> anyhow::Result<()> {
        if self.merge_period == 0 || t % self.merge_period != 0 {
            return Ok(());
        }
        // Average every live model, iterating walks in dense (creation)
        // order — the fixed summation order that keeps the result
        // bit-identical at every shard count.
        let mut idxs = Vec::new();
        for i in 0..arena.dense_len() {
            if let Some(idx) = arena.payload_at(i) {
                if self.params[idx].is_some() {
                    idxs.push(idx);
                }
            }
        }
        if idxs.len() < 2 {
            return Ok(());
        }
        let plen = self.op.param_count();
        let mut acc = vec![0f32; plen];
        for &idx in &idxs {
            let p = self.params[idx].as_ref().expect("filtered to Some above");
            anyhow::ensure!(
                p.len() == plen,
                "model {idx} has {} params, op expects {plen}",
                p.len()
            );
            for (a, &x) in acc.iter_mut().zip(p) {
                *a += x;
            }
        }
        let inv = 1.0 / idxs.len() as f32;
        for a in &mut acc {
            *a *= inv;
        }
        // Write the average in place: every target is an already-sized
        // buffer (ensured above), so no per-model reallocation.
        for &idx in &idxs {
            self.params[idx].as_mut().expect("filtered to Some above").copy_from_slice(&acc);
        }
        self.merge_rounds += 1;
        Ok(())
    }
}

/// Options for [`train_sharded`]. `workers` is the engine's actual
/// thread count — already planned through
/// [`CoreBudget`](crate::sim::CoreBudget) by budgeted callers
/// ([`TrainingRun::execute_budgeted`](crate::learning::TrainingRun::execute_budgeted))
/// — and, like everywhere in stream mode, cannot affect any result bit.
#[derive(Debug, Clone)]
pub struct ShardedTrainOptions {
    pub workers: usize,
    pub horizon: u64,
    pub seed: u64,
    /// Barrier parameter-merge period (0 = never).
    pub merge_period: u64,
}

/// End-to-end sharded training run: builds the scenario's stream-mode
/// engine with `opts.workers` threads, seeds one model per initial walk,
/// runs to the horizon through the hook protocol and summarizes.
pub fn train_sharded<O: TrainOp>(
    scenario: &Scenario,
    run: usize,
    op: &O,
    corpus: Arc<ShardedCorpus>,
    opts: &ShardedTrainOptions,
) -> anyhow::Result<TrainingSummary> {
    // Validate against the spec'd node count before paying for the
    // graph build — at learn_100k scale that build is seconds of work a
    // misconfigured corpus should not waste.
    crate::learning::ops::validate_corpus(op, &corpus, scenario.graph.nodes())?;
    let mut engine = scenario.sharded_engine(run, opts.workers)?;
    let mut trainer =
        ShardedTrainer::new(op, corpus, opts.seed).with_merge_period(opts.merge_period);
    let init = init_params(op, opts.seed);
    for payload in engine.payloads_mut() {
        *payload = Some(trainer.alloc(init.clone()));
    }
    engine.run_to_with(opts.horizon, &mut trainer)?;
    Ok(TrainingSummary::from_parts(
        engine.trace().clone(),
        std::mem::take(&mut trainer.losses),
        trainer.steps,
        trainer.merge_rounds,
        engine.alive() as usize,
        crate::walks::lineage::lineage_summary(&engine.snapshot()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::presets;

    #[test]
    fn trainer_learns_on_the_tiny_preset() {
        let spec = presets::learn_tiny();
        let op = spec.op();
        let corpus = Arc::new(spec.corpus());
        let s = train_sharded(
            &spec.scenario,
            0,
            &op,
            corpus,
            &ShardedTrainOptions {
                workers: 2,
                horizon: spec.scenario.horizon,
                seed: 7,
                merge_period: 0,
            },
        )
        .unwrap();
        assert!(s.steps > 200, "too few SGD steps: {}", s.steps);
        assert!(s.survivors >= 1);
        assert!(
            s.last_loss_mean < s.first_loss,
            "no learning progress: {} -> {}",
            s.first_loss,
            s.last_loss_mean
        );
        assert!(s.lineage.contains("living walks"), "{}", s.lineage);
    }

    #[test]
    fn periodic_merge_equalizes_live_models() {
        // With merge_period = 1 the barrier averages after every step, so
        // at the end every live model is bit-identical.
        let spec = presets::learn_tiny();
        let op = spec.op();
        let corpus = Arc::new(spec.corpus());
        let mut engine = spec.scenario.sharded_engine(0, 3).unwrap();
        let mut trainer = ShardedTrainer::new(&op, corpus, 5).with_merge_period(1);
        let init = init_params(&op, 5);
        for payload in engine.payloads_mut() {
            *payload = Some(trainer.alloc(init.clone()));
        }
        engine.run_to_with(spec.scenario.horizon, &mut trainer).unwrap();
        assert!(trainer.merge_rounds > 0, "merge never fired");
        let snap = engine.snapshot();
        let live: Vec<&Vec<f32>> = snap
            .iter()
            .filter(|w| w.alive)
            .filter_map(|w| w.payload.and_then(|i| trainer.get(i)))
            .collect();
        assert!(live.len() >= 2, "need at least two live models to check the merge");
        for p in &live[1..] {
            assert!(
                live[0].iter().zip(p.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "live models diverged despite a per-step parameter merge"
            );
        }
    }

    #[test]
    fn digest_discriminates_and_matches_equal_streams() {
        let a = vec![(1u64, 0u64, 0.5f32), (2, 1, 0.25)];
        let mut b = a.clone();
        assert_eq!(loss_digest(&a), loss_digest(&b));
        b[1].2 = f32::from_bits(b[1].2.to_bits() + 1);
        assert_ne!(loss_digest(&a), loss_digest(&b), "one-ulp loss change must change the digest");
        let swapped = vec![a[1], a[0]];
        assert_ne!(loss_digest(&a), loss_digest(&swapped), "order must matter");
    }
}
