//! The paper's motivating application: decentralized learning where the
//! walk token *is* the model. Every node holds a shard of the corpus; a
//! visiting walk runs one SGD step on the visited node's data through the
//! AOT-compiled JAX/Pallas train-step executable ([`crate::runtime`]),
//! then moves on. Forks duplicate the model, so a surviving lineage keeps
//! the training progress — resilience in the learning sense.

pub mod corpus;
pub mod rwsgd;

pub use corpus::ShardedCorpus;
pub use rwsgd::{TrainerHook, TrainingRun, TrainingSummary};
