//! The paper's motivating application: decentralized learning where the
//! walk token *is* the model. Every node holds a shard of the corpus; a
//! visiting walk runs one SGD step on the visited node's data — through
//! the AOT-compiled JAX/Pallas train-step executable ([`crate::runtime`])
//! or the pure-Rust [`BigramOp`] — then moves on. Forks duplicate the
//! model, so a surviving lineage keeps the training progress —
//! resilience in the learning sense.
//!
//! Two execution paths, one [`TrainOp`] operator abstraction:
//!
//! * [`rwsgd`] — the shared-stream [`Engine`](crate::sim::Engine) +
//!   [`VisitHook`](crate::sim::VisitHook) path (sequential visits);
//! * [`sharded`] — RW-SGD on the stream-mode
//!   [`ShardedEngine`](crate::sim::ShardedEngine) via the per-shard
//!   [`ShardHook`](crate::sim::ShardHook) protocol: shard-parallel SGD
//!   with a deterministic barrier merge, bit-identical at every worker
//!   count (`learn_10k`/`learn_100k` presets, `benches/perf_learn.rs`).
//!
//! [`TrainingRun::execute_budgeted`] is the front door: it picks the
//! path and plans worker counts through the session
//! [`CoreBudget`](crate::sim::CoreBudget).

pub mod corpus;
pub mod ops;
pub mod presets;
pub mod rwsgd;
pub mod sharded;

pub use corpus::ShardedCorpus;
pub use ops::{init_params, validate_corpus, BigramOp, PjrtOp, TrainOp};
pub use presets::LearnSpec;
pub use rwsgd::{TrainOptions, TrainerHook, TrainingRun, TrainingSummary};
pub use sharded::{loss_digest, train_sharded, ShardedTrainOptions, ShardedTrainer};
