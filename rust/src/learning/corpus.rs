//! Synthetic char-level corpus with learnable structure, sharded across
//! graph nodes.
//!
//! The paper does not fix a dataset; what matters to the system is that
//! each node owns local data and that the loss is learnable. We generate
//! text from a deterministic order-1 Markov chain over a small alphabet
//! whose transition matrix is sparse and sharply peaked — cross-entropy of
//! a converged model is far below the uniform `ln V`, so learning progress
//! is visible within a few hundred steps (see EXPERIMENTS.md).

use crate::rng::Rng;

/// A token corpus split into per-node shards.
#[derive(Debug, Clone)]
pub struct ShardedCorpus {
    /// One token stream per node.
    shards: Vec<Vec<i32>>,
    pub vocab: usize,
}

impl ShardedCorpus {
    /// Generate `tokens_per_node` tokens for each of `n_nodes` shards from
    /// a shared Markov chain (seeded by `seed`). All shards follow the
    /// same language, as in i.i.d.-data decentralized learning.
    pub fn markov(n_nodes: usize, tokens_per_node: usize, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 4);
        let mut chain_rng = Rng::new(seed);
        // Sparse peaked transition table: each symbol has 3 likely
        // successors (70/20/10).
        let succ: Vec<[usize; 3]> = (0..vocab)
            .map(|_| {
                [
                    chain_rng.below(vocab),
                    chain_rng.below(vocab),
                    chain_rng.below(vocab),
                ]
            })
            .collect();
        let shards = (0..n_nodes)
            .map(|node| {
                let mut rng = Rng::new(seed ^ 0x5348_4152).split(node as u64);
                let mut tok = rng.below(vocab);
                let mut out = Vec::with_capacity(tokens_per_node);
                for _ in 0..tokens_per_node {
                    out.push(tok as i32);
                    let u = rng.f64();
                    let s = &succ[tok];
                    tok = if u < 0.7 {
                        s[0]
                    } else if u < 0.9 {
                        s[1]
                    } else if u < 0.97 {
                        s[2]
                    } else {
                        rng.below(vocab)
                    };
                }
                out
            })
            .collect();
        ShardedCorpus { shards, vocab }
    }

    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, node: usize) -> &[i32] {
        &self.shards[node]
    }

    /// Sample a `(batch, seq+1)` token matrix (inputs + next-token
    /// targets) from node `node`'s shard, flattened row-major.
    pub fn sample_batch(&self, node: usize, batch: usize, seq: usize, rng: &mut Rng) -> Vec<i32> {
        let shard = &self.shards[node];
        assert!(shard.len() > seq + 1, "shard too small for seq {seq}");
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = rng.below(shard.len() - seq - 1);
            out.extend_from_slice(&shard[start..start + seq + 1]);
        }
        out
    }

    /// Entropy rate proxy: empirical bigram conditional entropy of a
    /// shard (nats). A learnable corpus has this well below `ln(vocab)`.
    pub fn bigram_entropy(&self, node: usize) -> f64 {
        let shard = &self.shards[node];
        let v = self.vocab;
        let mut counts = vec![0u64; v * v];
        let mut row = vec![0u64; v];
        for w in shard.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1;
            row[w[0] as usize] += 1;
        }
        let total: u64 = row.iter().sum();
        let mut h = 0.0;
        for a in 0..v {
            if row[a] == 0 {
                continue;
            }
            let pa = row[a] as f64 / total as f64;
            for b in 0..v {
                let c = counts[a * v + b];
                if c == 0 {
                    continue;
                }
                let p = c as f64 / row[a] as f64;
                h -= pa * p * p.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_range() {
        let c = ShardedCorpus::markov(4, 1000, 32, 7);
        assert_eq!(c.n_nodes(), 4);
        for node in 0..4 {
            assert_eq!(c.shard(node).len(), 1000);
            assert!(c.shard(node).iter().all(|&t| (0..32).contains(&t)));
        }
    }

    #[test]
    fn batches_are_windows_of_the_shard() {
        let c = ShardedCorpus::markov(2, 500, 16, 1);
        let mut rng = Rng::new(3);
        let b = c.sample_batch(1, 4, 8, &mut rng);
        assert_eq!(b.len(), 4 * 9);
        // Each row must appear contiguously in the shard.
        let shard = c.shard(1);
        for row in b.chunks(9) {
            let found = shard.windows(9).any(|w| w == row);
            assert!(found, "batch row not a shard window");
        }
    }

    #[test]
    fn corpus_is_learnable() {
        let c = ShardedCorpus::markov(1, 50_000, 32, 11);
        let h = c.bigram_entropy(0);
        let uniform = (32f64).ln();
        assert!(h < 0.55 * uniform, "bigram entropy {h:.3} vs uniform {uniform:.3}");
        assert!(h > 0.2, "degenerate corpus");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ShardedCorpus::markov(2, 100, 16, 5);
        let b = ShardedCorpus::markov(2, 100, 16, 5);
        assert_eq!(a.shard(0), b.shard(0));
        let c = ShardedCorpus::markov(2, 100, 16, 6);
        assert_ne!(a.shard(0), c.shard(0));
    }

    #[test]
    fn sample_batch_deterministic_under_call_interleaving() {
        // The property the sharded trainer's schedule invariance rests
        // on: with per-node streams (rng::streams::LEARN), node k's n-th
        // batch is a pure function of (corpus, node, stream, n) — the
        // order in which *other* nodes' batches are drawn is irrelevant,
        // so shard workers can interleave calls freely.
        use crate::rng::streams;
        let c = ShardedCorpus::markov(3, 500, 16, 9);
        let root = Rng::new(77);
        let draw = |order: &[usize]| -> Vec<(usize, Vec<i32>)> {
            let mut rngs: Vec<Rng> =
                (0..3).map(|i| root.derive(streams::LEARN, i as u64)).collect();
            order
                .iter()
                .map(|&node| (node, c.sample_batch(node, 4, 8, &mut rngs[node])))
                .collect()
        };
        // Sequential per node vs fully interleaved: per (node, call
        // index) the batches must be identical.
        let seq = draw(&[0, 0, 1, 1, 2, 2]);
        let inter = draw(&[2, 0, 1, 0, 1, 2]);
        let nth = |set: &[(usize, Vec<i32>)], node: usize, k: usize| -> Vec<i32> {
            set.iter().filter(|(n, _)| *n == node).nth(k).unwrap().1.clone()
        };
        for node in 0..3 {
            for k in 0..2 {
                assert_eq!(
                    nth(&seq, node, k),
                    nth(&inter, node, k),
                    "node {node} batch {k} depends on call interleaving"
                );
            }
        }
        // And a fixed seed reproduces the exact batches.
        assert_eq!(draw(&[0, 1, 2]), draw(&[0, 1, 2]));
    }

    #[test]
    fn shards_differ_but_share_language() {
        let c = ShardedCorpus::markov(2, 20_000, 16, 5);
        assert_ne!(c.shard(0), c.shard(1));
        let h0 = c.bigram_entropy(0);
        let h1 = c.bigram_entropy(1);
        assert!((h0 - h1).abs() < 0.15, "shards should share statistics: {h0} vs {h1}");
    }
}
