//! Maximum-likelihood fits for the return/hitting-time relaxations of
//! Assumption 1 (exponential in continuous time, geometric in discrete
//! time), plus goodness-of-fit helpers. DECAFORK can run with the
//! empirical survival function (default) or with an analytic fit to speed
//! up the initialization phase (paper footnote 5); these fits provide the
//! parameters.

use super::ecdf::EmpiricalCdf;

/// Exponential(λ) MLE from samples: λ̂ = 1 / mean.
pub fn fit_exponential(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let m = samples.iter().sum::<f64>() / samples.len() as f64;
    assert!(m > 0.0, "non-positive mean");
    1.0 / m
}

/// Geometric(q) MLE on support {1,2,…}: q̂ = 1 / mean.
pub fn fit_geometric(samples: &[u32]) -> f64 {
    assert!(!samples.is_empty());
    let m = samples.iter().map(|&x| x as f64).sum::<f64>() / samples.len() as f64;
    assert!(m >= 1.0, "geometric samples must be >= 1");
    1.0 / m
}

/// Geometric fit straight from an [`EmpiricalCdf`].
pub fn fit_geometric_ecdf(e: &EmpiricalCdf) -> f64 {
    let m = e.mean();
    assert!(m.is_finite() && m >= 1.0, "need non-empty ecdf with mean >= 1");
    1.0 / m
}

/// Survival function of Exponential(λ): `exp(−λ x)`.
#[inline]
pub fn exp_survival(lambda: f64, x: f64) -> f64 {
    (-lambda * x).exp()
}

/// Survival function of Geometric(q) on {1,2,…}: `(1−q)^x` = Pr(R > x).
#[inline]
pub fn geom_survival(q: f64, x: u32) -> f64 {
    (1.0 - q).powi(x as i32)
}

/// The paper's Sec. IV-A expectation of `S(r)` when R is geometric(q)
/// evaluated at an independent copy of itself:
/// `E[S(R)] = Σ_r (1−q)^{2r−1} q = (1−q)/(2−q)` — the discrete-time bias
/// away from ½ that Proposition 1 quantifies.
pub fn geom_self_survival_mean(q: f64) -> f64 {
    (1.0 - q) / (2.0 - q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn exponential_fit_recovers_lambda() {
        let mut rng = Rng::new(1);
        let lambda = 0.02;
        let xs: Vec<f64> = (0..100_000).map(|_| rng.exponential(lambda)).collect();
        let est = fit_exponential(&xs);
        assert!((est - lambda).abs() / lambda < 0.02, "est {est}");
    }

    #[test]
    fn geometric_fit_recovers_q() {
        let mut rng = Rng::new(2);
        let q = 0.01;
        let xs: Vec<u32> = (0..200_000).map(|_| rng.geometric(q) as u32).collect();
        let est = fit_geometric(&xs);
        assert!((est - q).abs() / q < 0.03, "est {est}");
    }

    #[test]
    fn ecdf_fit_agrees_with_slice_fit() {
        let mut rng = Rng::new(3);
        let mut e = EmpiricalCdf::new();
        let mut v = Vec::new();
        for _ in 0..50_000 {
            let x = rng.geometric(0.05) as u32;
            e.add(x);
            v.push(x);
        }
        assert!((fit_geometric_ecdf(&e) - fit_geometric(&v)).abs() < 1e-12);
    }

    #[test]
    fn survival_functions() {
        assert!((exp_survival(0.5, 0.0) - 1.0).abs() < 1e-12);
        assert!((exp_survival(0.5, 2.0) - (-1.0f64).exp()).abs() < 1e-12);
        assert!((geom_survival(0.1, 0) - 1.0).abs() < 1e-12);
        assert!((geom_survival(0.1, 2) - 0.81).abs() < 1e-12);
    }

    #[test]
    fn self_survival_mean_bias() {
        // For small q the bias is tiny (≈ 0.5 − q/4), for large q severe.
        assert!((geom_self_survival_mean(0.01) - 0.4975).abs() < 1e-3);
        assert!((geom_self_survival_mean(1.0) - 0.0).abs() < 1e-12);
        // Monte-Carlo check of E[S(R)] = (1-q)/(2-q).
        let mut rng = Rng::new(4);
        let q = 0.2;
        let trials = 200_000;
        let mean: f64 = (0..trials)
            .map(|_| geom_survival(q, rng.geometric(q) as u32))
            .sum::<f64>()
            / trials as f64;
        assert!((mean - geom_self_survival_mean(q)).abs() < 0.005, "mean {mean}");
    }
}
