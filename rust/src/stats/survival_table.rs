//! Lazy per-node memo `dt → S(dt)` for the DECAFORK estimator.
//!
//! Eq. (1) evaluates one survival value per known walk on **every**
//! control decision. The survival function itself is cheap to describe
//! but not to compute: a transcendental `exp` per term for the analytic
//! models, a cached-CDF lookup with a division for the empirical one.
//! At production walk counts (Z0 = 256+) that arithmetic dominates the
//! whole step loop (DESIGN.md §Perf iteration 6).
//!
//! The fix is a memo table indexed by the integer elapsed time `dt`:
//! each θ̂ term becomes one bounds-checked load, with the expensive
//! computation run once per distinct `dt` per invalidation epoch. The
//! table stays small and hot because [`NodeState::prune`] bounds the
//! `dt` of live last-seen entries to the survival horizon (plus at most
//! one prune interval of slack).
//!
//! ## Determinism contract
//!
//! The table stores **exactly** the `f64` the direct code path would
//! have produced — the fill closure *is* the direct computation, called
//! on miss — so a memoised θ̂ sum is bit-identical to the uncached one.
//! That only holds while the underlying survival function does not
//! change; the owner must [`sync`](SurvivalTable::sync) the table with
//! an epoch that advances whenever the function's observable values can
//! change:
//!
//! * analytic models (geometric / exponential): parameters are fixed at
//!   construction, the function is pure — the epoch never advances and
//!   the table is never cleared;
//! * empirical model: the observable values of
//!   [`EmpiricalCdf::survival`](crate::stats::EmpiricalCdf::survival)
//!   change only at lazy cache rebuilds (and, before the first rebuild,
//!   on every insert) — [`EmpiricalCdf::survival_epoch`] encodes exactly
//!   that, see the invariants note in `DESIGN.md` §Survival cache.
//!
//! [`NodeState::prune`]: crate::walks::NodeState::prune
//! [`EmpiricalCdf::survival_epoch`]: crate::stats::EmpiricalCdf::survival_epoch

/// Memoised survival values for one node, indexed by elapsed time `dt`.
///
/// `f64::NAN` marks an unfilled slot (survival values are probabilities
/// in `[0, 1]`, never NaN). Entries beyond [`Self::MAX_DT`] are not
/// memoised — the fill closure runs every time — so pathological `dt`
/// ranges (prune disabled, huge horizons) cost compute, never memory.
#[derive(Debug, Clone, Default)]
pub struct SurvivalTable {
    values: Vec<f64>,
    epoch: u64,
}

impl SurvivalTable {
    /// Largest memoised `dt` (exclusive). 2¹⁶ entries = 512 KiB/node
    /// worst case, far beyond any pruned table's live `dt` range.
    pub const MAX_DT: usize = 1 << 16;

    /// Empty table, valid for epoch 0 (the pristine epoch — real epochs
    /// from [`EmpiricalCdf::survival_epoch`] are never 0, so the first
    /// sync of an empirical table always clears the — empty — memo).
    ///
    /// [`EmpiricalCdf::survival_epoch`]: crate::stats::EmpiricalCdf::survival_epoch
    pub fn new() -> Self {
        Self::default()
    }

    /// Re-validate the memo against the survival function's current
    /// epoch, dropping every stored value if it advanced. Keeps the
    /// allocation — refills after an invalidation reuse the buffer.
    #[inline]
    pub fn sync(&mut self, epoch: u64) {
        if self.epoch != epoch {
            self.values.clear();
            self.epoch = epoch;
        }
    }

    /// The memoised value for `dt`, computing and storing it via `fill`
    /// on first use. `fill` must be the direct computation — its result
    /// is returned (and replayed) verbatim.
    #[inline]
    pub fn lookup(&mut self, dt: u32, fill: impl FnOnce(u32) -> f64) -> f64 {
        let i = dt as usize;
        if i >= Self::MAX_DT {
            return fill(dt);
        }
        if i >= self.values.len() {
            self.values.resize(i + 1, f64::NAN);
        }
        let v = self.values[i];
        if v.is_nan() {
            let v = fill(dt);
            self.values[i] = v;
            v
        } else {
            v
        }
    }

    /// Number of table slots currently allocated (filled or not).
    pub fn capacity(&self) -> usize {
        self.values.len()
    }

    /// Number of filled (memoised) entries.
    pub fn filled(&self) -> usize {
        self.values.iter().filter(|v| !v.is_nan()).count()
    }

    /// The epoch the stored values belong to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_fills_once_and_replays() {
        let mut t = SurvivalTable::new();
        let mut calls = 0;
        let mut get = |t: &mut SurvivalTable, dt| {
            t.lookup(dt, |d| {
                calls += 1;
                1.0 / (d as f64 + 1.0)
            })
        };
        let a = get(&mut t, 7);
        let b = get(&mut t, 7);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(calls, 1, "second lookup must not recompute");
        assert_eq!(t.filled(), 1);
        assert!(t.capacity() >= 8);
    }

    #[test]
    fn sync_same_epoch_keeps_values() {
        let mut t = SurvivalTable::new();
        t.lookup(3, |_| 0.25);
        t.sync(t.epoch());
        assert_eq!(t.filled(), 1);
    }

    #[test]
    fn sync_new_epoch_invalidates() {
        let mut t = SurvivalTable::new();
        t.lookup(3, |_| 0.25);
        t.sync(5);
        assert_eq!(t.filled(), 0);
        assert_eq!(t.epoch(), 5);
        // Refill under the new epoch sees the new function.
        assert_eq!(t.lookup(3, |_| 0.75), 0.75);
    }

    #[test]
    fn beyond_cap_never_memoises() {
        let mut t = SurvivalTable::new();
        let dt = SurvivalTable::MAX_DT as u32 + 10;
        let mut calls = 0;
        for _ in 0..3 {
            t.lookup(dt, |_| {
                calls += 1;
                0.5
            });
        }
        assert_eq!(calls, 3);
        assert_eq!(t.capacity(), 0, "out-of-range dt must not allocate");
    }

    #[test]
    fn zero_and_one_survival_values_roundtrip() {
        // 0.0 and 1.0 are legitimate survival values and must be
        // distinguishable from the NaN sentinel.
        let mut t = SurvivalTable::new();
        assert_eq!(t.lookup(0, |_| 1.0), 1.0);
        assert_eq!(t.lookup(1, |_| 0.0), 0.0);
        assert_eq!(t.lookup(0, |_| panic!("must be memoised")), 1.0);
        assert_eq!(t.lookup(1, |_| panic!("must be memoised")), 0.0);
    }
}
