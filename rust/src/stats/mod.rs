//! Statistics toolbox: the per-node empirical return-time distribution
//! (the heart of DECAFORK's estimator), the lazy survival-value memo
//! backing cached θ̂ evaluation ([`SurvivalTable`]), the Irwin–Hall
//! distribution used for threshold design (Prop. 3), maximum-likelihood
//! fits for the exponential/geometric relaxations of Assumption 1, and
//! small numeric helpers (ln-gamma, ln-binomial, summary statistics).

pub mod ecdf;
pub mod fit;
pub mod irwin_hall;
pub mod survival_table;

pub use ecdf::EmpiricalCdf;
pub use irwin_hall::IrwinHall;
pub use survival_table::SurvivalTable;

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
/// Accurate to ~1e-13 over the positive reals — ample for CDF work.
pub fn ln_gamma(x: f64) -> f64 {
    // Coefficients for g=7, n=9 (Godfrey / numerical recipes style).
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        return std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln C(n, k) via ln-gamma.
pub fn ln_binom(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Two-sided Kolmogorov–Smirnov distance between an empirical sample and a
/// CDF callback. Used by tests to verify distributional claims.
pub fn ks_distance(samples: &mut [f64], cdf: impl Fn(f64) -> f64) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in samples.iter().enumerate() {
        let f = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((f - lo).abs()).max((hi - f).abs());
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn ln_binom_values() {
        assert!((ln_binom(10, 3) - 120f64.ln()).abs() < 1e-9);
        assert!((ln_binom(5, 0)).abs() < 1e-9);
        assert_eq!(ln_binom(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn ks_uniform_small() {
        let mut rng = crate::rng::Rng::new(31);
        let mut xs: Vec<f64> = (0..20_000).map(|_| rng.f64()).collect();
        let d = ks_distance(&mut xs, |x| x.clamp(0.0, 1.0));
        assert!(d < 0.02, "KS {d}");
    }
}
