//! Irwin–Hall distribution: the sum of `K` i.i.d. `U(0,1)` variables.
//!
//! Proposition 3 of the paper shows the DECAFORK estimator `θ̂_i(t) − ½`
//! under `K` infinitely-long-active walks is Irwin–Hall with parameter
//! `K − 1`; the fork threshold ε and the DECAFORK+ termination threshold
//! ε₂ are designed from its quantiles:
//!
//! * choose ε   so `F_{Σ_{Z0−1}}(ε − ½) = δ`   (forking w/ Z0 walks is rare)
//! * choose ε₂  so `1 − F_{Σ_{Z0−1}}(ε₂ − ½) = δ` (terminating likewise)

use super::{ln_binom, ln_gamma};

/// Irwin–Hall distribution with `n` summands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IrwinHall {
    pub n: u32,
}

impl IrwinHall {
    /// New distribution of the sum of `n` U(0,1) variables.
    pub fn new(n: u32) -> Self {
        IrwinHall { n }
    }

    /// CDF `F_{Σ_n}(x) = (1/n!) Σ_{k=0}^{⌊x⌋} (−1)^k C(n,k) (x−k)^n`.
    ///
    /// Evaluated in log-space with cancellation care: the alternating sum
    /// is accumulated as two positive log-sums and combined at the end.
    /// That is stable in the lower half of the support; the upper half is
    /// mapped there through the symmetry `F(x) = 1 − F(n − x)`, keeping
    /// absolute error ~1e-14 across the whole range for the `n ≤ ~60`
    /// relevant here (Z0 is tens, not thousands).
    pub fn cdf(&self, x: f64) -> f64 {
        let n = self.n;
        if n == 0 {
            // Sum of zero variables is the constant 0.
            return if x >= 0.0 { 1.0 } else { 0.0 };
        }
        if x <= 0.0 {
            return 0.0;
        }
        if x >= n as f64 {
            return 1.0;
        }
        if x > n as f64 / 2.0 {
            return 1.0 - self.cdf_lower(n as f64 - x);
        }
        self.cdf_lower(x)
    }

    /// Raw alternating sum; accurate for `x ≤ n/2`.
    fn cdf_lower(&self, x: f64) -> f64 {
        let n = self.n;
        let ln_fact_n = ln_gamma(n as f64 + 1.0);
        let kmax = x.floor() as u64;
        let mut pos = f64::NEG_INFINITY; // log-sum of positive terms
        let mut neg = f64::NEG_INFINITY; // log-sum of negative terms
        for k in 0..=kmax {
            let term = ln_binom(n as u64, k) + (n as f64) * (x - k as f64).ln() - ln_fact_n;
            if k % 2 == 0 {
                pos = log_add(pos, term);
            } else {
                neg = log_add(neg, term);
            }
        }
        let value = if neg == f64::NEG_INFINITY {
            pos.exp()
        } else {
            // pos >= neg for a valid CDF; guard against tiny negatives.
            (pos.exp() - neg.exp()).max(0.0)
        };
        value.clamp(0.0, 1.0)
    }

    /// Survival `1 − F(x)`; the symmetry `1 − F(x) = F(n − x)` gives full
    /// relative precision in the upper tail.
    pub fn survival(&self, x: f64) -> f64 {
        if x >= self.n as f64 {
            return 0.0;
        }
        if x <= 0.0 {
            return 1.0;
        }
        if x > self.n as f64 / 2.0 {
            self.cdf_lower(self.n as f64 - x)
        } else {
            1.0 - self.cdf_lower(x)
        }
    }

    /// Inverse CDF via bisection: smallest `x` with `F(x) ≥ p`.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p), "p out of range");
        if self.n == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return 0.0;
        }
        if p >= 1.0 {
            return self.n as f64;
        }
        let (mut lo, mut hi) = (0.0f64, self.n as f64);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if self.cdf(mid) < p {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Mean `n/2`.
    pub fn mean(&self) -> f64 {
        self.n as f64 / 2.0
    }

    /// Variance `n/12`.
    pub fn variance(&self) -> f64 {
        self.n as f64 / 12.0
    }
}

/// log(exp(a) + exp(b)) without overflow.
fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Design the DECAFORK forking threshold ε for target `z0` walks and
/// confidence `delta`: the probability of seeing `θ̂ < ε` with `Z0` active
/// walks is `delta` (Sec. III-B, "Choosing the threshold").
pub fn design_epsilon(z0: u32, delta: f64) -> f64 {
    assert!(z0 >= 1);
    IrwinHall::new(z0 - 1).quantile(delta) + 0.5
}

/// Design the DECAFORK+ termination threshold ε₂: the probability of
/// seeing `θ̂ > ε₂` with `Z0` active walks is `delta` (Sec. III-C).
pub fn design_epsilon2(z0: u32, delta: f64) -> f64 {
    assert!(z0 >= 1);
    IrwinHall::new(z0 - 1).quantile(1.0 - delta) + 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_edges() {
        let ih = IrwinHall::new(5);
        assert_eq!(ih.cdf(-1.0), 0.0);
        assert_eq!(ih.cdf(0.0), 0.0);
        assert_eq!(ih.cdf(5.0), 1.0);
        assert_eq!(ih.cdf(99.0), 1.0);
    }

    #[test]
    fn n1_is_uniform() {
        let ih = IrwinHall::new(1);
        for x in [0.1, 0.25, 0.5, 0.9] {
            assert!((ih.cdf(x) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn n2_is_triangular() {
        let ih = IrwinHall::new(2);
        // F(x) = x²/2 on [0,1]; 1 − (2−x)²/2 on [1,2].
        assert!((ih.cdf(0.5) - 0.125).abs() < 1e-10);
        assert!((ih.cdf(1.0) - 0.5).abs() < 1e-10);
        assert!((ih.cdf(1.5) - 0.875).abs() < 1e-10);
    }

    #[test]
    fn symmetry_about_mean() {
        for n in [3u32, 9, 20, 41] {
            let ih = IrwinHall::new(n);
            for frac in [0.1, 0.3, 0.45] {
                let x = frac * n as f64;
                let a = ih.cdf(x);
                let b = 1.0 - ih.cdf(n as f64 - x);
                assert!((a - b).abs() < 1e-8, "n={n} x={x}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn monotone_cdf() {
        let ih = IrwinHall::new(9);
        let mut prev = -1.0;
        for i in 0..=90 {
            let f = ih.cdf(i as f64 / 10.0);
            assert!(f >= prev - 1e-12);
            prev = f;
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        let ih = IrwinHall::new(9);
        for p in [0.01, 0.1, 0.5, 0.9, 0.99] {
            let x = ih.quantile(p);
            assert!((ih.cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
    }

    #[test]
    fn matches_monte_carlo() {
        let mut rng = crate::rng::Rng::new(8);
        let n = 9;
        let ih = IrwinHall::new(n);
        let trials = 100_000;
        for threshold in [2.0f64, 3.5, 4.5, 6.0] {
            let hits = (0..trials)
                .filter(|_| (0..n).map(|_| rng.f64()).sum::<f64>() <= threshold)
                .count();
            let emp = hits as f64 / trials as f64;
            assert!((emp - ih.cdf(threshold)).abs() < 0.01, "thr={threshold}");
        }
    }

    #[test]
    fn paper_thresholds_are_in_range() {
        // The paper uses ε = 2 for Z0 = 10 (Fig. 1): under Z0 active walks
        // the fork probability F_{Σ9}(1.5) must be small but non-zero.
        let p_fork = IrwinHall::new(9).cdf(2.0 - 0.5);
        assert!(p_fork < 0.01, "fork prob at eps=2: {p_fork}");
        assert!(p_fork > 1e-8);
        // ε2 = 5.75 ⇒ termination prob 1 − F_{Σ9}(5.25) small.
        let p_term = IrwinHall::new(9).survival(5.75 - 0.5);
        assert!(p_term < 0.35, "term prob at eps2=5.75: {p_term}");
    }

    #[test]
    fn designers_roundtrip() {
        let eps = design_epsilon(10, 1e-4);
        let eps2 = design_epsilon2(10, 1e-4);
        assert!(eps > 0.5 && eps < 5.0, "eps={eps}");
        assert!(eps2 > 5.0 && eps2 < 9.6, "eps2={eps2}");
        let ih = IrwinHall::new(9);
        assert!((ih.cdf(eps - 0.5) - 1e-4).abs() < 1e-5);
        assert!((ih.survival(eps2 - 0.5) - 1e-4).abs() < 1e-5);
    }
}
