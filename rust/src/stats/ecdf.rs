//! Integer-support empirical distribution for return times.
//!
//! Every node maintains one of these for its return-time variable `R_i`
//! (the paper pools the observations of all walks, since walks are i.i.d.).
//! Return times are positive integers (discrete time steps), so we store a
//! count histogram behind a Fenwick (binary-indexed) tree; `survival(dt)`
//! is the paper's `S(t − L_{i,k}) = 1 − F̂_{R_i}(t − L_{i,k})`.
//!
//! The estimator alternates one insertion with `|L_i|` queries per visit —
//! it is the hot path of the whole simulator. The first implementation
//! rebuilt a cumulative table on every insert→query transition (O(support)
//! per visit, which collapsed throughput on large graphs where return
//! times reach thousands); the Fenwick tree makes both operations
//! O(log support). See EXPERIMENTS.md §Perf, iteration 3.

/// Empirical CDF over `u32` observations (time differences).
#[derive(Debug, Clone, Default)]
pub struct EmpiricalCdf {
    /// Raw histogram (kept for mean / max / exact reporting).
    counts: Vec<u64>,
    total: u64,
    /// Fenwick tree over `counts`: `tree` has `counts.len()` slots,
    /// 1-based internally.
    tree: Vec<u64>,
    /// Largest value inserted so far — O(1) fast path for queries beyond
    /// the support (stale walks dominate those; §Perf iteration 5).
    max_value: u32,
    /// O(1)-query accelerator: direct cumulative table, refreshed lazily
    /// once `stale` inserts exceed 1/64 of the sample count. Queries
    /// through `&mut self` use it (the estimator hot path — §Perf
    /// iteration 6); `*_ref` queries stay exact via the Fenwick tree.
    /// The cached CDF is the *exact* empirical CDF of the first
    /// `cache_total` samples, so the approximation error is a sample-size
    /// lag of at most total/64 — statistically negligible next to the
    /// estimator's own noise.
    cache: Vec<u64>,
    cache_total: u64,
    stale: u64,
    /// Number of cache rebuilds so far — the survival function's change
    /// counter. Between rebuilds (and max-value growth notwithstanding)
    /// every `&mut`-path query returns values from the same frozen
    /// `(cache, cache_total)` pair, so downstream memos
    /// ([`SurvivalTable`](crate::stats::SurvivalTable)) are valid exactly
    /// while this counter (and, pre-first-rebuild, `total`) holds still.
    /// See [`survival_epoch`](Self::survival_epoch).
    rebuilds: u64,
}

impl EmpiricalCdf {
    /// New empty distribution.
    pub fn new() -> Self {
        Self::default()
    }

    fn grow_to(&mut self, len: usize) {
        if len <= self.counts.len() {
            return;
        }
        // Geometric growth; rebuild the tree from counts (rare, amortized).
        let new_len = len.next_power_of_two().max(64);
        self.counts.resize(new_len, 0);
        self.tree = vec![0; new_len + 1];
        for (v, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                Self::tree_add(&mut self.tree, v, c);
            }
        }
    }

    #[inline]
    fn tree_add(tree: &mut [u64], index: usize, delta: u64) {
        let mut i = index + 1;
        while i < tree.len() {
            tree[i] += delta;
            i += i & i.wrapping_neg();
        }
    }

    /// Number of samples ≤ `index`.
    #[inline]
    fn tree_prefix(&self, index: usize) -> u64 {
        let mut i = (index + 1).min(self.tree.len().saturating_sub(1));
        let mut acc = 0;
        while i > 0 {
            acc += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        acc
    }

    /// Record one observation.
    #[inline]
    pub fn add(&mut self, value: u32) {
        let v = value as usize;
        if v >= self.counts.len() {
            self.grow_to(v + 1);
        }
        self.counts[v] += 1;
        self.total += 1;
        self.max_value = self.max_value.max(value);
        self.stale += 1;
        Self::tree_add(&mut self.tree, v, 1);
    }

    /// Refresh the O(1) cumulative cache from the histogram.
    fn rebuild_cache(&mut self) {
        self.cache.resize(self.counts.len(), 0);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            self.cache[i] = acc;
        }
        self.cache_total = self.total;
        self.stale = 0;
        self.rebuilds += 1;
    }

    /// Apply the pending lazy rebuild (the same trigger `cdf`/`survival`
    /// use) and return an **epoch** identifying the current observable
    /// survival function. Contract, relied on by
    /// [`SurvivalTable`](crate::stats::SurvivalTable)-backed θ̂
    /// (`NodeState::theta`):
    ///
    /// * while the epoch is unchanged, `survival(x)` returns bit-identical
    ///   values for every `x < max_observed()` (values at `x ≥
    ///   max_observed()` are identically 0.0 in every epoch, and a growing
    ///   `max_observed` cannot change them: the pre-growth cache already
    ///   maps that range to 0);
    /// * any mutation that can change those values advances the epoch.
    ///
    /// Two regimes, disambiguated by parity so their counters never
    /// collide: before the first rebuild the cache is empty and queries
    /// fall through to the Fenwick tree, which reflects every insert
    /// immediately — the epoch is `(total << 1) | 1`. From the first
    /// rebuild on, values come from the frozen `(cache, cache_total)`
    /// snapshot and change only at the next rebuild — the epoch is
    /// `rebuilds << 1`. Neither is ever 0 when queried with samples
    /// present, so 0 serves as the "pristine memo" epoch.
    ///
    /// Callers must invoke this **before** reading memoised values and
    /// only at points where the direct path would issue a below-maximum
    /// query (the lazy trigger fires for those queries only) — see the
    /// invariants note in `DESIGN.md` §Survival cache.
    #[inline]
    pub fn survival_epoch(&mut self) -> u64 {
        if self.rebuild_pending() {
            self.rebuild_cache();
        }
        if self.cache.is_empty() {
            (self.total << 1) | 1
        } else {
            self.rebuilds << 1
        }
    }

    /// The lazy-rebuild trigger: pending inserts exceed 1/64 of the
    /// sample count (or of the histogram length, whichever is larger —
    /// a large sparse support should not rebuild per insert). **One**
    /// definition, shared by `cdf`, `survival` and `survival_epoch`:
    /// the cached≡direct θ̂ bit-equality contract requires all three to
    /// rebuild on exactly the same schedule, so the condition must not
    /// be able to drift between call sites.
    #[inline]
    fn rebuild_pending(&self) -> bool {
        self.total > 0 && self.stale * 64 >= self.total.max(self.counts.len() as u64)
    }

    /// Number of recorded observations.
    #[inline]
    pub fn len(&self) -> u64 {
        self.total
    }

    /// Resident heap bytes of the histogram, Fenwick tree and survival
    /// cache — the per-node memory accounting `NodeState::heap_bytes`
    /// (and through it the `perf_state` O(visited) bar) sums over.
    pub fn heap_bytes(&self) -> usize {
        (self.counts.len() + self.tree.len() + self.cache.len()) * std::mem::size_of::<u64>()
    }

    /// True if no samples recorded yet.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// `F̂(x)` = fraction of samples ≤ x. Returns 0 for an empty
    /// distribution (callers must handle the warm-up phase explicitly).
    /// Uses the cached table (refreshing it if stale), so repeated
    /// queries are O(1).
    #[inline]
    pub fn cdf(&mut self, x: u32) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        if self.rebuild_pending() {
            self.rebuild_cache();
        }
        if self.cache.is_empty() {
            return self.cdf_ref(x);
        }
        let idx = (x as usize).min(self.cache.len() - 1);
        self.cache[idx] as f64 / self.cache_total as f64
    }

    /// `cdf` without the historical `&mut` (the Fenwick tree needs no
    /// lazy rebuild).
    #[inline]
    pub fn cdf_ref(&self, x: u32) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        if x >= self.max_value {
            return 1.0;
        }
        self.tree_prefix(x as usize) as f64 / self.total as f64
    }

    /// Survival `S(x) = 1 − F̂(x)`: estimated probability that a walk's
    /// return takes longer than `x` steps. For an *empty* distribution we
    /// return 1.0 — during warm-up a node that has never measured a return
    /// assumes walks are alive, which avoids spurious forks before the
    /// initialization phase completes (paper Sec. III-B). O(1) via the
    /// cached table.
    #[inline]
    pub fn survival(&mut self, x: u32) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        if x >= self.max_value {
            return 0.0;
        }
        if self.rebuild_pending() {
            self.rebuild_cache();
        }
        if self.cache.is_empty() {
            return self.survival_ref(x);
        }
        let idx = (x as usize).min(self.cache.len() - 1);
        let le = self.cache[idx];
        (self.cache_total - le) as f64 / self.cache_total as f64
    }

    /// `survival` through a shared reference.
    #[inline]
    pub fn survival_ref(&self, x: u32) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        if x >= self.max_value {
            return 0.0;
        }
        // Count strictly-greater samples to avoid 1.0 − (near-1.0)
        // cancellation: S(x) = (total − #≤x) / total exactly.
        let le = self.tree_prefix(x as usize);
        (self.total - le) as f64 / self.total as f64
    }

    /// Empirical mean of the observations.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let s: u64 = self.counts.iter().enumerate().map(|(v, &c)| v as u64 * c).sum();
        s as f64 / self.total as f64
    }

    /// Empirical quantile (smallest v with F(v) ≥ p).
    pub fn quantile(&mut self, p: f64) -> u32 {
        assert!((0.0..=1.0).contains(&p));
        if self.total == 0 {
            return 0;
        }
        let target = (p * self.total as f64).ceil().max(1.0) as u64;
        // Binary search over the Fenwick prefix sums.
        let (mut lo, mut hi) = (0usize, self.counts.len() - 1);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.tree_prefix(mid) >= target {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo as u32
    }

    /// Largest observed value (0 if empty).
    pub fn max_observed(&self) -> u32 {
        self.max_value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_survival_is_one() {
        let mut e = EmpiricalCdf::new();
        assert_eq!(e.survival(10), 1.0);
        assert_eq!(e.cdf(10), 0.0);
        assert!(e.is_empty());
    }

    #[test]
    fn cdf_step_function() {
        let mut e = EmpiricalCdf::new();
        for v in [2u32, 2, 4, 8] {
            e.add(v);
        }
        assert_eq!(e.len(), 4);
        assert!((e.cdf(1) - 0.0).abs() < 1e-12);
        assert!((e.cdf(2) - 0.5).abs() < 1e-12);
        assert!((e.cdf(4) - 0.75).abs() < 1e-12);
        assert!((e.cdf(8) - 1.0).abs() < 1e-12);
        assert!((e.cdf(1000) - 1.0).abs() < 1e-12);
        assert!((e.survival(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut rng = crate::rng::Rng::new(1);
        let mut e = EmpiricalCdf::new();
        for _ in 0..1000 {
            e.add(rng.below(200) as u32);
        }
        let mut prev = 0.0;
        for x in 0..250 {
            let f = e.cdf(x);
            assert!(f >= prev - 1e-15);
            assert!((0.0..=1.0).contains(&f));
            prev = f;
        }
    }

    #[test]
    fn matches_naive_counting() {
        // Fenwick vs brute force over random data.
        let mut rng = crate::rng::Rng::new(7);
        let mut e = EmpiricalCdf::new();
        let mut raw: Vec<u32> = Vec::new();
        for _ in 0..3000 {
            let v = rng.below(3000) as u32;
            e.add(v);
            raw.push(v);
        }
        for probe in [0u32, 1, 17, 100, 999, 2999, 5000] {
            let naive = raw.iter().filter(|&&v| v <= probe).count() as f64 / raw.len() as f64;
            assert!((e.cdf(probe) - naive).abs() < 1e-12, "probe {probe}");
        }
    }

    #[test]
    fn interleaved_add_query() {
        let mut e = EmpiricalCdf::new();
        e.add(5);
        assert!((e.survival(4) - 1.0).abs() < 1e-12);
        e.add(1);
        assert!((e.survival(4) - 0.5).abs() < 1e-12);
        e.add(10);
        assert!((e.cdf(5) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mean_quantile_max() {
        let mut e = EmpiricalCdf::new();
        for v in 1..=100u32 {
            e.add(v);
        }
        assert!((e.mean() - 50.5).abs() < 1e-9);
        assert_eq!(e.quantile(0.5), 50);
        assert_eq!(e.quantile(1.0), 100);
        assert_eq!(e.quantile(0.01), 1);
        assert_eq!(e.max_observed(), 100);
    }

    #[test]
    fn growth_preserves_counts() {
        let mut e = EmpiricalCdf::new();
        e.add(1);
        e.add(2);
        e.add(100_000); // forces a large rebuild
        assert_eq!(e.len(), 3);
        assert!((e.cdf(2) - 2.0 / 3.0).abs() < 1e-12);
        assert!((e.cdf(100_000) - 1.0).abs() < 1e-12);
        assert_eq!(e.max_observed(), 100_000);
    }

    #[test]
    fn geometric_samples_match_survival() {
        // Sample geometric(q) and check survival(x) ≈ (1-q)^x.
        let mut rng = crate::rng::Rng::new(2);
        let q = 0.05;
        let mut e = EmpiricalCdf::new();
        for _ in 0..200_000 {
            e.add(rng.geometric(q) as u32);
        }
        for x in [1u32, 5, 10, 20, 40] {
            let expect = (1.0 - q).powi(x as i32);
            assert!((e.survival(x) - expect).abs() < 0.01, "x={x}");
        }
    }
}
