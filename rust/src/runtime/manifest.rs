//! The artifacts manifest: a flat `key=value` text file written by
//! `python/compile/aot.py` describing every artifact (shapes, hyperparams,
//! file names). Deliberately not JSON — the vendored crate set has no
//! JSON parser and the schema is flat.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    map: BTreeMap<String, String>,
}

impl Manifest {
    /// Parse from `key=value` lines; `#` starts a comment; blank lines
    /// ignored.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("manifest line {} has no '=': {line}", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Manifest { map })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.map
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("manifest missing key '{key}'"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?
            .parse()
            .with_context(|| format!("manifest key '{key}' is not an integer"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?
            .parse()
            .with_context(|| format!("manifest key '{key}' is not a number"))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let m = Manifest::parse("# comment\n\nmodel=tiny\nparam_count=128\nlr=0.05\n").unwrap();
        assert_eq!(m.get("model").unwrap(), "tiny");
        assert_eq!(m.get_usize("param_count").unwrap(), 128);
        assert!((m.get_f64("lr").unwrap() - 0.05).abs() < 1e-12);
        assert!(m.get("missing").is_err());
        assert_eq!(m.keys().count(), 3);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Manifest::parse("oops no equals").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let m = Manifest::parse("  a = hello world \n").unwrap();
        assert_eq!(m.get("a").unwrap(), "hello world");
    }
}
