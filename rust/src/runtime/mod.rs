//! Process-level runtime services: the PJRT executor for the learning
//! layer and the [`pool`] worker pool the sharded engine dispatches on.
//!
//! PJRT side: loads the AOT artifacts produced by `make artifacts`
//! (HLO **text** — see DESIGN.md for why text, not serialized protos) and
//! executes them on the CPU PJRT client. Python never runs here; the rust
//! binary is self-contained once `artifacts/` exists.

pub mod affinity;
pub mod manifest;
pub mod pool;
pub mod prefetch;
pub mod telemetry;

pub use manifest::Manifest;
pub use pool::WorkerPool;
pub use telemetry::{Telemetry, WorkerCounters};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A compiled executable plus its source path (for diagnostics).
pub struct LoadedExec {
    pub exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<LoadedExec> {
        let path = path.as_ref().to_path_buf();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedExec { exe, path })
    }
}

/// The training-step executable: `(params f32[P], tokens i32[B,T]) ->
/// (new_params f32[P], loss f32)` lowered from `python/compile/model.py`.
pub struct TrainStep {
    exec: LoadedExec,
    pub manifest: Manifest,
}

impl TrainStep {
    /// Load from an artifacts directory (reads `manifest.txt`).
    pub fn load(rt: &Runtime, artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.txt"))?;
        let hlo = artifacts_dir.join(manifest.get("train_step")?);
        let exec = rt.load_hlo_text(hlo)?;
        Ok(TrainStep { exec, manifest })
    }

    /// Parameter vector length.
    pub fn param_count(&self) -> Result<usize> {
        self.manifest.get_usize("param_count")
    }

    /// Tokens-per-batch shape (batch, seq+1).
    pub fn token_shape(&self) -> Result<(usize, usize)> {
        Ok((self.manifest.get_usize("batch")?, self.manifest.get_usize("seq")? + 1))
    }

    /// Run one SGD step: returns updated params and the scalar loss.
    pub fn step(&self, params: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
        let (b, t1) = self.token_shape()?;
        anyhow::ensure!(
            tokens.len() == b * t1,
            "token batch must be {b}x{t1}, got {}",
            tokens.len()
        );
        anyhow::ensure!(
            params.len() == self.param_count()?,
            "param vector must be {}, got {}",
            self.param_count()?,
            params.len()
        );
        let p = xla::Literal::vec1(params);
        let tok = xla::Literal::vec1(tokens).reshape(&[b as i64, t1 as i64])?;
        let result = self.exec.exe.execute::<xla::Literal>(&[p, tok])?[0][0].to_literal_sync()?;
        let (new_params, loss) = result.to_tuple2()?;
        let new_params = new_params.to_vec::<f32>()?;
        let loss = loss.to_vec::<f32>()?[0];
        Ok((new_params, loss))
    }

    /// Source path (diagnostics).
    pub fn path(&self) -> &Path {
        &self.exec.path
    }
}

/// The batched estimator kernel: `(elapsed f32[N,K], q f32[N],
/// mask f32[N,K]) -> theta f32[N]` — evaluates `θ̂` for every node in one
/// call (the Pallas `survival` kernel from L1).
pub struct ThetaKernel {
    exec: LoadedExec,
    pub nodes: usize,
    pub walks: usize,
}

impl ThetaKernel {
    pub fn load(rt: &Runtime, artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.txt"))?;
        let hlo = artifacts_dir.join(manifest.get("theta_kernel")?);
        let exec = rt.load_hlo_text(hlo)?;
        Ok(ThetaKernel {
            exec,
            nodes: manifest.get_usize("theta_nodes")?,
            walks: manifest.get_usize("theta_walks")?,
        })
    }

    /// Evaluate θ̂ for all nodes at once.
    pub fn theta(&self, elapsed: &[f32], q: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        let (n, k) = (self.nodes, self.walks);
        anyhow::ensure!(elapsed.len() == n * k, "elapsed must be {n}x{k}");
        anyhow::ensure!(q.len() == n, "q must be length {n}");
        anyhow::ensure!(mask.len() == n * k, "mask must be {n}x{k}");
        let e = xla::Literal::vec1(elapsed).reshape(&[n as i64, k as i64])?;
        let qv = xla::Literal::vec1(q);
        let m = xla::Literal::vec1(mask).reshape(&[n as i64, k as i64])?;
        let result = self.exec.exe.execute::<xla::Literal>(&[e, qv, m])?[0][0].to_literal_sync()?;
        let theta = result.to_tuple1()?;
        Ok(theta.to_vec::<f32>()?)
    }
}

/// Resolve the default artifacts directory: `$DECAFORK_ARTIFACTS` or
/// `./artifacts` relative to the current directory / crate root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DECAFORK_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the artifacts needed by the learning runtime exist.
pub fn artifacts_present(dir: &Path) -> bool {
    dir.join("manifest.txt").exists()
}
