//! Zero-perturbation engine telemetry: per-phase wall-clock histograms
//! and per-worker counters (DESIGN.md §Observability).
//!
//! ## The invariant this module is built around
//!
//! Telemetry **observes**; it never participates. Every item here is a
//! clock read, a counter increment, or a fold of per-worker scratch at a
//! barrier that already exists — no RNG stream is touched, no work is
//! reordered, no lock is taken, no atomic lives on a hot path. That is
//! why traces are bit-identical with metrics on, off, or compiled to
//! the `off` no-op (locked by `prop_metrics_sink_is_observation_only`
//! and both golden families, like every prior A/B knob).
//!
//! ## Where the numbers come from
//!
//! * **Phase spans** — the coordinator reads `Instant::now()` at the
//!   four phase boundaries of the sharded step (pre-step failures, hop
//!   fan-out + death drain, control fan-out, merge barrier to end of
//!   step) and records the nanosecond deltas into log-bucketed
//!   power-of-two [`PowHistogram`]s. Clock reads happen on the
//!   coordinator only, between phases — they cannot move a draw.
//! * **Worker counters** — each phase task owns one [`WorkerCounters`]
//!   row of engine scratch (disjoint `&mut`, exactly like the hop
//!   scratch and mailbox rows) and bumps it at chunk granularity; the
//!   coordinator folds the rows into the step totals at the end-of-step
//!   barrier it already runs. No allocation after warm-up: the scratch
//!   vector is sized once at construction.
//! * **Merge-side counts** — forks, kills, terminations and the θ̂
//!   summary are tallied by the coordinator inside the merge loop it
//!   already executes (simple adds, gated on `enabled`).
//!
//! The streaming side (JSONL/CSV records every `--metrics-every`
//! steps) lives in [`crate::obs`]; this module is the measurement
//! substrate both engines thread through their steps.

/// A log-bucketed histogram: bucket `b` counts samples in
/// `[2^(b−1), 2^b)` (bucket 0 counts zeros). 64 buckets cover the full
/// `u64` range, so nanosecond spans from "empty step" to "minutes" all
/// land without configuration. Recording is two instructions (leading
/// zeros + increment); merging is 64 adds.
#[derive(Debug, Clone)]
pub struct PowHistogram {
    counts: [u64; 64],
}

impl Default for PowHistogram {
    fn default() -> Self {
        PowHistogram { counts: [0; 64] }
    }
}

impl PowHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for `value`: 0 for 0, else `64 − leading_zeros`
    /// clamped into the table (so `1 → 1`, `2..4 → 2`, `4..8 → 3`, …).
    #[inline]
    pub fn bucket_of(value: u64) -> usize {
        (64 - value.leading_zeros() as usize).min(63)
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, value: u64) {
        self.counts[Self::bucket_of(value)] += 1;
    }

    /// Fold `other` into `self` (the per-worker → run-total fold).
    pub fn merge(&mut self, other: &PowHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    /// Total samples recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// The raw bucket table (index `b` = samples in `[2^(b−1), 2^b)`).
    pub fn buckets(&self) -> &[u64; 64] {
        &self.counts
    }

    /// Upper bound (exclusive) of the highest non-empty bucket — a
    /// cheap "worst observed magnitude" summary. `None` when empty.
    pub fn max_bucket_bound(&self) -> Option<u64> {
        self.counts
            .iter()
            .rposition(|&c| c > 0)
            .map(|b| if b >= 63 { u64::MAX } else { 1u64 << b })
    }

    pub fn clear(&mut self) {
        self.counts = [0; 64];
    }
}

/// One worker's counter scratch for one step. The engine owns a
/// `Vec<WorkerCounters>` sized to the shard count (like its hop
/// scratch); phase task `k` receives row `k` as a disjoint `&mut` and
/// bumps it locally — no atomics, no sharing — and the coordinator
/// folds and clears the rows at the end-of-step barrier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerCounters {
    /// Walks advanced by the hop phase (chunk sizes, pre-death).
    pub hopped: u64,
    /// Walks killed in transit / on arrival during the hop phase.
    pub hop_deaths: u64,
    /// Arrival records binned into mailbox rows (mailbox routing only;
    /// 0 under the serial oracle, where the coordinator buckets).
    pub arrivals_binned: u64,
    /// Arrivals observed by the control phase (visits).
    pub visits: u64,
    /// `NodeStore` states materialized on first visit this step.
    pub materializations: u64,
    /// `SlotIndex`/store probe-length samples taken…
    pub probe_samples: u64,
    /// …and their total length (mean = total / samples).
    pub probe_len_total: u64,
}

impl WorkerCounters {
    /// Fold `self` into `acc` (the barrier fold).
    pub fn fold_into(&self, acc: &mut WorkerCounters) {
        acc.hopped += self.hopped;
        acc.hop_deaths += self.hop_deaths;
        acc.arrivals_binned += self.arrivals_binned;
        acc.visits += self.visits;
        acc.materializations += self.materializations;
        acc.probe_samples += self.probe_samples;
        acc.probe_len_total += self.probe_len_total;
    }

    pub fn clear(&mut self) {
        *self = WorkerCounters::default();
    }
}

/// Phase indices into the span tables (the order the sharded step runs
/// them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Master failure model + kill application + compact.
    PreStep = 0,
    /// Hop fan-out + hop-death drain.
    Hop = 1,
    /// Serial bucket scan (if any) + control fan-out.
    Control = 2,
    /// Hook merge, decision merge, prune, compact, Z_t push.
    Merge = 3,
}

pub const PHASES: usize = 4;

/// Everything accumulated since the last sink flush — the payload of
/// one streamed step record (period totals, not instantaneous values,
/// so `--metrics-every 100` still accounts for every step).
#[derive(Debug, Clone, Default)]
pub struct PeriodStats {
    /// Steps folded into this period.
    pub steps: u64,
    /// Wall-clock nanoseconds per phase, summed over the period
    /// (indexed by [`Phase`]).
    pub span_ns: [u64; PHASES],
    /// Folded worker counters.
    pub counters: WorkerCounters,
    /// Merge-side event tallies.
    pub forks: u64,
    pub terminations: u64,
    pub failures: u64,
    /// Arrival-count imbalance across shards: the smallest and largest
    /// per-shard arrival load seen in any step of the period (hop
    /// chunk sizes are deterministic ⌈live/shards⌉ splits; arrivals
    /// per node-range shard are where real imbalance shows).
    pub shard_arrivals_min: u64,
    pub shard_arrivals_max: u64,
    /// θ̂ summary over the period's control decisions.
    pub theta_n: u64,
    pub theta_sum: f64,
    pub theta_min: f64,
    pub theta_max: f64,
}

impl PeriodStats {
    /// Mean θ̂ over the period, `None` when no decision carried one.
    pub fn theta_mean(&self) -> Option<f64> {
        (self.theta_n > 0).then(|| self.theta_sum / self.theta_n as f64)
    }

    /// Mean probe length over the period's sampled lookups.
    pub fn probe_mean(&self) -> Option<f64> {
        (self.counters.probe_samples > 0)
            .then(|| self.counters.probe_len_total as f64 / self.counters.probe_samples as f64)
    }
}

/// The engine-owned telemetry accumulator: run-lifetime phase
/// histograms plus the current flush period. Constructed `enabled` or
/// not once, at engine build time — a disabled instance is a handful
/// of dead fields and every call site is behind one predictable
/// `if !enabled` branch.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    enabled: bool,
    /// Run-lifetime per-phase span histograms (log₂ ns buckets).
    pub phase_hist: [PowHistogram; PHASES],
    period: PeriodStats,
}

impl Telemetry {
    pub fn new(enabled: bool) -> Self {
        Telemetry { enabled, ..Default::default() }
    }

    /// Whether any recording should happen this run.
    #[inline(always)]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one phase span (coordinator-side clock delta).
    #[inline]
    pub fn record_span(&mut self, phase: Phase, ns: u64) {
        if !self.enabled {
            return;
        }
        self.phase_hist[phase as usize].record(ns);
        self.period.span_ns[phase as usize] += ns;
    }

    /// Fold and clear the per-worker scratch rows at the end-of-step
    /// barrier.
    pub fn fold_workers(&mut self, scratch: &mut [WorkerCounters]) {
        if !self.enabled {
            return;
        }
        for row in scratch {
            row.fold_into(&mut self.period.counters);
            row.clear();
        }
    }

    /// Merge-loop tally: one control decision's θ̂ (coordinator-side).
    #[inline]
    pub fn observe_theta(&mut self, theta: f64) {
        let p = &mut self.period;
        if p.theta_n == 0 {
            p.theta_min = theta;
            p.theta_max = theta;
        } else {
            p.theta_min = p.theta_min.min(theta);
            p.theta_max = p.theta_max.max(theta);
        }
        p.theta_n += 1;
        p.theta_sum += theta;
    }

    /// Merge-side event tallies for one step.
    pub fn count_events(&mut self, forks: u64, terminations: u64, failures: u64) {
        self.period.forks += forks;
        self.period.terminations += terminations;
        self.period.failures += failures;
    }

    /// Per-shard arrival-load extremes for one step.
    pub fn observe_shard_load(&mut self, min: u64, max: u64) {
        let p = &mut self.period;
        if p.steps == 0 {
            p.shard_arrivals_min = min;
            p.shard_arrivals_max = max;
        } else {
            p.shard_arrivals_min = p.shard_arrivals_min.min(min);
            p.shard_arrivals_max = p.shard_arrivals_max.max(max);
        }
    }

    /// Close one step into the period.
    pub fn end_step(&mut self) {
        self.period.steps += 1;
    }

    /// Read the open period (the sink formats from this)…
    pub fn period(&self) -> &PeriodStats {
        &self.period
    }

    /// …and reset it after a flush.
    pub fn reset_period(&mut self) {
        self.period = PeriodStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow_histogram_buckets_powers_of_two() {
        assert_eq!(PowHistogram::bucket_of(0), 0);
        assert_eq!(PowHistogram::bucket_of(1), 1);
        assert_eq!(PowHistogram::bucket_of(2), 2);
        assert_eq!(PowHistogram::bucket_of(3), 2);
        assert_eq!(PowHistogram::bucket_of(4), 3);
        assert_eq!(PowHistogram::bucket_of(1023), 10);
        assert_eq!(PowHistogram::bucket_of(1024), 11);
        assert_eq!(PowHistogram::bucket_of(u64::MAX), 63);
        let mut h = PowHistogram::new();
        for v in [0u64, 1, 3, 900, 1024] {
            h.record(v);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[2], 1);
        assert_eq!(h.buckets()[10], 1);
        assert_eq!(h.max_bucket_bound(), Some(1 << 11));
        let mut other = PowHistogram::new();
        other.record(3);
        h.merge(&other);
        assert_eq!(h.buckets()[2], 2);
        h.clear();
        assert_eq!(h.total(), 0);
        assert_eq!(h.max_bucket_bound(), None);
    }

    #[test]
    fn worker_counters_fold_and_clear() {
        let mut a = WorkerCounters {
            hopped: 10,
            hop_deaths: 1,
            arrivals_binned: 9,
            visits: 9,
            materializations: 4,
            probe_samples: 9,
            probe_len_total: 12,
        };
        let mut acc = WorkerCounters::default();
        a.fold_into(&mut acc);
        a.fold_into(&mut acc);
        assert_eq!(acc.hopped, 20);
        assert_eq!(acc.probe_len_total, 24);
        a.clear();
        assert_eq!(a, WorkerCounters::default());
    }

    #[test]
    fn telemetry_accumulates_only_when_enabled() {
        let mut off = Telemetry::new(false);
        off.record_span(Phase::Hop, 100);
        let mut scratch = vec![WorkerCounters { hopped: 5, ..Default::default() }];
        off.fold_workers(&mut scratch);
        assert_eq!(off.period().span_ns[Phase::Hop as usize], 0);
        assert_eq!(off.period().counters.hopped, 0);
        // Disabled folds must not clear the scratch either — nothing
        // observes it, so nothing may touch it.
        assert_eq!(scratch[0].hopped, 5);

        let mut on = Telemetry::new(true);
        on.record_span(Phase::Hop, 100);
        on.record_span(Phase::Hop, 50);
        on.fold_workers(&mut scratch);
        on.observe_theta(4.0);
        on.observe_theta(2.0);
        on.observe_theta(6.0);
        on.count_events(3, 1, 2);
        on.observe_shard_load(2, 9);
        on.end_step();
        on.observe_shard_load(1, 5);
        on.end_step();
        let p = on.period();
        assert_eq!(p.steps, 2);
        assert_eq!(p.span_ns[Phase::Hop as usize], 150);
        assert_eq!(p.counters.hopped, 5);
        assert_eq!(scratch[0].hopped, 0, "enabled fold clears the scratch");
        assert_eq!(p.theta_n, 3);
        assert_eq!(p.theta_mean(), Some(4.0));
        assert_eq!(p.theta_min, 2.0);
        assert_eq!(p.theta_max, 6.0);
        assert_eq!((p.forks, p.terminations, p.failures), (3, 1, 2));
        assert_eq!((p.shard_arrivals_min, p.shard_arrivals_max), (1, 9));
        assert_eq!(on.phase_hist[Phase::Hop as usize].total(), 2);
        on.reset_period();
        assert_eq!(on.period().steps, 0);
        assert_eq!(on.phase_hist[Phase::Hop as usize].total(), 2, "histograms span the run");
    }
}
