//! A persistent, std-only worker pool for the sharded engine's phase
//! dispatch (DESIGN.md §Worker pool).
//!
//! ## Why not `std::thread::scope` per phase
//!
//! The stream-mode [`ShardedEngine`](crate::sim::ShardedEngine) runs up
//! to three shard-parallel phases per step (hop, control, prune). Scoped
//! threads are correct but pay a full spawn+join per worker per phase —
//! tens of microseconds each — which is noise at 100k-node step sizes
//! and *dominant* at `perf_control` scale (1000 nodes), where the whole
//! step is comparable to one spawn. A [`WorkerPool`] creates its OS
//! threads **once** and parks them between dispatches, so a phase costs
//! one condvar broadcast plus one completion wait instead of N spawns.
//!
//! ## Wake protocol (one reusable barrier, two condvars)
//!
//! ```text
//! coordinator                         worker k
//! ───────────                         ────────
//! publish {tasks, epoch+1, remaining} wait until epoch != seen
//! notify_all(work) ──────────────────▶ seen = epoch; take tasks[k]
//! run tasks' first entry inline        run task (no lock held)
//! wait until remaining == 0 ◀───────── remaining -= 1; if 0 notify(done)
//! clear task slice; surface panics     park again on `work`
//! ```
//!
//! The epoch counter is what makes the barrier *reusable*: a worker that
//! slept through an entire dispatch (possible only when it had no task —
//! the coordinator cannot advance the epoch while any **assigned** task
//! is unfinished) simply sees a newer epoch next time it wakes. Workers
//! never hold the state lock while running a task.
//!
//! ## Safety contract
//!
//! [`WorkerPool::run_slice`] erases task lifetimes *and the task type*
//! to hand borrowed closures to persistent threads (the same job
//! `std::thread::scope` does with its lifetime brand). The published
//! [`TaskSlice`] carries a monomorphized call thunk alongside the raw
//! base pointer, so callers dispatch a plain `&mut [F]` of concrete
//! closures directly — no per-phase `Vec<Task>` re-collection, no
//! double indirection. Soundness rests on two invariants, both local
//! to this file:
//!
//! 1. `run_slice` does **not return** until `remaining == 0`, i.e.
//!    every published task has finished — so the erased borrows never
//!    outlive the caller's frame;
//! 2. each published slot is read by exactly one worker (slot `k` by
//!    worker `k`), and the coordinator runs only the *split-off* first
//!    task — so no `&mut` aliases.
//!
//! ## Sticky worker identity (DESIGN.md §Locality & routing)
//!
//! Worker `k` is a fixed OS thread for the pool's whole lifetime and
//! always runs slot `k + 1` of every dispatch (the coordinator runs
//! slot 0). Callers that index their task lists consistently — the
//! sharded engine hands shard `k`'s hop chunk, store, mailbox row and
//! decision buffer to slot `k` of every phase — therefore get *sticky
//! shard affinity* for free: the same thread touches the same shard's
//! working set every phase of every step, and data first-touched
//! inside a task (lazy node states, mailbox growth) is allocated warm
//! on its owning thread. [`WorkerPool::new_pinned`] optionally binds
//! worker `k` to core `k + 1` (`runtime::affinity`), extending the
//! binding down to the core/NUMA level; pinning is best-effort and can
//! never change results.
//!
//! ## Shutdown-on-drop
//!
//! Dropping the pool sets the shutdown flag, wakes everyone and joins
//! every worker thread: constructing and dropping engines in a loop
//! leaks nothing (locked by
//! `pool_lifecycle_does_not_leak_workers_or_change_traces` in
//! `tests/shard_invariance.rs`). A task panic is caught on the worker,
//! recorded, and re-raised on the coordinator once the dispatch
//! completes — the pool itself stays usable (and droppable) afterwards.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed unit of work: run exactly once per dispatch, on exactly
/// one thread. `FnMut` (not `FnOnce`) so a task slot can be re-armed by
/// the caller across steps without reboxing.
pub type Task<'a> = &'a mut (dyn FnMut() + Send);

/// Lifetime- and type-erased view of the caller's task slice. Only ever
/// dereferenced between publish and the `remaining == 0` handshake (see
/// the module-level safety contract). `call` is the monomorphized thunk
/// that knows the concrete task type: `call(ptr, k)` runs slot `k` of
/// the published `&mut [F]`.
#[derive(Clone, Copy)]
struct TaskSlice {
    ptr: *mut (),
    len: usize,
    call: unsafe fn(*mut (), usize),
}

/// # Safety
/// Never called: the empty slice publishes `len == 0`, so no worker
/// ever takes a slot from it.
unsafe fn call_nothing(_ptr: *mut (), _k: usize) {}

/// # Safety
/// `base` must be the base pointer of a live `&mut [F]` with more than
/// `k` elements, and slot `k` must not be aliased by any other thread
/// (the dispatch protocol guarantees both).
unsafe fn call_slot<F: FnMut()>(base: *mut (), k: usize) {
    (*(base as *mut F).add(k))()
}

impl TaskSlice {
    const EMPTY: TaskSlice = TaskSlice { ptr: std::ptr::null_mut(), len: 0, call: call_nothing };
}

// SAFETY: the raw pointer is only dereferenced under the dispatch
// protocol above (disjoint slots, coordinator blocked until done).
unsafe impl Send for TaskSlice {}

struct State {
    /// Bumped once per dispatch; workers compare against their last-seen
    /// value, which is what lets one Mutex+Condvar pair act as a barrier
    /// that can be reused forever.
    epoch: u64,
    tasks: TaskSlice,
    /// Published-but-unfinished task count for the current epoch.
    remaining: usize,
    /// A task panicked during the current epoch (re-raised by `run`).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work: Condvar,
    /// The coordinator parks here until `remaining == 0`.
    done: Condvar,
}

/// Persistent worker pool: `workers` parked OS threads plus the calling
/// thread, dispatched with [`run`](Self::run).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    pinned: bool,
    /// Dispatches that actually woke the workers (inline fast paths —
    /// empty slice, single task, zero workers — don't count). A plain
    /// coordinator-side field: `run_slice` takes `&mut self`, so no
    /// atomic is needed and the hot path pays one add. Observability
    /// only — read by [`dispatches`](Self::dispatches).
    dispatches: u64,
}

impl WorkerPool {
    /// Spawn `workers` parked threads (0 is allowed: every dispatch then
    /// runs inline on the caller). Workers are not pinned — see
    /// [`new_pinned`](Self::new_pinned).
    pub fn new(workers: usize) -> Self {
        Self::new_pinned(workers, false)
    }

    /// [`new`](Self::new) with opt-in core pinning: when `pin` is set,
    /// worker `k` binds itself to core `k + 1` at thread start (core 0
    /// is left to the coordinator/caller thread, whose mask is never
    /// touched — pinning the test runner's or a host application's main
    /// thread would be hostile). Best-effort: a rejected mask (cgroup
    /// cpuset, fewer cores than workers, non-Linux) leaves that worker
    /// unpinned. Placement only — traces are identical either way.
    pub fn new_pinned(workers: usize, pin: bool) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                tasks: TaskSlice::EMPTY,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|k| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("decafork-pool-{k}"))
                    .spawn(move || {
                        if pin {
                            let _ = crate::runtime::affinity::pin_current_thread(k + 1);
                        }
                        worker_loop(&shared, k)
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles, pinned: pin, dispatches: 0 }
    }

    /// Number of pooled worker threads (the caller thread is extra).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Whether this pool was built with core pinning requested
    /// (engines adopting a pre-built pool check the request matches
    /// their params — actual pinning success is best-effort).
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// Lifetime count of dispatches that published a task slice to the
    /// parked workers (condvar broadcast + completion wait). Telemetry
    /// accessor for the observability layer.
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Run every task to completion: `tasks[0]` on the calling thread,
    /// `tasks[1..]` on the pooled workers (slot `k+1` on worker `k`).
    /// Thin wrapper over [`run_slice`](Self::run_slice) for callers
    /// whose tasks are heterogeneous closures behind `dyn` (a
    /// `&mut dyn FnMut()` is itself `FnMut()`).
    pub fn run(&mut self, tasks: &mut [Task<'_>]) {
        self.run_slice(tasks)
    }

    /// Run a slice of concrete tasks to completion: `tasks[0]` on the
    /// calling thread, `tasks[1..]` on the pooled workers (slot `k+1`
    /// on worker `k` — the sticky identity the sharded engine's shard
    /// affinity rides on). Blocks until all tasks finished; panics if
    /// any task panicked or if `tasks.len() - 1` exceeds the worker
    /// count.
    ///
    /// Generic over the task type so phase dispatch needs no boxing and
    /// no intermediate `Vec<Task>`: the closure slice a phase builds is
    /// published as-is, with a monomorphized thunk carrying the type.
    ///
    /// Takes `&mut self` deliberately: the safety contract assumes a
    /// single dispatcher per pool (a second concurrent dispatch could
    /// overwrite the published task slice while a slow worker still
    /// holds a pointer into the first), and exclusive access makes that
    /// unrepresentable in safe code — at zero cost to the engine, which
    /// owns its pool uniquely.
    pub fn run_slice<F: FnMut() + Send>(&mut self, tasks: &mut [F]) {
        let Some((first, rest)) = tasks.split_first_mut() else { return };
        if rest.is_empty() || self.handles.is_empty() {
            first();
            for t in rest {
                t();
            }
            return;
        }
        assert!(
            rest.len() <= self.handles.len(),
            "pool has {} workers but was handed {} worker tasks",
            self.handles.len(),
            rest.len()
        );
        self.dispatches += 1;
        {
            let mut st = self.shared.state.lock().unwrap();
            st.tasks = TaskSlice {
                ptr: rest.as_mut_ptr() as *mut (),
                len: rest.len(),
                call: call_slot::<F>,
            };
            st.remaining = rest.len();
            st.panicked = false;
            st.epoch += 1;
        }
        self.shared.work.notify_all();
        // The caller's share of the phase overlaps the workers'.
        let own = catch_unwind(AssertUnwindSafe(|| (*first)()));
        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.tasks = TaskSlice::EMPTY;
            st.panicked
        };
        // Surface the caller-thread panic only after the barrier: the
        // published borrows must be dead before `run`'s frame unwinds.
        if let Err(payload) = own {
            std::panic::resume_unwind(payload);
        }
        if panicked {
            panic!("a pooled worker task panicked during dispatch");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, k: usize) {
    let mut seen = 0u64;
    loop {
        let job: Option<TaskSlice> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            seen = st.epoch;
            if k < st.tasks.len {
                Some(st.tasks)
            } else {
                None
            }
        };
        if let Some(ts) = job {
            // SAFETY: slot `k` of the published slice is read by this
            // worker only, the coordinator keeps the underlying borrows
            // alive until `remaining == 0`, and `call` is the thunk
            // monomorphized for the slice's actual element type by the
            // `run_slice` call that published it. The lock is released
            // before the call — tasks never run under the state mutex.
            let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (ts.call)(ts.ptr, k) })).is_ok();
            let mut st = shared.state.lock().unwrap();
            if !ok {
                st.panicked = true;
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done.notify_one();
            }
        }
    }
}

/// The pre-pool dispatch: one scoped spawn per task, first task on the
/// caller. Kept as the measured baseline of `benches/perf_pool.rs`
/// (pooled-vs-scoped on identical task lists) — not used on any
/// production path. Generic like [`WorkerPool::run_slice`] so both
/// dispatch modes accept the same concrete closure slices.
pub fn run_scoped_slice<F: FnMut() + Send>(tasks: &mut [F]) {
    let Some((first, rest)) = tasks.split_first_mut() else { return };
    std::thread::scope(|scope| {
        for t in rest.iter_mut() {
            scope.spawn(move || t());
        }
        first();
    });
}

/// [`run_scoped_slice`] for `dyn`-erased task lists (mirrors
/// [`WorkerPool::run`] over [`WorkerPool::run_slice`]).
pub fn run_scoped(tasks: &mut [Task<'_>]) {
    run_scoped_slice(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Collect a closure set into the dispatchable task-slice form.
    fn tasks_of<F: FnMut() + Send>(fs: &mut [F]) -> Vec<Task<'_>> {
        fs.iter_mut().map(|f| f as Task<'_>).collect()
    }

    fn bump(n: &AtomicUsize) {
        n.fetch_add(1, Ordering::Relaxed);
    }

    #[test]
    fn runs_every_task_exactly_once_per_dispatch() {
        let mut pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for round in 1..=50usize {
            let mut fs: Vec<_> = hits.iter().map(|h| move || bump(h)).collect();
            pool.run(&mut tasks_of(&mut fs));
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), round);
            }
        }
    }

    #[test]
    fn tasks_mutate_disjoint_borrowed_chunks() {
        let mut pool = WorkerPool::new(2);
        let mut data = vec![0u64; 90];
        {
            let mut fs: Vec<_> = data
                .chunks_mut(30)
                .enumerate()
                .map(|(k, chunk)| {
                    move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = (k * 1000 + i) as u64;
                        }
                    }
                })
                .collect();
            pool.run(&mut tasks_of(&mut fs));
        }
        for (k, chunk) in data.chunks(30).enumerate() {
            for (i, &v) in chunk.iter().enumerate() {
                assert_eq!(v, (k * 1000 + i) as u64);
            }
        }
    }

    #[test]
    fn fewer_tasks_than_workers_and_empty_dispatches() {
        let mut pool = WorkerPool::new(4);
        pool.run(&mut []); // no-op
        let hit = AtomicUsize::new(0);
        for len in [1usize, 2, 3] {
            // 0..2 worker tasks; the remaining workers idle through the
            // epoch and must stay dispatchable afterwards.
            let mut fs: Vec<_> = (0..len).map(|_| || bump(&hit)).collect();
            pool.run(&mut tasks_of(&mut fs));
        }
        assert_eq!(hit.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let mut pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 0);
        let mut parts = [0u64; 3];
        {
            let mut fs: Vec<_> =
                parts.iter_mut().enumerate().map(|(k, p)| move || *p = k as u64 + 1).collect();
            pool.run(&mut tasks_of(&mut fs));
        }
        assert_eq!(parts.iter().sum::<u64>(), 6);
    }

    #[test]
    fn scoped_baseline_matches_pool_results() {
        let pool = std::sync::Mutex::new(WorkerPool::new(3));
        let run = |use_pool: bool| {
            let mut out = vec![0u32; 40];
            let mut fs: Vec<_> = out
                .chunks_mut(10)
                .enumerate()
                .map(|(k, c)| move || c.iter_mut().for_each(|v| *v = k as u32))
                .collect();
            let mut ts = tasks_of(&mut fs);
            if use_pool {
                pool.lock().unwrap().run(&mut ts);
            } else {
                run_scoped(&mut ts);
            }
            out
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn worker_panic_is_reported_and_pool_survives() {
        let mut pool = WorkerPool::new(2);
        let blew_up = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut fs: Vec<Box<dyn FnMut() + Send>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            let mut ts: Vec<Task<'_>> = fs.iter_mut().map(|f| &mut **f as Task<'_>).collect();
            pool.run(&mut ts);
        }))
        .is_err();
        assert!(blew_up, "worker panic must surface on the coordinator");
        // ... and the pool still dispatches afterwards.
        let count = AtomicUsize::new(0);
        let mut fs: Vec<_> = (0..3).map(|_| || bump(&count)).collect();
        pool.run(&mut tasks_of(&mut fs));
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn run_slice_dispatches_concrete_closures_without_reboxing() {
        // The generic path the engine phases use: a plain Vec of one
        // concrete closure type, published as-is (no Vec<Task>
        // re-collection). Results must match the dyn-erased `run` path
        // on the same work, across repeated dispatches (epoch reuse).
        let mut pool = WorkerPool::new(3);
        let mut data = vec![0u64; 80];
        for round in 1..=10u64 {
            let mut fs: Vec<_> = data
                .chunks_mut(20)
                .enumerate()
                .map(|(k, chunk)| {
                    move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = round * 10_000 + (k * 100 + i) as u64;
                        }
                    }
                })
                .collect();
            pool.run_slice(&mut fs);
            drop(fs);
            for (k, chunk) in data.chunks(20).enumerate() {
                for (i, &v) in chunk.iter().enumerate() {
                    assert_eq!(v, round * 10_000 + (k * 100 + i) as u64, "round {round}");
                }
            }
        }
    }

    #[test]
    fn pinned_pool_is_placement_only() {
        // `new_pinned` may or may not succeed in binding cores (cgroup
        // cpusets, 2-core runners) — either way it must dispatch
        // exactly like an unpinned pool and report its request.
        let mut pinned = WorkerPool::new_pinned(2, true);
        let mut plain = WorkerPool::new(2);
        assert!(pinned.pinned());
        assert!(!plain.pinned());
        assert_eq!(pinned.workers(), plain.workers());
        let run = |pool: &mut WorkerPool| {
            let mut out = vec![0u32; 30];
            let mut fs: Vec<_> = out
                .chunks_mut(10)
                .enumerate()
                .map(|(k, c)| move || c.iter_mut().for_each(|v| *v = k as u32 + 7))
                .collect();
            pool.run_slice(&mut fs);
            drop(fs);
            out
        };
        assert_eq!(run(&mut pinned), run(&mut plain));
    }

    #[test]
    fn dispatch_counter_counts_published_epochs_only() {
        let mut pool = WorkerPool::new(2);
        assert_eq!(pool.dispatches(), 0);
        pool.run(&mut []); // empty: inline no-op
        let mut one: Vec<_> = vec![|| {}];
        pool.run_slice(&mut one); // single task: inline fast path
        assert_eq!(pool.dispatches(), 0, "inline paths never wake workers");
        for round in 1..=5u64 {
            let mut fs: Vec<_> = (0..3).map(|_| || {}).collect();
            pool.run_slice(&mut fs);
            assert_eq!(pool.dispatches(), round);
        }
        let mut inline_pool = WorkerPool::new(0);
        let mut fs: Vec<_> = (0..3).map(|_| || {}).collect();
        inline_pool.run_slice(&mut fs);
        assert_eq!(inline_pool.dispatches(), 0, "zero-worker pool runs inline");
    }

    #[test]
    fn construct_drop_churn_joins_workers() {
        // 60 pools × 2 workers: if Drop leaked threads this would leave
        // 120 of them; the Linux-only roster check in
        // tests/shard_invariance.rs asserts the count, here we just
        // exercise the join path (a deadlocked Drop would hang the test).
        for _ in 0..60 {
            let mut pool = WorkerPool::new(2);
            let mut fs: Vec<_> = (0..3).map(|_| || {}).collect();
            pool.run(&mut tasks_of(&mut fs));
        }
    }
}
