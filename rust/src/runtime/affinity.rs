//! Opt-in CPU pinning for the worker pool (`--pin-cores` /
//! `DECAFORK_PIN_CORES` — DESIGN.md §Locality & routing).
//!
//! ## Why pinning is a knob, not a default
//!
//! The sharded engine's shard↔worker mapping is *sticky* by
//! construction: task slot `k` of every phase always runs on pool
//! worker `k − 1` (slot 0 on the coordinator), so shard `k`'s
//! [`NodeStore`](crate::walks::NodeStore), mailbox rows and decision
//! buffers are always touched by the same OS thread and stay warm in
//! that thread's cache. Pinning adds the last binding — thread → core —
//! so the OS scheduler cannot migrate a worker away from the cache (or,
//! on multi-socket hosts, the NUMA domain) its shard's working set
//! lives in. That binding is the remainder of the ROADMAP 10⁸-node
//! item: first-touch allocation puts each shard's state on the owning
//! worker's node, and pinning keeps the worker there.
//!
//! It stays opt-in because it is only ever a *placement* hint:
//!
//! * on cgroup-restricted runners (CI containers, cpuset-limited
//!   hosts) the requested CPU may be outside the allowed mask and the
//!   syscall fails — we deliberately ignore the failure and run
//!   unpinned rather than abort;
//! * on an oversubscribed host (replications × shards > cores,
//!   `CoreBudget` notwithstanding) pinning two busy threads to one
//!   core is strictly worse than letting the scheduler spread them.
//!
//! Pinning can never change a trace: it decides where a thread runs,
//! never what any task computes — locked by
//! `pin_cores_is_placement_only_and_changes_no_trace` in
//! `tests/shard_invariance.rs`.

/// Pin the calling thread to `core` (taken modulo the kernel's
/// `CPU_SETSIZE` mask width). Returns `true` when the kernel accepted
/// the mask; `false` on failure (CPU outside the cgroup's cpuset,
/// core id beyond the machine) and always on non-Linux targets, where
/// this is a no-op. Callers treat `false` as "run unpinned", never as
/// an error.
pub fn pin_current_thread(core: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        // SAFETY: `cpu_set_t` is a plain bit array (all-zeroes is the
        // valid empty set); `sched_setaffinity(0, ..)` targets only the
        // calling thread and reads `set` before returning.
        unsafe {
            let mut set: libc::cpu_set_t = std::mem::zeroed();
            libc::CPU_ZERO(&mut set);
            libc::CPU_SET(core % libc::CPU_SETSIZE as usize, &mut set);
            libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = core;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_is_best_effort_and_never_panics() {
        // Whatever the host (bare metal, cgroup-restricted container,
        // non-Linux), pinning must degrade to a boolean — the engine
        // treats `false` as "run unpinned". An absurd core id must not
        // blow up either (it wraps modulo the mask width, and the
        // kernel rejects CPUs the machine doesn't have).
        let _ = pin_current_thread(0);
        let _ = pin_current_thread(usize::MAX);
        // A spawned thread pinning itself must not disturb this
        // thread's ability to keep running (the coordinator is never
        // pinned — see module docs).
        std::thread::spawn(|| {
            let _ = pin_current_thread(1);
        })
        .join()
        .unwrap();
    }
}
