//! Best-effort software prefetch (ISSUE 9).
//!
//! The block-pipelined hop and control phases (`sim/sharded.rs`) hide
//! memory latency by issuing prefetches for the *next* block's
//! dependent loads — CSR offset pairs, adjacency rows, `SlotIndex`
//! probe lines, `NodeState` rows — while the current block computes.
//! This module is the single place that knows how to spell a prefetch
//! per architecture; everything above it calls [`prefetch_read`] and
//! stays portable.
//!
//! Three properties the callers rely on:
//!
//! - **Advisory only.** A prefetch is a hint to the cache hierarchy; it
//!   never faults, never changes architectural state, and is legal on
//!   any address — including one past the end of a slice or a bucket a
//!   probe will never reach. Callers therefore do not bounds-check
//!   perfectly, only cheaply.
//! - **No-op fallback.** On targets without a stable prefetch spelling
//!   the function compiles to nothing, so the blocked path is portable
//!   (just not faster) everywhere the scalar path builds.
//! - **Result-invisible.** Because it touches no architectural state,
//!   interleaving prefetches into a loop cannot move a bit of the
//!   trace — the blocked-vs-scalar A/B oracle would catch it if it
//!   somehow did.

/// Hint the cache hierarchy that the line holding `*ptr` will be read
/// soon. Safe to call with any pointer value (no dereference occurs).
#[inline(always)]
pub fn prefetch_read<T>(ptr: *const T) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        // _MM_HINT_T0: fetch into all cache levels. Stable since 1.27.
        std::arch::x86_64::_mm_prefetch(ptr as *const i8, std::arch::x86_64::_MM_HINT_T0);
    }
    #[cfg(target_arch = "aarch64")]
    unsafe {
        // PRFM PLDL1KEEP: prefetch for load, L1, temporal. `nostack`
        // and `readonly` because the instruction only consumes an
        // address; it cannot write memory or touch the stack.
        std::arch::asm!(
            "prfm pldl1keep, [{0}]",
            in(reg) ptr,
            options(nostack, readonly, preserves_flags)
        );
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = ptr;
    }
}

/// Prefetch the element `slice[i]` if `i` is in bounds; silently skip
/// otherwise. The bounds check costs one compare — the point is to let
/// pipelined callers prefetch "block k+1" without replicating tail
/// logic.
#[inline(always)]
pub fn prefetch_slice<T>(slice: &[T], i: usize) {
    if let Some(item) = slice.get(i) {
        prefetch_read(item);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_is_a_pure_hint() {
        // Any address is legal, including dangling and null-ish ones;
        // the call must not fault and must not change the data.
        let v = vec![1u64, 2, 3];
        prefetch_read(&v[0]);
        prefetch_read(v.as_ptr().wrapping_add(1_000_000));
        prefetch_read(std::ptr::null::<u64>());
        assert_eq!(v, [1, 2, 3]);
    }

    #[test]
    fn prefetch_slice_skips_out_of_bounds() {
        let v = [7u32; 4];
        prefetch_slice(&v, 0);
        prefetch_slice(&v, 3);
        prefetch_slice(&v, 4); // out of bounds: no-op, no panic
        prefetch_slice::<u32>(&[], 0);
    }
}
