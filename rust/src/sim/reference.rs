//! The **frozen seed engine**: a verbatim-semantics copy of the
//! pre-arena `Engine` (boxed trait dispatch, one `Vec<Walk>` holding
//! every walk ever created, `O(history)` stepping, per-step `alive_ids`
//! scratch rebuild, sequential ids doubling as vector indices).
//!
//! It exists for two jobs and must not be "improved":
//!
//! 1. **Determinism oracle** — `tests/golden_traces.rs` asserts the
//!    arena engine reproduces this engine's `Trace::z` byte-for-byte on
//!    the golden scenarios ([`crate::scenario::presets::golden`]). Any
//!    edit here invalidates the lock.
//! 2. **Perf baseline** — `benches/perf_engine.rs` reports the arena
//!    engine's steps/sec as a multiple of this engine's on the same
//!    scenario (`BENCH_engine.json`).
//!
//! Scope of the freeze: this file pins the seed **engine core** (walk
//! storage, step loop, kill path, id scheme) **and the direct θ̂
//! arithmetic path**: node states are built with
//! [`NodeState::new_uncached`], so every survival term is computed the
//! seed way (no [`SurvivalTable`](crate::stats::SurvivalTable) memo) and
//! the golden-trace lock doubles as a cached-vs-direct equivalence
//! proof. Control and failure *implementations* are shared with the
//! arena engine — the lock proves engine-core equivalence, not
//! historical control behavior. One shared
//! implementation changed in the same PR: `PeriodicFork` now staggers
//! node phases (see `control/mod.rs`), so seed-era periodic-strawman
//! traces (ablation_strawman) are not reproducible bit-for-bit; none of
//! the golden scenarios use periodic control.
//!
//! Hooks and payloads are not supported; the learning layer runs on the
//! arena engine only.

use std::sync::Arc;

use crate::control::{ControlAlgorithm, VisitCtx};
use crate::failures::FailureModel;
use crate::graph::Graph;
use crate::rng::Rng;
use crate::sim::engine::{SimParams, StartPlacement};
use crate::sim::metrics::{Event, EventKind, Trace};
use crate::walks::{Lineage, NodeState, Walk, WalkId, WalkIdGen};

/// The seed engine, preserved for golden-trace and perf comparison.
pub struct ReferenceEngine {
    pub graph: Arc<Graph>,
    pub params: SimParams,
    walks: Vec<Walk>,
    states: Vec<NodeState>,
    control: Box<dyn ControlAlgorithm>,
    failures: Box<dyn FailureModel>,
    rng: Rng,
    idgen: WalkIdGen,
    t: u64,
    trace: Trace,
    alive_count: u32,
    /// Resolved control warm-up boundary.
    control_start: u64,
    /// Scratch buffer rebuilt every step (the seed's per-step cost).
    alive_ids: Vec<WalkId>,
}

impl ReferenceEngine {
    pub fn new(
        graph: Arc<Graph>,
        params: SimParams,
        control: Box<dyn ControlAlgorithm>,
        failures: Box<dyn FailureModel>,
        mut rng: Rng,
    ) -> Self {
        let n = graph.n();
        let z0 = params.z0;
        let mut idgen = WalkIdGen::new();
        let mut walks = Vec::with_capacity(z0 as usize);
        for slot in 0..z0 {
            let at = match params.start {
                StartPlacement::AtNode(v) => v,
                StartPlacement::Random => rng.below(n) as u32,
            };
            walks.push(Walk {
                id: idgen.fresh(),
                lineage: Lineage::Original { slot: slot as u16 },
                at,
                alive: true,
                born: 0,
                died: None,
                payload: None,
            });
        }
        // Seed semantics: θ̂ is evaluated directly, term by term — no
        // survival memo existed. Keeping the reference on the uncached
        // path makes the golden-trace lock prove cached-vs-direct θ̂
        // equivalence end-to-end, and gives `perf_control` its before
        // side.
        let states = (0..n)
            .map(|i| NodeState::new_uncached(z0 as usize, params.survival.resolve(&graph, i)))
            .collect();
        let mut trace = Trace::default();
        trace.z.push(z0);
        let control_start = params
            .control_start
            .unwrap_or_else(|| (1.5 * n as f64 * (n as f64).ln().max(1.0)).ceil() as u64);
        ReferenceEngine {
            graph,
            params,
            walks,
            states,
            control,
            failures,
            rng,
            idgen,
            t: 0,
            trace,
            alive_count: z0,
            control_start,
            alive_ids: Vec::new(),
        }
    }

    /// Number of active walks.
    pub fn alive(&self) -> u32 {
        self.alive_count
    }

    /// All walks ever created (dead ones included — the seed layout).
    pub fn walks(&self) -> &[Walk] {
        &self.walks
    }

    fn kill(&mut self, idx: usize, t: u64, node: u32, kind: EventKind) {
        let w = &mut self.walks[idx];
        if !w.alive {
            return;
        }
        w.alive = false;
        w.died = Some(t);
        self.alive_count -= 1;
        self.trace.events.push(Event { t, node, walk: w.id.0, kind });
    }

    /// Advance one time step (seed semantics, O(walks ever created)).
    pub fn step(&mut self) {
        self.t += 1;
        let t = self.t;

        // 1. External failure events (bursts, Byzantine state flips).
        self.alive_ids.clear();
        self.alive_ids
            .extend(self.walks.iter().filter(|w| w.alive).map(|w| w.id));
        let killed = self.failures.pre_step(t, &self.alive_ids, &mut self.rng);
        if !killed.is_empty() {
            // Ids are issued sequentially, so id.0 indexes `walks`.
            for id in killed {
                let idx = id.0 as usize;
                let node = self.walks[idx].at;
                self.kill(idx, t, node, EventKind::Failure);
            }
        }

        // 2. Every walk alive at the start of the step hops once. Walks
        //    forked during this step have `born == t` and do not hop.
        let snapshot_len = self.walks.len();
        for idx in 0..snapshot_len {
            if !self.walks[idx].alive || self.walks[idx].born == t {
                continue;
            }
            let from = self.walks[idx].at;
            let to = self.graph.step(from as usize, &mut self.rng) as u32;
            let wid = self.walks[idx].id;

            // 2a. Loss in transit.
            if self.failures.on_hop(t, wid, from, to, &mut self.rng) {
                self.kill(idx, t, from, EventKind::Failure);
                continue;
            }
            self.walks[idx].at = to;

            // 2b. Byzantine arrival.
            if self.failures.on_arrival(t, wid, to, &mut self.rng) {
                self.kill(idx, t, to, EventKind::Failure);
                continue;
            }

            // 2c. The node records the visit (return-time sample).
            let slot = self.walks[idx].lineage.slot();
            self.states[to as usize].observe(t, wid, slot);

            // 2d. Control decision — not during warm-up, and at most one
            //     per node per step (footnote 6).
            if t < self.control_start || self.states[to as usize].last_control_step == Some(t) {
                continue;
            }
            self.states[to as usize].last_control_step = Some(t);
            let decision = {
                let mut ctx = VisitCtx {
                    t,
                    node: to,
                    walk: wid,
                    slot,
                    z0: self.params.z0,
                    state: &mut self.states[to as usize],
                    rng: &mut self.rng,
                };
                self.control.on_visit(&mut ctx)
            };
            if self.params.record_theta {
                if let Some(th) = decision.theta {
                    self.trace.theta.push((t, th));
                }
            }
            for fork_slot in decision.forks {
                if self.alive_count as usize >= self.params.max_walks {
                    self.trace.capped = true;
                    break;
                }
                let child_id = self.idgen.fresh();
                let child = Walk {
                    id: child_id,
                    lineage: Lineage::Forked { parent: wid, by: to, at: t, slot: fork_slot },
                    at: to,
                    alive: true,
                    born: t,
                    died: None,
                    payload: None,
                };
                // The new walk is immediately visible to the forking node
                // (it "leaves the forking node" next step, footnote 7).
                self.states[to as usize].observe(t, child_id, fork_slot);
                self.walks.push(child);
                self.alive_count += 1;
                self.trace.events.push(Event { t, node: to, walk: child_id.0, kind: EventKind::Fork });
            }
            if decision.terminate {
                self.kill(idx, t, to, EventKind::ControlTermination);
            }
        }

        // 3. Housekeeping.
        if self.params.prune_every > 0 && t % self.params.prune_every == 0 {
            for s in &mut self.states {
                s.prune(t);
            }
        }
        self.trace.z.push(self.alive_count);
        if self.alive_count == 0 {
            self.trace.extinct = true;
        }
    }

    /// Run until `horizon` (inclusive), stopping early on extinction.
    pub fn run_to(&mut self, horizon: u64) {
        while self.t < horizon {
            if self.alive_count == 0 {
                self.trace.z.resize(horizon as usize + 1, 0);
                self.trace.extinct = true;
                self.t = horizon;
                break;
            }
            self.step();
        }
    }

    /// Consume the engine, returning its telemetry.
    pub fn into_trace(self) -> Trace {
        self.trace
    }

    /// Borrow telemetry.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::Decafork;
    use crate::failures::Burst;
    use crate::graph::generators;

    #[test]
    fn reference_reproduces_seed_behaviour() {
        // The seed suite's headline invariants, pinned on the frozen
        // engine so regressions here are caught independently of the
        // arena equivalence tests.
        let g = Arc::new(generators::random_regular(30, 4, &mut Rng::new(7)).unwrap());
        let mut e = ReferenceEngine::new(
            g,
            SimParams { z0: 10, ..Default::default() },
            Box::new(Decafork::new(2.0)),
            Box::new(Burst::new(vec![(800, 5)])),
            Rng::new(5),
        );
        e.run_to(2500);
        assert!(!e.trace().extinct);
        assert!(e.trace().recovery_time(800, 10).is_some());
        assert_eq!(e.trace().z.len(), 2501);
    }
}
