//! The synchronous multi-walk simulation engine.
//!
//! One call to [`Engine::step`] advances global time by one unit:
//! failures strike, every active walk hops to a uniformly random
//! neighbor, arrival nodes record visits and run the plugged-in control
//! algorithm (at most one decision per node per step, paper footnote 6).
//! Fork and termination actions take effect immediately — a forked walk
//! counts toward `Z_t` at once and starts hopping from the forking node on
//! the next step (footnote 7).

use std::sync::Arc;

use crate::control::{ControlAlgorithm, VisitCtx};
use crate::failures::FailureModel;
use crate::graph::Graph;
use crate::rng::Rng;
use crate::sim::metrics::{Event, EventKind, Trace};
use crate::walks::{Lineage, NodeState, SurvivalModel, Walk, WalkId, WalkIdGen};

/// Where the initial `Z0` walks start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartPlacement {
    /// All walks created by one node (the paper's footnote 4).
    AtNode(u32),
    /// Each walk starts at an independent uniformly random node.
    Random,
}

/// Application hook invoked on walk lifecycle events — the learning layer
/// implements this to run an SGD step per visit and to duplicate model
/// payloads on forks. Default impls make hooks opt-in.
pub trait VisitHook {
    /// Walk `walk` arrived at `node` at time `t` (after the node recorded
    /// the visit, before control runs).
    fn on_visit(&mut self, _t: u64, _node: u32, _walk: &mut Walk) {}

    /// `child` was just forked from `parent`; duplicate any payload.
    fn on_fork(&mut self, _t: u64, _parent: &Walk, _child: &mut Walk) {}

    /// Walk died (failure or deliberate termination).
    fn on_death(&mut self, _t: u64, _walk: &Walk) {}
}

/// No-op hook.
pub struct NoHook;
impl VisitHook for NoHook {}

/// How each node's survival function is instantiated (paper footnote 5:
/// the empirical distribution can be replaced by an analytic survival
/// function to speed up initialization and improve precision).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SurvivalSpec {
    /// Empirical return-time CDF per node (the algorithm's default).
    Empirical,
    /// Analytic geometric tail with the node's exact stationary rate:
    /// `q_i = π_i = deg(i)/2|E|` (Kac). Known closed form for random
    /// regular graphs (Tishby et al. 2021).
    AnalyticGeometric,
    /// Analytic exponential tail `λ_i = π_i` — the continuous relaxation
    /// used in the paper's theory (Assumption 1).
    AnalyticExponential,
    /// One fixed model for every node (tests / tools).
    Fixed(SurvivalModel),
}

impl SurvivalSpec {
    /// Resolve the model for node `i` of `g`.
    pub fn resolve(&self, g: &Graph, i: usize) -> SurvivalModel {
        match *self {
            SurvivalSpec::Empirical => SurvivalModel::Empirical,
            SurvivalSpec::AnalyticGeometric => SurvivalModel::Geometric { q: g.stationary(i) },
            SurvivalSpec::AnalyticExponential => {
                SurvivalModel::Exponential { lambda: g.stationary(i) }
            }
            SurvivalSpec::Fixed(m) => m,
        }
    }
}

/// Engine tuning parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Target / initial number of walks `Z0`.
    pub z0: u32,
    /// Survival model family for the nodes' estimators.
    pub survival: SurvivalSpec,
    pub start: StartPlacement,
    /// Record (t, θ̂) telemetry (costs memory; off for big sweeps).
    pub record_theta: bool,
    /// Control warm-up: no control decisions before this step. The paper
    /// (Sec. II) requires all `Z0` walks to have visited every node at
    /// least once before the first failure so return-time estimates are
    /// warm; starting cold makes every algorithm over-fork (unknown walks
    /// don't appear in `L_i`, so θ̂ starts at ½). `None` = auto:
    /// `⌈1.5 · n · ln n⌉`, a cover-time-scale bound.
    pub control_start: Option<u64>,
    /// Prune dead-weight last-seen entries every this many steps
    /// (0 = never). Pure optimization; see `NodeState::prune`.
    pub prune_every: u64,
    /// Hard cap on simultaneously active walks: beyond it forks are
    /// ignored and the trace is flagged `capped` (guards flooding
    /// strawmen like PeriodicFork with tiny periods).
    pub max_walks: usize,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            z0: 10,
            survival: SurvivalSpec::Empirical,
            start: StartPlacement::AtNode(0),
            record_theta: false,
            control_start: None,
            prune_every: 256,
            max_walks: 4096,
        }
    }
}

/// The simulation engine. Generic over nothing; control and failures are
/// boxed strategies so experiment configs stay data.
pub struct Engine {
    pub graph: Arc<Graph>,
    pub params: SimParams,
    walks: Vec<Walk>,
    states: Vec<NodeState>,
    control: Box<dyn ControlAlgorithm>,
    failures: Box<dyn FailureModel>,
    rng: Rng,
    idgen: WalkIdGen,
    t: u64,
    trace: Trace,
    alive_count: u32,
    /// Resolved control warm-up boundary.
    control_start: u64,
    /// Scratch buffer reused every step (avoids per-step allocation).
    alive_ids: Vec<WalkId>,
}

impl Engine {
    pub fn new(
        graph: Arc<Graph>,
        params: SimParams,
        control: Box<dyn ControlAlgorithm>,
        failures: Box<dyn FailureModel>,
        mut rng: Rng,
    ) -> Self {
        let n = graph.n();
        let z0 = params.z0;
        let mut idgen = WalkIdGen::new();
        let mut walks = Vec::with_capacity(z0 as usize);
        for slot in 0..z0 {
            let at = match params.start {
                StartPlacement::AtNode(v) => v,
                StartPlacement::Random => rng.below(n) as u32,
            };
            walks.push(Walk {
                id: idgen.fresh(),
                lineage: Lineage::Original { slot: slot as u16 },
                at,
                alive: true,
                born: 0,
                died: None,
                payload: None,
            });
        }
        let states = (0..n)
            .map(|i| NodeState::new(z0 as usize, params.survival.resolve(&graph, i)))
            .collect();
        let mut trace = Trace::default();
        trace.z.push(z0);
        let control_start = params
            .control_start
            .unwrap_or_else(|| (1.5 * n as f64 * (n as f64).ln().max(1.0)).ceil() as u64);
        Engine {
            graph,
            params,
            walks,
            states,
            control,
            failures,
            rng,
            idgen,
            t: 0,
            trace,
            alive_count: z0,
            control_start,
            alive_ids: Vec::new(),
        }
    }

    /// The resolved control warm-up boundary.
    pub fn control_start(&self) -> u64 {
        self.control_start
    }

    /// Current time.
    pub fn now(&self) -> u64 {
        self.t
    }

    /// Number of active walks.
    pub fn alive(&self) -> u32 {
        self.alive_count
    }

    /// All walks (including dead ones, for lineage inspection).
    pub fn walks(&self) -> &[Walk] {
        &self.walks
    }

    /// Node states (telemetry/tests).
    pub fn states(&self) -> &[NodeState] {
        &self.states
    }

    /// Mutable payload access for hooks run outside `step` (e.g. seeding).
    pub fn walks_mut(&mut self) -> &mut [Walk] {
        &mut self.walks
    }

    fn kill(&mut self, idx: usize, t: u64, node: u32, kind: EventKind, hook: &mut dyn VisitHook) {
        let w = &mut self.walks[idx];
        if !w.alive {
            return;
        }
        w.alive = false;
        w.died = Some(t);
        self.alive_count -= 1;
        self.trace.events.push(Event { t, node, walk: w.id.0, kind });
        hook.on_death(t, &self.walks[idx]);
    }

    /// Advance one time step with an application hook.
    pub fn step_with(&mut self, hook: &mut dyn VisitHook) {
        self.t += 1;
        let t = self.t;

        // 1. External failure events (bursts, Byzantine state flips).
        self.alive_ids.clear();
        self.alive_ids
            .extend(self.walks.iter().filter(|w| w.alive).map(|w| w.id));
        let killed = self.failures.pre_step(t, &self.alive_ids, &mut self.rng);
        if !killed.is_empty() {
            // Ids are issued sequentially, so id.0 indexes `walks`.
            for id in killed {
                let idx = id.0 as usize;
                let node = self.walks[idx].at;
                self.kill(idx, t, node, EventKind::Failure, hook);
            }
        }

        // 2. Every walk alive at the start of the step hops once. Walks
        //    forked during this step have `born == t` and do not hop.
        let snapshot_len = self.walks.len();
        for idx in 0..snapshot_len {
            if !self.walks[idx].alive || self.walks[idx].born == t {
                continue;
            }
            let from = self.walks[idx].at;
            let to = self.graph.step(from as usize, &mut self.rng) as u32;
            let wid = self.walks[idx].id;

            // 2a. Loss in transit.
            if self.failures.on_hop(t, wid, from, to, &mut self.rng) {
                self.kill(idx, t, from, EventKind::Failure, hook);
                continue;
            }
            self.walks[idx].at = to;

            // 2b. Byzantine arrival.
            if self.failures.on_arrival(t, wid, to, &mut self.rng) {
                self.kill(idx, t, to, EventKind::Failure, hook);
                continue;
            }

            // 2c. The node records the visit (return-time sample).
            let slot = self.walks[idx].lineage.slot();
            self.states[to as usize].observe(t, wid, slot);

            // 2d. Application work (e.g. one SGD step on the payload).
            hook.on_visit(t, to, &mut self.walks[idx]);

            // 2e. Control decision — not during warm-up, and at most one
            //     per node per step (footnote 6).
            if t < self.control_start || self.states[to as usize].last_control_step == Some(t) {
                continue;
            }
            self.states[to as usize].last_control_step = Some(t);
            let decision = {
                let mut ctx = VisitCtx {
                    t,
                    node: to,
                    walk: wid,
                    slot,
                    z0: self.params.z0,
                    state: &mut self.states[to as usize],
                    rng: &mut self.rng,
                };
                self.control.on_visit(&mut ctx)
            };
            if self.params.record_theta {
                if let Some(th) = decision.theta {
                    self.trace.theta.push((t, th));
                }
            }
            for fork_slot in decision.forks {
                if self.alive_count as usize >= self.params.max_walks {
                    self.trace.capped = true;
                    break;
                }
                let child_id = self.idgen.fresh();
                let mut child = Walk {
                    id: child_id,
                    lineage: Lineage::Forked { parent: wid, by: to, at: t, slot: fork_slot },
                    at: to,
                    alive: true,
                    born: t,
                    died: None,
                    payload: None,
                };
                hook.on_fork(t, &self.walks[idx], &mut child);
                // The new walk is immediately visible to the forking node
                // (it "leaves the forking node" next step, footnote 7).
                self.states[to as usize].observe(t, child_id, fork_slot);
                self.walks.push(child);
                self.alive_count += 1;
                self.trace.events.push(Event { t, node: to, walk: child_id.0, kind: EventKind::Fork });
            }
            if decision.terminate {
                self.kill(idx, t, to, EventKind::ControlTermination, hook);
            }
        }

        // 3. Housekeeping.
        if self.params.prune_every > 0 && t % self.params.prune_every == 0 {
            for s in &mut self.states {
                s.prune(t);
            }
        }
        self.trace.z.push(self.alive_count);
        if self.alive_count == 0 {
            self.trace.extinct = true;
        }
    }

    /// Advance one step without application hooks.
    pub fn step(&mut self) {
        let mut h = NoHook;
        self.step_with(&mut h);
    }

    /// Run until `horizon` (inclusive), stopping early on extinction
    /// (the population can never recover from zero — the catastrophic
    /// failure the paper is designed to prevent; the trace is padded with
    /// zeros so aggregation windows line up).
    pub fn run_to(&mut self, horizon: u64) {
        self.run_to_with(horizon, &mut NoHook)
    }

    /// `run_to` with an application hook.
    pub fn run_to_with(&mut self, horizon: u64, hook: &mut dyn VisitHook) {
        while self.t < horizon {
            if self.alive_count == 0 {
                self.trace.z.resize(horizon as usize + 1, 0);
                self.trace.extinct = true;
                self.t = horizon;
                break;
            }
            self.step_with(hook);
        }
    }

    /// Consume the engine, returning its telemetry.
    pub fn into_trace(self) -> Trace {
        self.trace
    }

    /// Borrow telemetry.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{Decafork, NoControl};
    use crate::failures::{Burst, NoFailures, Probabilistic};
    use crate::graph::generators;

    fn small_graph() -> Arc<Graph> {
        Arc::new(generators::random_regular(30, 4, &mut Rng::new(7)).unwrap())
    }

    #[test]
    fn population_constant_without_failures_or_control() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 5, ..Default::default() },
            Box::new(NoControl),
            Box::new(NoFailures),
            Rng::new(1),
        );
        e.run_to(500);
        assert_eq!(e.alive(), 5);
        assert!(e.trace().z.iter().all(|&z| z == 5));
        assert!(e.trace().events.is_empty());
    }

    #[test]
    fn burst_reduces_population_permanently_without_control() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 10, ..Default::default() },
            Box::new(NoControl),
            Box::new(Burst::new(vec![(50, 4)])),
            Rng::new(2),
        );
        e.run_to(100);
        assert_eq!(e.alive(), 6);
        assert_eq!(e.trace().z[49], 10);
        assert_eq!(e.trace().z[50], 6);
        assert_eq!(e.trace().count(EventKind::Failure), 4);
    }

    #[test]
    fn extinction_flagged_and_padded() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 3, ..Default::default() },
            Box::new(NoControl),
            Box::new(Probabilistic::new(0.5)),
            Rng::new(3),
        );
        e.run_to(200);
        assert!(e.trace().extinct);
        assert_eq!(e.trace().z.len(), 201);
        assert_eq!(*e.trace().z.last().unwrap(), 0);
    }

    #[test]
    fn z_trace_consistent_with_events() {
        // Conservation: z[t] - z[t-1] == forks(t) - deaths(t).
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 8, record_theta: true, ..Default::default() },
            Box::new(Decafork::new(2.0)),
            Box::new(Burst::new(vec![(100, 4), (300, 3)])),
            Rng::new(4),
        );
        e.run_to(600);
        let tr = e.trace();
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            match ev.kind {
                EventKind::Fork => delta[ev.t as usize] += 1,
                _ => delta[ev.t as usize] -= 1,
            }
        }
        for t in 1..tr.z.len() {
            assert_eq!(
                tr.z[t] as i64 - tr.z[t - 1] as i64,
                delta[t],
                "conservation violated at t={t}"
            );
        }
    }

    #[test]
    fn decafork_recovers_from_burst() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 10, ..Default::default() },
            Box::new(Decafork::new(2.0)),
            Box::new(Burst::new(vec![(800, 5)])),
            Rng::new(5),
        );
        e.run_to(2500);
        let tr = e.trace();
        assert!(!tr.extinct);
        let rec = tr.recovery_time(800, 10);
        assert!(rec.is_some(), "never recovered: final z = {}", e.alive());
        // Should not massively overshoot either.
        assert!(tr.max_z(800, 2500) <= 16, "overshoot {}", tr.max_z(800, 2500));
    }

    #[test]
    fn forked_walk_waits_one_step() {
        // A walk forked at t has born == t and must not hop until t+1;
        // verified indirectly: forked walks appear in the trace and the
        // engine never panics on the same-step snapshot boundary.
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 4, control_start: Some(0), ..Default::default() },
            Box::new(Decafork { epsilon: 50.0, p: Some(1.0) }), // forks every visit
            Box::new(NoFailures),
            Rng::new(6),
        );
        for _ in 0..3 {
            e.step();
        }
        assert!(e.alive() > 4);
        for w in e.walks() {
            if let Lineage::Forked { at, .. } = w.lineage {
                assert!(at >= w.born);
            }
        }
    }

    #[test]
    fn max_walks_cap_enforced() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 4, max_walks: 16, control_start: Some(0), ..Default::default() },
            Box::new(Decafork { epsilon: 100.0, p: Some(1.0) }),
            Box::new(NoFailures),
            Rng::new(7),
        );
        e.run_to(100);
        assert!(e.alive() <= 16);
        assert!(e.trace().capped);
    }

    #[test]
    fn deterministic_under_seed() {
        let mk = |seed| {
            let mut e = Engine::new(
                small_graph(),
                SimParams { z0: 10, ..Default::default() },
                Box::new(Decafork::new(2.0)),
                Box::new(Burst::paper_default()),
                Rng::new(seed),
            );
            e.run_to(3000);
            e.into_trace().z
        };
        assert_eq!(mk(11), mk(11));
        assert_ne!(mk(11), mk(12));
    }

    #[test]
    fn hook_sees_visits_forks_deaths() {
        struct Counter {
            visits: usize,
            forks: usize,
            deaths: usize,
        }
        impl VisitHook for Counter {
            fn on_visit(&mut self, _t: u64, _n: u32, _w: &mut Walk) {
                self.visits += 1;
            }
            fn on_fork(&mut self, _t: u64, _p: &Walk, _c: &mut Walk) {
                self.forks += 1;
            }
            fn on_death(&mut self, _t: u64, _w: &Walk) {
                self.deaths += 1;
            }
        }
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 6, ..Default::default() },
            Box::new(Decafork::new(2.0)),
            Box::new(Burst::new(vec![(40, 3)])),
            Rng::new(8),
        );
        let mut h = Counter { visits: 0, forks: 0, deaths: 0 };
        e.run_to_with(300, &mut h);
        assert!(h.visits > 1000);
        assert_eq!(h.deaths, e.trace().count(EventKind::Failure) + e.trace().count(EventKind::ControlTermination));
        assert_eq!(h.forks, e.trace().count(EventKind::Fork));
    }
}
