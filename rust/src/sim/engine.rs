//! The synchronous multi-walk simulation engine, built on the
//! [`WalkArena`].
//!
//! One call to [`Engine::step`] advances global time by one unit:
//! failures strike, every active walk hops to a uniformly random
//! neighbor, arrival nodes record visits and run the plugged-in control
//! algorithm (at most one decision per node per step, paper footnote 6).
//! Fork and termination actions take effect immediately — a forked walk
//! counts toward `Z_t` at once and starts hopping from the forking node on
//! the next step (footnote 7).
//!
//! ## Hot-loop shape (DESIGN.md §Walk arena)
//!
//! Per-step cost is **O(live walks)**, not O(walks ever created): the
//! arena's dense struct-of-arrays columns hold only live walks, in
//! creation order. The step is organized around two compaction barriers:
//!
//! 1. pre-step failures kill → **compact** → the hop loop scans a dense,
//!    all-alive prefix with no liveness or `born == t` checks (walks
//!    forked during the step are appended past the scan bound, and
//!    mid-loop kills only ever target the walk currently being
//!    processed);
//! 2. end of step → **compact** → `Z_t` recorded.
//!
//! Compaction is stable (creation-order preserving), which is what keeps
//! the RNG draw sequence — and therefore every trace — byte-identical to
//! the frozen [`ReferenceEngine`](crate::sim::reference::ReferenceEngine)
//! (`tests/golden_traces.rs`). Control and failure models are
//! enum-dispatched ([`Control`], [`Failures`]) so their per-visit code
//! inlines into this loop instead of bouncing through vtables.

use std::sync::Arc;

use crate::control::{Control, VisitCtx};
use crate::failures::Failures;
use crate::graph::Graph;
use crate::obs::{MetricsConfig, MetricsSink};
use crate::rng::Rng;
use crate::runtime::telemetry::{Phase, Telemetry, WorkerCounters};
use crate::sim::metrics::{Event, EventKind, Trace};
use crate::walks::{
    Lineage, NodeStateMode, NodeStore, StatesView, SurvivalModel, Walk, WalkArena, WalkMut, WalkRef,
};

/// Where the initial `Z0` walks start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartPlacement {
    /// All walks created by one node (the paper's footnote 4).
    AtNode(u32),
    /// Each walk starts at an independent uniformly random node.
    Random,
}

/// Application hook invoked on walk lifecycle events — the learning layer
/// implements this to run an SGD step per visit and to duplicate model
/// payloads on forks. Default impls make hooks opt-in.
///
/// Hooks see arena views, not owned records: [`WalkMut`] exposes the
/// walk's identity read-only plus a mutable borrow of its payload slot
/// (the only field application code may change); [`WalkRef`] is a cheap
/// by-value copy. Dead walks arrive as materialized [`Walk`] records from
/// the arena graveyard.
pub trait VisitHook {
    /// Walk `walk` arrived at `node` at time `t` (after the node recorded
    /// the visit, before control runs).
    fn on_visit(&mut self, _t: u64, _node: u32, _walk: WalkMut<'_>) {}

    /// `child` was just forked from `parent`; duplicate any payload.
    fn on_fork(&mut self, _t: u64, _parent: WalkRef, _child: WalkMut<'_>) {}

    /// Walk died (failure or deliberate termination).
    fn on_death(&mut self, _t: u64, _walk: &Walk) {}
}

/// No-op hook.
pub struct NoHook;
impl VisitHook for NoHook {}

/// How each node's survival function is instantiated (paper footnote 5:
/// the empirical distribution can be replaced by an analytic survival
/// function to speed up initialization and improve precision).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SurvivalSpec {
    /// Empirical return-time CDF per node (the algorithm's default).
    Empirical,
    /// Analytic geometric tail with the node's exact stationary rate:
    /// `q_i = π_i = deg(i)/2|E|` (Kac). Known closed form for random
    /// regular graphs (Tishby et al. 2021).
    AnalyticGeometric,
    /// Analytic exponential tail `λ_i = π_i` — the continuous relaxation
    /// used in the paper's theory (Assumption 1).
    AnalyticExponential,
    /// One fixed model for every node (tests / tools).
    Fixed(SurvivalModel),
}

impl SurvivalSpec {
    /// Resolve the model for node `i` of `g`.
    pub fn resolve(&self, g: &Graph, i: usize) -> SurvivalModel {
        match *self {
            SurvivalSpec::Empirical => SurvivalModel::Empirical,
            SurvivalSpec::AnalyticGeometric => SurvivalModel::Geometric { q: g.stationary(i) },
            SurvivalSpec::AnalyticExponential => {
                SurvivalModel::Exponential { lambda: g.stationary(i) }
            }
            SurvivalSpec::Fixed(m) => m,
        }
    }
}

/// Engine tuning parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Target / initial number of walks `Z0`.
    pub z0: u32,
    /// Survival model family for the nodes' estimators.
    pub survival: SurvivalSpec,
    pub start: StartPlacement,
    /// Record (t, θ̂) telemetry (costs memory; off for big sweeps).
    pub record_theta: bool,
    /// Control warm-up: no control decisions before this step. The paper
    /// (Sec. II) requires all `Z0` walks to have visited every node at
    /// least once before the first failure so return-time estimates are
    /// warm; starting cold makes every algorithm over-fork (unknown walks
    /// don't appear in `L_i`, so θ̂ starts at ½). `None` = auto:
    /// `⌈1.5 · n · ln n⌉`, a cover-time-scale bound.
    pub control_start: Option<u64>,
    /// Prune dead-weight last-seen entries every this many steps
    /// (0 = never). Pure optimization; see `NodeState::prune`.
    pub prune_every: u64,
    /// Hard cap on simultaneously active walks: beyond it forks are
    /// ignored and the trace is flagged `capped` (guards flooding
    /// strawmen like PeriodicFork with tiny periods).
    pub max_walks: usize,
    /// Engine-selection knob for the runner layer: `1` (default) keeps
    /// the shared-stream arena [`Engine`]; `>= 2` selects the stream-mode
    /// [`ShardedEngine`](crate::sim::sharded::ShardedEngine) with that
    /// many workers. This [`Engine`] itself ignores the field. NOTE:
    /// stream mode is a *different trace family* (per-walk RNG streams):
    /// `1 → 2` changes results, while any two counts `>= 1` **within
    /// stream mode** (`Scenario::sharded_engine`) are bit-identical.
    pub shards: usize,
    /// Node-state storage (`--node-state` / `DECAFORK_NODE_STATE`):
    /// `Lazy` (default) materializes a node's estimator state on first
    /// visit — O(visited) memory and prune sweeps, the mode that makes
    /// `scale_100m` runnable; `Dense` keeps the eager O(n) columns as
    /// the A/B oracle. Bit-identical by construction (DESIGN.md §Lazy
    /// node store), locked by `prop_lazy_store_bit_identical_to_dense`
    /// and both golden families.
    pub node_state: NodeStateMode,
    /// Arrival-routing strategy for the stream-mode engine (`--routing`
    /// / `DECAFORK_ROUTING`): `Mailbox` (default) makes the hop workers
    /// bin surviving walks into per-(chunk × destination-shard)
    /// mailboxes so the coordinator's inter-phase work is O(shards);
    /// `Serial` keeps the original O(live-walks) coordinator scan as
    /// the A/B oracle. Bit-identical by construction (DESIGN.md
    /// §Locality & routing), locked by
    /// `prop_mailbox_routing_bit_identical_to_serial` and both golden
    /// families. The single-arena [`Engine`] ignores the field.
    pub routing: RoutingMode,
    /// Pin pool worker `k` to CPU core `k + 1` (`--pin-cores` /
    /// `DECAFORK_PIN_CORES`, Linux only, best-effort). Placement hint
    /// only — can never change a trace; see
    /// [`runtime::affinity`](crate::runtime::affinity) for why it is
    /// off by default.
    pub pin_cores: bool,
    /// Hot-phase execution strategy for the stream-mode engine
    /// (`--hop-path` / `DECAFORK_HOP_PATH`): `Blocked` (default) runs
    /// the hop and control phases as block-pipelined stages over
    /// 64-walk blocks — gather, software-prefetch the next block's
    /// dependent lines, batched `Graph::step_block` — so each worker
    /// keeps many memory misses in flight instead of one; `Scalar`
    /// keeps the original one-walk-at-a-time loops as the A/B oracle.
    /// Per-walk draw order and stream ownership are untouched, so the
    /// paths are bit-identical by construction (DESIGN.md §Block
    /// pipelining), locked by `prop_blocked_hop_bit_identical_to_scalar`
    /// and both golden families. The single-arena [`Engine`] ignores
    /// the field (its walks share one RNG stream, so there is no
    /// per-walk batching to pipeline).
    pub hop_path: HopPath,
    /// Streaming telemetry (`--metrics` / `DECAFORK_METRICS`, plus
    /// `--metrics-out` / `--metrics-every`): `Off` (default) records
    /// nothing; `Jsonl`/`Csv` stream per-period step records (phase
    /// spans, worker counters, Z_t, θ̂ summary, failure/recovery
    /// series) to the configured path. Pure observation — telemetry
    /// reads clocks and counters after the step's trace updates and
    /// never touches an RNG stream or reorders work, so traces are
    /// bit-identical for off/jsonl/csv (DESIGN.md §Observability,
    /// locked by `prop_metrics_sink_is_observation_only` and both
    /// golden families).
    pub metrics: MetricsConfig,
}

/// How stream-mode arrivals travel from the hop phase to the control
/// phase (see [`SimParams::routing`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingMode {
    /// Coordinator scans the full dense position column between the
    /// phases — O(live walks) of serial work per step.
    Serial,
    /// Hop workers route arrivals into per-(chunk × shard) mailboxes
    /// in parallel; the coordinator only hands the mailbox rows to the
    /// control tasks — O(shards) of serial work per step.
    Mailbox,
}

/// How the stream-mode hot phases execute each chunk (see
/// [`SimParams::hop_path`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HopPath {
    /// One walk at a time: each iteration chains CSR offset →
    /// adjacency row (hop) or index probe → state row (control) through
    /// dependent random loads, so each worker has ~one memory miss in
    /// flight. Kept as the selectable A/B oracle.
    Scalar,
    /// Block-pipelined stages over 64-walk blocks: prefetch the next
    /// block's lines while drawing the current block through
    /// `Graph::step_block`, then replay failure checks / mailbox
    /// binning per block. Same draws from the same per-walk streams in
    /// the same order — bit-identical to `Scalar`, just overlapped.
    Blocked,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            z0: 10,
            survival: SurvivalSpec::Empirical,
            start: StartPlacement::AtNode(0),
            record_theta: false,
            control_start: None,
            prune_every: 256,
            max_walks: 4096,
            shards: 1,
            node_state: NodeStateMode::Lazy,
            routing: RoutingMode::Mailbox,
            pin_cores: false,
            hop_path: HopPath::Blocked,
            metrics: MetricsConfig::default(),
        }
    }
}

/// The simulation engine. Control and failure strategies are closed-world
/// enums so the compiler inlines their per-visit decisions into the hop
/// loop; experiment configs stay data (see [`crate::scenario`]).
pub struct Engine {
    pub graph: Arc<Graph>,
    pub params: SimParams,
    arena: WalkArena,
    states: NodeStore,
    control: Control,
    failures: Failures,
    rng: Rng,
    t: u64,
    trace: Trace,
    /// Resolved control warm-up boundary.
    control_start: u64,
    /// Observation-only telemetry accumulator (no-op when metrics are
    /// off; see DESIGN.md §Observability).
    tel: Telemetry,
    /// Streaming metrics sink (`None` when metrics are off).
    sink: Option<MetricsSink>,
}

impl Engine {
    pub fn new(
        graph: Arc<Graph>,
        params: SimParams,
        control: impl Into<Control>,
        failures: impl Into<Failures>,
        mut rng: Rng,
    ) -> Self {
        let n = graph.n();
        let z0 = params.z0;
        let mut arena = WalkArena::with_capacity(z0 as usize);
        for slot in 0..z0 {
            let at = match params.start {
                StartPlacement::AtNode(v) => v,
                StartPlacement::Random => rng.below(n) as u32,
            };
            arena.spawn(at, 0, Lineage::Original { slot: slot as u16 });
        }
        // Cached θ̂: per-node SurvivalTable memo — bit-identical to the
        // reference engine's direct evaluation (golden-trace lock), but
        // each survival term is an indexed load instead of an exp/CDF
        // division (`benches/perf_control.rs` measures the gap). The
        // store materializes each state lazily on first visit by
        // default (no per-node streams here — decisions draw from the
        // single shared engine stream, so `node_root` is `None`).
        let states = NodeStore::new(
            params.node_state,
            graph.clone(),
            0,
            n as u32,
            z0 as usize,
            params.survival,
            None,
        );
        let mut trace = Trace::default();
        trace.z.push(z0);
        let control_start = params
            .control_start
            .unwrap_or_else(|| (1.5 * n as f64 * (n as f64).ln().max(1.0)).ceil() as u64);
        let tel = Telemetry::new(params.metrics.enabled());
        let mut sink = MetricsSink::new(&params.metrics);
        if let Some(s) = &mut sink {
            s.prime(z0);
        }
        Engine {
            graph,
            params,
            arena,
            states,
            control: control.into(),
            failures: failures.into(),
            rng,
            t: 0,
            trace,
            control_start,
            tel,
            sink,
        }
    }

    /// The resolved control warm-up boundary.
    pub fn control_start(&self) -> u64 {
        self.control_start
    }

    /// Current time.
    pub fn now(&self) -> u64 {
        self.t
    }

    /// Number of active walks.
    pub fn alive(&self) -> u32 {
        self.arena.live()
    }

    /// The walk store (telemetry/tests).
    pub fn arena(&self) -> &WalkArena {
        &self.arena
    }

    /// Materialize every walk — live and retired — for lineage
    /// inspection and reports. Cold path; allocates.
    pub fn snapshot(&self) -> Vec<Walk> {
        self.arena.snapshot()
    }

    /// Node states (telemetry/tests): a visited-aware view — in the
    /// default lazy mode only visited nodes carry state, so there is no
    /// dense slice to hand out.
    pub fn states(&self) -> StatesView<'_> {
        StatesView::single(&self.states)
    }

    /// Mutable access to the live walks' payload slots, in creation
    /// order — used by application layers to seed payloads before the
    /// run (e.g. one model per initial walk).
    pub fn payloads_mut(&mut self) -> impl Iterator<Item = &mut Option<usize>> {
        self.arena.payloads_mut()
    }

    /// Retire the walk at dense position `dense`: trace event, graveyard
    /// move, death hook. Mirrors the reference engine's `kill` ordering.
    fn kill_dense(
        &mut self,
        dense: usize,
        t: u64,
        node: u32,
        kind: EventKind,
        hook: &mut dyn VisitHook,
    ) {
        let id = self.arena.id_at(dense);
        self.trace.events.push(Event { t, node, walk: id.0, kind });
        let dead = self.arena.retire(dense, t);
        hook.on_death(t, dead);
    }

    /// Advance one time step with an application hook.
    pub fn step_with(&mut self, hook: &mut dyn VisitHook) {
        self.t += 1;
        let t = self.t;

        // Telemetry is observation only: clock reads between phases,
        // counter deltas after the fact, sink IO after the trace
        // updates. Nothing below this line may touch `self.rng` or
        // reorder work (DESIGN.md §Observability).
        let tel_on = self.tel.enabled();
        let events_start = self.trace.events.len();
        let visited0 = self.states.visited_count();
        let (mut hop_deaths, mut visits) = (0u64, 0u64);
        let step_clock = tel_on.then(std::time::Instant::now);

        // 1. External failure events (bursts, Byzantine state flips). The
        //    arena's dense id column *is* the alive roster — no per-step
        //    scratch rebuild.
        let killed = self.failures.pre_step(t, self.arena.ids(), &mut self.rng);
        for id in killed {
            // Stale ids (never minted, or already retired) resolve to
            // None instead of relying on id==index.
            if let Some(dense) = self.arena.resolve(id) {
                let node = self.arena.position(dense);
                self.kill_dense(dense, t, node, EventKind::Failure, hook);
            }
        }
        self.arena.compact();

        let hop_clock = step_clock.map(|c| {
            self.tel.record_span(Phase::PreStep, c.elapsed().as_nanos() as u64);
            std::time::Instant::now()
        });

        // 2. Every walk alive at the start of the step hops once. After
        //    the barrier the dense prefix [0, len0) is exactly those
        //    walks, in creation order; forks spawned below land at
        //    positions >= len0 and hop next step (footnote 7). Mid-loop
        //    kills only ever hit the walk being processed, so no
        //    liveness check is needed on entry.
        let len0 = self.arena.dense_len();
        for i in 0..len0 {
            let from = self.arena.position(i);
            let to = self.graph.step(from as usize, &mut self.rng) as u32;
            let wid = self.arena.id_at(i);

            // 2a. Loss in transit.
            if self.failures.on_hop(t, wid, from, to, &mut self.rng) {
                self.kill_dense(i, t, from, EventKind::Failure, hook);
                hop_deaths += 1;
                continue;
            }
            self.arena.set_position(i, to);

            // 2b. Byzantine arrival.
            if self.failures.on_arrival(t, wid, to, &mut self.rng) {
                self.kill_dense(i, t, to, EventKind::Failure, hook);
                hop_deaths += 1;
                continue;
            }
            visits += 1;

            // 2c. The node records the visit (return-time sample). First
            //     visit of a lazily-stored node materializes its state
            //     here — a pure construction, so no RNG draw moves.
            let slot = self.arena.lineage_at(i).slot();
            self.states.state_mut(to).observe(t, wid, slot);

            // 2d. Application work (e.g. one SGD step on the payload).
            hook.on_visit(t, to, self.arena.walk_mut(i));

            // 2e. Control decision — not during warm-up, and at most one
            //     per node per step (footnote 6).
            if t < self.control_start || self.states.state_mut(to).last_control_step == Some(t) {
                continue;
            }
            self.states.state_mut(to).last_control_step = Some(t);
            let decision = {
                let mut ctx = VisitCtx {
                    t,
                    node: to,
                    walk: wid,
                    slot,
                    z0: self.params.z0,
                    state: self.states.state_mut(to),
                    rng: &mut self.rng,
                };
                self.control.on_visit(&mut ctx)
            };
            if self.params.record_theta {
                if let Some(th) = decision.theta {
                    self.trace.theta.push((t, th));
                }
            }
            if tel_on {
                if let Some(th) = decision.theta {
                    self.tel.observe_theta(th);
                }
            }
            if !decision.forks.is_empty() {
                let parent = self.arena.walk_ref(i);
                for fork_slot in decision.forks {
                    if self.arena.live() as usize >= self.params.max_walks {
                        self.trace.capped = true;
                        break;
                    }
                    let lineage = Lineage::Forked { parent: wid, by: to, at: t, slot: fork_slot };
                    let (child_id, child) = self.arena.spawn(to, t, lineage);
                    hook.on_fork(t, parent, self.arena.walk_mut(child));
                    // The new walk is immediately visible to the forking
                    // node (it "leaves the forking node" next step,
                    // footnote 7).
                    self.states.state_mut(to).observe(t, child_id, fork_slot);
                    self.trace.events.push(Event {
                        t,
                        node: to,
                        walk: child_id.0,
                        kind: EventKind::Fork,
                    });
                }
            }
            if decision.terminate {
                self.kill_dense(i, t, to, EventKind::ControlTermination, hook);
            }
        }

        // The shared-stream engine fuses hop + visit + control into one
        // loop, so the whole loop is charged to the hop span and the
        // control span is recorded as 0 (the sharded engine is where
        // the phases are separable).
        let merge_clock = hop_clock.map(|c| {
            self.tel.record_span(Phase::Hop, c.elapsed().as_nanos() as u64);
            self.tel.record_span(Phase::Control, 0);
            std::time::Instant::now()
        });

        // 3. Housekeeping. The sweep walks the store's materialized
        //    column only — O(visited) in lazy mode, and exact: a state
        //    that was never materialized holds nothing to prune.
        if self.params.prune_every > 0 && t % self.params.prune_every == 0 {
            self.states.prune(t);
        }
        self.arena.compact();
        self.trace.z.push(self.arena.live());
        if self.arena.live() == 0 {
            self.trace.extinct = true;
        }

        if tel_on {
            if let Some(c) = merge_clock {
                self.tel.record_span(Phase::Merge, c.elapsed().as_nanos() as u64);
            }
            let mut wc = WorkerCounters {
                hopped: len0 as u64,
                hop_deaths,
                visits,
                materializations: (self.states.visited_count() - visited0) as u64,
                ..Default::default()
            };
            self.tel.fold_workers(std::slice::from_mut(&mut wc));
            let (mut forks, mut terms, mut fails) = (0u64, 0u64, 0u64);
            for ev in &self.trace.events[events_start..] {
                match ev.kind {
                    EventKind::Fork => forks += 1,
                    EventKind::ControlTermination => terms += 1,
                    EventKind::Failure => fails += 1,
                }
            }
            self.tel.count_events(forks, terms, fails);
            self.tel.end_step();
            let live = self.arena.live();
            if let Some(sink) = &mut self.sink {
                sink.on_step(t, live, fails, &mut self.tel, None);
            }
        }
    }

    /// Advance one step without application hooks.
    pub fn step(&mut self) {
        let mut h = NoHook;
        self.step_with(&mut h);
    }

    /// Run until `horizon` (inclusive), stopping early on extinction
    /// (the population can never recover from zero — the catastrophic
    /// failure the paper is designed to prevent; the trace is padded with
    /// zeros so aggregation windows line up).
    pub fn run_to(&mut self, horizon: u64) {
        self.run_to_with(horizon, &mut NoHook)
    }

    /// `run_to` with an application hook.
    pub fn run_to_with(&mut self, horizon: u64, hook: &mut dyn VisitHook) {
        while self.t < horizon {
            if self.arena.live() == 0 {
                self.trace.z.resize(horizon as usize + 1, 0);
                self.trace.extinct = true;
                self.t = horizon;
                break;
            }
            self.step_with(hook);
        }
    }

    /// Consume the engine, returning its telemetry. The trace is
    /// stamped with the run's visited-state footprint (how many node
    /// states were materialized and their resident bytes) — summary
    /// metadata only, never part of [`Trace::bit_identical`].
    pub fn into_trace(mut self) -> Trace {
        self.trace.visited_nodes = self.states.visited_count();
        self.trace.state_bytes = StatesView::single(&self.states).memory_bytes();
        self.trace
    }

    /// Borrow telemetry.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{Decafork, NoControl};
    use crate::failures::{Burst, NoFailures, Probabilistic};
    use crate::graph::generators;
    use crate::walks::WalkId;
    use std::collections::HashSet;

    fn small_graph() -> Arc<Graph> {
        Arc::new(generators::random_regular(30, 4, &mut Rng::new(7)).unwrap())
    }

    #[test]
    fn population_constant_without_failures_or_control() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 5, ..Default::default() },
            NoControl,
            NoFailures,
            Rng::new(1),
        );
        e.run_to(500);
        assert_eq!(e.alive(), 5);
        assert!(e.trace().z.iter().all(|&z| z == 5));
        assert!(e.trace().events.is_empty());
    }

    #[test]
    fn burst_reduces_population_permanently_without_control() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 10, ..Default::default() },
            NoControl,
            Burst::new(vec![(50, 4)]),
            Rng::new(2),
        );
        e.run_to(100);
        assert_eq!(e.alive(), 6);
        assert_eq!(e.trace().z[49], 10);
        assert_eq!(e.trace().z[50], 6);
        assert_eq!(e.trace().count(EventKind::Failure), 4);
        assert_eq!(e.arena().graveyard().len(), 4);
    }

    #[test]
    fn extinction_flagged_and_padded() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 3, ..Default::default() },
            NoControl,
            Probabilistic::new(0.5),
            Rng::new(3),
        );
        e.run_to(200);
        assert!(e.trace().extinct);
        assert_eq!(e.trace().z.len(), 201);
        assert_eq!(*e.trace().z.last().unwrap(), 0);
    }

    #[test]
    fn z_trace_consistent_with_events() {
        // Conservation: z[t] - z[t-1] == forks(t) - deaths(t).
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 8, record_theta: true, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(100, 4), (300, 3)]),
            Rng::new(4),
        );
        e.run_to(600);
        let tr = e.trace();
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            match ev.kind {
                EventKind::Fork => delta[ev.t as usize] += 1,
                _ => delta[ev.t as usize] -= 1,
            }
        }
        for t in 1..tr.z.len() {
            assert_eq!(
                tr.z[t] as i64 - tr.z[t - 1] as i64,
                delta[t],
                "conservation violated at t={t}"
            );
        }
    }

    #[test]
    fn decafork_recovers_from_burst() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 10, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(800, 5)]),
            Rng::new(5),
        );
        e.run_to(2500);
        let tr = e.trace();
        assert!(!tr.extinct);
        let rec = tr.recovery_time(800, 10);
        assert!(rec.is_some(), "never recovered: final z = {}", e.alive());
        // Should not massively overshoot either.
        assert!(tr.max_z(800, 2500) <= 16, "overshoot {}", tr.max_z(800, 2500));
    }

    #[test]
    fn forked_walk_waits_one_step() {
        // A walk forked at t has born == t and must not hop until t+1;
        // verified indirectly: forked walks appear in the trace and the
        // engine never panics on the same-step snapshot boundary.
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 4, control_start: Some(0), ..Default::default() },
            Decafork { epsilon: 50.0, p: Some(1.0) }, // forks every visit
            NoFailures,
            Rng::new(6),
        );
        for _ in 0..3 {
            e.step();
        }
        assert!(e.alive() > 4);
        for w in e.snapshot() {
            if let Lineage::Forked { at, .. } = w.lineage {
                assert!(at >= w.born);
            }
        }
    }

    #[test]
    fn max_walks_cap_enforced() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 4, max_walks: 16, control_start: Some(0), ..Default::default() },
            Decafork { epsilon: 100.0, p: Some(1.0) },
            NoFailures,
            Rng::new(7),
        );
        e.run_to(100);
        assert!(e.alive() <= 16);
        assert!(e.trace().capped);
    }

    #[test]
    fn lazy_and_dense_node_state_bit_identical() {
        // The shared-stream arm of the lazy-store contract: state
        // construction is pure and draws nothing from the engine
        // stream, so deferring it to first visit cannot move a bit —
        // θ̂ samples included. (The stream-mode arm, with churn and
        // randomized prune schedules, is
        // `prop_lazy_store_bit_identical_to_dense`.)
        let run = |mode| {
            let mut e = Engine::new(
                small_graph(),
                SimParams {
                    z0: 8,
                    record_theta: true,
                    prune_every: 32,
                    node_state: mode,
                    ..Default::default()
                },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(0x1A2B),
            );
            e.run_to(600);
            e.into_trace()
        };
        let dense = run(NodeStateMode::Dense);
        let lazy = run(NodeStateMode::Lazy);
        assert!(dense.bit_identical(&lazy), "lazy store diverged from dense oracle");
        assert!(!dense.theta.is_empty(), "no θ̂ samples — comparison is vacuous");
    }

    #[test]
    fn lazy_store_materializes_only_visited_nodes() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 3, ..Default::default() },
            NoControl,
            NoFailures,
            Rng::new(41),
        );
        assert_eq!(e.states().visited_count(), 0, "no visits before the first step");
        e.run_to(5);
        let v = e.states().visited_count();
        assert!(v > 0, "steps must materialize state");
        assert!(v < 30, "3 walks × 5 hops cannot have covered all 30 nodes");
        assert!(e.states().iter().all(|(_, s)| s.known_walks() > 0));
    }

    #[test]
    fn deterministic_under_seed() {
        let mk = |seed| {
            let mut e = Engine::new(
                small_graph(),
                SimParams { z0: 10, ..Default::default() },
                Decafork::new(2.0),
                Burst::paper_default(),
                Rng::new(seed),
            );
            e.run_to(3000);
            e.into_trace().z
        };
        assert_eq!(mk(11), mk(11));
        assert_ne!(mk(11), mk(12));
    }

    #[test]
    fn walk_ids_never_alias_under_heavy_churn() {
        // The id-reuse satellite: Probabilistic(0.2) killing walks every
        // step while Decafork(p=1) forks on every visit — arena slots are
        // freed and reused constantly, and every id the trace ever
        // mentions must still be globally unique (generation bump).
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 8, control_start: Some(0), max_walks: 256, ..Default::default() },
            Decafork { epsilon: 100.0, p: Some(1.0) },
            Probabilistic::new(0.2),
            Rng::new(13),
        );
        e.run_to(400);
        let tr = e.trace();
        // Ids born: the initial Z0 plus one per fork event. Every fork
        // must mint an id never seen before (not an initial id, not a
        // previously forked id — dead or alive).
        let mut seen: HashSet<u64> = (0..8u64).map(|k| WalkId(k).0).collect();
        let mut deaths_of_known = 0usize;
        for ev in &tr.events {
            match ev.kind {
                EventKind::Fork => {
                    assert!(
                        seen.insert(ev.walk),
                        "fork at t={} reused id {} — generation aliasing",
                        ev.t,
                        WalkId(ev.walk)
                    );
                }
                _ => {
                    assert!(seen.contains(&ev.walk), "death of unknown id");
                    deaths_of_known += 1;
                }
            }
        }
        assert!(deaths_of_known > 100, "churn too low to exercise slot reuse");
        // Slot indices *are* reused (that's the point of the arena):
        // strictly fewer slots than ids when churn recycles them.
        let max_slot = tr
            .events
            .iter()
            .map(|ev| WalkId(ev.walk).index())
            .max()
            .unwrap();
        assert!(
            (max_slot as usize) < seen.len() - 1,
            "no slot reuse happened (max slot {max_slot}, {} ids)",
            seen.len()
        );
        // And conservation still holds under maximal churn.
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            delta[ev.t as usize] += if ev.kind == EventKind::Fork { 1 } else { -1 };
        }
        for t in 1..tr.z.len() {
            assert_eq!(tr.z[t] as i64 - tr.z[t - 1] as i64, delta[t], "churn broke z at t={t}");
        }
    }

    #[test]
    fn graveyard_preserves_lineage_of_dead_walks() {
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 6, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(40, 3), (80, 2)]),
            Rng::new(21),
        );
        e.run_to(300);
        let snap = e.snapshot();
        let dead: Vec<_> = snap.iter().filter(|w| !w.alive).collect();
        let losses = e.trace().count(EventKind::Failure)
            + e.trace().count(EventKind::ControlTermination);
        assert_eq!(dead.len(), losses);
        for w in &dead {
            assert!(w.died.is_some());
            assert!(w.died.unwrap() >= w.born);
            // Ancestry of every dead walk still resolves to a root slot.
            assert!(
                crate::walks::lineage::root_slot(&snap, w.id).is_some(),
                "lost ancestry for {}",
                w.id
            );
        }
        assert_eq!(
            snap.iter().filter(|w| w.alive).count(),
            e.alive() as usize
        );
    }

    #[test]
    fn hook_sees_visits_forks_deaths() {
        struct Counter {
            visits: usize,
            forks: usize,
            deaths: usize,
        }
        impl VisitHook for Counter {
            fn on_visit(&mut self, _t: u64, _n: u32, _w: WalkMut<'_>) {
                self.visits += 1;
            }
            fn on_fork(&mut self, _t: u64, _p: WalkRef, _c: WalkMut<'_>) {
                self.forks += 1;
            }
            fn on_death(&mut self, _t: u64, _w: &Walk) {
                self.deaths += 1;
            }
        }
        let mut e = Engine::new(
            small_graph(),
            SimParams { z0: 6, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(40, 3)]),
            Rng::new(8),
        );
        let mut h = Counter { visits: 0, forks: 0, deaths: 0 };
        e.run_to_with(300, &mut h);
        assert!(h.visits > 1000);
        let losses = e.trace().count(EventKind::Failure)
            + e.trace().count(EventKind::ControlTermination);
        assert_eq!(h.deaths, losses);
        assert_eq!(h.forks, e.trace().count(EventKind::Fork));
    }
}
