//! The **stream-mode sharded engine**: within-run parallelism built on
//! per-walk RNG streams, with schedule-invariant determinism — the trace
//! is bit-identical at every shard count (locked by
//! `tests/shard_invariance.rs` and the pinned stream-mode golden family).
//!
//! ## Randomness ownership (DESIGN.md §Per-walk streams)
//!
//! The shared-stream [`Engine`](crate::sim::engine::Engine) draws every
//! random number from one stream, so hop-iteration order *is* the trace:
//! nothing can run concurrently without changing results. Here every
//! draw belongs to exactly one owner, each with an independent stream
//! derived from the scenario's simulation stream (`rng::streams` tags):
//!
//! * **walks** — hop draws and in-transit loss checks come from the
//!   walk's own stream (original walk `k`: `base.derive(WALK, k)`; a
//!   fork's child splits the *parent's* stream, tagged by the
//!   within-decision fork index — the parent stream advances every step,
//!   so children forked in different steps never collide);
//! * **nodes** — control-decision draws come from the visited node's
//!   stream (`base.derive(NODE, i)`);
//! * **the failure model** — bursts and Byzantine Markov flips draw from
//!   one model-level stream (`base.derive`-style `FAIL` split).
//!
//! A walk's draw sequence is then a pure function of the scenario, never
//! of the order walks happen to be iterated — which is what makes the
//! phases below safe to run on any number of worker threads.
//!
//! ## Step anatomy: two shard-parallel phases, two canonical barriers
//!
//! ```text
//! pre-step failures (model stream, coordinator) → compact ─┐ barrier 1
//!   hop phase   — dense walk columns split into exactly    │
//!                 `shards` contiguous chunks; each worker  │
//!                 hops its walks on their own streams,     │
//!                 records hop deaths and (mailbox routing) │
//!                 bins survivors into per-(chunk ×         │
//!                 destination-shard) mailboxes             │
//!   [apply hop deaths in dense order]                      │
//!   control phase — nodes split into contiguous ranges;    │
//!                 each worker observes its nodes' arrivals │
//!                 in dense (creation) order and runs       │
//!                 control on per-node streams              │
//! k-way merge of the per-shard decision buffers, ascending ┘ barrier 2
//!   in the deciding walk's dense index
//!   (θ̂ telemetry, fork spawns + child streams, kills) → compact → Z_t
//! ```
//!
//! Everything order-sensitive happens at the barriers, in **canonical
//! (creation/dense) order**: hop deaths are applied in dense order (the
//! contiguous chunks concatenate to exactly that), decisions are merged
//! ascending in the deciding walk's dense index (each shard's buffer is
//! already ascending — arrivals are fed in dense order — so the merge is
//! a k-way head-pick, not a sort), and fork children are spawned — and
//! observed at the forking node — in that same order, so arena ids,
//! node-table first-seen order (the θ̂ float-sum order), the event log
//! and the θ̂ telemetry are all identical at any shard count. Inside a
//! phase nothing shared is touched: walk chunks are disjoint column
//! ranges; each shard owns a [`NodeStore`] holding its node range's
//! states and streams (materialized lazily on first visit — DESIGN.md
//! §Lazy node store) and its clone of the control algorithm (per-node
//! control state like `PeriodicFork::next_fork` is node-indexed, so
//! clones never disagree).
//!
//! ## Arrival routing: the coordinator off the critical path
//!
//! How arrivals travel from the hop phase to the control phase is a
//! [`RoutingMode`] knob (`--routing` / `DECAFORK_ROUTING`) — and, like
//! the lazy/dense node-store pair, the two modes are bit-identical by
//! construction (DESIGN.md §Locality & routing):
//!
//! * [`RoutingMode::Serial`] — the original, kept as the A/B oracle:
//!   the coordinator re-scans the full dense position column between
//!   the phases and buckets survivors by owning node range. O(live
//!   walks) of *serial* work on the step's critical path, which by
//!   Amdahl caps what the parallel phases can buy.
//! * [`RoutingMode::Mailbox`] — the default: each hop worker, while it
//!   still owns the walk, pushes the survivor's complete arrival record
//!   into the mailbox for (its chunk `c`, destination shard `s`) — a
//!   flat `shards²` matrix indexed `c·shards + s`, so every row has
//!   exactly one writer. The control task for shard `s` then drains
//!   rows `(0,s), (1,s), …` in chunk order. A chunk covers an ascending
//!   dense range and is scanned ascending, so each row is ascending in
//!   dense, and the chunk-major concatenation reproduces the serial
//!   scan's per-shard arrival order *exactly*: first-visit order, the
//!   θ̂ float-sum order and the golden traces cannot move a bit. The
//!   coordinator's inter-phase work drops to O(shards) buffer handoff.
//!
//! Hop deaths never reach a mailbox (a walk has one fate per step), and
//! the pre-hop compact means there are no stale tombstones to skip — the
//! two paths bucket the same survivors. Locked by
//! `prop_mailbox_routing_bit_identical_to_serial`
//! (tests/shard_invariance.rs) and by running both pinned golden
//! families under both modes; the speedup is gated by
//! `benches/perf_route.rs`.
//!
//! ## Thread model (DESIGN.md §Worker pool, §Locality & routing)
//!
//! Each parallel phase is a task list handed to a persistent
//! [`WorkerPool`]: `shards − 1` threads spawned once at construction and
//! parked between phases, with the coordinator running the first chunk
//! of every phase itself — a step costs up to three pool wakes instead
//! of three rounds of thread spawns, which is what makes `--shards`
//! profitable at `perf_control` scale (1000 nodes) and not just at
//! `scale_100k`. [`DispatchMode::Scoped`] keeps the old per-phase
//! `std::thread::scope` spawning as the measured baseline of
//! `benches/perf_pool.rs`. Dispatch never affects results: the trace is
//! bit-identical across modes and worker counts alike.
//!
//! Worker identity is **sticky**: task slot `k` of every phase — hop
//! chunk `k`, control shard `k`, prune sweep `k`, and the one-shot
//! store-construction phase at build time — always runs on pool worker
//! `k − 1` (slot 0 on the coordinator). Shard `k`'s [`NodeStore`],
//! mailbox rows and decision buffer are therefore always touched by the
//! same OS thread: its caches stay warm across phases and steps, and
//! because the stores are *built* on their owning workers too, the
//! kernel's default first-touch policy places each shard's state on
//! that worker's NUMA node. `--pin-cores` / `DECAFORK_PIN_CORES`
//! optionally adds the last binding — worker `k` → core `k + 1` — via
//! [`runtime::affinity`](crate::runtime::affinity); it is opt-in,
//! best-effort and placement-only (never changes a trace).
//!
//! ## What stream mode is *not*
//!
//! It is a different trace family from the shared-stream engine — same
//! system, different (but equally valid) sample path — so it carries its
//! own pinned golden family (`tests/stream_golden.rs`) instead of the
//! arena-vs-reference lock. Two semantic deltas, both deliberate:
//! fork children are observed by the forking node at the merge barrier
//! (after the step's arrivals) rather than mid-loop, and the
//! shared-stream `VisitHook` is replaced by the per-shard
//! [`ShardHook`] protocol (`sim::shard_hook`): each shard owns a hook
//! replica that sees its node range's visits during the parallel control
//! phase, and replica deltas merge at the end-of-step barrier in
//! canonical dense-index order — exactly how fork decisions already
//! merge — so hooked runs (RW-SGD via `learning::sharded`) stay
//! bit-identical at every shard count. `step()` runs the inert
//! [`NoShardHook`], whose `ACTIVE = false` const compiles every hook
//! touchpoint out of the loop.
//! Failure models must not mutate internal state in `on_hop`/`on_arrival`
//! (none do; state transitions belong in `pre_step`, which runs once on
//! the coordinator's master copy; the per-worker scratch copies — cloned
//! once at construction, not per chunk — are then re-synced from the
//! master by [`Failures::sync_from`], a few scalar copies per step).
//!
//! ## Hot-phase execution: blocked vs scalar
//!
//! *How* each hop/control chunk executes is the [`HopPath`] knob
//! (`--hop-path` / `DECAFORK_HOP_PATH`, default `blocked`) — the third
//! bit-identical A/B pair after lazy/dense and mailbox/serial. The
//! scalar path advances one walk at a time, chaining CSR offset →
//! adjacency row (hop) and index probe → state row (control) through
//! dependent random loads — at 10⁷⁺ nodes each worker is
//! memory-latency-bound with about one miss in flight. The blocked path
//! runs the same chunk as a pipeline over fixed 64-walk blocks:
//! prefetch block k+1's metadata lines, prefetch block k's dependent
//! rows, draw block k's hops through [`Graph::step_block`], then replay
//! block k's failure checks and mailbox binning scalar-wise. Every draw
//! still comes from the owning walk's (or node's) private stream in the
//! same per-stream order — batching across walks cannot move a bit
//! (DESIGN.md §Block pipelining) — locked by
//! `prop_blocked_hop_bit_identical_to_scalar` and both golden families;
//! the speedup is gated by `benches/perf_hop.rs`.

use std::sync::Arc;

use crate::control::{Control, VisitCtx};
use crate::failures::Failures;
use crate::graph::Graph;
use crate::obs::MetricsSink;
use crate::rng::{streams, Rng};
use crate::runtime::pool::{self, WorkerPool};
use crate::runtime::telemetry::{Phase, Telemetry, WorkerCounters};
use crate::sim::engine::{HopPath, RoutingMode, SimParams, StartPlacement};
use crate::sim::metrics::{Event, EventKind, Trace};
use crate::sim::shard_hook::{NoShardHook, ShardHook, ShardVisit};
use crate::walks::{Lineage, NodeStore, StatesView, Walk, WalkArena, WalkId};

/// How the per-phase shard tasks reach their threads.
///
/// The trace is identical either way — dispatch only decides *which*
/// thread runs a chunk, never what any chunk computes — which is what
/// lets `benches/perf_pool.rs` assert bit-identity before clocking the
/// two modes against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Persistent [`WorkerPool`] (the default): `shards − 1` workers are
    /// spawned once at engine construction and parked between phases, so
    /// a phase costs one wake instead of a spawn per worker
    /// (DESIGN.md §Worker pool).
    Pooled,
    /// One `std::thread::scope` spawn per chunk per phase — the pre-pool
    /// behavior, kept as the bench baseline only.
    Scoped,
}

/// One surviving walk's landing spot, queued for the control phase.
/// Payload indices for hooked runs travel in a *side* buffer
/// (`arrival_payloads`, filled only when `H::ACTIVE`), so the plain
/// `step()` path keeps the pre-hook arrival layout and cache density.
#[derive(Debug, Clone, Copy)]
struct Arrival {
    /// Dense position in the arena (canonical order key).
    dense: u32,
    node: u32,
    id: WalkId,
    slot: u16,
}

/// A walk killed during the hop phase (in transit or on arrival).
#[derive(Debug, Clone, Copy)]
struct HopDeath {
    dense: u32,
    /// Where it died: the origin for in-transit losses, the destination
    /// for Byzantine arrivals.
    node: u32,
}

/// One node's control decision, tagged for the canonical merge.
#[derive(Debug)]
struct DecisionOut {
    /// Dense position of the deciding (visiting) walk.
    dense: u32,
    node: u32,
    walk: WalkId,
    decision: crate::control::Decision,
}

/// The stream-mode engine. Construction mirrors [`Engine`]'s signature
/// plus the worker count; `shards == 1` runs the identical phased
/// semantics inline (no threads), so it is the reference point the
/// invariance tests compare higher counts against.
///
/// [`Engine`]: crate::sim::engine::Engine
pub struct ShardedEngine {
    pub graph: Arc<Graph>,
    pub params: SimParams,
    shards: usize,
    /// Contiguous node-range size per shard (static for the whole run —
    /// results never depend on it, only thread assignment does).
    nodes_per_shard: usize,
    arena: WalkArena,
    /// One [`NodeStore`] per shard, each owning a contiguous node range
    /// of `nodes_per_shard` nodes (trailing stores may be shorter or
    /// empty): the node's estimator state *and* its control-decision
    /// stream, both materialized on first visit in the default lazy
    /// mode (DESIGN.md §Lazy node store). Replaces the former dense
    /// `states` + `node_rngs` columns, making per-shard memory and
    /// housekeeping O(visited ∩ shard) instead of O(n / shards).
    stores: Vec<NodeStore>,
    /// One clone of the control algorithm per shard; per-node internal
    /// state is node-indexed and shards own disjoint node ranges, so the
    /// clones never diverge on state either of them reads.
    controls: Vec<Control>,
    /// Master failure model: `pre_step` runs here; hop-phase workers use
    /// per-step clones (read-only by contract).
    failures: Failures,
    /// Model-level failure stream.
    fail_rng: Rng,
    t: u64,
    trace: Trace,
    control_start: u64,
    /// `shards − 1` parked workers in pooled mode with `shards >= 2`
    /// (the coordinator thread runs the first chunk of every phase);
    /// `None` for single-shard inline stepping and for scoped dispatch.
    /// Dropped — and its threads joined — with the engine.
    pool: Option<WorkerPool>,
    dispatch: DispatchMode,
    // Per-shard scratch, reused across steps (cleared in place, so the
    // steady state allocates nothing per step beyond the `shards`-sized
    // per-phase task lists).
    hop_deaths: Vec<Vec<HopDeath>>,
    /// Serial-routing arrival buckets, one per shard — filled by the
    /// coordinator's inter-phase scan only in [`RoutingMode::Serial`].
    arrivals: Vec<Vec<Arrival>>,
    /// Parallel to `arrivals`, populated only on hooked steps
    /// (`H::ACTIVE`): the arriving walk's payload index for the hook's
    /// visit view. Stays empty — zero writes, zero reads — on the plain
    /// path.
    arrival_payloads: Vec<Vec<Option<usize>>>,
    /// Mailbox-routing arrival matrix, `shards²` rows flat-indexed
    /// `chunk · shards + destination_shard` — hop worker `c` writes only
    /// rows `c·shards ..`, control worker `s` reads only rows `(·, s)`,
    /// so rows never have two owners (see module docs). Unused in
    /// [`RoutingMode::Serial`].
    mailboxes: Vec<Vec<Arrival>>,
    /// Parallel to `mailboxes`, filled only on hooked mailbox steps —
    /// same contract as `arrival_payloads`.
    mailbox_payloads: Vec<Vec<Option<usize>>>,
    /// K-way merge cursors (one per shard) for the decision barrier.
    merge_heads: Vec<usize>,
    decisions: Vec<Vec<DecisionOut>>,
    /// Per-worker hop-phase scratch (failure-model copy + blocked-path
    /// block buffers), one per chunk slot, reused across steps.
    hop_scratch: Vec<HopScratch>,
    /// Observation-only telemetry accumulator (phase-span histograms +
    /// the open flush period). No-op when metrics are off — see
    /// DESIGN.md §Observability for why nothing here can move a bit.
    tel: Telemetry,
    /// Per-worker telemetry counter rows, one per shard slot, handed to
    /// phase tasks as disjoint `&mut` exactly like the hop scratch and
    /// mailbox rows (no atomics), folded and cleared by the coordinator
    /// at the end-of-step barrier. Sized once at construction — no
    /// allocation after warm-up.
    tel_scratch: Vec<WorkerCounters>,
    /// Streaming metrics sink (`None` when metrics are off). Runs on
    /// the coordinator, strictly after the step's trace updates.
    sink: Option<MetricsSink>,
}

/// One hop worker's reusable scratch. Owned by the engine and handed to
/// chunk `c`'s task as a disjoint `&mut`, like the death/mailbox rows.
struct HopScratch {
    /// Worker copy of the failure model: cloned from the master once at
    /// construction and re-synced (scalar copies, no allocation) after
    /// each master `pre_step` — hop-time checks are read-only by
    /// contract, so sync only has to carry `pre_step`'s mutations.
    failures: Failures,
    /// Blocked-path destination buffer: `Graph::step_block` writes one
    /// block's draws here, the replay stage reads them back. Sized to
    /// one block, cleared in place, never reallocated after the first
    /// blocked step.
    to: Vec<u32>,
}

impl ShardedEngine {
    /// Pooled-dispatch engine (the production default).
    pub fn new(
        graph: Arc<Graph>,
        params: SimParams,
        control: impl Into<Control>,
        failures: impl Into<Failures>,
        base: Rng,
        shards: usize,
    ) -> Self {
        Self::with_dispatch(graph, params, control, failures, base, shards, DispatchMode::Pooled)
    }

    /// Engine with an explicit [`DispatchMode`] — `Scoped` exists for
    /// `benches/perf_pool.rs`' pooled-vs-scoped measurement.
    pub fn with_dispatch(
        graph: Arc<Graph>,
        params: SimParams,
        control: impl Into<Control>,
        failures: impl Into<Failures>,
        base: Rng,
        shards: usize,
        dispatch: DispatchMode,
    ) -> Self {
        Self::with_pool(graph, params, control, failures, base, shards, dispatch, None)
    }

    /// [`with_dispatch`](Self::with_dispatch) that can adopt an existing
    /// [`WorkerPool`] — e.g. the one that just built the graph
    /// (`Scenario::sharded_engine_dispatch` hands its construction pool
    /// over), so a run spawns its threads once instead of once per
    /// subsystem. The pool is adopted only when its worker count matches
    /// what this dispatch/shard combination would have spawned
    /// (`shards − 1` in pooled mode); otherwise it is dropped here and
    /// the engine builds its own, keeping thread accounting
    /// (`pooled_workers`) and phase chunking identical to the
    /// non-adopting constructors. Results never depend on pool identity.
    #[allow(clippy::too_many_arguments)]
    pub fn with_pool(
        graph: Arc<Graph>,
        params: SimParams,
        control: impl Into<Control>,
        failures: impl Into<Failures>,
        base: Rng,
        shards: usize,
        dispatch: DispatchMode,
        adopt: Option<WorkerPool>,
    ) -> Self {
        let shards = shards.max(1);
        let n = graph.n();
        let control = control.into();
        let z0 = params.z0;

        let mut init_rng = base.split(streams::INIT);
        let fail_rng = base.split(streams::FAIL);
        let walk_root = base.split(streams::WALK);
        let node_root = base.split(streams::NODE);

        let mut arena = WalkArena::with_streams(z0 as usize);
        for slot in 0..z0 {
            let at = match params.start {
                StartPlacement::AtNode(v) => v,
                StartPlacement::Random => init_rng.below(n) as u32,
            };
            arena.spawn_with_stream(
                at,
                0,
                Lineage::Original { slot: slot as u16 },
                walk_root.split(slot as u64),
            );
        }
        // MISSINGPERSON is the only reader of the per-slot staleness
        // table; for every other control family the Z0-sized column per
        // node would be pure waste — at the million-node scale presets it
        // would be gigabytes (`observe` already tolerates the empty
        // table).
        let mp_slots = if matches!(control, Control::MissingPerson(_)) { z0 as usize } else { 0 };
        let controls = vec![control; shards];
        let nodes_per_shard = n.div_ceil(shards).max(1);
        let control_start = params
            .control_start
            .unwrap_or_else(|| (1.5 * n as f64 * (n as f64).ln().max(1.0)).ceil() as u64);
        let mut trace = Trace::default();
        trace.z.push(z0);
        // The pool comes up *before* the stores so store construction
        // can run on the workers that will own the stores. An adopted
        // pool must match both the worker count and the pinning this
        // engine was asked for — a mismatch silently changing placement
        // would make `--pin-cores` a lie — otherwise it is dropped and
        // rebuilt, keeping thread accounting identical to the
        // non-adopting constructors.
        let mut pool = match dispatch {
            DispatchMode::Pooled if shards > 1 => Some(match adopt {
                Some(p) if p.workers() == shards - 1 && p.pinned() == params.pin_cores => p,
                _ => WorkerPool::new_pinned(shards - 1, params.pin_cores),
            }),
            _ => None,
        };
        // One store per shard over its contiguous node range. Every
        // store hands lazily-materialized nodes a stream split from the
        // same `node_root` by *global* node id, so the partition is
        // invisible to every decision draw — and eager (dense-mode)
        // construction, done per-range here, is element-for-element the
        // `(0..n)` columns this replaced. Construction is *first-touch
        // aware* (DESIGN.md §Locality & routing): build slot `k` runs on
        // the same sticky pool worker that will run shard `k`'s control
        // tasks for the whole run, so the store's columns are first
        // touched — hence, under the kernel's default first-touch
        // policy, physically allocated — on the owning worker's NUMA
        // node. Safe to parallelize because `NodeStore::new` is a pure
        // function of (mode, graph, range, params, stream root): no
        // draw, no ordering effect, identical stores wherever it runs.
        let mut store_slots: Vec<Option<NodeStore>> = (0..shards).map(|_| None).collect();
        {
            let graph_ref = &graph;
            let node_root_ref = &node_root;
            let node_state = params.node_state;
            let survival = params.survival;
            let mut builds: Vec<_> = store_slots
                .iter_mut()
                .enumerate()
                .map(|(k, slot)| {
                    move || {
                        let lo = (k * nodes_per_shard).min(n);
                        let len = nodes_per_shard.min(n - lo);
                        *slot = Some(NodeStore::new(
                            node_state,
                            graph_ref.clone(),
                            lo as u32,
                            len as u32,
                            mp_slots,
                            survival,
                            Some(node_root_ref.clone()),
                        ));
                    }
                })
                .collect();
            match pool.as_mut() {
                Some(p) => p.run_slice(&mut builds),
                // Inline / scoped dispatch has no persistent workers to
                // place memory for — build on the coordinator.
                None => builds.iter_mut().for_each(|b| b()),
            }
        }
        let stores: Vec<NodeStore> =
            store_slots.into_iter().map(|s| s.expect("every build task ran")).collect();
        let failures: Failures = failures.into();
        let hop_scratch = (0..shards)
            .map(|_| HopScratch { failures: failures.clone(), to: Vec::new() })
            .collect();
        let tel = Telemetry::new(params.metrics.enabled());
        let tel_scratch = vec![WorkerCounters::default(); shards];
        let mut sink = MetricsSink::new(&params.metrics);
        if let Some(s) = &mut sink {
            s.prime(z0);
        }
        ShardedEngine {
            graph,
            params,
            shards,
            nodes_per_shard,
            arena,
            stores,
            controls,
            failures,
            fail_rng,
            t: 0,
            trace,
            control_start,
            pool,
            dispatch,
            hop_deaths: (0..shards).map(|_| Vec::new()).collect(),
            arrivals: (0..shards).map(|_| Vec::new()).collect(),
            arrival_payloads: (0..shards).map(|_| Vec::new()).collect(),
            mailboxes: (0..shards * shards).map(|_| Vec::new()).collect(),
            mailbox_payloads: (0..shards * shards).map(|_| Vec::new()).collect(),
            merge_heads: Vec::new(),
            decisions: (0..shards).map(|_| Vec::new()).collect(),
            hop_scratch,
            tel,
            tel_scratch,
            sink,
        }
    }

    /// The resolved control warm-up boundary.
    pub fn control_start(&self) -> u64 {
        self.control_start
    }

    /// Worker count this engine was built with.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// How phase tasks are dispatched to threads.
    pub fn dispatch_mode(&self) -> DispatchMode {
        self.dispatch
    }

    /// Number of persistent pool threads this engine owns (0 in inline
    /// or scoped mode) — lifecycle tests count these against the OS.
    pub fn pooled_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, WorkerPool::workers)
    }

    /// Current time.
    pub fn now(&self) -> u64 {
        self.t
    }

    /// Number of active walks.
    pub fn alive(&self) -> u32 {
        self.arena.live()
    }

    /// The walk store (telemetry/tests).
    pub fn arena(&self) -> &WalkArena {
        &self.arena
    }

    /// Node states (telemetry/tests): a visited-aware view over the
    /// per-shard stores — `(node, &state)` pairs in shard order, then
    /// first-visit order within a shard, plus `visited_count()` and the
    /// `memory_bytes()` accounting `benches/perf_state.rs` gates on.
    pub fn states(&self) -> StatesView<'_> {
        StatesView::new(&self.stores)
    }

    /// Materialize every walk — live and retired (cold path).
    pub fn snapshot(&self) -> Vec<Walk> {
        self.arena.snapshot()
    }

    /// Mutable access to the live walks' payload slots, in creation
    /// order — used by application layers to seed payloads before the
    /// run (e.g. one model per initial walk). Mirrors
    /// [`Engine::payloads_mut`](crate::sim::engine::Engine::payloads_mut).
    pub fn payloads_mut(&mut self) -> impl Iterator<Item = &mut Option<usize>> {
        self.arena.payloads_mut()
    }

    /// Advance one time step (no application hook — the inert
    /// [`NoShardHook`] compiles every hook touchpoint out, so this is
    /// byte-for-byte the pre-hook engine).
    pub fn step(&mut self) {
        let mut hook = NoShardHook;
        let mut replicas = hook.replicas(self.shards, self.nodes_per_shard, self.graph.n());
        self.step_hooked(&mut hook, &mut replicas).expect("NoShardHook cannot fail");
    }

    /// Advance one time step with a [`ShardHook`]: per-shard replicas see
    /// their node range's visits during the parallel control phase, and
    /// the hook's coordinator-side callbacks (delta merge, fork payload
    /// handoff, deaths, end-of-step) fire at the barriers in canonical
    /// dense order. `replicas` must be the slice built by
    /// [`ShardHook::replicas`] for this engine's shard count.
    pub fn step_hooked<H: ShardHook + Sync>(
        &mut self,
        hook: &mut H,
        replicas: &mut [H::Replica],
    ) -> anyhow::Result<()> {
        // A short replica slice would silently drop whole shards from
        // the control phase (zip truncation) — reject it outright.
        anyhow::ensure!(
            replicas.len() == self.shards,
            "step_hooked needs one replica per shard ({} replicas for {} shards)",
            replicas.len(),
            self.shards
        );
        self.t += 1;
        let t = self.t;
        // Telemetry is observation-only: clock reads on the coordinator
        // between phases, counter deltas after the work, nothing on any
        // RNG stream — metrics on/off is trace bit-identical by
        // construction (test-locked in `tests/shard_invariance.rs`).
        let tel_on = self.tel.enabled();
        let events_start = self.trace.events.len();
        let step_clock = tel_on.then(std::time::Instant::now);

        // 1. External failure events from the model-level stream; the
        //    dense id column is the alive roster, as in the sequential
        //    engine.
        let killed = self.failures.pre_step(t, self.arena.ids(), &mut self.fail_rng);
        for id in killed {
            if let Some(dense) = self.arena.resolve(id) {
                let node = self.arena.position(dense);
                kill_dense(
                    &mut self.arena,
                    &mut self.trace,
                    dense,
                    t,
                    node,
                    EventKind::Failure,
                    hook,
                );
            }
        }
        self.arena.compact();
        let hop_clock = step_clock.map(|c| {
            self.tel.record_span(Phase::PreStep, c.elapsed().as_nanos() as u64);
            std::time::Instant::now()
        });

        // 2. Hop phase: contiguous chunks of the dense walk columns, one
        //    worker each. Every draw comes from the walk's own stream,
        //    so chunk boundaries cannot influence any value. In mailbox
        //    routing the workers also bin surviving walks into the
        //    per-(chunk × destination-shard) mailboxes right here — the
        //    walk's columns are already in cache — which is what lets
        //    the coordinator skip its O(live) inter-phase scan below.
        let len0 = self.arena.dense_len();
        if len0 == 0 {
            self.trace.z.push(0);
            self.trace.extinct = true;
            if tel_on {
                // Close the step for the sink even on extinction, so a
                // row is emitted for every step and `steps / period`
                // stays exact regardless of outcome.
                self.finish_step_telemetry(t, events_start, None);
            }
            return Ok(());
        }
        let shards = self.shards;
        let nodes_per_shard = self.nodes_per_shard;
        let route = self.params.routing == RoutingMode::Mailbox;
        let route_payloads = route && H::ACTIVE;
        let blocked = self.params.hop_path == HopPath::Blocked;
        // Re-sync the per-worker failure copies with whatever the
        // master's `pre_step` just mutated (Byzantine occupation flags);
        // scalar copies, no allocation — the clone happened once at
        // construction.
        for scratch in &mut self.hop_scratch {
            scratch.failures.sync_from(&self.failures);
        }
        if route {
            for row in &mut self.mailboxes {
                row.clear();
            }
            for row in &mut self.mailbox_payloads {
                row.clear();
            }
        }
        let chunk = len0.div_ceil(shards).max(1);
        {
            let (ids, lineage, payloads, at, walk_rngs) = self.arena.hop_columns_routed_mut();
            let graph: &Graph = &self.graph;
            if shards == 1 {
                hop_chunk(
                    graph,
                    &mut self.hop_scratch[0],
                    t,
                    0,
                    ids,
                    lineage,
                    payloads,
                    at,
                    walk_rngs,
                    &mut self.hop_deaths[0],
                    &mut self.mailboxes,
                    &mut self.mailbox_payloads,
                    nodes_per_shard,
                    route,
                    route_payloads,
                    blocked,
                    if tel_on { Some(&mut self.tel_scratch[0]) } else { None },
                );
            } else {
                // Exactly `shards` chunks (trailing ones may be empty),
                // split at fixed `chunk` boundaries so chunk index `c`
                // always owns dense range `[c·chunk, (c+1)·chunk)` and
                // mailbox rows `c·shards ..` — `chunks_mut` would yield
                // fewer slices on small populations and break both the
                // sticky chunk↔worker mapping and the row ownership.
                let mut at_rest = at;
                let mut rng_rest = walk_rngs;
                let mut tasks = Vec::with_capacity(shards);
                for (c, ((((deaths, mail_row), pay_row), scratch), wc)) in self
                    .hop_deaths
                    .iter_mut()
                    .zip(self.mailboxes.chunks_mut(shards))
                    .zip(self.mailbox_payloads.chunks_mut(shards))
                    .zip(self.hop_scratch.iter_mut())
                    .zip(self.tel_scratch.iter_mut())
                    .enumerate()
                {
                    let take = chunk.min(at_rest.len());
                    let (at_c, next) = std::mem::take(&mut at_rest).split_at_mut(take);
                    at_rest = next;
                    let (rng_c, next) = std::mem::take(&mut rng_rest).split_at_mut(take);
                    rng_rest = next;
                    tasks.push(move || {
                        hop_chunk(
                            graph,
                            scratch,
                            t,
                            c * chunk,
                            ids,
                            lineage,
                            payloads,
                            at_c,
                            rng_c,
                            deaths,
                            mail_row,
                            pay_row,
                            nodes_per_shard,
                            route,
                            route_payloads,
                            blocked,
                            // Reborrow per call: the FnMut closure owns
                            // `wc: &mut WorkerCounters` and can't move it
                            // out, but a fresh `&mut *wc` per invocation
                            // is fine.
                            if tel_on { Some(&mut *wc) } else { None },
                        )
                    });
                }
                fan_out_slice(self.pool.as_mut(), &mut tasks);
            }
        }
        // Barrier: apply hop deaths in dense order. Chunks are contiguous
        // and scanned in order, so per-shard lists concatenate to exactly
        // the canonical order.
        for deaths in &mut self.hop_deaths {
            for hd in deaths.drain(..) {
                kill_dense(
                    &mut self.arena,
                    &mut self.trace,
                    hd.dense as usize,
                    t,
                    hd.node,
                    EventKind::Failure,
                    hook,
                );
            }
        }
        let control_clock = hop_clock.map(|c| {
            self.tel.record_span(Phase::Hop, c.elapsed().as_nanos() as u64);
            std::time::Instant::now()
        });

        // 3. Control phase. In serial routing the coordinator buckets
        //    survivors by owning node range here (the scan is in dense
        //    order, so each shard sees its nodes' arrivals in canonical
        //    order) — O(live walks) of serial work the mailbox path
        //    already did inside the hop workers. Then observe + control
        //    run shard-locally on per-node streams, each task reading
        //    its shard's [`ArrivalFeed`]: the serial bucket, or the
        //    shard's mailbox column in chunk order — the same arrivals
        //    in the same order either way (module docs).
        if !route {
            for bufs in &mut self.arrivals {
                bufs.clear();
            }
            if H::ACTIVE {
                for bufs in &mut self.arrival_payloads {
                    bufs.clear();
                }
            }
            for i in 0..len0 {
                if self.arena.is_tombstoned(i) {
                    continue;
                }
                let node = self.arena.position(i);
                let shard = node as usize / nodes_per_shard;
                self.arrivals[shard].push(Arrival {
                    dense: i as u32,
                    node,
                    id: self.arena.id_at(i),
                    slot: self.arena.lineage_at(i).slot(),
                });
                if H::ACTIVE {
                    self.arrival_payloads[shard].push(self.arena.payload_at(i));
                }
            }
        }
        {
            let control_start = self.control_start;
            let z0 = self.params.z0;
            // Shared (read-only) view of the hook for the parallel phase;
            // replicas are the only hook state a worker may mutate.
            let hook_ref: &H = &*hook;
            let mail = &self.mailboxes;
            let mail_pay = &self.mailbox_payloads;
            let arrivals = &self.arrivals;
            let arr_pay = &self.arrival_payloads;
            if shards == 1 {
                let feed = if route {
                    ArrivalFeed::Mailbox { mail, pay: mail_pay, shards, shard: 0 }
                } else {
                    ArrivalFeed::Single(&arrivals[0], &arr_pay[0])
                };
                control_chunk(
                    &mut self.stores[0],
                    &mut self.controls[0],
                    feed,
                    t,
                    control_start,
                    z0,
                    &mut self.decisions[0],
                    hook_ref,
                    &mut replicas[0],
                    blocked,
                    if tel_on { Some(&mut self.tel_scratch[0]) } else { None },
                );
            } else {
                // One task per shard: each store already owns its node
                // range (no split_at_mut carving needed), and a store
                // whose feed is empty costs one no-op closure.
                let mut tasks: Vec<_> = self
                    .stores
                    .iter_mut()
                    .zip(self.controls.iter_mut())
                    .zip(self.decisions.iter_mut())
                    .zip(replicas.iter_mut())
                    .zip(self.tel_scratch.iter_mut())
                    .enumerate()
                    .map(|(s, ((((store, control), out), rep), wc))| {
                        move || {
                            let feed = if route {
                                ArrivalFeed::Mailbox { mail, pay: mail_pay, shards, shard: s }
                            } else {
                                ArrivalFeed::Single(&arrivals[s], &arr_pay[s])
                            };
                            control_chunk(
                                store,
                                control,
                                feed,
                                t,
                                control_start,
                                z0,
                                out,
                                hook_ref,
                                rep,
                                blocked,
                                if tel_on { Some(&mut *wc) } else { None },
                            )
                        }
                    })
                    .collect();
                fan_out_slice(self.pool.as_mut(), &mut tasks);
            }
        }
        let merge_clock = control_clock.map(|c| {
            self.tel.record_span(Phase::Control, c.elapsed().as_nanos() as u64);
            std::time::Instant::now()
        });
        if tel_on {
            // Per-destination-shard arrival counts — the live-walk
            // imbalance the period reports as min/max. Reads the same
            // buffers the control phase just consumed (it never mutates
            // them), so this is a pure count.
            let mut lo = u64::MAX;
            let mut hi = 0u64;
            for s in 0..shards {
                let count: u64 = if route {
                    (0..shards).map(|c| self.mailboxes[c * shards + s].len() as u64).sum()
                } else {
                    self.arrivals[s].len() as u64
                };
                lo = lo.min(count);
                hi = hi.max(count);
            }
            self.tel.observe_shard_load(lo, hi);
        }

        // Barrier: the hook's replica deltas merge first (canonical
        // dense-index order, enforced by the hook per the ShardHook
        // contract), so fork payload handoff below sees parent state
        // that already includes this step's visits — mirroring the
        // sequential engine, where a walk's visit work precedes its own
        // fork decision.
        if H::ACTIVE {
            hook.merge(t, replicas)?;
        }

        // Barrier: merge decisions in canonical order — ascending in the
        // deciding walk's dense index, which reproduces the sequential
        // interleaving of the θ̂ telemetry, fork events and kills exactly,
        // independent of which shard computed what. Each shard's buffer
        // is already ascending (its feed is in dense order and a walk
        // decides at most once per step), so this is a k-way head-pick —
        // O(total · shards) comparisons, zero allocation, no sort — and
        // the buffers keep their capacity for the next step.
        self.merge_heads.clear();
        self.merge_heads.resize(shards, 0);
        loop {
            let mut next: Option<(u32, usize)> = None;
            for s in 0..shards {
                if let Some(cand) = self.decisions[s].get(self.merge_heads[s]) {
                    if next.map_or(true, |(dense, _)| cand.dense < dense) {
                        next = Some((cand.dense, s));
                    }
                }
            }
            let Some((_, s)) = next else { break };
            let idx = self.merge_heads[s];
            self.merge_heads[s] += 1;
            let d = &self.decisions[s][idx];
            if self.params.record_theta {
                if let Some(th) = d.decision.theta {
                    self.trace.theta.push((t, th));
                }
            }
            if tel_on {
                // θ̂ period stats ride the decision itself, not the trace,
                // so they work even when `record_theta` is off.
                if let Some(th) = d.decision.theta {
                    self.tel.observe_theta(th);
                }
            }
            for (j, &fork_slot) in d.decision.forks.iter().enumerate() {
                if self.arena.live() as usize >= self.params.max_walks {
                    self.trace.capped = true;
                    break;
                }
                // The child's stream splits off the parent's post-hop
                // state; `j` separates siblings of one decision, the
                // parent's per-step stream advance separates decisions.
                let child_stream = self.arena.stream_at(d.dense as usize).split(j as u64);
                let lineage =
                    Lineage::Forked { parent: d.walk, by: d.node, at: t, slot: fork_slot };
                let parent =
                    if H::ACTIVE { Some(self.arena.walk_ref(d.dense as usize)) } else { None };
                let (child_id, child_dense) =
                    self.arena.spawn_with_stream(d.node, t, lineage, child_stream);
                if let Some(parent) = parent {
                    hook.on_fork(t, parent, self.arena.walk_mut(child_dense));
                }
                // The new walk is immediately visible to the forking node
                // (footnote 7); in stream mode that visibility lands at
                // the barrier, after the step's arrivals. The forking
                // node decided *this step*, so its state is already
                // materialized — this lookup can never be a first visit.
                let shard = d.node as usize / self.nodes_per_shard;
                self.stores[shard].state_mut(d.node).observe(t, child_id, fork_slot);
                self.trace.events.push(Event {
                    t,
                    node: d.node,
                    walk: child_id.0,
                    kind: EventKind::Fork,
                });
            }
            if d.decision.terminate {
                kill_dense(
                    &mut self.arena,
                    &mut self.trace,
                    d.dense as usize,
                    t,
                    d.node,
                    EventKind::ControlTermination,
                    hook,
                );
            }
        }
        for out in &mut self.decisions {
            out.clear();
        }

        // 4. Housekeeping. Prune is per-node deterministic work, so it
        //    parallelizes over the per-shard stores with no merge step —
        //    and each store sweeps only its materialized (visited)
        //    states, making the sweep O(visited ∩ shard) in lazy mode.
        if self.params.prune_every > 0 && t % self.params.prune_every == 0 {
            if shards == 1 {
                self.stores[0].prune(t);
            } else {
                let mut sweeps: Vec<_> =
                    self.stores.iter_mut().map(|store| move || store.prune(t)).collect();
                fan_out_slice(self.pool.as_mut(), &mut sweeps);
            }
        }
        self.arena.compact();
        // The step is fully applied and the arena dense-compacted: the
        // hook's cross-walk barrier work (e.g. the trainer's periodic
        // parameter merge) iterates live walks in canonical order here.
        if H::ACTIVE {
            hook.end_step(t, &self.arena)?;
        }
        self.trace.z.push(self.arena.live());
        if self.arena.live() == 0 {
            self.trace.extinct = true;
        }
        if tel_on {
            self.finish_step_telemetry(t, events_start, merge_clock);
        }
        Ok(())
    }

    /// End-of-step telemetry barrier: close the Merge span, fold the
    /// per-worker counter rows into the period, count this step's trace
    /// events, and hand the closed step to the sink — strictly after
    /// every trace update, so the sink can only observe the step, never
    /// influence it. Also runs on the early-extinct return (with no
    /// open phase clock) so the sink emits one row per step regardless
    /// of outcome.
    fn finish_step_telemetry(
        &mut self,
        t: u64,
        events_start: usize,
        merge_clock: Option<std::time::Instant>,
    ) {
        if let Some(c) = merge_clock {
            self.tel.record_span(Phase::Merge, c.elapsed().as_nanos() as u64);
        }
        // The fold point: worker rows were last written before the
        // phase barriers above, so plain `&mut` access here is the
        // same happens-before the mailbox rows rely on — no atomics.
        self.tel.fold_workers(&mut self.tel_scratch);
        let (mut forks, mut terms, mut fails) = (0u64, 0u64, 0u64);
        for ev in &self.trace.events[events_start..] {
            match ev.kind {
                EventKind::Fork => forks += 1,
                EventKind::ControlTermination => terms += 1,
                EventKind::Failure => fails += 1,
            }
        }
        self.tel.count_events(forks, terms, fails);
        self.tel.end_step();
        let live = self.arena.live();
        let dispatches = self.pool.as_ref().map(WorkerPool::dispatches);
        if let Some(sink) = &mut self.sink {
            sink.on_step(t, live, fails, &mut self.tel, dispatches);
        }
    }

    /// Run until `horizon` (inclusive), stopping early on extinction
    /// (trace padded with zeros, as the sequential engine does).
    pub fn run_to(&mut self, horizon: u64) {
        self.run_to_with(horizon, &mut NoShardHook).expect("NoShardHook cannot fail");
    }

    /// [`run_to`](Self::run_to) with a [`ShardHook`]: builds one hook
    /// replica per shard (replica state persists across steps) and runs
    /// every step through [`step_hooked`](Self::step_hooked). Mirrors
    /// `Engine::run_to_with`; errors surface from the hook's barrier
    /// callbacks (e.g. a failing train step).
    pub fn run_to_with<H: ShardHook + Sync>(
        &mut self,
        horizon: u64,
        hook: &mut H,
    ) -> anyhow::Result<()> {
        let mut replicas = hook.replicas(self.shards, self.nodes_per_shard, self.graph.n());
        anyhow::ensure!(
            replicas.len() == self.shards,
            "hook built {} replicas for {} shards",
            replicas.len(),
            self.shards
        );
        while self.t < horizon {
            if self.arena.live() == 0 {
                self.trace.z.resize(horizon as usize + 1, 0);
                self.trace.extinct = true;
                self.t = horizon;
                break;
            }
            self.step_hooked(hook, &mut replicas)?;
        }
        Ok(())
    }

    /// Consume the engine, returning its telemetry. Stamps the run's
    /// visited-state footprint (nodes materialized, resident bytes)
    /// onto the trace — metadata `bit_identical` deliberately ignores.
    pub fn into_trace(mut self) -> Trace {
        self.trace.visited_nodes = StatesView::new(&self.stores).visited_count();
        self.trace.state_bytes = StatesView::new(&self.stores).memory_bytes();
        self.trace
    }

    /// Borrow telemetry.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }
}

/// Dispatch one phase's tasks: wake the persistent pool, or fall back to
/// per-call scoped spawning (bench baseline). Takes the phase's concrete
/// closure slice directly — the pool's `run_slice` type-erases it with a
/// monomorphized call thunk, so no per-phase `Vec<Task>` re-collection.
/// Free function so callers can hold disjoint `&mut` field borrows in
/// the tasks.
fn fan_out_slice<F: FnMut() + Send>(pool: Option<&mut WorkerPool>, tasks: &mut [F]) {
    match pool {
        Some(p) => p.run_slice(tasks),
        None => pool::run_scoped_slice(tasks),
    }
}

/// Retire the walk at dense position `dense`: trace event + graveyard
/// move + death hook (compiled out for [`NoShardHook`]). Free function so
/// barrier loops can hold disjoint field borrows. Only ever called at
/// barriers, in canonical dense order — which is what makes the hook's
/// death stream shard-count invariant.
fn kill_dense<H: ShardHook>(
    arena: &mut WalkArena,
    trace: &mut Trace,
    dense: usize,
    t: u64,
    node: u32,
    kind: EventKind,
    hook: &mut H,
) {
    let id = arena.id_at(dense);
    trace.events.push(Event { t, node, walk: id.0, kind });
    let dead = arena.retire(dense, t);
    if H::ACTIVE {
        hook.on_death(t, dead);
    }
}

/// Walks per block in the blocked hot-phase pipelines. 64 random-line
/// prefetches comfortably fit typical L1 miss-queue depths when spread
/// over a block's worth of compute, and one block's `from`/`to`/rng
/// working set (~3 KB) stays L1-resident — big enough to amortize the
/// per-block stage overhead, small enough that a prefetched line is
/// still cached when its walk replays one block (a few microseconds)
/// later. The value is a pure scheduling constant: any B produces the
/// identical trace (DESIGN.md §Block pipelining).
const HOP_BLOCK: usize = 64;

/// Hop-phase worker: advance each walk in the chunk on its own stream.
/// `base` is the chunk's offset into the dense columns; `ids`, `lineage`
/// and `payloads` are the full read-only rosters. The failure model used
/// here is the worker's persistent scratch copy — hop-time checks are
/// read-only by contract, and `pre_step` already ran on the
/// coordinator's master copy, whose mutations `sync_from` carried over.
///
/// With `route` set (mailbox routing), each survivor's arrival record is
/// pushed into `mail[destination_shard]` — this chunk's row of the
/// engine's mailbox matrix, `shards` destination bins owned exclusively
/// by this worker. The loop runs ascending in dense, so every bin stays
/// ascending in dense — the invariant the control phase's chunk-major
/// concatenation relies on. `route_payloads` additionally mirrors the
/// payload column into `pay` for hooked steps (same contract as the
/// serial path's payload side buffer). A killed walk is never binned: a
/// walk has exactly one fate per step.
///
/// `blocked` selects the pipelined execution (see [`HopPath`]): the
/// chunk is cut into [`HOP_BLOCK`]-walk blocks (plus an unaligned tail)
/// and each block runs prefetch-next → prefetch-this → batched
/// [`Graph::step_block`] → scalar replay of failure checks and binning.
/// Each walk's draws still come from its own stream in the same order —
/// hop draw, then failure draws — so both values of `blocked` produce
/// the identical trace; the scalar path stays byte-for-byte the
/// original loop (the replay below with the hop draw inlined).
#[allow(clippy::too_many_arguments)]
fn hop_chunk(
    graph: &Graph,
    scratch: &mut HopScratch,
    t: u64,
    base: usize,
    ids: &[WalkId],
    lineage: &[Lineage],
    payloads: &[Option<usize>],
    at: &mut [u32],
    walk_rngs: &mut [Rng],
    deaths: &mut Vec<HopDeath>,
    mail: &mut [Vec<Arrival>],
    pay: &mut [Vec<Option<usize>>],
    nodes_per_shard: usize,
    route: bool,
    route_payloads: bool,
    blocked: bool,
    tel: Option<&mut WorkerCounters>,
) {
    let HopScratch { failures, to } = scratch;
    let len = at.len();
    // Telemetry baselines, taken before any work. Deltas are read off
    // *after* the loop — nothing in between reads a clock or a stream.
    let deaths0 = deaths.len();
    let binned0: usize = if tel.is_some() && route { mail.iter().map(Vec::len).sum() } else { 0 };
    if blocked {
        // Reused across steps; only the first blocked step allocates.
        to.resize(HOP_BLOCK, 0);
        // Warm tier A for block 0 (later blocks are warmed one block
        // ahead, inside the loop).
        for &i in at.iter().take(HOP_BLOCK) {
            graph.prefetch_meta(i as usize);
        }
    }
    let mut start = 0;
    while start < len {
        let end = if blocked { (start + HOP_BLOCK).min(len) } else { len };
        if blocked {
            // Stage 1a: tier-A prefetch for block k+1 (offset pairs).
            let next_end = (end + HOP_BLOCK).min(len);
            for &i in &at[end..next_end] {
                graph.prefetch_meta(i as usize);
            }
            // Stage 1b: tier-B prefetch for block k (adjacency rows +
            // thresholds; reads the offsets tier A warmed last block).
            for &i in &at[start..end] {
                graph.prefetch(i as usize);
            }
            // Stage 2: batched hop draws, each from its walk's stream.
            graph.step_block(
                &at[start..end],
                &mut walk_rngs[start..end],
                &mut to[..end - start],
            );
        }
        // Stage 3 (blocked) / the whole loop (scalar): failure checks
        // and mailbox binning, one walk at a time in dense order.
        for j in start..end {
            let dense = base + j;
            let id = ids[dense];
            let from = at[j];
            let rng = &mut walk_rngs[j];
            let to_node =
                if blocked { to[j - start] } else { graph.step(from as usize, rng) as u32 };
            // Loss in transit (e.g. the per-hop Bernoulli) draws from the
            // walk's stream too — the check belongs to the walk's fate.
            if failures.on_hop(t, id, from, to_node, rng) {
                deaths.push(HopDeath { dense: dense as u32, node: from });
                continue;
            }
            at[j] = to_node;
            if failures.on_arrival(t, id, to_node, rng) {
                deaths.push(HopDeath { dense: dense as u32, node: to_node });
                continue;
            }
            if route {
                let s = to_node as usize / nodes_per_shard;
                mail[s].push(Arrival {
                    dense: dense as u32,
                    node: to_node,
                    id,
                    slot: lineage[dense].slot(),
                });
                if route_payloads {
                    pay[s].push(payloads[dense]);
                }
            }
        }
        start = end;
    }
    if let Some(c) = tel {
        c.hopped += len as u64;
        c.hop_deaths += (deaths.len() - deaths0) as u64;
        if route {
            let binned1: usize = mail.iter().map(Vec::len).sum();
            c.arrivals_binned += (binned1 - binned0) as u64;
        }
    }
}

/// The control phase's read-only view of one shard's arrivals — the one
/// point where the two [`RoutingMode`]s meet. Either way the consumer
/// sees the shard's arrivals ascending in the arena's dense order:
/// `Single` is the coordinator's serial bucket (one segment), `Mailbox`
/// is the shard's column of the mailbox matrix read in chunk order
/// (segment `c` = row `c·shards + shard`; chunks cover ascending dense
/// ranges, so the concatenation is exactly the serial bucket).
enum ArrivalFeed<'a> {
    Single(&'a [Arrival], &'a [Option<usize>]),
    Mailbox {
        mail: &'a [Vec<Arrival>],
        pay: &'a [Vec<Option<usize>>],
        shards: usize,
        shard: usize,
    },
}

impl<'a> ArrivalFeed<'a> {
    fn segments(&self) -> usize {
        match self {
            ArrivalFeed::Single(..) => 1,
            ArrivalFeed::Mailbox { shards, .. } => *shards,
        }
    }

    /// Segment `c`'s arrivals and (hooked runs only) payload mirror.
    fn segment(&self, c: usize) -> (&'a [Arrival], &'a [Option<usize>]) {
        match self {
            ArrivalFeed::Single(arrivals, payloads) => (arrivals, payloads),
            ArrivalFeed::Mailbox { mail, pay, shards, shard } => {
                (&mail[c * shards + shard], &pay[c * shards + shard])
            }
        }
    }
}

/// Control-phase worker: the shard's [`ArrivalFeed`] delivers its
/// arrivals in dense order; `observe` + the once-per-node-per-step
/// control decision run exactly as in the sequential engine, with
/// decision randomness drawn from the visited node's stream. The shard's
/// [`NodeStore`] owns both the states and the streams of its node range;
/// an arrival at a node the store has never seen materializes the node's
/// state and stream right here (a pure construction — no draw, no
/// ordering effect). The hook replica sees each arrival between
/// `observe` and the control decision — the same slot
/// `VisitHook::on_visit` occupies in the shared-stream engine; the
/// feed's payload mirror is empty, and never read, when `H::ACTIVE` is
/// false. Decisions land in `out` ascending in dense (the k-way merge
/// barrier's precondition), which holds because the feed is ascending
/// and a walk decides at most once per step.
#[allow(clippy::too_many_arguments)]
fn control_chunk<H: ShardHook>(
    store: &mut NodeStore,
    control: &mut Control,
    feed: ArrivalFeed<'_>,
    t: u64,
    control_start: u64,
    z0: u32,
    out: &mut Vec<DecisionOut>,
    hook: &H,
    replica: &mut H::Replica,
    blocked: bool,
    mut tel: Option<&mut WorkerCounters>,
) {
    let base = store.base();
    // Visited-count baseline: the delta at the end is exactly this
    // chunk's lazy materializations (dense stores never grow).
    let visited0 = tel.as_ref().map_or(0, |_| store.visited_count());
    for c in 0..feed.segments() {
        let (arrivals, payloads) = feed.segment(c);
        // Blocked pipelining (see [`HopPath`]): warm block 0's lookup
        // lines, then per block prefetch block k+1's lookups (tier A:
        // the `SlotIndex` home bucket in lazy mode, the state row in
        // dense mode) and block k's state rows + decision streams (tier
        // B, which needs the probe tier A warmed), then replay block k
        // scalar-wise. Prefetches are read-only hints — they never
        // materialize a lazy node and never touch a stream — so both
        // values of `blocked` produce identical decisions from identical
        // draws. (Mid-replay materializations may rehash the index under
        // an already-issued hint; the hint is then merely wasted.)
        if blocked {
            for a in arrivals.iter().take(HOP_BLOCK) {
                store.prefetch_lookup(a.node);
            }
        }
        let mut block_start = 0;
        while block_start < arrivals.len() {
            let block_end = if blocked {
                (block_start + HOP_BLOCK).min(arrivals.len())
            } else {
                arrivals.len()
            };
            if blocked {
                let next_end = (block_end + HOP_BLOCK).min(arrivals.len());
                for a in &arrivals[block_end..next_end] {
                    store.prefetch_lookup(a.node);
                }
                for a in &arrivals[block_start..block_end] {
                    store.prefetch_state(a.node);
                }
            }
            for j in block_start..block_end {
                let a = &arrivals[j];
                if let Some(c) = tel.as_deref_mut() {
                    // Probe-length sample *before* `state_rng_mut` can
                    // materialize the node: `probe_len` is a read-only
                    // walk of the index (0 for dense/unvisited), so the
                    // lookup it measures is unchanged by measuring it.
                    c.visits += 1;
                    c.probe_samples += 1;
                    c.probe_len_total += store.probe_len(a.node) as u64;
                }
                let (state, rng) = store.state_rng_mut(a.node);
                state.observe(t, a.id, a.slot);
                if H::ACTIVE {
                    hook.on_shard_visit(
                        replica,
                        t,
                        &ShardVisit {
                            dense: a.dense,
                            node: a.node,
                            local: a.node - base,
                            walk: a.id,
                            slot: a.slot,
                            payload: payloads[j],
                        },
                    );
                }
                // Warm-up and the one-decision-per-node-per-step rule
                // (footnote 6), exactly as in the sequential engine.
                if t < control_start || state.last_control_step == Some(t) {
                    continue;
                }
                state.last_control_step = Some(t);
                let decision = {
                    let mut ctx =
                        VisitCtx { t, node: a.node, walk: a.id, slot: a.slot, z0, state, rng };
                    control.on_visit(&mut ctx)
                };
                if decision.theta.is_some() || !decision.forks.is_empty() || decision.terminate {
                    out.push(DecisionOut { dense: a.dense, node: a.node, walk: a.id, decision });
                }
            }
            block_start = block_end;
        }
    }
    if let Some(c) = tel {
        c.materializations += (store.visited_count() - visited0) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::{Decafork, NoControl};
    use crate::failures::{Burst, NoFailures, Probabilistic};
    use crate::graph::generators;

    fn small_graph() -> Arc<Graph> {
        Arc::new(generators::random_regular(30, 4, &mut Rng::new(7)).unwrap())
    }

    fn run(shards: usize, seed: u64) -> Trace {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 8, record_theta: true, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(100, 4), (300, 3)]),
            Rng::new(seed),
            shards,
        );
        e.run_to(600);
        e.into_trace()
    }

    #[test]
    fn population_constant_without_failures_or_control() {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 5, ..Default::default() },
            NoControl,
            NoFailures,
            Rng::new(1),
            2,
        );
        e.run_to(300);
        assert_eq!(e.alive(), 5);
        assert!(e.trace().z.iter().all(|&z| z == 5));
        assert!(e.trace().events.is_empty());
    }

    #[test]
    fn trace_invariant_across_shard_counts() {
        let base = run(1, 11);
        for shards in [2, 3, 8] {
            let other = run(shards, 11);
            assert!(
                base.bit_identical(&other),
                "shards=1 vs {shards}: stream-mode trace diverged"
            );
        }
        assert_ne!(run(1, 11).z, run(1, 12).z, "different seeds must differ");
    }

    #[test]
    fn scoped_and_pooled_dispatch_bit_identical() {
        let mk = |mode| {
            let mut e = ShardedEngine::with_dispatch(
                small_graph(),
                SimParams { z0: 8, record_theta: true, ..Default::default() },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(11),
                4,
                mode,
            );
            e.run_to(600);
            e.into_trace()
        };
        assert!(
            mk(DispatchMode::Pooled).bit_identical(&mk(DispatchMode::Scoped)),
            "dispatch mode changed the trace — the perf_pool comparison would be meaningless"
        );
    }

    #[test]
    fn metrics_sink_is_observation_only_and_changes_no_trace() {
        use crate::obs::{MetricsConfig, MetricsMode};
        let mk = |mode: MetricsMode, name: &str| {
            let out = (mode != MetricsMode::Off).then(|| {
                let mut p = std::env::temp_dir();
                p.push(format!("decafork_sharded_metrics_{}_{name}", std::process::id()));
                p.to_string_lossy().into_owned()
            });
            let mut e = ShardedEngine::new(
                small_graph(),
                SimParams {
                    z0: 8,
                    record_theta: true,
                    metrics: MetricsConfig { mode, out: out.clone(), every: 7 },
                    ..Default::default()
                },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(11),
                4,
            );
            e.run_to(600);
            if let Some(p) = &out {
                std::fs::remove_file(p).ok();
            }
            e.into_trace()
        };
        let off = mk(MetricsMode::Off, "off");
        assert!(!off.theta.is_empty(), "vacuous without θ̂ telemetry to compare");
        assert!(
            off.bit_identical(&mk(MetricsMode::Jsonl, "jsonl")),
            "jsonl telemetry perturbed the trace — the zero-perturbation invariant is broken"
        );
        assert!(
            off.bit_identical(&mk(MetricsMode::Csv, "csv")),
            "csv telemetry perturbed the trace — the zero-perturbation invariant is broken"
        );
    }

    #[test]
    fn pool_sizing_tracks_shards_and_mode() {
        let mk = |shards, mode| {
            ShardedEngine::with_dispatch(
                small_graph(),
                SimParams { z0: 4, ..Default::default() },
                NoControl,
                NoFailures,
                Rng::new(1),
                shards,
                mode,
            )
        };
        assert_eq!(mk(1, DispatchMode::Pooled).pooled_workers(), 0);
        assert_eq!(mk(4, DispatchMode::Pooled).pooled_workers(), 3);
        assert_eq!(mk(4, DispatchMode::Scoped).pooled_workers(), 0);
        assert_eq!(mk(4, DispatchMode::Scoped).dispatch_mode(), DispatchMode::Scoped);
    }

    #[test]
    fn conservation_holds_under_churn() {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 8, control_start: Some(50), max_walks: 64, ..Default::default() },
            Decafork::new(2.0),
            Probabilistic::new(0.01),
            Rng::new(5),
            4,
        );
        e.run_to(400);
        let tr = e.trace();
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            delta[ev.t as usize] += if ev.kind == EventKind::Fork { 1 } else { -1 };
        }
        for t in 1..tr.z.len() {
            assert_eq!(
                tr.z[t] as i64 - tr.z[t - 1] as i64,
                delta[t],
                "conservation violated at t={t}"
            );
        }
    }

    #[test]
    fn extinction_flagged_and_padded() {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 3, ..Default::default() },
            NoControl,
            Probabilistic::new(0.5),
            Rng::new(3),
            2,
        );
        e.run_to(200);
        assert!(e.trace().extinct);
        assert_eq!(e.trace().z.len(), 201);
        assert_eq!(*e.trace().z.last().unwrap(), 0);
    }

    #[test]
    fn max_walks_cap_enforced() {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 4, max_walks: 16, control_start: Some(0), ..Default::default() },
            Decafork { epsilon: 100.0, p: Some(1.0) },
            NoFailures,
            Rng::new(7),
            4,
        );
        e.run_to(100);
        assert!(e.alive() <= 16);
        assert!(e.trace().capped);
    }

    #[test]
    fn forked_children_carry_lineage_and_wait_one_step() {
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 4, control_start: Some(0), max_walks: 64, ..Default::default() },
            Decafork { epsilon: 50.0, p: Some(1.0) },
            NoFailures,
            Rng::new(6),
            2,
        );
        for _ in 0..3 {
            e.step();
        }
        assert!(e.alive() > 4);
        for w in e.snapshot() {
            if let Lineage::Forked { at, .. } = w.lineage {
                assert!(at >= w.born);
            }
        }
    }

    #[test]
    fn slot_tables_allocated_only_for_missingperson() {
        // Run a few steps first: in the default lazy mode a state only
        // exists once its node is visited, so the assertions sweep the
        // visited set (and must not be vacuous — hence the count check).
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 6, ..Default::default() },
            Decafork::new(2.0),
            NoFailures,
            Rng::new(9),
            1,
        );
        e.run_to(20);
        assert!(e.states().visited_count() > 0, "20 steps must visit nodes");
        assert!(e.states().iter().all(|(_, s)| s.slot_last_seen.is_empty()));
        let mut e = ShardedEngine::new(
            small_graph(),
            SimParams { z0: 6, ..Default::default() },
            crate::control::MissingPerson::new(100),
            NoFailures,
            Rng::new(9),
            1,
        );
        e.run_to(20);
        assert!(e.states().visited_count() > 0, "20 steps must visit nodes");
        assert!(e.states().iter().all(|(_, s)| s.slot_last_seen.len() == 6));
    }

    #[test]
    fn lazy_and_dense_stores_bit_identical_and_lazy_stays_sparse() {
        use crate::walks::NodeStateMode;
        // One stream-mode scenario, four arms: {lazy, dense} × {1, 3}
        // workers — all four traces must be bit-identical (the store
        // mode and the shard count are both pure storage/scheduling
        // choices), and only the dense arms may have materialized every
        // node.
        let mk = |mode, shards| {
            let mut e = ShardedEngine::new(
                small_graph(),
                SimParams {
                    z0: 8,
                    record_theta: true,
                    prune_every: 16,
                    node_state: mode,
                    ..Default::default()
                },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(0xBEEF),
                shards,
            );
            e.run_to(250);
            let visited = e.states().visited_count();
            let bytes = e.states().memory_bytes();
            (e.into_trace(), visited, bytes)
        };
        let (dense1, dv, db) = mk(NodeStateMode::Dense, 1);
        assert_eq!(dv, 30, "dense mode materializes every node up front");
        for (mode, shards) in
            [(NodeStateMode::Lazy, 1), (NodeStateMode::Lazy, 3), (NodeStateMode::Dense, 3)]
        {
            let (tr, v, b) = mk(mode, shards);
            assert!(
                dense1.bit_identical(&tr),
                "{mode:?} × {shards} shards diverged from the dense oracle"
            );
            if mode == NodeStateMode::Lazy {
                assert!(v <= 30 && v > 0, "lazy visited count {v} out of range");
                assert!(b <= db * 2, "lazy store ({b} B) dwarfs dense ({db} B)");
            }
        }
        assert!(!dense1.theta.is_empty(), "no θ̂ samples — comparison is vacuous");
    }

    #[test]
    fn serial_and_mailbox_routing_bit_identical() {
        assert_eq!(
            SimParams::default().routing,
            RoutingMode::Mailbox,
            "mailbox routing is the production default; serial is the oracle"
        );
        // One churny scenario, four arms: {serial, mailbox} × {1, 4}
        // workers — all traces and first-visit orders (the witness for
        // arrival processing order) must match the serial 1-worker
        // oracle exactly.
        let mk = |routing, shards| {
            let mut e = ShardedEngine::new(
                small_graph(),
                SimParams {
                    z0: 8,
                    record_theta: true,
                    control_start: Some(50),
                    max_walks: 64,
                    routing,
                    ..Default::default()
                },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(0xA11CE),
                shards,
            );
            e.run_to(400);
            let visit_order: Vec<u32> = e.states().iter().map(|(node, _)| node).collect();
            (e.into_trace(), visit_order)
        };
        let (oracle, oracle_order) = mk(RoutingMode::Serial, 1);
        assert!(!oracle.events.is_empty(), "no churn — the comparison is vacuous");
        assert!(!oracle.theta.is_empty(), "no θ̂ samples — the comparison is vacuous");
        for (routing, shards) in
            [(RoutingMode::Mailbox, 1), (RoutingMode::Serial, 4), (RoutingMode::Mailbox, 4)]
        {
            let (tr, order) = mk(routing, shards);
            assert!(
                oracle.bit_identical(&tr),
                "{routing:?} × {shards} workers diverged from the serial oracle"
            );
            assert_eq!(
                order, oracle_order,
                "{routing:?} × {shards} workers moved the first-visit order — \
                 routing reordered the control feed"
            );
        }
    }

    #[test]
    fn scalar_and_blocked_hop_bit_identical() {
        assert_eq!(
            SimParams::default().hop_path,
            HopPath::Blocked,
            "blocked hot phases are the production default; scalar is the oracle"
        );
        // One churny scenario, six arms: {scalar, blocked} × {1, 3, 4}
        // workers — walk counts drift through sub-block, block-multiple
        // and unaligned-tail chunk sizes as forks and failures fire, and
        // every arm must match the scalar 1-worker oracle bit-for-bit
        // (trace, θ̂ floats, first-visit order).
        let mk = |hop_path, shards| {
            let mut e = ShardedEngine::new(
                small_graph(),
                SimParams {
                    z0: 8,
                    record_theta: true,
                    control_start: Some(50),
                    max_walks: 256,
                    hop_path,
                    ..Default::default()
                },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4), (300, 3)]),
                Rng::new(0xB10C_ED),
                shards,
            );
            e.run_to(400);
            let visit_order: Vec<u32> = e.states().iter().map(|(node, _)| node).collect();
            (e.into_trace(), visit_order)
        };
        let (oracle, oracle_order) = mk(HopPath::Scalar, 1);
        assert!(!oracle.events.is_empty(), "no churn — the comparison is vacuous");
        assert!(!oracle.theta.is_empty(), "no θ̂ samples — the comparison is vacuous");
        for (hop_path, shards) in [
            (HopPath::Blocked, 1),
            (HopPath::Scalar, 3),
            (HopPath::Blocked, 3),
            (HopPath::Scalar, 4),
            (HopPath::Blocked, 4),
        ] {
            let (tr, order) = mk(hop_path, shards);
            assert!(
                oracle.bit_identical(&tr),
                "{hop_path:?} × {shards} workers diverged from the scalar oracle"
            );
            assert_eq!(
                order, oracle_order,
                "{hop_path:?} × {shards} workers moved the first-visit order"
            );
        }
    }

    #[test]
    fn pin_cores_is_opt_in_and_changes_no_trace() {
        assert!(!SimParams::default().pin_cores, "pinning must be opt-in");
        let mk = |pin| {
            let mut e = ShardedEngine::new(
                small_graph(),
                SimParams { z0: 8, record_theta: true, pin_cores: pin, ..Default::default() },
                Decafork::new(2.0),
                Burst::new(vec![(100, 4)]),
                Rng::new(21),
                4,
            );
            assert_eq!(e.pooled_workers(), 3, "pinning must not change pool sizing");
            e.run_to(300);
            e.into_trace()
        };
        assert!(
            mk(false).bit_identical(&mk(true)),
            "--pin-cores changed the trace — pinning must be placement-only"
        );
    }
}
