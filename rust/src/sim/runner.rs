//! Multi-seed experiment runner: fans replications out over OS threads
//! (no async runtime needed — runs are CPU-bound and independent) and
//! aggregates traces into the mean ± std bands the paper plots.
//!
//! Engine selection: `cfg.params.shards == 1` (the default) runs each
//! replication on the shared-stream arena [`Engine`]; `>= 2` runs it on
//! the stream-mode [`ShardedEngine`](crate::sim::ShardedEngine).
//!
//! ## The core budget
//!
//! The two parallelism knobs — `threads` replications × `shards` workers
//! per replication — multiply, and historically both were trusted
//! independently: auto-threads with `shards = 8` on an 8-core box
//! spawned 64 workers. A [`CoreBudget`] (CLI `--cores`, env
//! `DECAFORK_CORES`, default = detected parallelism) now owns the split:
//! [`CoreBudget::plan`] deterministically turns `(runs, threads, shards)`
//! requests into `(threads, workers_per_run)` so the product never
//! exceeds the budget. Shrinking the per-run worker count is *free* —
//! stream-mode traces are bit-identical at every worker count — so the
//! plan can trade shards for replication throughput without changing a
//! single result bit; the `shards >= 2` request still selects the
//! stream-mode trace *family* even when the plan hands a run one worker.
//!
//! Results land in **pre-sized slots** indexed by run: each worker
//! writes replication `i`'s outcome into slot `i` (uncontended — every
//! slot is written exactly once), so ordering needs no post-hoc sort
//! and a failure can never lose track of *which* replication failed —
//! errors carry their run index as context.
//!
//! [`Engine`]: crate::sim::engine::Engine

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::config::ExperimentConfig;
use crate::sim::metrics::{AggregateTrace, Trace};

/// A process-wide core budget for the runner's `threads × shards`
/// product. Construction validates (`total >= 1`); the split itself is
/// [`plan`](Self::plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreBudget {
    total: usize,
}

impl CoreBudget {
    /// An explicit budget of `total` cores (rejects 0).
    pub fn new(total: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(total >= 1, "core budget must be >= 1 (got {total})");
        Ok(CoreBudget { total })
    }

    /// Detected available parallelism (the default budget).
    pub fn detect() -> Self {
        CoreBudget { total: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4) }
    }

    /// `DECAFORK_CORES` override, else [`detect`](Self::detect). A
    /// present-but-invalid value (0, non-numeric) is an **error**, not a
    /// silent fallback: a typo'd budget in a bench matrix must not
    /// quietly oversubscribe or serialize the whole sweep. Validation is
    /// the same [`positive_count`](crate::cli::positive_count) every
    /// shards/cores knob goes through.
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var("DECAFORK_CORES") {
            Err(_) => Ok(Self::detect()),
            Ok(v) => Self::new(crate::cli::positive_count("DECAFORK_CORES", &v)?),
        }
    }

    /// The number of cores this budget may spend.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Deterministically split the budget across `runs` replications of
    /// a scenario requesting `shards` stream-mode workers each. The
    /// resulting `threads × workers_per_run` product never exceeds the
    /// budget (both knobs are *requests*; the budget is the constraint —
    /// raise `--cores` to get more).
    ///
    /// * `threads == 0` (auto) resolves to `min(runs, total / shards)`
    ///   (floored at 1) — the oversubscription fix: auto mode used to
    ///   take the full parallelism for replications *and* multiply it by
    ///   the per-run worker count.
    /// * An explicit `threads` is honored up to the budget (capped at
    ///   `min(runs, total)`); the leftover then bounds the per-run
    ///   worker count: `workers = min(shards, total / threads)`, floored
    ///   at 1. Worker counts are a pure perf knob (schedule-invariant
    ///   traces), so none of this ever changes a result.
    pub fn plan(&self, runs: usize, threads: usize, shards: usize) -> RunPlan {
        let runs = runs.max(1);
        let shards = shards.max(1);
        let threads = if threads == 0 {
            (self.total / shards).max(1).min(runs)
        } else {
            threads.min(runs).min(self.total)
        };
        let workers_per_run =
            if shards == 1 { 1 } else { (self.total / threads).clamp(1, shards) };
        RunPlan { threads, workers_per_run }
    }
}

/// A resolved parallelism split: how many replication threads to run,
/// and how many stream-mode workers each replication gets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunPlan {
    pub threads: usize,
    pub workers_per_run: usize,
}

/// One replication. `cfg.params.shards` selects the engine *family*
/// (shared-stream vs stream-mode); `workers` — already budgeted by the
/// caller — sets the stream engine's actual worker count, which cannot
/// affect the trace.
fn run_one(cfg: &ExperimentConfig, run: usize, workers: usize) -> anyhow::Result<Trace> {
    if cfg.params.shards > 1 {
        let mut e = cfg.sharded_engine(run, workers)?;
        e.run_to(cfg.horizon);
        Ok(e.into_trace())
    } else {
        let mut e = cfg.build_engine(run)?;
        e.run_to(cfg.horizon);
        Ok(e.into_trace())
    }
}

/// Run `cfg.runs` independent replications in parallel across up to
/// `threads` OS threads (0 = auto), budgeted by `DECAFORK_CORES` /
/// detected parallelism, and return all traces (ordered by run index)
/// plus their aggregate. See [`run_many_with_budget`] for an explicit
/// budget (the CLI's `--cores`).
pub fn run_many(
    cfg: &ExperimentConfig,
    threads: usize,
) -> anyhow::Result<(Vec<Trace>, AggregateTrace)> {
    run_many_with_budget(cfg, threads, CoreBudget::from_env()?)
}

/// [`run_many`] with an explicit [`CoreBudget`] owning the
/// `threads × workers-per-run` split.
pub fn run_many_with_budget(
    cfg: &ExperimentConfig,
    threads: usize,
    budget: CoreBudget,
) -> anyhow::Result<(Vec<Trace>, AggregateTrace)> {
    let runs = cfg.runs;
    anyhow::ensure!(runs > 0, "need at least one run");
    let RunPlan { threads, workers_per_run } = budget.plan(runs, threads, cfg.params.shards);

    let next = AtomicUsize::new(0);
    // One slot per replication. The per-slot mutex is never contended
    // (exactly one writer per slot); it exists to make the disjoint
    // writes safe without unsafe code.
    type Slot = Mutex<Option<anyhow::Result<Trace>>>;
    let slots: Vec<Slot> = (0..runs).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let run = next.fetch_add(1, Ordering::Relaxed);
                if run >= runs {
                    break;
                }
                let out = run_one(cfg, run, workers_per_run)
                    .map_err(|e| e.context(format!("replication {run} (of {runs})")));
                *slots[run].lock().unwrap() = Some(out);
            });
        }
    });

    let mut traces = Vec::with_capacity(runs);
    for (run, slot) in slots.into_iter().enumerate() {
        let out = slot
            .into_inner()
            .unwrap()
            .unwrap_or_else(|| panic!("replication {run} was never executed"));
        traces.push(out?);
    }
    let agg = AggregateTrace::from_traces(&traces);
    Ok((traces, agg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{ControlSpec, FailureSpec, GraphSpec};
    use crate::sim::engine::SimParams;

    fn tiny_cfg(runs: usize) -> ExperimentConfig {
        ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 30, d: 4 },
            params: SimParams { z0: 6, ..Default::default() },
            control: ControlSpec::Decafork { epsilon: 1.5 },
            failures: FailureSpec::Burst { events: vec![(200, 3)] },
            horizon: 600,
            runs,
            seed: 99,
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = tiny_cfg(6);
        let (t1, _) = run_many(&cfg, 1).unwrap();
        let (t4, _) = run_many(&cfg, 4).unwrap();
        assert_eq!(t1.len(), t4.len());
        for (a, b) in t1.iter().zip(t4.iter()) {
            assert_eq!(a.z, b.z, "run traces differ between thread counts");
        }
    }

    #[test]
    fn aggregate_shape() {
        let cfg = tiny_cfg(4);
        let (traces, agg) = run_many(&cfg, 0).unwrap();
        assert_eq!(agg.runs, 4);
        assert_eq!(agg.mean.len(), traces[0].z.len());
        assert_eq!(agg.mean[0], 6.0);
        // The burst kills 3 walks at t=200: the mean must drop by ~3
        // relative to the pre-burst level (whatever forking did before).
        assert!(
            agg.mean[201] < agg.mean[199] - 2.0,
            "burst should dent the mean: {} -> {}",
            agg.mean[199],
            agg.mean[201]
        );
    }

    #[test]
    fn errors_carry_the_run_index() {
        // n*d odd → every replication's graph build fails; the surfaced
        // error (the lowest run index) must say which replication it was.
        let mut cfg = tiny_cfg(3);
        cfg.graph = GraphSpec::RandomRegular { n: 5, d: 3 };
        let err = run_many(&cfg, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("replication 0"), "error lost its run index: {msg}");
    }

    #[test]
    fn core_budget_plan_is_deterministic_and_bounded() {
        let b = CoreBudget::new(8).unwrap();
        assert_eq!(b.total(), 8);
        // Auto mode divides by shards instead of multiplying (the
        // oversubscription fix): 8 cores / 4-shard runs = 2 threads.
        assert_eq!(b.plan(50, 0, 4), RunPlan { threads: 2, workers_per_run: 4 });
        // shards == 1: the whole budget goes to replications.
        assert_eq!(b.plan(50, 0, 1), RunPlan { threads: 8, workers_per_run: 1 });
        // Few runs never spawn idle replication threads.
        assert_eq!(b.plan(3, 0, 1), RunPlan { threads: 3, workers_per_run: 1 });
        // Explicit threads are honored; the leftover bounds the per-run
        // worker count (worker counts are schedule-invariant, so this is
        // free).
        assert_eq!(b.plan(50, 8, 8), RunPlan { threads: 8, workers_per_run: 1 });
        assert_eq!(b.plan(50, 2, 8), RunPlan { threads: 2, workers_per_run: 4 });
        // Shard requests beyond the budget collapse to what fits.
        assert_eq!(b.plan(4, 0, 16), RunPlan { threads: 1, workers_per_run: 8 });
        // ... and so do explicit thread requests: 64 threads on an
        // 8-core budget is the oversubscription this type exists to
        // prevent, whichever knob asks for it.
        assert_eq!(b.plan(64, 64, 1), RunPlan { threads: 8, workers_per_run: 1 });
        // Auto mode's thread × worker product never exceeds the budget.
        for runs in [1usize, 3, 17] {
            for shards in [1usize, 2, 7, 64] {
                let p = b.plan(runs, 0, shards);
                assert!(
                    p.threads * p.workers_per_run <= 8,
                    "auto plan oversubscribed: runs={runs} shards={shards} -> {p:?}"
                );
                assert!(p.threads >= 1 && p.workers_per_run >= 1);
            }
        }
        assert!(CoreBudget::new(0).is_err(), "a zero-core budget must be rejected");
    }

    #[test]
    fn budgeted_runner_is_result_invariant() {
        // A 1-core budget and a generous one must produce bit-identical
        // traces — the plan only moves work between threads.
        let mut cfg = tiny_cfg(4);
        cfg.params.shards = 2;
        let (a, _) = run_many_with_budget(&cfg, 0, CoreBudget::new(1).unwrap()).unwrap();
        let (b, _) = run_many_with_budget(&cfg, 2, CoreBudget::new(8).unwrap()).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert!(x.bit_identical(y), "core budget changed a stream-mode trace");
        }
    }

    #[test]
    fn shards_field_dispatches_to_the_stream_engine() {
        // shards >= 2 must route through the sharded engine — and the
        // result must be invariant in both the worker count and the
        // runner's thread count.
        let mut cfg = tiny_cfg(2);
        cfg.params.shards = 2;
        let (t2, _) = run_many(&cfg, 1).unwrap();
        let direct = {
            let mut e = cfg.sharded_engine(0, 2).unwrap();
            e.run_to(cfg.horizon);
            e.into_trace()
        };
        assert!(t2[0].bit_identical(&direct), "runner dispatch diverged from direct build");
        cfg.params.shards = 4;
        let (t4, _) = run_many(&cfg, 2).unwrap();
        for (a, b) in t2.iter().zip(t4.iter()) {
            assert!(a.bit_identical(b), "stream-mode trace depends on worker count");
        }
        // ... and differs from the shared-stream family (different
        // randomness ownership, same scenario).
        cfg.params.shards = 1;
        let (t1, _) = run_many(&cfg, 1).unwrap();
        assert_ne!(t1[0].z, t2[0].z, "stream mode should be a distinct trace family");
    }
}
