//! Multi-seed experiment runner: fans replications out over OS threads
//! (no async runtime needed — runs are CPU-bound and independent) and
//! aggregates traces into the mean ± std bands the paper plots.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::config::ExperimentConfig;
use crate::sim::metrics::{AggregateTrace, Trace};

/// Run `cfg.runs` independent replications of the experiment, in parallel
/// across up to `threads` OS threads (0 = available parallelism), and
/// return all traces (ordered by run index) plus their aggregate.
pub fn run_many(cfg: &ExperimentConfig, threads: usize) -> anyhow::Result<(Vec<Trace>, AggregateTrace)> {
    let runs = cfg.runs;
    anyhow::ensure!(runs > 0, "need at least one run");
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(runs);

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, anyhow::Result<Trace>)>> = Mutex::new(Vec::with_capacity(runs));

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let run = next.fetch_add(1, Ordering::Relaxed);
                if run >= runs {
                    break;
                }
                let out = cfg.build_engine(run).map(|mut e| {
                    e.run_to(cfg.horizon);
                    e.into_trace()
                });
                results.lock().unwrap().push((run, out));
            });
        }
    });

    let mut collected = results.into_inner().unwrap();
    collected.sort_by_key(|(run, _)| *run);
    let mut traces = Vec::with_capacity(runs);
    for (_, r) in collected {
        traces.push(r?);
    }
    let agg = AggregateTrace::from_traces(&traces);
    Ok((traces, agg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{ControlSpec, FailureSpec, GraphSpec};
    use crate::sim::engine::SimParams;

    fn tiny_cfg(runs: usize) -> ExperimentConfig {
        ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 30, d: 4 },
            params: SimParams { z0: 6, ..Default::default() },
            control: ControlSpec::Decafork { epsilon: 1.5 },
            failures: FailureSpec::Burst { events: vec![(200, 3)] },
            horizon: 600,
            runs,
            seed: 99,
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = tiny_cfg(6);
        let (t1, _) = run_many(&cfg, 1).unwrap();
        let (t4, _) = run_many(&cfg, 4).unwrap();
        assert_eq!(t1.len(), t4.len());
        for (a, b) in t1.iter().zip(t4.iter()) {
            assert_eq!(a.z, b.z, "run traces differ between thread counts");
        }
    }

    #[test]
    fn aggregate_shape() {
        let cfg = tiny_cfg(4);
        let (traces, agg) = run_many(&cfg, 0).unwrap();
        assert_eq!(agg.runs, 4);
        assert_eq!(agg.mean.len(), traces[0].z.len());
        assert_eq!(agg.mean[0], 6.0);
        // The burst kills 3 walks at t=200: the mean must drop by ~3
        // relative to the pre-burst level (whatever forking did before).
        assert!(
            agg.mean[201] < agg.mean[199] - 2.0,
            "burst should dent the mean: {} -> {}",
            agg.mean[199],
            agg.mean[201]
        );
    }
}
