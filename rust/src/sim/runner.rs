//! Multi-seed experiment runner: fans replications out over OS threads
//! (no async runtime needed — runs are CPU-bound and independent) and
//! aggregates traces into the mean ± std bands the paper plots.
//!
//! Engine selection: `cfg.params.shards == 1` (the default) runs each
//! replication on the shared-stream arena [`Engine`]; `>= 2` runs it on
//! the stream-mode [`ShardedEngine`](crate::sim::ShardedEngine) with
//! that many workers per replication. Note the two knobs multiply:
//! `threads` replications × `shards` workers each — callers driving big
//! stream-mode scenarios usually want `threads = 1`.
//!
//! Results land in **pre-sized slots** indexed by run: each worker
//! writes replication `i`'s outcome into slot `i` (uncontended — every
//! slot is written exactly once), so ordering needs no post-hoc sort
//! and a failure can never lose track of *which* replication failed —
//! errors carry their run index as context.
//!
//! [`Engine`]: crate::sim::engine::Engine

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::config::ExperimentConfig;
use crate::sim::metrics::{AggregateTrace, Trace};

/// One replication, on whichever engine `cfg.params.shards` selects.
fn run_one(cfg: &ExperimentConfig, run: usize) -> anyhow::Result<Trace> {
    if cfg.params.shards > 1 {
        let mut e = cfg.sharded_engine(run, cfg.params.shards)?;
        e.run_to(cfg.horizon);
        Ok(e.into_trace())
    } else {
        let mut e = cfg.build_engine(run)?;
        e.run_to(cfg.horizon);
        Ok(e.into_trace())
    }
}

/// Run `cfg.runs` independent replications of the experiment, in parallel
/// across up to `threads` OS threads (0 = available parallelism), and
/// return all traces (ordered by run index) plus their aggregate.
pub fn run_many(
    cfg: &ExperimentConfig,
    threads: usize,
) -> anyhow::Result<(Vec<Trace>, AggregateTrace)> {
    let runs = cfg.runs;
    anyhow::ensure!(runs > 0, "need at least one run");
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    } else {
        threads
    }
    .min(runs);

    let next = AtomicUsize::new(0);
    // One slot per replication. The per-slot mutex is never contended
    // (exactly one writer per slot); it exists to make the disjoint
    // writes safe without unsafe code.
    type Slot = Mutex<Option<anyhow::Result<Trace>>>;
    let slots: Vec<Slot> = (0..runs).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let run = next.fetch_add(1, Ordering::Relaxed);
                if run >= runs {
                    break;
                }
                let out = run_one(cfg, run)
                    .map_err(|e| e.context(format!("replication {run} (of {runs})")));
                *slots[run].lock().unwrap() = Some(out);
            });
        }
    });

    let mut traces = Vec::with_capacity(runs);
    for (run, slot) in slots.into_iter().enumerate() {
        let out = slot
            .into_inner()
            .unwrap()
            .unwrap_or_else(|| panic!("replication {run} was never executed"));
        traces.push(out?);
    }
    let agg = AggregateTrace::from_traces(&traces);
    Ok((traces, agg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{ControlSpec, FailureSpec, GraphSpec};
    use crate::sim::engine::SimParams;

    fn tiny_cfg(runs: usize) -> ExperimentConfig {
        ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 30, d: 4 },
            params: SimParams { z0: 6, ..Default::default() },
            control: ControlSpec::Decafork { epsilon: 1.5 },
            failures: FailureSpec::Burst { events: vec![(200, 3)] },
            horizon: 600,
            runs,
            seed: 99,
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let cfg = tiny_cfg(6);
        let (t1, _) = run_many(&cfg, 1).unwrap();
        let (t4, _) = run_many(&cfg, 4).unwrap();
        assert_eq!(t1.len(), t4.len());
        for (a, b) in t1.iter().zip(t4.iter()) {
            assert_eq!(a.z, b.z, "run traces differ between thread counts");
        }
    }

    #[test]
    fn aggregate_shape() {
        let cfg = tiny_cfg(4);
        let (traces, agg) = run_many(&cfg, 0).unwrap();
        assert_eq!(agg.runs, 4);
        assert_eq!(agg.mean.len(), traces[0].z.len());
        assert_eq!(agg.mean[0], 6.0);
        // The burst kills 3 walks at t=200: the mean must drop by ~3
        // relative to the pre-burst level (whatever forking did before).
        assert!(
            agg.mean[201] < agg.mean[199] - 2.0,
            "burst should dent the mean: {} -> {}",
            agg.mean[199],
            agg.mean[201]
        );
    }

    #[test]
    fn errors_carry_the_run_index() {
        // n*d odd → every replication's graph build fails; the surfaced
        // error (the lowest run index) must say which replication it was.
        let mut cfg = tiny_cfg(3);
        cfg.graph = GraphSpec::RandomRegular { n: 5, d: 3 };
        let err = run_many(&cfg, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("replication 0"), "error lost its run index: {msg}");
    }

    #[test]
    fn shards_field_dispatches_to_the_stream_engine() {
        // shards >= 2 must route through the sharded engine — and the
        // result must be invariant in both the worker count and the
        // runner's thread count.
        let mut cfg = tiny_cfg(2);
        cfg.params.shards = 2;
        let (t2, _) = run_many(&cfg, 1).unwrap();
        let direct = {
            let mut e = cfg.sharded_engine(0, 2).unwrap();
            e.run_to(cfg.horizon);
            e.into_trace()
        };
        assert!(t2[0].bit_identical(&direct), "runner dispatch diverged from direct build");
        cfg.params.shards = 4;
        let (t4, _) = run_many(&cfg, 2).unwrap();
        for (a, b) in t2.iter().zip(t4.iter()) {
            assert!(a.bit_identical(b), "stream-mode trace depends on worker count");
        }
        // ... and differs from the shared-stream family (different
        // randomness ownership, same scenario).
        cfg.params.shards = 1;
        let (t1, _) = run_many(&cfg, 1).unwrap();
        assert_ne!(t1[0].z, t2[0].z, "stream mode should be a distinct trace family");
    }
}
