//! Back-compat shim: experiment configuration moved to the scenario
//! layer ([`crate::scenario`]), which unifies the config→engine wiring
//! for the CLI, figures, benches and tests. Existing imports through
//! `crate::sim::config` (and the historical `ExperimentConfig` name)
//! keep working.

pub use crate::scenario::{ControlSpec, FailureSpec, GraphSpec, Scenario};

/// Historical name for [`Scenario`].
pub type ExperimentConfig = Scenario;
