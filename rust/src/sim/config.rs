//! Declarative experiment configuration: pure-data specs for the graph,
//! control algorithm and failure model, so experiments (CLI, figures,
//! benches) are described by values and built reproducibly from a seed.

use std::sync::Arc;

use crate::control::{ControlAlgorithm, Decafork, DecaforkPlus, MissingPerson, NoControl, PeriodicFork};
use crate::failures::{Burst, Byzantine, Composite, FailureModel, NoFailures, Probabilistic};
use crate::graph::{generators, Graph};
use crate::rng::Rng;
use crate::sim::engine::{Engine, SimParams};

/// Which graph to build.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSpec {
    RandomRegular { n: usize, d: usize },
    ErdosRenyi { n: usize, p: f64 },
    Complete { n: usize },
    PowerLaw { n: usize, m: usize },
    Ring { n: usize },
    Torus { w: usize, h: usize },
}

impl GraphSpec {
    pub fn build(&self, rng: &mut Rng) -> anyhow::Result<Graph> {
        match *self {
            GraphSpec::RandomRegular { n, d } => generators::random_regular(n, d, rng),
            GraphSpec::ErdosRenyi { n, p } => generators::erdos_renyi(n, p, rng),
            GraphSpec::Complete { n } => Ok(generators::complete(n)),
            GraphSpec::PowerLaw { n, m } => generators::barabasi_albert(n, m, rng),
            GraphSpec::Ring { n } => Ok(generators::ring(n)),
            GraphSpec::Torus { w, h } => Ok(generators::grid_torus(w, h)),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            GraphSpec::RandomRegular { n, d } => format!("{d}-regular(n={n})"),
            GraphSpec::ErdosRenyi { n, p } => format!("ER(n={n},p={p})"),
            GraphSpec::Complete { n } => format!("complete(n={n})"),
            GraphSpec::PowerLaw { n, m } => format!("power-law(n={n},m={m})"),
            GraphSpec::Ring { n } => format!("ring(n={n})"),
            GraphSpec::Torus { w, h } => format!("torus({w}x{h})"),
        }
    }
}

/// Which control algorithm to run.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlSpec {
    None,
    Periodic { period: u64 },
    MissingPerson { eps_mp: u64 },
    Decafork { epsilon: f64 },
    DecaforkPlus { epsilon: f64, epsilon2: f64 },
}

impl ControlSpec {
    pub fn build(&self, n_nodes: usize) -> Box<dyn ControlAlgorithm> {
        match *self {
            ControlSpec::None => Box::new(NoControl),
            ControlSpec::Periodic { period } => Box::new(PeriodicFork::new(n_nodes, period)),
            ControlSpec::MissingPerson { eps_mp } => Box::new(MissingPerson::new(eps_mp)),
            ControlSpec::Decafork { epsilon } => Box::new(Decafork::new(epsilon)),
            ControlSpec::DecaforkPlus { epsilon, epsilon2 } => {
                Box::new(DecaforkPlus::new(epsilon, epsilon2))
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            ControlSpec::None => "none".into(),
            ControlSpec::Periodic { period } => format!("periodic(T={period})"),
            ControlSpec::MissingPerson { eps_mp } => format!("missingperson(eps={eps_mp})"),
            ControlSpec::Decafork { epsilon } => format!("decafork(eps={epsilon})"),
            ControlSpec::DecaforkPlus { epsilon, epsilon2 } => {
                format!("decafork+(eps={epsilon},eps2={epsilon2})")
            }
        }
    }
}

/// Which failure model to inject.
#[derive(Debug, Clone, PartialEq)]
pub enum FailureSpec {
    None,
    Burst { events: Vec<(u64, usize)> },
    Probabilistic { p_f: f64 },
    ByzantineScheduled { node: u32, schedule: Vec<(u64, bool)> },
    ByzantineMarkov { node: u32, p_b: f64 },
    Composite(Vec<FailureSpec>),
}

impl FailureSpec {
    pub fn build(&self) -> Box<dyn FailureModel> {
        match self {
            FailureSpec::None => Box::new(NoFailures),
            FailureSpec::Burst { events } => Box::new(Burst::new(events.clone())),
            FailureSpec::Probabilistic { p_f } => Box::new(Probabilistic::new(*p_f)),
            FailureSpec::ByzantineScheduled { node, schedule } => {
                Box::new(Byzantine::scheduled(*node, schedule.clone()))
            }
            FailureSpec::ByzantineMarkov { node, p_b } => {
                Box::new(Byzantine::markov(*node, *p_b, false))
            }
            FailureSpec::Composite(parts) => {
                Box::new(Composite::new(parts.iter().map(|p| p.build()).collect()))
            }
        }
    }

    /// The paper's Fig. 1 bursts.
    pub fn paper_bursts() -> Self {
        FailureSpec::Burst { events: vec![(2000, 5), (6000, 6)] }
    }
}

/// A complete experiment: graph + engine params + control + failures +
/// replication.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub graph: GraphSpec,
    pub params: SimParams,
    pub control: ControlSpec,
    pub failures: FailureSpec,
    pub horizon: u64,
    pub runs: usize,
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper Fig. 1 base setup (per-algorithm variants set `control`).
    pub fn fig1_base() -> Self {
        ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 100, d: 8 },
            params: SimParams::default(),
            control: ControlSpec::Decafork { epsilon: 2.0 },
            failures: FailureSpec::paper_bursts(),
            horizon: 10_000,
            runs: 50,
            seed: 0xDECAF,
        }
    }

    /// Build one engine for run index `run` (deterministic in seed+run).
    pub fn build_engine(&self, run: usize) -> anyhow::Result<Engine> {
        let root = Rng::new(self.seed);
        // Graph stream is shared across runs when `shared_graph` semantics
        // are wanted; the paper regenerates graphs per simulation, so we
        // derive a per-run graph stream.
        let mut grng = root.split(0x67726170).split(run as u64);
        let graph = Arc::new(self.graph.build(&mut grng)?);
        let srng = root.split(0x73696d75).split(run as u64);
        Ok(Engine::new(
            graph.clone(),
            self.params.clone(),
            self.control.build(graph.n()),
            self.failures.build(),
            srng,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build() {
        let mut rng = Rng::new(1);
        for spec in [
            GraphSpec::RandomRegular { n: 20, d: 4 },
            GraphSpec::Complete { n: 10 },
            GraphSpec::Ring { n: 12 },
            GraphSpec::Torus { w: 4, h: 4 },
            GraphSpec::ErdosRenyi { n: 30, p: 0.3 },
            GraphSpec::PowerLaw { n: 30, m: 3 },
        ] {
            let g = spec.build(&mut rng).unwrap();
            assert!(g.is_connected(), "{}", spec.label());
        }
    }

    #[test]
    fn control_specs_build() {
        for spec in [
            ControlSpec::None,
            ControlSpec::Periodic { period: 10 },
            ControlSpec::MissingPerson { eps_mp: 100 },
            ControlSpec::Decafork { epsilon: 2.0 },
            ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 },
        ] {
            let alg = spec.build(16);
            assert!(!alg.name().is_empty());
            assert!(!spec.label().is_empty());
        }
    }

    #[test]
    fn experiment_deterministic() {
        let mut cfg = ExperimentConfig::fig1_base();
        cfg.graph = GraphSpec::RandomRegular { n: 30, d: 4 };
        cfg.horizon = 300;
        let z1 = {
            let mut e = cfg.build_engine(0).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        let z2 = {
            let mut e = cfg.build_engine(0).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        assert_eq!(z1, z2);
        let z3 = {
            let mut e = cfg.build_engine(1).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        assert_ne!(z1, z3);
    }
}
