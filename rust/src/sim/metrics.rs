//! Simulation telemetry: per-run traces of the walk population `Z_t`,
//! discrete events (forks, control terminations, failures), and the
//! derived quantities the paper's evaluation reports — reaction time after
//! a failure event, overshoot beyond `Z0`, and extinction.

/// What happened to a walk at a point in time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A control fork created a new walk.
    Fork,
    /// A control algorithm deliberately terminated the walk (DECAFORK+).
    ControlTermination,
    /// A failure model killed the walk.
    Failure,
}

/// One recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t: u64,
    pub node: u32,
    pub walk: u64,
    pub kind: EventKind,
}

/// Full telemetry from one simulation run.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// `z[t]` = number of active walks at the end of step `t`
    /// (`z[0]` is the initial population `Z0`).
    pub z: Vec<u32>,
    pub events: Vec<Event>,
    /// Optional estimator telemetry: (t, θ̂) samples from control decisions.
    pub theta: Vec<(u64, f64)>,
    /// True if the population hit zero (catastrophic failure).
    pub extinct: bool,
    /// True if the safety cap on the number of walks was hit (flooding).
    pub capped: bool,
    /// Node states materialized over the run (`StatesView::
    /// visited_count()` at teardown; the full node count in dense
    /// mode). Footprint metadata stamped by `into_trace` — **not**
    /// compared by [`bit_identical`], which checks what the simulation
    /// *did*, not how much memory it used doing it.
    pub visited_nodes: usize,
    /// Resident bytes of the visited node state at teardown
    /// (`StatesView::memory_bytes()`). Metadata like `visited_nodes`.
    pub state_bytes: usize,
}

impl Trace {
    /// Steps simulated (excluding the t=0 entry).
    pub fn horizon(&self) -> u64 {
        self.z.len().saturating_sub(1) as u64
    }

    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// First time `>= from` at which `Z_t >= target`; `None` if never.
    /// With `from` = a burst time this is the paper's *reaction time*
    /// (time until the system restores the desired redundancy).
    pub fn recovery_time(&self, from: u64, target: u32) -> Option<u64> {
        (from as usize..self.z.len())
            .find(|&t| self.z[t] >= target)
            .map(|t| t as u64 - from)
    }

    /// Maximum population in the window `[from, to]` — overshoot probe.
    pub fn max_z(&self, from: u64, to: u64) -> u32 {
        let hi = (to as usize + 1).min(self.z.len());
        self.z[from as usize..hi].iter().copied().max().unwrap_or(0)
    }

    /// Minimum population in the window `[from, to]`.
    pub fn min_z(&self, from: u64, to: u64) -> u32 {
        let hi = (to as usize + 1).min(self.z.len());
        self.z[from as usize..hi].iter().copied().min().unwrap_or(0)
    }

    /// Bit-level trace equality: the population trace, the full event
    /// log (times, nodes, walk ids, kinds), the θ̂ telemetry compared by
    /// `f64::to_bits` (no epsilon — schedule invariance promises the
    /// *identical* float, not a close one), and the outcome flags. This
    /// is the assertion the sharded engine's shard-count invariance
    /// tests and `perf_shard` are built on.
    pub fn bit_identical(&self, other: &Trace) -> bool {
        self.z == other.z
            && self.events == other.events
            && self.extinct == other.extinct
            && self.capped == other.capped
            && self.theta.len() == other.theta.len()
            && self
                .theta
                .iter()
                .zip(&other.theta)
                .all(|(a, b)| a.0 == b.0 && a.1.to_bits() == b.1.to_bits())
    }

    /// Mean population over the window `[from, to]`.
    pub fn mean_z(&self, from: u64, to: u64) -> f64 {
        let hi = (to as usize + 1).min(self.z.len());
        let slice = &self.z[from as usize..hi];
        if slice.is_empty() {
            return f64::NAN;
        }
        slice.iter().map(|&z| z as f64).sum::<f64>() / slice.len() as f64
    }
}

/// Mean ± std aggregation of `Z_t` across runs (the shaded bands in the
/// paper's figures), plus run-level outcome counters.
#[derive(Debug, Clone, Default)]
pub struct AggregateTrace {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    pub min: Vec<u32>,
    pub max: Vec<u32>,
    pub runs: usize,
    pub extinctions: usize,
    pub capped_runs: usize,
    /// Per-run total fork / control-termination / failure counts.
    pub forks_per_run: Vec<usize>,
    pub terms_per_run: Vec<usize>,
    pub failures_per_run: Vec<usize>,
    /// Largest visited-state footprint across runs (nodes materialized
    /// / resident bytes) — what a summary reports as the memory high
    /// water mark without a debugger attached.
    pub max_visited_nodes: usize,
    pub max_state_bytes: usize,
}

impl AggregateTrace {
    /// Combine per-run traces (all must share the same horizon).
    pub fn from_traces(traces: &[Trace]) -> Self {
        assert!(!traces.is_empty());
        let len = traces.iter().map(|t| t.z.len()).min().unwrap();
        let runs = traces.len();
        let mut mean = vec![0.0; len];
        let mut m2 = vec![0.0; len];
        let mut min = vec![u32::MAX; len];
        let mut max = vec![0u32; len];
        for (k, tr) in traces.iter().enumerate() {
            for i in 0..len {
                let x = tr.z[i] as f64;
                // Welford online mean/variance across runs.
                let delta = x - mean[i];
                mean[i] += delta / (k + 1) as f64;
                m2[i] += delta * (x - mean[i]);
                min[i] = min[i].min(tr.z[i]);
                max[i] = max[i].max(tr.z[i]);
            }
        }
        let std = m2.iter().map(|&v| (v / runs as f64).sqrt()).collect();
        AggregateTrace {
            mean,
            std,
            min,
            max,
            runs,
            extinctions: traces.iter().filter(|t| t.extinct).count(),
            capped_runs: traces.iter().filter(|t| t.capped).count(),
            forks_per_run: traces.iter().map(|t| t.count(EventKind::Fork)).collect(),
            terms_per_run: traces.iter().map(|t| t.count(EventKind::ControlTermination)).collect(),
            failures_per_run: traces.iter().map(|t| t.count(EventKind::Failure)).collect(),
            max_visited_nodes: traces.iter().map(|t| t.visited_nodes).max().unwrap_or(0),
            max_state_bytes: traces.iter().map(|t| t.state_bytes).max().unwrap_or(0),
        }
    }

    /// Mean recovery time across runs after a burst at `from` (runs that
    /// never recover are excluded; the count is returned separately).
    pub fn mean_recovery(traces: &[Trace], from: u64, target: u32) -> (Option<f64>, usize) {
        let times: Vec<f64> = traces
            .iter()
            .filter_map(|t| t.recovery_time(from, target))
            .map(|t| t as f64)
            .collect();
        let unrecovered = traces.len() - times.len();
        if times.is_empty() {
            (None, unrecovered)
        } else {
            (Some(times.iter().sum::<f64>() / times.len() as f64), unrecovered)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(z: Vec<u32>) -> Trace {
        Trace { z, ..Default::default() }
    }

    #[test]
    fn recovery_and_windows() {
        let t = tr(vec![10, 10, 4, 5, 7, 10, 12, 10]);
        assert_eq!(t.recovery_time(2, 10), Some(3)); // z[5] = 10
        assert_eq!(t.recovery_time(2, 13), None);
        assert_eq!(t.max_z(2, 7), 12);
        assert_eq!(t.min_z(0, 7), 4);
        assert!((t.mean_z(0, 1) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_mean_std() {
        let a = tr(vec![10, 8, 6]);
        let b = tr(vec![10, 12, 6]);
        let agg = AggregateTrace::from_traces(&[a, b]);
        assert_eq!(agg.runs, 2);
        assert_eq!(agg.mean, vec![10.0, 10.0, 6.0]);
        assert!((agg.std[1] - 2.0).abs() < 1e-12);
        assert_eq!(agg.std[0], 0.0);
        assert_eq!(agg.min, vec![10, 8, 6]);
        assert_eq!(agg.max, vec![10, 12, 6]);
    }

    #[test]
    fn mean_recovery_excludes_failures() {
        let a = tr(vec![10, 5, 10]);
        let b = tr(vec![10, 5, 5]);
        let (mean, unrec) = AggregateTrace::mean_recovery(&[a, b], 1, 10);
        assert_eq!(mean, Some(1.0));
        assert_eq!(unrec, 1);
    }

    #[test]
    fn bit_identical_discriminates() {
        let mut a = tr(vec![5, 5, 5]);
        a.theta.push((1, 0.5));
        let mut b = a.clone();
        assert!(a.bit_identical(&b));
        // A one-ulp θ̂ difference must be detected.
        b.theta[0].1 = f64::from_bits(0.5f64.to_bits() + 1);
        assert!(!a.bit_identical(&b));
        b = a.clone();
        b.events.push(Event { t: 1, node: 0, walk: 3, kind: EventKind::Fork });
        assert!(!a.bit_identical(&b));
        b = a.clone();
        b.z[2] = 4;
        assert!(!a.bit_identical(&b));
        b = a.clone();
        b.capped = true;
        assert!(!a.bit_identical(&b));
        // Footprint metadata is *not* part of trace identity: the same
        // simulation in dense vs lazy storage differs only in memory.
        b = a.clone();
        b.visited_nodes = 999;
        b.state_bytes = 1 << 20;
        assert!(a.bit_identical(&b));
    }

    #[test]
    fn aggregate_tracks_footprint_high_water_mark() {
        let mut a = tr(vec![10, 8]);
        a.visited_nodes = 100;
        a.state_bytes = 4096;
        let mut b = tr(vec![10, 9]);
        b.visited_nodes = 250;
        b.state_bytes = 1024;
        let agg = AggregateTrace::from_traces(&[a, b]);
        assert_eq!(agg.max_visited_nodes, 250);
        assert_eq!(agg.max_state_bytes, 4096);
    }

    #[test]
    fn event_counts() {
        let mut t = tr(vec![1, 1]);
        t.events.push(Event { t: 0, node: 0, walk: 0, kind: EventKind::Fork });
        t.events.push(Event { t: 1, node: 0, walk: 1, kind: EventKind::Failure });
        assert_eq!(t.count(EventKind::Fork), 1);
        assert_eq!(t.count(EventKind::Failure), 1);
        assert_eq!(t.count(EventKind::ControlTermination), 0);
    }
}
