//! Discrete-time multi-random-walk simulation: the shared-stream arena
//! engine, the stream-mode [`ShardedEngine`] (per-walk RNG streams,
//! within-run parallelism, schedule-invariant traces), metrics, the
//! multi-seed runner (mean ± std aggregation as in the paper's 50-run
//! figures) and the frozen reference engine (determinism oracle / perf
//! baseline). Experiment *description* lives in [`crate::scenario`];
//! `sim::config` re-exports it for back-compat.
//!
//! Time model (matches the paper's synchronous simulations): at every step
//! each active walk performs one hop; failures strike before/during/after
//! the hop depending on the model; the arrival node records the visit and
//! — at most once per step (footnote 6) — runs the control algorithm on
//! the visiting walk.

pub mod config;
pub mod engine;
pub mod metrics;
pub mod reference;
pub mod runner;
pub mod shard_hook;
pub mod sharded;

pub use config::{ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};
pub use engine::{Engine, RoutingMode, SimParams, StartPlacement, VisitHook};
pub use metrics::{AggregateTrace, Event, EventKind, Trace};
pub use reference::ReferenceEngine;
pub use runner::{run_many, run_many_with_budget, CoreBudget, RunPlan};
pub use shard_hook::{NoShardHook, ShardHook, ShardVisit};
pub use sharded::{DispatchMode, ShardedEngine};
