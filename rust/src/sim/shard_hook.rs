//! The **per-shard hook protocol** for the stream-mode
//! [`ShardedEngine`](crate::sim::sharded::ShardedEngine): how application
//! layers (the learning stack) observe walk lifecycle events when the
//! simulation runs across worker threads — without ever touching the
//! trace or the schedule invariance the engine promises.
//!
//! ## Why the shared-stream [`VisitHook`] cannot ride the sharded engine
//!
//! [`VisitHook`](crate::sim::engine::VisitHook) hands every visit a
//! `&mut` view of one central hook object, which only works because the
//! shared-stream engine processes visits one at a time. The sharded
//! engine's control phase runs node ranges on parallel workers; a single
//! `&mut` hook would either serialize the phase (defeating the sharding)
//! or data-race. This module splits the hook into the same shape the
//! engine itself uses (DESIGN.md §Sharded learning):
//!
//! * **replicas** — per-shard worker state ([`ShardHook::Replica`]), one
//!   per shard, owned mutably by that shard's task for the duration of a
//!   parallel phase. A replica sees *its* node range's visits, in dense
//!   (canonical) order within the shard, and records side effects as
//!   **deltas** local to itself;
//! * **the hook** — the shared application state, visible read-only
//!   (`&self`) to every replica during parallel phases and mutably to the
//!   coordinator at the barriers.
//!
//! ## The barrier merge
//!
//! At the end-of-step barrier the coordinator calls
//! [`merge`](ShardHook::merge) with every replica: the hook combines the
//! per-replica deltas **sorted by the deciding walk's dense index** —
//! exactly how the engine already merges fork/termination decisions — so
//! the hook's observable state (e.g. a loss stream) is bit-identical at
//! every shard count. `merge` runs *before* the step's fork decisions are
//! applied, so [`on_fork`](ShardHook::on_fork) always sees parent state
//! that includes the parent's same-step visit (mirroring the sequential
//! engine, where a walk's visit work precedes its fork decision).
//!
//! Coordinator-side callbacks ([`on_fork`](ShardHook::on_fork),
//! [`on_death`](ShardHook::on_death), [`end_step`](ShardHook::end_step))
//! take `&mut self` and fire in canonical order by construction — the
//! engine only ever kills and forks at barriers, in dense order.
//!
//! ## Contract (what keeps shard-count invariance intact)
//!
//! 1. A replica must derive everything it computes from shard-local
//!    state, the read-only hook, and per-owner randomness (per-node /
//!    per-walk streams) — never from a stream shared across shards.
//! 2. Per-visit deltas must be merged in dense-index order at the
//!    barrier; the hook must not act on them earlier.
//! 3. Hooks may mutate **payload slots only** (via
//!    [`on_fork`](ShardHook::on_fork)'s [`WalkMut`]); the simulation
//!    state — RNG streams, node tables, the trace — is out of reach by
//!    construction, which is why attaching a hook can never change the
//!    z-trace, the event log, or a single θ̂ bit (locked by tests here
//!    and in `tests/learning_sharded.rs`).

use crate::walks::{Walk, WalkArena, WalkId, WalkMut, WalkRef};

/// A visit as seen by a shard replica during the control phase: the
/// arriving walk's identity plus its dense position (the canonical merge
/// key) and payload index. By-value and `Copy` — replicas own nothing of
/// the arena.
#[derive(Debug, Clone, Copy)]
pub struct ShardVisit {
    /// Dense (creation-order) position of the walk this step — the
    /// canonical ordering key every barrier merge sorts by.
    pub dense: u32,
    /// The visited node (owned by the replica's shard).
    pub node: u32,
    /// Index of `node` within the replica's shard range (`node` minus
    /// the shard's first node id) — computed by the engine so replicas
    /// indexing per-node state never re-derive the range formula.
    pub local: u32,
    pub walk: WalkId,
    /// Lineage slot label of the visiting walk.
    pub slot: u16,
    /// The walk's application payload index, if any.
    pub payload: Option<usize>,
}

/// Application hook for the sharded engine. See the module docs for the
/// replica/merge model; all coordinator-side methods default to no-ops so
/// implementors opt in. `Self::ACTIVE = false` (the [`NoShardHook`]
/// marker) compiles every hook call site out of the step entirely — the
/// plain `step()` path is byte-for-byte the pre-hook engine.
pub trait ShardHook {
    /// Per-shard worker state. Owned mutably by one shard's task during
    /// parallel phases; handed back to the hook at the barrier.
    type Replica: Send;

    /// Whether this hook does anything at all. The engine's hot loop
    /// tests this `const` so the no-hook path monomorphizes to the exact
    /// pre-hook code (no payload copies into arrival buckets, no calls).
    const ACTIVE: bool = true;

    /// Build one replica per shard. `nodes_per_shard` is the engine's
    /// static contiguous node-range size: shard `k` owns nodes
    /// `[k·nodes_per_shard, min((k+1)·nodes_per_shard, n_nodes))`.
    /// Called once per run by
    /// [`run_to_with`](crate::sim::sharded::ShardedEngine::run_to_with);
    /// replica state persists across steps.
    fn replicas(
        &mut self,
        shards: usize,
        nodes_per_shard: usize,
        n_nodes: usize,
    ) -> Vec<Self::Replica>;

    /// **Parallel.** A walk arrived at a node owned by `replica`'s shard
    /// (after the node recorded the visit, before control runs —
    /// mirroring `VisitHook::on_visit`). Visits arrive in dense order
    /// *within the shard*; cross-shard order is undefined, which is why
    /// observable effects must be deferred to [`merge`](Self::merge).
    fn on_shard_visit(&self, replica: &mut Self::Replica, t: u64, visit: &ShardVisit);

    /// **Coordinator, end-of-step barrier.** Combine the step's replica
    /// deltas in canonical (dense-index) order. Runs before this step's
    /// fork spawns and control kills are applied.
    fn merge(&mut self, _t: u64, _replicas: &mut [Self::Replica]) -> anyhow::Result<()> {
        Ok(())
    }

    /// **Coordinator.** `child` was just forked from `parent` at the
    /// barrier (canonical order); duplicate any payload. The payload slot
    /// is the only mutable simulation state a hook can reach.
    fn on_fork(&mut self, _t: u64, _parent: WalkRef, _child: WalkMut<'_>) {}

    /// **Coordinator.** A walk died (pre-step failure, hop loss, or
    /// control termination — all applied at barriers in dense order).
    fn on_death(&mut self, _t: u64, _walk: &Walk) {}

    /// **Coordinator.** The step is fully applied and the arena
    /// compacted (every dense entry is a live walk, in creation order).
    /// The hook for cross-walk work — e.g. the trainer's periodic
    /// parameter merge — whose float arithmetic must iterate in this
    /// canonical order to stay bit-identical across shard counts.
    fn end_step(&mut self, _t: u64, _arena: &WalkArena) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The inert hook: `ACTIVE = false` compiles every hook touchpoint out
/// of [`ShardedEngine::step`](crate::sim::sharded::ShardedEngine::step).
pub struct NoShardHook;

impl ShardHook for NoShardHook {
    type Replica = ();
    const ACTIVE: bool = false;

    fn replicas(&mut self, shards: usize, _nodes_per_shard: usize, _n_nodes: usize) -> Vec<()> {
        // A Vec of zero-sized units never allocates.
        (0..shards).map(|_| ()).collect()
    }

    fn on_shard_visit(&self, _replica: &mut (), _t: u64, _visit: &ShardVisit) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::Decafork;
    use crate::failures::Burst;
    use crate::graph::generators;
    use crate::rng::Rng;
    use crate::sim::engine::SimParams;
    use crate::sim::metrics::{EventKind, Trace};
    use crate::sim::sharded::ShardedEngine;
    use std::sync::Arc;

    /// A hook that mirrors the learning layer's bookkeeping shape with
    /// plain integers: every visit's (t, dense, node, walk) is a delta,
    /// merged canonically; forks clone a per-walk counter payload;
    /// deaths free it. Used to lock (a) shard-count invariance of the
    /// merged stream and (b) zero trace perturbation.
    struct Recorder {
        payloads: Vec<Option<u64>>,
        merged: Vec<(u64, u32, u32, u64)>,
        forks: usize,
        deaths: usize,
        end_steps: u64,
    }

    struct RecorderShard {
        base: u32,
        deltas: Vec<(u64, u32, u32, u64)>,
    }

    impl Recorder {
        fn new() -> Self {
            Recorder { payloads: Vec::new(), merged: Vec::new(), forks: 0, deaths: 0, end_steps: 0 }
        }
    }

    impl ShardHook for Recorder {
        type Replica = RecorderShard;

        fn replicas(&mut self, shards: usize, nps: usize, n: usize) -> Vec<RecorderShard> {
            (0..shards)
                .map(|k| RecorderShard { base: ((k * nps).min(n)) as u32, deltas: Vec::new() })
                .collect()
        }

        fn on_shard_visit(&self, rep: &mut RecorderShard, t: u64, v: &ShardVisit) {
            assert!(v.node >= rep.base, "visit routed to the wrong shard");
            assert_eq!(v.node - rep.base, v.local, "engine-provided local index disagrees");
            rep.deltas.push((t, v.dense, v.node, v.walk.0));
        }

        fn merge(&mut self, _t: u64, replicas: &mut [RecorderShard]) -> anyhow::Result<()> {
            let mut all: Vec<_> = Vec::new();
            for r in replicas.iter_mut() {
                all.append(&mut r.deltas);
            }
            all.sort_unstable_by_key(|d| d.1);
            self.merged.extend(all);
            Ok(())
        }

        fn on_fork(&mut self, _t: u64, parent: WalkRef, child: WalkMut<'_>) {
            self.forks += 1;
            if let Some(p) = parent.payload.and_then(|i| self.payloads[i]) {
                self.payloads.push(Some(p + 1));
                *child.payload = Some(self.payloads.len() - 1);
            }
        }

        fn on_death(&mut self, _t: u64, walk: &Walk) {
            self.deaths += 1;
            if let Some(i) = walk.payload {
                self.payloads[i] = None;
            }
        }

        fn end_step(&mut self, _t: u64, arena: &WalkArena) -> anyhow::Result<()> {
            self.end_steps += 1;
            // Post-compact: every dense entry must be live.
            for i in 0..arena.dense_len() {
                assert!(!arena.is_tombstoned(i));
            }
            Ok(())
        }
    }

    fn engine(shards: usize) -> ShardedEngine {
        let graph = Arc::new(generators::random_regular(40, 4, &mut Rng::new(7)).unwrap());
        ShardedEngine::new(
            graph,
            SimParams { z0: 8, control_start: Some(60), max_walks: 64, ..Default::default() },
            Decafork::new(2.0),
            Burst::new(vec![(100, 3), (220, 2)]),
            Rng::new(11),
            shards,
        )
    }

    fn run_recorded(shards: usize) -> (Trace, Recorder) {
        let mut e = engine(shards);
        let mut hook = Recorder::new();
        // Seed a payload per initial walk (as the trainer does).
        for (k, payload) in e.payloads_mut().enumerate() {
            *payload = Some(k);
        }
        for _ in 0..8 {
            hook.payloads.push(Some(0));
        }
        e.run_to_with(300, &mut hook).unwrap();
        (e.into_trace(), hook)
    }

    #[test]
    fn hook_does_not_perturb_the_trace() {
        let mut plain = engine(2);
        plain.run_to(300);
        let (hooked, _) = run_recorded(2);
        assert!(
            plain.into_trace().bit_identical(&hooked),
            "attaching a ShardHook changed the simulation trace"
        );
    }

    #[test]
    fn merged_visit_stream_is_shard_count_invariant() {
        let (tr1, h1) = run_recorded(1);
        for shards in [2usize, 3, 8] {
            let (tr, h) = run_recorded(shards);
            assert!(tr1.bit_identical(&tr), "trace diverged at {shards} shards");
            assert_eq!(
                h1.merged, h.merged,
                "canonical merged visit stream diverged at {shards} shards"
            );
            assert_eq!((h1.forks, h1.deaths), (h.forks, h.deaths));
        }
        assert!(!h1.merged.is_empty(), "no visits recorded — the hook never ran");
    }

    #[test]
    fn hook_sees_every_fork_and_death_and_step() {
        let (tr, h) = run_recorded(4);
        assert_eq!(h.forks, tr.count(EventKind::Fork));
        assert_eq!(
            h.deaths,
            tr.count(EventKind::Failure) + tr.count(EventKind::ControlTermination)
        );
        assert_eq!(h.end_steps, tr.horizon());
        // Payload lifecycle: every fork with a live parent payload minted
        // a new slot (8 originals + one per fork).
        assert_eq!(h.payloads.len(), 8 + h.forks);
    }

    #[test]
    fn noop_hook_replicas_match_shards() {
        let mut h = NoShardHook;
        assert_eq!(h.replicas(5, 10, 40).len(), 5);
        assert!(!NoShardHook::ACTIVE);
    }
}
