//! Decentralized control algorithms: the paper's DECAFORK and DECAFORK+,
//! the MISSINGPERSON baseline (Sec. III-A), the naive periodic-fork
//! strawman from the introduction, and a no-op control.
//!
//! All algorithms obey the paper's Rules 1–3: decisions use only state
//! local to the visited node (`NodeState`) plus the visiting token. The
//! engine enforces footnote 6 (a node takes at most one control decision
//! per time step even if several walks visit it).

pub mod decafork;
pub mod missing_person;

pub use decafork::{Decafork, DecaforkPlus};
pub use missing_person::MissingPerson;

use crate::rng::Rng;
use crate::walks::{NodeState, WalkId};

/// Everything a node-local control decision may read/mutate.
pub struct VisitCtx<'a> {
    /// Current time step.
    pub t: u64,
    /// Visited node.
    pub node: u32,
    /// Visiting walk (the only walk the node may fork or terminate).
    pub walk: WalkId,
    /// MISSINGPERSON slot label of the visiting walk.
    pub slot: u16,
    /// Target number of walks `Z0`.
    pub z0: u32,
    /// The visited node's local state (last-seen tables, return ECDF).
    pub state: &'a mut NodeState,
    /// Node-local randomness.
    pub rng: &'a mut Rng,
}

/// Outcome of one control decision.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Decision {
    /// Slots of walks to fork (duplicates of the *visiting* walk; the slot
    /// labels matter only to MISSINGPERSON's replacement semantics).
    pub forks: Vec<u16>,
    /// Terminate the visiting walk (DECAFORK+ only).
    pub terminate: bool,
    /// The estimator value, when the algorithm computes one (telemetry).
    pub theta: Option<f64>,
}

impl Decision {
    /// The do-nothing decision.
    pub fn none() -> Self {
        Decision::default()
    }

    pub fn is_noop(&self) -> bool {
        self.forks.is_empty() && !self.terminate
    }
}

/// Closed-world enum over the control algorithms, used by the arena
/// engine's hot loop. Unlike `Box<dyn ControlAlgorithm>`, the `match`
/// dispatch is visible to the compiler, so the per-visit decision code
/// inlines into the hop loop. The open trait below remains for the
/// actor runtime and the frozen reference engine.
#[derive(Debug, Clone)]
pub enum Control {
    None(NoControl),
    Periodic(PeriodicFork),
    MissingPerson(MissingPerson),
    Decafork(Decafork),
    DecaforkPlus(DecaforkPlus),
}

impl Control {
    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Control::None(a) => a.name(),
            Control::Periodic(a) => a.name(),
            Control::MissingPerson(a) => a.name(),
            Control::Decafork(a) => a.name(),
            Control::DecaforkPlus(a) => a.name(),
        }
    }

    /// Statically dispatched control decision (see [`ControlAlgorithm::on_visit`]).
    #[inline]
    pub fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        match self {
            Control::None(a) => a.on_visit(ctx),
            Control::Periodic(a) => a.on_visit(ctx),
            Control::MissingPerson(a) => a.on_visit(ctx),
            Control::Decafork(a) => a.on_visit(ctx),
            Control::DecaforkPlus(a) => a.on_visit(ctx),
        }
    }
}

impl From<NoControl> for Control {
    fn from(a: NoControl) -> Self {
        Control::None(a)
    }
}

impl From<PeriodicFork> for Control {
    fn from(a: PeriodicFork) -> Self {
        Control::Periodic(a)
    }
}

impl From<MissingPerson> for Control {
    fn from(a: MissingPerson) -> Self {
        Control::MissingPerson(a)
    }
}

impl From<Decafork> for Control {
    fn from(a: Decafork) -> Self {
        Control::Decafork(a)
    }
}

impl From<DecaforkPlus> for Control {
    fn from(a: DecaforkPlus) -> Self {
        Control::DecaforkPlus(a)
    }
}

/// A decentralized control algorithm executed at the visited node.
pub trait ControlAlgorithm: Send {
    /// Short name for reports.
    fn name(&self) -> &'static str;

    /// Called when a walk visits a node **after** the node has recorded
    /// the visit in its `NodeState`.
    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision;

    /// Clone into a boxed trait object (multi-run fan-out).
    fn clone_box(&self) -> Box<dyn ControlAlgorithm>;
}

impl Clone for Box<dyn ControlAlgorithm> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// No control: walks die, nothing replaces them. The catastrophic
/// baseline that motivates the paper.
#[derive(Debug, Clone, Default)]
pub struct NoControl;

impl ControlAlgorithm for NoControl {
    fn name(&self) -> &'static str {
        "none"
    }

    fn on_visit(&mut self, _ctx: &mut VisitCtx<'_>) -> Decision {
        Decision::none()
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

/// The introduction's strawman: every node independently forks the
/// visiting walk every `period` steps, regardless of system state. For
/// small periods it floods the network; for large ones it goes extinct —
/// exactly the failure mode DECAFORK is designed to avoid.
///
/// Nodes start phase-staggered (node `i`'s first fork window opens at
/// `i·period/n`), so the aggregate fork rate ramps to its steady
/// `n/period` immediately instead of every node firing in the same step
/// once `period` has first elapsed — the synchronized-storm artifact
/// would otherwise dominate the strawman's cold start.
#[derive(Debug, Clone)]
pub struct PeriodicFork {
    pub period: u64,
    /// Earliest step at which each node may fork next.
    next_fork: Vec<u64>,
}

impl PeriodicFork {
    pub fn new(n_nodes: usize, period: u64) -> Self {
        // u128 keeps the phase math exact for absurd periods (the
        // "never fork" strawman arm passes u64-scale values); each
        // phase is < period, so the result always fits back in u64.
        let n = n_nodes.max(1) as u128;
        let next_fork = (0..n_nodes)
            .map(|i| ((i as u128 * period as u128) / n) as u64)
            .collect();
        PeriodicFork { period, next_fork }
    }
}

impl ControlAlgorithm for PeriodicFork {
    fn name(&self) -> &'static str {
        "periodic"
    }

    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        let next = &mut self.next_fork[ctx.node as usize];
        if ctx.t >= *next {
            *next = ctx.t.saturating_add(self.period);
            Decision { forks: vec![ctx.slot], terminate: false, theta: None }
        } else {
            Decision::none()
        }
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::walks::SurvivalModel;

    fn ctx_at<'a>(
        t: u64,
        state: &'a mut NodeState,
        rng: &'a mut Rng,
    ) -> VisitCtx<'a> {
        VisitCtx { t, node: 0, walk: WalkId(1), slot: 0, z0: 10, state, rng }
    }

    #[test]
    fn no_control_never_acts() {
        let mut state = NodeState::new(10, SurvivalModel::Empirical);
        let mut rng = Rng::new(1);
        let mut alg = NoControl;
        for t in 0..100 {
            let mut c = ctx_at(t, &mut state, &mut rng);
            assert!(alg.on_visit(&mut c).is_noop());
        }
    }

    #[test]
    fn periodic_forks_on_schedule() {
        // Node 0's phase opens at t=0 (stagger i·T/n = 0), so with
        // period 10 and visits every step it forks at t = 1, 11, 21, …
        // — asserting the exact times locks the stagger formula, not
        // just the steady-state rate.
        let mut state = NodeState::new(10, SurvivalModel::Empirical);
        let mut rng = Rng::new(1);
        let mut alg = PeriodicFork::new(4, 10);
        let mut fork_times = Vec::new();
        for t in 1..=50 {
            let mut c = ctx_at(t, &mut state, &mut rng);
            if !alg.on_visit(&mut c).forks.is_empty() {
                fork_times.push(t);
            }
        }
        assert_eq!(fork_times, vec![1, 11, 21, 31, 41]);
    }

    #[test]
    fn periodic_phases_staggered_and_huge_periods_safe() {
        // Node i's first window opens at i·T/n.
        let mut state = NodeState::new(10, SurvivalModel::Empirical);
        let mut rng = Rng::new(2);
        let mut alg = PeriodicFork::new(4, 100);
        for (node, first_allowed) in [(0u32, 0u64), (1, 25), (2, 50), (3, 75)] {
            let mut c = VisitCtx {
                t: first_allowed.max(1),
                node,
                walk: WalkId(1),
                slot: 0,
                z0: 10,
                state: &mut state,
                rng: &mut rng,
            };
            assert!(!alg.on_visit(&mut c).forks.is_empty(), "node {node} window not open");
        }
        // An absurd "never fork" period must not overflow: each node
        // forks at most once (phase 0 node), then saturates.
        let mut alg = PeriodicFork::new(4, u64::MAX);
        let mut forks = 0;
        for t in 1..200u64 {
            let mut c = ctx_at(t, &mut state, &mut rng);
            forks += alg.on_visit(&mut c).forks.len();
        }
        assert!(forks <= 1, "huge period must not flood: {forks} forks");
    }
}
