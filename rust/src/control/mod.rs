//! Decentralized control algorithms: the paper's DECAFORK and DECAFORK+,
//! the MISSINGPERSON baseline (Sec. III-A), the naive periodic-fork
//! strawman from the introduction, and a no-op control.
//!
//! All algorithms obey the paper's Rules 1–3: decisions use only state
//! local to the visited node (`NodeState`) plus the visiting token. The
//! engine enforces footnote 6 (a node takes at most one control decision
//! per time step even if several walks visit it).

pub mod decafork;
pub mod missing_person;

pub use decafork::{Decafork, DecaforkPlus};
pub use missing_person::MissingPerson;

use crate::rng::Rng;
use crate::walks::{NodeState, WalkId};

/// Everything a node-local control decision may read/mutate.
pub struct VisitCtx<'a> {
    /// Current time step.
    pub t: u64,
    /// Visited node.
    pub node: u32,
    /// Visiting walk (the only walk the node may fork or terminate).
    pub walk: WalkId,
    /// MISSINGPERSON slot label of the visiting walk.
    pub slot: u16,
    /// Target number of walks `Z0`.
    pub z0: u32,
    /// The visited node's local state (last-seen tables, return ECDF).
    pub state: &'a mut NodeState,
    /// Node-local randomness.
    pub rng: &'a mut Rng,
}

/// Outcome of one control decision.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Decision {
    /// Slots of walks to fork (duplicates of the *visiting* walk; the slot
    /// labels matter only to MISSINGPERSON's replacement semantics).
    pub forks: Vec<u16>,
    /// Terminate the visiting walk (DECAFORK+ only).
    pub terminate: bool,
    /// The estimator value, when the algorithm computes one (telemetry).
    pub theta: Option<f64>,
}

impl Decision {
    /// The do-nothing decision.
    pub fn none() -> Self {
        Decision::default()
    }

    pub fn is_noop(&self) -> bool {
        self.forks.is_empty() && !self.terminate
    }
}

/// A decentralized control algorithm executed at the visited node.
pub trait ControlAlgorithm: Send {
    /// Short name for reports.
    fn name(&self) -> &'static str;

    /// Called when a walk visits a node **after** the node has recorded
    /// the visit in its `NodeState`.
    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision;

    /// Clone into a boxed trait object (multi-run fan-out).
    fn clone_box(&self) -> Box<dyn ControlAlgorithm>;
}

impl Clone for Box<dyn ControlAlgorithm> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// No control: walks die, nothing replaces them. The catastrophic
/// baseline that motivates the paper.
#[derive(Debug, Clone, Default)]
pub struct NoControl;

impl ControlAlgorithm for NoControl {
    fn name(&self) -> &'static str {
        "none"
    }

    fn on_visit(&mut self, _ctx: &mut VisitCtx<'_>) -> Decision {
        Decision::none()
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

/// The introduction's strawman: every node independently forks the
/// visiting walk every `period` steps, regardless of system state. For
/// small periods it floods the network; for large ones it goes extinct —
/// exactly the failure mode DECAFORK is designed to avoid.
#[derive(Debug, Clone)]
pub struct PeriodicFork {
    pub period: u64,
    last_fork: Vec<u64>,
}

impl PeriodicFork {
    pub fn new(n_nodes: usize, period: u64) -> Self {
        PeriodicFork { period, last_fork: vec![0; n_nodes] }
    }
}

impl ControlAlgorithm for PeriodicFork {
    fn name(&self) -> &'static str {
        "periodic"
    }

    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        let last = &mut self.last_fork[ctx.node as usize];
        if ctx.t.saturating_sub(*last) >= self.period {
            *last = ctx.t;
            Decision { forks: vec![ctx.slot], terminate: false, theta: None }
        } else {
            Decision::none()
        }
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::walks::SurvivalModel;

    fn ctx_at<'a>(
        t: u64,
        state: &'a mut NodeState,
        rng: &'a mut Rng,
    ) -> VisitCtx<'a> {
        VisitCtx { t, node: 0, walk: WalkId(1), slot: 0, z0: 10, state, rng }
    }

    #[test]
    fn no_control_never_acts() {
        let mut state = NodeState::new(10, SurvivalModel::Empirical);
        let mut rng = Rng::new(1);
        let mut alg = NoControl;
        for t in 0..100 {
            let mut c = ctx_at(t, &mut state, &mut rng);
            assert!(alg.on_visit(&mut c).is_noop());
        }
    }

    #[test]
    fn periodic_forks_on_schedule() {
        let mut state = NodeState::new(10, SurvivalModel::Empirical);
        let mut rng = Rng::new(1);
        let mut alg = PeriodicFork::new(4, 10);
        let mut forks = 0;
        for t in 1..=50 {
            let mut c = ctx_at(t, &mut state, &mut rng);
            if !alg.on_visit(&mut c).forks.is_empty() {
                forks += 1;
            }
        }
        assert_eq!(forks, 5); // t = 10, 20, 30, 40, 50
    }
}
