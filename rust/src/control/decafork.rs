//! DECAFORK and DECAFORK+ (Sec. III-B / III-C).
//!
//! DECAFORK: when walk `k` visits node `i` at time `t`, the node computes
//! the estimator `θ̂_i(t)` (Eq. 1). If `θ̂_i(t) < ε` the node forks the
//! visiting walk with probability `p = 1/Z0` under a new unique id.
//!
//! DECAFORK+: additionally, if `θ̂_i(t) > ε₂`, the node terminates the
//! visiting walk with probability `p`, bounding the redundancy from above
//! and allowing a more aggressive ε.

use super::{ControlAlgorithm, Decision, VisitCtx};
use crate::stats::irwin_hall::{design_epsilon, design_epsilon2};

/// DECAFORK configuration + behaviour.
#[derive(Debug, Clone)]
pub struct Decafork {
    /// Forking threshold ε on the estimator.
    pub epsilon: f64,
    /// Forking probability `p` (paper: `1/Z0`; `None` selects `1/Z0` at
    /// visit time so one struct serves any `Z0`).
    pub p: Option<f64>,
}

impl Decafork {
    /// Paper parameterization: explicit ε, `p = 1/Z0`.
    pub fn new(epsilon: f64) -> Self {
        Decafork { epsilon, p: None }
    }

    /// Threshold designed from the Irwin–Hall quantile so the probability
    /// of a (spurious) fork with `Z0` healthy walks is `delta`
    /// (Sec. III-B, "Choosing the threshold").
    pub fn designed(z0: u32, delta: f64) -> Self {
        Decafork { epsilon: design_epsilon(z0, delta), p: None }
    }

    /// The per-decision fork/termination probability. `z0 = 0` yields
    /// 0.0, not `1/0 = inf`: a zero-walk target means "never act" (an
    /// infinite probability would make `Rng::bernoulli` fire always and
    /// fork from a population that should not exist).
    #[inline]
    pub(crate) fn fork_prob(&self, z0: u32) -> f64 {
        match self.p {
            Some(p) => p,
            None if z0 == 0 => 0.0,
            None => 1.0 / z0 as f64,
        }
    }
}

impl ControlAlgorithm for Decafork {
    fn name(&self) -> &'static str {
        "decafork"
    }

    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        let theta = ctx.state.theta(ctx.t, ctx.walk);
        let mut d = Decision { theta: Some(theta), ..Decision::none() };
        if theta < self.epsilon && ctx.rng.bernoulli(self.fork_prob(ctx.z0)) {
            d.forks.push(ctx.slot);
        }
        d
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

/// DECAFORK+ — forking plus deliberate termination.
#[derive(Debug, Clone)]
pub struct DecaforkPlus {
    /// Inner forking rule (threshold ε, probability p).
    pub fork: Decafork,
    /// Termination threshold ε₂ (> ε).
    pub epsilon2: f64,
}

impl DecaforkPlus {
    /// Paper parameterization (Fig. 1: ε = 3.25, ε₂ = 5.75 for Z0 = 10).
    pub fn new(epsilon: f64, epsilon2: f64) -> Self {
        assert!(epsilon2 > epsilon, "need ε₂ > ε");
        DecaforkPlus { fork: Decafork::new(epsilon), epsilon2 }
    }

    /// Both thresholds designed from Irwin–Hall quantiles (Sec. III-C).
    pub fn designed(z0: u32, delta_fork: f64, delta_term: f64) -> Self {
        let epsilon = design_epsilon(z0, delta_fork);
        let epsilon2 = design_epsilon2(z0, delta_term);
        assert!(epsilon2 > epsilon, "inconsistent deltas: ε={epsilon} ε₂={epsilon2}");
        DecaforkPlus { fork: Decafork { epsilon, p: None }, epsilon2 }
    }
}

impl ControlAlgorithm for DecaforkPlus {
    fn name(&self) -> &'static str {
        "decafork+"
    }

    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        // DECAFORK+ runs DECAFORK first (which computes θ̂), then checks
        // the termination threshold on the same estimate.
        let mut d = self.fork.on_visit(ctx);
        let theta = d.theta.expect("decafork always sets theta");
        if theta > self.epsilon2 && ctx.rng.bernoulli(self.fork.fork_prob(ctx.z0)) {
            d.terminate = true;
        }
        d
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::walks::{NodeState, SurvivalModel, WalkId};

    fn state_with_walks(n_walks: u64, last_seen_at: u64, q: f64) -> NodeState {
        let mut s = NodeState::new(10, SurvivalModel::Geometric { q });
        for w in 0..n_walks {
            s.observe(last_seen_at, WalkId(w), (w % 10) as u16);
        }
        s
    }

    #[test]
    fn forks_when_estimate_collapses() {
        // All other walks last seen ages ago → θ̂ ≈ ½ < ε ⇒ fork happens
        // with probability 1/Z0; force p = 1 to make it deterministic.
        let mut alg = Decafork { epsilon: 2.0, p: Some(1.0) };
        let mut s = state_with_walks(10, 0, 0.05);
        let mut rng = Rng::new(1);
        let mut ctx = VisitCtx {
            t: 2000,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 10,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert_eq!(d.forks.len(), 1);
        assert!(d.theta.unwrap() < 0.51);
    }

    #[test]
    fn no_fork_when_population_healthy() {
        // All walks just seen → θ̂ ≈ ½ + 9 ≫ ε ⇒ no fork regardless of p.
        let mut alg = Decafork { epsilon: 2.0, p: Some(1.0) };
        let mut s = state_with_walks(10, 999, 0.05);
        let mut rng = Rng::new(2);
        let mut ctx = VisitCtx {
            t: 1000,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 10,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert!(d.forks.is_empty());
        assert!(d.theta.unwrap() > 8.0);
    }

    #[test]
    fn fork_probability_defaults_to_inv_z0() {
        let mut alg = Decafork::new(2.0);
        assert!((alg.fork_prob(10) - 0.1).abs() < 1e-12);
        let mut s = state_with_walks(10, 0, 0.05);
        let mut rng = Rng::new(3);
        let mut forks = 0;
        let trials = 20_000;
        for _ in 0..trials {
            let mut ctx = VisitCtx {
                t: 5000,
                node: 0,
                walk: WalkId(0),
                slot: 0,
                z0: 10,
                state: &mut s,
                rng: &mut rng,
            };
            forks += alg.on_visit(&mut ctx).forks.len();
        }
        let rate = forks as f64 / trials as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn zero_z0_never_forks() {
        // 1/Z0 with Z0 = 0 used to be +inf; the guard maps it to "never".
        let alg = Decafork::new(2.0);
        assert_eq!(alg.fork_prob(0), 0.0);
        assert!(alg.fork_prob(0).is_finite());
        // An explicit p overrides the guard (the caller opted out of 1/Z0).
        let forced = Decafork { epsilon: 2.0, p: Some(1.0) };
        assert_eq!(forced.fork_prob(0), 1.0);
        // End-to-end: a collapsed estimate with z0 = 0 must still not fork.
        let mut alg = Decafork::new(2.0);
        let mut s = state_with_walks(10, 0, 0.05);
        let mut rng = Rng::new(6);
        let mut ctx = VisitCtx {
            t: 2000,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 0,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert!(d.forks.is_empty(), "z0=0 forked: {d:?}");
        assert!(d.theta.unwrap() < 2.0, "theta should be collapsed in this setup");
    }

    #[test]
    fn plus_terminates_on_overshoot() {
        let mut alg = DecaforkPlus {
            fork: Decafork { epsilon: 2.0, p: Some(1.0) },
            epsilon2: 5.75,
        };
        // 15 fresh walks → θ̂ ≈ 14.5 > ε₂ ⇒ terminate (p = 1).
        let mut s = state_with_walks(15, 999, 0.05);
        let mut rng = Rng::new(4);
        let mut ctx = VisitCtx {
            t: 1000,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 10,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert!(d.terminate);
        assert!(d.forks.is_empty());
    }

    #[test]
    fn plus_never_both_forks_and_terminates() {
        // ε < θ̂ < ε₂ band: neither action.
        let mut alg = DecaforkPlus {
            fork: Decafork { epsilon: 2.0, p: Some(1.0) },
            epsilon2: 8.0,
        };
        let mut s = state_with_walks(6, 999, 0.05);
        let mut rng = Rng::new(5);
        let mut ctx = VisitCtx {
            t: 1000,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 10,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert!(d.is_noop(), "{d:?}");
    }

    #[test]
    fn designed_thresholds_sane() {
        let alg = Decafork::designed(10, 1e-4);
        assert!(alg.epsilon > 0.5 && alg.epsilon < 4.0);
        let plus = DecaforkPlus::designed(10, 1e-3, 1e-3);
        assert!(plus.epsilon2 > plus.fork.epsilon);
    }
}
