//! MISSINGPERSON baseline (Sec. III-A).
//!
//! Each node tracks, for each of the `Z0` original walk identities
//! `ℓ ∈ [Z0]`, the last time any walk carrying identity `ℓ` visited
//! (`L_{i,ℓ}`, initialized to 0). On a visit by walk `k`, for every other
//! identity `ℓ` not seen for more than `ε_mp` steps, the node forks a
//! replacement (with identity `ℓ`) with probability `1/Z0`.
//!
//! The difficulty the paper points out: a good `ε_mp` depends on the graph
//! and the node's position in it, and nothing stops several nodes from
//! replacing the same missing identity — the over-forking visible in Fig. 1.

use super::{ControlAlgorithm, Decision, VisitCtx};

/// MISSINGPERSON with threshold `ε_mp` on per-identity staleness.
#[derive(Debug, Clone)]
pub struct MissingPerson {
    /// Staleness threshold ε_mp (time steps).
    pub eps_mp: u64,
    /// Replacement probability (paper: 1/Z0; `None` = 1/Z0).
    pub p: Option<f64>,
}

impl MissingPerson {
    pub fn new(eps_mp: u64) -> Self {
        MissingPerson { eps_mp, p: None }
    }

    /// Rule-of-thumb threshold: a multiple of the analytic mean return
    /// time `2|E|/deg` (Kac), the natural scale of inter-visit gaps.
    pub fn from_mean_return(mean_return: f64, multiplier: f64) -> Self {
        MissingPerson { eps_mp: (mean_return * multiplier).ceil() as u64, p: None }
    }
}

impl ControlAlgorithm for MissingPerson {
    fn name(&self) -> &'static str {
        "missingperson"
    }

    fn on_visit(&mut self, ctx: &mut VisitCtx<'_>) -> Decision {
        let p = self.p.unwrap_or(1.0 / ctx.z0 as f64);
        let mut d = Decision::none();
        for ell in 0..ctx.state.slot_last_seen.len() as u16 {
            if ell == ctx.slot {
                continue;
            }
            let last = ctx.state.slot_last_seen[ell as usize];
            if ctx.t.saturating_sub(last) > self.eps_mp && ctx.rng.bernoulli(p) {
                // Fork the visiting walk as a replacement carrying ℓ.
                d.forks.push(ell);
            }
        }
        d
    }

    fn clone_box(&self) -> Box<dyn ControlAlgorithm> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::walks::{NodeState, SurvivalModel, WalkId};

    #[test]
    fn replaces_stale_identities() {
        let mut alg = MissingPerson { eps_mp: 100, p: Some(1.0) };
        let mut s = NodeState::new(3, SurvivalModel::Empirical);
        // Identity 0 visits now; identities 1, 2 never seen (L = 0).
        s.observe(500, WalkId(0), 0);
        let mut rng = Rng::new(1);
        let mut ctx = VisitCtx {
            t: 500,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 3,
            state: &mut s,
            rng: &mut rng,
        };
        let d = alg.on_visit(&mut ctx);
        assert_eq!(d.forks, vec![1, 2]);
        assert!(!d.terminate);
    }

    #[test]
    fn fresh_identities_not_replaced() {
        let mut alg = MissingPerson { eps_mp: 100, p: Some(1.0) };
        let mut s = NodeState::new(3, SurvivalModel::Empirical);
        s.observe(490, WalkId(1), 1);
        s.observe(495, WalkId(2), 2);
        s.observe(500, WalkId(0), 0);
        let mut rng = Rng::new(2);
        let mut ctx = VisitCtx {
            t: 500,
            node: 0,
            walk: WalkId(0),
            slot: 0,
            z0: 3,
            state: &mut s,
            rng: &mut rng,
        };
        assert!(alg.on_visit(&mut ctx).is_noop());
    }

    #[test]
    fn replacement_probability_is_inv_z0() {
        let mut alg = MissingPerson::new(10); // p = 1/Z0 = 0.1
        let mut rng = Rng::new(3);
        let trials = 20_000;
        let mut forks = 0usize;
        for _ in 0..trials {
            let mut s = NodeState::new(2, SurvivalModel::Empirical);
            s.observe(500, WalkId(0), 0);
            let mut ctx = VisitCtx {
                t: 500,
                node: 0,
                walk: WalkId(0),
                slot: 0,
                z0: 10,
                state: &mut s,
                rng: &mut rng,
            };
            forks += alg.on_visit(&mut ctx).forks.len();
        }
        let rate = forks as f64 / trials as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn from_mean_return_scales() {
        let alg = MissingPerson::from_mean_return(100.0, 6.0);
        assert_eq!(alg.eps_mp, 600);
    }
}
