//! Named scenarios: the paper's figure setups, the perf workloads the
//! engine and the control stack are benchmarked on (`perf_hot_loop`,
//! `perf_control_*`, `scale_10k`, the stream-mode `scale_100k` /
//! `scale_1m` sharding probes, and the routing-dominated `route_100k`
//! leg), and the golden determinism-lock
//! quartet. Keeping them here means the CLI, the figure harness, the
//! benches and the tests all run the *same* experiment when they say the
//! same name.

use super::{ControlSpec, FailureSpec, GraphSpec, Scenario};
use crate::sim::engine::{SimParams, SurvivalSpec};

/// Paper Fig. 1 base setup: 8-regular n=100, Z0=10, DECAFORK ε=2,
/// bursts −5 @ 2000 and −6 @ 6000, 10k-step horizon.
pub fn fig1_base(runs: usize) -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        params: SimParams::default(),
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures: FailureSpec::paper_bursts(),
        horizon: 10_000,
        runs,
        seed: 0xDECAF,
    }
}

/// The engine-throughput workload from ISSUE 1's acceptance criteria:
/// 1000-node random-regular graph, 256 walks, 10k steps, 30% cumulative
/// burst failures (three bursts totalling 77 ≈ 0.3·256 walks) plus a
/// continuous per-hop loss rate, with periodic forking refilling the
/// population. The continuous component is what separates O(live) from
/// O(history) stepping: thousands of death+refork cycles grow the seed
/// engine's walk vector (and, pre-index, its node tables) forever while
/// the arena's dense columns stay at ~Z0 entries.
///
/// Control choice: **PeriodicFork**, deliberately. The determinism lock
/// freezes DECAFORK's θ̂ float-sum evaluation bit-for-bit, so its Θ(Z)
/// per-visit estimator costs the arena and reference engines *exactly*
/// the same and would mask the engine-core difference this bench exists
/// to measure (at Z0=256, θ̂ arithmetic is ~10× every other per-step
/// cost combined; the fig benches cover DECAFORK throughput at paper
/// scale). PeriodicFork's O(1) decision keeps the workload engine-bound
/// while sustaining the same churn.
///
/// Tuning: each node forks once per `T` steps when visited, so the
/// aggregate fork rate is `n/T ≈ 1.02/step`; deaths are `p_f·Z`. The
/// fixed point `Z* = n/(p_f·T) ≈ 255` is strongly stable (deaths scale
/// with Z, forks don't), and the staggered fork phases ramp refill up
/// from t≈0, so the population holds near 256 for the whole run while
/// ~1 death+refork per step retires ~10k walks — the O(history)/O(live)
/// gap the arena removes.
pub fn perf_hot_loop() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 1000, d: 8 },
        params: SimParams {
            z0: 256,
            control_start: Some(1),
            max_walks: 2048,
            ..SimParams::default()
        },
        control: ControlSpec::Periodic { period: 980 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(3000, 26), (5500, 26), (8000, 25)] },
            FailureSpec::Probabilistic { p_f: 0.004 },
        ]),
        horizon: 10_000,
        runs: 1,
        seed: 0xBEEF,
    }
}

/// The **control-bound** perf workloads (ISSUE 2): same 1000-node churn
/// shape as [`perf_hot_loop`], but driven by the θ̂-computing control
/// families at Z0 = 256 — the regime `perf_hot_loop` deliberately avoids
/// because DECAFORK's Θ(known-walks) estimator dominates everything
/// else. `benches/perf_control.rs` runs arena (survival-cached θ̂) vs
/// reference (direct θ̂) on these, asserts byte-identical traces, and
/// writes `BENCH_control.json` (bar: ≥ 3×).
///
/// Analytic-geometric family: every θ̂ term is an `exp` on the direct
/// path, an indexed load on the cached one. Plain DECAFORK.
///
/// Tuning: q = π_i = 0.001 here, so survival decays on the E[R] ≈ 1000
/// step scale while per-hop churn kills on the 1/p_f scale — p_f is kept
/// at 5e-4 (E[R] ≪ 1/p_f) so the estimator outpaces attrition and the
/// population rides out the three 10% bursts instead of sliding to
/// extinction. ε = 110 ≈ the Irwin–Hall(255) mean 128 minus ~4σ
/// (σ = √(255/12) ≈ 4.6) — the normal-approximation design point; the
/// exact alternating-sum quantile is numerically unreliable at n = 255.
pub fn perf_control_geometric() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 1000, d: 8 },
        params: SimParams {
            z0: 256,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(500),
            max_walks: 2048,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 110.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(1500, 26), (2750, 26), (4000, 25)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 5000,
        runs: 1,
        seed: 0xCAFE0,
    }
}

/// Control-bound workload, empirical family (the paper default): every
/// θ̂ term is a cached-CDF lookup + division on the direct path, an
/// indexed load on the cached one — and the memo is regularly
/// invalidated by return-time samples, so this scenario exercises the
/// epoch-tracking machinery, not just steady-state replay. DECAFORK+
/// (ε₂ = mean + ~4σ) bounds the early over-fork transient that the
/// empirical model's short warm-up support produces.
pub fn perf_control_empirical() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 1000, d: 8 },
        params: SimParams {
            z0: 256,
            survival: SurvivalSpec::Empirical,
            control_start: Some(500),
            max_walks: 2048,
            ..SimParams::default()
        },
        control: ControlSpec::DecaforkPlus { epsilon: 110.0, epsilon2: 146.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(1500, 26), (2750, 26), (4000, 25)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 5000,
        runs: 1,
        seed: 0xCAFE1,
    }
}

/// Scale probe: 10k nodes, 1024 walks, DECAFORK+ on the empirical
/// family. Arena-only in the bench (the reference engine's direct θ̂ at
/// this size is minutes per run, not seconds) — reported as absolute
/// steps/sec to track the production-scale trajectory. Thresholds are
/// the Irwin–Hall(1023) normal-approximation design points
/// (mean 512, σ ≈ 9.2).
pub fn scale_10k() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 10_000, d: 8 },
        params: SimParams {
            z0: 1024,
            survival: SurvivalSpec::Empirical,
            control_start: Some(500),
            max_walks: 4096,
            ..SimParams::default()
        },
        control: ControlSpec::DecaforkPlus { epsilon: 476.0, epsilon2: 548.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(800, 102), (1400, 102)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 2000,
        runs: 1,
        seed: 0xCAFE2,
    }
}

/// Stream-mode scale probe for `benches/perf_shard.rs`: 100k nodes,
/// 8192 walks, DECAFORK+ — the workload the sharded engine's 1-vs-8
/// worker speedup is measured on. Analytic-geometric survival
/// (footnote 5: the empirical distribution may be replaced by an
/// analytic form to speed up initialization — at this scale the mean
/// return time is `E[R] = n = 100k` steps, far beyond any affordable
/// horizon, so a warm empirical CDF is physically unreachable and the
/// analytic family is the honest choice). Per-node θ̂ cost grows with
/// the distinct walks each node has seen (~`Z/n` new per step), which is
/// exactly the control-phase load the node-sharded workers divide.
///
/// Thresholds: under healthy stationarity θ̂ ≈ ½ + known·S̄; ε = Z0/4
/// lets the cold-start phase fork mildly (known < 2048) and then go
/// quiet, ε₂ high enough that termination stays rare — the bench wants
/// sustained θ̂ evaluation with live fork/kill paths, not a fork storm
/// (`max_walks` caps the worst case anyway).
pub fn scale_100k() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 100_000, d: 8 },
        params: SimParams {
            z0: 8192,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(400),
            max_walks: 16_384,
            ..SimParams::default()
        },
        control: ControlSpec::DecaforkPlus { epsilon: 2048.0, epsilon2: 6000.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(800, 819), (1400, 819)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 2000,
        runs: 1,
        seed: 0xCAFE3,
    }
}

/// The routing-dominated leg for `benches/perf_route.rs`: `scale_100k`'s
/// topology and failure shape with the walk population doubled
/// (Z0 = 16384). What the bench measures is the coordinator's
/// inter-phase arrival work, which scales with *live walks* — not with
/// nodes — so doubling Z pushes the serial O(live) scan toward the top
/// of the per-step profile (Amdahl: the parallel hop/control phases
/// divide by the worker count, the scan doesn't) and makes the
/// mailbox-vs-serial gap measurable rather than noise. Thresholds keep
/// the scale-preset design rule: ε = Z0/4, ε₂ high enough that
/// termination stays rare, 10% bursts, p_f = 5e-4.
pub fn route_100k() -> Scenario {
    let mut s = scale_100k();
    s.params.z0 = 16_384;
    s.params.max_walks = 32_768;
    s.control = ControlSpec::DecaforkPlus { epsilon: 4096.0, epsilon2: 12_000.0 };
    s.failures = FailureSpec::Composite(vec![
        FailureSpec::Burst { events: vec![(800, 1638), (1400, 1638)] },
        FailureSpec::Probabilistic { p_f: 0.0005 },
    ]);
    s.seed = 0xCAFE7;
    s
}

/// The ROADMAP north-star probe: one million nodes, plain DECAFORK on
/// the analytic-geometric family. The `perf_shard` acceptance criterion
/// is simply that a 1000-step horizon *completes* (with steps/sec
/// recorded) — the regime where within-run sharding is the only lever,
/// since 50 sequential replications don't help when one replication is
/// this big.
///
/// Z0 = 8192 is the **dense-population** setting the compact per-node
/// walk index unlocked (ISSUE 4): the old direct `slot_pos` array cost
/// every visited node ~4 B × the largest walk-slot index it ever
/// observed, so a dense population at 10⁶ nodes priced out at tens of
/// GB of index and the probe capped Z0 at 1024. The open-addressing
/// index is sized by each node's own `|L_i(t)|`, so per-node memory no
/// longer scales with the peak walk-slot index and the probe can run
/// the multi-stream walk density the Pac-Man-attack literature studies
/// on top of node-count scale. Thresholds follow the `scale_100k`
/// design (ε = Z0/4 for a quiet post-cold-start regime; 10% burst).
pub fn scale_1m() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 1_000_000, d: 8 },
        params: SimParams {
            z0: 8192,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(300),
            max_walks: 16_384,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 2048.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(400, 819)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 1000,
        runs: 1,
        seed: 0xCAFE4,
    }
}

/// The first implicit-backend scale probe: 10⁷ nodes on the
/// degree-preserving small world, plain DECAFORK on the
/// analytic-geometric family. The materialized CSR at this size would
/// cost ~0.5 GB and minutes of single-threaded pairing; the implicit
/// circulant family needs a few dozen bytes *total* for the topology and
/// builds in microseconds, so what this probe actually prices is the
/// engine's O(n) per-node state (`NodeState` + node streams, ~100 B/node
/// ≈ 1 GB here) and the walk columns — exactly the scaling frontier
/// ROADMAP names next. `benches/perf_graph.rs` runs it end-to-end
/// (gated by `DECAFORK_PERF_SKIP_10M`); the acceptance bar is
/// completion with steps/sec recorded, as for `scale_1m`.
///
/// Thresholds follow the scale-preset design rule (ε = Z0/4, 10%
/// burst, p_f = 5e-4, explicit `control_start` well inside the
/// horizon); Z0 doubles over `scale_1m` to keep the walk population
/// dense relative to the failure volume at the shorter horizon.
pub fn scale_10m() -> Scenario {
    Scenario {
        graph: GraphSpec::ImplicitSmallWorld { n: 10_000_000, d: 8 },
        params: SimParams {
            z0: 16_384,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(150),
            max_walks: 32_768,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 4096.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(200, 1638)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 500,
        runs: 1,
        seed: 0xCAFE5,
    }
}

/// The 10⁸-node preset: same design as [`scale_10m`] one order up —
/// and **runnable**, not just a shape lock, since the lazy node store
/// landed. The topology is O(1) memory (implicit small world) and the
/// engine's per-node state is O(visited): with Z0 = 32768 walks over a
/// 250-step horizon at most ~8M of the 10⁸ nodes are ever visited, so
/// the state footprint is a few GB where the old dense columns needed
/// ~10 GB before the first step. `benches/perf_state.rs` runs this
/// preset end-to-end under an explicit memory budget (the `scale_100m`
/// completion probe, `DECAFORK_PERF_SKIP_100M` to skip on small
/// machines); `perf_graph` continues to assert the topology side at
/// 10⁸.
pub fn scale_100m() -> Scenario {
    Scenario {
        graph: GraphSpec::ImplicitSmallWorld { n: 100_000_000, d: 8 },
        params: SimParams {
            z0: 32_768,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(80),
            max_walks: 65_536,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 8192.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(100, 3276)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 250,
        runs: 1,
        seed: 0xCAFE6,
    }
}

/// Simulation side of the `learn_tiny` training workload
/// (`learning::presets` adds the corpus/operator knobs): 64 nodes,
/// 8 walks, one burst plus a light probabilistic drip so the trainer's
/// fork-handoff and death paths both fire within a unit-test budget.
pub fn learn_tiny_scenario() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 64, d: 8 },
        params: SimParams {
            z0: 8,
            control_start: Some(100),
            max_walks: 32,
            ..SimParams::default()
        },
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(150, 3)] },
            FailureSpec::Probabilistic { p_f: 0.001 },
        ]),
        horizon: 400,
        runs: 1,
        seed: 0x1EA0,
    }
}

/// Simulation side of the `learn_10k` training workload — the
/// `benches/perf_learn.rs` scale: 10k nodes, 512 model-carrying walks,
/// DECAFORK+ on the analytic-geometric family (E[R] = n = 10k steps, so
/// as at `scale_100k` a warm empirical CDF is unreachable within any
/// training horizon and the analytic form is the honest choice).
/// Thresholds follow the scale-preset design rule: ε = Z0/4 lets the
/// cold start fork mildly then go quiet; ε₂ high enough that
/// termination stays rare. One 10% burst mid-run exercises recovery
/// forking — i.e. model handoff — under load.
pub fn learn_10k() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 10_000, d: 8 },
        params: SimParams {
            z0: 512,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(300),
            max_walks: 1024,
            ..SimParams::default()
        },
        control: ControlSpec::DecaforkPlus { epsilon: 128.0, epsilon2: 400.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(500, 51)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 1000,
        runs: 1,
        seed: 0x1EA1,
    }
}

/// Simulation side of the `learn_100k` training workload: the
/// `scale_100k` node count with a 4096-walk model-carrying population
/// (16 KB of parameters per walk at the bigram operator's vocab — the
/// walk density is capped by model memory, not by the index, at this
/// scale). Same threshold design as `learn_10k`.
pub fn learn_100k() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 100_000, d: 8 },
        params: SimParams {
            z0: 4096,
            survival: SurvivalSpec::AnalyticGeometric,
            control_start: Some(200),
            max_walks: 8192,
            ..SimParams::default()
        },
        control: ControlSpec::DecaforkPlus { epsilon: 1024.0, epsilon2: 3000.0 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(400, 410)] },
            FailureSpec::Probabilistic { p_f: 0.0005 },
        ]),
        horizon: 600,
        runs: 1,
        seed: 0x1EA2,
    }
}

/// The four seeded scenarios whose `Trace::z` vectors are the
/// determinism lock (`tests/golden_traces.rs`): the arena engine must
/// reproduce the frozen reference engine on all of them, byte for byte.
/// Chosen to cover the three failure surfaces (pre-step bursts, per-hop
/// probabilistic losses, Byzantine arrivals), all control families that
/// fork (DECAFORK, DECAFORK+, MISSINGPERSON), and — via the
/// DECAFORK-heavy churn scenario — the survival-cached θ̂ path against
/// the reference's direct evaluation under sustained empirical-CDF
/// growth.
pub fn golden() -> Vec<(&'static str, Scenario)> {
    vec![
        (
            "fig1_burst",
            Scenario {
                graph: GraphSpec::RandomRegular { n: 100, d: 8 },
                params: SimParams::default(),
                control: ControlSpec::Decafork { epsilon: 2.0 },
                failures: FailureSpec::paper_bursts(),
                horizon: 3000,
                runs: 1,
                seed: 0xDECAF,
            },
        ),
        (
            "churn_byzantine_decaforkplus",
            // All three failure surfaces at once, against the control
            // family that exercises termination too. DECAFORK+'s
            // survival-based detection reacts on the return-time scale
            // (E[R] = 50 here), fast enough to outpace the Byzantine
            // node's ~Z/n kills per step during its phase.
            Scenario {
                graph: GraphSpec::RandomRegular { n: 50, d: 6 },
                params: SimParams {
                    z0: 12,
                    control_start: Some(200),
                    ..SimParams::default()
                },
                control: ControlSpec::DecaforkPlus { epsilon: 2.0, epsilon2: 5.0 },
                failures: FailureSpec::Composite(vec![
                    FailureSpec::Burst { events: vec![(300, 4)] },
                    FailureSpec::Probabilistic { p_f: 0.002 },
                    FailureSpec::ByzantineScheduled {
                        node: 1,
                        schedule: vec![(600, true), (1200, false)],
                    },
                ]),
                horizon: 2000,
                runs: 1,
                seed: 42,
            },
        ),
        (
            "churn_decafork_empirical",
            // The survival-cache workout (ISSUE 2): plain DECAFORK on
            // the empirical family under *sustained* per-hop churn, so
            // the return-time CDF keeps gaining samples for the whole
            // run — every insert can invalidate the θ̂ memo, and the
            // arena engine's cached sums must still match the
            // reference's direct ones bit-for-bit through hundreds of
            // epoch changes. E[R] = 80 here vs 1/p_f = 500, so the
            // estimator tracks attrition comfortably; the two ~35%
            // bursts exercise recovery forking on top of the steady
            // drip. ε = 3.5 ≈ Irwin–Hall(15) mean 8 minus ~4σ.
            Scenario {
                graph: GraphSpec::RandomRegular { n: 80, d: 8 },
                params: SimParams {
                    z0: 16,
                    control_start: Some(200),
                    ..SimParams::default()
                },
                control: ControlSpec::Decafork { epsilon: 3.5 },
                failures: FailureSpec::Composite(vec![
                    FailureSpec::Probabilistic { p_f: 0.002 },
                    FailureSpec::Burst { events: vec![(600, 6), (1500, 5)] },
                ]),
                horizon: 2500,
                runs: 1,
                seed: 1337,
            },
        ),
        (
            "bursts_missingperson",
            // MISSINGPERSON detects via slot staleness only, so its
            // reaction lag is several E[R] (= 60 here); instantaneous
            // bursts are the failure mode it can actually recover from
            // (a sustained Byzantine killer would outpace it — the
            // paper's Sec. III-A criticism). ε_mp = 5·E[R] keeps false
            // alarms rare; the multi-slot replacement decisions and the
            // resulting slot-reuse churn are what this scenario locks.
            Scenario {
                graph: GraphSpec::RandomRegular { n: 60, d: 6 },
                params: SimParams {
                    z0: 10,
                    control_start: Some(100),
                    ..SimParams::default()
                },
                control: ControlSpec::MissingPerson { eps_mp: 300 },
                failures: FailureSpec::Burst { events: vec![(400, 4), (1100, 3)] },
                horizon: 2000,
                runs: 1,
                seed: 7,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_engines() {
        assert!(fig1_base(2).engine(0).is_ok());
        assert!(perf_hot_loop().engine(0).is_ok());
        for (name, s) in golden() {
            assert!(s.engine(0).is_ok(), "golden scenario {name} failed to build");
            assert!(s.reference_engine(0).is_ok(), "reference {name} failed to build");
        }
    }

    #[test]
    fn perf_control_presets_build_engines() {
        // Small stand-ins are not possible here (the preset IS the
        // workload), but graph construction + wiring must not regress.
        // scale_10k is exercised build-only too: a 10k-node random
        // regular graph builds in well under a second.
        for (name, s) in [
            ("perf_control_geometric", perf_control_geometric()),
            ("perf_control_empirical", perf_control_empirical()),
            ("scale_10k", scale_10k()),
        ] {
            let e = s.engine(0);
            assert!(e.is_ok(), "{name} failed to build: {:?}", e.err());
        }
        // The control-bound pair must be reference-buildable as well —
        // perf_control benches arena against reference on them.
        assert!(perf_control_geometric().reference_engine(0).is_ok());
        assert!(perf_control_empirical().reference_engine(0).is_ok());
    }

    #[test]
    fn scale_presets_are_wired_for_stream_mode() {
        // No graph build here: a 100k/1M-node random-regular sample is a
        // bench-time cost, not a unit-test one. Lock the scenario shape
        // the sharding bench and its acceptance criteria quote.
        let s = scale_100k();
        assert_eq!(s.graph, GraphSpec::RandomRegular { n: 100_000, d: 8 });
        assert_eq!(s.params.z0, 8192);
        assert!(s.params.control_start.is_some(), "auto warm-up would exceed the horizon");
        let m = scale_1m();
        assert_eq!(m.graph, GraphSpec::RandomRegular { n: 1_000_000, d: 8 });
        assert_eq!(m.horizon, 1000);
        assert!(m.params.control_start.is_some());
        // The dense-population acceptance bar (ISSUE 4): the compact
        // per-node index made walk density affordable at 10⁶ nodes.
        assert!(m.params.z0 >= 8192, "scale_1m must keep a dense walk population");
        // Both must survive the benches' DECAFORK_PERF_STEPS rescale.
        let mut r = scale_100k();
        r.rescale_to(200);
        assert_eq!(r.horizon, 200);
        assert_eq!(r.params.control_start, Some(40));
        // The routing-dominated leg (`perf_route`): same topology as
        // scale_100k, doubled walk population — the coordinator's
        // serial arrival scan costs O(live walks), so this is the
        // preset where routing choice shows up.
        let rt = route_100k();
        assert_eq!(rt.graph, s.graph, "route_100k must keep the scale_100k topology");
        assert_eq!(rt.params.z0, 2 * s.params.z0, "route leg doubles the walk population");
        assert!(rt.params.max_walks >= rt.params.z0 as usize * 2);
        assert_ne!(rt.seed, s.seed, "distinct preset, distinct sample");
        let mut rq = route_100k();
        rq.rescale_to(200);
        assert_eq!(rq.horizon, 200);
    }

    #[test]
    fn implicit_scale_presets_are_wired() {
        // Shape lock for the 10⁷/10⁸ probes. Building the graph here is
        // actually cheap (implicit backend — microseconds, O(1) bytes),
        // so unlike scale_1m we can afford to construct the topology and
        // check it; only the engine's O(n) node state is bench-time.
        for (name, s, n) in
            [("scale_10m", scale_10m(), 10_000_000), ("scale_100m", scale_100m(), 100_000_000)]
        {
            assert_eq!(s.graph, GraphSpec::ImplicitSmallWorld { n, d: 8 }, "{name}");
            assert!(s.params.control_start.is_some(), "{name}: auto warm-up exceeds horizon");
            assert!(
                matches!(s.params.survival, SurvivalSpec::AnalyticGeometric),
                "{name}: empirical CDF unreachable at E[R] = n"
            );
            let g = s.build_graph(0).unwrap();
            assert!(g.is_implicit(), "{name}");
            assert_eq!(g.n(), n, "{name}");
            assert_eq!(g.degree(0), 8, "{name}");
            assert!(g.memory_bytes() < 1024, "{name}: topology must stay O(1) memory");
        }
        assert!(scale_10m().params.z0 >= 16_384, "dense walk population at 10⁷");
        // The 10m probe must survive the bench's quick-mode rescale.
        let mut r = scale_10m();
        r.rescale_to(100);
        assert_eq!(r.horizon, 100);
        assert_eq!(r.params.control_start, Some(30));
        // What makes scale_100m *runnable* (ISSUE 7): the default lazy
        // store caps engine state at O(visited) = O(Z0 · horizon) ≪ n.
        let h = scale_100m();
        assert_eq!(
            h.params.node_state,
            crate::walks::NodeStateMode::Lazy,
            "scale_100m needs the lazy store — dense would allocate ~10 GB up front"
        );
        assert!(
            (h.params.max_walks as u64) * h.horizon < 100_000_000 / 4,
            "visited bound must stay far below n for the O(visited) bet to pay"
        );
        // …and it must survive the bench's quick-mode rescale too.
        let mut r = scale_100m();
        r.rescale_to(50);
        assert_eq!(r.horizon, 50);
        assert_eq!(r.params.control_start, Some(16));
    }

    #[test]
    fn learn_presets_are_wired_for_stream_mode() {
        // Shape lock for the training workloads (graph builds for the
        // 10k/100k sizes are bench-time costs, not unit-test ones; the
        // tiny one builds for real).
        assert!(learn_tiny_scenario().sharded_engine(0, 2).is_ok());
        let s = learn_10k();
        assert_eq!(s.graph, GraphSpec::RandomRegular { n: 10_000, d: 8 });
        assert_eq!(s.params.z0, 512);
        assert!(s.params.control_start.is_some(), "auto warm-up would exceed the horizon");
        let b = learn_100k();
        assert_eq!(b.graph, GraphSpec::RandomRegular { n: 100_000, d: 8 });
        assert!(b.params.control_start.is_some());
        // Both must survive the benches' DECAFORK_PERF_STEPS rescale.
        let mut r = learn_10k();
        r.rescale_to(200);
        assert_eq!(r.horizon, 200);
        assert_eq!(r.params.control_start, Some(60));
    }

    #[test]
    fn golden_includes_survival_cache_workout() {
        // The determinism lock must keep exercising the cached θ̂ path
        // under empirical-CDF growth (ISSUE 2 satellite); guard against
        // the scenario being dropped or renamed silently.
        let names: Vec<&str> = golden().into_iter().map(|(n, _)| n).collect();
        assert!(names.contains(&"churn_decafork_empirical"), "{names:?}");
        assert_eq!(names.len(), 4);
    }
}
