//! Named scenarios: the paper's figure setups, the perf workload the
//! engine is benchmarked on, and the golden determinism-lock trio. Keeping
//! them here means the CLI, the figure harness, the benches and the tests
//! all run the *same* experiment when they say the same name.

use super::{ControlSpec, FailureSpec, GraphSpec, Scenario};
use crate::sim::engine::SimParams;

/// Paper Fig. 1 base setup: 8-regular n=100, Z0=10, DECAFORK ε=2,
/// bursts −5 @ 2000 and −6 @ 6000, 10k-step horizon.
pub fn fig1_base(runs: usize) -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        params: SimParams::default(),
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures: FailureSpec::paper_bursts(),
        horizon: 10_000,
        runs,
        seed: 0xDECAF,
    }
}

/// The engine-throughput workload from ISSUE 1's acceptance criteria:
/// 1000-node random-regular graph, 256 walks, 10k steps, 30% cumulative
/// burst failures (three bursts totalling 77 ≈ 0.3·256 walks) plus a
/// continuous per-hop loss rate, with periodic forking refilling the
/// population. The continuous component is what separates O(live) from
/// O(history) stepping: thousands of death+refork cycles grow the seed
/// engine's walk vector (and, pre-index, its node tables) forever while
/// the arena's dense columns stay at ~Z0 entries.
///
/// Control choice: **PeriodicFork**, deliberately. The determinism lock
/// freezes DECAFORK's θ̂ float-sum evaluation bit-for-bit, so its Θ(Z)
/// per-visit estimator costs the arena and reference engines *exactly*
/// the same and would mask the engine-core difference this bench exists
/// to measure (at Z0=256, θ̂ arithmetic is ~10× every other per-step
/// cost combined; the fig benches cover DECAFORK throughput at paper
/// scale). PeriodicFork's O(1) decision keeps the workload engine-bound
/// while sustaining the same churn.
///
/// Tuning: each node forks once per `T` steps when visited, so the
/// aggregate fork rate is `n/T ≈ 1.02/step`; deaths are `p_f·Z`. The
/// fixed point `Z* = n/(p_f·T) ≈ 255` is strongly stable (deaths scale
/// with Z, forks don't), and the staggered fork phases ramp refill up
/// from t≈0, so the population holds near 256 for the whole run while
/// ~1 death+refork per step retires ~10k walks — the O(history)/O(live)
/// gap the arena removes.
pub fn perf_hot_loop() -> Scenario {
    Scenario {
        graph: GraphSpec::RandomRegular { n: 1000, d: 8 },
        params: SimParams {
            z0: 256,
            control_start: Some(1),
            max_walks: 2048,
            ..SimParams::default()
        },
        control: ControlSpec::Periodic { period: 980 },
        failures: FailureSpec::Composite(vec![
            FailureSpec::Burst { events: vec![(3000, 26), (5500, 26), (8000, 25)] },
            FailureSpec::Probabilistic { p_f: 0.004 },
        ]),
        horizon: 10_000,
        runs: 1,
        seed: 0xBEEF,
    }
}

/// The three seeded scenarios whose `Trace::z` vectors are the
/// determinism lock (`tests/golden_traces.rs`): the arena engine must
/// reproduce the frozen reference engine on all of them, byte for byte.
/// Chosen to cover the three failure surfaces (pre-step bursts, per-hop
/// probabilistic losses, Byzantine arrivals) and all control families
/// that fork (DECAFORK, DECAFORK+, MISSINGPERSON).
pub fn golden() -> Vec<(&'static str, Scenario)> {
    vec![
        (
            "fig1_burst",
            Scenario {
                graph: GraphSpec::RandomRegular { n: 100, d: 8 },
                params: SimParams::default(),
                control: ControlSpec::Decafork { epsilon: 2.0 },
                failures: FailureSpec::paper_bursts(),
                horizon: 3000,
                runs: 1,
                seed: 0xDECAF,
            },
        ),
        (
            "churn_byzantine_decaforkplus",
            // All three failure surfaces at once, against the control
            // family that exercises termination too. DECAFORK+'s
            // survival-based detection reacts on the return-time scale
            // (E[R] = 50 here), fast enough to outpace the Byzantine
            // node's ~Z/n kills per step during its phase.
            Scenario {
                graph: GraphSpec::RandomRegular { n: 50, d: 6 },
                params: SimParams {
                    z0: 12,
                    control_start: Some(200),
                    ..SimParams::default()
                },
                control: ControlSpec::DecaforkPlus { epsilon: 2.0, epsilon2: 5.0 },
                failures: FailureSpec::Composite(vec![
                    FailureSpec::Burst { events: vec![(300, 4)] },
                    FailureSpec::Probabilistic { p_f: 0.002 },
                    FailureSpec::ByzantineScheduled {
                        node: 1,
                        schedule: vec![(600, true), (1200, false)],
                    },
                ]),
                horizon: 2000,
                runs: 1,
                seed: 42,
            },
        ),
        (
            "bursts_missingperson",
            // MISSINGPERSON detects via slot staleness only, so its
            // reaction lag is several E[R] (= 60 here); instantaneous
            // bursts are the failure mode it can actually recover from
            // (a sustained Byzantine killer would outpace it — the
            // paper's Sec. III-A criticism). ε_mp = 5·E[R] keeps false
            // alarms rare; the multi-slot replacement decisions and the
            // resulting slot-reuse churn are what this scenario locks.
            Scenario {
                graph: GraphSpec::RandomRegular { n: 60, d: 6 },
                params: SimParams {
                    z0: 10,
                    control_start: Some(100),
                    ..SimParams::default()
                },
                control: ControlSpec::MissingPerson { eps_mp: 300 },
                failures: FailureSpec::Burst { events: vec![(400, 4), (1100, 3)] },
                horizon: 2000,
                runs: 1,
                seed: 7,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_engines() {
        assert!(fig1_base(2).engine(0).is_ok());
        assert!(perf_hot_loop().engine(0).is_ok());
        for (name, s) in golden() {
            assert!(s.engine(0).is_ok(), "golden scenario {name} failed to build");
            assert!(s.reference_engine(0).is_ok(), "reference {name} failed to build");
        }
    }
}
