//! CLI-flag → [`Scenario`] parsing, shared by every binary entry point
//! (previously private helpers inside `main.rs`).

use super::{ControlSpec, FailureSpec, GraphSpec, Scenario};
use crate::cli::Args;
use crate::obs::{MetricsConfig, MetricsMode};
use crate::sim::engine::{HopPath, RoutingMode, SimParams, SurvivalSpec};
use crate::walks::NodeStateMode;

/// `--graph regular|er|complete|ba|ring` plus its family flags, and
/// `--topology` — the same knob under the name the implicit families
/// introduced (every `--graph` value works there too, plus
/// `implicit-ring`/`implicit-smallworld`). Giving both is an error
/// rather than a precedence rule.
pub fn graph(args: &Args) -> anyhow::Result<GraphSpec> {
    let n = args.get("n", 100usize)?;
    anyhow::ensure!(
        !args.has("topology"),
        "--topology needs a value (e.g. --topology implicit-smallworld)"
    );
    anyhow::ensure!(
        !(args.flags.contains_key("graph") && args.flags.contains_key("topology")),
        "--graph and --topology are the same knob — give one"
    );
    let family = match args.flags.get("topology") {
        Some(t) => t.clone(),
        None => args.get_str("graph", "regular"),
    };
    Ok(match family.as_str() {
        "regular" => GraphSpec::RandomRegular { n, d: args.get("d", 8usize)? },
        "er" | "erdos-renyi" => GraphSpec::ErdosRenyi { n, p: args.get("p", 0.08f64)? },
        "complete" => GraphSpec::Complete { n },
        "ba" | "power-law" => GraphSpec::PowerLaw { n, m: args.get("m", 4usize)? },
        "ring" => GraphSpec::Ring { n },
        "implicit-ring" | "implicit-regular" => {
            GraphSpec::ImplicitRegular { n, d: args.get("d", 8usize)? }
        }
        "implicit-smallworld" | "smallworld" => {
            GraphSpec::ImplicitSmallWorld { n, d: args.get("d", 8usize)? }
        }
        other => anyhow::bail!("unknown graph '{other}'"),
    })
}

/// `t:count,t:count,…` burst schedules (empty / "none" = no bursts).
pub fn bursts(s: &str) -> anyhow::Result<Vec<(u64, usize)>> {
    if s.is_empty() || s == "none" {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|pair| {
            let (t, c) = pair
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("burst '{pair}' must be t:count"))?;
            Ok((t.trim().parse()?, c.trim().parse()?))
        })
        .collect()
}

/// `--control decafork|decafork+|missingperson|periodic|none` plus its
/// threshold flags.
pub fn control(args: &Args) -> anyhow::Result<ControlSpec> {
    Ok(match args.get_str("control", "decafork").as_str() {
        "decafork" => ControlSpec::Decafork { epsilon: args.get("eps", 2.0)? },
        "decafork+" | "decaforkplus" => ControlSpec::DecaforkPlus {
            epsilon: args.get("eps", 3.25)?,
            epsilon2: args.get("eps2", 5.75)?,
        },
        "missingperson" | "mp" => {
            ControlSpec::MissingPerson { eps_mp: args.get("eps-mp", 600u64)? }
        }
        "periodic" => ControlSpec::Periodic { period: args.get("period", 100u64)? },
        "none" => ControlSpec::None,
        other => anyhow::bail!("unknown control '{other}'"),
    })
}

/// Assemble the failure model from `--bursts`, `--pf` and `--byz-node`.
pub fn failures(args: &Args) -> anyhow::Result<FailureSpec> {
    let mut parts = vec![];
    let burst_events = bursts(&args.get_str("bursts", "2000:5,6000:6"))?;
    if !burst_events.is_empty() {
        parts.push(FailureSpec::Burst { events: burst_events });
    }
    let pf = args.get("pf", 0.0f64)?;
    if pf > 0.0 {
        parts.push(FailureSpec::Probabilistic { p_f: pf });
    }
    let byz: i64 = args.get("byz-node", -1i64)?;
    if byz >= 0 {
        parts.push(FailureSpec::ByzantineScheduled {
            node: byz as u32,
            schedule: vec![
                (args.get("byz-from", 1000u64)?, true),
                (args.get("byz-until", 5000u64)?, false),
            ],
        });
    }
    Ok(match parts.len() {
        0 => FailureSpec::None,
        1 => parts.pop().unwrap(),
        _ => FailureSpec::Composite(parts),
    })
}

/// `--survival empirical|geometric|exponential`.
pub fn survival(args: &Args) -> anyhow::Result<SurvivalSpec> {
    Ok(match args.get_str("survival", "empirical").as_str() {
        "empirical" => SurvivalSpec::Empirical,
        "geometric" => SurvivalSpec::AnalyticGeometric,
        "exponential" => SurvivalSpec::AnalyticExponential,
        other => anyhow::bail!("unknown survival model '{other}'"),
    })
}

// The shared positive-integer knob validator lives in `cli` (a leaf
// module both this layer and `sim::runner`'s `CoreBudget::from_env` can
// reach); re-exported here because the shards/cores flag plumbing is
// where callers look for it.
pub use crate::cli::positive_count;

/// `--shards N`: stream-mode worker count. `1` (the default) keeps the
/// shared-stream engine — existing invocations are byte-for-byte
/// unchanged; `>= 2` switches the runner to the per-walk-stream
/// [`ShardedEngine`](crate::sim::ShardedEngine), whose trace is
/// bit-identical at any worker count but is a different sample family
/// than shard count 1's shared-stream engine.
pub fn shards(args: &Args) -> anyhow::Result<usize> {
    // A valueless `--shards` (last arg, or followed by another flag)
    // parses as a switch; treating it as "default 1" would silently run
    // the shared-stream engine — a different trace family — so it is an
    // error, like every other invalid value for this knob.
    anyhow::ensure!(!args.has("shards"), "--shards needs a value (e.g. --shards 4)");
    match args.flags.get("shards") {
        None => Ok(1),
        Some(v) => positive_count("--shards", v),
    }
}

/// `DECAFORK_SHARDS` env override for binaries without flag plumbing
/// (ablation benches, examples, the stream-golden test): same semantics
/// as `--shards`, default 1 (shared-stream engine, results unchanged).
/// A present-but-invalid value (0, non-numeric) is an error.
pub fn shards_from_env() -> anyhow::Result<usize> {
    match std::env::var("DECAFORK_SHARDS") {
        Err(_) => Ok(1),
        Ok(v) => positive_count("DECAFORK_SHARDS", &v),
    }
}

/// `--node-state dense|lazy`: how engines store per-node estimator
/// state. `lazy` (the default, also when the flag is absent)
/// materializes a node's state on first visit — O(visited) memory and
/// housekeeping, the mode that makes `scale_100m` runnable; `dense`
/// keeps the eager O(n) columns as the A/B oracle `perf_state` and the
/// lazy-vs-dense golden matrix compare against. Results are
/// bit-identical either way (DESIGN.md §Lazy node store), so unlike
/// `--shards` this knob can never select a different trace family —
/// but a valueless or unknown value is still an error, not a fallback.
pub fn node_state(args: &Args) -> anyhow::Result<NodeStateMode> {
    anyhow::ensure!(!args.has("node-state"), "--node-state needs a value (dense or lazy)");
    match args.flags.get("node-state") {
        None => Ok(NodeStateMode::Lazy),
        Some(v) => node_state_value("--node-state", v),
    }
}

/// Shared value validation for `--node-state` / `DECAFORK_NODE_STATE`:
/// errors name the knob, like [`positive_count`] does for the count
/// knobs.
fn node_state_value(knob: &str, v: &str) -> anyhow::Result<NodeStateMode> {
    match v.trim() {
        "lazy" => Ok(NodeStateMode::Lazy),
        "dense" => Ok(NodeStateMode::Dense),
        other => anyhow::bail!("{knob} must be 'dense' or 'lazy', got '{other}'"),
    }
}

/// `DECAFORK_NODE_STATE` env mirror for binaries without flag plumbing
/// (benches, the golden tests' lazy-vs-dense CI matrix): same semantics
/// as `--node-state`, absent = lazy, present-but-invalid = error.
pub fn node_state_from_env() -> anyhow::Result<NodeStateMode> {
    match std::env::var("DECAFORK_NODE_STATE") {
        Err(_) => Ok(NodeStateMode::Lazy),
        Ok(v) => node_state_value("DECAFORK_NODE_STATE", &v),
    }
}

/// `--routing serial|mailbox`: how the stream-mode engine moves arrivals
/// from the hop phase to the control phase. `mailbox` (the default, also
/// when the flag is absent) bins arrivals on the hop workers so the
/// coordinator's inter-phase work is O(shards); `serial` keeps the
/// O(live-walks) coordinator scan as the A/B oracle `perf_route` and the
/// routing golden matrix compare against. Results are bit-identical
/// either way (DESIGN.md §Locality & routing) — like `--node-state`,
/// this knob can never select a different trace family — but a valueless
/// or unknown value is still an error, not a fallback.
pub fn routing(args: &Args) -> anyhow::Result<RoutingMode> {
    anyhow::ensure!(!args.has("routing"), "--routing needs a value (serial or mailbox)");
    match args.flags.get("routing") {
        None => Ok(RoutingMode::Mailbox),
        Some(v) => routing_value("--routing", v),
    }
}

/// Shared value validation for `--routing` / `DECAFORK_ROUTING`: errors
/// name the knob, like [`positive_count`] does for the count knobs.
fn routing_value(knob: &str, v: &str) -> anyhow::Result<RoutingMode> {
    match v.trim() {
        "mailbox" => Ok(RoutingMode::Mailbox),
        "serial" => Ok(RoutingMode::Serial),
        other => anyhow::bail!("{knob} must be 'serial' or 'mailbox', got '{other}'"),
    }
}

/// `DECAFORK_ROUTING` env mirror for binaries without flag plumbing
/// (benches, the golden tests' routing CI matrix): same semantics as
/// `--routing`, absent = mailbox, present-but-invalid = error.
pub fn routing_from_env() -> anyhow::Result<RoutingMode> {
    match std::env::var("DECAFORK_ROUTING") {
        Err(_) => Ok(RoutingMode::Mailbox),
        Ok(v) => routing_value("DECAFORK_ROUTING", &v),
    }
}

/// `--hop-path scalar|blocked`: how the stream-mode engine executes its
/// hop and control chunks. `blocked` (the default, also when the flag
/// is absent) pipelines each chunk over 64-walk blocks — software
/// prefetch of the next block's CSR/index lines, batched
/// `Graph::step_block` draws — so workers keep many memory misses in
/// flight; `scalar` keeps the one-walk-at-a-time loops as the A/B
/// oracle `perf_hop` and the hop-path golden matrix compare against.
/// Results are bit-identical either way (DESIGN.md §Block pipelining) —
/// like `--node-state` and `--routing`, this knob can never select a
/// different trace family — but a valueless or unknown value is still
/// an error, not a fallback.
pub fn hop_path(args: &Args) -> anyhow::Result<HopPath> {
    anyhow::ensure!(!args.has("hop-path"), "--hop-path needs a value (scalar or blocked)");
    match args.flags.get("hop-path") {
        None => Ok(HopPath::Blocked),
        Some(v) => hop_path_value("--hop-path", v),
    }
}

/// Shared value validation for `--hop-path` / `DECAFORK_HOP_PATH`:
/// errors name the knob, like [`positive_count`] does for the count
/// knobs.
fn hop_path_value(knob: &str, v: &str) -> anyhow::Result<HopPath> {
    match v.trim() {
        "blocked" => Ok(HopPath::Blocked),
        "scalar" => Ok(HopPath::Scalar),
        other => anyhow::bail!("{knob} must be 'scalar' or 'blocked', got '{other}'"),
    }
}

/// `DECAFORK_HOP_PATH` env mirror for binaries without flag plumbing
/// (benches, the golden tests' hop-path CI matrix): same semantics as
/// `--hop-path`, absent = blocked, present-but-invalid = error.
pub fn hop_path_from_env() -> anyhow::Result<HopPath> {
    match std::env::var("DECAFORK_HOP_PATH") {
        Err(_) => Ok(HopPath::Blocked),
        Ok(v) => hop_path_value("DECAFORK_HOP_PATH", &v),
    }
}

/// `--pin-cores on|off`: pin stream-mode pool worker `k` to CPU core
/// `k + 1` (Linux only, best-effort, placement-only — DESIGN.md
/// §Locality & routing explains why it is off by default). Takes an
/// explicit value rather than acting as a bare switch so the env mirror,
/// scripts and CI matrices can spell both states; a valueless or unknown
/// value is an error, not a fallback.
pub fn pin_cores(args: &Args) -> anyhow::Result<bool> {
    anyhow::ensure!(!args.has("pin-cores"), "--pin-cores needs a value (on or off)");
    match args.flags.get("pin-cores") {
        None => Ok(false),
        Some(v) => pin_cores_value("--pin-cores", v),
    }
}

/// Shared value validation for `--pin-cores` / `DECAFORK_PIN_CORES`.
fn pin_cores_value(knob: &str, v: &str) -> anyhow::Result<bool> {
    match v.trim() {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("{knob} must be 'on' or 'off', got '{other}'"),
    }
}

/// `DECAFORK_PIN_CORES` env mirror for binaries without flag plumbing
/// (benches, examples): same semantics as `--pin-cores`, absent = off,
/// present-but-invalid = error.
pub fn pin_cores_from_env() -> anyhow::Result<bool> {
    match std::env::var("DECAFORK_PIN_CORES") {
        Err(_) => Ok(false),
        Ok(v) => pin_cores_value("DECAFORK_PIN_CORES", &v),
    }
}

/// `--metrics off|jsonl|csv`: streaming engine telemetry (DESIGN.md
/// §Observability). `off` (the default, also when the flag is absent)
/// records nothing — existing invocations are byte-for-byte unchanged;
/// `jsonl`/`csv` stream one step record every `--metrics-every` steps
/// to `--metrics-out`. Telemetry is pure observation, so like
/// `--node-state`/`--routing`/`--hop-path` this knob can never select
/// a different trace family — but a valueless or unknown value is
/// still an error, not a fallback.
pub fn metrics_mode(args: &Args) -> anyhow::Result<MetricsMode> {
    anyhow::ensure!(!args.has("metrics"), "--metrics needs a value (off, jsonl or csv)");
    match args.flags.get("metrics") {
        None => Ok(MetricsMode::Off),
        Some(v) => metrics_value("--metrics", v),
    }
}

/// Shared value validation for `--metrics` / `DECAFORK_METRICS`:
/// errors name the knob, like [`positive_count`] does for the count
/// knobs.
fn metrics_value(knob: &str, v: &str) -> anyhow::Result<MetricsMode> {
    match v.trim() {
        "off" => Ok(MetricsMode::Off),
        "jsonl" => Ok(MetricsMode::Jsonl),
        "csv" => Ok(MetricsMode::Csv),
        other => anyhow::bail!("{knob} must be 'off', 'jsonl' or 'csv', got '{other}'"),
    }
}

/// `DECAFORK_METRICS` env mirror for binaries without flag plumbing
/// (benches, the golden tests' metrics CI matrix): same semantics as
/// `--metrics`, absent = off, present-but-invalid = error.
pub fn metrics_mode_from_env() -> anyhow::Result<MetricsMode> {
    match std::env::var("DECAFORK_METRICS") {
        Err(_) => Ok(MetricsMode::Off),
        Ok(v) => metrics_value("DECAFORK_METRICS", &v),
    }
}

/// `--metrics-out PATH`: where the sink streams (absent = the mode's
/// default, `metrics.jsonl` / `metrics.csv`). Any path is a valid
/// value, but a valueless flag is still an error naming the knob.
pub fn metrics_out(args: &Args) -> anyhow::Result<Option<String>> {
    anyhow::ensure!(
        !args.has("metrics-out"),
        "--metrics-out needs a value (e.g. --metrics-out run.jsonl)"
    );
    Ok(args.flags.get("metrics-out").cloned())
}

/// `DECAFORK_METRICS_OUT` env mirror of `--metrics-out`.
pub fn metrics_out_from_env() -> Option<String> {
    std::env::var("DECAFORK_METRICS_OUT").ok()
}

/// `--metrics-every K`: the sink's flush period in steps. Absent = 1
/// (one record per step); a present value goes through the same
/// [`positive_count`] validation as every count knob ("flush every 0
/// steps" is a typo, not a request). Records are period totals, so a
/// coarse period loses nothing.
pub fn metrics_every(args: &Args) -> anyhow::Result<u64> {
    anyhow::ensure!(!args.has("metrics-every"), "--metrics-every needs a value (in steps)");
    match args.flags.get("metrics-every") {
        None => Ok(1),
        Some(v) => Ok(positive_count("--metrics-every", v)? as u64),
    }
}

/// `DECAFORK_METRICS_EVERY` env mirror of `--metrics-every`.
pub fn metrics_every_from_env() -> anyhow::Result<u64> {
    match std::env::var("DECAFORK_METRICS_EVERY") {
        Err(_) => Ok(1),
        Ok(v) => Ok(positive_count("DECAFORK_METRICS_EVERY", &v)? as u64),
    }
}

/// The assembled metrics knob family from the command line.
pub fn metrics(args: &Args) -> anyhow::Result<MetricsConfig> {
    Ok(MetricsConfig {
        mode: metrics_mode(args)?,
        out: metrics_out(args)?,
        every: metrics_every(args)?,
    })
}

/// The assembled metrics knob family from the `DECAFORK_METRICS*` env
/// mirrors (benches, the golden tests' metrics CI matrix).
pub fn metrics_from_env() -> anyhow::Result<MetricsConfig> {
    Ok(MetricsConfig {
        mode: metrics_mode_from_env()?,
        out: metrics_out_from_env(),
        every: metrics_every_from_env()?,
    })
}

/// `--cores N`: the runner's [`CoreBudget`] — total cores split across
/// replication threads × per-run stream workers
/// ([`CoreBudget::plan`](crate::sim::CoreBudget::plan)). Falls back to
/// `DECAFORK_CORES`, then to detected parallelism.
pub fn cores(args: &Args) -> anyhow::Result<crate::sim::CoreBudget> {
    anyhow::ensure!(!args.has("cores"), "--cores needs a value (e.g. --cores 8)");
    match args.flags.get("cores") {
        Some(v) => crate::sim::CoreBudget::new(positive_count("--cores", v)?),
        None => crate::sim::CoreBudget::from_env(),
    }
}

/// `--merge-every K`: the sharded trainer's barrier parameter-merge
/// period. Absent = 0 = never merge; a present value goes through the
/// same [`positive_count`] validation as every shards/cores knob (`0`
/// and non-numeric error with the knob named — "merge every 0 steps" is
/// a typo, not a request).
pub fn merge_every(args: &Args) -> anyhow::Result<u64> {
    anyhow::ensure!(!args.has("merge-every"), "--merge-every needs a value (in steps)");
    match args.flags.get("merge-every") {
        None => Ok(0),
        Some(v) => Ok(positive_count("--merge-every", v)? as u64),
    }
}

/// The full `simulate` scenario from the command line.
pub fn scenario(args: &Args) -> anyhow::Result<Scenario> {
    Ok(Scenario {
        graph: graph(args)?,
        params: SimParams {
            z0: args.get("z0", 10u32)?,
            record_theta: args.has("record-theta"),
            survival: survival(args)?,
            control_start: args.flags.get("warmup").map(|w| w.parse()).transpose()?,
            shards: shards(args)?,
            node_state: node_state(args)?,
            routing: routing(args)?,
            pin_cores: pin_cores(args)?,
            hop_path: hop_path(args)?,
            metrics: metrics(args)?,
            ..Default::default()
        },
        control: control(args)?,
        failures: failures(args)?,
        horizon: args.get("horizon", 10_000u64)?,
        runs: args.get("runs", 10usize)?,
        seed: args.get("seed", 0xDECAFu64)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn bursts_parse_and_reject() {
        assert_eq!(bursts("2000:5,6000:6").unwrap(), vec![(2000, 5), (6000, 6)]);
        assert!(bursts("none").unwrap().is_empty());
        assert!(bursts("2000").is_err());
    }

    #[test]
    fn full_scenario_from_flags() {
        let a = args(
            "simulate --graph regular --n 50 --d 4 --z0 8 --control decafork+ \
             --eps 3.0 --eps2 6.0 --pf 0.001 --bursts 100:2 --horizon 500 --runs 3 --seed 9",
        );
        let s = scenario(&a).unwrap();
        assert_eq!(s.graph, GraphSpec::RandomRegular { n: 50, d: 4 });
        assert_eq!(s.control, ControlSpec::DecaforkPlus { epsilon: 3.0, epsilon2: 6.0 });
        assert_eq!(s.params.z0, 8);
        assert_eq!(s.horizon, 500);
        assert_eq!(s.runs, 3);
        assert_eq!(s.seed, 9);
        match s.failures {
            FailureSpec::Composite(parts) => assert_eq!(parts.len(), 2),
            other => panic!("expected composite, got {other:?}"),
        }
    }

    #[test]
    fn defaults_match_paper() {
        let a = args("simulate");
        let s = scenario(&a).unwrap();
        assert_eq!(s.failures, FailureSpec::paper_bursts());
        assert_eq!(s.control, ControlSpec::Decafork { epsilon: 2.0 });
        assert_eq!(s.params.shards, 1, "default must stay on the shared-stream engine");
    }

    #[test]
    fn topology_knob_selects_implicit_families() {
        let s = scenario(&args("simulate --topology implicit-smallworld --n 4096 --d 8")).unwrap();
        assert_eq!(s.graph, GraphSpec::ImplicitSmallWorld { n: 4096, d: 8 });
        let r = graph(&args("simulate --topology implicit-ring --n 64 --d 4")).unwrap();
        assert_eq!(r, GraphSpec::ImplicitRegular { n: 64, d: 4 });
        // --topology accepts the materializing families too…
        let g = graph(&args("simulate --topology ring --n 12")).unwrap();
        assert_eq!(g, GraphSpec::Ring { n: 12 });
        // …valueless and both-knobs forms are errors, not fallbacks.
        let e = graph(&args("simulate --topology")).unwrap_err().to_string();
        assert!(e.contains("--topology"), "{e}");
        let e = graph(&args("simulate --graph ring --topology ring")).unwrap_err().to_string();
        assert!(e.contains("same knob"), "{e}");
        assert!(graph(&args("simulate --topology nope")).is_err());
    }

    #[test]
    fn shards_flag_parses_and_rejects_zero() {
        let s = scenario(&args("simulate --shards 8")).unwrap();
        assert_eq!(s.params.shards, 8);
        assert!(scenario(&args("simulate --shards 0")).is_err());
    }

    #[test]
    fn positive_count_rejects_zero_and_nonnumeric_with_named_knob() {
        // The shared validator behind --shards / DECAFORK_SHARDS /
        // --cores / DECAFORK_CORES: both failure paths must error (not
        // panic, not fall back) and say which knob was wrong.
        assert_eq!(positive_count("--shards", "8").unwrap(), 8);
        assert_eq!(positive_count("DECAFORK_SHARDS", " 2 ").unwrap(), 2);
        let zero = positive_count("DECAFORK_SHARDS", "0").unwrap_err().to_string();
        assert!(zero.contains("DECAFORK_SHARDS") && zero.contains(">= 1"), "{zero}");
        for bad in ["abc", "", "-3", "2.5", "1e3"] {
            let err = positive_count("--shards", bad).unwrap_err().to_string();
            assert!(err.contains("--shards"), "{err}");
        }
        // Flag plumbing routes through the same validator.
        let err = shards(&args("simulate --shards nope")).unwrap_err().to_string();
        assert!(err.contains("--shards"), "{err}");
        assert_eq!(shards(&args("simulate")).unwrap(), 1);
    }

    #[test]
    fn merge_every_validates_like_the_other_knobs() {
        assert_eq!(merge_every(&args("train")).unwrap(), 0, "absent = merging off");
        assert_eq!(merge_every(&args("train --merge-every 50")).unwrap(), 50);
        for bad in ["0", "abc", "-2"] {
            let err = merge_every(&args(&format!("train --merge-every {bad}")))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--merge-every"), "knob not named: {err}");
        }
    }

    #[test]
    fn valueless_knobs_error_instead_of_falling_back() {
        // `--shards` parsed as a trailing switch must not silently mean
        // "shards = 1" (that selects a different trace family); same for
        // the other count knobs.
        for (parse_err, cmd, knob) in [
            (shards(&args("simulate --shards")).unwrap_err().to_string(), "simulate", "--shards"),
            (
                merge_every(&args("train --merge-every --local")).unwrap_err().to_string(),
                "train",
                "--merge-every",
            ),
            (cores(&args("simulate --cores")).unwrap_err().to_string(), "simulate", "--cores"),
        ] {
            assert!(parse_err.contains(knob), "{cmd}: knob not named: {parse_err}");
        }
    }

    #[test]
    fn node_state_knob_validates_and_defaults_lazy() {
        // Absent = lazy (the O(visited) default), explicit values parse,
        // and both failure modes — valueless switch and unknown value —
        // error with the knob named instead of falling back.
        assert_eq!(node_state(&args("simulate")).unwrap(), NodeStateMode::Lazy);
        assert_eq!(node_state(&args("simulate --node-state lazy")).unwrap(), NodeStateMode::Lazy);
        assert_eq!(node_state(&args("simulate --node-state dense")).unwrap(), NodeStateMode::Dense);
        let e = node_state(&args("simulate --node-state")).unwrap_err().to_string();
        assert!(e.contains("--node-state"), "valueless: knob not named: {e}");
        let e = node_state(&args("simulate --node-state --record-theta"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--node-state"), "switch-before-flag: knob not named: {e}");
        for bad in ["sparse", "eager", "0", ""] {
            let e = node_state(&args(&format!("simulate --node-state {bad}")))
                .unwrap_err()
                .to_string();
            assert!(e.contains("--node-state"), "'{bad}': knob not named: {e}");
        }
        // Full scenario plumbing.
        let s = scenario(&args("simulate --node-state dense")).unwrap();
        assert_eq!(s.params.node_state, NodeStateMode::Dense);
        let s = scenario(&args("simulate")).unwrap();
        assert_eq!(s.params.node_state, NodeStateMode::Lazy, "default must be the lazy store");
    }

    #[test]
    fn node_state_env_mirror_validates_values() {
        // Value validation only — the absent-variable default is covered
        // by the knob test above (reading the live process env here
        // would race other tests).
        assert_eq!(node_state_value("DECAFORK_NODE_STATE", "lazy").unwrap(), NodeStateMode::Lazy);
        assert_eq!(
            node_state_value("DECAFORK_NODE_STATE", " dense ").unwrap(),
            NodeStateMode::Dense
        );
        let e = node_state_value("DECAFORK_NODE_STATE", "both").unwrap_err().to_string();
        assert!(e.contains("DECAFORK_NODE_STATE"), "env var not named: {e}");
    }

    #[test]
    fn routing_knob_validates_and_defaults_mailbox() {
        // Absent = mailbox (the O(shards) coordinator default), explicit
        // values parse, and both failure modes — valueless switch and
        // unknown value — error with the knob named instead of falling
        // back.
        assert_eq!(routing(&args("simulate")).unwrap(), RoutingMode::Mailbox);
        assert_eq!(routing(&args("simulate --routing mailbox")).unwrap(), RoutingMode::Mailbox);
        assert_eq!(routing(&args("simulate --routing serial")).unwrap(), RoutingMode::Serial);
        let e = routing(&args("simulate --routing")).unwrap_err().to_string();
        assert!(e.contains("--routing"), "valueless: knob not named: {e}");
        let e = routing(&args("simulate --routing --record-theta")).unwrap_err().to_string();
        assert!(e.contains("--routing"), "switch-before-flag: knob not named: {e}");
        for bad in ["parallel", "scan", "0", ""] {
            let e = routing(&args(&format!("simulate --routing {bad}"))).unwrap_err().to_string();
            assert!(e.contains("--routing"), "'{bad}': knob not named: {e}");
        }
        // Full scenario plumbing.
        let s = scenario(&args("simulate --routing serial")).unwrap();
        assert_eq!(s.params.routing, RoutingMode::Serial);
        let s = scenario(&args("simulate")).unwrap();
        assert_eq!(s.params.routing, RoutingMode::Mailbox, "default must be mailbox routing");
    }

    #[test]
    fn routing_env_mirror_validates_values() {
        // Value validation only — the absent-variable default is covered
        // by the knob test above (reading the live process env here
        // would race other tests).
        assert_eq!(routing_value("DECAFORK_ROUTING", "serial").unwrap(), RoutingMode::Serial);
        assert_eq!(routing_value("DECAFORK_ROUTING", " mailbox ").unwrap(), RoutingMode::Mailbox);
        let e = routing_value("DECAFORK_ROUTING", "both").unwrap_err().to_string();
        assert!(e.contains("DECAFORK_ROUTING"), "env var not named: {e}");
    }

    #[test]
    fn hop_path_knob_validates_and_defaults_blocked() {
        // Absent = blocked (the pipelined default), explicit values
        // parse, and both failure modes — valueless switch and unknown
        // value — error with the knob named instead of falling back.
        assert_eq!(hop_path(&args("simulate")).unwrap(), HopPath::Blocked);
        assert_eq!(hop_path(&args("simulate --hop-path blocked")).unwrap(), HopPath::Blocked);
        assert_eq!(hop_path(&args("simulate --hop-path scalar")).unwrap(), HopPath::Scalar);
        let e = hop_path(&args("simulate --hop-path")).unwrap_err().to_string();
        assert!(e.contains("--hop-path"), "valueless: knob not named: {e}");
        let e = hop_path(&args("simulate --hop-path --record-theta")).unwrap_err().to_string();
        assert!(e.contains("--hop-path"), "switch-before-flag: knob not named: {e}");
        for bad in ["vector", "batched", "0", ""] {
            let e = hop_path(&args(&format!("simulate --hop-path {bad}")))
                .unwrap_err()
                .to_string();
            assert!(e.contains("--hop-path"), "'{bad}': knob not named: {e}");
        }
        // Full scenario plumbing.
        let s = scenario(&args("simulate --hop-path scalar")).unwrap();
        assert_eq!(s.params.hop_path, HopPath::Scalar);
        let s = scenario(&args("simulate")).unwrap();
        assert_eq!(s.params.hop_path, HopPath::Blocked, "default must be the blocked path");
    }

    #[test]
    fn hop_path_env_mirror_validates_values() {
        // Value validation only — the absent-variable default is covered
        // by the knob test above (reading the live process env here
        // would race other tests).
        assert_eq!(hop_path_value("DECAFORK_HOP_PATH", "scalar").unwrap(), HopPath::Scalar);
        assert_eq!(hop_path_value("DECAFORK_HOP_PATH", " blocked ").unwrap(), HopPath::Blocked);
        let e = hop_path_value("DECAFORK_HOP_PATH", "both").unwrap_err().to_string();
        assert!(e.contains("DECAFORK_HOP_PATH"), "env var not named: {e}");
    }

    #[test]
    fn pin_cores_knob_validates_and_defaults_off() {
        assert!(!pin_cores(&args("simulate")).unwrap(), "pinning must be opt-in");
        assert!(pin_cores(&args("simulate --pin-cores on")).unwrap());
        assert!(!pin_cores(&args("simulate --pin-cores off")).unwrap());
        let e = pin_cores(&args("simulate --pin-cores")).unwrap_err().to_string();
        assert!(e.contains("--pin-cores"), "valueless: knob not named: {e}");
        let e = pin_cores(&args("simulate --pin-cores --record-theta")).unwrap_err().to_string();
        assert!(e.contains("--pin-cores"), "switch-before-flag: knob not named: {e}");
        for bad in ["true", "yes", "1", ""] {
            let e = pin_cores(&args(&format!("simulate --pin-cores {bad}")))
                .unwrap_err()
                .to_string();
            assert!(e.contains("--pin-cores"), "'{bad}': knob not named: {e}");
        }
        // Env mirror value validation + full scenario plumbing.
        assert!(pin_cores_value("DECAFORK_PIN_CORES", " on ").unwrap());
        let e = pin_cores_value("DECAFORK_PIN_CORES", "maybe").unwrap_err().to_string();
        assert!(e.contains("DECAFORK_PIN_CORES"), "env var not named: {e}");
        let s = scenario(&args("simulate --pin-cores on")).unwrap();
        assert!(s.params.pin_cores);
        let s = scenario(&args("simulate")).unwrap();
        assert!(!s.params.pin_cores, "default must leave threads unpinned");
    }

    #[test]
    fn metrics_knob_validates_and_defaults_off() {
        // Absent = off (telemetry is strictly opt-in), explicit values
        // parse, and both failure modes — valueless switch and unknown
        // value — error with the knob named instead of falling back.
        assert_eq!(metrics_mode(&args("simulate")).unwrap(), MetricsMode::Off);
        assert_eq!(metrics_mode(&args("simulate --metrics off")).unwrap(), MetricsMode::Off);
        assert_eq!(metrics_mode(&args("simulate --metrics jsonl")).unwrap(), MetricsMode::Jsonl);
        assert_eq!(metrics_mode(&args("simulate --metrics csv")).unwrap(), MetricsMode::Csv);
        let e = metrics_mode(&args("simulate --metrics")).unwrap_err().to_string();
        assert!(e.contains("--metrics"), "valueless: knob not named: {e}");
        let e = metrics_mode(&args("simulate --metrics --record-theta")).unwrap_err().to_string();
        assert!(e.contains("--metrics"), "switch-before-flag: knob not named: {e}");
        for bad in ["json", "ndjson", "on", "0", ""] {
            let e = metrics_mode(&args(&format!("simulate --metrics {bad}")))
                .unwrap_err()
                .to_string();
            assert!(e.contains("--metrics"), "'{bad}': knob not named: {e}");
        }
        // Full scenario plumbing: mode, path and period land on SimParams.
        let s = scenario(&args(
            "simulate --metrics jsonl --metrics-out run.ndjson --metrics-every 25",
        ))
        .unwrap();
        assert_eq!(s.params.metrics.mode, MetricsMode::Jsonl);
        assert_eq!(s.params.metrics.out.as_deref(), Some("run.ndjson"));
        assert_eq!(s.params.metrics.every, 25);
        let s = scenario(&args("simulate")).unwrap();
        assert!(!s.params.metrics.enabled(), "default must record nothing");
        assert_eq!(s.params.metrics.every, 1);
        assert_eq!(s.params.metrics.out, None);
    }

    #[test]
    fn metrics_out_and_every_validate_like_the_other_knobs() {
        assert_eq!(metrics_out(&args("simulate")).unwrap(), None);
        assert_eq!(
            metrics_out(&args("simulate --metrics-out m.csv")).unwrap().as_deref(),
            Some("m.csv")
        );
        let e = metrics_out(&args("simulate --metrics-out")).unwrap_err().to_string();
        assert!(e.contains("--metrics-out"), "valueless: knob not named: {e}");

        assert_eq!(metrics_every(&args("simulate")).unwrap(), 1, "absent = every step");
        assert_eq!(metrics_every(&args("simulate --metrics-every 100")).unwrap(), 100);
        for bad in ["0", "abc", "-2"] {
            let e = metrics_every(&args(&format!("simulate --metrics-every {bad}")))
                .unwrap_err()
                .to_string();
            assert!(e.contains("--metrics-every"), "'{bad}': knob not named: {e}");
        }
        let e = metrics_every(&args("simulate --metrics-every --record-theta"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--metrics-every"), "valueless: knob not named: {e}");
    }

    #[test]
    fn metrics_env_mirror_validates_values() {
        // Value validation only — the absent-variable default is covered
        // by the knob test above (reading the live process env here
        // would race other tests).
        assert_eq!(metrics_value("DECAFORK_METRICS", "jsonl").unwrap(), MetricsMode::Jsonl);
        assert_eq!(metrics_value("DECAFORK_METRICS", " csv ").unwrap(), MetricsMode::Csv);
        assert_eq!(metrics_value("DECAFORK_METRICS", "off").unwrap(), MetricsMode::Off);
        let e = metrics_value("DECAFORK_METRICS", "yaml").unwrap_err().to_string();
        assert!(e.contains("DECAFORK_METRICS"), "env var not named: {e}");
    }

    #[test]
    fn cores_flag_builds_a_budget() {
        assert_eq!(cores(&args("simulate --cores 6")).unwrap().total(), 6);
        assert!(cores(&args("simulate --cores 0")).is_err());
        assert!(cores(&args("simulate --cores many")).is_err());
        // No flag: env/detected fallback must still produce >= 1 core.
        assert!(cores(&args("simulate")).unwrap().total() >= 1);
    }
}
