//! The scenario layer: one place where `config → (graph, control,
//! failures, params) → engine` wiring lives.
//!
//! Before this layer existed the same five steps — derive per-run RNG
//! streams, build the graph, instantiate control, instantiate failures,
//! assemble an engine — were duplicated across `main.rs`, `figures.rs`,
//! the integration tests and every bench. A [`Scenario`] is the single
//! pure-data description of an experiment; it can be turned into
//!
//! * an arena [`Engine`] (`engine(run)`) — the production hot path with
//!   enum-dispatched control/failures, and
//! * a [`ReferenceEngine`] (`reference_engine(run)`) — the frozen seed
//!   engine used as the determinism oracle and perf baseline,
//!
//! both fed from **identical** per-run RNG streams, which is what makes
//! the golden-trace equivalence tests (`tests/golden_traces.rs`) and the
//! `perf_engine` before/after comparison meaningful.
//!
//! Dataflow (DESIGN.md §Scenario layer has the diagram):
//!
//! ```text
//! Scenario { graph, params, control, failures, horizon, runs, seed }
//!    │  rngs(run): root=Rng(seed); grng=root.split("grap").split(run)
//!    │             srng=root.split("simu").split(run)
//!    ├─ graph.build(grng)          → Arc<Graph>
//!    ├─ control.build_control(n)   → Control   (enum, inlined)   ─┐
//!    ├─ failures.build_failures()  → Failures  (enum, inlined)   ─┤→ Engine
//!    └─ control.build(n)/failures.build() → Box<dyn …> → ReferenceEngine
//! ```

pub mod parse;
pub mod presets;
mod spec;

pub use spec::{ControlSpec, FailureSpec, GraphSpec};

use std::sync::Arc;

use crate::graph::Graph;
use crate::rng::Rng;
use crate::runtime::pool::WorkerPool;
use crate::sim::engine::{Engine, SimParams};
use crate::sim::reference::ReferenceEngine;
use crate::sim::sharded::{DispatchMode, ShardedEngine};

/// A complete experiment: graph + engine params + control + failures +
/// replication. (The historical name `ExperimentConfig` is kept as an
/// alias in `crate::sim::config`.)
#[derive(Debug, Clone)]
pub struct Scenario {
    pub graph: GraphSpec,
    pub params: SimParams,
    pub control: ControlSpec,
    pub failures: FailureSpec,
    pub horizon: u64,
    pub runs: usize,
    pub seed: u64,
}

impl Scenario {
    /// Paper Fig. 1 base setup (per-algorithm variants set `control`).
    pub fn fig1_base() -> Self {
        presets::fig1_base(50)
    }

    /// One-line description for logs and reports.
    pub fn label(&self) -> String {
        format!("{} on {}", self.control.label(), self.graph.label())
    }

    /// Per-run RNG streams: (graph stream, simulation stream). The
    /// derivation is frozen — golden traces and every recorded experiment
    /// depend on it. The paper regenerates graphs per simulation, so the
    /// graph stream is split per run too.
    fn rngs(&self, run: usize) -> (Rng, Rng) {
        let root = Rng::new(self.seed);
        let grng = root.split(0x67726170).split(run as u64); // "grap"
        let srng = root.split(0x73696d75).split(run as u64); // "simu"
        (grng, srng)
    }

    /// Build the run's graph (deterministic in `seed` + `run`).
    pub fn build_graph(&self, run: usize) -> anyhow::Result<Arc<Graph>> {
        let (mut grng, _) = self.rngs(run);
        Ok(Arc::new(self.graph.build(&mut grng)?))
    }

    /// Per-run engine params: with metrics on, replications after the
    /// first stream to `<out>.run<k>` so parallel runs never clobber
    /// one sink file (run 0 keeps the configured path — the `--runs 1`
    /// common case writes exactly where the user asked).
    fn run_params(&self, run: usize) -> SimParams {
        let mut params = self.params.clone();
        if params.metrics.enabled() && run > 0 {
            params.metrics.out = Some(format!("{}.run{run}", params.metrics.out_path()));
        }
        params
    }

    /// Build the arena engine for run index `run`.
    pub fn engine(&self, run: usize) -> anyhow::Result<Engine> {
        let (mut grng, srng) = self.rngs(run);
        let graph = Arc::new(self.graph.build(&mut grng)?);
        let control = self.control.build_control(graph.n());
        let failures = self.failures.build_failures();
        Ok(Engine::new(graph, self.run_params(run), control, failures, srng))
    }

    /// Historical name for [`engine`](Self::engine).
    pub fn build_engine(&self, run: usize) -> anyhow::Result<Engine> {
        self.engine(run)
    }

    /// Build the stream-mode sharded engine for run `run` with `shards`
    /// worker threads — identical graph and base RNG stream as
    /// [`engine`](Self::engine), but randomness ownership is per-walk /
    /// per-node (the engine derives its sub-streams from `srng`), so the
    /// trace is a *different, schedule-invariant* sample of the same
    /// system: bit-identical at every `shards >= 1`, not comparable to
    /// the shared-stream engines. The worker count is an explicit
    /// argument (not read from `params.shards`) so benches and the
    /// invariance tests can run one scenario at several counts.
    pub fn sharded_engine(&self, run: usize, shards: usize) -> anyhow::Result<ShardedEngine> {
        self.sharded_engine_dispatch(run, shards, DispatchMode::Pooled)
    }

    /// [`sharded_engine`](Self::sharded_engine) with an explicit
    /// [`DispatchMode`] — `Scoped` is the measured baseline of
    /// `benches/perf_pool.rs`; traces are identical in both modes.
    pub fn sharded_engine_dispatch(
        &self,
        run: usize,
        shards: usize,
        dispatch: DispatchMode,
    ) -> anyhow::Result<ShardedEngine> {
        let (mut grng, srng) = self.rngs(run);
        // Spawn the engine's worker pool first and lend it to graph
        // construction, so families with a parallel build path
        // (`random_regular` at preset scale) assemble their CSR on the
        // same threads the run will step on. Graph bytes and RNG
        // consumption are pool-invariant, so this changes build *time*
        // only — never the trace. Pinning (if requested) is applied at
        // spawn so graph build, store construction and every stepping
        // phase all land on the bound cores; the engine adopts the pool
        // only when its pinning matches `params.pin_cores`.
        let mut pool = match dispatch {
            DispatchMode::Pooled if shards > 1 => {
                Some(WorkerPool::new_pinned(shards - 1, self.params.pin_cores))
            }
            _ => None,
        };
        let graph = Arc::new(self.graph.build_pooled(&mut grng, pool.as_mut())?);
        let control = self.control.build_control(graph.n());
        let failures = self.failures.build_failures();
        Ok(ShardedEngine::with_pool(
            graph,
            self.run_params(run),
            control,
            failures,
            srng,
            shards,
            dispatch,
            pool,
        ))
    }

    /// Build the frozen seed engine for the same run — identical graph
    /// and RNG streams, boxed dispatch, O(history) stepping. Determinism
    /// oracle and perf baseline only.
    pub fn reference_engine(&self, run: usize) -> anyhow::Result<ReferenceEngine> {
        let (mut grng, srng) = self.rngs(run);
        let graph = Arc::new(self.graph.build(&mut grng)?);
        let control = self.control.build(graph.n());
        let failures = self.failures.build();
        Ok(ReferenceEngine::new(graph, self.params.clone(), control, failures, srng))
    }

    /// Shrink (or stretch) the experiment to `steps` keeping its shape:
    /// the horizon, the control warm-up and every burst time scale by
    /// the same factor (floored at 1 so t=0 bursts — which never fire,
    /// the engine starts at t=1 — cannot appear). Continuous failure
    /// rates are left alone: they are per-step quantities. One shared
    /// implementation for every bench's `DECAFORK_PERF_STEPS` quick
    /// mode, so smoke runs exercise the same scenario shape everywhere.
    pub fn rescale_to(&mut self, steps: u64) {
        let old = self.horizon;
        if steps == old || old == 0 {
            return;
        }
        let scale = move |t: u64| ((t as u128 * steps as u128) / old as u128).max(1) as u64;
        fn walk(f: &mut FailureSpec, scale: &dyn Fn(u64) -> u64) {
            match f {
                FailureSpec::Burst { events } => {
                    for e in events.iter_mut() {
                        e.0 = scale(e.0);
                    }
                }
                FailureSpec::ByzantineScheduled { schedule, .. } => {
                    for s in schedule.iter_mut() {
                        s.0 = scale(s.0);
                    }
                }
                FailureSpec::Composite(parts) => {
                    for p in parts.iter_mut() {
                        walk(p, scale);
                    }
                }
                _ => {}
            }
        }
        walk(&mut self.failures, &scale);
        if let Some(cs) = self.params.control_start {
            self.params.control_start = Some(scale(cs));
        }
        self.horizon = steps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_deterministic() {
        let mut cfg = presets::fig1_base(1);
        cfg.graph = GraphSpec::RandomRegular { n: 30, d: 4 };
        cfg.horizon = 300;
        let z1 = {
            let mut e = cfg.engine(0).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        let z2 = {
            let mut e = cfg.engine(0).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        assert_eq!(z1, z2);
        let z3 = {
            let mut e = cfg.engine(1).unwrap();
            e.run_to(300);
            e.into_trace().z
        };
        assert_ne!(z1, z3);
    }

    #[test]
    fn run_params_disambiguates_metrics_paths_per_run() {
        use crate::obs::{MetricsConfig, MetricsMode};
        let mut cfg = presets::fig1_base(1);
        // Metrics off: every run keeps identical params.
        assert_eq!(cfg.run_params(3).metrics.out, None);
        cfg.params.metrics = MetricsConfig {
            mode: MetricsMode::Jsonl,
            out: Some("m.jsonl".into()),
            every: 1,
        };
        assert_eq!(cfg.run_params(0).metrics.out.as_deref(), Some("m.jsonl"));
        assert_eq!(cfg.run_params(2).metrics.out.as_deref(), Some("m.jsonl.run2"));
        // The default path gets the same treatment.
        cfg.params.metrics.out = None;
        assert_eq!(cfg.run_params(1).metrics.out.as_deref(), Some("metrics.jsonl.run1"));
    }

    #[test]
    fn rescale_keeps_shape() {
        let mut s = presets::perf_control_geometric();
        s.rescale_to(1000);
        assert_eq!(s.horizon, 1000);
        assert_eq!(s.params.control_start, Some(100)); // 500 · 1000/5000
        match &s.failures {
            FailureSpec::Composite(parts) => match &parts[0] {
                FailureSpec::Burst { events } => {
                    assert_eq!(events.as_slice(), &[(300, 26), (550, 26), (800, 25)]);
                }
                other => panic!("expected burst, got {other:?}"),
            },
            other => panic!("expected composite, got {other:?}"),
        }
        // Identity rescale is a no-op.
        let before = format!("{:?}", s.failures);
        s.rescale_to(1000);
        assert_eq!(format!("{:?}", s.failures), before);
    }

    #[test]
    fn sharded_engine_invariant_and_shares_graph_stream() {
        let mut cfg = presets::fig1_base(1);
        cfg.graph = GraphSpec::RandomRegular { n: 24, d: 4 };
        cfg.horizon = 200;
        cfg.params.record_theta = true;
        let run = |shards: usize| {
            let mut e = cfg.sharded_engine(0, shards).unwrap();
            e.run_to(200);
            e.into_trace()
        };
        let base = run(1);
        assert!(base.bit_identical(&run(4)), "stream-mode trace depends on worker count");
        // Same per-run graph stream as the sequential engines.
        let seq = cfg.engine(0).unwrap();
        let sh = cfg.sharded_engine(0, 2).unwrap();
        for i in 0..24 {
            assert_eq!(seq.graph.neighbors(i), sh.graph.neighbors(i));
        }
    }

    #[test]
    fn sharded_engine_invariant_on_implicit_topology() {
        // The stream-mode engine never materializes the graph: hop and
        // control phases derive neighbors on demand through the same
        // `Graph` API, and shard invariance must hold there too.
        let mut cfg = presets::fig1_base(1);
        cfg.graph = GraphSpec::ImplicitSmallWorld { n: 300, d: 8 };
        cfg.horizon = 150;
        cfg.params.record_theta = true;
        let run = |shards: usize| {
            let mut e = cfg.sharded_engine(0, shards).unwrap();
            assert!(e.graph.is_implicit());
            e.run_to(150);
            e.into_trace()
        };
        let base = run(1);
        assert!(base.bit_identical(&run(4)), "implicit-backend trace depends on worker count");
    }

    #[test]
    fn engine_and_reference_share_graph_stream() {
        let mut cfg = presets::fig1_base(1);
        cfg.graph = GraphSpec::RandomRegular { n: 24, d: 4 };
        let a = cfg.engine(3).unwrap();
        let b = cfg.reference_engine(3).unwrap();
        for i in 0..24 {
            assert_eq!(a.graph.neighbors(i), b.graph.neighbors(i));
        }
    }
}
