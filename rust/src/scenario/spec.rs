//! Pure-data specs for the graph, control algorithm and failure model.
//! A spec is a value: cheap to clone, comparable, buildable any number of
//! times from a seed. Each spec builds both the enum-dispatched form the
//! arena engine inlines (`build_control` / `build_failures`) and the
//! boxed-trait form the frozen reference engine consumes (`build`).

use crate::control::{
    Control, ControlAlgorithm, Decafork, DecaforkPlus, MissingPerson, NoControl, PeriodicFork,
};
use crate::failures::{
    Burst, Byzantine, Composite, FailureModel, Failures, NoFailures, Probabilistic,
};
use crate::graph::{generators, Graph};
use crate::rng::Rng;
use crate::runtime::pool::WorkerPool;

/// Which graph to build.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSpec {
    RandomRegular { n: usize, d: usize },
    ErdosRenyi { n: usize, p: f64 },
    Complete { n: usize },
    PowerLaw { n: usize, m: usize },
    Ring { n: usize },
    Torus { w: usize, h: usize },
    /// d-regular circulant ring lattice on the implicit backend (zero
    /// stored edges — the 10⁷⁺-node families).
    ImplicitRegular { n: usize, d: usize },
    /// Degree-preserving small world on the implicit backend.
    ImplicitSmallWorld { n: usize, d: usize },
}

impl GraphSpec {
    pub fn build(&self, rng: &mut Rng) -> anyhow::Result<Graph> {
        self.build_pooled(rng, None)
    }

    /// [`build`](Self::build) with an optional worker pool: families
    /// with a parallel construction path (currently `RandomRegular`)
    /// use it for CSR assembly and the connectivity check; all others
    /// ignore it. Graph-RNG consumption and the built graph are
    /// identical with or without a pool.
    pub fn build_pooled(
        &self,
        rng: &mut Rng,
        pool: Option<&mut WorkerPool>,
    ) -> anyhow::Result<Graph> {
        match *self {
            GraphSpec::RandomRegular { n, d } => match pool {
                Some(pool) => generators::random_regular_pooled(n, d, rng, pool),
                None => generators::random_regular(n, d, rng),
            },
            GraphSpec::ErdosRenyi { n, p } => generators::erdos_renyi(n, p, rng),
            GraphSpec::Complete { n } => Ok(generators::complete(n)),
            GraphSpec::PowerLaw { n, m } => generators::barabasi_albert(n, m, rng),
            GraphSpec::Ring { n } => Ok(generators::ring(n)),
            GraphSpec::Torus { w, h } => Ok(generators::grid_torus(w, h)),
            GraphSpec::ImplicitRegular { n, d } => generators::implicit_ring(n, d),
            GraphSpec::ImplicitSmallWorld { n, d } => generators::implicit_small_world(n, d, rng),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            GraphSpec::RandomRegular { n, d } => format!("{d}-regular(n={n})"),
            GraphSpec::ErdosRenyi { n, p } => format!("ER(n={n},p={p})"),
            GraphSpec::Complete { n } => format!("complete(n={n})"),
            GraphSpec::PowerLaw { n, m } => format!("power-law(n={n},m={m})"),
            GraphSpec::Ring { n } => format!("ring(n={n})"),
            GraphSpec::Torus { w, h } => format!("torus({w}x{h})"),
            GraphSpec::ImplicitRegular { n, d } => format!("implicit-{d}-ring(n={n})"),
            GraphSpec::ImplicitSmallWorld { n, d } => format!("implicit-smallworld(n={n},d={d})"),
        }
    }

    /// Node count of the graph this spec builds (without building it).
    pub fn nodes(&self) -> usize {
        match *self {
            GraphSpec::RandomRegular { n, .. }
            | GraphSpec::ErdosRenyi { n, .. }
            | GraphSpec::Complete { n }
            | GraphSpec::PowerLaw { n, .. }
            | GraphSpec::Ring { n }
            | GraphSpec::ImplicitRegular { n, .. }
            | GraphSpec::ImplicitSmallWorld { n, .. } => n,
            GraphSpec::Torus { w, h } => w * h,
        }
    }
}

/// Which control algorithm to run.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlSpec {
    None,
    Periodic { period: u64 },
    MissingPerson { eps_mp: u64 },
    Decafork { epsilon: f64 },
    DecaforkPlus { epsilon: f64, epsilon2: f64 },
}

impl ControlSpec {
    /// Enum-dispatched form for the arena engine.
    pub fn build_control(&self, n_nodes: usize) -> Control {
        match *self {
            ControlSpec::None => NoControl.into(),
            ControlSpec::Periodic { period } => PeriodicFork::new(n_nodes, period).into(),
            ControlSpec::MissingPerson { eps_mp } => MissingPerson::new(eps_mp).into(),
            ControlSpec::Decafork { epsilon } => Decafork::new(epsilon).into(),
            ControlSpec::DecaforkPlus { epsilon, epsilon2 } => {
                DecaforkPlus::new(epsilon, epsilon2).into()
            }
        }
    }

    /// Boxed-trait form (reference engine, open extensions).
    pub fn build(&self, n_nodes: usize) -> Box<dyn ControlAlgorithm> {
        match *self {
            ControlSpec::None => Box::new(NoControl),
            ControlSpec::Periodic { period } => Box::new(PeriodicFork::new(n_nodes, period)),
            ControlSpec::MissingPerson { eps_mp } => Box::new(MissingPerson::new(eps_mp)),
            ControlSpec::Decafork { epsilon } => Box::new(Decafork::new(epsilon)),
            ControlSpec::DecaforkPlus { epsilon, epsilon2 } => {
                Box::new(DecaforkPlus::new(epsilon, epsilon2))
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            ControlSpec::None => "none".into(),
            ControlSpec::Periodic { period } => format!("periodic(T={period})"),
            ControlSpec::MissingPerson { eps_mp } => format!("missingperson(eps={eps_mp})"),
            ControlSpec::Decafork { epsilon } => format!("decafork(eps={epsilon})"),
            ControlSpec::DecaforkPlus { epsilon, epsilon2 } => {
                format!("decafork+(eps={epsilon},eps2={epsilon2})")
            }
        }
    }
}

/// Which failure model to inject.
#[derive(Debug, Clone, PartialEq)]
pub enum FailureSpec {
    None,
    Burst { events: Vec<(u64, usize)> },
    Probabilistic { p_f: f64 },
    ByzantineScheduled { node: u32, schedule: Vec<(u64, bool)> },
    ByzantineMarkov { node: u32, p_b: f64 },
    Composite(Vec<FailureSpec>),
}

impl FailureSpec {
    /// Enum-dispatched form for the arena engine.
    pub fn build_failures(&self) -> Failures {
        match self {
            FailureSpec::None => NoFailures.into(),
            FailureSpec::Burst { events } => Burst::new(events.clone()).into(),
            FailureSpec::Probabilistic { p_f } => Probabilistic::new(*p_f).into(),
            FailureSpec::ByzantineScheduled { node, schedule } => {
                Byzantine::scheduled(*node, schedule.clone()).into()
            }
            FailureSpec::ByzantineMarkov { node, p_b } => {
                Byzantine::markov(*node, *p_b, false).into()
            }
            FailureSpec::Composite(parts) => {
                Failures::composite(parts.iter().map(|p| p.build_failures()).collect())
            }
        }
    }

    /// Boxed-trait form (reference engine, open extensions).
    pub fn build(&self) -> Box<dyn FailureModel> {
        match self {
            FailureSpec::None => Box::new(NoFailures),
            FailureSpec::Burst { events } => Box::new(Burst::new(events.clone())),
            FailureSpec::Probabilistic { p_f } => Box::new(Probabilistic::new(*p_f)),
            FailureSpec::ByzantineScheduled { node, schedule } => {
                Box::new(Byzantine::scheduled(*node, schedule.clone()))
            }
            FailureSpec::ByzantineMarkov { node, p_b } => {
                Box::new(Byzantine::markov(*node, *p_b, false))
            }
            FailureSpec::Composite(parts) => {
                Box::new(Composite::new(parts.iter().map(|p| p.build()).collect()))
            }
        }
    }

    /// The paper's Fig. 1 bursts.
    pub fn paper_bursts() -> Self {
        FailureSpec::Burst { events: vec![(2000, 5), (6000, 6)] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build() {
        let mut rng = Rng::new(1);
        for spec in [
            GraphSpec::RandomRegular { n: 20, d: 4 },
            GraphSpec::Complete { n: 10 },
            GraphSpec::Ring { n: 12 },
            GraphSpec::Torus { w: 4, h: 4 },
            GraphSpec::ErdosRenyi { n: 30, p: 0.3 },
            GraphSpec::PowerLaw { n: 30, m: 3 },
            GraphSpec::ImplicitRegular { n: 40, d: 8 },
            GraphSpec::ImplicitSmallWorld { n: 40, d: 8 },
        ] {
            let g = spec.build(&mut rng).unwrap();
            assert!(g.is_connected(), "{}", spec.label());
            assert_eq!(g.n(), spec.nodes(), "{}", spec.label());
        }
    }

    #[test]
    fn implicit_specs_use_implicit_backend() {
        let mut rng = Rng::new(2);
        for spec in [
            GraphSpec::ImplicitRegular { n: 100, d: 8 },
            GraphSpec::ImplicitSmallWorld { n: 100, d: 8 },
        ] {
            let g = spec.build(&mut rng).unwrap();
            assert!(g.is_implicit(), "{}", spec.label());
            assert!((0..100).all(|i| g.degree(i) == 8), "{}", spec.label());
        }
        assert_eq!(GraphSpec::ImplicitRegular { n: 100, d: 8 }.label(), "implicit-8-ring(n=100)");
    }

    #[test]
    fn build_pooled_matches_build() {
        // Same RNG stream, same graph, pool or not.
        let spec = GraphSpec::RandomRegular { n: 60, d: 6 };
        let a = spec.build(&mut Rng::new(7)).unwrap();
        let mut pool = WorkerPool::new(2);
        let b = spec.build_pooled(&mut Rng::new(7), Some(&mut pool)).unwrap();
        for i in 0..60 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }

    #[test]
    fn control_specs_build_both_forms() {
        for spec in [
            ControlSpec::None,
            ControlSpec::Periodic { period: 10 },
            ControlSpec::MissingPerson { eps_mp: 100 },
            ControlSpec::Decafork { epsilon: 2.0 },
            ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 },
        ] {
            let boxed = spec.build(16);
            let enumed = spec.build_control(16);
            assert_eq!(boxed.name(), enumed.name());
            assert!(!spec.label().is_empty());
        }
    }

    #[test]
    fn failure_specs_build_both_forms() {
        for spec in [
            FailureSpec::None,
            FailureSpec::paper_bursts(),
            FailureSpec::Probabilistic { p_f: 0.01 },
            FailureSpec::ByzantineScheduled { node: 1, schedule: vec![(5, true)] },
            FailureSpec::ByzantineMarkov { node: 0, p_b: 0.1 },
            FailureSpec::Composite(vec![
                FailureSpec::paper_bursts(),
                FailureSpec::Probabilistic { p_f: 0.001 },
            ]),
        ] {
            let boxed = spec.build();
            let enumed = spec.build_failures();
            assert_eq!(boxed.name(), enumed.name());
        }
    }
}
