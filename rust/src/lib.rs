//! # decafork — self-regulating random walks for resilient decentralized learning
//!
//! Reproduction of Egger, Bitar, Ayache, Wachter-Zeh, El Rouayheb,
//! *"Self-Regulating Random Walks for Resilient Decentralized Learning on
//! Graphs"* (2024). The crate implements the full stack the paper
//! describes:
//!
//! * graph substrates (random regular, Erdős–Rényi, complete, power-law, …),
//! * multi-random-walk simulation on a struct-of-arrays walk arena with
//!   generational ids and arbitrary failure models, described by the
//!   unified scenario layer (`scenario::Scenario`),
//! * the decentralized control algorithms MISSINGPERSON (baseline),
//!   DECAFORK and DECAFORK+,
//! * the paper's full theoretical toolbox (Irwin–Hall threshold design,
//!   Lemma 1 estimator CDF, reaction-time and overshoot bounds),
//! * the motivating application: decentralized learning where the walk
//!   token carries a model that is updated at every visited node via an
//!   AOT-compiled JAX/Pallas computation executed through PJRT, and
//! * a thread-per-node decentralized runtime (no central coordinator)
//!   that runs the same control algorithms over real message channels.
//!
//! Layer map (see `DESIGN.md`): L3 = this crate; L2 = `python/compile/model.py`
//! (JAX transformer fwd/bwd); L1 = `python/compile/kernels/*.py` (Pallas).
//! Python only ever runs at build time (`make artifacts`).

pub mod rng;
pub mod graph;
pub mod stats;
pub mod walks;
pub mod control;
pub mod failures;
pub mod obs;
pub mod scenario;
pub mod sim;
pub mod theory;
pub mod runtime;
pub mod learning;
pub mod coordinator;
pub mod cli;
pub mod figures;
pub mod report;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
