//! Structural graph properties used for threshold design, analytic
//! survival functions and experiment reporting.

use super::Graph;
use crate::rng::Rng;

/// Summary statistics of the degree sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub std: f64,
}

/// Compute degree statistics.
pub fn degree_stats(g: &Graph) -> DegreeStats {
    let n = g.n();
    let degs: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
    let mean = degs.iter().sum::<usize>() as f64 / n as f64;
    let var = degs.iter().map(|&d| (d as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    DegreeStats {
        min: *degs.iter().min().unwrap(),
        max: *degs.iter().max().unwrap(),
        mean,
        std: var.sqrt(),
    }
}

/// Exact diameter via BFS from every node. O(n·m) — fine at the paper's
/// scales (n ≤ a few hundred).
pub fn diameter(g: &Graph) -> usize {
    (0..g.n())
        .map(|s| {
            g.bfs_distances(s)
                .into_iter()
                .filter(|&d| d != usize::MAX)
                .max()
                .unwrap_or(0)
        })
        .max()
        .unwrap_or(0)
}

/// Estimate the geometric tail parameter `q` of the return-time
/// distribution at node `i` by simulating `samples` returns. For random
/// regular graphs, Tishby–Biham–Katzav (2021) show the return time is
/// approximately geometric in its tail; `q ≈ 1 / E[R_i] = π_i`.
pub fn fit_return_q(g: &Graph, i: usize, samples: usize, rng: &mut Rng) -> f64 {
    let mut pos = i;
    let mut collected = 0usize;
    let mut total = 0u64;
    let mut t = 0u64;
    let mut last = 0u64;
    while collected < samples {
        pos = g.step(pos, rng);
        t += 1;
        if pos == i {
            total += t - last;
            last = t;
            collected += 1;
        }
        // Safety valve: abort pathological runs (disconnected misuse).
        if t > (samples as u64 + 1) * 1_000_000 {
            break;
        }
    }
    if collected == 0 {
        return g.stationary(i);
    }
    collected as f64 / total as f64
}

/// Expected cover time heuristic `n ln n / λ` proxy: an upper-bound style
/// estimate of how long the initialization phase should last so that every
/// walk has visited every node at least once (paper Sec. II requires this
/// before the first failure). We use the Matthews-style bound
/// `t_cov ≤ max_i E[H_i] · H_n` with `E[H_i] ≤ 2|E| · diam` replaced by the
/// cheaper empirical proxy below: simulate one walk until full coverage.
pub fn empirical_cover_time(g: &Graph, start: usize, rng: &mut Rng) -> u64 {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut remaining = n - 1;
    seen[start] = true;
    let mut pos = start;
    let mut t = 0u64;
    while remaining > 0 {
        pos = g.step(pos, rng);
        t += 1;
        if !seen[pos] {
            seen[pos] = true;
            remaining -= 1;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn degree_stats_regular() {
        let mut rng = Rng::new(1);
        let g = generators::random_regular(50, 8, &mut rng).unwrap();
        let s = degree_stats(&g);
        assert_eq!(s.min, 8);
        assert_eq!(s.max, 8);
        assert!((s.mean - 8.0).abs() < 1e-12);
        assert!(s.std < 1e-12);
    }

    #[test]
    fn diameter_ring() {
        let g = generators::ring(10);
        assert_eq!(diameter(&g), 5);
    }

    #[test]
    fn fit_return_q_matches_stationary() {
        let mut rng = Rng::new(2);
        let g = generators::random_regular(50, 8, &mut rng).unwrap();
        let q = fit_return_q(&g, 0, 4000, &mut rng);
        // q should be ~ π_0 = 1/50 = 0.02 for a regular graph.
        assert!((q - 0.02).abs() < 0.004, "q = {q}");
    }

    #[test]
    fn cover_time_reasonable() {
        let mut rng = Rng::new(3);
        let g = generators::random_regular(50, 8, &mut rng).unwrap();
        let t = empirical_cover_time(&g, 0, &mut rng);
        // Coupon-collector scale: n ln n ≈ 196. Allow wide slack.
        assert!(t > 50 && t < 20_000, "cover time {t}");
    }
}
