//! Parallel CSR construction and connectivity checking on the
//! [`WorkerPool`] (DESIGN.md §Topology backends).
//!
//! `Graph::from_edges` is a validating, single-threaded entry point —
//! right for untrusted edge lists, wrong as the hot path under
//! generator output at 10⁶⁺ nodes, where assembly (degree count,
//! scatter, per-node sort) dominates graph build time. The chunked
//! builder here produces **byte-identical** CSR at any worker count:
//!
//! 1. *degree histograms* — each edge chunk counts into its own row of
//!    a chunk-major `c × n` matrix (disjoint `&mut` rows, no atomics);
//! 2. *prefix sums* — per node, the chunk rows are folded into the
//!    global degree while each row cell becomes that chunk's exclusive
//!    write base within the node's adjacency block (parallel over node
//!    ranges), then one sequential scan turns degrees into offsets;
//! 3. *scatter* — chunk `c` writes edge endpoints at
//!    `offsets[i] + base(c, i) + k`, windows disjoint per
//!    `(node, chunk)`, so the only unsafe is a shared raw pointer with
//!    a disjointness argument (the same lifetime-erasure trade the pool
//!    itself makes) and the pre-sort layout equals the sequential
//!    builder's edge-order layout exactly;
//! 4. *per-node sort + Lemire thresholds* — contiguous node ranges own
//!    contiguous `adj` spans, so this phase is safe `split_at_mut`
//!    parallelism.
//!
//! The equality with `Graph::from_edges` output is locked by
//! `tests/graph_backend.rs` at several worker counts.
//!
//! [`is_connected_parallel`] is a level-synchronous BFS: an atomic
//! visited bitmap (`fetch_or` claims each node exactly once) and
//! per-lane next-frontier buffers merged at the level barrier. Which
//! lane claims a node is scheduling-dependent, but the *set* of nodes
//! claimed per level is the distance-≤ level ball — so the boolean (and
//! the visit count behind it) is deterministic.
//!
//! Both entry points fall back to the sequential path below
//! [`PARALLEL_MIN_EDGES`] / [`PARALLEL_MIN_NODES`] — the outputs are
//! identical either way, so the switch is invisible to callers.

use super::{Csr, Graph};
use crate::runtime::pool::{Task, WorkerPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Below this many edges the chunked builder's extra passes cost more
/// than they parallelize away; `from_edges_parallel` runs the
/// sequential trusted path instead (same output bytes).
pub const PARALLEL_MIN_EDGES: usize = 1 << 16;

/// Sequential-BFS fallback bound for [`is_connected_parallel`].
pub const PARALLEL_MIN_NODES: usize = 1 << 15;

/// Dispatch a uniform closure set on the pool (first entry runs on the
/// calling thread) — the builder-side twin of the sharded engine's
/// `fan_out_slice` (this one predates the generic `run_slice` path and
/// keeps the `dyn`-erased dispatch; the work is identical either way).
fn run_tasks<F: FnMut() + Send>(pool: &mut WorkerPool, fs: &mut [F]) {
    let mut tasks: Vec<Task<'_>> = fs.iter_mut().map(|f| f as Task<'_>).collect();
    pool.run(&mut tasks);
}

/// Shared-mutable cell view for pool tasks writing provably disjoint
/// index sets (the scatter windows / histogram columns documented at
/// each use). Copyable so `move` closures can capture it.
#[derive(Clone, Copy)]
struct RawCells<T>(*mut T);

// SAFETY: dereferenced only inside pool dispatches whose tasks write
// disjoint indices, with the pool's barrier ordering reads after
// writes.
unsafe impl<T: Send> Send for RawCells<T> {}
unsafe impl<T: Send> Sync for RawCells<T> {}

/// Chunked, pool-parallel [`Graph::from_edges_trusted`]: byte-identical
/// output, `workers + 1` lanes. Trusted-input contract (and its
/// debug-build validation) is inherited from the sequential trusted
/// path.
pub fn from_edges_parallel(n: usize, edges: &[(u32, u32)], pool: &mut WorkerPool) -> Graph {
    if pool.workers() == 0 || edges.len() < PARALLEL_MIN_EDGES {
        return Graph::from_edges_trusted(n, edges);
    }
    #[cfg(debug_assertions)]
    Graph::debug_validate_simple(n, edges);
    Graph::from_csr(assemble_parallel(n, edges, pool))
}

fn assemble_parallel(n: usize, edges: &[(u32, u32)], pool: &mut WorkerPool) -> Csr {
    let lanes = pool.workers() + 1;
    let chunk_len = edges.len().div_ceil(lanes);
    let chunks: Vec<&[(u32, u32)]> = edges.chunks(chunk_len).collect();
    let c = chunks.len();

    // Phase 1: per-chunk degree histograms, chunk-major (row `ch` =
    // `counts[ch*n..][..n]`). The c·n·4-byte matrix is the price of an
    // atomic-free deterministic scatter; at 8 lanes × 10⁶ nodes that is
    // 32 MB of transient build scratch against a 48 MB resident CSR.
    let mut counts = vec![0u32; c * n];
    {
        let mut fs: Vec<_> = counts
            .chunks_mut(n)
            .zip(&chunks)
            .map(|(cnt, &ch)| {
                move || {
                    for &(a, b) in ch {
                        cnt[a as usize] += 1;
                        cnt[b as usize] += 1;
                    }
                }
            })
            .collect();
        run_tasks(pool, &mut fs);
    }

    // Phase 2: fold histogram columns into global degrees while turning
    // each cell into its chunk's exclusive write base inside the node's
    // block — chunk-major bases are what make the scatter reproduce the
    // sequential builder's edge-order layout. Parallel over node
    // ranges: tasks own disjoint columns of every row.
    let node_chunk = n.div_ceil(lanes).max(1);
    let mut deg = vec![0u32; n];
    {
        let counts_cells = RawCells(counts.as_mut_ptr());
        let mut fs: Vec<_> = deg
            .chunks_mut(node_chunk)
            .enumerate()
            .map(|(r, dchunk)| {
                let lo = r * node_chunk;
                move || {
                    for (off, d) in dchunk.iter_mut().enumerate() {
                        let i = lo + off;
                        let mut acc = 0u32;
                        for ch in 0..c {
                            // SAFETY: column `i` is touched by this
                            // range task only; the dispatch barrier
                            // ordered phase 1's writes before these.
                            let cell = unsafe { &mut *counts_cells.0.add(ch * n + i) };
                            let t = *cell;
                            *cell = acc;
                            acc += t;
                        }
                        *d = acc;
                    }
                }
            })
            .collect();
        run_tasks(pool, &mut fs);
    }

    // Offsets: one sequential exclusive scan — memory-bound `n` adds,
    // noise next to the phases around it even at 10⁸ nodes.
    let mut offsets = Vec::with_capacity(n + 1);
    let mut acc = 0usize;
    offsets.push(0);
    for &d in &deg {
        acc += d as usize;
        offsets.push(acc);
    }
    debug_assert_eq!(acc, 2 * edges.len());

    // Phase 3: scatter. Chunk `ch`'s cursor for node `i` is its own
    // (task-local) histogram cell, so cursor bumps need no
    // synchronization; the windows `offsets[i] + base .. + base + cnt`
    // are disjoint per (node, chunk).
    let mut adj = vec![0u32; 2 * edges.len()];
    {
        let adj_cells = RawCells(adj.as_mut_ptr());
        let offsets_ref = &offsets;
        let mut fs: Vec<_> = counts
            .chunks_mut(n)
            .zip(&chunks)
            .map(|(cur, &ch)| {
                move || {
                    for &(a, b) in ch {
                        let (a, b) = (a as usize, b as usize);
                        // SAFETY: disjoint per-(node, chunk) windows —
                        // see the phase comment.
                        unsafe {
                            *adj_cells.0.add(offsets_ref[a] + cur[a] as usize) = b as u32;
                            cur[a] += 1;
                            *adj_cells.0.add(offsets_ref[b] + cur[b] as usize) = a as u32;
                            cur[b] += 1;
                        }
                    }
                }
            })
            .collect();
        run_tasks(pool, &mut fs);
    }

    // Phase 4: per-node adjacency sort + Lemire thresholds. Contiguous
    // node ranges own contiguous `adj` spans, so plain `split_at_mut`
    // partitions suffice (and `sort_unstable` on duplicate-free u32
    // spans has a unique result — layout differences before the sort
    // could not leak through even if phase 3 had any).
    let mut step_threshold = vec![0u64; n];
    {
        let ranges: Vec<(usize, usize)> = (0..n.div_ceil(node_chunk))
            .map(|r| (r * node_chunk, ((r + 1) * node_chunk).min(n)))
            .collect();
        let mut adj_parts: Vec<&mut [u32]> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [u32] = &mut adj;
        let mut cut = 0usize;
        for &(_, hi) in &ranges {
            let (part, r) = rest.split_at_mut(offsets[hi] - cut);
            cut = offsets[hi];
            adj_parts.push(part);
            rest = r;
        }
        let offsets_ref = &offsets;
        let mut fs: Vec<_> = adj_parts
            .into_iter()
            .zip(step_threshold.chunks_mut(node_chunk))
            .zip(&ranges)
            .map(|((apart, tpart), &(lo, hi))| {
                move || {
                    let base = offsets_ref[lo];
                    for i in lo..hi {
                        let s = offsets_ref[i] - base;
                        let e = offsets_ref[i + 1] - base;
                        apart[s..e].sort_unstable();
                        let d = (e - s) as u64;
                        tpart[i - lo] = if d == 0 { 0 } else { d.wrapping_neg() % d };
                    }
                }
            })
            .collect();
        run_tasks(pool, &mut fs);
    }

    Csr { offsets, adj, step_threshold }
}

/// Pool-parallel connectivity: level-synchronous BFS from node 0 with
/// an atomic claim bitmap. Same answer as [`Graph::is_connected`] (to
/// which it falls back below [`PARALLEL_MIN_NODES`]); works on both
/// backends — implicit-topology lanes derive neighbors into lane-local
/// buffers, touching no shared scratch.
pub fn is_connected_parallel(g: &Graph, pool: &mut WorkerPool) -> bool {
    let n = g.n();
    if n == 0 {
        return true;
    }
    let lanes = pool.workers() + 1;
    if lanes == 1 || n < PARALLEL_MIN_NODES {
        return g.is_connected();
    }
    let visited: Vec<AtomicU64> = (0..n.div_ceil(64)).map(|_| AtomicU64::new(0)).collect();
    visited[0].store(1, Ordering::Relaxed);
    let mut frontier: Vec<u32> = vec![0];
    let mut seen = 1usize;
    let mut next: Vec<(Vec<u32>, Vec<u32>)> = (0..lanes).map(|_| Default::default()).collect();
    while !frontier.is_empty() {
        let chunk = frontier.len().div_ceil(lanes).max(1);
        let pieces: Vec<&[u32]> = frontier.chunks(chunk).collect();
        let used = pieces.len();
        {
            let visited_ref = &visited;
            let mut fs: Vec<_> = next
                .iter_mut()
                .zip(pieces)
                .map(|((buf, nbrs), piece)| {
                    move || {
                        buf.clear();
                        for &u in piece {
                            g.neighbors_into(u as usize, nbrs);
                            for &v in nbrs.iter() {
                                let (w, bit) = (v as usize / 64, 1u64 << (v % 64));
                                // fetch_or claims each node exactly
                                // once across racing lanes.
                                if visited_ref[w].fetch_or(bit, Ordering::Relaxed) & bit == 0 {
                                    buf.push(v);
                                }
                            }
                        }
                    }
                })
                .collect();
            run_tasks(pool, &mut fs);
        }
        frontier.clear();
        for (buf, _) in &next[..used] {
            seen += buf.len();
            frontier.extend_from_slice(buf);
        }
    }
    seen == n
}
