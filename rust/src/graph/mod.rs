//! Graph substrate: the two-backend topology layer (materialized CSR +
//! implicit circulant families), the generator families used in the
//! paper's experiments (random d-regular, Erdős–Rényi, complete,
//! power-law) plus ring/torus for tests, pool-parallel construction
//! (`build`), and structural properties (connectivity, degrees,
//! stationary distribution, analytic mean return times).

pub mod build;
pub mod generators;
pub mod implicit;
pub mod properties;

pub use build::{from_edges_parallel, is_connected_parallel};
pub use generators::{
    barabasi_albert, complete, er_default_p, erdos_renyi, grid_torus, implicit_ring,
    implicit_small_world, random_regular, random_regular_pooled, ring,
};
pub use implicit::{ImplicitTopology, MAX_IMPLICIT_DEGREE};

use crate::rng::Rng;
use crate::runtime::prefetch::prefetch_slice;

/// The materialized backend: undirected graph in CSR form with the
/// per-node Lemire threshold column. ~`8 + 8 + 4·deg` bytes per node —
/// exact and family-agnostic, but both the footprint and the build walk
/// every edge.
#[derive(Debug, Clone)]
struct Csr {
    offsets: Vec<usize>,
    adj: Vec<u32>,
    /// Per-node Lemire rejection threshold `deg.wrapping_neg() % deg`
    /// (0 for isolated nodes, where `step` is undefined anyway).
    step_threshold: Vec<u64>,
}

impl Csr {
    /// Assemble from a pre-counted degree vector (the validation /
    /// trust decision already happened at the caller): offsets scan,
    /// scatter, per-node sort, thresholds. `build::from_edges_parallel`
    /// is the chunked pool twin of this exact layout.
    fn assemble(n: usize, edges: &[(u32, u32)], deg: Vec<usize>) -> Self {
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        for d in &deg {
            offsets.push(offsets.last().unwrap() + d);
        }
        let mut cursor = offsets[..n].to_vec();
        let mut adj = vec![0u32; 2 * edges.len()];
        for &(a, b) in edges {
            adj[cursor[a as usize]] = b;
            cursor[a as usize] += 1;
            adj[cursor[b as usize]] = a;
            cursor[b as usize] += 1;
        }
        // Sort each adjacency list for deterministic iteration order.
        for i in 0..n {
            let (lo, hi) = (offsets[i], offsets[i + 1]);
            adj[lo..hi].sort_unstable();
        }
        let step_threshold = deg
            .iter()
            .map(|&d| {
                let d = d as u64;
                if d == 0 {
                    0
                } else {
                    d.wrapping_neg() % d
                }
            })
            .collect();
        Csr { offsets, adj, step_threshold }
    }

    #[inline]
    fn neighbors(&self, i: usize) -> &[u32] {
        &self.adj[self.offsets[i]..self.offsets[i + 1]]
    }

    #[inline]
    fn step(&self, i: usize, rng: &mut Rng) -> usize {
        // Indexing through the per-node slice keeps the seed's
        // release-mode backstop: an isolated node (deg = 0) panics on
        // the empty slice instead of silently reading a neighbor of
        // the next node.
        let nbrs = &self.adj[self.offsets[i]..self.offsets[i + 1]];
        let deg = nbrs.len() as u64;
        debug_assert!(deg > 0, "walk stranded at isolated node {i}");
        nbrs[rng.below_threshold(deg, self.step_threshold[i])] as usize
    }

    /// Tier-A prefetch: the `offsets[i..=i+1]` pair (one line except at
    /// line boundaries). Issued one block ahead so that by the time
    /// [`prefetch`](Self::prefetch) reads `offsets[i]` the pair is
    /// cached.
    #[inline(always)]
    fn prefetch_meta(&self, i: usize) {
        prefetch_slice(&self.offsets, i);
        prefetch_slice(&self.offsets, i + 1);
    }

    /// Tier-B prefetch: the per-node Lemire threshold and the head of
    /// the adjacency row. The row address depends on `offsets[i]` — a
    /// real load, which is why the meta tier runs a block earlier.
    #[inline(always)]
    fn prefetch(&self, i: usize) {
        prefetch_slice(&self.step_threshold, i);
        prefetch_slice(&self.adj, self.offsets[i]);
    }

    #[inline]
    fn step_block(&self, from: &[u32], rngs: &mut [Rng], out: &mut [u32]) {
        for ((&i, rng), o) in from.iter().zip(rngs).zip(out) {
            *o = self.step(i as usize, rng) as u32;
        }
    }
}

/// Which representation serves a [`Graph`]'s queries.
#[derive(Debug, Clone)]
enum Backend {
    Csr(Csr),
    Implicit(ImplicitTopology),
}

/// Undirected graph behind one API and two backends. Nodes are `0..n`;
/// `neighbors(i)` is the sorted adjacency list of `i`. The
/// representation is immutable after construction — the simulator never
/// rewires the topology mid-run.
///
/// * **CSR** (every `from_edges*` constructor, every materializing
///   generator): stored offsets/adjacency/threshold columns, exactly
///   the pre-backend layout — same bytes, same `step` Lemire path, same
///   RNG consumption, so both pinned golden families are untouched.
/// * **Implicit** ([`Graph::from_implicit`], the `implicit_*`
///   generators): circulant families whose neighbor sets are computed
///   on demand from the offset parameters — O(1) memory per node, the
///   backend the `scale_10m`/`scale_100m` presets run on. `step`
///   consumes the RNG stream bit-identically to the CSR the topology
///   would materialize to (`tests/graph_backend.rs` locks this).
///
/// Construction also precomputes per-node sampling strata for the hop
/// loop: the Lemire rejection threshold `(2⁶⁴ − deg) mod deg` for each
/// node, so [`step`](Self::step) draws a uniform neighbor with zero
/// integer divisions per hop while consuming the RNG stream **bit-for-bit
/// identically** to `rng.below(deg)` (the determinism lock in
/// `tests/golden_traces.rs` depends on that equivalence — an alias table
/// would be division-free too but would change the draw sequence).
#[derive(Debug, Clone)]
pub struct Graph {
    backend: Backend,
}

impl Graph {
    /// Build from an undirected edge list, **validating** it: self-loops,
    /// duplicate edges and out-of-range endpoints are rejected (the
    /// paper's walks are simple random walks on simple graphs). One pass
    /// folds validation into the degree count; this is the entry point
    /// for untrusted input. Generator-internal output goes through
    /// [`from_edges_trusted`](Self::from_edges_trusted) /
    /// [`build::from_edges_parallel`] instead.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> anyhow::Result<Self> {
        let mut seen = std::collections::HashSet::with_capacity(edges.len());
        let mut deg = vec![0usize; n];
        for &(a, b) in edges {
            anyhow::ensure!((a as usize) < n && (b as usize) < n, "edge ({a},{b}) out of range");
            anyhow::ensure!(a != b, "self-loop at {a}");
            let key = if a < b { (a, b) } else { (b, a) };
            anyhow::ensure!(seen.insert(key), "duplicate edge ({a},{b})");
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        Ok(Graph { backend: Backend::Csr(Csr::assemble(n, edges, deg)) })
    }

    /// [`from_edges`](Self::from_edges) minus the O(m) HashSet pass, for
    /// edge lists that are simple **by construction** (generator
    /// output). Debug builds still run the full validation; release
    /// builds trust the caller.
    pub fn from_edges_trusted(n: usize, edges: &[(u32, u32)]) -> Self {
        #[cfg(debug_assertions)]
        Self::debug_validate_simple(n, edges);
        let mut deg = vec![0usize; n];
        for &(a, b) in edges {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        Graph { backend: Backend::Csr(Csr::assemble(n, edges, deg)) }
    }

    /// The trusted-path debug backstop: panics on any violation of the
    /// simple-graph contract.
    #[cfg(debug_assertions)]
    pub(crate) fn debug_validate_simple(n: usize, edges: &[(u32, u32)]) {
        let mut seen = std::collections::HashSet::with_capacity(edges.len());
        for &(a, b) in edges {
            assert!((a as usize) < n && (b as usize) < n, "trusted edge ({a},{b}) out of range");
            assert!(a != b, "trusted self-loop at {a}");
            let key = if a < b { (a, b) } else { (b, a) };
            assert!(seen.insert(key), "trusted duplicate edge ({a},{b})");
        }
    }

    /// Wrap an implicit topology — zero stored edges, O(1) memory per
    /// node, every `Graph` method answered by on-demand derivation.
    pub fn from_implicit(topology: ImplicitTopology) -> Self {
        Graph { backend: Backend::Implicit(topology) }
    }

    /// Internal CSR constructor for [`build::from_edges_parallel`].
    fn from_csr(csr: Csr) -> Self {
        Graph { backend: Backend::Csr(csr) }
    }

    /// Whether queries are served by on-demand derivation (no stored
    /// edges) rather than materialized CSR columns.
    #[inline]
    pub fn is_implicit(&self) -> bool {
        matches!(self.backend, Backend::Implicit(_))
    }

    /// Backend tag for reports and bench JSON.
    #[inline]
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Csr(_) => "csr",
            Backend::Implicit(_) => "implicit",
        }
    }

    /// The implicit topology behind this graph, if that is the backend.
    #[inline]
    pub fn implicit(&self) -> Option<&ImplicitTopology> {
        match &self.backend {
            Backend::Csr(_) => None,
            Backend::Implicit(t) => Some(t),
        }
    }

    /// Resident bytes of the topology representation (the stored CSR
    /// columns, or the implicit backend's O(1) parameter block). The
    /// `perf_graph` memory-per-node budget is asserted on this.
    pub fn memory_bytes(&self) -> usize {
        match &self.backend {
            Backend::Csr(c) => {
                c.offsets.len() * std::mem::size_of::<usize>()
                    + c.adj.len() * std::mem::size_of::<u32>()
                    + c.step_threshold.len() * std::mem::size_of::<u64>()
            }
            Backend::Implicit(t) => t.memory_bytes(),
        }
    }

    /// Materialize into the CSR backend: bit-identical neighbor sets,
    /// degrees, thresholds and `step` RNG streams (the invariance lock
    /// in `tests/graph_backend.rs`). A CSR graph clones.
    pub fn materialize(&self) -> Graph {
        match &self.backend {
            Backend::Csr(_) => self.clone(),
            Backend::Implicit(t) => Graph::from_edges_trusted(t.n(), &t.edge_list()),
        }
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        match &self.backend {
            Backend::Csr(c) => c.offsets.len() - 1,
            Backend::Implicit(t) => t.n(),
        }
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        match &self.backend {
            Backend::Csr(c) => c.adj.len() / 2,
            Backend::Implicit(t) => t.m(),
        }
    }

    /// Degree of node `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        match &self.backend {
            Backend::Csr(c) => c.offsets[i + 1] - c.offsets[i],
            Backend::Implicit(t) => t.degree(),
        }
    }

    /// Sorted adjacency list of node `i`.
    ///
    /// **Scratch contract** (implicit backend): the returned slice
    /// lives in a small per-thread scratch buffer and stays valid only
    /// until the same thread's next implicit-backend `neighbors` call
    /// (on any graph — the scratch is shared per thread). Iterating one
    /// node's slice before asking for the next — what every call site
    /// in the engines, controls and properties does — is always fine;
    /// code holding two nodes' lists at once must copy the first
    /// (`.to_vec()`) or use [`neighbors_into`](Self::neighbors_into)
    /// with its own buffers. On the CSR backend the slice borrows the
    /// graph itself and has no such constraint.
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        match &self.backend {
            Backend::Csr(c) => c.neighbors(i),
            Backend::Implicit(t) => t.scratch_neighbors(i),
        }
    }

    /// Copy node `i`'s sorted adjacency list into `out` (cleared
    /// first). The scratch-free form of [`neighbors`](Self::neighbors):
    /// callers own the buffer, so many threads can query concurrently
    /// and hold many nodes' lists at once on either backend.
    #[inline]
    pub fn neighbors_into(&self, i: usize, out: &mut Vec<u32>) {
        out.clear();
        match &self.backend {
            Backend::Csr(c) => out.extend_from_slice(c.neighbors(i)),
            Backend::Implicit(t) => {
                let mut buf = [0u32; MAX_IMPLICIT_DEGREE];
                let d = t.fill_sorted(i, &mut buf);
                out.extend_from_slice(&buf[..d]);
            }
        }
    }

    /// One step of a simple random walk from `i`: uniform neighbor.
    ///
    /// Division-free: Lemire's multiply-shift with the per-node rejection
    /// threshold precomputed at construction. `rng.below(n)` accepts a
    /// draw iff `lo ≥ n` or `lo ≥ (2⁶⁴ − n) mod n`; since the threshold
    /// is `< n`, both collapse to the single precomputed comparison, so
    /// this consumes the identical RNG stream (asserted by
    /// `step_matches_rng_below_stream` below). The implicit backend runs
    /// the same loop against its shared threshold and selects by sorted
    /// rank — bit-identical draws *and* destinations versus the
    /// materialized CSR (`tests/graph_backend.rs`).
    #[inline]
    pub fn step(&self, i: usize, rng: &mut Rng) -> usize {
        match &self.backend {
            Backend::Csr(c) => c.step(i, rng),
            Backend::Implicit(t) => t.step(i, rng),
        }
    }

    /// Tier-A step prefetch: hint the lines that
    /// [`prefetch`](Self::prefetch) will itself *read* for node `i`
    /// (the CSR offset pair). The blocked hop pipeline issues this one
    /// block ahead of the tier-B call so neither tier stalls. Advisory
    /// only — never changes results; no-op on the implicit backend,
    /// whose topology parameters live in registers.
    #[inline(always)]
    pub fn prefetch_meta(&self, i: usize) {
        match &self.backend {
            Backend::Csr(c) => c.prefetch_meta(i),
            Backend::Implicit(_) => {}
        }
    }

    /// Tier-B step prefetch: hint the lines [`step`](Self::step) will
    /// read for node `i` — the adjacency row and the per-node Lemire
    /// threshold. Reads `offsets[i]` to compute the row address, which
    /// is why [`prefetch_meta`](Self::prefetch_meta) runs a block
    /// earlier. Advisory only; no-op on the implicit backend.
    #[inline(always)]
    pub fn prefetch(&self, i: usize) {
        match &self.backend {
            Backend::Csr(c) => c.prefetch(i),
            Backend::Implicit(_) => {}
        }
    }

    /// Batched [`step`](Self::step): one uniform-neighbor draw per
    /// entry, `out[j] = step(from[j], &mut rngs[j])`. Same per-walk
    /// draws in the same per-walk order as the scalar calls — each walk
    /// owns `rngs[j]`, so batching cannot move a bit of any stream —
    /// but the backend dispatch is hoisted out of the loop and the loop
    /// body is branch-predictable, which is what lets the blocked hop
    /// pipeline overlap one block's draws with the next block's
    /// prefetches. Panics if the slice lengths differ.
    #[inline]
    pub fn step_block(&self, from: &[u32], rngs: &mut [Rng], out: &mut [u32]) {
        assert_eq!(from.len(), rngs.len(), "step_block: from/rngs length mismatch");
        assert_eq!(from.len(), out.len(), "step_block: from/out length mismatch");
        match &self.backend {
            Backend::Csr(c) => c.step_block(from, rngs, out),
            Backend::Implicit(t) => t.step_block(from, rngs, out),
        }
    }

    /// Whether the graph is connected (BFS from node 0, on-demand
    /// neighbor derivation on the implicit backend). Empty graphs are
    /// considered connected. `build::is_connected_parallel` is the
    /// pool-parallel form for generator-scale graphs.
    pub fn is_connected(&self) -> bool {
        let n = self.n();
        if n == 0 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::new();
        let mut nbrs = Vec::new();
        seen[0] = true;
        queue.push_back(0usize);
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            self.neighbors_into(u, &mut nbrs);
            for &v in &nbrs {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    count += 1;
                    queue.push_back(v as usize);
                }
            }
        }
        count == n
    }

    /// BFS distances from `src` (`usize::MAX` for unreachable).
    pub fn bfs_distances(&self, src: usize) -> Vec<usize> {
        let n = self.n();
        let mut dist = vec![usize::MAX; n];
        let mut queue = std::collections::VecDeque::new();
        let mut nbrs = Vec::new();
        dist[src] = 0;
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            self.neighbors_into(u, &mut nbrs);
            for &v in &nbrs {
                let v = v as usize;
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Stationary probability of the simple random walk at node `i`:
    /// `deg(i) / 2|E|`.
    #[inline]
    pub fn stationary(&self, i: usize) -> f64 {
        self.degree(i) as f64 / (2.0 * self.m() as f64)
    }

    /// Analytic mean return time to node `i` for the simple random walk on
    /// a connected graph: `E[R_i] = 1/π_i = 2|E| / deg(i)` (Kac's formula).
    /// Used both to seed analytic survival functions and as a
    /// property-test oracle for the empirical estimator.
    #[inline]
    pub fn mean_return_time(&self, i: usize) -> f64 {
        2.0 * self.m() as f64 / self.degree(i) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_basic() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 3]);
        assert!(g.is_connected());
        assert!(!g.is_implicit());
        assert_eq!(g.backend_name(), "csr");
    }

    #[test]
    fn rejects_self_loops_and_duplicates() {
        assert!(Graph::from_edges(3, &[(0, 0)]).is_err());
        assert!(Graph::from_edges(3, &[(0, 1), (1, 0)]).is_err());
        assert!(Graph::from_edges(2, &[(0, 5)]).is_err());
    }

    #[test]
    fn trusted_matches_validating_constructor() {
        let edges = [(0u32, 1u32), (1, 2), (2, 3), (3, 0), (0, 2)];
        let a = Graph::from_edges(4, &edges).unwrap();
        let b = Graph::from_edges_trusted(4, &edges);
        assert_eq!(a.m(), b.m());
        for i in 0..4 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
        // Bit-identical step streams (same thresholds by construction).
        let (mut ra, mut rb) = (Rng::new(3), Rng::new(3));
        let (mut pa, mut pb) = (0usize, 0usize);
        for _ in 0..2000 {
            pa = a.step(pa, &mut ra);
            pb = b.step(pb, &mut rb);
            assert_eq!(pa, pb);
        }
    }

    #[test]
    #[should_panic(expected = "trusted duplicate edge")]
    #[cfg(debug_assertions)]
    fn trusted_path_still_panics_in_debug_builds() {
        let _ = Graph::from_edges_trusted(3, &[(0, 1), (1, 0)]);
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]).unwrap();
        assert!(!g.is_connected());
    }

    #[test]
    fn bfs_distances_line() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(g.bfs_distances(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn neighbors_into_matches_neighbors_on_both_backends() {
        let csr = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (3, 4)]).unwrap();
        let imp = Graph::from_implicit(ImplicitTopology::ring_lattice(9, 4).unwrap());
        let mut buf = Vec::new();
        for g in [&csr, &imp] {
            for i in 0..g.n() {
                g.neighbors_into(i, &mut buf);
                assert_eq!(buf.as_slice(), g.neighbors(i));
            }
        }
    }

    #[test]
    fn implicit_backend_dispatches_through_graph_api() {
        // C_n({1}) is the plain ring — compare against the materializing
        // ring generator on every API surface.
        let imp = Graph::from_implicit(ImplicitTopology::new(10, vec![1], "ring").unwrap());
        let csr = generators::ring(10);
        assert!(imp.is_implicit());
        assert_eq!(imp.backend_name(), "implicit");
        assert_eq!((imp.n(), imp.m()), (csr.n(), csr.m()));
        for i in 0..10 {
            assert_eq!(imp.degree(i), csr.degree(i));
            assert_eq!(imp.neighbors(i).to_vec(), csr.neighbors(i));
            assert_eq!(imp.bfs_distances(i), csr.bfs_distances(i));
            assert!((imp.stationary(i) - csr.stationary(i)).abs() < 1e-15);
        }
        assert!(imp.is_connected());
        // Disconnected circulant: C_10({2}) is two 5-cycles.
        let two = Graph::from_implicit(ImplicitTopology::new(10, vec![2], "t").unwrap());
        assert!(!two.is_connected());
        assert_eq!(two.bfs_distances(0)[1], usize::MAX);
    }

    #[test]
    fn memory_bytes_o1_for_implicit_linear_for_csr() {
        let imp = Graph::from_implicit(ImplicitTopology::ring_lattice(1_000_000, 8).unwrap());
        assert!(imp.memory_bytes() < 1024, "implicit: {}", imp.memory_bytes());
        let csr = imp.materialize();
        // 8 B offsets + 8 B threshold + 4·8 B adjacency per node.
        assert!(csr.memory_bytes() > 1_000_000 * 40, "csr: {}", csr.memory_bytes());
    }

    #[test]
    fn materialize_is_identity_on_csr() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]).unwrap();
        let m = g.materialize();
        assert_eq!(m.backend_name(), "csr");
        for i in 0..4 {
            assert_eq!(g.neighbors(i), m.neighbors(i));
        }
    }

    #[test]
    fn stationary_sums_to_one() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]).unwrap();
        let total: f64 = (0..g.n()).map(|i| g.stationary(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kac_formula_matches_simulation_on_small_graph() {
        // Empirical mean return time on a cycle of 4 ≈ 2|E|/deg = 4.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        let mut rng = Rng::new(5);
        let mut pos = 0usize;
        let mut last_at_zero: Option<u64> = Some(0);
        let mut samples = Vec::new();
        for t in 1..400_000u64 {
            pos = g.step(pos, &mut rng);
            if pos == 0 {
                if let Some(l) = last_at_zero {
                    samples.push((t - l) as f64);
                }
                last_at_zero = Some(t);
            }
        }
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - g.mean_return_time(0)).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn step_matches_rng_below_stream() {
        // The precomputed-threshold sampler must consume the RNG stream
        // bit-for-bit identically to `nbrs[rng.below(nbrs.len())]` — the
        // determinism lock depends on this equivalence.
        for (n, edges) in [
            (4, vec![(0u32, 1u32), (1, 2), (2, 3), (3, 0)]),
            (5, vec![(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (3, 4)]),
            (3, vec![(0, 1), (1, 2)]),
        ] {
            let g = Graph::from_edges(n, &edges).unwrap();
            let mut ra = Rng::new(0xFEED);
            let mut rb = ra.clone();
            let mut pos_a = 0usize;
            let mut pos_b = 0usize;
            for _ in 0..50_000 {
                pos_a = g.step(pos_a, &mut ra);
                let nbrs = g.neighbors(pos_b);
                pos_b = nbrs[rb.below(nbrs.len())] as usize;
                assert_eq!(pos_a, pos_b);
                assert_eq!(ra.next_u64(), rb.next_u64(), "rng streams diverged");
            }
        }
    }

    #[test]
    fn step_block_matches_scalar_steps_both_backends() {
        // The batched draw must be walk-for-walk identical to scalar
        // `step` calls: same destinations, same per-stream RNG state
        // afterwards. Exercised on both backends and with prefetches
        // interleaved (they are hints and must be invisible).
        let imp =
            Graph::from_implicit(ImplicitTopology::small_world(64, 8, &mut Rng::new(41)).unwrap());
        let csr = imp.materialize();
        for g in [&imp, &csr] {
            let from: Vec<u32> = (0..97u32).map(|j| (j * 13) % 64).collect();
            let mut rngs_a: Vec<Rng> =
                (0..from.len()).map(|j| Rng::new(0xB10C ^ j as u64)).collect();
            let mut rngs_b = rngs_a.clone();
            let mut out = vec![0u32; from.len()];
            for (k, &i) in from.iter().enumerate() {
                g.prefetch_meta(i as usize);
                if k > 0 {
                    g.prefetch(from[k - 1] as usize);
                }
            }
            g.step_block(&from, &mut rngs_a, &mut out);
            for (j, &i) in from.iter().enumerate() {
                let want = g.step(i as usize, &mut rngs_b[j]) as u32;
                assert_eq!(out[j], want, "destination diverged at j={j}");
                assert_eq!(
                    rngs_a[j].next_u64(),
                    rngs_b[j].next_u64(),
                    "rng stream diverged at j={j}"
                );
            }
        }
    }

    #[test]
    fn walk_step_uniform_over_neighbors() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]).unwrap();
        let mut rng = Rng::new(77);
        let mut counts = [0usize; 4];
        for _ in 0..30_000 {
            counts[g.step(0, &mut rng)] += 1;
        }
        assert_eq!(counts[0], 0);
        for &c in &counts[1..] {
            assert!((c as f64 - 10_000.0).abs() < 500.0);
        }
    }
}
