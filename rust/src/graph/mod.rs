//! Graph substrate: compressed-sparse-row undirected graphs, the generator
//! families used in the paper's experiments (random d-regular,
//! Erdős–Rényi, complete, power-law) plus ring/torus for tests, and
//! structural properties (connectivity, degrees, stationary distribution,
//! analytic mean return times).

pub mod generators;
pub mod properties;

pub use generators::{barabasi_albert, complete, erdos_renyi, grid_torus, random_regular, ring};

use crate::rng::Rng;

/// Undirected graph in CSR form. Nodes are `0..n`; `neighbors(i)` is the
/// adjacency list of `i`. The representation is immutable after
/// construction — the simulator never rewires the topology mid-run.
///
/// Construction also precomputes per-node sampling strata for the hop
/// loop: the Lemire rejection threshold `(2⁶⁴ − deg) mod deg` for each
/// node, so [`step`](Self::step) draws a uniform neighbor with zero
/// integer divisions per hop while consuming the RNG stream **bit-for-bit
/// identically** to `rng.below(deg)` (the determinism lock in
/// `tests/golden_traces.rs` depends on that equivalence — an alias table
/// would be division-free too but would change the draw sequence).
#[derive(Debug, Clone)]
pub struct Graph {
    offsets: Vec<usize>,
    adj: Vec<u32>,
    /// Per-node Lemire rejection threshold `deg.wrapping_neg() % deg`
    /// (0 for isolated nodes, where `step` is undefined anyway).
    step_threshold: Vec<u64>,
}

impl Graph {
    /// Build from an undirected edge list. Self-loops and duplicate edges
    /// are rejected: the paper's walks are simple random walks on simple
    /// graphs.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> anyhow::Result<Self> {
        let mut seen = std::collections::HashSet::with_capacity(edges.len());
        for &(a, b) in edges {
            anyhow::ensure!((a as usize) < n && (b as usize) < n, "edge ({a},{b}) out of range");
            anyhow::ensure!(a != b, "self-loop at {a}");
            let key = if a < b { (a, b) } else { (b, a) };
            anyhow::ensure!(seen.insert(key), "duplicate edge ({a},{b})");
        }
        let mut deg = vec![0usize; n];
        for &(a, b) in edges {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0usize);
        for d in &deg {
            offsets.push(offsets.last().unwrap() + d);
        }
        let mut cursor = offsets[..n].to_vec();
        let mut adj = vec![0u32; 2 * edges.len()];
        for &(a, b) in edges {
            adj[cursor[a as usize]] = b;
            cursor[a as usize] += 1;
            adj[cursor[b as usize]] = a;
            cursor[b as usize] += 1;
        }
        // Sort each adjacency list for deterministic iteration order.
        let g = {
            let step_threshold = deg
                .iter()
                .map(|&d| {
                    let d = d as u64;
                    if d == 0 {
                        0
                    } else {
                        d.wrapping_neg() % d
                    }
                })
                .collect();
            let mut g = Graph { offsets, adj, step_threshold };
            for i in 0..n {
                let (lo, hi) = (g.offsets[i], g.offsets[i + 1]);
                g.adj[lo..hi].sort_unstable();
            }
            g
        };
        Ok(g)
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.adj.len() / 2
    }

    /// Degree of node `i`.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Adjacency list of node `i`.
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.adj[self.offsets[i]..self.offsets[i + 1]]
    }

    /// One step of a simple random walk from `i`: uniform neighbor.
    ///
    /// Division-free: Lemire's multiply-shift with the per-node rejection
    /// threshold precomputed at construction. `rng.below(n)` accepts a
    /// draw iff `lo ≥ n` or `lo ≥ (2⁶⁴ − n) mod n`; since the threshold
    /// is `< n`, both collapse to the single precomputed comparison, so
    /// this consumes the identical RNG stream (asserted by
    /// `step_matches_rng_below_stream` below).
    #[inline]
    pub fn step(&self, i: usize, rng: &mut Rng) -> usize {
        // Indexing through the per-node slice keeps the seed's
        // release-mode backstop: an isolated node (deg = 0) panics on
        // the empty slice instead of silently reading a neighbor of
        // the next node.
        let nbrs = &self.adj[self.offsets[i]..self.offsets[i + 1]];
        let deg = nbrs.len() as u64;
        debug_assert!(deg > 0, "walk stranded at isolated node {i}");
        let threshold = self.step_threshold[i];
        loop {
            let x = rng.next_u64();
            let m = (x as u128).wrapping_mul(deg as u128);
            if (m as u64) >= threshold {
                return nbrs[(m >> 64) as usize] as usize;
            }
        }
    }

    /// Whether the graph is connected (BFS from node 0). Empty graphs are
    /// considered connected.
    pub fn is_connected(&self) -> bool {
        let n = self.n();
        if n == 0 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::new();
        seen[0] = true;
        queue.push_back(0usize);
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in self.neighbors(u) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    count += 1;
                    queue.push_back(v as usize);
                }
            }
        }
        count == n
    }

    /// BFS distances from `src` (`usize::MAX` for unreachable).
    pub fn bfs_distances(&self, src: usize) -> Vec<usize> {
        let n = self.n();
        let mut dist = vec![usize::MAX; n];
        let mut queue = std::collections::VecDeque::new();
        dist[src] = 0;
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            for &v in self.neighbors(u) {
                let v = v as usize;
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Stationary probability of the simple random walk at node `i`:
    /// `deg(i) / 2|E|`.
    #[inline]
    pub fn stationary(&self, i: usize) -> f64 {
        self.degree(i) as f64 / (2.0 * self.m() as f64)
    }

    /// Analytic mean return time to node `i` for the simple random walk on
    /// a connected graph: `E[R_i] = 1/π_i = 2|E| / deg(i)` (Kac's formula).
    /// Used both to seed analytic survival functions and as a
    /// property-test oracle for the empirical estimator.
    #[inline]
    pub fn mean_return_time(&self, i: usize) -> f64 {
        2.0 * self.m() as f64 / self.degree(i) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_basic() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 3]);
        assert!(g.is_connected());
    }

    #[test]
    fn rejects_self_loops_and_duplicates() {
        assert!(Graph::from_edges(3, &[(0, 0)]).is_err());
        assert!(Graph::from_edges(3, &[(0, 1), (1, 0)]).is_err());
        assert!(Graph::from_edges(2, &[(0, 5)]).is_err());
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]).unwrap();
        assert!(!g.is_connected());
    }

    #[test]
    fn bfs_distances_line() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(g.bfs_distances(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn stationary_sums_to_one() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]).unwrap();
        let total: f64 = (0..g.n()).map(|i| g.stationary(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kac_formula_matches_simulation_on_small_graph() {
        // Empirical mean return time on a cycle of 4 ≈ 2|E|/deg = 4.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        let mut rng = Rng::new(5);
        let mut pos = 0usize;
        let mut last_at_zero: Option<u64> = Some(0);
        let mut samples = Vec::new();
        for t in 1..400_000u64 {
            pos = g.step(pos, &mut rng);
            if pos == 0 {
                if let Some(l) = last_at_zero {
                    samples.push((t - l) as f64);
                }
                last_at_zero = Some(t);
            }
        }
        let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - g.mean_return_time(0)).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn step_matches_rng_below_stream() {
        // The precomputed-threshold sampler must consume the RNG stream
        // bit-for-bit identically to `nbrs[rng.below(nbrs.len())]` — the
        // determinism lock depends on this equivalence.
        for (n, edges) in [
            (4, vec![(0u32, 1u32), (1, 2), (2, 3), (3, 0)]),
            (5, vec![(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (3, 4)]),
            (3, vec![(0, 1), (1, 2)]),
        ] {
            let g = Graph::from_edges(n, &edges).unwrap();
            let mut ra = Rng::new(0xFEED);
            let mut rb = ra.clone();
            let mut pos_a = 0usize;
            let mut pos_b = 0usize;
            for _ in 0..50_000 {
                pos_a = g.step(pos_a, &mut ra);
                let nbrs = g.neighbors(pos_b);
                pos_b = nbrs[rb.below(nbrs.len())] as usize;
                assert_eq!(pos_a, pos_b);
                assert_eq!(ra.next_u64(), rb.next_u64(), "rng streams diverged");
            }
        }
    }

    #[test]
    fn walk_step_uniform_over_neighbors() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]).unwrap();
        let mut rng = Rng::new(77);
        let mut counts = [0usize; 4];
        for _ in 0..30_000 {
            counts[g.step(0, &mut rng)] += 1;
        }
        assert_eq!(counts[0], 0);
        for &c in &counts[1..] {
            assert!((c as f64 - 10_000.0).abs() < 500.0);
        }
    }
}
