//! The implicit topology backend: circulant graph families whose
//! neighbor sets are **derived on demand** from `(parameters, node)` —
//! zero stored edges, O(1) memory per node — so graph size stops being
//! a memory axis at all (DESIGN.md §Topology backends).
//!
//! ## The family
//!
//! A circulant graph `C_n(S)` connects node `i` to `(i ± s) mod n` for
//! every offset `s ∈ S`, with `S ⊂ {1, …, ⌊(n−1)/2⌋}` distinct. Under
//! that offset bound every node has exactly `2|S|` **distinct**
//! neighbors and no self-loops: `s ≢ 0 (mod n)` rules out loops,
//! `s + s′ < n` rules out `i − s ≡ i + s′` collisions, and the offsets
//! being distinct rules out the rest. (`s = n/2` is deliberately
//! forbidden — it would contribute a single neighbor instead of two and
//! break the uniform-degree invariant the shared Lemire threshold
//! relies on.) Two sub-families are exposed through
//! [`generators`](super::generators):
//!
//! * **shifted ring** (`ring_lattice`): `S = {1, …, d/2}` — the
//!   d-regular ring lattice, the deterministic skeleton of the
//!   Watts–Strogatz construction;
//! * **small world** (`small_world`): `S = {1, …} ∪ {seed-derived long
//!   chords}` — a degree-preserving Newman–Watts-flavored small world.
//!   Exact Watts–Strogatz *rewiring* cannot be derived locally: whether
//!   some far node rewired one of its edges **onto** `i` is not a
//!   function of `(seed, i)`, so any zero-storage backend would have to
//!   scan all n nodes per query. Random *chord offsets* keep the
//!   small-world diameter collapse (long-range shortcuts at every
//!   node) while staying a pure local function — and keep the graph
//!   regular, which the paper's return-time analysis prefers anyway.
//!
//! Connectivity is `gcd(n, S) = 1`; both exposed families include
//! offset 1 and are therefore always connected. [`ImplicitTopology::new`]
//! accepts disconnected offset sets on purpose (`C_10({2})` is two
//! 5-cycles) so `Graph::is_connected` has something real to detect on
//! this backend.
//!
//! ## Bit-compatibility with the CSR backend
//!
//! `materialize()`d into CSR, a circulant must be indistinguishable
//! from the implicit original: same degrees, same sorted neighbor
//! lists, same Lemire threshold, and — the part the determinism locks
//! care about — the same `step` RNG consumption. `step` here runs the
//! identical accept/reject loop against the (single, shared) threshold
//! and then selects the j-th neighbor **in sorted order**, exactly
//! where the CSR backend's sorted adjacency slice would put it. For
//! interior nodes (`span ≤ i < n − span`, i.e. no modular wraparound)
//! the sorted order is the closed form
//! `[i−s_k, …, i−s_1, i+s_1, …, i+s_k]`, so selection is O(1); the
//! `2·span` boundary nodes fill a stack buffer and sort it. The
//! equivalence is locked by `tests/graph_backend.rs`.

use crate::rng::Rng;

/// Hard cap on the implicit backend's degree: neighbor derivation uses
/// fixed-size stack buffers (no allocation on the `step` hot path), and
/// the scale presets live at d = 8 — a 64-degree circulant is already
/// outside anything the walk analysis targets.
pub const MAX_IMPLICIT_DEGREE: usize = 64;

/// A circulant topology `C_n(S)`, stored as its offset set only:
/// `size_of::<Self>() + 4·|S|` bytes regardless of `n`.
#[derive(Debug, Clone)]
pub struct ImplicitTopology {
    n: usize,
    /// Sorted distinct half-offsets, each in `1..=(n−1)/2`.
    half_offsets: Box<[u32]>,
    /// `half_offsets.last()` — nodes within `span` of either end wrap.
    span: usize,
    /// `2·|half_offsets|`, identical at every node.
    degree: usize,
    /// The shared Lemire rejection threshold `deg.wrapping_neg() % deg`
    /// (per-node in the CSR backend; one value suffices here because
    /// the degree is uniform).
    step_threshold: u64,
    /// Family tag for labels/diagnostics ("ring-lattice", "small-world").
    family: &'static str,
}

impl ImplicitTopology {
    /// Circulant `C_n(S)` from an explicit offset set. Offsets must be
    /// distinct and in `1..=(n−1)/2`; the resulting degree `2|S|` must
    /// stay within [`MAX_IMPLICIT_DEGREE`]. Connectivity is *not*
    /// required (`gcd(n, S) > 1` builds a disconnected circulant, which
    /// `Graph::is_connected` then reports).
    pub fn new(n: usize, mut half_offsets: Vec<u32>, family: &'static str) -> anyhow::Result<Self> {
        anyhow::ensure!(n >= 3, "implicit topology needs n >= 3, got {n}");
        anyhow::ensure!(!half_offsets.is_empty(), "implicit topology needs at least one offset");
        let before = half_offsets.len();
        half_offsets.sort_unstable();
        half_offsets.dedup();
        anyhow::ensure!(half_offsets.len() == before, "duplicate circulant offset");
        let max_off = (n - 1) / 2;
        let (lo, hi) = (half_offsets[0], *half_offsets.last().unwrap());
        anyhow::ensure!(
            lo >= 1 && (hi as usize) <= max_off,
            "circulant offsets must lie in 1..={max_off} for n = {n} (got {lo}..={hi})"
        );
        let degree = 2 * half_offsets.len();
        anyhow::ensure!(
            degree <= MAX_IMPLICIT_DEGREE,
            "implicit degree {degree} exceeds the stack-buffer cap {MAX_IMPLICIT_DEGREE}"
        );
        let d = degree as u64;
        Ok(ImplicitTopology {
            n,
            span: hi as usize,
            half_offsets: half_offsets.into_boxed_slice(),
            degree,
            step_threshold: d.wrapping_neg() % d,
            family,
        })
    }

    /// The d-regular ring lattice: `S = {1, …, d/2}`. Always connected
    /// (offset 1 is a Hamiltonian cycle).
    pub fn ring_lattice(n: usize, d: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(d >= 2 && d % 2 == 0, "ring lattice degree must be even and >= 2, got {d}");
        anyhow::ensure!(
            d / 2 <= (n.max(1) - 1) / 2,
            "ring lattice d = {d} needs n >= {}, got {n}",
            d + 2
        );
        Self::new(n, (1..=(d / 2) as u32).collect(), "ring-lattice")
    }

    /// Degree-preserving small world: half the offset budget is the
    /// local band `{1, …}`, half is seed-derived long chords drawn
    /// uniformly from the remaining range (see the module docs for why
    /// this — and not true Watts–Strogatz rewiring — is the family a
    /// zero-storage backend can serve). Always connected (offset 1 is
    /// in the local band). Deterministic in the `rng` state, matching
    /// the other randomized generators.
    pub fn small_world(n: usize, d: usize, rng: &mut Rng) -> anyhow::Result<Self> {
        anyhow::ensure!(d >= 4 && d % 2 == 0, "small world degree must be even and >= 4, got {d}");
        let half = d / 2;
        let chords = half / 2;
        let locals = half - chords;
        let max_off = (n.max(1) - 1) / 2;
        anyhow::ensure!(
            max_off >= locals + chords,
            "small world d = {d} needs n >= {}, got {n}",
            2 * (locals + chords) + 1
        );
        let mut offsets: Vec<u32> = (1..=locals as u32).collect();
        while offsets.len() < half {
            // Rejection-sample distinct chords beyond the local band;
            // `chords ≤ 16`, so the linear contains-scan is cheaper
            // than any set structure.
            let c = (locals + 1 + rng.below(max_off - locals)) as u32;
            if !offsets.contains(&c) {
                offsets.push(c);
            }
        }
        Self::new(n, offsets, "small-world")
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Undirected edge count `n·|S|` (every offset contributes one edge
    /// per node).
    #[inline]
    pub fn m(&self) -> usize {
        self.n * self.half_offsets.len()
    }

    /// Uniform degree `2|S|`.
    #[inline]
    pub fn degree(&self) -> usize {
        self.degree
    }

    /// The offset set `S` (sorted).
    #[inline]
    pub fn half_offsets(&self) -> &[u32] {
        &self.half_offsets
    }

    /// Family tag ("ring-lattice" / "small-world" / caller-supplied).
    #[inline]
    pub fn family(&self) -> &'static str {
        self.family
    }

    /// Derived memory footprint — the O(1)-per-node claim in numbers.
    #[inline]
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.half_offsets.len() * std::mem::size_of::<u32>()
    }

    /// Write node `i`'s neighbors into `buf` in sorted order; returns
    /// the degree. `buf` is caller stack space — no allocation, no
    /// shared state — which is what the hop loop and the parallel BFS
    /// use from many threads at once.
    #[inline]
    pub(super) fn fill_sorted(&self, i: usize, buf: &mut [u32; MAX_IMPLICIT_DEGREE]) -> usize {
        let k = self.half_offsets.len();
        if i >= self.span && i + self.span < self.n {
            // Interior: no wraparound, so `i−s` descends as `s` ascends
            // and every `i−s` precedes every `i+s` — sorted by
            // construction.
            for (j, &s) in self.half_offsets.iter().enumerate() {
                buf[k - 1 - j] = (i - s as usize) as u32;
                buf[k + j] = (i + s as usize) as u32;
            }
        } else {
            for (j, &s) in self.half_offsets.iter().enumerate() {
                let s = s as usize;
                buf[2 * j] = ((i + s) % self.n) as u32;
                buf[2 * j + 1] = ((i + self.n - s) % self.n) as u32;
            }
            buf[..2 * k].sort_unstable();
        }
        2 * k
    }

    /// The j-th neighbor of `i` in sorted order — the exact element a
    /// materialized CSR's sorted adjacency slice holds at rank `j`.
    #[inline]
    fn neighbor_sorted(&self, i: usize, j: usize) -> usize {
        let k = self.half_offsets.len();
        if i >= self.span && i + self.span < self.n {
            if j < k {
                i - self.half_offsets[k - 1 - j] as usize
            } else {
                i + self.half_offsets[j - k] as usize
            }
        } else {
            let mut buf = [0u32; MAX_IMPLICIT_DEGREE];
            let d = self.fill_sorted(i, &mut buf);
            debug_assert!(j < d);
            buf[j] as usize
        }
    }

    /// One uniform-neighbor step — the same Lemire accept/reject loop
    /// as the CSR backend against the same threshold value, then rank
    /// selection in sorted order: RNG consumption and the chosen
    /// neighbor are bit-identical to stepping on `materialize()`d CSR
    /// (locked by `tests/graph_backend.rs`).
    #[inline]
    pub fn step(&self, i: usize, rng: &mut Rng) -> usize {
        let rank = rng.below_threshold(self.degree as u64, self.step_threshold);
        self.neighbor_sorted(i, rank)
    }

    /// Batched [`step`](Self::step) — see `Graph::step_block`. There is
    /// nothing to prefetch on this backend (the topology parameters sit
    /// in registers), but batching still hoists the shared
    /// degree/threshold loads and the `Graph` dispatch out of the
    /// per-walk loop. Draw-for-draw identical to scalar `step` calls.
    #[inline]
    pub fn step_block(&self, from: &[u32], rngs: &mut [Rng], out: &mut [u32]) {
        let deg = self.degree as u64;
        let threshold = self.step_threshold;
        for ((&i, rng), o) in from.iter().zip(rngs).zip(out) {
            let rank = rng.below_threshold(deg, threshold);
            *o = self.neighbor_sorted(i as usize, rank) as u32;
        }
    }

    /// The per-thread scratch serving `Graph::neighbors`'s `&[u32]`
    /// signature on a backend that stores no edges. The returned slice
    /// is valid until the **same thread's next** implicit-backend
    /// `neighbors` call (any implicit graph — the scratch is shared per
    /// thread); see the contract on [`Graph::neighbors`](super::Graph::neighbors).
    pub(super) fn scratch_neighbors(&self, i: usize) -> &[u32] {
        use std::cell::UnsafeCell;
        thread_local! {
            static SCRATCH: UnsafeCell<Vec<u32>> = const { UnsafeCell::new(Vec::new()) };
        }
        let mut buf = [0u32; MAX_IMPLICIT_DEGREE];
        let d = self.fill_sorted(i, &mut buf);
        SCRATCH.with(|cell| {
            // SAFETY: the scratch is thread-local and the &mut borrow is
            // confined to this non-reentrant function body, so no two
            // live &mut aliases exist. The returned shared slice points
            // into the scratch's heap buffer; the next call on this
            // thread overwrites (and may reallocate) it — exactly the
            // documented validity window.
            let scratch = unsafe { &mut *cell.get() };
            scratch.clear();
            scratch.extend_from_slice(&buf[..d]);
            unsafe { std::slice::from_raw_parts(scratch.as_ptr(), scratch.len()) }
        })
    }

    /// The full undirected edge list `{(i, (i+s) mod n)}` — each edge
    /// exactly once (the mirror `(i, i−s)` would need an offset `n−s`,
    /// which the `≤ (n−1)/2` bound excludes). This is what
    /// `Graph::materialize` feeds to the CSR builder.
    pub fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut edges = Vec::with_capacity(self.m());
        for i in 0..self.n {
            for &s in self.half_offsets.iter() {
                edges.push((i as u32, ((i + s as usize) % self.n) as u32));
            }
        }
        edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_offsets() {
        assert!(ImplicitTopology::new(10, vec![1, 2], "t").is_ok());
        assert!(ImplicitTopology::new(10, vec![], "t").is_err(), "empty offset set");
        assert!(ImplicitTopology::new(10, vec![0], "t").is_err(), "offset 0 is a self-loop");
        assert!(ImplicitTopology::new(10, vec![5], "t").is_err(), "n/2 breaks uniform degree");
        assert!(ImplicitTopology::new(10, vec![1, 1], "t").is_err(), "duplicate offset");
        assert!(ImplicitTopology::new(2, vec![1], "t").is_err(), "n too small");
        let too_many: Vec<u32> = (1..=(MAX_IMPLICIT_DEGREE / 2 + 1) as u32).collect();
        assert!(ImplicitTopology::new(1000, too_many, "t").is_err(), "degree cap");
    }

    #[test]
    fn ring_lattice_shape() {
        let t = ImplicitTopology::ring_lattice(11, 6).unwrap();
        assert_eq!(t.n(), 11);
        assert_eq!(t.degree(), 6);
        assert_eq!(t.m(), 33);
        assert_eq!(t.half_offsets(), &[1, 2, 3]);
        assert!(ImplicitTopology::ring_lattice(6, 6).is_err(), "d/2 > (n-1)/2");
        assert!(ImplicitTopology::ring_lattice(10, 3).is_err(), "odd degree");
    }

    #[test]
    fn neighbors_distinct_and_symmetric() {
        // Interior and wraparound nodes alike: 2|S| distinct neighbors,
        // none equal to the node, and j ∈ N(i) ⟺ i ∈ N(j).
        let t = ImplicitTopology::new(17, vec![1, 4, 7], "t").unwrap();
        let nbrs = |i: usize| {
            let mut buf = [0u32; MAX_IMPLICIT_DEGREE];
            let d = t.fill_sorted(i, &mut buf);
            buf[..d].to_vec()
        };
        for i in 0..17 {
            let ns = nbrs(i);
            assert_eq!(ns.len(), 6);
            let mut dedup = ns.clone();
            dedup.dedup();
            assert_eq!(dedup, ns, "unsorted or duplicate neighbors at {i}: {ns:?}");
            assert!(!ns.contains(&(i as u32)), "self-loop at {i}");
            for &v in &ns {
                assert!(nbrs(v as usize).contains(&(i as u32)), "asymmetry {i}↔{v}");
            }
        }
    }

    #[test]
    fn interior_fast_path_matches_boundary_path() {
        // Force every node through the sort-based derivation and compare
        // with fill_sorted's own (fast-path-for-interior) answer.
        let t = ImplicitTopology::new(40, vec![2, 5, 9], "t").unwrap();
        for i in 0..40 {
            let mut fast = [0u32; MAX_IMPLICIT_DEGREE];
            let d = t.fill_sorted(i, &mut fast);
            let mut slow: Vec<u32> = t
                .half_offsets()
                .iter()
                .flat_map(|&s| {
                    [((i + s as usize) % 40) as u32, ((i + 40 - s as usize) % 40) as u32]
                })
                .collect();
            slow.sort_unstable();
            assert_eq!(&fast[..d], slow.as_slice(), "node {i}");
            // Rank selection agrees with the sorted list.
            for (j, &v) in slow.iter().enumerate() {
                assert_eq!(t.neighbor_sorted(i, j), v as usize, "rank {j} at node {i}");
            }
        }
    }

    #[test]
    fn small_world_deterministic_and_regular() {
        let a = ImplicitTopology::small_world(1001, 8, &mut Rng::new(9)).unwrap();
        let b = ImplicitTopology::small_world(1001, 8, &mut Rng::new(9)).unwrap();
        assert_eq!(a.half_offsets(), b.half_offsets());
        assert_eq!(a.degree(), 8);
        assert_eq!(a.half_offsets()[0], 1, "local band keeps connectivity");
        assert_eq!(a.half_offsets().len(), 4);
        let c = ImplicitTopology::small_world(1001, 8, &mut Rng::new(10)).unwrap();
        assert_ne!(a.half_offsets(), c.half_offsets(), "seed must matter");
    }

    #[test]
    fn memory_is_independent_of_n() {
        let small = ImplicitTopology::ring_lattice(100, 8).unwrap();
        let huge = ImplicitTopology::ring_lattice(100_000_000, 8).unwrap();
        assert_eq!(small.memory_bytes(), huge.memory_bytes());
        assert!(huge.memory_bytes() < 1024, "got {}", huge.memory_bytes());
    }

    #[test]
    fn edge_list_covers_each_edge_once() {
        let t = ImplicitTopology::new(12, vec![1, 3], "t").unwrap();
        let edges = t.edge_list();
        assert_eq!(edges.len(), t.m());
        let mut keys: Vec<(u32, u32)> =
            edges.iter().map(|&(a, b)| if a < b { (a, b) } else { (b, a) }).collect();
        keys.sort_unstable();
        let before = keys.len();
        keys.dedup();
        assert_eq!(keys.len(), before, "duplicate undirected edge");
    }
}
