//! Graph generators for the families evaluated in the paper (Fig. 1–6):
//! random d-regular (the main testbed), Erdős–Rényi, complete and
//! power-law (Barabási–Albert), plus deterministic ring/torus used in
//! tests and the implicit circulant families for the 10⁷–10⁸-node
//! presets. All randomized generators retry until the sample is
//! connected — the paper assumes connectivity (Sec. II) and applies the
//! algorithms per component otherwise.
//!
//! Generator output is simple by construction, so the materializing
//! families build through [`Graph::from_edges_trusted`] (debug builds
//! still validate); [`Graph::from_edges`] remains the validating entry
//! point for untrusted edge lists.

use super::{build, implicit::ImplicitTopology, Graph};
use crate::rng::Rng;
use crate::runtime::pool::WorkerPool;

/// Complete graph `K_n`.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n as u32 {
        for b in (a + 1)..n as u32 {
            edges.push((a, b));
        }
    }
    Graph::from_edges_trusted(n, &edges)
}

/// Cycle graph `C_n` (n >= 3).
pub fn ring(n: usize) -> Graph {
    assert!(n >= 3);
    let edges: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
    Graph::from_edges_trusted(n, &edges)
}

/// 2-D torus grid `w x h` (4-regular when w,h >= 3).
pub fn grid_torus(w: usize, h: usize) -> Graph {
    assert!(w >= 3 && h >= 3);
    let idx = |x: usize, y: usize| (y * w + x) as u32;
    let mut edges = Vec::new();
    for y in 0..h {
        for x in 0..w {
            edges.push((idx(x, y), idx((x + 1) % w, y)));
            edges.push((idx(x, y), idx(x, (y + 1) % h)));
        }
    }
    Graph::from_edges_trusted(w * h, &edges)
}

/// Implicit ring lattice `C_n({1..d/2})` — the d-regular circulant on the
/// implicit backend: zero stored edges, O(1) memory. Offset 1 is always
/// in the set, so the family is connected for every n.
pub fn implicit_ring(n: usize, d: usize) -> anyhow::Result<Graph> {
    Ok(Graph::from_implicit(ImplicitTopology::ring_lattice(n, d)?))
}

/// Implicit degree-preserving small world: `d/4`-ish of the ring
/// lattice's offsets are replaced by seed-derived long-range chords
/// (see `implicit.rs` for why exact Watts–Strogatz rewiring cannot be
/// derived locally). Local offset 1 is always kept, so connectivity
/// holds for every n and seed.
pub fn implicit_small_world(n: usize, d: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    Ok(Graph::from_implicit(ImplicitTopology::small_world(n, d, rng)?))
}

/// Erdős–Rényi `G(n, p)`, resampled until connected (up to `max_tries`).
/// For the paper's regimes (`n = 100`, `p` well above `ln n / n`) a
/// connected sample is found almost immediately.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> anyhow::Result<Graph> {
    anyhow::ensure!((0.0..=1.0).contains(&p), "p out of range");
    let max_tries = 1000;
    for _ in 0..max_tries {
        let mut edges = Vec::new();
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                if rng.bernoulli(p) {
                    edges.push((a, b));
                }
            }
        }
        let g = Graph::from_edges_trusted(n, &edges);
        if g.is_connected() {
            return Ok(g);
        }
    }
    anyhow::bail!("no connected G({n},{p}) sample in {max_tries} tries — p too small?")
}

/// Default ER edge probability for [`by_name`]: 8 expected neighbors,
/// floored at `1.5·ln n / n` for connectivity, capped at 1.0 **last** so
/// the result is always a valid probability (flooring after the cap
/// could push p above 1.0 and make `erdos_renyi` reject its own
/// default).
pub fn er_default_p(n: usize) -> f64 {
    (8.0 / n as f64).max(1.5 * (n as f64).ln() / n as f64).min(1.0)
}

/// Random d-regular graph via the progressive pairing model: shuffle the
/// stub multiset, pair consecutively, recycle clashing stubs (self-loops /
/// multi-edges) into the next round; restart the attempt when a round
/// makes no progress. (Whole-sample rejection is infeasible for d=8 — the
/// probability of a simple pairing is `≈ e^{-(d²-1)/4} ~ 1e-7`.) Resampled
/// until connected. This is the paper's main testbed (8-regular,
/// n ∈ {50, 100, 200}).
pub fn random_regular(n: usize, d: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    random_regular_impl(n, d, rng, None)
}

/// [`random_regular`] with CSR assembly and the connectivity check run
/// on the pool (`build::from_edges_parallel` / `is_connected_parallel`).
/// Consumes the RNG stream identically to the sequential form — only
/// `try_pairing` draws — and both build paths are output-identical, so
/// the sampled graph is **bit-for-bit the same** at any worker count
/// (locked by `tests/graph_backend.rs`).
pub fn random_regular_pooled(
    n: usize,
    d: usize,
    rng: &mut Rng,
    pool: &mut WorkerPool,
) -> anyhow::Result<Graph> {
    random_regular_impl(n, d, rng, Some(pool))
}

fn random_regular_impl(
    n: usize,
    d: usize,
    rng: &mut Rng,
    mut pool: Option<&mut WorkerPool>,
) -> anyhow::Result<Graph> {
    anyhow::ensure!(n * d % 2 == 0, "n*d must be even");
    anyhow::ensure!(d < n, "degree must be < n");
    anyhow::ensure!(d >= 1, "degree must be >= 1");
    let max_tries = 500;
    for _ in 0..max_tries {
        if let Some(edges) = try_pairing(n, d, rng) {
            let (g, connected) = match pool.as_deref_mut() {
                Some(pool) => {
                    let g = build::from_edges_parallel(n, &edges, pool);
                    let ok = build::is_connected_parallel(&g, pool);
                    (g, ok)
                }
                None => {
                    let g = Graph::from_edges_trusted(n, &edges);
                    let ok = g.is_connected();
                    (g, ok)
                }
            };
            if connected {
                return Ok(g);
            }
        }
    }
    anyhow::bail!("no simple connected {d}-regular graph on {n} nodes in {max_tries} tries")
}

/// One progressive-pairing attempt; `None` when stuck.
fn try_pairing(n: usize, d: usize, rng: &mut Rng) -> Option<Vec<(u32, u32)>> {
    let mut stubs: Vec<u32> = (0..n as u32).flat_map(|i| std::iter::repeat(i).take(d)).collect();
    let mut seen = std::collections::HashSet::with_capacity(n * d / 2);
    let mut edges = Vec::with_capacity(n * d / 2);
    while !stubs.is_empty() {
        rng.shuffle(&mut stubs);
        let mut leftover = Vec::new();
        let before = stubs.len();
        for pair in stubs.chunks(2) {
            let (a, b) = (pair[0], pair[1]);
            let key = if a < b { (a, b) } else { (b, a) };
            if a == b || !seen.insert(key) {
                leftover.push(a);
                leftover.push(b);
            } else {
                edges.push((a, b));
            }
        }
        if leftover.len() == before {
            return None; // stuck: e.g. two stubs of the same node remain
        }
        stubs = leftover;
    }
    Some(edges)
}

/// Barabási–Albert preferential-attachment graph: start from a clique of
/// `m0 = m + 1` nodes, each new node attaches to `m` distinct existing
/// nodes with probability proportional to degree. Produces the power-law
/// degree distribution the paper's Fig. 6 uses.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    anyhow::ensure!(m >= 1 && m + 1 <= n, "need 1 <= m < n");
    let m0 = m + 1;
    let mut edges: Vec<(u32, u32)> = Vec::new();
    // Seed clique.
    for a in 0..m0 as u32 {
        for b in (a + 1)..m0 as u32 {
            edges.push((a, b));
        }
    }
    // Repeated-nodes list: each endpoint appearance = one unit of degree.
    let mut targets: Vec<u32> = edges.iter().flat_map(|&(a, b)| [a, b]).collect();
    for v in m0..n {
        let mut chosen = std::collections::HashSet::new();
        while chosen.len() < m {
            let t = *rng.choose(&targets);
            chosen.insert(t);
        }
        for &t in &chosen {
            edges.push((v as u32, t));
            targets.push(v as u32);
            targets.push(t);
        }
    }
    let g = Graph::from_edges_trusted(n, &edges);
    debug_assert!(g.is_connected(), "BA graphs are connected by construction");
    Ok(g)
}

/// The topology families by name: the four from Fig. 6 plus ring/torus
/// and the implicit circulant families. `seed` controls the randomized
/// families.
pub fn by_name(name: &str, n: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    match name {
        "regular" => random_regular(n, 8, rng),
        "complete" => Ok(complete(n)),
        "erdos-renyi" | "er" => erdos_renyi(n, er_default_p(n), rng),
        "power-law" | "ba" => barabasi_albert(n, 4, rng),
        "ring" => Ok(ring(n)),
        "torus" => {
            let w = (n as f64).sqrt().round() as usize;
            anyhow::ensure!(w * w == n, "torus needs square n");
            Ok(grid_torus(w, w))
        }
        "implicit-regular" | "implicit-ring" => implicit_ring(n, 8),
        "implicit-smallworld" | "smallworld" => implicit_small_world(n, 8, rng),
        other => anyhow::bail!("unknown graph family '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_props() {
        let g = complete(10);
        assert_eq!(g.m(), 45);
        assert!((0..10).all(|i| g.degree(i) == 9));
        assert!(g.is_connected());
    }

    #[test]
    fn ring_props() {
        let g = ring(10);
        assert!((0..10).all(|i| g.degree(i) == 2));
        assert!(g.is_connected());
    }

    #[test]
    fn torus_is_4_regular() {
        let g = grid_torus(5, 5);
        assert_eq!(g.n(), 25);
        assert!((0..25).all(|i| g.degree(i) == 4));
        assert!(g.is_connected());
    }

    #[test]
    fn implicit_families_regular_connected_zero_edge_storage() {
        let g = implicit_ring(500, 8).unwrap();
        assert!(g.is_implicit());
        assert_eq!(g.m(), 2000);
        assert!((0..500).all(|i| g.degree(i) == 8));
        assert!(g.is_connected());
        let mut rng = Rng::new(11);
        let sw = implicit_small_world(500, 8, &mut rng).unwrap();
        assert!(sw.is_implicit());
        assert!((0..500).all(|i| sw.degree(i) == 8));
        assert!(sw.is_connected());
        assert!(sw.memory_bytes() < 1024);
    }

    #[test]
    fn implicit_small_world_deterministic_under_seed() {
        let a = implicit_small_world(400, 8, &mut Rng::new(21)).unwrap();
        let b = implicit_small_world(400, 8, &mut Rng::new(21)).unwrap();
        for i in 0..400 {
            assert_eq!(a.neighbors(i).to_vec(), b.neighbors(i));
        }
    }

    #[test]
    fn random_regular_is_regular_and_connected() {
        let mut rng = Rng::new(1);
        for &(n, d) in &[(20, 3), (50, 8), (100, 8)] {
            let g = random_regular(n, d, &mut rng).unwrap();
            assert_eq!(g.n(), n);
            assert!((0..n).all(|i| g.degree(i) == d), "not {d}-regular");
            assert!(g.is_connected());
        }
    }

    #[test]
    fn random_regular_rejects_odd() {
        let mut rng = Rng::new(2);
        assert!(random_regular(5, 3, &mut rng).is_err());
        assert!(random_regular(10, 10, &mut rng).is_err());
    }

    #[test]
    fn random_regular_pooled_matches_sequential() {
        // Below PARALLEL_MIN_EDGES this exercises the fallback plumbing;
        // the above-threshold bit-identity oracle lives in
        // tests/graph_backend.rs.
        let mut pool = WorkerPool::new(3);
        let seq = random_regular(200, 8, &mut Rng::new(31)).unwrap();
        let par = random_regular_pooled(200, 8, &mut Rng::new(31), &mut pool).unwrap();
        assert_eq!(seq.m(), par.m());
        for i in 0..200 {
            assert_eq!(seq.neighbors(i), par.neighbors(i));
        }
    }

    #[test]
    fn erdos_renyi_connected() {
        let mut rng = Rng::new(3);
        let g = erdos_renyi(60, 0.15, &mut rng).unwrap();
        assert!(g.is_connected());
        assert_eq!(g.n(), 60);
    }

    #[test]
    fn er_default_p_is_a_probability_for_all_n() {
        // The old clamp order (`.min(1.0).max(floor)`) applied the
        // connectivity floor after the cap; the fixed order must yield a
        // valid probability and respect the floor for every small n.
        for n in 5..200usize {
            let p = er_default_p(n);
            assert!((0.0..=1.0).contains(&p), "n={n}: p={p} out of range");
            let floor = 1.5 * (n as f64).ln() / n as f64;
            assert!(p >= floor.min(1.0), "n={n}: p={p} below connectivity floor {floor}");
        }
    }

    #[test]
    fn barabasi_albert_degree_tail() {
        let mut rng = Rng::new(4);
        let g = barabasi_albert(300, 4, &mut rng).unwrap();
        assert!(g.is_connected());
        // New nodes attach with m=4 edges, so min degree is 4.
        assert!((0..300).all(|i| g.degree(i) >= 4));
        // Power-law: the max degree should far exceed the median.
        let mut degs: Vec<usize> = (0..300).map(|i| g.degree(i)).collect();
        degs.sort_unstable();
        assert!(degs[299] as f64 > 3.0 * degs[150] as f64, "hub missing: {:?}", &degs[290..]);
    }

    #[test]
    fn by_name_families() {
        let mut rng = Rng::new(5);
        for name in ["regular", "complete", "er", "ba", "implicit-ring", "smallworld"] {
            let g = by_name(name, 64, &mut rng).unwrap();
            assert!(g.is_connected(), "{name} not connected");
        }
        assert!(by_name("nope", 10, &mut rng).is_err());
    }

    #[test]
    fn generators_deterministic_under_seed() {
        let g1 = random_regular(40, 4, &mut Rng::new(9)).unwrap();
        let g2 = random_regular(40, 4, &mut Rng::new(9)).unwrap();
        for i in 0..40 {
            assert_eq!(g1.neighbors(i), g2.neighbors(i));
        }
    }
}
