//! Graph generators for the families evaluated in the paper (Fig. 1–6):
//! random d-regular (the main testbed), Erdős–Rényi, complete and
//! power-law (Barabási–Albert), plus deterministic ring/torus used in
//! tests. All randomized generators retry until the sample is connected —
//! the paper assumes connectivity (Sec. II) and applies the algorithms per
//! component otherwise.

use super::Graph;
use crate::rng::Rng;

/// Complete graph `K_n`.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n as u32 {
        for b in (a + 1)..n as u32 {
            edges.push((a, b));
        }
    }
    Graph::from_edges(n, &edges).expect("complete graph is simple")
}

/// Cycle graph `C_n` (n >= 3).
pub fn ring(n: usize) -> Graph {
    assert!(n >= 3);
    let edges: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
    Graph::from_edges(n, &edges).expect("ring is simple")
}

/// 2-D torus grid `w x h` (4-regular when w,h >= 3).
pub fn grid_torus(w: usize, h: usize) -> Graph {
    assert!(w >= 3 && h >= 3);
    let idx = |x: usize, y: usize| (y * w + x) as u32;
    let mut edges = Vec::new();
    for y in 0..h {
        for x in 0..w {
            edges.push((idx(x, y), idx((x + 1) % w, y)));
            edges.push((idx(x, y), idx(x, (y + 1) % h)));
        }
    }
    Graph::from_edges(w * h, &edges).expect("torus is simple")
}

/// Erdős–Rényi `G(n, p)`, resampled until connected (up to `max_tries`).
/// For the paper's regimes (`n = 100`, `p` well above `ln n / n`) a
/// connected sample is found almost immediately.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> anyhow::Result<Graph> {
    anyhow::ensure!((0.0..=1.0).contains(&p), "p out of range");
    let max_tries = 1000;
    for _ in 0..max_tries {
        let mut edges = Vec::new();
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                if rng.bernoulli(p) {
                    edges.push((a, b));
                }
            }
        }
        let g = Graph::from_edges(n, &edges)?;
        if g.is_connected() {
            return Ok(g);
        }
    }
    anyhow::bail!("no connected G({n},{p}) sample in {max_tries} tries — p too small?")
}

/// Random d-regular graph via the progressive pairing model: shuffle the
/// stub multiset, pair consecutively, recycle clashing stubs (self-loops /
/// multi-edges) into the next round; restart the attempt when a round
/// makes no progress. (Whole-sample rejection is infeasible for d=8 — the
/// probability of a simple pairing is `≈ e^{-(d²-1)/4} ~ 1e-7`.) Resampled
/// until connected. This is the paper's main testbed (8-regular,
/// n ∈ {50, 100, 200}).
pub fn random_regular(n: usize, d: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    anyhow::ensure!(n * d % 2 == 0, "n*d must be even");
    anyhow::ensure!(d < n, "degree must be < n");
    anyhow::ensure!(d >= 1, "degree must be >= 1");
    let max_tries = 500;
    for _ in 0..max_tries {
        if let Some(edges) = try_pairing(n, d, rng) {
            let g = Graph::from_edges(n, &edges)?;
            if g.is_connected() {
                return Ok(g);
            }
        }
    }
    anyhow::bail!("no simple connected {d}-regular graph on {n} nodes in {max_tries} tries")
}

/// One progressive-pairing attempt; `None` when stuck.
fn try_pairing(n: usize, d: usize, rng: &mut Rng) -> Option<Vec<(u32, u32)>> {
    let mut stubs: Vec<u32> = (0..n as u32).flat_map(|i| std::iter::repeat(i).take(d)).collect();
    let mut seen = std::collections::HashSet::with_capacity(n * d / 2);
    let mut edges = Vec::with_capacity(n * d / 2);
    while !stubs.is_empty() {
        rng.shuffle(&mut stubs);
        let mut leftover = Vec::new();
        let before = stubs.len();
        for pair in stubs.chunks(2) {
            let (a, b) = (pair[0], pair[1]);
            let key = if a < b { (a, b) } else { (b, a) };
            if a == b || !seen.insert(key) {
                leftover.push(a);
                leftover.push(b);
            } else {
                edges.push((a, b));
            }
        }
        if leftover.len() == before {
            return None; // stuck: e.g. two stubs of the same node remain
        }
        stubs = leftover;
    }
    Some(edges)
}

/// Barabási–Albert preferential-attachment graph: start from a clique of
/// `m0 = m + 1` nodes, each new node attaches to `m` distinct existing
/// nodes with probability proportional to degree. Produces the power-law
/// degree distribution the paper's Fig. 6 uses.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    anyhow::ensure!(m >= 1 && m + 1 <= n, "need 1 <= m < n");
    let m0 = m + 1;
    let mut edges: Vec<(u32, u32)> = Vec::new();
    // Seed clique.
    for a in 0..m0 as u32 {
        for b in (a + 1)..m0 as u32 {
            edges.push((a, b));
        }
    }
    // Repeated-nodes list: each endpoint appearance = one unit of degree.
    let mut targets: Vec<u32> = edges.iter().flat_map(|&(a, b)| [a, b]).collect();
    for v in m0..n {
        let mut chosen = std::collections::HashSet::new();
        while chosen.len() < m {
            let t = *rng.choose(&targets);
            chosen.insert(t);
        }
        for &t in &chosen {
            edges.push((v as u32, t));
            targets.push(v as u32);
            targets.push(t);
        }
    }
    let g = Graph::from_edges(n, &edges)?;
    debug_assert!(g.is_connected(), "BA graphs are connected by construction");
    Ok(g)
}

/// The four topology families from Fig. 6, by name. `seed` controls the
/// randomized families.
pub fn by_name(name: &str, n: usize, rng: &mut Rng) -> anyhow::Result<Graph> {
    match name {
        "regular" => random_regular(n, 8, rng),
        "complete" => Ok(complete(n)),
        "erdos-renyi" | "er" => erdos_renyi(n, (8.0 / n as f64).min(1.0).max(1.5 * (n as f64).ln() / n as f64), rng),
        "power-law" | "ba" => barabasi_albert(n, 4, rng),
        "ring" => Ok(ring(n)),
        "torus" => {
            let w = (n as f64).sqrt().round() as usize;
            anyhow::ensure!(w * w == n, "torus needs square n");
            Ok(grid_torus(w, w))
        }
        other => anyhow::bail!("unknown graph family '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_props() {
        let g = complete(10);
        assert_eq!(g.m(), 45);
        assert!((0..10).all(|i| g.degree(i) == 9));
        assert!(g.is_connected());
    }

    #[test]
    fn ring_props() {
        let g = ring(10);
        assert!((0..10).all(|i| g.degree(i) == 2));
        assert!(g.is_connected());
    }

    #[test]
    fn torus_is_4_regular() {
        let g = grid_torus(5, 5);
        assert_eq!(g.n(), 25);
        assert!((0..25).all(|i| g.degree(i) == 4));
        assert!(g.is_connected());
    }

    #[test]
    fn random_regular_is_regular_and_connected() {
        let mut rng = Rng::new(1);
        for &(n, d) in &[(20, 3), (50, 8), (100, 8)] {
            let g = random_regular(n, d, &mut rng).unwrap();
            assert_eq!(g.n(), n);
            assert!((0..n).all(|i| g.degree(i) == d), "not {d}-regular");
            assert!(g.is_connected());
        }
    }

    #[test]
    fn random_regular_rejects_odd() {
        let mut rng = Rng::new(2);
        assert!(random_regular(5, 3, &mut rng).is_err());
        assert!(random_regular(10, 10, &mut rng).is_err());
    }

    #[test]
    fn erdos_renyi_connected() {
        let mut rng = Rng::new(3);
        let g = erdos_renyi(60, 0.15, &mut rng).unwrap();
        assert!(g.is_connected());
        assert_eq!(g.n(), 60);
    }

    #[test]
    fn barabasi_albert_degree_tail() {
        let mut rng = Rng::new(4);
        let g = barabasi_albert(300, 4, &mut rng).unwrap();
        assert!(g.is_connected());
        // New nodes attach with m=4 edges, so min degree is 4.
        assert!((0..300).all(|i| g.degree(i) >= 4));
        // Power-law: the max degree should far exceed the median.
        let mut degs: Vec<usize> = (0..300).map(|i| g.degree(i)).collect();
        degs.sort_unstable();
        assert!(degs[299] as f64 > 3.0 * degs[150] as f64, "hub missing: {:?}", &degs[290..]);
    }

    #[test]
    fn by_name_families() {
        let mut rng = Rng::new(5);
        for name in ["regular", "complete", "er", "ba"] {
            let g = by_name(name, 64, &mut rng).unwrap();
            assert!(g.is_connected(), "{name} not connected");
        }
        assert!(by_name("nope", 10, &mut rng).is_err());
    }

    #[test]
    fn generators_deterministic_under_seed() {
        let g1 = random_regular(40, 4, &mut Rng::new(9)).unwrap();
        let g2 = random_regular(40, 4, &mut Rng::new(9)).unwrap();
        for i in 0..40 {
            assert_eq!(g1.neighbors(i), g2.neighbors(i));
        }
    }
}
