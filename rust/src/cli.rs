//! Minimal command-line argument parser (the vendored crate set has no
//! `clap`): `program SUBCOMMAND [--flag value] [--switch]`.

use std::collections::HashMap;

/// Shared validation for every "how many shards/cores" knob (`--shards`
/// / `DECAFORK_SHARDS` / `--cores` / `DECAFORK_CORES`): a positive
/// integer, with a clear error naming the knob for both the zero and the
/// non-numeric case (no panic, no silent fallback — a typo'd value in a
/// CI matrix must not quietly turn the whole matrix into 1-shard runs
/// that test nothing).
pub fn positive_count(knob: &str, v: &str) -> anyhow::Result<usize> {
    match v.trim().parse::<usize>() {
        Ok(s) if s >= 1 => Ok(s),
        Ok(_) => anyhow::bail!("{knob}={v} is invalid: must be >= 1"),
        Err(_) => anyhow::bail!("{knob}={v} is invalid: need an integer >= 1"),
    }
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                anyhow::ensure!(!name.is_empty(), "empty flag name");
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                anyhow::bail!("unexpected positional argument '{a}'");
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("flag --{name}={v}: {e}")),
        }
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let v = self
            .flags
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))?;
        v.parse().map_err(|e| anyhow::anyhow!("flag --{name}={v}: {e}"))
    }

    /// String flag with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse("simulate --n 100 --eps 2.0 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get::<usize>("n", 0).unwrap(), 100);
        assert!((a.get::<f64>("eps", 0.0).unwrap() - 2.0).abs() < 1e-12);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_requires() {
        let a = parse("x --set 5");
        assert_eq!(a.get::<u64>("missing", 7).unwrap(), 7);
        assert_eq!(a.require::<u64>("set").unwrap(), 5);
        assert!(a.require::<u64>("missing").is_err());
        assert!(a.get::<u64>("set", 0).is_ok());
    }

    #[test]
    fn bad_values_error() {
        let a = parse("x --n abc");
        assert!(a.get::<usize>("n", 0).is_err());
    }

    #[test]
    fn rejects_extra_positionals() {
        assert!(Args::parse(vec!["a".into(), "b".into()]).is_err());
    }

    #[test]
    fn consecutive_switches() {
        let a = parse("run --fast --loud --n 3");
        assert!(a.has("fast") && a.has("loud"));
        assert_eq!(a.get::<usize>("n", 0).unwrap(), 3);
    }
}
