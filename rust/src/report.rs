//! Reporting utilities for the figure harnesses: CSV output, ASCII line
//! plots (so every paper figure renders directly in the terminal / bench
//! log) and aligned tables.

use std::io::Write;
use std::path::Path;

/// Write a CSV file: `headers` then one row per record.
pub fn write_csv<P: AsRef<Path>>(path: P, headers: &[&str], rows: &[Vec<f64>]) -> anyhow::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        let line: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Downsample a series to at most `n` points (mean pooling) so plots of
/// 10k-step traces stay readable.
pub fn downsample(xs: &[f64], n: usize) -> Vec<f64> {
    if xs.len() <= n || n == 0 {
        return xs.to_vec();
    }
    let chunk = (xs.len() + n - 1) / n;
    xs.chunks(chunk)
        .map(|c| c.iter().sum::<f64>() / c.len() as f64)
        .collect()
}

/// Render one or more series as an ASCII line plot with a y-axis.
/// Each series gets a distinct glyph; series share the x domain
/// `[0, len)` and are downsampled to the plot width.
pub fn ascii_plot(title: &str, series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, ys) in series {
        for &y in *ys {
            if y.is_finite() {
                lo = lo.min(y);
                hi = hi.max(y);
            }
        }
    }
    if !lo.is_finite() || !hi.is_finite() {
        return format!("{title}\n(empty)\n");
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let ds = downsample(ys, width);
        let g = GLYPHS[si % GLYPHS.len()];
        for (x, &y) in ds.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let row = ((y - lo) / (hi - lo) * (height - 1) as f64).round() as usize;
            let row = height - 1 - row.min(height - 1);
            let col = x * width / ds.len().max(1);
            if col < width {
                grid[row][col] = g;
            }
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (r, row) in grid.iter().enumerate() {
        let yval = hi - (hi - lo) * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:8.2} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:8} +{}\n", "", "-".repeat(width)));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", GLYPHS[i % GLYPHS.len()], name))
        .collect();
    out.push_str(&format!("{:10}{}\n", "", legend.join("   ")));
    out
}

/// Render a byte count for run summaries: `512 B`, `4.0 KiB`,
/// `1.5 MiB`, `2.3 GiB` — the visited-state footprint lines use this
/// so a 100M-node run reads as gigabytes, not a 10-digit integer.
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit + 1 < UNITS.len() {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Simple aligned table rendering.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn downsample_preserves_short() {
        let xs = vec![1.0, 2.0, 3.0];
        assert_eq!(downsample(&xs, 10), xs);
    }

    #[test]
    fn downsample_pools_means() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let d = downsample(&xs, 10);
        assert_eq!(d.len(), 10);
        assert!((d[0] - 4.5).abs() < 1e-12);
    }

    #[test]
    fn plot_contains_series_glyphs() {
        let ys1: Vec<f64> = (0..50).map(|i| (i as f64 / 5.0).sin()).collect();
        let ys2: Vec<f64> = (0..50).map(|i| (i as f64 / 5.0).cos()).collect();
        let p = ascii_plot("test", &[("sin", &ys1), ("cos", &ys2)], 40, 10);
        assert!(p.contains('*'));
        assert!(p.contains('o'));
        assert!(p.contains("sin"));
        assert!(p.contains("cos"));
    }

    #[test]
    fn plot_handles_flat_series() {
        let ys = vec![5.0; 10];
        let p = ascii_plot("flat", &[("c", &ys)], 20, 5);
        assert!(p.contains('*'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("longer"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn human_bytes_picks_sane_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(4096), "4.0 KiB");
        assert_eq!(human_bytes(1_572_864), "1.5 MiB");
        assert_eq!(human_bytes(usize::MAX).split_whitespace().nth(1), Some("GiB"));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("decafork_test_csv");
        let path = dir.join("x.csv");
        write_csv(&path, &["t", "z"], &[vec![0.0, 10.0], vec![1.0, 9.5]]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("t,z\n0,10\n"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
