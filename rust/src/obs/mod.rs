//! Streaming metrics sinks (DESIGN.md §Observability).
//!
//! [`crate::runtime::telemetry`] is the measurement substrate — phase
//! spans, worker counters, merge-side tallies. This module is the
//! egress: every `--metrics-every` steps the engine hands the open
//! period to a [`MetricsSink`], which formats one step record (JSONL or
//! CSV) and streams it to `--metrics-out`. Records carry the
//! paper-facing series next to the engine internals: Z_t, the θ̂
//! mean/min/max over the period's control decisions, steps since the
//! last failure, and the time-to-recovery after each failure burst
//! (detection latency — how long until Z_t climbs back to its
//! pre-burst level).
//!
//! The sink runs strictly **after** the step's trace updates, on the
//! coordinator, and does nothing but read accumulated numbers and
//! write bytes — it can slow a run down, never change it. Traces are
//! bit-identical for `off`/`jsonl`/`csv` (test-locked like every other
//! A/B knob). IO failures print one warning to stderr and self-disable
//! the sink rather than poisoning a long run.
//!
//! No `serde` exists in the vendored dependency set: JSONL is
//! hand-formatted (all fields are numbers or `null`, so escaping never
//! arises), and the tests hand-parse lines back with a string scanner.

use std::fs::File;
use std::io::{BufWriter, Write};

use crate::runtime::telemetry::{PeriodStats, Phase, Telemetry};

/// Output format selector for `--metrics` / `DECAFORK_METRICS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// No sink, no recording — the compiled-out baseline.
    #[default]
    Off,
    /// One JSON object per line (NDJSON), self-describing keys.
    Jsonl,
    /// Header row + one comma-separated row per record.
    Csv,
}

impl MetricsMode {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricsMode::Off => "off",
            MetricsMode::Jsonl => "jsonl",
            MetricsMode::Csv => "csv",
        }
    }
}

/// Everything the engines need to know about metrics, carried on
/// `SimParams`. Default is `Off` — telemetry is strictly opt-in, and
/// every pre-existing scenario is unchanged.
#[derive(Debug, Clone, Default)]
pub struct MetricsConfig {
    pub mode: MetricsMode,
    /// Output path; `None` defaults to `metrics.jsonl` / `metrics.csv`
    /// in the working directory.
    pub out: Option<String>,
    /// Flush period in steps (`--metrics-every`, ≥ 1). Records are
    /// period *totals*, so nothing is lost at coarse periods.
    pub every: u64,
}

impl MetricsConfig {
    /// Whether any telemetry should be recorded at all.
    pub fn enabled(&self) -> bool {
        self.mode != MetricsMode::Off
    }

    /// The effective flush period (treats an unset 0 as 1).
    pub fn period(&self) -> u64 {
        self.every.max(1)
    }

    /// The effective output path.
    pub fn out_path(&self) -> String {
        match (&self.out, self.mode) {
            (Some(p), _) => p.clone(),
            (None, MetricsMode::Csv) => "metrics.csv".to_string(),
            (None, _) => "metrics.jsonl".to_string(),
        }
    }
}

/// CSV column order — single-sourced so the header and the row
/// formatter cannot drift apart (JSONL reuses the same names as keys).
const COLUMNS: [&str; 26] = [
    "t",
    "z",
    "steps",
    "pre_step_ns",
    "hop_ns",
    "control_ns",
    "merge_ns",
    "hopped",
    "hop_deaths",
    "arrivals_binned",
    "visits",
    "materializations",
    "probe_samples",
    "probe_len_total",
    "forks",
    "terminations",
    "failures",
    "shard_arrivals_min",
    "shard_arrivals_max",
    "theta_n",
    "theta_mean",
    "theta_min",
    "theta_max",
    "steps_since_failure",
    "recovery_steps",
    "pool_dispatches",
];

/// The streaming sink: owns the output file (opened lazily at the
/// first flush), the flush period, and the failure/recovery state
/// machine that turns the raw failure tallies into detection-latency
/// episodes.
pub struct MetricsSink {
    mode: MetricsMode,
    every: u64,
    path: String,
    out: Option<BufWriter<File>>,
    wrote_header: bool,
    /// Sink disabled after an IO error (warn once, never poison a run).
    dead: bool,
    /// Step of the most recent failure event, for `steps_since_failure`.
    last_failure_t: Option<u64>,
    /// Open recovery episode: `(step the burst hit, Z_t to climb back
    /// to)`. Opens at the first failure while closed (target = Z_t just
    /// before that step); later failures inside an open episode deepen
    /// it but don't reset the clock; closes when Z_t ≥ target.
    episode: Option<(u64, u32)>,
    /// Recovery duration completed since the last flush (emitted once).
    pending_recovery: Option<u64>,
    /// Z_t after the previous step — the pre-burst level a new episode
    /// targets.
    prev_z: u32,
}

impl MetricsSink {
    /// Build a sink from config; `None` when the mode is `Off`.
    pub fn new(cfg: &MetricsConfig) -> Option<MetricsSink> {
        if !cfg.enabled() {
            return None;
        }
        Some(MetricsSink {
            mode: cfg.mode,
            every: cfg.period(),
            path: cfg.out_path(),
            out: None,
            wrote_header: false,
            dead: false,
            last_failure_t: None,
            episode: None,
            pending_recovery: None,
            prev_z: 0,
        })
    }

    /// Seed the recovery state machine with the population before the
    /// first step (so a burst on step 1 targets Z0, not 0).
    pub fn prime(&mut self, z0: u32) {
        self.prev_z = z0;
    }

    /// Close one step: advance the failure/recovery state machine and,
    /// on flush boundaries, stream one record built from the telemetry
    /// period. Runs after the step's trace updates; reads only.
    /// `pool_dispatches` is the worker pool's lifetime dispatch count
    /// (`None` for pool-less engines → `null`/blank in the record).
    pub fn on_step(
        &mut self,
        t: u64,
        z: u32,
        failures_this_step: u64,
        tel: &mut Telemetry,
        pool_dispatches: Option<u64>,
    ) {
        if failures_this_step > 0 {
            self.last_failure_t = Some(t);
            if self.episode.is_none() {
                self.episode = Some((t, self.prev_z));
            }
        }
        if let Some((t_open, target)) = self.episode {
            if z >= target {
                self.episode = None;
                self.pending_recovery = Some(t - t_open);
            }
        }
        self.prev_z = z;
        if t % self.every == 0 {
            self.flush(t, z, tel, pool_dispatches);
            tel.reset_period();
            self.pending_recovery = None;
        }
    }

    fn flush(&mut self, t: u64, z: u32, tel: &Telemetry, pool_dispatches: Option<u64>) {
        if self.dead {
            return;
        }
        let line = self.format_record(t, z, tel.period(), pool_dispatches);
        if self.out.is_none() {
            match File::create(&self.path) {
                Ok(f) => self.out = Some(BufWriter::new(f)),
                Err(e) => {
                    eprintln!("decafork: metrics sink disabled: cannot open '{}': {e}", self.path);
                    self.dead = true;
                    return;
                }
            }
        }
        let w = self.out.as_mut().expect("sink file just opened");
        let res = (|| -> std::io::Result<()> {
            if self.mode == MetricsMode::Csv && !self.wrote_header {
                writeln!(w, "{}", COLUMNS.join(","))?;
                self.wrote_header = true;
            }
            writeln!(w, "{line}")?;
            w.flush()
        })();
        if let Err(e) = res {
            eprintln!("decafork: metrics sink disabled: write to '{}' failed: {e}", self.path);
            self.dead = true;
        }
    }

    /// One record, in the configured format. Values are the period
    /// *totals* since the previous flush plus the instantaneous t / Z_t.
    fn format_record(
        &self,
        t: u64,
        z: u32,
        p: &PeriodStats,
        pool_dispatches: Option<u64>,
    ) -> String {
        let steps_since_failure = self.last_failure_t.map(|f| t - f);
        // Columns, in COLUMNS order, as (value, is_null) strings.
        let opt_u64 = |v: Option<u64>| v.map(|v| v.to_string());
        let opt_f64 = |v: Option<f64>| v.map(fmt_f64);
        let theta_min = (p.theta_n > 0).then_some(p.theta_min);
        let theta_max = (p.theta_n > 0).then_some(p.theta_max);
        let values: [Option<String>; 26] = [
            Some(t.to_string()),
            Some(z.to_string()),
            Some(p.steps.to_string()),
            Some(p.span_ns[Phase::PreStep as usize].to_string()),
            Some(p.span_ns[Phase::Hop as usize].to_string()),
            Some(p.span_ns[Phase::Control as usize].to_string()),
            Some(p.span_ns[Phase::Merge as usize].to_string()),
            Some(p.counters.hopped.to_string()),
            Some(p.counters.hop_deaths.to_string()),
            Some(p.counters.arrivals_binned.to_string()),
            Some(p.counters.visits.to_string()),
            Some(p.counters.materializations.to_string()),
            Some(p.counters.probe_samples.to_string()),
            Some(p.counters.probe_len_total.to_string()),
            Some(p.forks.to_string()),
            Some(p.terminations.to_string()),
            Some(p.failures.to_string()),
            Some(p.shard_arrivals_min.to_string()),
            Some(p.shard_arrivals_max.to_string()),
            Some(p.theta_n.to_string()),
            opt_f64(p.theta_mean()),
            opt_f64(theta_min),
            opt_f64(theta_max),
            opt_u64(steps_since_failure),
            opt_u64(self.pending_recovery),
            opt_u64(pool_dispatches),
        ];
        match self.mode {
            MetricsMode::Jsonl => {
                let fields: Vec<String> = COLUMNS
                    .iter()
                    .zip(values.iter())
                    .map(|(k, v)| {
                        format!("\"{k}\":{}", v.as_deref().unwrap_or("null"))
                    })
                    .collect();
                format!("{{{}}}", fields.join(","))
            }
            MetricsMode::Csv | MetricsMode::Off => values
                .iter()
                .map(|v| v.as_deref().unwrap_or("").to_string())
                .collect::<Vec<_>>()
                .join(","),
        }
    }
}

/// `f64` → JSON number. `{:?}` round-trips f64 exactly (shortest
/// representation) and never produces bare `NaN`-unfriendly output for
/// the finite θ̂ values the engine emits; guard anyway so a pathological
/// control rule cannot emit invalid JSON.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:?}")
    } else {
        "null".to_string()
    }
}

/// Extract field `key` from a hand-formatted JSONL line as a raw token
/// (number or `null`). Test/CI helper — the emitter writes flat objects
/// with unescaped keys, so a string scan is exact.
pub fn jsonl_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    Some(rest[..end].trim())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::Decafork;
    use crate::failures::Burst;
    use crate::graph::generators;
    use crate::rng::Rng;
    use crate::sim::{ShardedEngine, SimParams};

    fn tmp(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("decafork_obs_{}_{}", std::process::id(), name));
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn config_defaults_and_paths() {
        let d = MetricsConfig::default();
        assert_eq!(d.mode, MetricsMode::Off);
        assert!(!d.enabled());
        assert_eq!(d.period(), 1);
        assert!(MetricsSink::new(&d).is_none());
        assert_eq!(d.out_path(), "metrics.jsonl");
        let c = MetricsConfig { mode: MetricsMode::Csv, out: None, every: 10 };
        assert_eq!(c.out_path(), "metrics.csv");
        let j = MetricsConfig {
            mode: MetricsMode::Jsonl,
            out: Some("x.ndjson".into()),
            every: 10,
        };
        assert_eq!(j.out_path(), "x.ndjson");
        assert_eq!(j.period(), 10);
    }

    #[test]
    fn jsonl_field_scans_numbers_and_nulls() {
        let line = r#"{"t":12,"z":40,"theta_mean":1.25,"recovery_steps":null}"#;
        assert_eq!(jsonl_field(line, "t"), Some("12"));
        assert_eq!(jsonl_field(line, "theta_mean"), Some("1.25"));
        assert_eq!(jsonl_field(line, "recovery_steps"), Some("null"));
        assert_eq!(jsonl_field(line, "missing"), None);
    }

    #[test]
    fn recovery_episode_measures_return_to_preburst_z() {
        let cfg = MetricsConfig {
            mode: MetricsMode::Jsonl,
            out: Some(tmp("episode.jsonl")),
            every: 1,
        };
        let mut sink = MetricsSink::new(&cfg).unwrap();
        let mut tel = Telemetry::new(true);
        sink.prime(10);
        // Steps 1-2 healthy, burst at 3 (z drops to 4), climb back by 6.
        for (t, z, f) in [(1, 10, 0), (2, 10, 0), (3, 4, 6), (4, 6, 0), (5, 8, 0)] {
            sink.on_step(t, z, f, &mut tel, None);
            assert_eq!(sink.pending_recovery, None);
            assert_eq!(sink.episode.is_some(), t >= 3);
        }
        tel.end_step();
        sink.on_step(6, 10, 0, &mut tel, Some(42));
        // Flushed (every=1) so pending cleared, but the record carried it.
        assert_eq!(sink.episode, None);
        let body = std::fs::read_to_string(cfg.out_path()).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 6);
        assert_eq!(jsonl_field(lines[5], "recovery_steps"), Some("3"));
        assert_eq!(jsonl_field(lines[4], "recovery_steps"), Some("null"));
        assert_eq!(jsonl_field(lines[2], "steps_since_failure"), Some("0"));
        assert_eq!(jsonl_field(lines[5], "steps_since_failure"), Some("3"));
        assert_eq!(jsonl_field(lines[1], "steps_since_failure"), Some("null"));
        assert_eq!(jsonl_field(lines[5], "pool_dispatches"), Some("42"));
        assert_eq!(jsonl_field(lines[4], "pool_dispatches"), Some("null"));
        std::fs::remove_file(cfg.out_path()).ok();
    }

    /// End-to-end: run a sharded engine with the jsonl sink on, parse
    /// every emitted line back, and check Z_t and the event totals
    /// against the in-memory `Trace` (ISSUE 10 satellite 4).
    #[test]
    fn jsonl_records_match_in_memory_trace() {
        use crate::sim::metrics::EventKind;
        let path = tmp("roundtrip.jsonl");
        let graph =
            std::sync::Arc::new(generators::random_regular(30, 4, &mut Rng::new(7)).unwrap());
        let params = SimParams {
            z0: 8,
            record_theta: true,
            metrics: MetricsConfig {
                mode: MetricsMode::Jsonl,
                out: Some(path.clone()),
                every: 5,
            },
            ..Default::default()
        };
        let mut e = ShardedEngine::new(
            graph,
            params,
            Decafork::new(2.0),
            Burst::new(vec![(100, 4), (300, 3)]),
            Rng::new(11),
            4,
        );
        e.run_to(600);
        let trace = e.into_trace();
        assert!(!trace.extinct, "scenario must survive for exact row-count accounting");

        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 600 / 5, "one record per flush period");
        let (mut forks, mut terms, mut fails, mut theta_n) = (0u64, 0u64, 0u64, 0u64);
        for line in &lines {
            assert!(line.starts_with('{') && line.ends_with('}'), "well-formed: {line}");
            let t: usize = jsonl_field(line, "t").unwrap().parse().unwrap();
            let z: u32 = jsonl_field(line, "z").unwrap().parse().unwrap();
            assert_eq!(z, trace.z[t], "Z_t at t={t} must match the trace");
            assert_eq!(jsonl_field(line, "steps").unwrap(), "5");
            forks += jsonl_field(line, "forks").unwrap().parse::<u64>().unwrap();
            terms += jsonl_field(line, "terminations").unwrap().parse::<u64>().unwrap();
            fails += jsonl_field(line, "failures").unwrap().parse::<u64>().unwrap();
            theta_n += jsonl_field(line, "theta_n").unwrap().parse::<u64>().unwrap();
            let hopped: u64 = jsonl_field(line, "hopped").unwrap().parse().unwrap();
            assert!(hopped > 0, "walks hopped every period");
        }
        assert_eq!(forks, trace.count(EventKind::Fork) as u64);
        assert_eq!(terms, trace.count(EventKind::ControlTermination) as u64);
        assert_eq!(fails, trace.count(EventKind::Failure) as u64);
        assert_eq!(theta_n, trace.theta.len() as u64, "every θ̂ decision streamed");
        assert!(forks > 0 && fails > 0, "vacuous without events");
        std::fs::remove_file(&path).ok();
    }

    /// CSV sink: header + rows, blank cells for nulls, same cadence.
    #[test]
    fn csv_sink_writes_header_and_rows() {
        let path = tmp("rows.csv");
        let cfg = MetricsConfig {
            mode: MetricsMode::Csv,
            out: Some(path.clone()),
            every: 2,
        };
        let mut sink = MetricsSink::new(&cfg).unwrap();
        let mut tel = Telemetry::new(true);
        sink.prime(4);
        for t in 1..=6 {
            tel.end_step();
            sink.on_step(t, 4, 0, &mut tel, None);
        }
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 1 + 3, "header + one row per period");
        assert_eq!(lines[0], COLUMNS.join(","));
        let row: Vec<&str> = lines[1].split(',').collect();
        assert_eq!(row.len(), COLUMNS.len());
        assert_eq!(row[0], "2");
        assert_eq!(row[1], "4");
        assert_eq!(row[2], "2", "period folds every step");
        let ssf = COLUMNS.iter().position(|&c| c == "steps_since_failure").unwrap();
        assert_eq!(row[ssf], "", "null → blank cell");
        std::fs::remove_file(&path).ok();
    }
}
