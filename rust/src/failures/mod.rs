//! Failure/threat models (Sec. II): burst failures, per-step probabilistic
//! failures, and a Byzantine node driven by a two-state Markov chain that
//! terminates every incoming walk while in its `Byz` state. The control
//! algorithms make **no assumption** about which of these is active — the
//! models exist to stress them, mirroring Figs. 1–3.

use crate::rng::Rng;
use crate::walks::WalkId;

/// Closed-world enum over the failure models, used by the arena engine's
/// hot loop: the `match` dispatch is visible to the compiler, so the
/// per-hop checks (`on_hop`, `on_arrival`) inline into the hop loop
/// instead of going through a vtable per visit. The open trait below
/// remains for the frozen reference engine and external extensions.
///
/// Semantics mirror the trait implementations exactly (the composite
/// variant unions kills with the same sort+dedup and the same
/// short-circuiting as [`Composite`]), so enum- and box-dispatched
/// engines consume identical RNG streams.
#[derive(Debug, Clone)]
pub enum Failures {
    None(NoFailures),
    Burst(Burst),
    Probabilistic(Probabilistic),
    Byzantine(Byzantine),
    Composite(Vec<Failures>),
}

impl Failures {
    /// Combine several models; a walk dies if any component kills it.
    pub fn composite(parts: Vec<Failures>) -> Self {
        Failures::Composite(parts)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Failures::None(f) => f.name(),
            Failures::Burst(f) => f.name(),
            Failures::Probabilistic(f) => f.name(),
            Failures::Byzantine(f) => f.name(),
            Failures::Composite(_) => "composite",
        }
    }

    /// Walks to kill at the start of step `t` (see [`FailureModel::pre_step`]).
    pub fn pre_step(&mut self, t: u64, alive: &[WalkId], rng: &mut Rng) -> Vec<WalkId> {
        match self {
            Failures::None(f) => f.pre_step(t, alive, rng),
            Failures::Burst(f) => f.pre_step(t, alive, rng),
            Failures::Probabilistic(f) => f.pre_step(t, alive, rng),
            Failures::Byzantine(f) => f.pre_step(t, alive, rng),
            Failures::Composite(parts) => {
                let mut killed = Vec::new();
                for p in parts {
                    killed.extend(p.pre_step(t, alive, rng));
                }
                killed.sort_unstable();
                killed.dedup();
                killed
            }
        }
    }

    /// Whether the walk dies while hopping `from → to` at step `t`.
    #[inline]
    pub fn on_hop(&mut self, t: u64, walk: WalkId, from: u32, to: u32, rng: &mut Rng) -> bool {
        match self {
            Failures::None(f) => f.on_hop(t, walk, from, to, rng),
            Failures::Burst(f) => f.on_hop(t, walk, from, to, rng),
            Failures::Probabilistic(f) => f.on_hop(t, walk, from, to, rng),
            Failures::Byzantine(f) => f.on_hop(t, walk, from, to, rng),
            Failures::Composite(parts) => {
                parts.iter_mut().any(|p| p.on_hop(t, walk, from, to, rng))
            }
        }
    }

    /// Whether the walk dies upon arriving at `node` at step `t`.
    #[inline]
    pub fn on_arrival(&mut self, t: u64, walk: WalkId, node: u32, rng: &mut Rng) -> bool {
        match self {
            Failures::None(f) => f.on_arrival(t, walk, node, rng),
            Failures::Burst(f) => f.on_arrival(t, walk, node, rng),
            Failures::Probabilistic(f) => f.on_arrival(t, walk, node, rng),
            Failures::Byzantine(f) => f.on_arrival(t, walk, node, rng),
            Failures::Composite(parts) => {
                parts.iter_mut().any(|p| p.on_arrival(t, walk, node, rng))
            }
        }
    }

    /// Refresh a per-worker scratch copy from the coordinator's master
    /// model after the master's `pre_step` ran. The only state
    /// `pre_step` mutates that the worker-side hooks later *read* is
    /// the Byzantine occupation flag (`Byzantine::byz`, consulted by
    /// `on_arrival`); everything else a model holds is either immutable
    /// configuration (burst schedules, probabilities) that the initial
    /// clone already carries, or coordinator-only. So syncing is a few
    /// scalar copies — no allocation, unlike the per-chunk `clone()`
    /// this replaced (ISSUE 9 satellite). Panics if the scratch was
    /// cloned from a different model shape, which cannot happen for a
    /// clone of the same master.
    pub fn sync_from(&mut self, master: &Failures) {
        match (self, master) {
            (Failures::None(_), Failures::None(_)) => {}
            (Failures::Burst(_), Failures::Burst(_)) => {}
            (Failures::Probabilistic(_), Failures::Probabilistic(_)) => {}
            (Failures::Byzantine(s), Failures::Byzantine(m)) => s.byz = m.byz,
            (Failures::Composite(s), Failures::Composite(m)) => {
                debug_assert_eq!(s.len(), m.len());
                for (part, master_part) in s.iter_mut().zip(m) {
                    part.sync_from(master_part);
                }
            }
            _ => unreachable!("worker failure scratch diverged from the master's variant"),
        }
    }
}

impl From<NoFailures> for Failures {
    fn from(f: NoFailures) -> Self {
        Failures::None(f)
    }
}

impl From<Burst> for Failures {
    fn from(f: Burst) -> Self {
        Failures::Burst(f)
    }
}

impl From<Probabilistic> for Failures {
    fn from(f: Probabilistic) -> Self {
        Failures::Probabilistic(f)
    }
}

impl From<Byzantine> for Failures {
    fn from(f: Byzantine) -> Self {
        Failures::Byzantine(f)
    }
}

/// A failure model injected into the simulation engine.
///
/// Hooks mirror where failures physically occur:
/// * `pre_step` — external events at the start of step `t` (bursts; also
///   advances internal Markov state for Byzantine nodes),
/// * `on_hop` — token lost in transit (node/link down, buffer overflow),
/// * `on_arrival` — the receiving node destroys the token (Byzantine).
pub trait FailureModel: Send {
    fn name(&self) -> &'static str;

    /// Walks to kill at the start of step `t`. `alive` lists current ids.
    fn pre_step(&mut self, _t: u64, _alive: &[WalkId], _rng: &mut Rng) -> Vec<WalkId> {
        Vec::new()
    }

    /// Whether the walk dies while hopping `from → to` at step `t`.
    fn on_hop(&mut self, _t: u64, _walk: WalkId, _from: u32, _to: u32, _rng: &mut Rng) -> bool {
        false
    }

    /// Whether the walk dies upon arriving at `node` at step `t`.
    fn on_arrival(&mut self, _t: u64, _walk: WalkId, _node: u32, _rng: &mut Rng) -> bool {
        false
    }

    fn clone_box(&self) -> Box<dyn FailureModel>;
}

impl Clone for Box<dyn FailureModel> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// No failures.
#[derive(Debug, Clone, Default)]
pub struct NoFailures;

impl FailureModel for NoFailures {
    fn name(&self) -> &'static str {
        "none"
    }

    fn clone_box(&self) -> Box<dyn FailureModel> {
        Box::new(self.clone())
    }
}

/// Deterministic burst events: at time `t`, kill `count` randomly chosen
/// walks simultaneously (Fig. 1: −5 at t=2000, −6 at t=6000).
#[derive(Debug, Clone)]
pub struct Burst {
    /// (time, number of walks to kill) — sorted by time at construction.
    events: Vec<(u64, usize)>,
}

impl Burst {
    pub fn new(mut events: Vec<(u64, usize)>) -> Self {
        events.sort_unstable();
        Burst { events }
    }

    /// The paper's Fig. 1 schedule.
    pub fn paper_default() -> Self {
        Burst::new(vec![(2000, 5), (6000, 6)])
    }

    pub fn events(&self) -> &[(u64, usize)] {
        &self.events
    }
}

impl FailureModel for Burst {
    fn name(&self) -> &'static str {
        "burst"
    }

    fn pre_step(&mut self, t: u64, alive: &[WalkId], rng: &mut Rng) -> Vec<WalkId> {
        let mut killed = Vec::new();
        for &(et, count) in &self.events {
            if et == t {
                let k = count.min(alive.len());
                if k > 0 {
                    let idx = rng.sample_indices(alive.len(), k);
                    killed.extend(idx.into_iter().map(|i| alive[i]));
                }
            }
        }
        killed
    }

    fn clone_box(&self) -> Box<dyn FailureModel> {
        Box::new(self.clone())
    }
}

/// Probabilistic failures: each walk independently dies with probability
/// `p_f` at every step (modelled as loss in transit). Fig. 2 uses
/// `p_f ∈ {0.001, 0.0002}` on top of bursts.
#[derive(Debug, Clone)]
pub struct Probabilistic {
    pub p_f: f64,
}

impl Probabilistic {
    pub fn new(p_f: f64) -> Self {
        assert!((0.0..=1.0).contains(&p_f));
        Probabilistic { p_f }
    }
}

impl FailureModel for Probabilistic {
    fn name(&self) -> &'static str {
        "probabilistic"
    }

    fn on_hop(&mut self, _t: u64, _walk: WalkId, _from: u32, _to: u32, rng: &mut Rng) -> bool {
        rng.bernoulli(self.p_f)
    }

    fn clone_box(&self) -> Box<dyn FailureModel> {
        Box::new(self.clone())
    }
}

/// Byzantine node (Fig. 3): a dedicated node whose behaviour follows a
/// two-state Markov chain with flip probability `p_b` per step. In state
/// `Byz` it deterministically terminates every incoming walk; in state
/// `NoByz` it follows the protocol.
#[derive(Debug, Clone)]
pub struct Byzantine {
    pub node: u32,
    pub p_b: f64,
    pub byz: bool,
    /// Optional schedule override: forced (time, state) transitions, used
    /// to reproduce Fig. 3's marked Byz / No-Byz phases deterministically.
    pub schedule: Vec<(u64, bool)>,
}

impl Byzantine {
    /// Markov-chain variant.
    pub fn markov(node: u32, p_b: f64, start_byz: bool) -> Self {
        Byzantine { node, p_b, byz: start_byz, schedule: Vec::new() }
    }

    /// Deterministic phase schedule (e.g. Byz during [t0,t1), honest after).
    pub fn scheduled(node: u32, schedule: Vec<(u64, bool)>) -> Self {
        Byzantine { node, p_b: 0.0, byz: false, schedule }
    }

    pub fn is_byz(&self) -> bool {
        self.byz
    }
}

impl FailureModel for Byzantine {
    fn name(&self) -> &'static str {
        "byzantine"
    }

    fn pre_step(&mut self, t: u64, _alive: &[WalkId], rng: &mut Rng) -> Vec<WalkId> {
        for &(st, state) in &self.schedule {
            if st == t {
                self.byz = state;
            }
        }
        if self.p_b > 0.0 && rng.bernoulli(self.p_b) {
            self.byz = !self.byz;
        }
        Vec::new()
    }

    fn on_arrival(&mut self, _t: u64, _walk: WalkId, node: u32, _rng: &mut Rng) -> bool {
        self.byz && node == self.node
    }

    fn clone_box(&self) -> Box<dyn FailureModel> {
        Box::new(self.clone())
    }
}

/// Combine several failure models; a walk dies if any component kills it.
#[derive(Default)]
pub struct Composite {
    pub parts: Vec<Box<dyn FailureModel>>,
}

impl Composite {
    pub fn new(parts: Vec<Box<dyn FailureModel>>) -> Self {
        Composite { parts }
    }
}

impl Clone for Composite {
    fn clone(&self) -> Self {
        Composite { parts: self.parts.clone() }
    }
}

impl FailureModel for Composite {
    fn name(&self) -> &'static str {
        "composite"
    }

    fn pre_step(&mut self, t: u64, alive: &[WalkId], rng: &mut Rng) -> Vec<WalkId> {
        let mut killed = Vec::new();
        for p in &mut self.parts {
            killed.extend(p.pre_step(t, alive, rng));
        }
        killed.sort_unstable();
        killed.dedup();
        killed
    }

    fn on_hop(&mut self, t: u64, walk: WalkId, from: u32, to: u32, rng: &mut Rng) -> bool {
        self.parts.iter_mut().any(|p| p.on_hop(t, walk, from, to, rng))
    }

    fn on_arrival(&mut self, t: u64, walk: WalkId, node: u32, rng: &mut Rng) -> bool {
        self.parts.iter_mut().any(|p| p.on_arrival(t, walk, node, rng))
    }

    fn clone_box(&self) -> Box<dyn FailureModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: u64) -> Vec<WalkId> {
        (0..n).map(WalkId).collect()
    }

    #[test]
    fn burst_kills_exactly_count_at_time() {
        let mut b = Burst::new(vec![(100, 3)]);
        let mut rng = Rng::new(1);
        let alive = ids(10);
        assert!(b.pre_step(99, &alive, &mut rng).is_empty());
        let killed = b.pre_step(100, &alive, &mut rng);
        assert_eq!(killed.len(), 3);
        let set: std::collections::HashSet<_> = killed.iter().collect();
        assert_eq!(set.len(), 3);
        assert!(b.pre_step(101, &alive, &mut rng).is_empty());
    }

    #[test]
    fn burst_caps_at_population() {
        let mut b = Burst::new(vec![(5, 100)]);
        let mut rng = Rng::new(2);
        let alive = ids(4);
        assert_eq!(b.pre_step(5, &alive, &mut rng).len(), 4);
    }

    #[test]
    fn probabilistic_rate() {
        let mut p = Probabilistic::new(0.01);
        let mut rng = Rng::new(3);
        let n = 100_000;
        let deaths = (0..n)
            .filter(|_| p.on_hop(0, WalkId(0), 0, 1, &mut rng))
            .count();
        assert!((deaths as f64 / n as f64 - 0.01).abs() < 0.002);
    }

    #[test]
    fn byzantine_schedule_phases() {
        let mut byz = Byzantine::scheduled(7, vec![(10, true), (20, false)]);
        let mut rng = Rng::new(4);
        byz.pre_step(5, &[], &mut rng);
        assert!(!byz.on_arrival(5, WalkId(0), 7, &mut rng));
        byz.pre_step(10, &[], &mut rng);
        assert!(byz.on_arrival(10, WalkId(0), 7, &mut rng));
        assert!(!byz.on_arrival(10, WalkId(0), 8, &mut rng)); // other nodes fine
        byz.pre_step(20, &[], &mut rng);
        assert!(!byz.on_arrival(20, WalkId(0), 7, &mut rng));
    }

    #[test]
    fn byzantine_markov_flips() {
        let mut byz = Byzantine::markov(0, 0.5, false);
        let mut rng = Rng::new(5);
        let mut flips = 0;
        let mut prev = byz.is_byz();
        for t in 0..1000 {
            byz.pre_step(t, &[], &mut rng);
            if byz.is_byz() != prev {
                flips += 1;
                prev = byz.is_byz();
            }
        }
        assert!(flips > 300, "flips {flips}");
    }

    #[test]
    fn enum_dispatch_matches_boxed_composite() {
        // The enum path must consume the identical RNG stream as the
        // boxed-trait path (golden-trace parity depends on it).
        let mut boxed = Composite::new(vec![
            Box::new(Burst::new(vec![(1, 2)])),
            Box::new(Probabilistic::new(0.25)),
        ]);
        let mut enumed = Failures::composite(vec![
            Burst::new(vec![(1, 2)]).into(),
            Probabilistic::new(0.25).into(),
        ]);
        let alive = ids(6);
        let mut ra = Rng::new(31);
        let mut rb = ra.clone();
        for t in 0..200 {
            assert_eq!(
                boxed.pre_step(t, &alive, &mut ra),
                enumed.pre_step(t, &alive, &mut rb)
            );
            for w in 0..4 {
                assert_eq!(
                    boxed.on_hop(t, WalkId(w), 0, 1, &mut ra),
                    enumed.on_hop(t, WalkId(w), 0, 1, &mut rb)
                );
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "rng streams diverged at t={t}");
        }
    }

    #[test]
    fn sync_from_tracks_masters_prestep_mutations() {
        // A worker scratch clone refreshed via `sync_from` after each
        // master `pre_step` must answer `on_arrival` exactly like a
        // fresh clone would — across scheduled phases AND Markov flips
        // (the one piece of pre_step-mutated state the hooks read).
        let mut master = Failures::composite(vec![
            Burst::new(vec![(3, 1)]).into(),
            Byzantine::scheduled(7, vec![(10, true), (20, false)]).into(),
            Byzantine::markov(4, 0.3, false).into(),
        ]);
        let mut scratch = master.clone();
        let mut rng = Rng::new(0x5C_1A7C);
        let alive = ids(8);
        for t in 0..200 {
            master.pre_step(t, &alive, &mut rng);
            scratch.sync_from(&master);
            let mut fresh = master.clone();
            // Hook rng: both sides must consume the same stream, so give
            // each the same clone.
            let mut ha = Rng::new(t ^ 0x0B5);
            let mut hb = ha.clone();
            for node in [4u32, 7, 9] {
                assert_eq!(
                    scratch.on_arrival(t, WalkId(0), node, &mut ha),
                    fresh.on_arrival(t, WalkId(0), node, &mut hb),
                    "scratch diverged from a fresh clone at t={t}, node={node}"
                );
            }
        }
    }

    #[test]
    fn composite_unions_kills() {
        let mut c = Composite::new(vec![
            Box::new(Burst::new(vec![(1, 2)])),
            Box::new(Probabilistic::new(1.0)),
        ]);
        let mut rng = Rng::new(6);
        let alive = ids(5);
        assert_eq!(c.pre_step(1, &alive, &mut rng).len(), 2);
        assert!(c.on_hop(1, WalkId(0), 0, 1, &mut rng));
    }
}
