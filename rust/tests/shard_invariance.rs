//! Schedule invariance of the stream-mode sharded engine (ISSUE 3): the
//! trace — `Trace::z`, the full event log, and the θ̂ telemetry, all
//! compared at the bit level — must be **identical at every shard
//! count**. Two layers:
//!
//! 1. the golden quartet driven through `ShardedEngine` at 1 / 2 / 8
//!    workers (the scenarios already cover every failure surface and
//!    every forking control family);
//! 2. a seeded property test: randomized scenarios (graph family, Z0,
//!    control, failure mix, horizon) at the deliberately awkward worker
//!    counts {1, 2, 7, 16} — 7 exercises uneven node/walk ranges, 16
//!    usually exceeds the walk count, so chunk-boundary bookkeeping is
//!    stressed from both sides.
//!
//! No assertion here compares stream mode against the shared-stream
//! engines: stream mode is its own trace family (per-walk randomness
//! ownership), pinned separately by `tests/stream_golden.rs`.

use decafork::obs::{MetricsConfig, MetricsMode};
use decafork::rng::Rng;
use decafork::scenario::{presets, ControlSpec, FailureSpec, GraphSpec, Scenario};
use decafork::sim::engine::{HopPath, RoutingMode, SimParams};
use decafork::sim::metrics::{EventKind, Trace};
use decafork::walks::NodeStateMode;

fn run_sharded(scenario: &Scenario, shards: usize) -> Trace {
    let mut e = scenario.sharded_engine(0, shards).expect("scenario must build");
    e.run_to(scenario.horizon);
    e.into_trace()
}

#[test]
fn golden_quartet_bit_identical_across_shard_counts() {
    for (name, mut scenario) in presets::golden() {
        // θ̂ telemetry on: invariance must hold for the float samples
        // too, not just the integer population trace.
        scenario.params.record_theta = true;
        let base = run_sharded(&scenario, 1);
        for shards in [2usize, 8] {
            let other = run_sharded(&scenario, shards);
            assert!(
                base.bit_identical(&other),
                "golden scenario '{name}': stream-mode trace diverged between \
                 1 and {shards} shards"
            );
        }
    }
}

/// Draw a randomized-but-buildable scenario from a seeded stream.
fn random_scenario(rng: &mut Rng, case: u64) -> Scenario {
    let n = 2 * (10 + rng.below(21)); // even 20..=60 (n*d must be even for any d)
    let d = *rng.choose(&[4usize, 5, 6]);
    let graph = match rng.below(3) {
        0 => GraphSpec::RandomRegular { n, d },
        1 => GraphSpec::Complete { n: 20 + rng.below(11) },
        _ => GraphSpec::Ring { n: 20 + rng.below(21) },
    };
    let z0 = 4 + rng.below(9) as u32; // 4..=12
    let control = match rng.below(5) {
        0 => ControlSpec::Decafork { epsilon: 1.5 + rng.f64() },
        1 => ControlSpec::DecaforkPlus { epsilon: 2.0, epsilon2: 5.0 },
        2 => ControlSpec::MissingPerson { eps_mp: 100 + rng.below(200) as u64 },
        3 => ControlSpec::Periodic { period: 40 + rng.below(80) as u64 },
        _ => ControlSpec::None,
    };
    let mut parts = Vec::new();
    if rng.bernoulli(0.7) {
        parts.push(FailureSpec::Burst {
            events: vec![(60 + rng.below(100) as u64, 1 + rng.below(3))],
        });
    }
    if rng.bernoulli(0.7) {
        parts.push(FailureSpec::Probabilistic { p_f: 0.001 + 0.009 * rng.f64() });
    }
    if rng.bernoulli(0.3) {
        parts.push(FailureSpec::ByzantineScheduled {
            node: rng.below(20) as u32,
            schedule: vec![(80, true), (200, false)],
        });
    }
    let failures = match parts.len() {
        0 => FailureSpec::None,
        1 => parts.pop().unwrap(),
        _ => FailureSpec::Composite(parts),
    };
    Scenario {
        graph,
        params: SimParams {
            z0,
            control_start: Some(30 + rng.below(40) as u64),
            max_walks: 256,
            record_theta: true,
            ..SimParams::default()
        },
        control,
        failures,
        horizon: 200 + rng.below(300) as u64,
        runs: 1,
        seed: 0x5EED_0000 ^ case,
    }
}

/// OS thread count of this test process (Linux; `None` elsewhere).
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status.lines().find_map(|l| l.strip_prefix("Threads:"))?.trim().parse().ok()
}

#[test]
fn pool_lifecycle_does_not_leak_workers_or_change_traces() {
    // Every ShardedEngine now owns a persistent worker pool; building
    // and dropping engines in a loop must (a) keep producing the same
    // bits and (b) join its workers on drop instead of leaking them.
    let mut rng = Rng::new(0xBEEF);
    let scenario = random_scenario(&mut rng, 99);
    let base = run_sharded(&scenario, 4);
    let before = os_thread_count();
    for round in 0..15 {
        let other = run_sharded(&scenario, 4);
        assert!(base.bit_identical(&other), "round {round}: engine churn changed the trace");
    }
    // Construct-without-stepping churn exercises the drop path alone.
    for _ in 0..25 {
        let e = scenario.sharded_engine(0, 4).expect("scenario must build");
        assert_eq!(e.pooled_workers(), 3, "pooled engine must own shards - 1 workers");
        drop(e);
    }
    if let (Some(b), Some(a)) = (before, os_thread_count()) {
        // 40 dropped engines × 3 workers = 120 leaked threads if Drop
        // failed to join. The slack absorbs sibling tests running
        // concurrently in this process (cargo's default parallel test
        // runner): the worst-case transient is a 16-shard pooled engine
        // (15 workers) plus an 8-shard one (7) plus scoped spawns — keep
        // the bound well above that, well below a real leak.
        assert!(a < b + 60, "worker threads leaked across engine drops: {b} -> {a}");
    }
}

#[test]
fn prop_lazy_store_bit_identical_to_dense() {
    // The lazy-vs-dense oracle (ISSUE 7): materializing node state on
    // first visit is a pure storage choice, so at any shard count the
    // lazy store must reproduce the eager dense columns bit for bit —
    // z, the event log, extinction/cap flags AND every θ̂ float. The
    // randomized scenarios already mix churn (probabilistic +
    // Byzantine failures, forking controls); on top we randomize the
    // prune schedule aggressively so the O(visited) sweep fires many
    // times mid-run, at phases that differ from the default 256.
    let mut rng = Rng::new(0x1A2B_5EED);
    let mut total_theta = 0usize;
    let mut total_events = 0usize;
    for case in 0..8u64 {
        let mut scenario = random_scenario(&mut rng, 0x700 + case);
        scenario.params.prune_every = 8 + rng.below(56) as u64;
        let mut dense = scenario.clone();
        dense.params.node_state = NodeStateMode::Dense;
        let lazy = scenario; // lazy is the default — keep it explicit below
        assert_eq!(lazy.params.node_state, NodeStateMode::Lazy);
        for shards in [1usize, 2, 7, 16] {
            let d = run_sharded(&dense, shards);
            let l = run_sharded(&lazy, shards);
            assert!(
                d.bit_identical(&l),
                "case {case} ({}) at {shards} shards: lazy store diverged from dense",
                lazy.label()
            );
            // bit_identical already covers θ̂, but the θ̂-bit comparison
            // is the load-bearing half of this oracle — assert it
            // explicitly so a future bit_identical refactor can't
            // silently drop it.
            assert_eq!(d.theta.len(), l.theta.len(), "case {case}");
            for ((td, xd), (tl, xl)) in d.theta.iter().zip(l.theta.iter()) {
                assert_eq!((td, xd.to_bits()), (tl, xl.to_bits()), "case {case}: θ̂ bits");
            }
            total_theta += d.theta.len();
            total_events += d.events.len();
        }
    }
    // Vacuity guard: the sweep must actually produce decisions and
    // lifecycle events for the comparison to mean anything.
    assert!(total_theta > 0, "no randomized case recorded θ̂");
    assert!(total_events > 0, "no randomized case produced events");
}

/// [`run_sharded`] plus the node-store first-visit order — the witness
/// for arrival *processing* order (a node's state materializes the first
/// time the control phase touches it, so reordered arrivals reorder this
/// list even when every trace field happens to agree).
fn run_sharded_with_visit_order(scenario: &Scenario, shards: usize) -> (Trace, Vec<u32>) {
    let mut e = scenario.sharded_engine(0, shards).expect("scenario must build");
    e.run_to(scenario.horizon);
    let order: Vec<u32> = e.states().iter().map(|(node, _)| node).collect();
    (e.into_trace(), order)
}

#[test]
fn prop_mailbox_routing_bit_identical_to_serial() {
    // The routing oracle (ISSUE 8): binning arrivals on the hop workers
    // (per-(chunk × destination-shard) mailboxes, drained chunk-major)
    // is a pure transport choice, so at any shard count the mailbox
    // path must reproduce the serial coordinator scan bit for bit — z,
    // the event log, extinction/cap flags, every θ̂ float, AND the
    // per-shard arrival processing order (asserted via the node stores'
    // first-visit order, which is exactly arrival order). Randomized
    // scenarios mix churn and bursts; worker counts {1, 2, 7, 16}
    // stress uneven chunks and empty mailbox rows from both sides.
    let mut rng = Rng::new(0x0DD_5EED);
    let mut total_theta = 0usize;
    let mut total_events = 0usize;
    for case in 0..8u64 {
        let scenario = random_scenario(&mut rng, 0x800 + case);
        let mut serial = scenario.clone();
        serial.params.routing = RoutingMode::Serial;
        let mailbox = scenario; // mailbox is the default — keep it explicit below
        assert_eq!(mailbox.params.routing, RoutingMode::Mailbox);
        for shards in [1usize, 2, 7, 16] {
            let (s, s_order) = run_sharded_with_visit_order(&serial, shards);
            let (m, m_order) = run_sharded_with_visit_order(&mailbox, shards);
            assert!(
                s.bit_identical(&m),
                "case {case} ({}) at {shards} shards: mailbox routing diverged from serial",
                mailbox.label()
            );
            assert_eq!(
                s_order, m_order,
                "case {case} at {shards} shards: first-visit order moved — \
                 mailbox routing reordered the control feed"
            );
            // bit_identical already covers θ̂, but the float bits are the
            // load-bearing half of this oracle (first-seen order is the
            // θ̂ float-sum order) — assert them explicitly so a future
            // bit_identical refactor can't silently drop them.
            assert_eq!(s.theta.len(), m.theta.len(), "case {case}");
            for ((ts, xs), (tm, xm)) in s.theta.iter().zip(m.theta.iter()) {
                assert_eq!((ts, xs.to_bits()), (tm, xm.to_bits()), "case {case}: θ̂ bits");
            }
            total_theta += s.theta.len();
            total_events += s.events.len();
        }
    }
    // Vacuity guard: the sweep must actually produce decisions and
    // lifecycle events for the comparison to mean anything.
    assert!(total_theta > 0, "no randomized case recorded θ̂");
    assert!(total_events > 0, "no randomized case produced events");
}

#[test]
fn prop_blocked_hop_bit_identical_to_scalar() {
    // The hop-path oracle (ISSUE 9): block-pipelining the hop and
    // control phases (prefetch stage + batched `step_block` + scalar
    // replay over 64-walk blocks) only restages *when* memory is
    // touched — every walk still draws from its own stream in the same
    // per-walk order — so at any shard count the blocked path must
    // reproduce the scalar loop bit for bit: z, the event log,
    // extinction/cap flags AND every θ̂ float. The walk counts are
    // chosen around the block size: a sub-block population (< 64, the
    // whole chunk is one ragged tail), an exact multiple of 64 (no
    // tail at 1 shard), and an unaligned tail — and sharding at
    // {1, 2, 7, 16} re-slices those populations into chunk lengths
    // that hit every alignment anyway.
    let mut rng = Rng::new(0x3B10_C5EE);
    let mut total_theta = 0usize;
    let mut total_events = 0usize;
    for (case, z0) in [7u32, 64, 100, 64, 29, 192, 77, 13].into_iter().enumerate() {
        let mut scenario = random_scenario(&mut rng, 0xA00 + case as u64);
        scenario.params.z0 = z0;
        scenario.params.max_walks = 512; // headroom so forking crosses block boundaries
        let mut scalar = scenario.clone();
        scalar.params.hop_path = HopPath::Scalar;
        let blocked = scenario; // blocked is the default — keep it explicit below
        assert_eq!(blocked.params.hop_path, HopPath::Blocked);
        for shards in [1usize, 2, 7, 16] {
            let s = run_sharded(&scalar, shards);
            let b = run_sharded(&blocked, shards);
            assert!(
                s.bit_identical(&b),
                "case {case} z0={z0} ({}) at {shards} shards: blocked hop path \
                 diverged from the scalar loop",
                blocked.label()
            );
            // bit_identical already covers θ̂, but the float bits are the
            // load-bearing half of this oracle (the control phase is
            // block-pipelined too) — assert them explicitly so a future
            // bit_identical refactor can't silently drop them.
            assert_eq!(s.theta.len(), b.theta.len(), "case {case}");
            for ((ts, xs), (tb, xb)) in s.theta.iter().zip(b.theta.iter()) {
                assert_eq!((ts, xs.to_bits()), (tb, xb.to_bits()), "case {case}: θ̂ bits");
            }
            total_theta += s.theta.len();
            total_events += s.events.len();
        }
    }
    // Vacuity guard: the sweep must actually produce decisions and
    // lifecycle events for the comparison to mean anything.
    assert!(total_theta > 0, "no randomized case recorded θ̂");
    assert!(total_events > 0, "no randomized case produced events");
}

#[test]
fn prop_metrics_sink_is_observation_only() {
    // The observability oracle (ISSUE 10): telemetry reads clocks and
    // counters, never an RNG, and the sink writes strictly after the
    // trace is updated — so a jsonl-streaming run must reproduce the
    // metrics-off run bit for bit at any shard count: z, the event log,
    // extinction/cap flags AND every θ̂ float. The flush period is
    // randomized so period boundaries land mid-run, not only at the
    // end, and worker counts {1, 2, 7, 16} stress the per-worker
    // counter scratch from sub-walk to super-walk chunkings.
    let mut rng = Rng::new(0x0B5_5EED);
    let mut total_theta = 0usize;
    let mut total_events = 0usize;
    for case in 0..8u64 {
        let scenario = random_scenario(&mut rng, 0xC00 + case);
        let every = 1 + rng.below(9) as u64;
        for shards in [1usize, 2, 7, 16] {
            let off = run_sharded(&scenario, shards);
            let mut streamed = scenario.clone();
            let mut path = std::env::temp_dir();
            path.push(format!("decafork_inv_metrics_{}_{case}_{shards}.jsonl", std::process::id()));
            streamed.params.metrics = MetricsConfig {
                mode: MetricsMode::Jsonl,
                out: Some(path.to_string_lossy().into_owned()),
                every,
            };
            let on = run_sharded(&streamed, shards);
            std::fs::remove_file(&path).ok();
            assert!(
                off.bit_identical(&on),
                "case {case} ({}) at {shards} shards (every={every}): \
                 the metrics sink perturbed the trace",
                scenario.label()
            );
            // bit_identical already covers θ̂, but the float bits are the
            // load-bearing half of this oracle (the sink serializes θ̂
            // period aggregates) — assert them explicitly so a future
            // bit_identical refactor can't silently drop them.
            assert_eq!(off.theta.len(), on.theta.len(), "case {case}");
            for ((to, xo), (tn, xn)) in off.theta.iter().zip(on.theta.iter()) {
                assert_eq!((to, xo.to_bits()), (tn, xn.to_bits()), "case {case}: θ̂ bits");
            }
            total_theta += off.theta.len();
            total_events += off.events.len();
        }
    }
    // Vacuity guard: the sweep must actually produce decisions and
    // lifecycle events for the comparison to mean anything.
    assert!(total_theta > 0, "no randomized case recorded θ̂");
    assert!(total_events > 0, "no randomized case produced events");
}

#[test]
fn golden_quartet_bit_identical_across_hop_paths() {
    // Re-assert the pinned stream-mode family under both hop paths:
    // whatever `DECAFORK_HOP_PATH` CI crosses into `stream_golden.rs`,
    // this test locks scalar ≡ blocked on the quartet directly.
    for (name, mut scenario) in presets::golden() {
        scenario.params.record_theta = true;
        scenario.params.hop_path = HopPath::Scalar;
        let scalar = run_sharded(&scenario, 1);
        scenario.params.hop_path = HopPath::Blocked;
        for shards in [1usize, 2, 8] {
            let blocked = run_sharded(&scenario, shards);
            assert!(
                scalar.bit_identical(&blocked),
                "golden scenario '{name}': blocked hop path at {shards} shards \
                 diverged from the scalar loop"
            );
        }
    }
}

#[test]
fn pin_cores_is_placement_only_and_changes_no_trace() {
    // `--pin-cores` binds pool worker k to core k+1 (best-effort — on a
    // cgroup-restricted runner every pin may fail and that must be
    // fine). It decides where threads run, never what they compute: the
    // trace and the first-visit order must match the unpinned run
    // exactly, whatever the host did with the affinity requests.
    let mut rng = Rng::new(0x91B_C0DE);
    let scenario = random_scenario(&mut rng, 0x900);
    let mut pinned = scenario.clone();
    pinned.params.pin_cores = true;
    assert!(!scenario.params.pin_cores, "pinning must be opt-in");
    for shards in [1usize, 4] {
        let (base, base_order) = run_sharded_with_visit_order(&scenario, shards);
        let (pin, pin_order) = run_sharded_with_visit_order(&pinned, shards);
        assert!(
            base.bit_identical(&pin),
            "{shards} shards: --pin-cores changed the trace — pinning must be placement-only"
        );
        assert_eq!(base_order, pin_order, "{shards} shards: pinning moved first-visit order");
    }
}

#[test]
fn randomized_scenarios_bit_identical_across_shard_counts() {
    let mut rng = Rng::new(0x1517);
    let mut total_forks = 0usize;
    let mut total_failures = 0usize;
    for case in 0..10u64 {
        let scenario = random_scenario(&mut rng, case);
        let base = run_sharded(&scenario, 1);
        total_forks += base.count(EventKind::Fork);
        total_failures += base.count(EventKind::Failure);
        for shards in [2usize, 7, 16] {
            let other = run_sharded(&scenario, shards);
            assert!(
                base.bit_identical(&other),
                "case {case} ({}): trace diverged between 1 and {shards} shards",
                scenario.label()
            );
        }
    }
    // The sweep as a whole must actually exercise the cross-effect merge
    // paths — a fleet of do-nothing scenarios would prove nothing.
    assert!(total_forks > 0, "no randomized case ever forked");
    assert!(total_failures > 0, "no randomized case ever killed a walk");
}
