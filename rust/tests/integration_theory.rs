//! Theory vs Monte-Carlo: the paper's distributional claims and bounds
//! validated against direct simulation of the Assumption-1 model and
//! against the full walk simulator.

use decafork::rng::Rng;
use decafork::stats::IrwinHall;
use decafork::theory::estimator::{EventHistory, ThetaHatDistribution};
use decafork::theory::{
    fork_probability_bound, growth_bound, reaction_time_bound, Rates,
};

fn rates() -> Rates {
    Rates::new(0.01, 0.025)
}

/// Simulate one sample of the survival estimate S(t − L) for a walk
/// forked at `t_f` and terminated at `t_d`, observed at `t` by a random
/// node, under Assumption 1.
fn sample_theta_hat(rng: &mut Rng, r: Rates, t_f: f64, t_d: f64, t: f64) -> f64 {
    // Arrival of the forked walk at the observing node.
    let arrive = t_f + rng.exponential(r.lambda_a);
    if arrive > t_d {
        return 0.0; // never seen before the walk died
    }
    // Renewal process of returns with rate λ_r from `arrive` to `t_d`;
    // the last visit before t_d is t_d minus a stationary age, but for an
    // exponential renewal the age at t_d since the last event given at
    // least the arrival is min(Exp(λ_r), t_d − arrive).
    let age = rng.exponential(r.lambda_r).min(t_d - arrive);
    let last = t_d - age;
    (-r.lambda_r * (t - last)).exp()
}

#[test]
fn lemma1_cdf_matches_monte_carlo() {
    let r = rates();
    let (t_f, t_d, t) = (0.0, 300.0, 400.0);
    let dist = ThetaHatDistribution::new(r, t_f, t_d, t);
    let mut rng = Rng::new(1);
    let n = 200_000;
    let samples: Vec<f64> = (0..n).map(|_| sample_theta_hat(&mut rng, r, t_f, t_d, t)).collect();
    for x in [0.005, 0.01, 0.02, 0.03] {
        let emp = samples.iter().filter(|&&s| s <= x).count() as f64 / n as f64;
        let thy = dist.cdf(x);
        assert!(
            (emp - thy).abs() < 0.015,
            "CDF mismatch at {x}: emp {emp:.4} thy {thy:.4}"
        );
    }
}

#[test]
fn corollary1_mean_matches_monte_carlo() {
    let r = rates();
    let (t_f, t_d, t) = (0.0, 300.0, 350.0);
    let dist = ThetaHatDistribution::new(r, t_f, t_d, t);
    let mut rng = Rng::new(2);
    let n = 400_000;
    let mean: f64 =
        (0..n).map(|_| sample_theta_hat(&mut rng, r, t_f, t_d, t)).sum::<f64>() / n as f64;
    assert!(
        (mean - dist.mean()).abs() < 0.01,
        "mean: MC {mean:.4} vs closed form {:.4}",
        dist.mean()
    );
}

#[test]
fn lemma3_variance_quadrature_consistent() {
    // The printed closed form is cross-checked against quadrature; where
    // they disagree the quadrature (integral of the Lemma-1 CDF) wins —
    // DESIGN.md records this as a suspected transcription issue.
    let r = rates();
    let dist = ThetaHatDistribution::new(r, 0.0, 300.0, 400.0);
    let vq = dist.variance_quadrature();
    assert!(vq > 0.0 && vq < 1.0 / 4.0, "variance out of range: {vq}");
    // Monte-Carlo agreement.
    let mut rng = Rng::new(3);
    let n = 400_000;
    let samples: Vec<f64> =
        (0..n).map(|_| sample_theta_hat(&mut rng, r, 0.0, 300.0, 400.0)).collect();
    let m = samples.iter().sum::<f64>() / n as f64;
    let v = samples.iter().map(|s| (s - m) * (s - m)).sum::<f64>() / n as f64;
    assert!((v - vq).abs() < 0.01, "variance: MC {v:.5} vs quadrature {vq:.5}");
}

#[test]
fn proposition3_irwin_hall_in_simulator() {
    // In the real simulator with K stable walks and warm estimates, θ̂
    // samples should follow ~½ + Irwin-Hall(K−1): compare a few quantiles.
    use decafork::control::Decafork;
    use decafork::failures::NoFailures;
    use decafork::graph::generators;
    use decafork::sim::engine::{Engine, SimParams};
    use std::sync::Arc;

    let g = Arc::new(generators::random_regular(100, 8, &mut Rng::new(4)).unwrap());
    let mut e = Engine::new(
        g,
        SimParams { record_theta: true, ..Default::default() },
        Decafork::new(2.0),
        NoFailures,
        Rng::new(4),
    );
    e.run_to(8000);
    let samples: Vec<f64> = e
        .trace()
        .theta
        .iter()
        .filter(|&&(t, _)| t > 4000)
        .map(|&(_, th)| th - 0.5)
        .collect();
    assert!(samples.len() > 1000);
    // Prop. 3 describes θ̂ for K *active, fully propagated* walks.
    // Without failures the population drifts slightly above Z0 (Thm. 3's
    // slow growth) while recent forks are under-counted at most nodes, so
    // the realized distribution sits between Irwin–Hall(Z0−1) and
    // Irwin–Hall(K̄−1). Check the median lands in that corridor and the
    // spread matches the Irwin–Hall scale.
    let z_mean = e.trace().mean_z(4000, 8000);
    let k_hi = (z_mean.round() as u32).saturating_sub(1).max(9);
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let quant = |q: f64| sorted[(q * sorted.len() as f64) as usize];
    let med = quant(0.5);
    let lo = IrwinHall::new(9).quantile(0.5) - 0.3;
    let hi = IrwinHall::new(k_hi).quantile(0.5) + 0.3;
    assert!(
        (lo..=hi).contains(&med),
        "median {med:.2} outside [{lo:.2}, {hi:.2}] (Z mean {z_mean:.1})"
    );
    let iqr = quant(0.75) - quant(0.25);
    let iqr_lo = IrwinHall::new(9).quantile(0.75) - IrwinHall::new(9).quantile(0.25);
    assert!(
        iqr > 0.6 * iqr_lo && iqr < 2.5 * iqr_lo,
        "IQR {iqr:.2} inconsistent with Irwin-Hall scale {iqr_lo:.2}"
    );
}

#[test]
fn lemma4_bound_is_an_upper_bound_in_the_assumption1_model() {
    // Directly simulate θ̂ = ½ + Σ U(0,1) for K = 10 healthy walks and
    // check the Bennett bound dominates the true fork probability.
    let r = rates();
    let h = EventHistory { active_forever: 10.0, ..Default::default() };
    let eps = 2.0;
    let p = 0.1;
    let bound = fork_probability_bound(&h, r, 1000.0, eps, p);
    let mut rng = Rng::new(5);
    let n = 2_000_000;
    let mut forks = 0u64;
    for _ in 0..n {
        let theta = 0.5 + (0..9).map(|_| rng.f64()).sum::<f64>();
        if theta < eps && rng.bernoulli(p) {
            forks += 1;
        }
    }
    let emp = forks as f64 / n as f64;
    assert!(
        emp <= bound * 1.05 + 1e-9,
        "Lemma 4 violated: empirical {emp:.2e} > bound {bound:.2e}"
    );
}

#[test]
fn theorem2_bound_dominates_simulated_reaction_time() {
    // After D = 5 of 10 walks fail, the simulator's median time to the
    // first fork must be below the Thm. 2 worst-case bound at δ = 0.5.
    use decafork::control::Decafork;
    use decafork::failures::Burst;
    use decafork::graph::generators;
    use decafork::sim::engine::{Engine, SimParams};
    use decafork::sim::metrics::EventKind;
    use std::sync::Arc;

    let r = Rates::new(0.01, 0.01); // λ ≈ 1/n for n = 100
    let bound = reaction_time_bound(5, 0, 5, 2.0, 0.1, r, 0.5, 5_000_000)
        .expect("bound should be finite");
    let mut first_forks = Vec::new();
    for seed in 0..10 {
        let g = Arc::new(generators::random_regular(100, 8, &mut Rng::new(seed)).unwrap());
        let mut e = Engine::new(
            g,
            SimParams::default(),
            Decafork::new(2.0),
            Burst::new(vec![(2000, 5)]),
            Rng::new(1000 + seed),
        );
        e.run_to(2000 + bound.max(10_000));
        if let Some(ev) = e
            .trace()
            .events
            .iter()
            .find(|ev| ev.kind == EventKind::Fork && ev.t >= 2000)
        {
            first_forks.push(ev.t - 2000);
        }
    }
    assert!(first_forks.len() >= 8, "forks should happen in most runs");
    first_forks.sort_unstable();
    let median = first_forks[first_forks.len() / 2];
    assert!(
        median <= bound,
        "median first fork {median} exceeds Thm2 bound {bound}"
    );
}

#[test]
fn theorem3_growth_bound_holds_in_simulator() {
    // Without failures, the probability of exceeding z = 2·Z0 within the
    // horizon must be below the Thm. 3 bound (evaluated at the same T).
    use decafork::control::Decafork;
    use decafork::failures::NoFailures;
    use decafork::graph::generators;
    use decafork::sim::engine::{Engine, SimParams};
    use std::sync::Arc;

    let r = Rates::new(0.01, 0.01);
    let horizon = 10_000.0;
    let g_bound = growth_bound(10, 20, 2.0, 0.1, 100, r, horizon);
    let runs = 20;
    let mut exceed = 0;
    for seed in 0..runs {
        let g = Arc::new(generators::random_regular(100, 8, &mut Rng::new(seed)).unwrap());
        let mut e = Engine::new(
            g,
            SimParams::default(),
            Decafork::new(2.0),
            NoFailures,
            Rng::new(2000 + seed),
        );
        e.run_to(horizon as u64);
        if e.trace().max_z(0, horizon as u64) > 20 {
            exceed += 1;
        }
    }
    let emp = exceed as f64 / runs as f64;
    assert!(
        emp <= g_bound.delta + 0.1,
        "Thm3 violated: empirical {emp} > bound {:.3}",
        g_bound.delta
    );
}
