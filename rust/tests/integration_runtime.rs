//! Runtime + learning integration: requires `make artifacts`. Every test
//! is skipped (with a loud message) when artifacts are absent so
//! `cargo test` works on a fresh checkout; `make test` builds them first.

use std::sync::Arc;

use decafork::learning::{PjrtOp, ShardedCorpus, TrainingRun};
use decafork::rng::Rng;
use decafork::runtime::{artifacts_present, default_artifacts_dir, Runtime, TrainStep};

macro_rules! require_artifacts {
    () => {{
        let dir = default_artifacts_dir();
        if !artifacts_present(&dir) {
            eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
            return;
        }
        dir
    }};
}

fn read_init_params(dir: &std::path::Path, m: &decafork::runtime::Manifest) -> Vec<f32> {
    let bytes = std::fs::read(dir.join(m.get("init_params").unwrap())).unwrap();
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn train_step_roundtrip_and_loss_decrease() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::load(&rt, &dir).unwrap();
    let params = read_init_params(&dir, &ts.manifest);
    assert_eq!(params.len(), ts.param_count().unwrap());

    let (b, t1) = ts.token_shape().unwrap();
    let vocab = ts.manifest.get_usize("vocab").unwrap() as i32;
    let tokens: Vec<i32> = (0..b * t1).map(|i| (i as i32 * 7 + 3) % vocab).collect();

    let (p1, l0) = ts.step(&params, &tokens).unwrap();
    assert!(l0.is_finite());
    // Near-uniform initial loss ≈ ln(vocab).
    assert!((l0 - (vocab as f32).ln()).abs() < 0.5, "init loss {l0}");
    let mut p = p1;
    let mut l = l0;
    for _ in 0..15 {
        let (np, nl) = ts.step(&p, &tokens).unwrap();
        p = np;
        l = nl;
    }
    assert!(l < 0.7 * l0, "loss did not drop: {l0} -> {l}");
    assert_ne!(p[..10], params[..10], "params unchanged");
}

#[test]
fn train_step_rejects_bad_shapes() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::load(&rt, &dir).unwrap();
    let params = vec![0.0f32; ts.param_count().unwrap()];
    assert!(ts.step(&params, &[0i32; 3]).is_err());
    assert!(ts.step(&params[..10], &vec![0i32; {
        let (b, t1) = ts.token_shape().unwrap();
        b * t1
    }]).is_err());
}

#[test]
fn theta_kernel_matches_rust_estimator() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let th = decafork::runtime::ThetaKernel::load(&rt, &dir).unwrap();
    let (n, k) = (th.nodes, th.walks);
    let mut rng = Rng::new(9);
    let elapsed: Vec<f32> = (0..n * k).map(|_| rng.below(300) as f32).collect();
    let q: Vec<f32> = (0..n).map(|_| 0.005 + rng.f32() * 0.05).collect();
    let mask: Vec<f32> = (0..n * k).map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 }).collect();
    let theta = th.theta(&elapsed, &q, &mask).unwrap();
    // Rust-side reference: θ = ½ + Σ mask·(1−q)^elapsed.
    for i in 0..n {
        let mut want = 0.5f64;
        for j in 0..k {
            if mask[i * k + j] > 0.0 {
                want += (1.0 - q[i] as f64).powf(elapsed[i * k + j] as f64);
            }
        }
        assert!(
            (theta[i] as f64 - want).abs() < 1e-3,
            "node {i}: kernel {} vs rust {want}",
            theta[i]
        );
    }
}

#[test]
fn eval_loss_artifact_loads_and_runs() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::load(&rt, &dir).unwrap();
    let exec = rt
        .load_hlo_text(dir.join(ts.manifest.get("eval_loss").unwrap()))
        .unwrap();
    let params = read_init_params(&dir, &ts.manifest);
    let (b, t1) = ts.token_shape().unwrap();
    let tokens: Vec<i32> = vec![1; b * t1];
    let p = xla::Literal::vec1(&params);
    let t = xla::Literal::vec1(&tokens).reshape(&[b as i64, t1 as i64]).unwrap();
    let result = exec.exe.execute::<xla::Literal>(&[p, t]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let loss = result.to_tuple1().unwrap().to_vec::<f32>().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn end_to_end_training_with_failures_and_decafork() {
    // The headline integration: models ride walks, a burst kills some,
    // DECAFORK forks replacements carrying copied models, loss improves.
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::load(&rt, &dir).unwrap();
    let n = 32;
    let corpus = Arc::new(ShardedCorpus::markov(
        n,
        2048,
        ts.manifest.get_usize("vocab").unwrap(),
        123,
    ));
    let graph = Arc::new(
        decafork::graph::generators::random_regular(n, 6, &mut Rng::new(5)).unwrap(),
    );
    let mut engine = decafork::sim::engine::Engine::new(
        graph,
        decafork::sim::engine::SimParams {
            z0: 3,
            control_start: Some(100),
            max_walks: 12,
            ..Default::default()
        },
        decafork::control::Decafork::new(1.5),
        decafork::failures::Burst::new(vec![(110, 1)]),
        Rng::new(6),
    );
    let op = PjrtOp::new(&ts).unwrap();
    let summary = TrainingRun::execute(&mut engine, &op, corpus, 220, 7).unwrap();
    assert!(summary.steps > 100, "too few SGD steps: {}", summary.steps);
    assert!(summary.survivors >= 1, "no surviving walk");
    assert!(
        summary.last_loss_mean < summary.first_loss,
        "no learning progress: {} -> {}",
        summary.first_loss,
        summary.last_loss_mean
    );
    // The burst must show in the trace as exactly one failure event.
    use decafork::sim::metrics::EventKind;
    assert_eq!(summary.trace.count(EventKind::Failure), 1);
    assert!(summary.trace.events.iter().any(|e| e.kind == EventKind::Failure && e.t == 110));
    assert!(summary.lineage.contains("living walks"), "{}", summary.lineage);
}

#[test]
fn gossip_on_meet_merges_models() {
    // Extension test: with merge_on_meet, co-located walks average their
    // parameters. On a tiny dense graph meetings are frequent.
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let ts = TrainStep::load(&rt, &dir).unwrap();
    let n = 8;
    let corpus = Arc::new(ShardedCorpus::markov(
        n,
        2048,
        ts.manifest.get_usize("vocab").unwrap(),
        321,
    ));
    let graph = Arc::new(decafork::graph::generators::complete(n));
    let mut engine = decafork::sim::engine::Engine::new(
        graph,
        decafork::sim::engine::SimParams {
            z0: 4,
            control_start: Some(10_000), // no control: isolate the merge path
            ..Default::default()
        },
        decafork::control::NoControl,
        decafork::failures::NoFailures,
        Rng::new(13),
    );
    let op = PjrtOp::new(&ts).unwrap();
    let summary =
        TrainingRun::execute_opts(&mut engine, &op, corpus, 120, 17, true).unwrap();
    assert!(summary.merges > 0, "no meetings on a complete graph in 120 steps?");
    assert!(summary.last_loss_mean < summary.first_loss);
    assert_eq!(summary.survivors, 4);
}
