//! The topology-backend invariance locks (ISSUE 6): the implicit
//! circulant backend must be **bit-identical** to the CSR it
//! materializes to — degrees, sorted neighbor lists, and the `step`
//! RNG-draw sequence — and the pool-parallel CSR builder must reproduce
//! the sequential validating builder neighbor-for-neighbor at every
//! worker count. These are the guarantees that let `scale_10m` run on
//! zero stored edges while both pinned golden families stay untouched
//! on the CSR backend.

use decafork::graph::{build, generators, Graph, ImplicitTopology};
use decafork::rng::Rng;
use decafork::runtime::WorkerPool;

/// Copy the implicit side's list before touching the other graph — the
/// implicit `neighbors` slice lives in per-thread scratch.
fn neighbors_owned(g: &Graph, i: usize) -> Vec<u32> {
    g.neighbors(i).to_vec()
}

#[test]
fn implicit_matches_materialized_oracle() {
    // Randomized families: ring lattices and small worlds across sizes
    // and degrees; every one must materialize to an identical CSR.
    for case in 0u64..12 {
        let mut rng = Rng::new(0x0B5E55ED ^ case);
        let n = 50 + rng.below(400);
        let d = [4usize, 6, 8][rng.below(3)];
        let imp = if case % 2 == 0 {
            Graph::from_implicit(ImplicitTopology::ring_lattice(n, d).unwrap())
        } else {
            Graph::from_implicit(ImplicitTopology::small_world(n, d, &mut rng).unwrap())
        };
        let mat = imp.materialize();
        assert!(!mat.is_implicit());
        assert_eq!((imp.n(), imp.m()), (mat.n(), mat.m()), "case {case}");
        for i in 0..n {
            assert_eq!(imp.degree(i), d, "case {case}, node {i}");
            assert_eq!(neighbors_owned(&imp, i), mat.neighbors(i), "case {case}, node {i}");
        }
        // 50k step draws bit-for-bit, and the RNG streams must stay in
        // lockstep (same number of Lemire rejections — i.e. identical
        // thresholds — not just same destinations).
        let (mut ra, mut rb) = (Rng::new(case ^ 0xF00D), Rng::new(case ^ 0xF00D));
        let (mut pa, mut pb) = (0usize, 0usize);
        for hop in 0..50_000 {
            pa = imp.step(pa, &mut ra);
            pb = mat.step(pb, &mut rb);
            assert_eq!(pa, pb, "case {case}: destinations diverged at hop {hop}");
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "case {case}: rng streams diverged");
    }
}

#[test]
fn implicit_step_matches_rng_below_stream() {
    // The implicit sampler must consume the stream exactly like
    // `nbrs[rng.below(deg)]` — the same equivalence the CSR backend
    // locks in its module tests.
    let g = Graph::from_implicit(ImplicitTopology::small_world(300, 8, &mut Rng::new(5)).unwrap());
    let mut ra = Rng::new(0xFEED);
    let mut rb = ra.clone();
    let (mut pa, mut pb) = (0usize, 0usize);
    for _ in 0..50_000 {
        pa = g.step(pa, &mut ra);
        let nbrs = neighbors_owned(&g, pb);
        pb = nbrs[rb.below(nbrs.len())] as usize;
        assert_eq!(pa, pb);
        assert_eq!(ra.next_u64(), rb.next_u64(), "rng streams diverged");
    }
}

#[test]
fn implicit_bfs_and_connectivity() {
    // Plain ring C_n({1}): distances have a closed form.
    let ring = Graph::from_implicit(ImplicitTopology::new(31, vec![1], "ring").unwrap());
    let dist = ring.bfs_distances(4);
    for (j, &dj) in dist.iter().enumerate() {
        let around = (j as i64 - 4).rem_euclid(31) as usize;
        assert_eq!(dj, around.min(31 - around), "node {j}");
    }
    assert!(ring.is_connected());
    // C_10({2}) splits into two 5-cycles: implicit BFS must see it.
    let split = Graph::from_implicit(ImplicitTopology::new(10, vec![2], "split").unwrap());
    assert!(!split.is_connected());
    let d0 = split.bfs_distances(0);
    assert_eq!(d0[1], usize::MAX);
    assert_eq!(d0[4], 2);
    // And the generic oracle: implicit BFS == materialized BFS.
    let sw = Graph::from_implicit(ImplicitTopology::small_world(257, 8, &mut Rng::new(9)).unwrap());
    let mat = sw.materialize();
    assert_eq!(sw.is_connected(), mat.is_connected());
    for src in [0usize, 13, 256] {
        assert_eq!(sw.bfs_distances(src), mat.bfs_distances(src), "src {src}");
    }
}

/// Deterministic irregular edge list big enough to cross
/// `PARALLEL_MIN_EDGES`: a ring for connectivity plus seeded random
/// chords (deduped, self-loop-free).
fn irregular_edges(n: usize, chords: usize, seed: u64) -> Vec<(u32, u32)> {
    let mut rng = Rng::new(seed);
    let mut seen = std::collections::HashSet::new();
    let mut edges = Vec::new();
    for i in 0..n as u32 {
        let j = (i + 1) % n as u32;
        seen.insert((i.min(j), i.max(j)));
        edges.push((i, j));
    }
    while edges.len() < n + chords {
        let a = rng.below(n) as u32;
        let b = rng.below(n) as u32;
        if a != b && seen.insert((a.min(b), a.max(b))) {
            edges.push((a, b));
        }
    }
    edges
}

#[test]
fn parallel_builder_matches_sequential_at_any_worker_count() {
    // Two shapes above the 2^16-edge threshold: a uniform-degree
    // circulant and an irregular ring+chords list (degree skew exercises
    // the per-chunk write-base arithmetic).
    let regular = ImplicitTopology::ring_lattice(20_000, 8).unwrap().edge_list();
    let irregular = irregular_edges(30_000, 60_000, 0xC0FFEE);
    for (name, n, edges) in [("regular", 20_000, &regular), ("irregular", 30_000, &irregular)] {
        assert!(edges.len() >= build::PARALLEL_MIN_EDGES, "{name}: below parallel threshold");
        let seq = Graph::from_edges(n, edges).unwrap();
        for workers in [1usize, 2, 5] {
            let mut pool = WorkerPool::new(workers);
            let par = build::from_edges_parallel(n, edges, &mut pool);
            assert_eq!(seq.m(), par.m(), "{name} @ {workers} workers");
            for i in 0..n {
                assert_eq!(seq.neighbors(i), par.neighbors(i), "{name} @ {workers}, node {i}");
            }
            // Identical step streams too (thresholds byte-equal).
            let (mut ra, mut rb) = (Rng::new(workers as u64), Rng::new(workers as u64));
            let (mut pa, mut pb) = (0usize, 0usize);
            for _ in 0..5_000 {
                pa = seq.step(pa, &mut ra);
                pb = par.step(pb, &mut rb);
                assert_eq!(pa, pb, "{name} @ {workers}");
            }
        }
    }
}

#[test]
fn parallel_connectivity_matches_sequential() {
    let mut pool = WorkerPool::new(3);
    // Connected, above the 2^15-node threshold, on both backends.
    let imp = Graph::from_implicit(ImplicitTopology::ring_lattice(40_000, 8).unwrap());
    assert!(build::is_connected_parallel(&imp, &mut pool));
    let csr = imp.materialize();
    assert!(build::is_connected_parallel(&csr, &mut pool));
    // Disconnected at scale: two disjoint 20k-node rings.
    let mut edges: Vec<(u32, u32)> = (0..20_000u32).map(|i| (i, (i + 1) % 20_000)).collect();
    edges.extend((0..20_000u32).map(|i| (20_000 + i, 20_000 + (i + 1) % 20_000)));
    let split = Graph::from_edges_trusted(40_000, &edges);
    assert!(!split.is_connected());
    assert!(!build::is_connected_parallel(&split, &mut pool));
}

#[test]
fn random_regular_pooled_is_bit_identical_above_threshold() {
    // 20k nodes × d=8 → 80k edges per pairing attempt: the pooled path
    // really assembles in parallel here, and must sample the *same*
    // graph (identical RNG consumption, identical CSR bytes).
    let n = 20_000;
    let seq = generators::random_regular(n, 8, &mut Rng::new(0xAB)).unwrap();
    let mut pool = WorkerPool::new(3);
    let par = generators::random_regular_pooled(n, 8, &mut Rng::new(0xAB), &mut pool).unwrap();
    assert_eq!(seq.m(), par.m());
    for i in 0..n {
        assert_eq!(seq.neighbors(i), par.neighbors(i), "node {i}");
    }
}
