//! Property-based tests (in-repo harness; `proptest` is not in the
//! vendored crate set — see DESIGN.md substitutions). Each property runs
//! against many seeded random cases and reports the failing seed.

use decafork::graph::{generators, Graph};
use decafork::rng::Rng;
use decafork::stats::{ecdf::EmpiricalCdf, IrwinHall};
use decafork::walks::{NodeState, SurvivalModel, WalkId};

/// Run `cases` random cases; on panic the failing seed is in the message.
fn prop(cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBADC0DE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property failed at case {seed}: {e:?}");
        }
    }
}

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.below(5) {
        0 => {
            let n = 2 * rng.range(5, 40);
            let d = [2, 3, 4, 6, 8][rng.below(5)].min(n - 1);
            let d = if n * d % 2 == 1 { d + 1 } else { d };
            generators::random_regular(n, d, rng).unwrap()
        }
        1 => generators::complete(rng.range(3, 30)),
        2 => generators::erdos_renyi(rng.range(10, 50), 0.3, rng).unwrap(),
        3 => generators::barabasi_albert(rng.range(10, 60), 3, rng).unwrap(),
        _ => generators::ring(rng.range(3, 50)),
    }
}

#[test]
fn prop_graphs_are_simple_symmetric_connected() {
    prop(40, |rng| {
        let g = random_graph(rng);
        assert!(g.is_connected());
        let mut edge_count = 0usize;
        for i in 0..g.n() {
            let nbrs = g.neighbors(i);
            edge_count += nbrs.len();
            // No self-loops, sorted, no duplicates.
            let mut prev: Option<u32> = None;
            for &v in nbrs {
                assert_ne!(v as usize, i, "self-loop at {i}");
                if let Some(p) = prev {
                    assert!(v > p, "unsorted/duplicate adjacency at {i}");
                }
                prev = Some(v);
                // Symmetry.
                assert!(
                    g.neighbors(v as usize).contains(&(i as u32)),
                    "asymmetric edge ({i},{v})"
                );
            }
        }
        assert_eq!(edge_count, 2 * g.m());
    });
}

#[test]
fn prop_stationary_distribution_sums_to_one_and_kac_holds() {
    prop(20, |rng| {
        let g = random_graph(rng);
        let total: f64 = (0..g.n()).map(|i| g.stationary(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        for i in 0..g.n() {
            let kac = g.mean_return_time(i);
            assert!((kac * g.stationary(i) - 1.0).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_ecdf_is_a_cdf() {
    prop(50, |rng| {
        let mut e = EmpiricalCdf::new();
        let n = rng.range(1, 500);
        let max = rng.range(2, 1000);
        for _ in 0..n {
            e.add(rng.below(max) as u32);
        }
        let mut prev = 0.0;
        for x in (0..max as u32 + 10).step_by(7) {
            let f = e.cdf(x);
            assert!((0.0..=1.0).contains(&f));
            assert!(f >= prev - 1e-15, "not monotone at {x}");
            assert!((e.survival(x) - (1.0 - f)).abs() < 1e-12);
            prev = f;
        }
        assert_eq!(e.cdf(max as u32 + 100), 1.0);
        assert_eq!(e.len(), n as u64);
    });
}

#[test]
fn prop_ecdf_quantile_inverts_cdf() {
    prop(30, |rng| {
        let mut e = EmpiricalCdf::new();
        for _ in 0..rng.range(10, 400) {
            e.add(rng.below(200) as u32);
        }
        for pi in 1..=9 {
            let p = pi as f64 / 10.0;
            let q = e.quantile(p);
            assert!(e.cdf(q) >= p - 1e-12, "cdf(quantile({p})) too small");
            if q > 0 {
                assert!(e.cdf(q - 1) < p + 1e-12, "quantile({p}) not minimal");
            }
        }
    });
}

#[test]
fn prop_irwin_hall_cdf_properties() {
    prop(25, |rng| {
        let n = rng.range(1, 45) as u32;
        let ih = IrwinHall::new(n);
        let mut prev = 0.0;
        for i in 0..=20 {
            let x = n as f64 * i as f64 / 20.0;
            let f = ih.cdf(x);
            assert!((0.0..=1.0 + 1e-12).contains(&f));
            assert!(f >= prev - 1e-9, "not monotone: n={n} x={x}");
            // CDF + survival = 1.
            assert!((f + ih.survival(x) - 1.0).abs() < 1e-9);
            prev = f;
        }
        // Mean/median symmetry (the alternating sum cancels hardest at
        // the midpoint; ~1e-8 absolute error at n=40 is expected).
        assert!((ih.cdf(n as f64 / 2.0) - 0.5).abs() < 1e-6);
    });
}

#[test]
fn prop_theta_estimator_bounds() {
    // ½ ≤ θ̂ ≤ ½ + (known − 1) always, with any survival model and any
    // visit pattern.
    prop(40, |rng| {
        let model = match rng.below(3) {
            0 => SurvivalModel::Empirical,
            1 => SurvivalModel::Geometric { q: 0.001 + rng.f64() * 0.5 },
            _ => SurvivalModel::Exponential { lambda: 0.001 + rng.f64() * 0.2 },
        };
        let mut s = NodeState::new(8, model);
        let walks = rng.range(1, 30) as u64;
        let mut t = 0u64;
        for _ in 0..rng.range(1, 200) {
            t += rng.range(0, 10) as u64;
            let id = WalkId(rng.below(walks as usize) as u64);
            s.observe(t, id, (id.0 % 8) as u16);
        }
        let visiting = WalkId(rng.below(walks as usize) as u64);
        s.observe(t + 1, visiting, 0);
        let theta = s.theta(t + 1, visiting);
        let known = s.known_walks() as f64;
        assert!(theta >= 0.5 - 1e-12, "theta {theta} < 0.5");
        assert!(theta <= 0.5 + known - 1.0 + 1e-12, "theta {theta} > bound");
    });
}

#[test]
fn prop_theta_monotone_decreasing_in_staleness() {
    // With an analytic survival model, waiting longer without seeing the
    // other walks can only lower the estimate.
    prop(30, |rng| {
        let q = 0.001 + rng.f64() * 0.3;
        let mut s = NodeState::new(4, SurvivalModel::Geometric { q });
        let k = rng.range(2, 10) as u64;
        for w in 0..k {
            s.observe(rng.below(50) as u64, WalkId(w), (w % 4) as u16);
        }
        let visiting = WalkId(0);
        let t1 = 100 + rng.below(100) as u64;
        let t2 = t1 + 1 + rng.below(500) as u64;
        assert!(s.theta(t1, visiting) >= s.theta(t2, visiting) - 1e-12);
    });
}

#[test]
fn prop_prune_never_changes_theta() {
    prop(30, |rng| {
        let mut s = NodeState::new(8, SurvivalModel::Empirical);
        let mut t = 0u64;
        for _ in 0..rng.range(10, 300) {
            t += rng.range(0, 5) as u64;
            let id = WalkId(rng.below(20) as u64);
            s.observe(t, id, (id.0 % 8) as u16);
        }
        let visiting = WalkId(0);
        s.observe(t + 1, visiting, 0);
        let now = t + 1 + rng.below(2000) as u64;
        let before = s.theta(now, visiting);
        s.prune(now);
        let after = s.theta(now, visiting);
        assert!((before - after).abs() < 1e-12, "{before} != {after}");
    });
}

#[test]
fn prop_cached_theta_bit_identical_to_direct() {
    // The survival-cache determinism lock at the unit level (ISSUE 2):
    // a `SurvivalTable`-backed NodeState and an uncached twin fed the
    // *same* randomized schedule of visits (new walks, revisits, arena
    // generation reuse), prunes, out-of-band CDF inserts and θ̂ queries
    // must agree on every single estimate **to the bit** — including
    // across empirical-CDF cache rebuilds, which are triggered lazily
    // and must fire on the same schedule in both.
    prop(60, |rng| {
        let model = match rng.below(3) {
            0 => SurvivalModel::Empirical,
            1 => SurvivalModel::Geometric { q: 0.001 + rng.f64() * 0.5 },
            _ => SurvivalModel::Exponential { lambda: 0.001 + rng.f64() * 0.2 },
        };
        let mut cached = NodeState::new(8, model);
        let mut direct = NodeState::new_uncached(8, model);
        let mut t = 0u64;
        let mut thetas = 0u32;
        for op in 0..rng.range(50, 400) {
            t += rng.below(6) as u64;
            match rng.below(10) {
                // Visits dominate: mix of fresh ids, revisits, and reused
                // slot indices under a new generation.
                0..=5 => {
                    let slot_idx = rng.below(24) as u32;
                    let generation = rng.below(3) as u32;
                    let id = WalkId::compose(slot_idx, generation);
                    let a = cached.observe(t, id, (slot_idx % 8) as u16);
                    let b = direct.observe(t, id, (slot_idx % 8) as u16);
                    assert_eq!(a, b, "case op {op}: observe diverged");
                }
                // Out-of-band CDF growth (the engine only adds via
                // observe, but the field is public — the memo must
                // survive arbitrary insert schedules).
                6 => {
                    let v = 1 + rng.below(500) as u32;
                    cached.return_cdf.add(v);
                    direct.return_cdf.add(v);
                }
                7 => {
                    cached.prune(t);
                    direct.prune(t);
                }
                // θ̂ queries, sometimes repeated at the same t (memo
                // replay) and sometimes far in the future (beyond-support
                // fast path).
                _ => {
                    let jump = if rng.below(4) == 0 { rng.below(3000) as u64 } else { 0 };
                    let visiting = WalkId::compose(rng.below(24) as u32, rng.below(3) as u32);
                    for _ in 0..1 + rng.below(2) {
                        let a = cached.theta(t + jump, visiting);
                        let b = direct.theta(t + jump, visiting);
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "case op {op}: theta diverged ({a} vs {b}) at t={} model {model:?}",
                            t + jump
                        );
                        thetas += 1;
                    }
                }
            }
        }
        // Every case ends with one guaranteed estimate so a query-free
        // random schedule still exercises the equivalence at least once.
        let a = cached.theta(t + 1, WalkId(0));
        let b = direct.theta(t + 1, WalkId(0));
        assert_eq!(a.to_bits(), b.to_bits(), "final theta diverged ({a} vs {b}), {thetas} before");
    });
}

#[test]
fn prop_engine_z_trace_conserved_and_bounded() {
    use decafork::control::DecaforkPlus;
    use decafork::failures::Probabilistic;
    use decafork::sim::engine::{Engine, SimParams};
    use decafork::sim::metrics::EventKind;
    use std::sync::Arc;

    prop(15, |rng| {
        let g = Arc::new(generators::random_regular(30, 4, rng).unwrap());
        let z0 = rng.range(2, 12) as u32;
        let max_walks = 64;
        let mut e = Engine::new(
            g,
            SimParams {
                z0,
                max_walks,
                control_start: Some(rng.below(100) as u64),
                ..Default::default()
            },
            DecaforkPlus::new(1.0 + rng.f64() * 2.0, 4.0 + rng.f64() * 3.0),
            Probabilistic::new(rng.f64() * 0.005),
            rng.split(99),
        );
        e.run_to(800);
        let tr = e.trace();
        // Conservation.
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            delta[ev.t as usize] += if ev.kind == EventKind::Fork { 1 } else { -1 };
        }
        for t in 1..tr.z.len() {
            assert_eq!(tr.z[t] as i64 - tr.z[t - 1] as i64, delta[t]);
        }
        // Cap respected.
        assert!(tr.z.iter().all(|&z| z as usize <= max_walks));
        // Extinction is flagged iff the trace hits zero.
        assert_eq!(tr.extinct, tr.z.contains(&0));
    });
}

#[test]
fn prop_walk_positions_always_valid() {
    use decafork::control::Decafork;
    use decafork::failures::Burst;
    use decafork::sim::engine::{Engine, SimParams};
    use std::sync::Arc;

    prop(10, |rng| {
        let g = Arc::new(random_graph(rng));
        let n = g.n();
        let mut e = Engine::new(
            g,
            SimParams { z0: 5, ..Default::default() },
            Decafork::new(1.5),
            Burst::new(vec![(50, 2)]),
            rng.split(1),
        );
        e.run_to(300);
        for w in e.snapshot() {
            assert!((w.at as usize) < n, "walk off-graph");
            if let Some(d) = w.died {
                assert!(d >= w.born);
                assert!(!w.alive);
            }
        }
    });
}
