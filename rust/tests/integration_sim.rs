//! End-to-end simulation invariants across the full stack
//! (graph × control × failures × engine × runner), at the paper's scales.

use decafork::control::{Decafork, DecaforkPlus};
use decafork::failures::{Burst, Byzantine, Failures, NoFailures, Probabilistic};
use decafork::graph::generators;
use decafork::rng::Rng;
use decafork::sim::engine::{Engine, SimParams};
use decafork::sim::metrics::EventKind;
use decafork::sim::{run_many, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};
use std::sync::Arc;

fn paper_graph(seed: u64) -> Arc<decafork::graph::Graph> {
    Arc::new(generators::random_regular(100, 8, &mut Rng::new(seed)).unwrap())
}

#[test]
fn decafork_survives_the_paper_scenario() {
    // Fig. 1 setting, single run: bursts of 5 and 6 walks; DECAFORK must
    // recover both times and stay within a sane corridor.
    let mut e = Engine::new(
        paper_graph(1),
        SimParams::default(),
        Decafork::new(2.0),
        Burst::paper_default(),
        Rng::new(42),
    );
    e.run_to(10_000);
    let tr = e.trace();
    assert!(!tr.extinct);
    assert!(tr.recovery_time(2000, 10).is_some(), "no recovery from burst 1");
    assert!(tr.recovery_time(6000, 10).is_some(), "no recovery from burst 2");
    assert!(tr.max_z(0, 10_000) <= 25, "overshoot {}", tr.max_z(0, 10_000));
    // Warm-up must silence the cold-start over-forking.
    assert!(tr.max_z(0, 1500) <= 12, "pre-failure forking: {}", tr.max_z(0, 1500));
}

#[test]
fn no_control_goes_extinct_under_continuous_failures() {
    let mut e = Engine::new(
        paper_graph(2),
        SimParams::default(),
        decafork::control::NoControl,
        Probabilistic::new(0.002),
        Rng::new(7),
    );
    e.run_to(10_000);
    assert!(e.trace().extinct, "10 walks with p_f=0.002 must die within 10k steps");
}

#[test]
fn decafork_plus_handles_byzantine_flip() {
    // Fig. 3 scenario: Byzantine node active until t=5000, honest after.
    // Byz starts after the failure-free initialization the paper requires.
    let failures = Failures::composite(vec![
        Burst::paper_default().into(),
        Byzantine::scheduled(1, vec![(1000, true), (5000, false)]).into(),
    ]);
    let mut e = Engine::new(
        paper_graph(3),
        SimParams::default(),
        DecaforkPlus::new(3.25, 5.75),
        failures,
        Rng::new(11),
    );
    e.run_to(10_000);
    let tr = e.trace();
    assert!(!tr.extinct, "DECAFORK+ must survive the Byzantine phase");
    // After the node turns honest the population must not explode.
    assert!(tr.max_z(5000, 10_000) <= 30, "post-flip overshoot {}", tr.max_z(5000, 10_000));
    assert!(tr.min_z(8000, 10_000) >= 1);
}

#[test]
fn theta_telemetry_tracks_population() {
    // Prop. 1 / Thm. 1 in vivo: estimator mean ≈ Z/2 during the stable
    // pre-failure window.
    let mut e = Engine::new(
        paper_graph(4),
        SimParams { record_theta: true, ..Default::default() },
        Decafork::new(2.0),
        NoFailures,
        Rng::new(5),
    );
    e.run_to(6000);
    let tr = e.trace();
    let window: Vec<f64> = tr
        .theta
        .iter()
        .filter(|&&(t, _)| t > 3000)
        .map(|&(_, th)| th)
        .collect();
    assert!(window.len() > 100);
    let mean = window.iter().sum::<f64>() / window.len() as f64;
    let z_mean = tr.mean_z(3000, 6000);
    // The estimator lags the true population by the propagation time of
    // recent forks (Thm. 1 is asymptotic in t − T_ℓ), and the empirical
    // survival adds a small negative bias — allow a ±2 corridor.
    assert!(
        (2.0 * mean - z_mean).abs() < 2.0,
        "2E[theta] = {:.2} vs Z = {:.2}",
        2.0 * mean,
        z_mean
    );
}

#[test]
fn missingperson_overshoots_more_than_decafork() {
    // The Fig. 1 qualitative ranking.
    let base = ExperimentConfig {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        params: SimParams::default(),
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures: FailureSpec::paper_bursts(),
        horizon: 10_000,
        runs: 5,
        seed: 77,
    };
    let (_, dk) = run_many(&base, 0).unwrap();
    let mp_cfg = ExperimentConfig {
        control: ControlSpec::MissingPerson { eps_mp: 800 },
        ..base.clone()
    };
    let (_, mp) = run_many(&mp_cfg, 0).unwrap();
    let dk_max = dk.max.iter().max().copied().unwrap();
    let mp_max = mp.max.iter().max().copied().unwrap();
    assert!(
        mp_max > dk_max,
        "missingperson should overshoot more: mp {mp_max} vs dk {dk_max}"
    );
    assert_eq!(dk.extinctions + mp.extinctions, 0);
}

#[test]
fn decafork_plus_reacts_faster_than_decafork() {
    let base = ExperimentConfig {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        params: SimParams::default(),
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures: FailureSpec::Burst { events: vec![(2000, 5)] },
        horizon: 5000,
        runs: 8,
        seed: 3,
    };
    let (t_dk, _) = run_many(&base, 0).unwrap();
    let plus_cfg = ExperimentConfig {
        control: ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 },
        ..base.clone()
    };
    let (t_plus, _) = run_many(&plus_cfg, 0).unwrap();
    let mean_rec = |traces: &[decafork::sim::metrics::Trace]| {
        let (m, _) = decafork::sim::AggregateTrace::mean_recovery(traces, 2000, 10);
        m.unwrap_or(f64::INFINITY)
    };
    let r_dk = mean_rec(&t_dk);
    let r_plus = mean_rec(&t_plus);
    assert!(
        r_plus < r_dk,
        "DECAFORK+ should react faster: {r_plus:.0} vs {r_dk:.0}"
    );
}

#[test]
fn probabilistic_failures_fig2_shape() {
    // DECAFORK with ε=2 under p_f=0.001 settles below Z0; DECAFORK+
    // (ε=3.25) holds more redundancy. This is the headline claim of Fig. 2.
    let failures = FailureSpec::Composite(vec![
        FailureSpec::paper_bursts(),
        FailureSpec::Probabilistic { p_f: 0.001 },
    ]);
    let base = ExperimentConfig {
        graph: GraphSpec::RandomRegular { n: 100, d: 8 },
        params: SimParams::default(),
        control: ControlSpec::Decafork { epsilon: 2.0 },
        failures,
        horizon: 10_000,
        runs: 6,
        seed: 21,
    };
    let (_, agg_dk) = run_many(&base, 0).unwrap();
    let cfg_plus = ExperimentConfig {
        control: ControlSpec::DecaforkPlus { epsilon: 3.25, epsilon2: 5.75 },
        ..base.clone()
    };
    let (_, agg_plus) = run_many(&cfg_plus, 0).unwrap();
    let tail_dk: f64 = agg_dk.mean[8000..].iter().sum::<f64>() / agg_dk.mean[8000..].len() as f64;
    let tail_plus: f64 =
        agg_plus.mean[8000..].iter().sum::<f64>() / agg_plus.mean[8000..].len() as f64;
    assert!(tail_dk < 10.0, "DECAFORK should sag below Z0: {tail_dk:.2}");
    assert!(tail_plus > tail_dk, "DECAFORK+ should hold more redundancy");
    assert_eq!(agg_plus.extinctions, 0);
}

#[test]
fn engine_conservation_across_scenarios() {
    // Z_t deltas must equal fork-minus-death counts for every step in
    // every scenario (burst, probabilistic, byzantine).
    let scenarios: Vec<Failures> = vec![
        Burst::new(vec![(500, 4)]).into(),
        Probabilistic::new(0.001).into(),
        Byzantine::scheduled(0, vec![(100, true), (900, false)]).into(),
    ];
    for (i, f) in scenarios.into_iter().enumerate() {
        let mut e = Engine::new(
            Arc::new(generators::random_regular(40, 6, &mut Rng::new(9)).unwrap()),
            SimParams { z0: 8, ..Default::default() },
            DecaforkPlus::new(2.0, 5.0),
            f,
            Rng::new(100 + i as u64),
        );
        e.run_to(2000);
        let tr = e.trace();
        let mut delta = vec![0i64; tr.z.len()];
        for ev in &tr.events {
            let d = if ev.kind == EventKind::Fork { 1 } else { -1 };
            delta[ev.t as usize] += d;
        }
        for t in 1..tr.z.len() {
            assert_eq!(
                tr.z[t] as i64 - tr.z[t - 1] as i64,
                delta[t],
                "scenario {i} violated conservation at t={t}"
            );
        }
    }
}

#[test]
fn all_graph_families_stable_fig6() {
    for (graph, eps) in [
        (GraphSpec::RandomRegular { n: 100, d: 8 }, 2.0),
        (GraphSpec::Complete { n: 100 }, 2.0),
        (GraphSpec::ErdosRenyi { n: 100, p: 0.08 }, 1.9),
        (GraphSpec::PowerLaw { n: 100, m: 4 }, 2.1),
    ] {
        let cfg = ExperimentConfig {
            graph: graph.clone(),
            params: SimParams::default(),
            control: ControlSpec::Decafork { epsilon: eps },
            failures: FailureSpec::paper_bursts(),
            horizon: 10_000,
            runs: 3,
            seed: 5,
        };
        let (traces, agg) = run_many(&cfg, 0).unwrap();
        assert_eq!(agg.extinctions, 0, "{} died", graph.label());
        for tr in &traces {
            assert!(
                tr.recovery_time(2000, 10).is_some(),
                "{} failed to recover",
                graph.label()
            );
        }
    }
}
