//! The determinism lock (ISSUE 1, extended by ISSUE 2): the arena engine
//! must reproduce the frozen seed engine's `Trace::z` **byte-for-byte**
//! on four seeded golden scenarios covering every failure surface
//! (pre-step bursts, per-hop probabilistic losses, Byzantine arrivals)
//! and every forking control family (DECAFORK, DECAFORK+,
//! MISSINGPERSON). Since the arena engine evaluates θ̂ through the
//! per-node `SurvivalTable` memo while the reference computes every term
//! directly, the lock also proves the cached and direct estimator paths
//! bit-identical — the DECAFORK-heavy `churn_decafork_empirical`
//! scenario exists specifically to stress that equivalence under
//! sustained empirical-CDF growth (every return-time sample can
//! invalidate the memo).
//!
//! Two layers of locking:
//!
//! 1. **Executable oracle** — `ReferenceEngine` in `sim/reference.rs` is
//!    a verbatim-semantics copy of the pre-refactor engine; both engines
//!    are built from the same [`Scenario`] (identical graph and RNG
//!    streams) and their z-traces compared on every `cargo test`.
//! 2. **Pinned files** — if `tests/golden/<name>.z.txt` exists, both
//!    traces are also compared against it, so a *simultaneous* regression
//!    of both engines cannot slip through. Set `DECAFORK_WRITE_GOLDEN=1`
//!    while running this test once to (re)record the files. The files
//!    are not yet committed: the refactor was authored in an offline
//!    sandbox with no Rust toolchain, so the first toolchain-equipped
//!    run must record and commit them (the CI `record golden traces`
//!    step uploads them as an artifact for exactly that purpose). Until
//!    then layer 1 — the frozen reference engine — is the active oracle.
//!
//! `DECAFORK_NODE_STATE=dense|lazy` selects the arena engine's
//! node-state store for the comparison (default lazy; the frozen
//! reference always keeps its own eager columns — `sim/reference.rs` is
//! byte-untouched). Lazy materialization is a pure storage choice, so
//! the arena must reproduce the reference in **both** modes — CI runs
//! this lock with each value, which is the shared-stream half of the
//! lazy-vs-dense golden matrix.
//!
//! `DECAFORK_HOP_PATH=scalar|blocked` is honored the same way (default
//! blocked): the single-arena `Engine` runs its shared-stream loop
//! unconditionally — like `routing` and `shards`, the knob only changes
//! behavior in the `ShardedEngine` — so setting it here is a vacuous
//! but deliberate part of the CI hop-path matrix (the substantive half
//! lives in `stream_golden.rs` and `shard_invariance.rs`).
//!
//! `DECAFORK_METRICS=off|jsonl|csv` (default off) turns the streaming
//! metrics sink on for the arena side of the comparison (the frozen
//! reference predates telemetry and stays byte-untouched). Telemetry
//! is observation-only (DESIGN.md §Observability), so the arena must
//! keep reproducing the reference with the sink streaming — CI's
//! metrics smoke re-runs this lock under off and jsonl. An enabled
//! sink with no `DECAFORK_METRICS_OUT` writes to a temp path.

use decafork::scenario::presets;
use std::path::PathBuf;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(format!("{name}.z.txt"))
}

fn encode(z: &[u32]) -> String {
    z.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
}

/// `DECAFORK_METRICS` family for test runs: same parsing as the CLI,
/// but an enabled sink with no explicit path streams to a temp file
/// (tagged per process and scenario) instead of littering the cwd.
fn metrics_from_env_for_tests(tag: &str) -> decafork::obs::MetricsConfig {
    let mut cfg = decafork::scenario::parse::metrics_from_env().expect("DECAFORK_METRICS");
    if cfg.enabled() && cfg.out.is_none() {
        let mut p = std::env::temp_dir();
        p.push(format!("decafork_shared_{}_{tag}.{}", std::process::id(), cfg.mode.as_str()));
        cfg.out = Some(p.to_string_lossy().into_owned());
    }
    cfg
}

#[test]
fn arena_engine_reproduces_reference_engine_exactly() {
    let node_state = decafork::scenario::parse::node_state_from_env().expect("DECAFORK_NODE_STATE");
    let hop_path = decafork::scenario::parse::hop_path_from_env().expect("DECAFORK_HOP_PATH");
    for (name, mut scenario) in presets::golden() {
        let reference = {
            let mut e = scenario.reference_engine(0).unwrap();
            e.run_to(scenario.horizon);
            e.into_trace()
        };
        scenario.params.node_state = node_state;
        scenario.params.hop_path = hop_path;
        scenario.params.metrics = metrics_from_env_for_tests(name);
        let arena = {
            let mut e = scenario.engine(0).unwrap();
            e.run_to(scenario.horizon);
            e.into_trace()
        };

        assert_eq!(
            arena.z, reference.z,
            "golden scenario '{name}': arena z-trace diverged from the seed engine"
        );
        assert_eq!(arena.extinct, reference.extinct, "'{name}': extinction flag diverged");
        assert_eq!(arena.capped, reference.capped, "'{name}': cap flag diverged");
        // Event *sets* must agree even though arena ids are generational:
        // same number of forks/deaths at every t (kill order inside one
        // composite pre-step may differ, values of ids may differ).
        let count_at = |tr: &decafork::sim::metrics::Trace, fork: bool| {
            let mut v = vec![0i64; tr.z.len()];
            for ev in &tr.events {
                use decafork::sim::metrics::EventKind;
                if (ev.kind == EventKind::Fork) == fork {
                    v[ev.t as usize] += 1;
                }
            }
            v
        };
        assert_eq!(count_at(&arena, true), count_at(&reference, true), "'{name}': fork counts");
        assert_eq!(count_at(&arena, false), count_at(&reference, false), "'{name}': death counts");

        // Layer 2: pinned golden files, when present.
        let path = golden_path(name);
        if std::env::var("DECAFORK_WRITE_GOLDEN").is_ok() {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, encode(&arena.z)).unwrap();
            eprintln!("recorded golden trace {}", path.display());
        } else if path.exists() {
            let want = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                encode(&arena.z),
                want.trim_end(),
                "golden scenario '{name}': z-trace diverged from the pinned file {}",
                path.display()
            );
        }
    }
}

#[test]
fn golden_scenarios_are_nontrivial() {
    // Guard against the lock silently testing a dead scenario: each
    // golden run must actually exercise forks AND failures.
    use decafork::sim::metrics::EventKind;
    for (name, scenario) in presets::golden() {
        let mut e = scenario.engine(0).unwrap();
        e.run_to(scenario.horizon);
        let tr = e.trace();
        assert!(!tr.extinct, "'{name}' went extinct — useless as a lock");
        assert!(tr.count(EventKind::Fork) > 0, "'{name}' never forked");
        assert!(tr.count(EventKind::Failure) > 0, "'{name}' never failed a walk");
    }
}
