//! The stream-mode golden family (ISSUE 3): pinned `Trace::z` files for
//! the golden quartet driven through the per-walk-stream `ShardedEngine`.
//!
//! Stream mode is a *different trace family* from the shared-stream
//! engines (randomness ownership moved from one engine-wide stream to
//! per-walk / per-node streams), so it cannot share the arena-vs-
//! reference oracle — its lock is the pin itself plus the shard-count
//! invariance suite (`tests/shard_invariance.rs`).
//!
//! * `DECAFORK_SHARDS=k` runs the comparison at `k` workers (default 1).
//!   Schedule invariance means the pinned file must match at **every**
//!   `k` — CI's shard-matrix smoke step runs this test at 1, 2 and 8.
//! * `DECAFORK_NODE_STATE=dense|lazy` selects the node-state store the
//!   comparison runs with (default lazy). Lazy materialization is a
//!   pure storage choice (DESIGN.md §Lazy node store), so the **same**
//!   pinned file must match in both modes — CI crosses this knob with
//!   the shard matrix, which is the golden-family half of the
//!   lazy-vs-dense lock.
//! * `DECAFORK_ROUTING=serial|mailbox` selects the arrival routing
//!   (default mailbox). Routing is a pure transport choice (DESIGN.md
//!   §Locality & routing), so the **same** pinned file must match in
//!   both modes — CI crosses this knob with the node-state × shard
//!   matrix, the golden-family half of the mailbox-vs-serial lock.
//! * `DECAFORK_HOP_PATH=scalar|blocked` selects the hot-phase execution
//!   strategy (default blocked). Block pipelining only restages *when*
//!   memory is touched — per-walk draw order is untouched (DESIGN.md
//!   §Block pipelining) — so the **same** pinned file must match under
//!   both paths; CI crosses this knob with the shard matrix.
//! * `DECAFORK_METRICS=off|jsonl|csv` turns the streaming metrics sink
//!   on for the comparison (default off; `DECAFORK_METRICS_OUT` and
//!   `DECAFORK_METRICS_EVERY` are honored, with the output defaulting
//!   to a per-process temp path so test runs leave no files behind).
//!   Telemetry is observation-only (DESIGN.md §Observability), so the
//!   **same** pinned file must match with the sink on — CI's metrics
//!   smoke re-runs this lock under off and jsonl.
//! * `DECAFORK_WRITE_GOLDEN=1` (re)records the pins. Like the
//!   shared-stream pins, the files cannot be generated in the offline
//!   authoring sandbox (no Rust toolchain); the CI `record golden
//!   traces` step uploads them for the one-time commit. Until the files
//!   exist, the invariance suite is the active lock.

use decafork::scenario::presets;
use std::path::PathBuf;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("stream_{name}.z.txt"))
}

fn encode(z: &[u32]) -> String {
    z.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
}

/// `DECAFORK_METRICS` family for test runs: same parsing as the CLI,
/// but an enabled sink with no explicit path streams to a temp file
/// (tagged per process and scenario) instead of littering the cwd.
fn metrics_from_env_for_tests(tag: &str) -> decafork::obs::MetricsConfig {
    let mut cfg = decafork::scenario::parse::metrics_from_env().expect("DECAFORK_METRICS");
    if cfg.enabled() && cfg.out.is_none() {
        let mut p = std::env::temp_dir();
        p.push(format!("decafork_golden_{}_{tag}.{}", std::process::id(), cfg.mode.as_str()));
        cfg.out = Some(p.to_string_lossy().into_owned());
    }
    cfg
}

#[test]
fn stream_mode_traces_match_pinned_goldens() {
    let shards = decafork::scenario::parse::shards_from_env().expect("DECAFORK_SHARDS");
    let node_state = decafork::scenario::parse::node_state_from_env().expect("DECAFORK_NODE_STATE");
    let routing = decafork::scenario::parse::routing_from_env().expect("DECAFORK_ROUTING");
    let hop_path = decafork::scenario::parse::hop_path_from_env().expect("DECAFORK_HOP_PATH");
    for (name, mut scenario) in presets::golden() {
        scenario.params.node_state = node_state;
        scenario.params.routing = routing;
        scenario.params.hop_path = hop_path;
        scenario.params.metrics = metrics_from_env_for_tests(name);
        let trace = {
            let mut e = scenario.sharded_engine(0, shards).unwrap();
            e.run_to(scenario.horizon);
            e.into_trace()
        };
        let path = golden_path(name);
        if std::env::var("DECAFORK_WRITE_GOLDEN").is_ok() {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, encode(&trace.z)).unwrap();
            eprintln!("recorded stream-mode golden trace {}", path.display());
        } else if path.exists() {
            let want = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                encode(&trace.z),
                want.trim_end(),
                "stream golden '{name}' (shards={shards}): z-trace diverged from {}",
                path.display()
            );
        }
    }
}

#[test]
fn stream_golden_scenarios_are_nontrivial() {
    // Mirror of the shared-stream guard: each stream-mode golden run
    // must exercise forks AND failures, or the pin locks a dead system.
    use decafork::sim::metrics::EventKind;
    for (name, scenario) in presets::golden() {
        let mut e = scenario.sharded_engine(0, 1).unwrap();
        e.run_to(scenario.horizon);
        let tr = e.trace();
        assert!(!tr.extinct, "stream-mode '{name}' went extinct — useless as a lock");
        assert!(tr.count(EventKind::Fork) > 0, "stream-mode '{name}' never forked");
        assert!(tr.count(EventKind::Failure) > 0, "stream-mode '{name}' never failed a walk");
    }
}
