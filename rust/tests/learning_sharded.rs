//! Shard invariance of the **sharded learning subsystem** (ISSUE 5):
//! the trainer's observable outputs — the canonical per-visit loss
//! stream, its digest, and the simulation trace it rode on — must be
//! bit-identical at every worker count, and attaching the trainer must
//! not move a single trace bit relative to a hook-free run of the same
//! scenario. Runs entirely on the pure-Rust `BigramOp`, so it needs no
//! artifacts and no PJRT.

use std::sync::Arc;

use decafork::learning::{
    presets, train_sharded, ShardedTrainOptions, TrainOptions, TrainingRun, TrainingSummary,
};
use decafork::sim::CoreBudget;

fn run_at(workers: usize) -> TrainingSummary {
    let spec = presets::learn_tiny();
    let op = spec.op();
    let corpus = Arc::new(spec.corpus());
    train_sharded(
        &spec.scenario,
        0,
        &op,
        corpus,
        &ShardedTrainOptions {
            workers,
            horizon: spec.scenario.horizon,
            // The seed execute_budgeted derives from the scenario, so
            // the budget test below can compare digests directly.
            seed: spec.scenario.seed,
            merge_period: spec.merge_period,
        },
    )
    .expect("tiny training run must succeed")
}

#[test]
fn loss_curve_bit_identical_at_shards_1_2_8() {
    let base = run_at(1);
    assert!(base.steps > 200, "workload too small to prove anything: {} steps", base.steps);
    for workers in [2usize, 8] {
        let other = run_at(workers);
        assert!(
            base.trace.bit_identical(&other.trace),
            "simulation trace diverged between 1 and {workers} workers"
        );
        assert_eq!(base.losses.len(), other.losses.len());
        for (a, b) in base.losses.iter().zip(&other.losses) {
            assert_eq!(a.0, b.0, "loss timestamps diverged at {workers} workers");
            assert_eq!(a.1, b.1, "loss walk ids diverged at {workers} workers");
            assert_eq!(
                a.2.to_bits(),
                b.2.to_bits(),
                "loss bits diverged at {workers} workers (t={}, walk={})",
                a.0,
                a.1
            );
        }
        assert_eq!(base.loss_digest(), other.loss_digest());
        assert_eq!(base.merges, other.merges, "merge rounds diverged");
    }
}

#[test]
fn trainer_does_not_perturb_the_simulation() {
    // Same scenario, same worker count, no hook: the z-trace, event log
    // and θ̂ telemetry must be exactly what the trainer-carrying run saw.
    let spec = presets::learn_tiny();
    let trained = run_at(2);
    let mut plain = spec.scenario.sharded_engine(0, 2).unwrap();
    plain.run_to(spec.scenario.horizon);
    assert!(
        plain.into_trace().bit_identical(&trained.trace),
        "attaching the sharded trainer changed the simulation trace"
    );
}

#[test]
fn budgeted_training_is_result_invariant() {
    // The CoreBudget satellite: the budget plans the worker count, and
    // the plan must never change a result bit — a 1-core budget and a
    // generous one produce the same digest for the same request.
    let spec = presets::learn_tiny();
    let op = spec.op();
    let opts = |budget: CoreBudget| TrainOptions {
        stream: true,
        shards: 8,
        budget,
        merge_period: spec.merge_period,
        merge_on_meet: false,
    };
    let tight = TrainingRun::execute_budgeted(
        &spec.scenario,
        0,
        &op,
        Arc::new(spec.corpus()),
        &opts(CoreBudget::new(1).unwrap()),
    )
    .unwrap();
    let wide = TrainingRun::execute_budgeted(
        &spec.scenario,
        0,
        &op,
        Arc::new(spec.corpus()),
        &opts(CoreBudget::new(16).unwrap()),
    )
    .unwrap();
    assert_eq!(tight.loss_digest(), wide.loss_digest(), "core budget changed the loss stream");
    assert!(tight.trace.bit_identical(&wide.trace));
    // ... and matches the direct sharded run with the same seed.
    assert_eq!(tight.loss_digest(), run_at(8).loss_digest());
}

#[test]
fn fork_handoff_keeps_training_alive_through_the_burst() {
    // learn_tiny kills 3 of 8 walks at t=150; DECAFORK refills the
    // population with model-carrying forks. If payload handoff broke,
    // the post-burst loss stream would carry walks without models (no
    // losses) or restart from scratch (loss jumping back to ln V).
    let s = run_at(4);
    let burst_t = 150u64;
    let post: Vec<f32> =
        s.losses.iter().filter(|&&(t, _, _)| t > burst_t + 50).map(|&(_, _, l)| l).collect();
    assert!(!post.is_empty(), "training died after the burst");
    let uniform = (16f32).ln();
    let post_mean = post.iter().sum::<f32>() / post.len() as f32;
    assert!(
        post_mean < 0.9 * uniform,
        "post-burst losses regressed to cold start: mean {post_mean} vs uniform {uniform}"
    );
    assert!(s.last_loss_mean < s.first_loss, "no end-to-end progress");
}
