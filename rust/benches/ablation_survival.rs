//! Ablation (paper footnote 5): empirical survival vs analytic
//! geometric/exponential survival functions. The analytic variants skip
//! the estimation warm-up entirely (control can start at the paper's
//! "every walk visited every node" point) and give smoother estimates —
//! at the price of assuming the return-time family.

use decafork::report::Table;
use decafork::sim::engine::{SimParams, SurvivalSpec};
use decafork::sim::{run_many, AggregateTrace, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let mut table = Table::new(&[
        "survival model",
        "warm-up",
        "mean Z (t>1k)",
        "max Z",
        "reaction b1",
        "reaction b2",
        "forks/run",
        "extinct",
    ]);
    for (label, spec, warmup) in [
        ("empirical (default)", SurvivalSpec::Empirical, None::<u64>),
        ("analytic geometric", SurvivalSpec::AnalyticGeometric, Some(700)),
        ("analytic exponential", SurvivalSpec::AnalyticExponential, Some(700)),
        // The analytic models stay correct even with a minimal warm-up —
        // only the coverage requirement remains (each walk known at each
        // node); cover time for n=100 8-regular is ~550.
        ("analytic geometric, short warm-up", SurvivalSpec::AnalyticGeometric, Some(560)),
    ] {
        let cfg = ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 100, d: 8 },
            params: SimParams {
                survival: spec,
                control_start: warmup,
                shards: decafork::scenario::parse::shards_from_env()?,
                ..Default::default()
            },
            control: ControlSpec::Decafork { epsilon: 2.0 },
            failures: FailureSpec::paper_bursts(),
            horizon: 10_000,
            runs,
            seed: 0xAB1A,
        };
        let (traces, agg) = run_many(&cfg, 0)?;
        let fmt = |r: (Option<f64>, usize)| match r {
            (Some(v), 0) => format!("{v:.0}"),
            (Some(v), u) => format!("{v:.0} ({u}!)"),
            (None, _) => "never".into(),
        };
        let mean_z: f64 =
            traces.iter().map(|t| t.mean_z(1000, 10_000)).sum::<f64>() / traces.len() as f64;
        table.row(vec![
            label.to_string(),
            warmup.map(|w| w.to_string()).unwrap_or("auto(691)".into()),
            format!("{mean_z:.2}"),
            format!("{}", agg.max.iter().max().unwrap()),
            fmt(AggregateTrace::mean_recovery(&traces, 2000, 10)),
            fmt(AggregateTrace::mean_recovery(&traces, 6000, 10)),
            format!("{:.1}", agg.forks_per_run.iter().sum::<usize>() as f64 / agg.runs as f64),
            format!("{}/{}", agg.extinctions, agg.runs),
        ]);
    }
    println!("ablation_survival — DECAFORK e=2, Fig.1 failures, {runs} runs\n");
    println!("{}", table.render());
    println!("({:.2?})", t0.elapsed());
    Ok(())
}
