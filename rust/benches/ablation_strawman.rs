//! The introduction's strawman, quantified: periodic forking with period
//! T either floods the network (small T) or lets the population die
//! (large T, under continuous failures) — there is no good fixed T,
//! which is the gap DECAFORK fills. Also sweeps DECAFORK's fork
//! probability p (paper: p = 1/Z0) showing the flooding risk at p = 1.

use decafork::report::Table;
use decafork::sim::engine::SimParams;
use decafork::sim::{run_many, ControlSpec, ExperimentConfig, FailureSpec, GraphSpec};

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    // Continuous failures so "never fork" is fatal.
    let failures = FailureSpec::Composite(vec![
        FailureSpec::paper_bursts(),
        FailureSpec::Probabilistic { p_f: 0.0005 },
    ]);
    let mut table = Table::new(&["policy", "mean Z (t>1k)", "max Z", "capped", "extinct"]);
    let mut run = |label: String, control: ControlSpec| -> anyhow::Result<()> {
        let cfg = ExperimentConfig {
            graph: GraphSpec::RandomRegular { n: 100, d: 8 },
            params: SimParams {
                max_walks: 512,
                shards: decafork::scenario::parse::shards_from_env()?,
                ..Default::default()
            },
            control,
            failures: failures.clone(),
            horizon: 10_000,
            runs,
            seed: 0x57A1,
        };
        let (traces, agg) = run_many(&cfg, 0)?;
        let mean_z: f64 =
            traces.iter().map(|t| t.mean_z(1000, 10_000)).sum::<f64>() / traces.len() as f64;
        table.row(vec![
            label,
            format!("{mean_z:.1}"),
            format!("{}", agg.max.iter().max().unwrap()),
            format!("{}/{}", agg.capped_runs, agg.runs),
            format!("{}/{}", agg.extinctions, agg.runs),
        ]);
        Ok(())
    };
    for period in [200u64, 1000, 4000, 20_000] {
        run(format!("periodic T={period}"), ControlSpec::Periodic { period })?;
    }
    run("decafork e=2 (p=1/Z0)".into(), ControlSpec::Decafork { epsilon: 2.0 })?;
    println!("ablation_strawman — bursts + p_f=5e-4, {runs} runs, walk cap 512\n");
    println!("{}", table.render());
    println!("expected: small T floods (hits the cap), huge T drains; DECAFORK holds ~Z0.");
    Ok(())
}
