//! Hop-path bench (ISSUE 9): proves the block-pipelined hot phases —
//! per-block prefetch staging, batched `Graph::step_block` draws, and
//! the prefetched control sweep — beat the scalar loop on the memory-
//! bound large-graph regime, without moving a single bit of the trace.
//!
//! Two legs:
//!
//! 1. **scale_10m scalar vs blocked** at the full worker count: the
//!    10⁷-node small-world preset, where both hot phases are cache-miss
//!    bound — the hop phase on per-walk node state and the control
//!    phase on `NodeStore`/`SlotIndex` probes over millions of visited
//!    nodes. Before any clock is trusted the leg **asserts
//!    `Trace::bit_identical`** between the two paths — z, the full
//!    event log, flags, and every θ̂ float at the bit level. A "blocked
//!    win" that moved a bit is a bug, not a result.
//!    Acceptance bar: blocked ≥ 1.3× scalar steps/s.
//! 2. **CSR leg (report)**: the same scenario on a materialized
//!    random-regular CSR graph, where the tier-B prefetch additionally
//!    covers the adjacency row and the per-node Lemire threshold —
//!    the backend the offset-pair/row prefetches were built for.
//!
//! Writes `BENCH_hop.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_HOP_N` shrinks the node count (CI smoke),
//! `DECAFORK_PERF_STEPS` rescales the horizon, `DECAFORK_HOP_WORKERS`
//! sets the worker count (default 7 workers = 8 shards),
//! `DECAFORK_PIN_CORES=on` additionally pins workers to cores (off by
//! default — CI runners are cgroup-restricted), and
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the speedup bar to a report
//! (the bit-identical assert is **never** downgraded).

mod perf_common;

use decafork::scenario::{parse, presets, GraphSpec, Scenario};
use decafork::sim::engine::HopPath;
use perf_common::{
    assert_bit_identical, enforce_bar, env_u64, steps_per_sec, write_bench_json,
};
use std::time::Instant;

struct Run {
    secs: f64,
    trace: decafork::sim::metrics::Trace,
}

/// Build, run to the horizon, and measure one scenario/hop-path cell.
fn run_cell(
    scenario: &Scenario,
    hop_path: HopPath,
    shards: usize,
    pin: bool,
) -> anyhow::Result<Run> {
    let mut s = scenario.clone();
    s.params.hop_path = hop_path;
    s.params.pin_cores = pin;
    let mut e = s.sharded_engine(0, shards)?;
    let t0 = Instant::now();
    e.run_to(s.horizon);
    let secs = t0.elapsed().as_secs_f64();
    Ok(Run { secs, trace: e.into_trace() })
}

fn main() -> anyhow::Result<()> {
    let workers = env_u64("DECAFORK_HOP_WORKERS").map(|w| (w as usize).max(1)).unwrap_or(7);
    let shards = workers + 1;
    let pin = parse::pin_cores_from_env()?;

    // ---- Leg 1: scalar vs blocked on the scale_10m implicit preset ----
    let mut h1 = presets::scale_10m();
    h1.params.record_theta = true; // θ̂ floats must match bit-for-bit too
    let n1 = env_u64("DECAFORK_HOP_N").map(|n| (n as usize).max(10_000)).unwrap_or(10_000_000);
    if n1 != 10_000_000 {
        h1.graph = GraphSpec::ImplicitSmallWorld { n: n1, d: 8 };
    }
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS") {
        h1.rescale_to(steps.max(50));
    }
    println!(
        "perf_hop leg 1: {} | {} steps | {shards} shards | pin_cores={pin}",
        h1.label(),
        h1.horizon
    );

    let scalar = run_cell(&h1, HopPath::Scalar, shards, pin)?;
    let blocked = run_cell(&h1, HopPath::Blocked, shards, pin)?;

    // The oracle comes before the clock: identical bits or no result.
    assert_bit_identical(
        &scalar.trace,
        &blocked.trace,
        "blocked hop path diverged from the scalar loop at scale_10m",
    );
    let (ss, sb) = (steps_per_sec(&scalar.trace, scalar.secs), steps_per_sec(&blocked.trace, blocked.secs));
    let speedup = sb / ss;
    println!("  steps/s scalar          : {ss:>8.1}");
    println!("  steps/s blocked         : {sb:>8.1}");
    println!("  blocked / scalar        : {speedup:>8.2}x  (acceptance bar: >= 1.3x)");
    let pass = speedup >= 1.3;

    // ---- Leg 2: CSR backend report (prefetch covers adjacency rows) ----
    let mut h2 = h1.clone();
    let n2 = n1.min(1_000_000); // materialized: 8 stored edges per node
    h2.graph = GraphSpec::RandomRegular { n: n2, d: 8 };
    println!("\nperf_hop leg 2: {} | {} steps (CSR, report only)", h2.label(), h2.horizon);
    let s2 = run_cell(&h2, HopPath::Scalar, shards, pin)?;
    let b2 = run_cell(&h2, HopPath::Blocked, shards, pin)?;
    assert_bit_identical(
        &s2.trace,
        &b2.trace,
        "blocked hop path diverged from the scalar loop on the CSR leg",
    );
    let (ss2, sb2) = (steps_per_sec(&s2.trace, s2.secs), steps_per_sec(&b2.trace, b2.secs));
    println!("  steps/s scalar / blocked: {ss2:>8.1} / {sb2:.1} ({:.2}x)", sb2 / ss2);

    let json = format!(
        "{{\n  \"bench\": \"perf_hop\",\n  \"mode\": \"block-pipelined hop & control phases vs scalar loop, traces asserted bit-identical\",\n  \"shards\": {shards},\n  \"pin_cores\": {pin},\n  \"hop_block\": 64,\n  \"scale_10m\": {{\n    \"n\": {n1},\n    \"steps\": {},\n    \"bit_identical\": true,\n    \"theta_samples_compared\": {},\n    \"steps_per_sec_scalar\": {ss:.1},\n    \"steps_per_sec_blocked\": {sb:.1},\n    \"speedup_blocked_over_scalar\": {speedup:.3}\n  }},\n  \"csr_leg\": {{\n    \"n\": {n2},\n    \"bit_identical\": true,\n    \"steps_per_sec_scalar\": {ss2:.1},\n    \"steps_per_sec_blocked\": {sb2:.1},\n    \"speedup_blocked_over_scalar\": {:.3}\n  }},\n  \"acceptance_min_speedup\": 1.3,\n  \"pass\": {pass}\n}}\n",
        h1.horizon,
        scalar.trace.theta.len(),
        sb2 / ss2,
    );
    let out = write_bench_json("BENCH_hop.json", &json)?;

    enforce_bar(pass, format!("perf_hop speedup bar not met ({speedup:.2}x < 1.3x) — see {out}"))
}
