//! Bench/regenerator for paper Fig. 5: the ε trade-off — larger ε reacts
//! faster but forks more beyond Z0 (objectives (i) vs (ii)).

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let fig = decafork::figures::fig5(
        runs,
        0,
        decafork::scenario::parse::shards_from_env()?,
        decafork::sim::CoreBudget::from_env()?,
    )?;
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv("results")?;
    println!("fig5 done in {:.2?}; csv {}", t0.elapsed(), path.display());
    Ok(())
}
