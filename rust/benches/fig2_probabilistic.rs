//! Bench/regenerator for paper Fig. 2: bursts + per-step probabilistic
//! failures, DECAFORK vs DECAFORK+ at p_f ∈ {0.0002, 0.001}.

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::var("DECAFORK_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let t0 = std::time::Instant::now();
    let fig = decafork::figures::fig2(
        runs,
        0,
        decafork::scenario::parse::shards_from_env()?,
        decafork::sim::CoreBudget::from_env()?,
    )?;
    println!("{}", fig.plot(100, 18));
    println!("{}", fig.summary());
    let path = fig.write_csv("results")?;
    println!("fig2 done in {:.2?}; csv {}", t0.elapsed(), path.display());
    Ok(())
}
