//! Estimator micro-bench: θ̂ evaluations/second for each survival model
//! and walk-table size — the innermost loop of every control decision —
//! with the survival-cached path (`NodeState::new`, SurvivalTable memo)
//! benched against the direct path (`NodeState::new_uncached`, the seed
//! arithmetic). The `cached/direct` column is the microscopic version of
//! what `perf_control` measures end-to-end.

use decafork::rng::Rng;
use decafork::walks::{NodeState, SurvivalModel, WalkId};

fn bench(model: SurvivalModel, known: usize, iters: u64, cached: bool) -> f64 {
    let mut s = if cached {
        NodeState::new(16, model)
    } else {
        NodeState::new_uncached(16, model)
    };
    let mut rng = Rng::new(3);
    for w in 0..known as u64 {
        s.observe(rng.below(1000) as u64, WalkId(w), (w % 16) as u16);
    }
    // Populate the return-time distribution (empirical model reads it).
    for _ in 0..2000 {
        s.return_cdf.add(rng.geometric(0.01) as u32);
    }
    let mut acc = 0.0f64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        // Query just past the observe window (last-seen ∈ [0, 1000)), so
        // empirical dt values land *inside* the CDF support (geometric
        // q=0.01 samples reach ~700+) and the loop measures real survival
        // lookups, not the beyond-support skip path.
        acc += s.theta(1000 + i % 64, WalkId(i % known as u64));
    }
    let dt = t0.elapsed();
    std::hint::black_box(acc);
    iters as f64 / dt.as_secs_f64()
}

fn main() {
    println!("perf_estimator: theta() evaluations/second\n");
    println!(
        "{:<28} {:>10} {:>16} {:>16} {:>10}",
        "model", "known", "direct/s", "cached/s", "ratio"
    );
    for known in [10usize, 40, 200] {
        for (name, model) in [
            ("empirical", SurvivalModel::Empirical),
            ("geometric", SurvivalModel::Geometric { q: 0.01 }),
            ("exponential", SurvivalModel::Exponential { lambda: 0.01 }),
        ] {
            let direct = bench(model, known, 2_000_000, false);
            let cached = bench(model, known, 2_000_000, true);
            let ratio = cached / direct;
            println!("{name:<28} {known:>10} {direct:>16.3e} {cached:>16.3e} {ratio:>9.2}x");
        }
    }
}
