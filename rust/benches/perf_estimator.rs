//! Estimator micro-bench: θ̂ evaluations/second for each survival model
//! and walk-table size — the innermost loop of every control decision.

use decafork::rng::Rng;
use decafork::walks::{NodeState, SurvivalModel, WalkId};

fn bench(model: SurvivalModel, known: usize, iters: u64) -> f64 {
    let mut s = NodeState::new(16, model);
    let mut rng = Rng::new(3);
    for w in 0..known as u64 {
        s.observe(rng.below(1000) as u64, WalkId(w), (w % 16) as u16);
    }
    // Populate the return-time distribution (empirical model reads it).
    for _ in 0..2000 {
        s.return_cdf.add(rng.geometric(0.01) as u32);
    }
    let mut acc = 0.0f64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        acc += s.theta(2000 + i % 64, WalkId(i % known as u64));
    }
    let dt = t0.elapsed();
    std::hint::black_box(acc);
    iters as f64 / dt.as_secs_f64()
}

fn main() {
    println!("perf_estimator: theta() evaluations/second\n");
    println!("{:<28} {:>10} {:>16}", "model", "known", "theta/s");
    for known in [10usize, 40, 200] {
        for (name, model) in [
            ("empirical", SurvivalModel::Empirical),
            ("geometric", SurvivalModel::Geometric { q: 0.01 }),
            ("exponential", SurvivalModel::Exponential { lambda: 0.01 }),
        ] {
            let rate = bench(model, known, 2_000_000);
            println!("{:<28} {:>10} {:>16.3e}", name, known, rate);
        }
    }
}
