//! PJRT runtime latency: train-step and θ-kernel wall time through the
//! compiled artifacts — the L2/L1 contribution to a visit's cost.
//! Skips (exit 0, loud message) when artifacts are missing.

use decafork::runtime::{artifacts_present, default_artifacts_dir, Runtime, ThetaKernel, TrainStep};

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    if !artifacts_present(&dir) {
        eprintln!("SKIP perf_runtime: no artifacts at {} (make artifacts)", dir.display());
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let ts = TrainStep::load(&rt, &dir)?;
    let pc = ts.param_count()?;
    let (b, t1) = ts.token_shape()?;
    let vocab = ts.manifest.get_usize("vocab")? as i32;
    println!(
        "perf_runtime: model={} params={} batch={}x{}",
        ts.manifest.get("model")?,
        pc,
        b,
        t1
    );
    let mut params = vec![0.01f32; pc];
    let tokens: Vec<i32> = (0..b * t1).map(|i| (i as i32 * 13 + 1) % vocab).collect();

    // Warm-up (compilation already done at load; first exec warms caches).
    for _ in 0..3 {
        let (p, _) = ts.step(&params, &tokens)?;
        params = p;
    }
    let iters = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let (p, l) = ts.step(&params, &tokens)?;
        params = p;
        std::hint::black_box(l);
    }
    let per = t0.elapsed() / iters;
    let tok_per_s = (b * (t1 - 1)) as f64 / per.as_secs_f64();
    println!("train_step: {per:?}/step  ({tok_per_s:.0} tokens/s)");

    let th = ThetaKernel::load(&rt, &dir)?;
    let (n, k) = (th.nodes, th.walks);
    let elapsed = vec![25.0f32; n * k];
    let q = vec![0.01f32; n];
    let mask = vec![1.0f32; n * k];
    for _ in 0..3 {
        th.theta(&elapsed, &q, &mask)?;
    }
    let iters = 200;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(th.theta(&elapsed, &q, &mask)?);
    }
    let per = t0.elapsed() / iters;
    println!(
        "theta_kernel: {per:?}/call for {n}x{k} ({:.3e} survival evals/s)",
        (n * k) as f64 / per.as_secs_f64()
    );
    Ok(())
}
