//! Topology-layer throughput (ISSUE 6): the pool-parallel CSR builder
//! against the single-threaded validating `from_edges` it replaced on
//! the generator hot path, the implicit backend's O(1) memory budget,
//! and the `scale_10m` completion probe.
//!
//! Before any clock is trusted the bench **asserts output equality**:
//! the parallel builder's CSR must match the sequential one
//! neighbor-for-neighbor at 10⁶ nodes, the parallel connectivity check
//! must agree with the sequential BFS, and the implicit backend must be
//! bit-identical to its materialization (degrees, neighbor lists, and a
//! 50k-draw `step` stream) — a "speedup" that moved one byte is a bug,
//! not a result.
//!
//! Acceptance bars (gated on `DECAFORK_PERF_NO_ENFORCE` like every
//! bench): parallel build ≥ 4× the validating sequential build at 10⁶
//! nodes; implicit topology ≤ 1 KB resident regardless of n (asserted
//! hard — memory is deterministic, no machine excuse); `scale_10m`
//! completes its horizon on the implicit backend.
//!
//! Writes `BENCH_graph.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_GRAPH_N` shrinks the build-benchmark node count
//! (CI smoke), `DECAFORK_GRAPH_WORKERS` sets the pool size (default 7
//! workers = 8 lanes), `DECAFORK_PERF_STEPS` rescales the 10m probe's
//! horizon, `DECAFORK_NODE_STATE=dense|lazy` selects the probe's
//! node-state store (default lazy — O(visited) state instead of ~1 GB
//! of dense columns; the two modes are bit-identical, see
//! `benches/perf_state.rs`), `DECAFORK_PERF_SKIP_10M=1` skips the
//! probe, `DECAFORK_PERF_NO_ENFORCE=1` downgrades the speedup gate to
//! a report.

mod perf_common;

use decafork::graph::{build, Graph, ImplicitTopology};
use decafork::rng::Rng;
use decafork::runtime::WorkerPool;
use perf_common::{enforce_bar, env_u64, steps_per_sec, write_bench_json};
use std::time::Instant;

/// Best-of-3 wall time for a build closure (builds are one-shot, so a
/// min over a few reps is the stable statistic).
fn clock<T>(mut f: impl FnMut() -> T) -> (f64, T) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..3 {
        let t0 = Instant::now();
        let v = f();
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(v);
    }
    (best, out.unwrap())
}

fn assert_same_graph(a: &Graph, b: &Graph, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: node count");
    assert_eq!(a.m(), b.m(), "{what}: edge count");
    for i in 0..a.n() {
        assert_eq!(a.neighbors(i).to_vec(), b.neighbors(i), "{what}: neighbors of {i}");
    }
}

fn main() -> anyhow::Result<()> {
    let n_build = env_u64("DECAFORK_GRAPH_N").map(|n| (n as usize).max(50_000)).unwrap_or(1_000_000);
    let workers = env_u64("DECAFORK_GRAPH_WORKERS")
        .map(|w| w as usize)
        .filter(|&w| w >= 1)
        .unwrap_or(7);
    let mut pool = WorkerPool::new(workers);

    // ---- Parallel CSR assembly vs the sequential paths ----
    // Deterministic 8-regular edge list (4·n edges) from the circulant
    // family, so every run on every machine builds the same graph.
    let edges = ImplicitTopology::ring_lattice(n_build, 8)?.edge_list();
    println!(
        "perf_graph: CSR assembly at n = {n_build} ({} edges), {} lanes\n",
        edges.len(),
        workers + 1
    );

    let (t_validating, g_seq) = clock(|| Graph::from_edges(n_build, &edges).unwrap());
    println!("  from_edges (validating) : {:>8.1} ms", t_validating * 1e3);
    let (t_trusted, g_trusted) = clock(|| Graph::from_edges_trusted(n_build, &edges));
    println!("  from_edges_trusted      : {:>8.1} ms", t_trusted * 1e3);
    let (t_parallel, g_par) = clock(|| build::from_edges_parallel(n_build, &edges, &mut pool));
    println!("  from_edges_parallel     : {:>8.1} ms", t_parallel * 1e3);
    assert_same_graph(&g_seq, &g_trusted, "trusted vs validating");
    assert_same_graph(&g_seq, &g_par, "parallel vs validating");
    let speedup = t_validating / t_parallel;
    let trusted_ratio = t_trusted / t_parallel;
    println!("  speedup vs validating   : {speedup:>8.2}x  (acceptance bar: >= 4.0x)");
    println!("  speedup vs trusted      : {trusted_ratio:>8.2}x");

    let (t_bfs_seq, conn_seq) = clock(|| g_seq.is_connected());
    let (t_bfs_par, conn_par) = clock(|| build::is_connected_parallel(&g_par, &mut pool));
    assert_eq!(conn_seq, conn_par, "connectivity answers diverged");
    assert!(conn_seq, "ring lattice must be connected");
    println!(
        "  is_connected seq/par    : {:>8.1} / {:.1} ms (agree: {conn_seq})",
        t_bfs_seq * 1e3,
        t_bfs_par * 1e3
    );

    // ---- Implicit backend: memory budget + bit-compat + hop rate ----
    // Budget asserted at 10⁸ nodes: the whole topology must fit in 1 KB
    // no matter how large n gets (that is the point of the backend).
    let huge = Graph::from_implicit(ImplicitTopology::small_world(
        100_000_000,
        8,
        &mut Rng::new(0xCAFE6),
    )?);
    let mem = huge.memory_bytes();
    let mem_per_node = mem as f64 / huge.n() as f64;
    println!("\n  implicit @ 10^8 nodes   : {mem} B total ({mem_per_node:.2e} B/node)");
    assert!(mem <= 1024, "implicit topology must stay O(1) memory, got {mem} B");

    // Bit-compat oracle at a materializable size: same neighbors, and a
    // 50k-hop step stream that is draw-for-draw identical.
    let imp = Graph::from_implicit(ImplicitTopology::small_world(100_000, 8, &mut Rng::new(7))?);
    let mat = imp.materialize();
    assert_same_graph(&mat, &imp, "implicit vs materialized");
    {
        let (mut ra, mut rb) = (Rng::new(99), Rng::new(99));
        let (mut pa, mut pb) = (0usize, 0usize);
        for _ in 0..50_000 {
            pa = imp.step(pa, &mut ra);
            pb = mat.step(pb, &mut rb);
            assert_eq!(pa, pb, "implicit step stream diverged from CSR");
        }
    }
    let hops = 2_000_000u64;
    let (t_imp_hops, _) = clock(|| {
        let mut rng = Rng::new(3);
        let mut pos = 0usize;
        for _ in 0..hops {
            pos = huge.step(pos, &mut rng);
        }
        pos
    });
    let implicit_hops_per_sec = hops as f64 / t_imp_hops;
    println!("  implicit step @ 10^8    : {implicit_hops_per_sec:>12.0} hops/s");

    // ---- scale_10m completion probe (implicit backend end-to-end) ----
    let skip_10m = std::env::var("DECAFORK_PERF_SKIP_10M").is_ok();
    let mut scale10m = decafork::scenario::presets::scale_10m();
    // ISSUE 7: honor the benches' node-state mirror (default lazy —
    // O(visited) state instead of ~1 GB of dense columns at 10^7).
    scale10m.params.node_state = decafork::scenario::parse::node_state_from_env()?;
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS").map(|s| s.max(100)) {
        scale10m.rescale_to(steps);
    }
    let sps_10m = if skip_10m {
        println!("\nscale_10m: skipped (DECAFORK_PERF_SKIP_10M)");
        None
    } else {
        println!("\nscale_10m: {} | {} steps", scale10m.label(), scale10m.horizon);
        let mut e = scale10m.sharded_engine(0, workers + 1)?;
        assert!(e.graph.is_implicit(), "scale_10m must run on the implicit backend");
        let t0 = Instant::now();
        e.run_to(scale10m.horizon);
        let dt = t0.elapsed().as_secs_f64();
        let trace = e.into_trace();
        anyhow::ensure!(
            !trace.extinct,
            "scale_10m went extinct before its {}-step horizon — the completion \
             criterion is not met",
            scale10m.horizon
        );
        let sps = steps_per_sec(&trace, dt);
        println!(
            "  {} workers            : {sps:>12.1} steps/s (final z = {})",
            workers + 1,
            trace.z.last().unwrap()
        );
        Some(sps)
    };

    let pass = speedup >= 4.0;
    let sps_10m_json = sps_10m.map(|v| format!("{v:.1}")).unwrap_or_else(|| "null".into());
    let json = format!(
        "{{\n  \"bench\": \"perf_graph\",\n  \"mode\": \"parallel CSR assembly + implicit topology backend, outputs asserted identical\",\n  \"lanes\": {},\n  \"build\": {{\n    \"n\": {n_build},\n    \"edges\": {},\n    \"from_edges_ms\": {:.1},\n    \"from_edges_trusted_ms\": {:.1},\n    \"from_edges_parallel_ms\": {:.1},\n    \"speedup_vs_validating\": {speedup:.3},\n    \"speedup_vs_trusted\": {trusted_ratio:.3}\n  }},\n  \"implicit\": {{\n    \"n\": 100000000,\n    \"memory_bytes_total\": {mem},\n    \"memory_bytes_per_node\": {mem_per_node:.3e},\n    \"hops_per_sec\": {implicit_hops_per_sec:.0}\n  }},\n  \"scale_10m\": {{\n    \"graph\": \"{}\",\n    \"z0\": {},\n    \"steps\": {},\n    \"steps_per_sec\": {sps_10m_json},\n    \"completed\": {}\n  }},\n  \"acceptance_min_speedup\": 4.0,\n  \"pass\": {pass}\n}}\n",
        workers + 1,
        edges.len(),
        t_validating * 1e3,
        t_trusted * 1e3,
        t_parallel * 1e3,
        scale10m.graph.label(),
        scale10m.params.z0,
        scale10m.horizon,
        !skip_10m
    );
    let out = write_bench_json("BENCH_graph.json", &json)?;

    enforce_bar(pass, format!("perf_graph below the 4.0x parallel-build bar — see {out}"))
}
