//! Arrival-routing bench (ISSUE 8): proves the mailbox path — hop
//! workers binning arrivals into per-(chunk × destination-shard)
//! mailboxes — beats the serial coordinator scan on a routing-dominated
//! workload, without moving a single bit of the trace.
//!
//! Two legs:
//!
//! 1. **route_100k serial vs mailbox** at the full worker count: the
//!    `scale_100k` topology with the walk population doubled, so the
//!    coordinator's O(live-walks) inter-phase scan is a first-order
//!    term of the step profile (it is the serial fraction Amdahl charges
//!    at any worker count). Before any clock is trusted the leg
//!    **asserts `Trace::bit_identical`** between the two routings — z,
//!    the full event log, flags, and every θ̂ float at the bit level. A
//!    "routing win" that moved a bit is a bug, not a result.
//!    Acceptance bar: mailbox ≥ 1.5× serial steps/s.
//! 2. **single-worker overhead report**: both routings at 1 shard
//!    (report only — mailbox pays its binning with nobody to hand the
//!    work to, and this leg prices that honestly).
//!
//! Writes `BENCH_route.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_ROUTE_N` shrinks leg 1's node count (CI smoke),
//! `DECAFORK_PERF_STEPS` rescales the horizon, `DECAFORK_ROUTE_WORKERS`
//! sets the worker count (default 7 workers = 8 shards),
//! `DECAFORK_PIN_CORES=on` additionally pins workers to cores (off by
//! default — CI runners are cgroup-restricted), and
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the speedup bar to a report
//! (the bit-identical assert is **never** downgraded).

mod perf_common;

use decafork::scenario::{parse, presets, GraphSpec, Scenario};
use decafork::sim::engine::RoutingMode;
use perf_common::{assert_bit_identical, enforce_bar, env_u64, write_bench_json};
use std::time::Instant;

struct Run {
    secs: f64,
    trace: decafork::sim::metrics::Trace,
}

/// Build, run to the horizon, and measure one scenario/routing cell.
fn run_cell(
    scenario: &Scenario,
    routing: RoutingMode,
    shards: usize,
    pin: bool,
) -> anyhow::Result<Run> {
    let mut s = scenario.clone();
    s.params.routing = routing;
    s.params.pin_cores = pin;
    let mut e = s.sharded_engine(0, shards)?;
    let t0 = Instant::now();
    e.run_to(s.horizon);
    let secs = t0.elapsed().as_secs_f64();
    Ok(Run { secs, trace: e.into_trace() })
}

fn steps_per_sec(r: &Run) -> f64 {
    perf_common::steps_per_sec(&r.trace, r.secs)
}

fn main() -> anyhow::Result<()> {
    let workers = env_u64("DECAFORK_ROUTE_WORKERS").map(|w| (w as usize).max(1)).unwrap_or(7);
    let shards = workers + 1;
    let pin = parse::pin_cores_from_env()?;

    // ---- Leg 1: serial vs mailbox on the routing-dominated preset ----
    let mut r1 = presets::route_100k();
    r1.params.record_theta = true; // θ̂ floats must match bit-for-bit too
    let n1 = env_u64("DECAFORK_ROUTE_N").map(|n| (n as usize).max(1_000)).unwrap_or(100_000);
    if n1 != 100_000 {
        r1.graph = GraphSpec::RandomRegular { n: n1, d: 8 };
    }
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS") {
        r1.rescale_to(steps.max(50));
    }
    println!(
        "perf_route leg 1: {} | {} steps | {shards} shards | pin_cores={pin}",
        r1.label(),
        r1.horizon
    );

    let serial = run_cell(&r1, RoutingMode::Serial, shards, pin)?;
    let mailbox = run_cell(&r1, RoutingMode::Mailbox, shards, pin)?;

    // The oracle comes before the clock: identical bits or no result.
    assert_bit_identical(
        &serial.trace,
        &mailbox.trace,
        "mailbox routing diverged from the serial scan",
    );
    let (ss, sm) = (steps_per_sec(&serial), steps_per_sec(&mailbox));
    let speedup = sm / ss;
    println!("  steps/s serial          : {ss:>8.1}");
    println!("  steps/s mailbox         : {sm:>8.1}");
    println!("  mailbox / serial        : {speedup:>8.2}x  (acceptance bar: >= 1.5x)");
    let pass = speedup >= 1.5;

    // ---- Leg 2: single-worker overhead report (both routings) ----
    let s1 = run_cell(&r1, RoutingMode::Serial, 1, false)?;
    let m1 = run_cell(&r1, RoutingMode::Mailbox, 1, false)?;
    assert!(
        s1.trace.bit_identical(&m1.trace),
        "mailbox routing diverged from serial at 1 shard"
    );
    let (ss1, sm1) = (steps_per_sec(&s1), steps_per_sec(&m1));
    println!("\nperf_route leg 2: 1 shard (routing overhead, report only)");
    println!("  steps/s serial / mailbox: {ss1:>8.1} / {sm1:.1} ({:.2}x)", sm1 / ss1);

    let json = format!(
        "{{\n  \"bench\": \"perf_route\",\n  \"mode\": \"mailbox arrival routing vs serial coordinator scan, traces asserted bit-identical\",\n  \"shards\": {shards},\n  \"pin_cores\": {pin},\n  \"route_100k\": {{\n    \"n\": {n1},\n    \"steps\": {},\n    \"bit_identical\": true,\n    \"theta_samples_compared\": {},\n    \"steps_per_sec_serial\": {ss:.1},\n    \"steps_per_sec_mailbox\": {sm:.1},\n    \"speedup_mailbox_over_serial\": {speedup:.3}\n  }},\n  \"single_shard\": {{\n    \"steps_per_sec_serial\": {ss1:.1},\n    \"steps_per_sec_mailbox\": {sm1:.1}\n  }},\n  \"acceptance_min_speedup\": 1.5,\n  \"pass\": {pass}\n}}\n",
        r1.horizon,
        serial.trace.theta.len(),
    );
    let out = write_bench_json("BENCH_route.json", &json)?;

    enforce_bar(pass, format!("perf_route speedup bar not met ({speedup:.2}x < 1.5x) — see {out}"))
}
