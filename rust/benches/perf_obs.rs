//! Observability overhead bench (ISSUE 10): proves the telemetry layer
//! is *zero-perturbation* (traces bit-identical with metrics off vs
//! jsonl vs csv) and *near-zero-cost* (enabled jsonl streaming keeps
//! ≥ 95% of the disabled path's steps/s) on the routing-dominated
//! `route_100k` workload.
//!
//! Two legs:
//!
//! 1. **off vs jsonl** at the full worker count, flush period 1 (a
//!    record every step — the worst case for sink overhead). Before any
//!    clock is trusted the leg **asserts `Trace::bit_identical`**
//!    between the runs — z, the full event log, flags, and every θ̂
//!    float at the bit level. Telemetry that moved a bit is a bug, not
//!    an overhead number. Acceptance bar: jsonl ≥ 0.95× off steps/s.
//! 2. **csv report leg**: same scenario with the csv sink (report only
//!    — the formats share every code path except row formatting), plus
//!    a row-count check: one record per step, exactly.
//!
//! Writes `BENCH_obs.json` (or `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_ROUTE_N` shrinks the node count (CI smoke),
//! `DECAFORK_PERF_STEPS` rescales the horizon, `DECAFORK_ROUTE_WORKERS`
//! sets the worker count (default 7 workers = 8 shards), and
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the overhead bar to a report
//! (the bit-identical assert is **never** downgraded).

mod perf_common;

use decafork::obs::{MetricsConfig, MetricsMode};
use decafork::scenario::{presets, GraphSpec, Scenario};
use perf_common::{assert_bit_identical, enforce_bar, env_u64, write_bench_json};
use std::time::Instant;

struct Run {
    secs: f64,
    trace: decafork::sim::metrics::Trace,
}

/// Build, run to the horizon, and measure one scenario/metrics cell.
fn run_cell(scenario: &Scenario, metrics: MetricsConfig, shards: usize) -> anyhow::Result<Run> {
    let mut s = scenario.clone();
    s.params.metrics = metrics;
    let mut e = s.sharded_engine(0, shards)?;
    let t0 = Instant::now();
    e.run_to(s.horizon);
    let secs = t0.elapsed().as_secs_f64();
    Ok(Run { secs, trace: e.into_trace() })
}

fn steps_per_sec(r: &Run) -> f64 {
    perf_common::steps_per_sec(&r.trace, r.secs)
}

fn tmp(name: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("decafork_perf_obs_{}_{name}", std::process::id()));
    p.to_string_lossy().into_owned()
}

fn main() -> anyhow::Result<()> {
    let workers = env_u64("DECAFORK_ROUTE_WORKERS").map(|w| (w as usize).max(1)).unwrap_or(7);
    let shards = workers + 1;

    let mut sc = presets::route_100k();
    sc.params.record_theta = true; // θ̂ floats must match bit-for-bit too
    let n = env_u64("DECAFORK_ROUTE_N").map(|n| (n as usize).max(1_000)).unwrap_or(100_000);
    if n != 100_000 {
        sc.graph = GraphSpec::RandomRegular { n, d: 8 };
    }
    if let Some(steps) = env_u64("DECAFORK_PERF_STEPS") {
        sc.rescale_to(steps.max(50));
    }
    println!(
        "perf_obs leg 1: {} | {} steps | {shards} shards | metrics off vs jsonl (every=1)",
        sc.label(),
        sc.horizon
    );

    // ---- Leg 1: off vs jsonl, record-per-step (worst case) ----
    let off = run_cell(&sc, MetricsConfig::default(), shards)?;
    let jsonl_path = tmp("leg1.jsonl");
    let jsonl = run_cell(
        &sc,
        MetricsConfig { mode: MetricsMode::Jsonl, out: Some(jsonl_path.clone()), every: 1 },
        shards,
    )?;

    // The oracle comes before the clock: identical bits or no result.
    assert_bit_identical(
        &off.trace,
        &jsonl.trace,
        "jsonl telemetry perturbed the trace",
    );
    let rows = std::fs::read_to_string(&jsonl_path)?.lines().count();
    let steps = perf_common::steps_simulated(&jsonl.trace);
    assert_eq!(rows, steps, "jsonl sink must emit exactly one record per simulated step");
    std::fs::remove_file(&jsonl_path).ok();

    let (so, sj) = (steps_per_sec(&off), steps_per_sec(&jsonl));
    let ratio = sj / so;
    println!("  steps/s metrics off     : {so:>8.1}");
    println!("  steps/s metrics jsonl   : {sj:>8.1}");
    println!("  jsonl / off             : {ratio:>8.3}x  (acceptance bar: >= 0.95x)");
    let pass = ratio >= 0.95;

    // ---- Leg 2: csv report (bit-identity + row cadence only) ----
    let csv_path = tmp("leg2.csv");
    let csv = run_cell(
        &sc,
        MetricsConfig { mode: MetricsMode::Csv, out: Some(csv_path.clone()), every: 1 },
        shards,
    )?;
    assert!(off.trace.bit_identical(&csv.trace), "csv telemetry perturbed the trace");
    let csv_rows = std::fs::read_to_string(&csv_path)?.lines().count();
    assert_eq!(csv_rows, steps + 1, "csv = header + one row per step");
    std::fs::remove_file(&csv_path).ok();
    let sc_csv = steps_per_sec(&csv);
    println!("\nperf_obs leg 2: csv sink (report only)");
    println!("  steps/s metrics csv     : {sc_csv:>8.1} ({:.3}x of off)", sc_csv / so);

    let json = format!(
        "{{\n  \"bench\": \"perf_obs\",\n  \"mode\": \"streaming telemetry overhead vs metrics-off, traces asserted bit-identical\",\n  \"shards\": {shards},\n  \"route_100k\": {{\n    \"n\": {n},\n    \"steps\": {steps},\n    \"bit_identical\": true,\n    \"theta_samples_compared\": {},\n    \"jsonl_rows\": {rows},\n    \"steps_per_sec_off\": {so:.1},\n    \"steps_per_sec_jsonl\": {sj:.1},\n    \"steps_per_sec_csv\": {sc_csv:.1},\n    \"jsonl_over_off\": {ratio:.4}\n  }},\n  \"acceptance_min_ratio\": 0.95,\n  \"pass\": {pass}\n}}\n",
        off.trace.theta.len(),
    );
    let out = write_bench_json("BENCH_obs.json", &json)?;

    enforce_bar(
        pass,
        format!("perf_obs overhead bar not met ({ratio:.3}x < 0.95x of metrics-off) — see {out}"),
    )
}
