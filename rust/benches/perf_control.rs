//! Control-path throughput: survival-cached θ̂ (arena engine) vs direct
//! θ̂ (frozen reference engine) on the **control-bound** workloads —
//! DECAFORK / DECAFORK+ at Z0 = 256 on a 1000-node churn scenario, both
//! survival families (`presets::perf_control_{geometric,empirical}`).
//! This is the regime `perf_engine` deliberately avoids (its PeriodicFork
//! scenario keeps the workload engine-bound); here the Θ(known-walks)
//! estimator *is* the cost, and the measurement isolates what the
//! [`SurvivalTable`] memo + SoA node columns buy.
//!
//! Both engines are built from the same `Scenario` (identical graph and
//! RNG streams); the bench **asserts byte-identical z-traces** before
//! reporting any number — a perf win that changes a single fork decision
//! is a bug, not a result.
//!
//! Also reports the arena-only `scale_10k` probe (10k nodes, 1024 walks)
//! as absolute steps/sec; the reference engine at that size runs minutes
//! per attempt and would tell us nothing new.
//!
//! Writes `BENCH_control.json` (to the bench's working directory — the
//! `rust/` package root under cargo — or to `$DECAFORK_BENCH_OUT`).
//! Acceptance bar: speedup ≥ 3.0 on both control-bound scenarios,
//! **enforced** — the bench exits nonzero below the bar, so the CI
//! smoke step is a real perf gate.
//!
//! Env knobs: `DECAFORK_PERF_STEPS` rescales every horizon
//! ([`Scenario::rescale_to`] — burst times, control warm-up and the
//! step count shrink proportionally), `DECAFORK_BENCH_OUT` sets the
//! JSON path, `DECAFORK_PERF_NO_ENFORCE=1` downgrades the gate to a
//! report.
//!
//! [`SurvivalTable`]: decafork::stats::SurvivalTable

mod perf_common;

use decafork::scenario::{presets, Scenario};
use perf_common::{enforce_bar, env_u64, steps_per_sec, write_bench_json};
use std::time::Instant;

struct Pair {
    name: &'static str,
    reference_sps: f64,
    arena_sps: f64,
    speedup: f64,
}

/// Run reference (direct θ̂) then arena (cached θ̂) and demand identical
/// traces before trusting the clock.
fn run_pair(name: &'static str, scenario: &Scenario) -> anyhow::Result<Pair> {
    let horizon = scenario.horizon;

    // Clocks cover only the stepping: graph generation and node-state
    // allocation are identical setup work on both sides and would bias
    // the short smoke runs toward 1.0x.
    let mut reference = scenario.reference_engine(0)?;
    let t0 = Instant::now();
    reference.run_to(horizon);
    let dt_ref = t0.elapsed().as_secs_f64();

    let mut arena = scenario.engine(0)?;
    let t0 = Instant::now();
    arena.run_to(horizon);
    let dt_arena = t0.elapsed().as_secs_f64();

    assert_eq!(
        arena.trace().z,
        reference.trace().z,
        "{name}: cached θ̂ diverged from direct — perf numbers would be meaningless"
    );
    assert_eq!(arena.trace().extinct, reference.trace().extinct, "{name}: extinction flag");
    assert_eq!(arena.trace().capped, reference.trace().capped, "{name}: cap flag");

    let reference_sps = steps_per_sec(reference.trace(), dt_ref);
    let arena_sps = steps_per_sec(arena.trace(), dt_arena);
    let speedup = arena_sps / reference_sps;
    println!("{name}: {} steps, final z = {}", horizon, arena.alive());
    println!("  reference (direct θ̂) : {reference_sps:>12.1} steps/s  ({dt_ref:.2}s)");
    println!("  arena (cached θ̂)     : {arena_sps:>12.1} steps/s  ({dt_arena:.2}s)");
    println!("  speedup              : {speedup:>12.2}x  (acceptance bar: >= 3.0x)");
    Ok(Pair { name, reference_sps, arena_sps, speedup })
}

fn main() -> anyhow::Result<()> {
    let quick_steps = env_u64("DECAFORK_PERF_STEPS").map(|s| s.max(200));

    let mut geometric = presets::perf_control_geometric();
    let mut empirical = presets::perf_control_empirical();
    let mut scale = presets::scale_10k();
    if let Some(steps) = quick_steps {
        geometric.rescale_to(steps);
        empirical.rescale_to(steps);
        // The 10k-node probe is ~4x the per-step work; keep smoke runs
        // inside a CI minute.
        scale.rescale_to((steps / 2).max(100));
    }

    println!("perf_control: θ̂-bound workloads, cached vs direct estimator\n");
    let pairs = [
        run_pair("perf_control_geometric", &geometric)?,
        run_pair("perf_control_empirical", &empirical)?,
    ];

    // Arena-only scale probe (again, clock excludes the graph build).
    let mut big = scale.engine(0)?;
    let t0 = Instant::now();
    big.run_to(scale.horizon);
    let dt_big = t0.elapsed().as_secs_f64();
    let big_sps = steps_per_sec(big.trace(), dt_big);
    println!("scale_10k: {} steps, final z = {}", scale.horizon, big.alive());
    println!("  arena (cached θ̂)     : {big_sps:>12.1} steps/s  ({dt_big:.2}s, arena-only)");

    let pass = pairs.iter().all(|p| p.speedup >= 3.0);
    let scenarios = pairs
        .iter()
        .map(|p| {
            format!(
                "    \"{}\": {{\n      \"reference_steps_per_sec\": {:.1},\n      \"arena_steps_per_sec\": {:.1},\n      \"speedup\": {:.3}\n    }}",
                p.name, p.reference_sps, p.arena_sps, p.speedup
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"perf_control\",\n  \"workload\": \"1000-node churn, Z0=256, DECAFORK/DECAFORK+, both survival families\",\n  \"steps\": {},\n  \"scenarios\": {{\n{scenarios},\n    \"scale_10k\": {{\n      \"graph\": \"random-regular n=10000 d=8\",\n      \"z0\": 1024,\n      \"steps\": {},\n      \"arena_steps_per_sec\": {:.1}\n    }}\n  }},\n  \"acceptance_min_speedup\": 3.0,\n  \"pass\": {pass}\n}}\n",
        geometric.horizon, scale.horizon, big_sps
    );
    let out = write_bench_json("BENCH_control.json", &json)?;

    // The gate is a gate: a regression below the bar fails the bench
    // (and the CI smoke step) instead of hiding in an artifact nobody
    // reads. `DECAFORK_PERF_NO_ENFORCE=1` downgrades it to a report for
    // exploratory runs on busy machines.
    enforce_bar(pass, format!("perf_control below the 3.0x acceptance bar — see {out}"))
}
