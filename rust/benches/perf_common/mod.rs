//! Shared plumbing for the `perf_*` benches (ISSUE 9 satellite): every
//! bench in this directory is a plain-`main` binary that (1) reads the
//! same family of `DECAFORK_*` env knobs, (2) asserts its A/B traces
//! **bit-identical before any clock is trusted**, (3) writes a
//! `BENCH_*.json` report to `$DECAFORK_BENCH_OUT` or a default path,
//! and (4) enforces its acceptance bar unless
//! `DECAFORK_PERF_NO_ENFORCE=1`. That boilerplate used to be
//! copy-pasted per bench; it lives here now, compiled into each bench
//! via `mod perf_common;` (the directory form keeps cargo's bench
//! auto-discovery from treating this file as a bench target of its
//! own).
//!
//! The one rule the helpers encode and never relax: the speedup /
//! memory bars are *downgradeable* (reports on weak CI runners), the
//! bit-identical oracle is **not** — `assert_bit_identical` is a hard
//! `assert!` with no env escape hatch. A perf win that moved a bit is
//! a bug, not a result.

#![allow(dead_code)] // each bench uses the subset it needs

use decafork::sim::metrics::Trace;

/// Parse a `u64` env knob; unset or unparsable means "use the default".
pub fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.parse::<u64>().ok())
}

/// `DECAFORK_PERF_NO_ENFORCE=1` (any value) downgrades acceptance bars
/// to reports. It never touches the bit-identical oracle.
pub fn no_enforce() -> bool {
    std::env::var("DECAFORK_PERF_NO_ENFORCE").is_ok()
}

/// Steps actually simulated before extinction (for honest steps/s on
/// traces that die early), never less than 1.
pub fn steps_simulated(trace: &Trace) -> usize {
    trace.z.iter().position(|&z| z == 0).unwrap_or(trace.z.len() - 1).max(1)
}

/// Steps per wall-clock second for one measured cell.
pub fn steps_per_sec(trace: &Trace, secs: f64) -> f64 {
    steps_simulated(trace) as f64 / secs
}

/// The oracle that comes before the clock: the A and B traces must be
/// bit-identical (z, event log, flags, every θ̂ float at the bit level)
/// and must have recorded θ̂ samples at all — a comparison over an
/// empty telemetry stream proves nothing. Hard assert, no env gate.
pub fn assert_bit_identical(a: &Trace, b: &Trace, what: &str) {
    assert!(a.bit_identical(b), "{what} — the A/B variants must be invisible to the trace");
    assert!(!a.theta.is_empty(), "{what}: no θ̂ recorded — the oracle would be vacuous");
    println!("  bit-identical           : yes ({} θ̂ samples compared)", a.theta.len());
}

/// Resolve the report path: `$DECAFORK_BENCH_OUT` wins, else `default`.
pub fn bench_out(default: &str) -> String {
    std::env::var("DECAFORK_BENCH_OUT").unwrap_or_else(|_| default.into())
}

/// Write the report JSON to [`bench_out`]`(default)` and echo the path.
pub fn write_bench_json(default: &str, json: &str) -> anyhow::Result<String> {
    let out = bench_out(default);
    std::fs::write(&out, json)?;
    println!("\n  wrote {out}");
    Ok(out)
}

/// Enforce an acceptance bar: no-op when it passed or when
/// `DECAFORK_PERF_NO_ENFORCE=1`, otherwise bail with the bench's
/// message (which should name the report file).
pub fn enforce_bar(pass: bool, msg: String) -> anyhow::Result<()> {
    if !pass && !no_enforce() {
        anyhow::bail!(msg);
    }
    Ok(())
}
