//! Within-run sharding throughput: the stream-mode `ShardedEngine` at 1
//! worker vs `DECAFORK_SHARDS_HI` (default 8) workers on the same
//! scenario — the measurement ISSUE 3's acceptance bar (≥ 3× steps/sec
//! at 8 shards on `scale_100k`) is taken from — plus the `scale_1m`
//! completion probe (one million nodes, 1000-step horizon, absolute
//! steps/sec).
//!
//! Before any clock is trusted the bench **asserts the two traces are
//! bit-identical** (`perf_common::assert_bit_identical`: z, events, θ̂
//! bits, flags — θ̂ recording is turned on so the float comparison is
//! non-vacuous) — schedule invariance is the whole point; a "speedup"
//! that moved one fork decision is a bug, not a result. Note both
//! sides are stream mode: this measures what worker threads buy
//! *within* the per-walk stream family, not stream-vs-shared-stream
//! semantics (those are different trace families by design).
//!
//! Writes `BENCH_shard.json` (to the bench's working directory — the
//! `rust/` package root under cargo — or to `$DECAFORK_BENCH_OUT`).
//!
//! Env knobs: `DECAFORK_PERF_STEPS` rescales the horizons
//! ([`Scenario::rescale_to`]), `DECAFORK_SHARDS_HI` sets the high worker
//! count, `DECAFORK_PERF_SKIP_1M=1` skips the million-node probe (CI
//! smoke: the graph build alone is tens of seconds),
//! `DECAFORK_PERF_NO_ENFORCE=1` downgrades the ≥ 3× gate to a report
//! (CI smoke runs on 2-core runners where the bar is unreachable).

mod perf_common;

use decafork::scenario::{presets, Scenario};
use perf_common::{assert_bit_identical, enforce_bar, env_u64, steps_per_sec, write_bench_json};
use std::time::Instant;

fn run_once(scenario: &Scenario, shards: usize) -> anyhow::Result<(f64, decafork::sim::Trace)> {
    // Clock covers only the stepping: the graph build is identical setup
    // work at every shard count and would bias short smoke runs.
    let mut e = scenario.sharded_engine(0, shards)?;
    let t0 = Instant::now();
    e.run_to(scenario.horizon);
    let dt = t0.elapsed().as_secs_f64();
    let trace = e.into_trace();
    // Rate over steps actually simulated — an extinct run stops early
    // (the trace is only zero-padded from the first z = 0 on), and
    // horizon/dt would flatter it.
    Ok((steps_per_sec(&trace, dt), trace))
}

fn main() -> anyhow::Result<()> {
    let quick_steps = env_u64("DECAFORK_PERF_STEPS").map(|s| s.max(100));
    let hi_shards = env_u64("DECAFORK_SHARDS_HI")
        .map(|v| v as usize)
        .filter(|&s| s >= 2)
        .unwrap_or(8);

    let mut scale100k = presets::scale_100k();
    // θ̂ floats join the bit-identical oracle (symmetric across both
    // arms, so the speedup ratio is untouched).
    scale100k.params.record_theta = true;
    let mut scale1m = presets::scale_1m();
    if let Some(steps) = quick_steps {
        scale100k.rescale_to(steps);
        scale1m.rescale_to(steps.max(200));
    }

    println!("perf_shard: stream-mode engine, 1 vs {hi_shards} workers\n");
    println!(
        "scale_100k: {} | {} steps",
        scale100k.label(),
        scale100k.horizon
    );
    let (sps_1, trace_1) = run_once(&scale100k, 1)?;
    println!("  1 worker             : {sps_1:>12.1} steps/s");
    let (sps_hi, trace_hi) = run_once(&scale100k, hi_shards)?;
    println!("  {hi_shards} workers            : {sps_hi:>12.1} steps/s");
    assert_bit_identical(
        &trace_1,
        &trace_hi,
        &format!(
            "scale_100k: trace diverged between 1 and {hi_shards} workers — \
             schedule invariance broken, perf numbers meaningless"
        ),
    );
    let speedup = sps_hi / sps_1;
    println!("  speedup              : {speedup:>12.2}x  (acceptance bar: >= 3.0x)");
    println!(
        "  events / final z     : {} / {}",
        trace_1.events.len(),
        trace_1.z.last().unwrap()
    );

    // The million-node completion probe (arena-scale memory + sharded
    // control): the criterion is that the horizon completes at all, with
    // the absolute rate recorded for the trajectory log.
    let skip_1m = std::env::var("DECAFORK_PERF_SKIP_1M").is_ok();
    let sps_1m = if skip_1m {
        println!("\nscale_1m: skipped (DECAFORK_PERF_SKIP_1M)");
        None
    } else {
        println!("\nscale_1m: {} | {} steps", scale1m.label(), scale1m.horizon);
        let (sps, trace) = run_once(&scale1m, hi_shards)?;
        anyhow::ensure!(
            !trace.extinct,
            "scale_1m went extinct before its {}-step horizon — the completion \
             criterion is not met",
            scale1m.horizon
        );
        println!(
            "  {hi_shards} workers            : {sps:>12.1} steps/s (final z = {})",
            trace.z.last().unwrap()
        );
        Some(sps)
    };

    let pass = speedup >= 3.0;
    let sps_1m_json = sps_1m.map(|v| format!("{v:.1}")).unwrap_or_else(|| "null".into());
    // Workload metadata comes from the presets (not hand-copied
    // literals), and key names are fixed — the worker count is a value
    // (`hi_workers`), so consumers keep parsing when DECAFORK_SHARDS_HI
    // changes.
    let json = format!(
        "{{\n  \"bench\": \"perf_shard\",\n  \"mode\": \"stream (per-walk RNG streams), trace bit-identical across worker counts\",\n  \"hi_workers\": {hi_shards},\n  \"scale_100k\": {{\n    \"graph\": \"{}\",\n    \"z0\": {},\n    \"steps\": {},\n    \"steps_per_sec_1_worker\": {sps_1:.1},\n    \"steps_per_sec_hi_workers\": {sps_hi:.1},\n    \"speedup\": {speedup:.3}\n  }},\n  \"scale_1m\": {{\n    \"graph\": \"{}\",\n    \"z0\": {},\n    \"steps\": {},\n    \"steps_per_sec_hi_workers\": {sps_1m_json},\n    \"completed\": {}\n  }},\n  \"acceptance_min_speedup\": 3.0,\n  \"pass\": {pass}\n}}\n",
        scale100k.graph.label(),
        scale100k.params.z0,
        scale100k.horizon,
        scale1m.graph.label(),
        scale1m.params.z0,
        scale1m.horizon,
        !skip_1m
    );
    let out = write_bench_json("BENCH_shard.json", &json)?;

    enforce_bar(pass, format!("perf_shard below the 3.0x acceptance bar — see {out}"))
}
